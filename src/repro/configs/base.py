"""Config system: model + parallelism + run configs.

Every assigned architecture is a `ModelConfig` in its own module under
repro/configs/; `repro.configs.registry` maps --arch ids to them.  Reduced
("smoke") variants shrink layers/width/experts for CPU tests while keeping
the family wiring identical.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]
Mixer = Literal["attention", "rwkv6", "rglru"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden size
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None          # default d_model // n_heads
    mixer: Mixer = "attention"
    # attention pattern: every `global_every`-th layer is global, the rest
    # use `sliding_window` local attention (None = all global/full).
    sliding_window: int | None = None
    global_every: int | None = None
    # hybrid (recurrentgemma): layers cycle [recurrent]*rnn_per + [attn]
    rnn_per_attention: int = 0
    rnn_width: int | None = None
    conv1d_width: int = 4
    moe: MoEConfig | None = None
    # encoder-decoder (whisper): encoder depth/length; frontend is a stub
    encoder_layers: int = 0
    encoder_seq: int = 0
    # vlm: patch-embedding stub
    n_patches: int = 0
    patch_dim: int = 0
    mlp_act: Literal["swiglu", "gelu"] = "swiglu"
    tie_embeddings: bool = True
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # set for archs where full attention makes 500k contexts intractable
    subquadratic: bool = False
    # ---- performance knobs (hillclimbed in EXPERIMENTS.md §Perf) ----
    # "full": recompute everything in bwd; "dots": save matmul outputs
    remat_policy: str = "full"
    # skip fully-masked kv blocks in causal blockwise attention (unrolls
    # the q-block loop; halves prefill attention FLOPs)
    causal_skip: bool = False
    # pin the microbatch grad accumulator to the param sharding (turns the
    # per-mb full-gradient all-reduce into a reduce-scatter)
    shard_grad_accum: bool = False
    # force the microbatch count (0 = auto from the 2 GB activation budget);
    # FSDP param-gather volume scales with it (paper: loop blocking)
    microbatch_override: int = 0
    # serve cells: keep params TP-sharded + data-replicated instead of
    # ZeRO/FSDP (no per-token param all-gather); training keeps FSDP
    serve_tp_params: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def params_count(self) -> int:
        """Approximate parameter count (embeddings + per-layer blocks)."""
        d, hd = self.d_model, self.resolved_head_dim
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        per = 0
        if self.mixer == "attention" or self.family in ("encdec",):
            per += d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads
            per += hd * self.n_heads * d
        if self.mixer == "rwkv6":
            per += 5 * d * d + d * d  # r,k,v,g,w(+lora approx) + out
        if self.moe:
            per_e = d * self.moe.d_expert * (3 if self.mlp_act == "swiglu" else 2)
            per += self.moe.num_experts * per_e + d * self.moe.num_experts
        else:
            per += d * self.d_ff * (3 if self.mlp_act == "swiglu" else 2)
        total = emb + self.n_layers * per
        if self.family == "encdec":
            total += self.encoder_layers * per
        return total

    def active_params_count(self) -> int:
        """Parameters touched per token (MoE: only top-k experts)."""
        if not self.moe:
            return self.params_count()
        d = self.d_model
        per_e = d * self.moe.d_expert * (3 if self.mlp_act == "swiglu" else 2)
        inactive = (self.moe.num_experts - self.moe.top_k) * per_e
        return self.params_count() - self.n_layers * inactive

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        kw: dict = dict(
            name=self.name + "-smoke",
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            d_ff=128,
            vocab=256,
            head_dim=16,
        )
        if self.moe:
            kw["moe"] = MoEConfig(
                num_experts=4, top_k=min(self.moe.top_k, 2), d_expert=32
            )
        if self.sliding_window:
            kw["sliding_window"] = 8
        if self.global_every:
            # one full (local*(ge-1), global) group + one tail local layer
            kw["n_layers"] = self.global_every + 1
        if self.rnn_per_attention:
            kw["rnn_width"] = 64
            # keep one full (rnn, ..., attn) group plus one tail rnn layer
            kw["n_layers"] = self.rnn_per_attention + 2
        if self.encoder_layers:
            kw["encoder_layers"] = 2
            kw["encoder_seq"] = 16
        if self.n_patches:
            kw["n_patches"] = 4
            kw["patch_dim"] = 32
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One of the assigned input-shape cells."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
