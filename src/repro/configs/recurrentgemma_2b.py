"""recurrentgemma-2b [hybrid] — RG-LRU + local attn 1:2 [arXiv:2402.19427; hf]."""
from repro.configs.base import ModelConfig

config = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab=256000, head_dim=256,
    sliding_window=2048, rnn_per_attention=2, rnn_width=2560,
    mlp_act="gelu", subquadratic=True,
)
