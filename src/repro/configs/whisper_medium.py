"""whisper-medium [audio] — enc-dec; conv frontend STUB [arXiv:2212.04356]."""
from repro.configs.base import ModelConfig

config = ModelConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=51865, head_dim=64,
    encoder_layers=24, encoder_seq=1500, mlp_act="gelu",
)
