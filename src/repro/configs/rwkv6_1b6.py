"""rwkv6-1.6b [ssm] — Finch, data-dependent decay, attention-free [arXiv:2404.05892]."""
from repro.configs.base import ModelConfig

config = ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=7168, vocab=65536, head_dim=64,
    mixer="rwkv6", subquadratic=True,
)
