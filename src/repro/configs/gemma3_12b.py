"""gemma3-12b [dense] — 5:1 local:global attention, 128k ctx [hf:google/gemma-3]."""
from repro.configs.base import ModelConfig

config = ModelConfig(
    name="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8,
    d_ff=15360, vocab=262144, head_dim=240,
    sliding_window=1024, global_every=6,    # 5 local : 1 global
    mlp_act="gelu", subquadratic=True,
)
