"""--arch registry: the 10 assigned architectures (+ smoke variants).

One module per architecture under repro/configs/ with the exact published
dims; this registry maps --arch ids to them.
"""

from __future__ import annotations

from repro.configs import (
    deepseek_7b,
    gemma3_12b,
    granite_8b,
    granite_moe_1b,
    grok_1_314b,
    llava_next_34b,
    recurrentgemma_2b,
    rwkv6_1b6,
    smollm_360m,
    whisper_medium,
)
from repro.configs.base import ModelConfig

_MODULES = (
    granite_8b, smollm_360m, deepseek_7b, gemma3_12b, rwkv6_1b6,
    whisper_medium, grok_1_314b, granite_moe_1b, llava_next_34b,
    recurrentgemma_2b,
)

ARCHS: dict[str, ModelConfig] = {m.config.name: m.config for m in _MODULES}


def get(name: str) -> ModelConfig:
    if name.endswith("-smoke"):
        return ARCHS[name[: -len("-smoke")]].smoke()
    return ARCHS[name]
