"""llava-next-34b [vlm] — anyres tiling; patch frontend STUB [hf:llava-hf]."""
from repro.configs.base import ModelConfig

config = ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab=64000, head_dim=128,
    n_patches=576, patch_dim=1024,
)
