"""Training loop: microbatched step, checkpoint/restart, straggler monitor.

The step function is built once per (model, mesh, plan):

  * grad accumulation via lax.scan over microbatches (activation memory is
    bounded by one microbatch - required at grok-1 scale),
  * per-layer remat inside the model (jax.checkpoint on scan bodies),
  * optional int8 gradient compression w/ error feedback,
  * donated params/opt-state so the update is in-place.

Fault tolerance:
  * AsyncCheckpointer snapshots every `ckpt_every` steps; restart resumes
    from the latest manifest (data pipeline is deterministic in step, so
    the sample stream continues exactly),
  * the straggler monitor tracks a rolling step-time median; steps slower
    than `straggler_factor` x median are logged and counted - the hook a
    real deployment wires to its reconfiguration controller (on CPU CI we
    assert the detection fires; we cannot actually evict a host),
  * elastic restore: ckpt.restore(shardings=...) re-lays leaves onto the
    current mesh, so a different host/chip count resumes the same state.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.arch.model_zoo import build
from repro.ckpt import checkpoint as ckpt
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, Pipeline
from repro.train import optim


@dataclasses.dataclass
class TrainConfig:
    steps: int = 20
    microbatches: int = 1
    ckpt_every: int = 10
    ckpt_dir: str | None = None
    straggler_factor: float = 3.0
    log_every: int = 5
    compress_grads: bool = False
    opt: optim.AdamWConfig = dataclasses.field(default_factory=optim.AdamWConfig)


def make_train_step(
    model, tcfg: TrainConfig
) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  batch leaves have a leading microbatch dim when
    tcfg.microbatches > 1."""

    def loss_fn(params, tokens, labels):
        return model.loss(params, tokens, labels)

    def train_step(params, opt_state, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        if tcfg.microbatches > 1:
            mb_tok = tokens.reshape(
                (tcfg.microbatches, -1) + tokens.shape[1:]
            )
            mb_lab = labels.reshape(
                (tcfg.microbatches, -1) + labels.shape[1:]
            )

            def mb_body(acc, tl):
                t, l = tl
                loss, g = jax.value_and_grad(loss_fn)(params, t, l)
                acc_g, acc_l = acc
                acc_g = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), acc_g, g
                )
                return (acc_g, acc_l + loss), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (gsum, lsum), _ = jax.lax.scan(
                mb_body, (zero, jnp.zeros((), jnp.float32)), (mb_tok, mb_lab)
            )
            grads = jax.tree.map(lambda g: g / tcfg.microbatches, gsum)
            loss = lsum / tcfg.microbatches
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, tokens, labels)
        params, opt_state, metrics = optim.apply_updates(
            tcfg.opt, params, grads, opt_state
        )
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


@dataclasses.dataclass
class StragglerMonitor:
    factor: float = 3.0
    window: int = 20
    times: list = dataclasses.field(default_factory=list)
    flagged: list = dataclasses.field(default_factory=list)

    def record(self, step: int, dt: float) -> bool:
        slow = False
        if len(self.times) >= 5:
            med = float(np.median(self.times[-self.window:]))
            if dt > self.factor * med:
                self.flagged.append((step, dt, med))
                slow = True
        self.times.append(dt)
        return slow


def train(
    cfg: ModelConfig,
    dcfg: DataConfig,
    tcfg: TrainConfig,
    *,
    resume: bool = True,
    pipeline: Pipeline | None = None,
    seed: int = 0,
) -> dict:
    """End-to-end (single-host) training driver; returns final metrics.

    The multi-pod variant only changes how params/batches are placed (see
    launch/train.py + parallel/sharding.py); the loop body is identical.
    """
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    opt_state = optim.init_state(params)
    start_step = 0

    saver = None
    if tcfg.ckpt_dir:
        saver = ckpt.AsyncCheckpointer(tcfg.ckpt_dir)
        if resume and ckpt.latest_step(tcfg.ckpt_dir) is not None:
            state = {"params": params, "opt": opt_state}
            state, extra = ckpt.restore(tcfg.ckpt_dir, state)
            params, opt_state = state["params"], state["opt"]
            start_step = int(extra.get("next_step", 0))

    step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=(0, 1))
    monitor = StragglerMonitor(factor=tcfg.straggler_factor)
    pipe = pipeline or Pipeline(dcfg, start_step=start_step)
    losses = []
    try:
        for step, batch in pipe:
            if step >= tcfg.steps:
                break
            t0 = time.perf_counter()
            jbatch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt_state, metrics = step_fn(params, opt_state, jbatch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            monitor.record(step, dt)
            losses.append(loss)
            if step % tcfg.log_every == 0:
                print(
                    f"step {step:5d} loss {loss:.4f} "
                    f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms"
                )
            if saver and (step + 1) % tcfg.ckpt_every == 0:
                saver.save_async(
                    step + 1,
                    {"params": params, "opt": opt_state},
                    extra={"next_step": step + 1},
                )
    finally:
        if pipeline is None:
            pipe.close()
        if saver:
            saver.wait()
    return {
        "losses": losses,
        "final_params": params,
        "stragglers": monitor.flagged,
        "last_step": step,
    }
