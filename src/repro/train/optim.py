"""Hand-rolled optimizers (no optax dependency): AdamW + schedules.

State layout mirrors the param pytree (m, v per leaf) so the same sharding
rules apply to optimizer state — required for the ZeRO-style sharded states
used at grok-1 scale.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (s - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * jnp.minimum(warm, 1.0) * decay


def init_state(params: Any) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def apply_updates(
    cfg: AdamWConfig, params: Any, grads: Any, state: dict
) -> tuple[Any, dict, dict]:
    """One AdamW step; returns (params, state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * gf * gf
        mh = m2 / (1 - b1 ** step.astype(jnp.float32))
        vh = v2 / (1 - b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
