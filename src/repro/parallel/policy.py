"""Logical-axis activation sharding policy.

Model code names activation dims logically (batch/seq/heads/ff/vocab/...);
launchers activate a mapping from logical names to mesh axes, and
`shard(x, ...)` emits with_sharding_constraint at trace time.  When no
policy is active (CPU unit tests) it is a no-op, so model code stays
mesh-agnostic.

This is the pod-scale analogue of the paper's `in`/`compute_at`: the policy
pins which loop dims live on which physical array dimension, and XLA's SPMD
partitioner materializes the data movement that choice implies (visible in
the dry-run's collective bytes).
"""

from __future__ import annotations

import contextlib
import math
from typing import Any, Mapping

import jax
from jax.sharding import PartitionSpec as P

_ACTIVE: dict[str, Any] | None = None
_AXIS_SIZES: dict[str, int] | None = None


def default_rules(mesh) -> dict[str, Any]:
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_ax = dp if len(dp) > 1 else (dp[0] if dp else None)
    return {
        "batch": dp_ax,
        "seq": None,
        "embed": None,          # residual stream replicated across 'model'
        "heads": "model",
        "kv_heads": "model",
        "ff": "model",
        "vocab": "model",
        "expert": None,
        "cap": None,
        "kv_seq": "model",      # flash-decoding style KV sharding
    }


@contextlib.contextmanager
def activate(mesh, rules: Mapping[str, Any] | None = None):
    global _ACTIVE, _AXIS_SIZES
    prev, prev_sizes = _ACTIVE, _AXIS_SIZES
    _ACTIVE = dict(default_rules(mesh))
    if rules:
        _ACTIVE.update(rules)
    _AXIS_SIZES = {name: mesh.shape[name] for name in mesh.axis_names}
    try:
        yield
    finally:
        _ACTIVE, _AXIS_SIZES = prev, prev_sizes


def _axis_size(ax) -> int:
    if ax is None:
        return 1
    if isinstance(ax, tuple):
        return math.prod(_axis_size(a) for a in ax)
    return _AXIS_SIZES.get(ax, 1)


def shard(x: jax.Array, *logical: str | None) -> jax.Array:
    """Apply the active policy to x; drops axes that don't divide."""
    if _ACTIVE is None:
        return x
    assert len(logical) == x.ndim, (logical, x.shape)
    spec = []
    for dim, name in zip(x.shape, logical):
        ax = _ACTIVE.get(name) if name else None
        if ax is not None and dim % _axis_size(ax) == 0:
            spec.append(ax)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, P(*spec))


def active() -> bool:
    return _ACTIVE is not None
