"""Sharding plans: pod-scale spatial unrolling of the model loop nest.

In the paper's taxonomy (core/dataflow.py) a distributed mapping is a spatial
unrolling of loops onto physical dims.  Here the physical dims are mesh axes:

    batch (B)          -> ('pod', 'data')     data parallel (+ pod DP)
    d_model / hidden   -> 'data'  (FSDP: params/opt-state sharded, gathered
                                   on use - ZeRO-3)
    heads / d_ff / V   -> 'model' (tensor parallel)
    KV-cache sequence  -> 'model' (flash-decoding style sequence sharding)

Rules are path-based over plain param pytrees; every rule degrades to
replication when a dim is not divisible by the axis size (uneven vocab
like granite-moe's 49155 stays replicated rather than failing to lower).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (path substring, spec for the TRAILING dims; leading dims -> None)
PARAM_RULES: tuple[tuple[str, tuple], ...] = (
    ("embed/tok", ("model", "data")),
    ("embed/unembed", ("data", "model")),
    ("patch_proj", (None, "model")),
    ("/wq", ("data", "model")),
    ("/wk", ("data", "model")),
    ("/wv", ("data", "model")),
    ("/wg", ("data", "model")),
    ("/wr", ("data", "model")),
    ("/wo", ("model", "data")),
    ("mlp/w_in", ("data", "model")),
    ("mlp/w_gate", ("data", "model")),
    ("mlp/w_out", ("model", "data")),
    ("moe/router", (None, None)),
    ("moe/w_in", (None, "data", "model")),
    ("moe/w_gate", (None, "data", "model")),
    ("moe/w_out", (None, "model", "data")),
    ("w_lora_a", ("data", None)),
    ("w_lora_b", (None, "data")),
    ("rnn/w_y", ("data", "model")),
    ("rnn/w_x", ("data", "model")),
    ("rnn/w_a", ("data", "model")),
    ("rnn/w_i", ("data", "model")),
    ("rnn/w_o", ("model", "data")),
)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    mesh: Mesh

    @property
    def dp_axes(self) -> tuple[str, ...]:
        return tuple(a for a in ("pod", "data") if a in self.mesh.axis_names)

    def axis_size(self, name) -> int:
        if name is None:
            return 1
        if isinstance(name, tuple):
            return math.prod(self.axis_size(n) for n in name)
        return self.mesh.shape[name]

    def _fit(self, shape: tuple[int, ...], spec: Sequence) -> P:
        """Drop axes that do not evenly divide their dim (graceful fallback).
        The FSDP axis 'data' expands to all DP axes (pod included) so ZeRO
        sharding scales with the full data-parallel world size."""
        full = [None] * (len(shape) - len(spec)) + list(spec)
        fixed = []
        for dim, ax in zip(shape, full):
            if ax == "data":
                dp = self.dp_axes
                ax = dp if len(dp) > 1 else dp[0]
            if ax is not None and dim % self.axis_size(ax) == 0 and dim > 0:
                fixed.append(ax)
            else:
                fixed.append(None)
        return P(*fixed)

    # -------------------------------------------------------------- params --
    def param_spec(self, shapes: Any, fsdp: bool = True) -> Any:
        """fsdp=False (serving): params TP-sharded over 'model' only and
        replicated over the data axes - no per-step param all-gather."""
        def one(path, leaf):
            ps = _path_str(path)
            for key, spec in PARAM_RULES:
                if key in ps:
                    use = spec if fsdp else tuple(
                        None if ax == "data" else ax for ax in spec
                    )
                    return self._fit(leaf.shape, use)
            return P(*([None] * len(leaf.shape)))

        return jax.tree_util.tree_map_with_path(one, shapes)

    def opt_state_spec(self, param_specs: Any) -> dict:
        return {
            "m": param_specs,
            "v": param_specs,
            "step": P(),
        }

    # --------------------------------------------------------------- batch --
    def batch_spec(self, shapes: Any) -> Any:
        dp = self.dp_axes

        def one(leaf):
            if not leaf.shape:
                return P()
            spec = [None] * len(leaf.shape)
            if leaf.shape[0] % self.axis_size(dp) == 0:
                spec[0] = dp
            return P(*spec)

        return jax.tree.map(one, shapes)

    # -------------------------------------------------------------- caches --
    def cache_spec(self, shapes: Any) -> Any:
        """KV caches: batch over DP axes, cache sequence over 'model'
        (flash-decoding style); recurrent states: width/heads over 'model'."""
        dp = self.dp_axes

        def one(path, leaf):
            ps = _path_str(path)
            shape = leaf.shape
            name = ps.rsplit("/", 1)[-1]
            spec: list = [None] * len(shape)
            if name in ("k", "v"):
                # (..., B, size, KV, hd)
                b_i, s_i = len(shape) - 4, len(shape) - 3
                if shape[b_i] % self.axis_size(dp) == 0:
                    spec[b_i] = dp
                # sequence-shard only LARGE caches: sharding a small ring
                # buffer turns every insert into a replicate-then-partition
                # reshard (SPMD cannot localize modular scatters) - §Perf
                if (shape[s_i] % self.axis_size("model") == 0
                        and shape[s_i] >= 4096):
                    spec[s_i] = "model"
            elif name == "pos":
                s_i = len(shape) - 1
                if (shape[s_i] % self.axis_size("model") == 0
                        and shape[s_i] >= 4096):
                    spec[s_i] = "model"
            elif name == "state":
                # (..., B, H, dk, dv)
                b_i, h_i = len(shape) - 4, len(shape) - 3
                if shape[b_i] % self.axis_size(dp) == 0:
                    spec[b_i] = dp
                if shape[h_i] % self.axis_size("model") == 0:
                    spec[h_i] = "model"
            elif name in ("h", "x_prev", "conv"):
                b_i = 1 if len(shape) > 2 else 0
                # trailing width dim over model
                if shape[-1] % self.axis_size("model") == 0:
                    spec[-1] = "model"
                if len(shape) > 1 and shape[b_i] % self.axis_size(dp) == 0:
                    spec[b_i] = dp
            return P(*spec)

        return jax.tree_util.tree_map_with_path(one, shapes)

    # ------------------------------------------------------------- helpers --
    def named(self, spec_tree: Any) -> Any:
        return jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), spec_tree,
            is_leaf=lambda x: isinstance(x, P),
        )
