"""Distributed-optimization tricks: gradient compression + overlap helpers.

int8 gradient all-reduce with error feedback (1-bit-Adam family, adapted):
each data shard quantizes its local gradient to int8 with a per-block scale,
all-reduces the int8 payload (as int32 accumulators to avoid overflow at
512-way reductions), dequantizes, and keeps the quantization residual as
error feedback added to the next step's gradient.  Cuts DP gradient traffic
~2x (bf16->int8) to ~4x (fp32->int8) on the wire.

Implemented with shard_map + lax.psum so the collective is explicit in the
HLO (visible to the roofline's collective-bytes parser).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

BLOCK = 256


def _quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-block symmetric int8 quantization. x: (N,) fp32 (N % BLOCK == 0)."""
    xb = x.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(xb), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return (q.astype(jnp.float32) * scale).reshape(-1)


def quantize_roundtrip(x: jax.Array) -> jax.Array:
    """Reference (single-host) quantize->dequantize for error-bound tests."""
    n = x.size
    pad = (-n) % BLOCK
    xf = jnp.pad(x.reshape(-1).astype(jnp.float32), (0, pad))
    q, s = _quantize(xf)
    return _dequantize(q, s)[:n].reshape(x.shape)


def compressed_psum(
    x: jax.Array, mesh: Mesh, axis: str = "data"
) -> jax.Array:
    """int8-compressed all-reduce of a replicated-layout tensor over `axis`.

    The payload crosses the wire as int8 (packed in int32 lanes for the
    reduction); result is the dequantized mean.
    """
    n_dev = mesh.shape[axis]

    def body(xs):
        n = xs.size
        pad = (-n) % BLOCK
        flat = jnp.pad(xs.reshape(-1).astype(jnp.float32), (0, pad))
        q, s = _quantize(flat)
        # psum int8 payloads (as int32 accumulators) and scales separately
        qsum = jax.lax.psum(q.astype(jnp.int32), axis)
        ssum = jax.lax.psum(s, axis)
        # mean of per-shard dequantized values (approximation: shared scale
        # sum; exact when shards have equal scales)
        deq = qsum.astype(jnp.float32) * (ssum / n_dev) / n_dev
        return deq.reshape(-1)[:n].reshape(xs.shape)

    specs = P(*([None] * x.ndim))
    f = shard_map(
        body, mesh=mesh, in_specs=(specs,), out_specs=specs,
        check_vma=False,
    )
    return f(x)


def error_feedback_update(
    grads: Any, residual: Any
) -> tuple[Any, Any]:
    """Add residual, quantize-roundtrip, compute next residual."""

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        gq = quantize_roundtrip(gf)
        return gq.astype(g.dtype), gf - gq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    pairs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (
        tdef.unflatten([p[0] for p in pairs]),
        tdef.unflatten([p[1] for p in pairs]),
    )


def init_residual(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
