"""Serving engine: batched prefill + decode over the model zoo.

A minimal production shape: a request queue is packed into fixed-size
batches, prefilled once, then decoded step-by-step with greedy or
temperature sampling.  KV caches are preallocated to max_len (ring buffers
for sliding-window layers), so decode steps are shape-stable = one compiled
XLA program regardless of position, which is what the decode_32k/long_500k
dry-run cells lower.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.arch.model_zoo import build
from repro.configs.base import ModelConfig


@dataclasses.dataclass
class Request:
    prompt: np.ndarray           # (T,) int32
    max_new_tokens: int = 16


@dataclasses.dataclass
class ServeConfig:
    batch: int = 4
    max_len: int = 256
    temperature: float = 0.0
    seed: int = 0


class Engine:
    def __init__(self, cfg: ModelConfig, params: Any, scfg: ServeConfig):
        self.cfg = cfg
        self.model = build(cfg)
        self.params = params
        self.scfg = scfg
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode_step)

    def _sample(self, logits: jax.Array, key: jax.Array) -> jax.Array:
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.scfg.temperature, axis=-1
        ).astype(jnp.int32)

    def generate(self, requests: list[Request]) -> list[np.ndarray]:
        """Pack requests (padded to batch), prefill, decode greedily."""
        scfg = self.scfg
        assert len(requests) <= scfg.batch
        pad_n = scfg.batch - len(requests)
        plen = max(len(r.prompt) for r in requests)
        prompts = np.zeros((scfg.batch, plen), np.int32)
        for i, r in enumerate(requests):
            prompts[i, plen - len(r.prompt):] = r.prompt  # left-pad
        max_new = max(r.max_new_tokens for r in requests)

        caches = self.model.init_caches(scfg.batch, scfg.max_len)
        logits, caches = self._prefill(
            self.params, jnp.asarray(prompts), caches
        )
        key = jax.random.PRNGKey(scfg.seed)
        outs = []
        tok = self._sample(logits, key)
        outs.append(np.asarray(tok))
        for i in range(max_new - 1):
            key, sub = jax.random.split(key)
            logits, caches = self._decode(self.params, tok[:, None], caches)
            tok = self._sample(logits, sub)
            outs.append(np.asarray(tok))
        gen = np.stack(outs, axis=1)  # (B, max_new)
        return [gen[i, : r.max_new_tokens] for i, r in enumerate(requests)]
