"""Continuous-batching serve engine: slot-based KV cache + async admission.

The paper's §6.3 lesson — allocate resources to match the delivered
throughput, don't leave them idle — recurs at request granularity in
serving.  The old engine padded every request in a static batch to the
slowest prompt and the largest ``max_new_tokens``; here the decode batch is
a fixed ring of ``batch`` KV *slots* (one compiled decode program,
shape-stable forever) and requests flow through it continuously:

  * **admission**: a waiting request is prefilled into a batch-1 cache and
    scattered into a free slot (`serve/kvcache.slot_store`), interleaved
    with decode steps;
  * **decode**: every step advances *all* occupied slots by one token;
  * **eviction + backfill**: a slot frees the moment its request finishes
    and is re-admitted from the queue on the next step — no drain barrier.

Sampling keys are derived per request as ``fold_in(fold_in(seed, rid), t)``
so outputs are bitwise-deterministic for a fixed seed regardless of arrival
order or slot assignment (slot rows are computationally independent).

Request lifecycle.  Every request moves through a real state machine::

    WAITING -> ACTIVE -> FINISHED
                 |   \\-> CANCELLED | FAILED        (cancel / deadline)
                 \\-> PREEMPTED -> WAITING -> ACTIVE  (block/slot pressure)
    WAITING -> CANCELLED | FAILED | REJECTED       (cancel / deadline / shed)

  * :meth:`Engine.cancel` works in every state — dequeue if waiting,
    evict-and-release-blocks if active, no-op (idempotent) once terminal.
  * Per-request **deadlines** (``Request.deadline_steps``) are checked at
    the top of every :meth:`step`; an expired request is evicted through
    the same block-release path as cancellation and ends ``FAILED``.
  * **Preemption**: when the best waiting request outranks an active one
    and admission is starved (no free slot, or — paged — not enough free
    blocks), the lowest-priority victim's blocks are released (its table
    repointed at the sink, exactly the eviction idiom) and it is requeued.
    On re-admission its prompt is re-prefilled through the radix prefix
    index (shared-prefix blocks are aliased again) and its already
    generated tokens are *replayed* through the identical decode programs
    (teacher-forced, not re-emitted) — decode is deterministic, so the
    recovered KV state and every subsequent token are **bitwise identical**
    to the uninterrupted run.  (Replaying beats sampling from a re-prefill
    of ``prompt + generated``: prefill and decode attention use different
    softmax reduction orders, so prefill-produced KV/logits for
    decode-generated positions would not be bitwise-reproducible.)
  * **Load shedding**: ``ServeConfig.max_waiting`` bounds the queue
    (overflow submissions end ``REJECTED`` immediately), and a watchdog
    sheds the head of a queue that makes no admission progress with zero
    active slots for ``stall_patience`` consecutive steps — the engine
    degrades by rejecting loudly instead of livelocking.

``serve/chaos.py`` drives all of this under a seeded fault schedule and
audits the block-pool invariants plus bitwise oracle agreement after every
step; ``make test-chaos`` runs the episode matrix.

The decode hot loop is memory-shaped (the paper's words-per-MAC argument at
serve granularity), so both of its memory sins are fixed here:

  * **flash-decoding attention** (``ServeConfig(attention="flash")``, the
    default): single-token attention routes through the ragged Pallas
    decode kernel (``kernels/flash_attention/decode_attention``; jnp twin
    on CPU) with per-slot live lengths traced, so each slot reads
    ``ceil(len/bk)`` KV blocks instead of scanning all ``max_len`` slots
    through a broadcast mask.  ``attention="xla"`` keeps the masked
    dense/blockwise oracle as the measured baseline.
  * **donated KV caches**: ``_decode``/``_admit_group`` donate the cache
    pytree, so the per-row ring scatter updates the buffers in place — no
    per-step copy of every KV tensor (the engine always rebinds
    ``self.caches`` to the jit output; the donated input is dead).

Decode GEMMs can be routed through the Pallas matmul with tile sizes from
the paper's blocking search (``core.mapper.choose_matmul_tiles``) exactly
like ``kernels/matmul/ops.py`` — enable with ``ServeConfig(matmul="pallas")``.

The pre-continuous static-batch loop survives as :class:`StaticEngine`, the
baseline that ``benchmarks/serve_bench.py`` measures against; it follows the
same ``attention`` setting so the A/B isolates scheduling.
"""

from __future__ import annotations

import bisect
import dataclasses
import enum
import warnings
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.arch import layers as L
from repro.arch.model_zoo import build
from repro.configs.base import ModelConfig
from repro.serve import kvcache

# on_token(request_id, token, index, done)
TokenCallback = Callable[[int, int, int, bool], None]


class RequestStatus(str, enum.Enum):
    """Lifecycle states.  WAITING/ACTIVE/PREEMPTED are live; FINISHED,
    CANCELLED, FAILED and REJECTED are terminal (all blocks released, the
    accumulated tokens frozen); UNKNOWN is the answer for ids the engine
    has never seen (or whose results were already popped)."""

    WAITING = "WAITING"       # queued, not yet admitted
    ACTIVE = "ACTIVE"         # holds a slot (and, paged, blocks)
    PREEMPTED = "PREEMPTED"   # evicted mid-generation, requeued for recovery
    FINISHED = "FINISHED"     # ran to its token budget
    CANCELLED = "CANCELLED"   # Engine.cancel(); partial tokens kept
    FAILED = "FAILED"         # deadline expiry (reason says why)
    REJECTED = "REJECTED"     # load-shed: queue bound or watchdog
    UNKNOWN = "UNKNOWN"


TERMINAL_STATUSES = frozenset(
    {
        RequestStatus.FINISHED,
        RequestStatus.CANCELLED,
        RequestStatus.FAILED,
        RequestStatus.REJECTED,
    }
)


@dataclasses.dataclass
class RequestResult:
    """Typed request outcome: terminal status + the generated tokens.

    Terminal guarantees: FINISHED tokens are the full budget; CANCELLED /
    FAILED tokens are the prefix generated before eviction (bitwise equal
    to the same prefix of an unfaulted run); REJECTED generated nothing.
    Every terminal status implies all slot/block resources were released.

    The raw-array return of :meth:`Engine.pop_result` is deprecated; the
    array-like surface below (``__array__``/``tolist``/``len``/``shape``)
    keeps pre-lifecycle callers working unchanged.
    """

    status: RequestStatus
    tokens: np.ndarray
    reason: str = ""
    preemptions: int = 0

    def __array__(self, dtype=None, copy=None):
        arr = np.asarray(self.tokens, dtype)
        return arr.copy() if copy else arr

    def __len__(self) -> int:
        return len(self.tokens)

    def __iter__(self):
        return iter(self.tokens)

    def __getitem__(self, i):
        return self.tokens[i]

    @property
    def shape(self):
        return self.tokens.shape

    def tolist(self) -> list[int]:
        return self.tokens.tolist()

    # elementwise comparisons, so pre-lifecycle range checks like
    # ``(out >= 0).all()`` keep working on the typed result
    def __lt__(self, other):
        return np.asarray(self.tokens) < other

    def __le__(self, other):
        return np.asarray(self.tokens) <= other

    def __gt__(self, other):
        return np.asarray(self.tokens) > other

    def __ge__(self, other):
        return np.asarray(self.tokens) >= other


@dataclasses.dataclass
class Request:
    prompt: np.ndarray           # (T,) int32
    max_new_tokens: int = 16
    # stable id for deterministic sampling; defaults to submission order
    request_id: int | None = None
    # higher priority admits first and may preempt strictly-lower-priority
    # active requests when admission is slot- or block-starved
    priority: int = 0
    # engine steps (not wall clock, so chaos/CI replays are deterministic)
    # the request may participate in before it FAILs; None = no deadline
    deadline_steps: int | None = None


@dataclasses.dataclass
class ServeConfig:
    batch: int = 4               # number of KV slots (decode batch width)
    max_len: int = 256
    temperature: float = 0.0
    seed: int = 0
    # >0: right-pad prompts to a multiple of this so prefill compiles once
    # per bucket, not once per length (global-attention models only; other
    # families silently fall back to exact-length prefill)
    prefill_bucket: int = 0
    # "xla" | "pallas": route projection GEMMs through the Pallas kernel
    # with mapper-chosen tiles (core.mapper.choose_matmul_tiles)
    matmul: str = "xla"
    # "flash" | "xla": decode-attention substrate.  "flash" (default) is
    # the ragged flash-decoding path (per-slot live lengths, KV reads
    # scale with live length); "xla" is the masked dense/blockwise oracle.
    attention: str = "flash"
    # "contiguous": one (slots, max_len) KV ring per layer — HBM is sized
    # by the worst case.  "paged": a refcounted block pool + per-row block
    # tables (serve/kvcache.BlockPool); capacity tracks LIVE tokens,
    # prompts sharing a prefix alias physical blocks, and `batch` becomes a
    # scheduling cap instead of a memory cap.  The contiguous layout is the
    # paged engine's bitwise differential oracle.
    kv_layout: str = "contiguous"
    # paged: tokens per physical KV block
    block_size: int = 16
    # paged: pool size per layer, INCLUDING the sink block.  None sizes the
    # pool to the contiguous layout's footprint (batch * max_len tokens)
    # plus the sink, which is what the equal-HBM benchmarks compare.
    num_blocks: int | None = None
    # paged: alias physical blocks across requests sharing a prompt prefix
    # (radix index + copy-on-write; see serve/kvcache.BlockPool)
    prefix_sharing: bool = True
    # pin the contiguous flash-decoding KV split (None = auto-tuned).  The
    # paged layout always splits at block_size; pinning the contiguous
    # oracle to the same value makes the two layouts' online-softmax
    # reductions identical, hence bitwise-comparable.
    decode_block: int | None = None
    # bound the waiting queue: a submit that would exceed it is REJECTED
    # immediately (load shedding) instead of growing the queue without
    # bound.  None = unbounded.
    max_waiting: int | None = None
    # watchdog: consecutive steps with zero active slots and zero admission
    # progress (while requests wait) before the head of the queue is shed
    # REJECTED — the engine degrades loudly instead of livelocking on a
    # pool that will never free (external pressure, accounting bugs).
    stall_patience: int = 64
    # crash consistency (serve/recovery.py): a directory here arms the
    # RecoveryManager — a crc32'd write-ahead journal of submits/cancels/
    # pops/token deltas (fsync'd once per step) plus a crash-atomic
    # snapshot of the full serving state every `snapshot_every` steps,
    # staged synchronously and published tmp-dir+rename on a background
    # thread.  restore_engine() rebuilds a crashed engine with survivor
    # outputs bitwise identical to the never-crashed run.
    snapshot_dir: str | None = None
    snapshot_every: int = 32
    snapshot_keep: int = 3           # published snapshots retained by GC
    # fsync the journal every N per-step commits (submit/cancel/pop always
    # force a sync).  1 = classic WAL durability; raise it when the journal
    # lives on a slow disk and losing a few steps of tokens is acceptable.
    journal_fsync_every: int = 1
    # corruption quarantine: per-step NaN/Inf guard on decode logits — a
    # non-finite row FAILs (blocks released, survivors untouched) instead
    # of silently streaming garbage.  Costs nothing: the flag rides the
    # existing device->host token sync.
    guard_nan: bool = True
    # paged-only debug/detection mode: per-physical-block checksums
    # recomputed each step; an unexpected change in a block no live row
    # legally wrote quarantines every request referencing it (FAILED,
    # blocks released).  O(pool) device work per step — off by default.
    kv_checksum: bool = False
    # one-shot kernel-failure fallback: if the jitted decode path raises
    # (Pallas lowering/compile failure on an exotic backend), rebuild it on
    # the oracle substrate (flash -> masked xla; paged -> gather twin) with
    # a logged warning instead of dying.  Greedy outputs are substrate-
    # independent (tests pin this), so serving continues bitwise-intact.
    substrate_fallback: bool = True

    def __post_init__(self):
        # every mis-setting here used to surface as a downstream shape
        # error or a silently-wrong A/B — validate eagerly with messages
        # that say what to change
        if self.matmul not in ("xla", "pallas"):
            raise ValueError(f"matmul must be 'xla' or 'pallas': {self.matmul!r}")
        if self.attention not in ("flash", "xla"):
            raise ValueError(
                f"attention must be 'flash' or 'xla': {self.attention!r}"
            )
        if self.kv_layout not in ("contiguous", "paged"):
            raise ValueError(
                f"kv_layout must be 'contiguous' or 'paged': {self.kv_layout!r}"
            )
        if self.batch < 1:
            raise ValueError(f"batch (KV slot count) must be >= 1: {self.batch}")
        if self.max_len < 2:
            raise ValueError(
                f"max_len must be >= 2 (one prompt token + one generated): "
                f"{self.max_len}"
            )
        if self.prefill_bucket < 0:
            raise ValueError(
                f"prefill_bucket must be >= 0 (0 disables bucketing): "
                f"{self.prefill_bucket}"
            )
        if self.max_waiting is not None and self.max_waiting < 1:
            raise ValueError(
                f"max_waiting must be >= 1 (or None for unbounded): "
                f"{self.max_waiting}"
            )
        if self.stall_patience < 1:
            raise ValueError(
                f"stall_patience must be >= 1 step: {self.stall_patience}"
            )
        if self.snapshot_every < 1:
            raise ValueError(
                f"snapshot_every must be >= 1 step: {self.snapshot_every}"
            )
        if self.snapshot_keep < 1:
            raise ValueError(
                f"snapshot_keep must be >= 1 snapshot: {self.snapshot_keep}"
            )
        if self.journal_fsync_every < 1:
            raise ValueError(
                f"journal_fsync_every must be >= 1 commit: "
                f"{self.journal_fsync_every}"
            )
        if self.kv_checksum and self.kv_layout != "paged":
            raise ValueError(
                "kv_checksum tracks per-physical-block sums, which only "
                "exist under kv_layout='paged'"
            )
        if self.decode_block is not None and self.decode_block < 1:
            raise ValueError(f"decode_block must be >= 1: {self.decode_block}")
        if self.kv_layout == "paged":
            if self.block_size < 1:
                raise ValueError(f"block_size must be >= 1: {self.block_size}")
            if self.max_len % self.block_size:
                raise ValueError(
                    f"max_len {self.max_len} must be a multiple of "
                    f"block_size {self.block_size}"
                )
            if self.num_blocks is not None and self.num_blocks < 2:
                raise ValueError(
                    f"num_blocks counts the sink block too, so a usable pool "
                    f"needs num_blocks >= 2: got {self.num_blocks} (or pass "
                    f"None to size the pool to the contiguous footprint)"
                )
            if (
                self.decode_block is not None
                and self.decode_block != self.block_size
            ):
                raise ValueError(
                    f"the paged layout always splits decode attention at "
                    f"block_size={self.block_size}; decode_block="
                    f"{self.decode_block} contradicts it — drop decode_block "
                    f"(it is only for pinning a CONTIGUOUS oracle) or set "
                    f"them equal"
                )
        elif self.num_blocks is not None:
            raise ValueError(
                f"num_blocks={self.num_blocks} only applies to "
                f"kv_layout='paged'; the contiguous layout is sized by "
                f"batch * max_len"
            )

    def resolved_num_blocks(self) -> int:
        if self.num_blocks is not None:
            return self.num_blocks
        return self.batch * self.max_len // self.block_size + 1  # + sink


@dataclasses.dataclass
class _ReqInfo:
    """Host-side record of one request, alive from submit to pop_result."""

    rid: int
    prompt: np.ndarray
    budget: int                  # effective max_new_tokens
    priority: int
    deadline: int | None         # absolute engine step number, or None
    seq: int                     # arrival order (FIFO tie-break in-priority)
    status: RequestStatus = RequestStatus.WAITING
    reason: str = ""
    preemptions: int = 0


@dataclasses.dataclass
class _SlotState:
    rid: int
    emitted: int                 # tokens generated so far (this occupancy)
    budget: int                  # effective max_new_tokens
    # preemption recovery: tokens already recorded before eviction.  While
    # emitted < replay the decode loop teacher-forces the recorded tokens
    # (asserting bitwise re-derivation) without re-emitting them.
    replay: int = 0


@dataclasses.dataclass
class _PagedRow:
    """Block ownership of one live paged request (host side)."""

    blocks: list[int]            # logical block -> physical, len == total
    plen: int                    # prompt tokens
    n_shared_full: int           # leading full blocks aliased via the index
    tail_shared: bool            # partial prompt tail aliased (CoW pending)
    cow_dst: int | None          # pre-allocated CoW target for the tail


def _pallas_mm(x: jax.Array, w: jax.Array) -> jax.Array:
    """(..., K) @ (K, N) through the schedule-driven Pallas matmul."""
    from repro.kernels.matmul.ops import matmul

    out = matmul(x.reshape(-1, x.shape[-1]), w)
    return out.reshape(x.shape[:-1] + (w.shape[-1],))


class Engine:
    """Continuous-batching engine over the model zoo's prefill/decode."""

    def __init__(self, cfg: ModelConfig, params: Any, scfg: ServeConfig):
        if cfg.family == "encdec":
            raise ValueError(
                "continuous batching serves decoder-only LMs; whisper-style "
                "encdec requests need per-request encoder state"
            )
        self.cfg = cfg
        self.model = build(cfg)
        self.params = params
        self.scfg = scfg
        self._impl = _pallas_mm if scfg.matmul == "pallas" else None
        self._attn = "flash" if scfg.attention == "flash" else None
        self._paged = scfg.kv_layout == "paged"

        if self._paged:
            if not kvcache.supports_paged(cfg):
                raise ValueError(
                    f"kv_layout='paged' needs all-global attention; "
                    f"{cfg.name} has ring/recurrent/hybrid caches"
                )
            nb = scfg.resolved_num_blocks()
            self.caches = kvcache.build_paged_caches(
                cfg, scfg.batch, scfg.max_len, nb, scfg.block_size
            )
            self.pool = kvcache.BlockPool(nb, scfg.block_size)
            self._axes = None
        else:
            self.caches = kvcache.build_caches(cfg, scfg.batch, scfg.max_len)
            self.pool = None
            self._axes = kvcache.slot_axes(cfg, scfg.max_len)
        self._free: deque[int] = deque(range(scfg.batch))
        # waiting rids, kept sorted by (-priority, seq): head = best request.
        # Preempted requests keep their original seq, so they re-enter ahead
        # of later arrivals of the same priority.
        self._waiting: list[int] = []
        self._reqs: dict[int, _ReqInfo] = {}
        self._slots: dict[int, _SlotState] = {}
        self._rows: dict[int, _PagedRow] = {}
        self._outputs: dict[int, list[int]] = {}
        self._next_rid = 0
        self._next_seq = 0
        self._step_no = 0
        self._stalled = 0            # consecutive idle no-progress steps
        self._cur_tok = np.zeros((scfg.batch,), np.int32)
        # scheduling evidence for the iso-memory benches plus the lifecycle
        # counters the chaos harness and fault-storm bench report
        self.stats = {
            "peak_active": 0,
            "admitted": 0,
            "preempted": 0,
            "recovered": 0,
            "cancelled": 0,
            "expired": 0,
            "rejected": 0,
            "shed": 0,
            "quarantined": 0,   # corruption guard: rows FAILED mid-decode
            "fallbacks": 0,     # substrate fallbacks taken (0 or 1)
            "snapshots": 0,     # recovery snapshots staged
        }

        model, impl, axes = self.model, self._impl, self._axes
        max_len = scfg.max_len
        sample_one, req_key = self._sampler()

        def admit_fn(params, toks, big, slots_, rids, true_lens):
            """Fused admission: prefill `n` prompts (right-padded rows mask
            their tail; exact rows mask nothing), scatter each into its
            slot, and sample each request's first token — one dispatch."""
            n = toks.shape[0]
            small = kvcache.build_caches(cfg, n, max_len)
            with L.matmul_override(impl):
                logits, small = model.prefill(
                    params, toks, small, last_index=true_lens - 1
                )
            small = kvcache.mask_prompt_tail(small, true_lens)
            for i in range(n):
                big = kvcache.slot_store(
                    big, kvcache.take_slot(small, i, axes), slots_[i], axes
                )
            toks0 = jax.vmap(
                lambda lg, r: sample_one(lg, req_key(r, jnp.int32(0)))
            )(logits, rids)
            return toks0, big

        def paged_prefill_fn(params, toks, rids, true_lens):
            """Paged admission, phase 1: prefill into a contiguous scratch
            (the SAME program shape the contiguous oracle admits through,
            so first tokens and packed K/V stay bitwise comparable) and
            sample each request's first token.  Phase 2 packs the scratch
            into pool blocks row by row (`kvcache.paged_store_row_blocks`),
            skipping blocks aliased from the prefix index."""
            n = toks.shape[0]
            small = kvcache.build_caches(cfg, n, max_len)
            with L.matmul_override(impl):
                logits, small = model.prefill(
                    params, toks, small, last_index=true_lens - 1
                )
            toks0 = jax.vmap(
                lambda lg, r: sample_one(lg, req_key(r, jnp.int32(0)))
            )(logits, rids)
            return toks0, {"k": small["k"], "v": small["v"]}

        # the KV cache pytree is DONATED: the ring scatter and admission
        # slot_store update the buffers in place instead of copying every
        # KV tensor per step.  The engine immediately rebinds self.caches
        # to the jit output, so the consumed input is never read again.
        # The paged helpers follow the same contract: pack/set/CoW are
        # donated scatters into the pool, never pool copies.
        self._decode = self._make_decode(self._attn)
        self._fallback_done = False
        self._admit_group = jax.jit(admit_fn, donate_argnums=(2,))
        self._paged_prefill = jax.jit(paged_prefill_fn)
        self._pack_row = jax.jit(kvcache.paged_store_row_blocks, donate_argnums=(0,))
        self._set_row = jax.jit(kvcache.paged_set_row, donate_argnums=(0,))
        self._cow = jax.jit(kvcache.paged_copy_block, donate_argnums=(0,))
        if self._paged:
            self._sink_row = np.zeros((scfg.max_len // scfg.block_size,), np.int32)
        else:
            self._sink_row = None

        # optional per-physical-block checksum audit (paged only): host
        # mirror of |kpool|+|vpool| sums per block, verified after every
        # step against the blocks legally written that step
        self._kv_sums: np.ndarray | None = None
        self._pool_sums = None
        self._touched: set[int] = set()
        if scfg.kv_checksum:

            def pool_sums_fn(caches):
                k = jnp.sum(
                    jnp.abs(caches["kpool"].astype(jnp.float32)),
                    axis=(0, 2, 3, 4),
                )
                v = jnp.sum(
                    jnp.abs(caches["vpool"].astype(jnp.float32)),
                    axis=(0, 2, 3, 4),
                )
                return k + v

            self._pool_sums = jax.jit(pool_sums_fn)
            self._refresh_kv_sums()

        # crash consistency: journal + periodic snapshots (serve/recovery)
        self.recovery = None
        if scfg.snapshot_dir:
            from repro.serve.recovery import RecoveryManager

            RecoveryManager.attach(
                self,
                scfg.snapshot_dir,
                every=scfg.snapshot_every,
                keep=scfg.snapshot_keep,
                fsync_every=scfg.journal_fsync_every,
            )

    def _sampler(self):
        key0 = jax.random.PRNGKey(self.scfg.seed)
        temp = self.scfg.temperature

        def sample_one(logits: jax.Array, key: jax.Array) -> jax.Array:
            if temp <= 0:
                return jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return jax.random.categorical(key, logits / temp).astype(jnp.int32)

        def req_key(rid: jax.Array, t: jax.Array) -> jax.Array:
            return jax.random.fold_in(jax.random.fold_in(key0, rid), t)

        return sample_one, req_key

    def _make_decode(self, attn):
        """Build the jitted decode program on substrate ``attn`` (rebuilt
        once by `_decode_call` on kernel failure).  Besides the sampled
        tokens it returns a per-row non-finite-logits flag — the
        corruption guard rides the token sync, costing no extra transfer.
        """
        model, impl, dblk = self.model, self._impl, self.scfg.decode_block
        sample_one, req_key = self._sampler()

        def decode_fn(params, toks, caches, rids, ts):
            with (
                L.matmul_override(impl),
                L.attention_override(attn),
                L.decode_block_override(dblk),
            ):
                logits, caches = model.decode_step(params, toks, caches)
            nxt = jax.vmap(lambda lg, r, t: sample_one(lg, req_key(r, t)))(
                logits, rids, ts
            )
            bad = ~jnp.all(jnp.isfinite(logits.astype(jnp.float32)), axis=-1)
            return (nxt, bad), caches

        return jax.jit(decode_fn, donate_argnums=(2,))

    def _decode_call(self, *args):
        """Run the decode program, falling back ONCE to the oracle
        substrate on failure (flash -> masked xla attend; paged -> the
        gather twin, both reached by rebuilding with ``attn=None``).
        Pallas kernel failures surface at trace/compile time — before the
        donated caches are consumed — so the retry sees intact buffers."""
        try:
            return self._decode(*args)
        except Exception as e:
            if (
                self._fallback_done
                or not self.scfg.substrate_fallback
                or self._attn is None
            ):
                raise
            warnings.warn(
                f"decode substrate {self._attn!r} failed ({type(e).__name__}: "
                f"{e}); falling back to the oracle substrate once",
                RuntimeWarning,
                stacklevel=2,
            )
            self._fallback_done = True
            self._attn = None
            self._decode = self._make_decode(None)
            self.stats["fallbacks"] += 1
            return self._decode(*args)

    def _refresh_kv_sums(self) -> None:
        """(Re)baseline the per-block checksum mirror from the current
        device pools — at init and after a snapshot restore."""
        if self._pool_sums is not None:
            self._kv_sums = np.asarray(self._pool_sums(self.caches))

    # ---------------------------------------------------------- admission --
    def submit(self, req: Request) -> int:
        """Queue a request; returns its id.  Prompts longer than
        ``max_len - 1`` keep their most recent tokens; ``max_new_tokens`` is
        truncated so the request never outgrows its slot.  A full waiting
        queue (``ServeConfig.max_waiting``) REJECTs the submission instead
        of raising — poll :meth:`status` / :meth:`pop_result`."""
        rid = req.request_id if req.request_id is not None else self._next_rid
        if rid in self._reqs:
            raise ValueError(f"duplicate request_id {rid}")
        if req.deadline_steps is not None and req.deadline_steps < 0:
            raise ValueError(
                f"request {rid}: deadline_steps must be >= 0: "
                f"{req.deadline_steps}"
            )
        self._next_rid = max(self._next_rid, rid + 1)
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        max_len = self.scfg.max_len
        if len(prompt) >= max_len:
            prompt = prompt[-(max_len - 1) :]
        budget = min(int(req.max_new_tokens), max_len - len(prompt))
        if self._paged:
            # never let one request outgrow the whole pool: its admission
            # would wait forever for blocks that can't exist (deadlock),
            # and silently shrinking the budget would quietly diverge from
            # the contiguous oracle — reject loudly instead.  With the
            # default pool sizing (batch * max_len tokens) this can never
            # trigger: the max_len truncation above already bounds
            # prompt + budget to max_len <= capacity.
            cap_tokens = (self.pool.num_blocks - 1) * self.scfg.block_size
            if len(prompt) + budget > cap_tokens:
                raise ValueError(
                    f"request {rid} needs {len(prompt) + budget} KV tokens "
                    f"but the whole pool holds {cap_tokens}; grow "
                    f"num_blocks or shorten the request"
                )
        deadline = (
            self._step_no + req.deadline_steps
            if req.deadline_steps is not None
            else None
        )
        info = _ReqInfo(
            rid=rid,
            prompt=prompt,
            budget=budget,
            priority=int(req.priority),
            deadline=deadline,
            seq=self._next_seq,
        )
        self._next_seq += 1
        self._reqs[rid] = info
        self._outputs[rid] = []
        if budget <= 0 or len(prompt) == 0:
            self._finish(info, RequestStatus.FINISHED, "empty prompt or budget")
        elif (
            self.scfg.max_waiting is not None
            and len(self._waiting) >= self.scfg.max_waiting
        ):
            self.stats["rejected"] += 1
            self._finish(
                info,
                RequestStatus.REJECTED,
                f"queue full (max_waiting={self.scfg.max_waiting})",
            )
        else:
            self._enqueue(info)
        if self.recovery is not None:
            # journaled AFTER the outcome is known: the record carries the
            # terminal-at-submit status too, so replay needs no re-validation
            self.recovery.record_submit(info)
        return rid

    def _enqueue(self, info: _ReqInfo) -> None:
        bisect.insort(
            self._waiting,
            info.rid,
            key=lambda r: (-self._reqs[r].priority, self._reqs[r].seq),
        )

    def _finish(self, info: _ReqInfo, status: RequestStatus, reason: str) -> None:
        info.status = status
        info.reason = reason

    def _bucket_len(self, plen: int) -> int:
        scfg = self.scfg
        bucket = (
            scfg.prefill_bucket
            if kvcache.supports_padded_prefill(self.cfg)
            else 0
        )
        lpad = -(-plen // bucket) * bucket if bucket > 0 else plen
        if lpad > scfg.max_len:
            lpad = plen  # bucket would overflow the cache: exact length
        return lpad

    def _activate(self, info: _ReqInfo, slot: int, tok: int, on_token) -> bool:
        """Shared first-token bookkeeping; returns True when the request
        stays active (budget not exhausted at admission).  A recovering
        (preempted) request replays instead of emitting: its recorded
        first token must re-derive bitwise from the fresh prefill."""
        out = self._outputs[info.rid]
        replay = len(out)
        if replay:
            assert tok == out[0], (
                f"request {info.rid}: recovery re-prefill diverged at token "
                f"0 ({tok} != recorded {out[0]})"
            )
            self.stats["recovered"] += 1
        else:
            out.append(tok)
        self._cur_tok[slot] = tok
        info.status = RequestStatus.ACTIVE
        # the slot is registered BEFORE the callback runs so a callback
        # that cancels/preempts (stop sequences, client disconnects) goes
        # through the ordinary ACTIVE eviction path
        self._slots[slot] = _SlotState(
            rid=info.rid, emitted=1, budget=info.budget, replay=replay
        )
        done = info.budget == 1
        if not replay and on_token is not None:
            on_token(info.rid, tok, 0, done)
        if info.status != RequestStatus.ACTIVE:
            return False  # callback ended it; slot already released
        if done:
            self._release_slot(slot)
            self._finish(info, RequestStatus.FINISHED, "")
            return False
        return True

    @staticmethod
    def _prompt_batch(lpad: int, infos: list[_ReqInfo]) -> tuple:
        """Right-pad one admission group's prompts into a (n, lpad) token
        batch plus per-row request ids / true lengths."""
        n = len(infos)
        toks = np.zeros((n, lpad), np.int32)
        rids = np.empty((n,), np.int32)
        tlens = np.empty((n,), np.int32)
        for j, info in enumerate(infos):
            toks[j, : len(info.prompt)] = info.prompt
            rids[j], tlens[j] = info.rid, len(info.prompt)
        return toks, rids, tlens

    def _admit_waiting(self, on_token: TokenCallback | None) -> bool:
        """Backfill every free slot from the queue.  Admissions sharing a
        prefill length run as ONE fused jitted call (prefill + tail mask +
        slot scatter + first-token sample); right-padding to
        ``prefill_bucket`` collapses mixed prompt lengths onto one compiled
        shape where that is exact (`kvcache.supports_padded_prefill`).
        Returns True when anything was admitted."""
        if self._paged:
            return self._admit_waiting_paged(on_token)
        groups: dict[int, list[tuple[_ReqInfo, int]]] = {}
        while self._free and self._waiting:
            info = self._reqs[self._waiting.pop(0)]
            slot = self._free.popleft()
            lpad = self._bucket_len(len(info.prompt))
            groups.setdefault(lpad, []).append((info, slot))

        for lpad, items in groups.items():
            toks, rids, tlens = self._prompt_batch(lpad, [it[0] for it in items])
            slots_ = np.asarray([it[1] for it in items], np.int32)
            toks0, self.caches = self._admit_group(
                self.params,
                jnp.asarray(toks),
                self.caches,
                jnp.asarray(slots_),
                jnp.asarray(rids),
                jnp.asarray(tlens),
            )
            toks0 = np.asarray(toks0)
            self.stats["admitted"] += len(items)
            for j, (info, slot) in enumerate(items):
                self._activate(info, slot, int(toks0[j]), on_token)
        self.stats["peak_active"] = max(self.stats["peak_active"], len(self._slots))
        return bool(groups)

    # ------------------------------------------------------ paged admission --
    def _admit_waiting_paged(self, on_token: TokenCallback | None) -> bool:
        """Paged admission: a request enters when a slot AND enough free
        blocks are available (strict order over (-priority, arrival) — the
        queue head never gets jumped).  Ownership is committed host-side
        first (prefix match -> retain aliases, allocate the rest, register
        this chain), then each prefill group runs as one jitted call and
        each row's private blocks are packed into the pool."""
        scfg = self.scfg
        bs = scfg.block_size
        n_blk = scfg.max_len // bs
        groups: dict[int, list[tuple[_ReqInfo, int, _PagedRow]]] = {}
        while self._free and self._waiting:
            info = self._reqs[self._waiting[0]]
            prompt, budget = info.prompt, info.budget
            plen = len(prompt)
            total = -(-(plen + budget) // bs)
            shared_full: list[int] = []
            shared_tail = None
            if scfg.prefix_sharing:
                shared_full, shared_tail = self.pool.match_prefix(prompt.tolist())
            n_shared = len(shared_full) + (1 if shared_tail is not None else 0)
            cow_needed = shared_tail is not None and budget > 1
            need = total - n_shared + (1 if cow_needed else 0)
            if need > self.pool.free_blocks:
                break  # head-of-line waits for completions to free blocks
            self._waiting.pop(0)
            slot = self._free.popleft()
            for b in shared_full:
                self.pool.retain(b)
            if shared_tail is not None:
                self.pool.retain(shared_tail)
            blocks = list(shared_full)
            if shared_tail is not None:
                blocks.append(shared_tail)
            while len(blocks) < total:
                blocks.append(self.pool.alloc())
            # the CoW target is reserved NOW so the first divergent write
            # can never be starved by admissions racing it to the free list
            cow_dst = self.pool.alloc() if cow_needed else None
            if scfg.prefix_sharing:
                toks = prompt.tolist()
                n_full = plen // bs
                prev = -1
                for i in range(n_full):
                    self.pool.register(
                        prev, tuple(toks[i * bs : (i + 1) * bs]), blocks[i]
                    )
                    prev = blocks[i]
                tail = tuple(toks[n_full * bs :])
                if tail and n_full < total:
                    self.pool.register(prev, tail, blocks[n_full])
            row = _PagedRow(
                blocks=blocks,
                plen=plen,
                n_shared_full=len(shared_full),
                tail_shared=shared_tail is not None,
                cow_dst=cow_dst,
            )
            self._rows[slot] = row
            if self._kv_sums is not None:
                # checksum mode: admission packs (or aliases) these blocks
                # this step; aliased prefix blocks are untouched on device
                # but marking them is a harmless over-approximation
                self._touched.update(row.blocks)
            lpad = self._bucket_len(plen)
            groups.setdefault(lpad, []).append((info, slot, row))

        for lpad, items in groups.items():
            toks, rids, tlens = self._prompt_batch(lpad, [it[0] for it in items])
            toks0, scratch = self._paged_prefill(
                self.params,
                jnp.asarray(toks),
                jnp.asarray(rids),
                jnp.asarray(tlens),
            )
            toks0 = np.asarray(toks0)
            self.stats["admitted"] += len(items)
            for j, (info, slot, row) in enumerate(items):
                table_row = np.full((n_blk,), kvcache.SINK_BLOCK, np.int32)
                table_row[: len(row.blocks)] = row.blocks
                self.caches = self._set_row(
                    self.caches,
                    jnp.int32(slot),
                    jnp.asarray(table_row),
                    jnp.int32(row.plen),
                )
                n_prompt = -(-row.plen // bs)
                start = row.n_shared_full
                n_pack = n_prompt - start - (1 if row.tail_shared else 0)
                if n_pack > 0:
                    self.caches = self._pack_row(
                        self.caches,
                        scratch,
                        jnp.int32(j),
                        jnp.int32(start),
                        jnp.asarray(row.blocks[start : start + n_pack], jnp.int32),
                    )
                self._activate(info, slot, int(toks0[j]), on_token)
        self.stats["peak_active"] = max(self.stats["peak_active"], len(self._slots))
        return bool(groups)

    def _resolve_cow(self) -> None:
        """Before rows write: give every slot still aliasing a shared
        prompt-tail block its pre-reserved private copy (first divergent
        write is about to land at ``plen``, inside that block)."""
        for slot in sorted(self._slots):
            row = self._rows.get(slot)
            if row is None or row.cow_dst is None:
                continue
            lb = row.plen // self.scfg.block_size
            src = row.blocks[lb]
            self.caches = self._cow(
                self.caches,
                jnp.int32(slot),
                jnp.int32(lb),
                jnp.int32(src),
                jnp.int32(row.cow_dst),
            )
            self.pool.release(src)
            if self._kv_sums is not None:
                self._touched.add(row.cow_dst)
            row.blocks[lb] = row.cow_dst
            row.cow_dst = None
            row.tail_shared = False

    def _evict_paged(self, slot: int) -> None:
        """Release a finished/cancelled/preempted row: repoint its device
        table at the sink (the always-full-batch decode keeps writing
        through dead rows, and these blocks are about to be reused) and
        return every owned block — including a still-pending CoW
        reservation — to the pool."""
        row = self._rows.pop(slot)
        self.caches = self._set_row(
            self.caches,
            jnp.int32(slot),
            jnp.asarray(self._sink_row),
            jnp.int32(0),
        )
        for b in row.blocks:
            self.pool.release(b)
        if row.cow_dst is not None:
            self.pool.release(row.cow_dst)

    def _release_slot(self, slot: int) -> None:
        """Evict a live slot for any reason (finish, cancel, deadline,
        preemption): paged rows release their blocks through the sink
        repoint, and the slot returns to the free ring for backfill."""
        del self._slots[slot]
        if self._paged:
            self._evict_paged(slot)
        self._free.append(slot)

    def _slot_of(self, rid: int) -> int:
        return next(s for s, st in self._slots.items() if st.rid == rid)

    def live_block_refs(self) -> dict[int, int]:
        """Physical block -> reference count implied by live rows (the
        ground truth the pool's refcounts must mirror; used by the fuzz
        suite's invariant checks)."""
        refs: dict[int, int] = {}
        for row in self._rows.values():
            for b in row.blocks:
                refs[b] = refs.get(b, 0) + 1
            if row.cow_dst is not None:
                refs[row.cow_dst] = refs.get(row.cow_dst, 0) + 1
        return refs

    # ---------------------------------------------------------- lifecycle --
    def status(self, rid: int) -> RequestStatus:
        info = self._reqs.get(rid)
        return RequestStatus.UNKNOWN if info is None else info.status

    def cancel(self, rid: int, reason: str = "cancelled") -> RequestStatus:
        """Cancel a request in any state: dequeue if waiting/preempted,
        evict-and-release-blocks if active.  Idempotent — cancelling a
        terminal (or unknown) request changes nothing and returns its
        current status.  Partial tokens stay retrievable via
        :meth:`pop_result`."""
        info = self._reqs.get(rid)
        if info is None:
            return RequestStatus.UNKNOWN
        if info.status in TERMINAL_STATUSES:
            return info.status
        if info.status == RequestStatus.ACTIVE:
            self._release_slot(self._slot_of(rid))
        else:  # WAITING or PREEMPTED: sitting in the queue
            self._waiting.remove(rid)
        self.stats["cancelled"] += 1
        self._finish(info, RequestStatus.CANCELLED, reason)
        if self.recovery is not None:
            self.recovery.record_cancel(rid, reason)
        return RequestStatus.CANCELLED

    def preempt(self, rid: int) -> bool:
        """Forcibly evict an ACTIVE request: its blocks are released (table
        repointed at the sink) and it is requeued as PREEMPTED at its
        original arrival position.  On re-admission the prompt re-prefills
        through the prefix index and the already-generated tokens replay
        through the identical decode programs, so the resumed output is
        bitwise identical to an uninterrupted run.  Returns False for
        non-active requests."""
        info = self._reqs.get(rid)
        if info is None or info.status != RequestStatus.ACTIVE:
            return False
        self._release_slot(self._slot_of(rid))
        info.status = RequestStatus.PREEMPTED
        info.preemptions += 1
        self.stats["preempted"] += 1
        self._enqueue(info)
        return True

    def _expire_deadlines(self) -> None:
        """FAIL every request whose deadline has passed, waiting or active,
        through the same eviction path as cancellation."""
        now = self._step_no
        for rid in [
            r
            for r in self._waiting
            if self._reqs[r].deadline is not None and now > self._reqs[r].deadline
        ]:
            self._waiting.remove(rid)
            self.stats["expired"] += 1
            self._finish(
                self._reqs[rid], RequestStatus.FAILED, "deadline expired in queue"
            )
        for slot in [
            s
            for s, st in sorted(self._slots.items())
            if self._reqs[st.rid].deadline is not None
            and now > self._reqs[st.rid].deadline
        ]:
            info = self._reqs[self._slots[slot].rid]
            self._release_slot(slot)
            self.stats["expired"] += 1
            self._finish(info, RequestStatus.FAILED, "deadline expired while active")

    def _blocks_needed(self, info: _ReqInfo) -> int:
        """Free blocks the paged admission of ``info`` would consume right
        now (worst-case reservation minus prefix aliases, plus a CoW
        target) — the same arithmetic `_admit_waiting_paged` commits."""
        bs = self.scfg.block_size
        total = -(-(len(info.prompt) + info.budget) // bs)
        if not self.scfg.prefix_sharing:
            return total
        shared_full, shared_tail = self.pool.match_prefix(info.prompt.tolist())
        n_shared = len(shared_full) + (1 if shared_tail is not None else 0)
        cow = shared_tail is not None and info.budget > 1
        return total - n_shared + (1 if cow else 0)

    def _preempt_pass(self) -> None:
        """Priority preemption: while the best waiting request is starved
        (no free slot, or — paged — not enough free blocks) and a strictly
        lower-priority request is active, evict the worst victim (lowest
        priority, then youngest) and retry.  Victims recover bitwise after
        re-admission, so a preemption that frees less than hoped (shared
        blocks stay referenced) costs replay latency, never correctness."""
        while self._waiting:
            head = self._reqs[self._waiting[0]]
            starved = not self._free or (
                self._paged and self._blocks_needed(head) > self.pool.free_blocks
            )
            if not starved:
                return
            victims = sorted(
                (self._reqs[st.rid].priority, -self._reqs[st.rid].seq, st.rid)
                for st in self._slots.values()
                if self._reqs[st.rid].priority < head.priority
            )
            if not victims:
                return
            self.preempt(victims[0][2])

    # ---------------------------------------------------------- integrity --
    def _quarantine(self, slot: int, reason: str) -> None:
        """Corruption response: FAIL the request in ``slot`` and release
        its resources through the ordinary eviction path — pool invariants
        hold and the other rows never notice (slot rows are
        computationally independent)."""
        info = self._reqs[self._slots[slot].rid]
        self._release_slot(slot)
        self.stats["quarantined"] += 1
        self._finish(info, RequestStatus.FAILED, reason)

    def _audit_kv_checksums(self) -> None:
        """kv_checksum mode: recompute per-physical-block sums and compare
        against last step's mirror.  A block that changed without a legal
        write this step (``self._touched``) is corrupt: every request
        referencing it is quarantined.  NaN sums compare equal to
        themselves here, so an already-quarantined poisoned block does not
        re-fire once it sits idle in the free list."""
        sums = np.asarray(self._pool_sums(self.caches))
        prev = self._kv_sums
        changed = (sums != prev) & ~(np.isnan(sums) & np.isnan(prev))
        if self._touched:
            changed[list(self._touched)] = False
        for b in np.nonzero(changed)[0]:
            b = int(b)
            owners = [
                s
                for s, row in self._rows.items()
                if b in row.blocks or row.cow_dst == b
            ]
            for s in owners:
                if s in self._slots:
                    self._quarantine(
                        s,
                        f"KV corruption: block {b} checksum changed "
                        f"without a write",
                    )
        self._kv_sums = sums

    # -------------------------------------------------------------- drive --
    def step(self, on_token: TokenCallback | None = None) -> bool:
        """One engine iteration: expire deadlines, preempt for starved
        higher-priority arrivals, backfill free slots from the queue, then
        advance every occupied slot by one decode token.  Returns False
        once the engine is idle.  When a RecoveryManager is attached, the
        step's emitted-token deltas are journaled (and a snapshot staged on
        cadence) before control returns — the crash-durability boundary is
        the end of every step."""
        alive = self._step_core(on_token)
        if self.recovery is not None:
            self.recovery.after_step()
        return alive

    def _step_core(self, on_token: TokenCallback | None) -> bool:
        self._step_no += 1
        self._touched = {kvcache.SINK_BLOCK}
        self._expire_deadlines()
        self._preempt_pass()
        admitted = False
        while self._free and self._waiting:
            if not self._admit_waiting(on_token):
                break  # paged: head of queue waits for free blocks
            admitted = True
        if self._paged:
            self._resolve_cow()
        if not self._slots:
            if not self._waiting:
                self._stalled = 0
                return False
            if admitted:
                # budget-1 admissions finished instantly: that is progress
                self._stalled = 0
            else:
                # zero active slots, zero admissions, a non-empty queue:
                # nothing inside the engine can free capacity.  Shed the
                # head after `stall_patience` such steps instead of
                # spinning forever on externally-held or leaked blocks.
                self._stalled += 1
                if self._stalled >= self.scfg.stall_patience:
                    info = self._reqs[self._waiting.pop(0)]
                    self.stats["shed"] += 1
                    self._finish(
                        info,
                        RequestStatus.REJECTED,
                        f"shed by watchdog: no admission progress in "
                        f"{self._stalled} idle steps",
                    )
                    self._stalled = 0
            return bool(self._waiting)
        self._stalled = 0

        B = self.scfg.batch
        rids = np.zeros((B,), np.int32)
        ts = np.zeros((B,), np.int32)
        for s, st in self._slots.items():
            rids[s], ts[s] = st.rid, st.emitted
        if self._kv_sums is not None:
            # the one block each live row legally appends to this step:
            # decode writes KV at position plen + emitted - 1 (the first
            # generated token's KV lands on the next step's feed)
            bs = self.scfg.block_size
            for s, st in self._slots.items():
                row = self._rows[s]
                self._touched.add(row.blocks[(row.plen + st.emitted - 1) // bs])
        (nxt, bad), self.caches = self._decode_call(
            self.params,
            jnp.asarray(self._cur_tok[:, None]),
            self.caches,
            jnp.asarray(rids),
            jnp.asarray(ts),
        )
        nxt = np.asarray(nxt)
        bad = np.asarray(bad)
        self._cur_tok = nxt.copy()
        if self.scfg.guard_nan and bad.any():
            # quarantine BEFORE emission: a poisoned row's sampled token is
            # garbage and must reach neither the output nor the journal
            for s in [s for s in sorted(self._slots) if bad[s]]:
                self._quarantine(
                    s, "non-finite logits: KV/activation corruption"
                )

        finished = []
        for s in sorted(self._slots):
            st = self._slots.get(s)
            if st is None:
                continue  # an on_token callback cancelled this row mid-loop
            tok = int(nxt[s])
            out = self._outputs[st.rid]
            if st.emitted < st.replay:
                # preemption recovery: the decode programs are
                # deterministic, so the replayed token must re-derive the
                # recorded one bitwise; it was already emitted pre-eviction
                assert tok == out[st.emitted], (
                    f"request {st.rid}: recovery replay diverged at token "
                    f"{st.emitted} ({tok} != recorded {out[st.emitted]})"
                )
                st.emitted += 1
                if st.emitted >= st.budget:
                    # crash recovery can replay a request to COMPLETION
                    # (it finished after the last snapshot): the journaled
                    # final token re-derives here and no fresh emission
                    # remains to trigger the ordinary finish path below
                    finished.append((s, st.rid))
                continue
            out.append(tok)
            st.emitted += 1
            done = st.emitted >= st.budget
            if on_token is not None:
                on_token(st.rid, tok, st.emitted - 1, done)
            if done:
                finished.append((s, st.rid))
        for s, rid in finished:
            st = self._slots.get(s)
            if st is None or st.rid != rid:
                continue  # the done-callback already cancelled it
            self._release_slot(s)  # backfilled at the next step
            self._finish(self._reqs[rid], RequestStatus.FINISHED, "")
        if self._kv_sums is not None:
            self._audit_kv_checksums()
        return True

    def pop_result(self, rid: int) -> RequestResult:
        """Take a request's :class:`RequestResult`.  Terminal requests are
        consumed (their id becomes reusable); a live request's result is a
        non-consuming snapshot of its current status and partial tokens;
        an unknown id reports ``UNKNOWN`` instead of raising.  Long-running
        step()-driven servers must pop terminal results, or completed
        outputs accumulate without bound."""
        info = self._reqs.get(rid)
        if info is None:
            return RequestResult(
                RequestStatus.UNKNOWN,
                np.zeros((0,), np.int32),
                reason="request id never submitted (or already popped)",
            )
        tokens = np.asarray(self._outputs[rid], np.int32)
        result = RequestResult(info.status, tokens, info.reason, info.preemptions)
        if info.status in TERMINAL_STATUSES:
            del self._reqs[rid]
            del self._outputs[rid]
            if self.recovery is not None:
                self.recovery.record_pop(rid)
        return result

    def run(
        self,
        requests: list[Request] = (),
        on_token: TokenCallback | None = None,
    ) -> list[RequestResult]:
        """Submit ``requests``, drive the engine dry, and return each
        request's :class:`RequestResult` (in submission order; array-like,
        so legacy token-array callers keep working).  Returned results are
        evicted from the engine (their ids become reusable)."""
        rids = [self.submit(r) for r in requests]
        while self.step(on_token):
            pass
        return [self.pop_result(r) for r in rids]

    # legacy API (PR-2-era callers): identical signature, continuous core
    def generate(self, requests: list[Request]) -> list[RequestResult]:
        return self.run(requests)

    def close(self) -> None:
        """Flush and close the recovery journal (no-op without durability).
        Simulated crashes skip this on purpose — every journal record is
        already fsync'd at the step boundary that produced it."""
        if self.recovery is not None:
            self.recovery.close()
            self.recovery = None


class StaticEngine:
    """The pre-continuous static-batch engine, kept as the measured
    baseline: requests are packed into fixed batches, left-padded to the
    longest prompt, and decoded in lockstep to the largest
    ``max_new_tokens`` in the batch.  It shares the continuous engine's
    decode-attention substrate and donated caches, so the serve bench A/B
    measures scheduling, not kernels."""

    def __init__(self, cfg: ModelConfig, params: Any, scfg: ServeConfig):
        if scfg.kv_layout != "contiguous":
            # silently serving contiguous numbers under a paged config
            # would corrupt every A/B built on this baseline
            raise ValueError(
                "StaticEngine serves the contiguous layout only (fixed "
                "lockstep batches have no block pool); use Engine for "
                "kv_layout='paged', or drop kv_layout/num_blocks from "
                "ServeConfig for the static baseline"
            )
        self.cfg = cfg
        self.model = build(cfg)
        self.params = params
        self.scfg = scfg
        model = self.model
        impl = _pallas_mm if scfg.matmul == "pallas" else None
        attn = "flash" if scfg.attention == "flash" else None

        def prefill_fn(params, toks, caches):
            with L.matmul_override(impl):
                return model.prefill(params, toks, caches)

        def decode_fn(params, toks, caches):
            with L.matmul_override(impl), L.attention_override(attn):
                return model.decode_step(params, toks, caches)

        self._prefill = jax.jit(prefill_fn)
        # same matmul/attention substrates + donated caches as the
        # continuous engine, so the bench A/B isolates scheduling
        self._decode = jax.jit(decode_fn, donate_argnums=(2,))

    def _sample(self, logits: jax.Array, key: jax.Array) -> jax.Array:
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.scfg.temperature, axis=-1
        ).astype(jnp.int32)

    def _generate_batch(
        self,
        requests: list[Request],
        rids: list[int],
        on_token: TokenCallback | None,
    ) -> list[np.ndarray]:
        scfg = self.scfg
        plen = max(len(r.prompt) for r in requests)
        prompts = np.zeros((scfg.batch, plen), np.int32)
        for i, r in enumerate(requests):
            prompts[i, plen - len(r.prompt) :] = r.prompt  # left-pad
        max_new = max(r.max_new_tokens for r in requests)

        caches = self.model.init_caches(scfg.batch, scfg.max_len)
        logits, caches = self._prefill(self.params, jnp.asarray(prompts), caches)
        key = jax.random.PRNGKey(scfg.seed)
        outs = []
        tok = self._sample(logits, key)
        outs.append(np.asarray(tok))
        self._emit(requests, rids, outs, on_token)
        for _ in range(max_new - 1):
            key, sub = jax.random.split(key)
            logits, caches = self._decode(self.params, tok[:, None], caches)
            tok = self._sample(logits, sub)
            outs.append(np.asarray(tok))
            self._emit(requests, rids, outs, on_token)
        gen = np.stack(outs, axis=1)  # (B, max_new)
        return [gen[i, : r.max_new_tokens] for i, r in enumerate(requests)]

    @staticmethod
    def _emit(requests, rids, outs, on_token):
        if on_token is None:
            return
        t = len(outs) - 1
        for i, r in enumerate(requests):
            if t < r.max_new_tokens:
                on_token(rids[i], int(outs[-1][i]), t, t == r.max_new_tokens - 1)

    def generate(
        self,
        requests: list[Request],
        on_token: TokenCallback | None = None,
    ) -> list[np.ndarray]:
        """Serve in fixed batches of ``scfg.batch`` requests."""
        results: list[np.ndarray] = []
        B = self.scfg.batch
        for lo in range(0, len(requests), B):
            chunk = requests[lo : lo + B]
            rids = [
                r.request_id if r.request_id is not None else lo + i
                for i, r in enumerate(chunk)
            ]
            results.extend(self._generate_batch(chunk, rids, on_token))
        return results
