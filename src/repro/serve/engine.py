"""Continuous-batching serve engine: slot-based KV cache + async admission.

The paper's §6.3 lesson — allocate resources to match the delivered
throughput, don't leave them idle — recurs at request granularity in
serving.  The old engine padded every request in a static batch to the
slowest prompt and the largest ``max_new_tokens``; here the decode batch is
a fixed ring of ``batch`` KV *slots* (one compiled decode program,
shape-stable forever) and requests flow through it continuously:

  * **admission**: a waiting request is prefilled into a batch-1 cache and
    scattered into a free slot (`serve/kvcache.slot_store`), interleaved
    with decode steps;
  * **decode**: every step advances *all* occupied slots by one token;
  * **eviction + backfill**: a slot frees the moment its request finishes
    and is re-admitted from the queue on the next step — no drain barrier.

Sampling keys are derived per request as ``fold_in(fold_in(seed, rid), t)``
so outputs are bitwise-deterministic for a fixed seed regardless of arrival
order or slot assignment (slot rows are computationally independent).

Request lifecycle.  Every request moves through a real state machine::

    WAITING -> ACTIVE -> FINISHED
                 |   \\-> CANCELLED | FAILED        (cancel / deadline)
                 \\-> PREEMPTED -> WAITING -> ACTIVE  (block/slot pressure)
    WAITING -> CANCELLED | FAILED | REJECTED       (cancel / deadline / shed)

  * :meth:`Engine.cancel` works in every state — dequeue if waiting,
    evict-and-release-blocks if active, no-op (idempotent) once terminal.
  * Per-request **deadlines** (``Request.deadline_steps``) are checked at
    the top of every :meth:`step`; an expired request is evicted through
    the same block-release path as cancellation and ends ``FAILED``.
  * **Preemption**: when the best waiting request outranks an active one
    and admission is starved (no free slot, or — paged — not enough free
    blocks), the lowest-priority victim's blocks are released (its table
    repointed at the sink, exactly the eviction idiom) and it is requeued.
    On re-admission its prompt is re-prefilled through the radix prefix
    index (shared-prefix blocks are aliased again) and its already
    generated tokens are *replayed* through the identical decode programs
    (teacher-forced, not re-emitted) — decode is deterministic, so the
    recovered KV state and every subsequent token are **bitwise identical**
    to the uninterrupted run.  (Replaying beats sampling from a re-prefill
    of ``prompt + generated``: prefill and decode attention use different
    softmax reduction orders, so prefill-produced KV/logits for
    decode-generated positions would not be bitwise-reproducible.)
  * **Load shedding**: ``ServeConfig.max_waiting`` bounds the queue
    (overflow submissions end ``REJECTED`` immediately), and a watchdog
    sheds the head of a queue that makes no admission progress with zero
    active slots for ``stall_patience`` consecutive steps — the engine
    degrades by rejecting loudly instead of livelocking.

``serve/chaos.py`` drives all of this under a seeded fault schedule and
audits the block-pool invariants plus bitwise oracle agreement after every
step; ``make test-chaos`` runs the episode matrix.

The decode hot loop is memory-shaped (the paper's words-per-MAC argument at
serve granularity), so both of its memory sins are fixed here:

  * **flash-decoding attention** (``ServeConfig(attention="flash")``, the
    default): single-token attention routes through the ragged Pallas
    decode kernel (``kernels/flash_attention/decode_attention``; jnp twin
    on CPU) with per-slot live lengths traced, so each slot reads
    ``ceil(len/bk)`` KV blocks instead of scanning all ``max_len`` slots
    through a broadcast mask.  ``attention="xla"`` keeps the masked
    dense/blockwise oracle as the measured baseline.
  * **donated KV caches**: ``_decode``/``_admit_group`` donate the cache
    pytree, so the per-row ring scatter updates the buffers in place — no
    per-step copy of every KV tensor (the engine always rebinds
    ``self.caches`` to the jit output; the donated input is dead).

Decode GEMMs can be routed through the Pallas matmul with tile sizes from
the paper's blocking search (``core.mapper.choose_matmul_tiles``) exactly
like ``kernels/matmul/ops.py`` — enable with ``ServeConfig(matmul="pallas")``.

The pre-continuous static-batch loop survives as :class:`StaticEngine`, the
baseline that ``benchmarks/serve_bench.py`` measures against; it follows the
same ``attention`` setting so the A/B isolates scheduling.
"""

from __future__ import annotations

import bisect
import dataclasses
import enum
import warnings
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.arch import layers as L
from repro.arch.model_zoo import build
from repro.configs.base import ModelConfig
from repro.serve import kvcache

# on_token(request_id, token, index, done)
TokenCallback = Callable[[int, int, int, bool], None]


class RequestStatus(str, enum.Enum):
    """Lifecycle states.  WAITING/ACTIVE/PREEMPTED are live; FINISHED,
    CANCELLED, FAILED and REJECTED are terminal (all blocks released, the
    accumulated tokens frozen); UNKNOWN is the answer for ids the engine
    has never seen (or whose results were already popped)."""

    WAITING = "WAITING"       # queued, not yet admitted
    PREFILLING = "PREFILLING"  # chunked prefill mid-flight: holds a slot
    ACTIVE = "ACTIVE"         # holds a slot (and, paged, blocks)
    PREEMPTED = "PREEMPTED"   # evicted mid-generation, requeued for recovery
    FINISHED = "FINISHED"     # ran to its token budget
    CANCELLED = "CANCELLED"   # Engine.cancel(); partial tokens kept
    FAILED = "FAILED"         # deadline expiry (reason says why)
    REJECTED = "REJECTED"     # load-shed: queue bound or watchdog
    UNKNOWN = "UNKNOWN"


TERMINAL_STATUSES = frozenset(
    {
        RequestStatus.FINISHED,
        RequestStatus.CANCELLED,
        RequestStatus.FAILED,
        RequestStatus.REJECTED,
    }
)


@dataclasses.dataclass
class RequestResult:
    """Typed request outcome: terminal status + the generated tokens.

    Terminal guarantees: FINISHED tokens are the full budget; CANCELLED /
    FAILED tokens are the prefix generated before eviction (bitwise equal
    to the same prefix of an unfaulted run); REJECTED generated nothing.
    Every terminal status implies all slot/block resources were released.

    The raw-array return of :meth:`Engine.pop_result` is deprecated; the
    array-like surface below (``__array__``/``tolist``/``len``/``shape``)
    keeps pre-lifecycle callers working unchanged.
    """

    status: RequestStatus
    tokens: np.ndarray
    reason: str = ""
    preemptions: int = 0
    # steps from submit to the first emitted token (None until it streams;
    # survives into the terminal result for SLO accounting)
    ttft_steps: int | None = None

    def __array__(self, dtype=None, copy=None):
        arr = np.asarray(self.tokens, dtype)
        return arr.copy() if copy else arr

    def __len__(self) -> int:
        return len(self.tokens)

    def __iter__(self):
        return iter(self.tokens)

    def __getitem__(self, i):
        return self.tokens[i]

    @property
    def shape(self):
        return self.tokens.shape

    def tolist(self) -> list[int]:
        return self.tokens.tolist()

    # elementwise comparisons, so pre-lifecycle range checks like
    # ``(out >= 0).all()`` keep working on the typed result
    def __lt__(self, other):
        return np.asarray(self.tokens) < other

    def __le__(self, other):
        return np.asarray(self.tokens) <= other

    def __gt__(self, other):
        return np.asarray(self.tokens) > other

    def __ge__(self, other):
        return np.asarray(self.tokens) >= other


@dataclasses.dataclass(frozen=True, eq=False, init=False)
class Request:
    """One unit of work for :meth:`Engine.submit` — frozen, so a request
    enqueued on one thread can never be mutated under the engine.  The
    second positional slot stays the max-new-token count it has always
    been; ``max_new_tokens=`` is kept as a keyword alias so every
    pre-redesign caller survives unchanged."""

    prompt: np.ndarray           # (T,) int32
    max_new: int = 16
    # stable id for deterministic sampling; defaults to submission order
    request_id: int | None = None
    # higher priority admits first and may preempt strictly-lower-priority
    # active requests when admission is slot- or block-starved
    priority: int = 0
    # engine steps (not wall clock, so chaos/CI replays are deterministic)
    # the request may participate in before it FAILs; None = no deadline
    deadline_steps: int | None = None
    # per-request sampling seed; None inherits ServeConfig.seed (the
    # default computes bit-identical keys to the pre-redesign engine)
    seed: int | None = None
    # per-request streaming callback, invoked in addition to the step-level
    # one; not journaled (callbacks are not durable state)
    on_token: TokenCallback | None = None

    def __init__(
        self,
        prompt,
        max_new: int | None = None,
        request_id: int | None = None,
        priority: int = 0,
        deadline_steps: int | None = None,
        seed: int | None = None,
        on_token: TokenCallback | None = None,
        *,
        max_new_tokens: int | None = None,
    ):
        if max_new_tokens is not None:
            if max_new is not None:
                raise TypeError(
                    "pass the token budget positionally (max_new) or as "
                    "max_new_tokens=, not both"
                )
            max_new = max_new_tokens
        object.__setattr__(self, "prompt", prompt)
        object.__setattr__(self, "max_new", 16 if max_new is None else int(max_new))
        object.__setattr__(self, "request_id", request_id)
        object.__setattr__(self, "priority", priority)
        object.__setattr__(self, "deadline_steps", deadline_steps)
        object.__setattr__(self, "seed", seed)
        object.__setattr__(self, "on_token", on_token)

    @property
    def max_new_tokens(self) -> int:
        return self.max_new


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Admission + step-loop scheduling knobs (frozen; validation runs at
    construction so invalid combos fail eagerly, next to the fields)."""

    batch: int = 4               # number of KV slots (decode batch width)
    # >0: right-pad prompts to a multiple of this so monolithic prefill
    # compiles once per bucket, not once per length (global-attention
    # models only; other families fall back to exact-length prefill)
    prefill_bucket: int = 0
    # >0: token-level unified scheduler — prompts stream into KV through a
    # batch-1 scratch lane in fixed chunks of this many tokens, interleaved
    # with decode steps.  0 (default) keeps monolithic fused admission,
    # which is the chunked scheduler's bitwise differential oracle.
    prefill_chunk: int = 0
    # chunked only: max prefill tokens advanced per engine step
    # (token_budget // prefill_chunk chunks).  None = unlimited, which
    # degenerates to whole-prompt admission within one step.
    token_budget: int | None = None
    # bound the waiting queue: a submit that would exceed it is REJECTED
    # immediately (load shedding) instead of growing the queue without
    # bound.  None = unbounded.
    max_waiting: int | None = None
    # watchdog: consecutive steps with zero active slots and zero admission
    # progress (while requests wait) before the head of the queue is shed
    # REJECTED — the engine degrades loudly instead of livelocking on a
    # pool that will never free (external pressure, accounting bugs).
    stall_patience: int = 64
    # False: pure FIFO — priority ordering, priority preemption, and
    # chunk-granular prefill takeover are all disabled
    priorities: bool = True

    def __post_init__(self):
        if self.batch < 1:
            raise ValueError(f"batch (KV slot count) must be >= 1: {self.batch}")
        if self.prefill_bucket < 0:
            raise ValueError(
                f"prefill_bucket must be >= 0 (0 disables bucketing): "
                f"{self.prefill_bucket}"
            )
        if self.prefill_chunk < 0:
            raise ValueError(
                f"prefill_chunk must be >= 0 (0 = monolithic admission): "
                f"{self.prefill_chunk}"
            )
        if self.token_budget is not None:
            if self.prefill_chunk == 0:
                raise ValueError(
                    f"token_budget={self.token_budget} only takes effect "
                    f"with chunked prefill; set prefill_chunk > 0 or drop "
                    f"token_budget"
                )
            if self.token_budget < self.prefill_chunk:
                raise ValueError(
                    f"token_budget ({self.token_budget}) must cover at "
                    f"least one prefill_chunk ({self.prefill_chunk}) per "
                    f"step, or admission livelocks"
                )
        if self.max_waiting is not None and self.max_waiting < 1:
            raise ValueError(
                f"max_waiting must be >= 1 (or None for unbounded): "
                f"{self.max_waiting}"
            )
        if self.stall_patience < 1:
            raise ValueError(
                f"stall_patience must be >= 1 step: {self.stall_patience}"
            )


@dataclasses.dataclass(frozen=True)
class KVConfig:
    """KV-cache layout + paged-pool knobs."""

    # "contiguous": one (slots, max_len) KV ring per layer — HBM is sized
    # by the worst case.  "paged": a refcounted block pool + per-row block
    # tables (serve/kvcache.BlockPool); capacity tracks LIVE tokens,
    # prompts sharing a prefix alias physical blocks, and `batch` becomes a
    # scheduling cap instead of a memory cap.  The contiguous layout is the
    # paged engine's bitwise differential oracle.
    layout: str = "contiguous"
    # paged: tokens per physical KV block
    block_size: int = 16
    # paged: pool size per layer, INCLUDING the sink block.  None sizes the
    # pool to the contiguous layout's footprint (batch * max_len tokens)
    # plus the sink, which is what the equal-HBM benchmarks compare.
    num_blocks: int | None = None
    # paged: alias physical blocks across requests sharing a prompt prefix
    # (radix index + copy-on-write; see serve/kvcache.BlockPool)
    prefix_sharing: bool = True
    # pin the contiguous flash-decoding KV split (None = auto-tuned).  The
    # paged layout always splits at block_size; pinning the contiguous
    # oracle to the same value makes the two layouts' online-softmax
    # reductions identical, hence bitwise-comparable.
    decode_block: int | None = None

    def __post_init__(self):
        if self.layout not in ("contiguous", "paged"):
            raise ValueError(
                f"kv_layout must be 'contiguous' or 'paged': {self.layout!r}"
            )
        if self.decode_block is not None and self.decode_block < 1:
            raise ValueError(f"decode_block must be >= 1: {self.decode_block}")
        if self.layout == "paged":
            if self.block_size < 1:
                raise ValueError(f"block_size must be >= 1: {self.block_size}")
            if self.num_blocks is not None and self.num_blocks < 2:
                raise ValueError(
                    f"num_blocks counts the sink block too, so a usable pool "
                    f"needs num_blocks >= 2: got {self.num_blocks} (or pass "
                    f"None to size the pool to the contiguous footprint)"
                )
            if (
                self.decode_block is not None
                and self.decode_block != self.block_size
            ):
                raise ValueError(
                    f"the paged layout always splits decode attention at "
                    f"block_size={self.block_size}; decode_block="
                    f"{self.decode_block} contradicts it — drop decode_block "
                    f"(it is only for pinning a CONTIGUOUS oracle) or set "
                    f"them equal"
                )
        elif self.num_blocks is not None:
            raise ValueError(
                f"num_blocks={self.num_blocks} only applies to "
                f"kv_layout='paged'; the contiguous layout is sized by "
                f"batch * max_len"
            )


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """Compute-substrate routing."""

    # "xla" | "pallas": route projection GEMMs through the Pallas kernel
    # with mapper-chosen tiles (core.mapper.choose_matmul_tiles)
    matmul: str = "xla"
    # "flash" | "xla": decode-attention substrate.  "flash" (default) is
    # the ragged flash-decoding path (per-slot live lengths, KV reads
    # scale with live length); "xla" is the masked dense/blockwise oracle.
    attention: str = "flash"
    # "off" | "checksum" | "paranoid": ABFT verification of the decode
    # step (kernels/abft.py).  "checksum" column-checksums every
    # projection GEMM and fingerprints 4 sampled rows of each paged
    # decode-attention output; "paranoid" fingerprints every row.  Arms
    # the engine's detect->localize->retry->quarantine pipeline
    # (paged layout only).  Served tokens are bitwise identical to "off".
    abft: str = "off"
    # decode steps between full weight-fingerprint passes (abft modes
    # only).  Checksums cannot see weight corruption — both sides of the
    # Huang–Abraham identity use the corrupted operand — so weights get a
    # periodic scrub instead: it re-reads every parameter, which at 1
    # (every step, the default and the strictest setting) can dominate a
    # memory-bound decode step.  At N > 1 a weight flip is caught at the
    # next scrub, i.e. up to N-1 steps after it lands; compute/KV faults
    # are still detected on the very step they strike.
    scrub_every: int = 1

    def __post_init__(self):
        if self.matmul not in ("xla", "pallas"):
            raise ValueError(f"matmul must be 'xla' or 'pallas': {self.matmul!r}")
        if self.attention not in ("flash", "xla"):
            raise ValueError(
                f"attention must be 'flash' or 'xla': {self.attention!r}"
            )
        if self.abft not in ("off", "checksum", "paranoid"):
            raise ValueError(
                f"abft must be 'off', 'checksum' or 'paranoid': {self.abft!r}"
            )
        if not isinstance(self.scrub_every, int) or self.scrub_every < 1:
            raise ValueError(
                f"scrub_every must be a positive int: {self.scrub_every!r}"
            )


@dataclasses.dataclass(frozen=True)
class DurabilityConfig:
    """Crash-consistency + corruption-defense knobs (serve/recovery.py)."""

    # a directory here arms the RecoveryManager — a crc32'd write-ahead
    # journal of submits/cancels/pops/token deltas (fsync'd once per step)
    # plus a crash-atomic snapshot of the full serving state every
    # `snapshot_every` steps, staged synchronously and published
    # tmp-dir+rename on a background thread.  restore_engine() rebuilds a
    # crashed engine with survivor outputs bitwise identical to the
    # never-crashed run.
    snapshot_dir: str | None = None
    snapshot_every: int = 32
    snapshot_keep: int = 3           # published snapshots retained by GC
    # fsync the journal every N per-step commits (submit/cancel/pop always
    # force a sync).  1 = classic WAL durability; raise it when the journal
    # lives on a slow disk and losing a few steps of tokens is acceptable.
    journal_fsync_every: int = 1
    # corruption quarantine: per-step NaN/Inf guard on decode logits — a
    # non-finite row FAILs (blocks released, survivors untouched) instead
    # of silently streaming garbage.  Costs nothing: the flag rides the
    # existing device->host token sync.
    guard_nan: bool = True
    # paged-only debug/detection mode: per-physical-block checksums
    # recomputed each step; an unexpected change in a block no live row
    # legally wrote quarantines every request referencing it (FAILED,
    # blocks released).  O(pool) device work per step — off by default.
    kv_checksum: bool = False
    # one-shot kernel-failure fallback: if the jitted decode path raises
    # (Pallas lowering/compile failure on an exotic backend), rebuild it on
    # the oracle substrate (flash -> masked xla; paged -> gather twin) with
    # a logged warning instead of dying.  Greedy outputs are substrate-
    # independent (tests pin this), so serving continues bitwise-intact.
    substrate_fallback: bool = True

    def __post_init__(self):
        if self.snapshot_every < 1:
            raise ValueError(
                f"snapshot_every must be >= 1 step: {self.snapshot_every}"
            )
        if self.snapshot_keep < 1:
            raise ValueError(
                f"snapshot_keep must be >= 1 snapshot: {self.snapshot_keep}"
            )
        if self.journal_fsync_every < 1:
            raise ValueError(
                f"journal_fsync_every must be >= 1 commit: "
                f"{self.journal_fsync_every}"
            )


# legacy flat ServeConfig kwarg -> (sub-config attribute, field name).
# ServeConfig.__init__ routes these through dataclasses.replace on the
# matching sub-config (re-running its validation) with one
# DeprecationWarning per construction naming every flat kwarg used.
_LEGACY_FLAT = {
    "batch": ("scheduler", "batch"),
    "prefill_bucket": ("scheduler", "prefill_bucket"),
    "prefill_chunk": ("scheduler", "prefill_chunk"),
    "token_budget": ("scheduler", "token_budget"),
    "max_waiting": ("scheduler", "max_waiting"),
    "stall_patience": ("scheduler", "stall_patience"),
    "priorities": ("scheduler", "priorities"),
    "kv_layout": ("kv", "layout"),
    "block_size": ("kv", "block_size"),
    "num_blocks": ("kv", "num_blocks"),
    "prefix_sharing": ("kv", "prefix_sharing"),
    "decode_block": ("kv", "decode_block"),
    "matmul": ("kernel", "matmul"),
    "attention": ("kernel", "attention"),
    "abft": ("kernel", "abft"),
    "scrub_every": ("kernel", "scrub_every"),
    "snapshot_dir": ("durability", "snapshot_dir"),
    "snapshot_every": ("durability", "snapshot_every"),
    "snapshot_keep": ("durability", "snapshot_keep"),
    "journal_fsync_every": ("durability", "journal_fsync_every"),
    "guard_nan": ("durability", "guard_nan"),
    "kv_checksum": ("durability", "kv_checksum"),
    "substrate_fallback": ("durability", "substrate_fallback"),
}


@dataclasses.dataclass(init=False)
class ServeConfig:
    """Engine configuration: shape/sampling fields at the top level plus
    four nested sub-configs (scheduler / kv / kernel / durability).

    Backward compatibility is two-sided: every pre-redesign flat kwarg
    still constructs (``ServeConfig(block_size=32)`` routes into
    ``kv.block_size`` with a DeprecationWarning), and every flat name
    still READS (``scfg.block_size`` is a property over ``kv.block_size``)
    so fingerprints, engine internals, and user code survive unchanged.
    ``dataclasses.replace`` works with both spellings."""

    max_len: int = 256
    temperature: float = 0.0
    seed: int = 0
    scheduler: SchedulerConfig = dataclasses.field(
        default_factory=SchedulerConfig
    )
    kv: KVConfig = dataclasses.field(default_factory=KVConfig)
    kernel: KernelConfig = dataclasses.field(default_factory=KernelConfig)
    durability: DurabilityConfig = dataclasses.field(
        default_factory=DurabilityConfig
    )

    def __init__(
        self,
        max_len: int = 256,
        temperature: float = 0.0,
        seed: int = 0,
        scheduler: SchedulerConfig | None = None,
        kv: KVConfig | None = None,
        kernel: KernelConfig | None = None,
        durability: DurabilityConfig | None = None,
        **flat,
    ):
        self.max_len = max_len
        self.temperature = temperature
        self.seed = seed
        self.scheduler = scheduler if scheduler is not None else SchedulerConfig()
        self.kv = kv if kv is not None else KVConfig()
        self.kernel = kernel if kernel is not None else KernelConfig()
        self.durability = (
            durability if durability is not None else DurabilityConfig()
        )
        if flat:
            unknown = sorted(set(flat) - set(_LEGACY_FLAT))
            if unknown:
                raise TypeError(
                    f"ServeConfig got unexpected kwargs: {', '.join(unknown)}"
                )
            warnings.warn(
                f"flat ServeConfig kwarg(s) {sorted(flat)} are deprecated; "
                f"use the nested sub-configs "
                f"(scheduler=SchedulerConfig(...), kv=KVConfig(...), "
                f"kernel=KernelConfig(...), durability=DurabilityConfig(...))",
                DeprecationWarning,
                stacklevel=2,
            )
            grouped: dict[str, dict] = {}
            for name, val in flat.items():
                sub, field = _LEGACY_FLAT[name]
                grouped.setdefault(sub, {})[field] = val
            for sub, kwargs in grouped.items():
                # replace() re-runs the sub-config's __post_init__, so flat
                # construction validates exactly like nested construction
                setattr(self, sub, dataclasses.replace(getattr(self, sub), **kwargs))
        self.__post_init__()

    def __post_init__(self):
        # cross-sub-config checks live here, next to the fields they span;
        # everything field-local validates inside its own sub-config
        if self.max_len < 2:
            raise ValueError(
                f"max_len must be >= 2 (one prompt token + one generated): "
                f"{self.max_len}"
            )
        if self.kv_checksum and self.kv_layout != "paged":
            raise ValueError(
                "kv_checksum tracks per-physical-block sums, which only "
                "exist under kv_layout='paged'"
            )
        if self.abft != "off" and self.kv_layout != "paged":
            raise ValueError(
                "abft localizes corruption through the paged pool's "
                "per-block fingerprints and the paged attention twin; "
                "set kv_layout='paged' (or abft='off')"
            )
        if self.kv_layout == "paged" and self.max_len % self.block_size:
            raise ValueError(
                f"max_len {self.max_len} must be a multiple of "
                f"block_size {self.block_size}"
            )
        if self.prefill_chunk > 0 and self.max_len % self.prefill_chunk:
            raise ValueError(
                f"max_len {self.max_len} must be a multiple of "
                f"prefill_chunk {self.prefill_chunk} so the final chunk's "
                f"right-padding never overflows the scratch lane"
            )

    # ----- flat read-through aliases (pre-redesign field names) -----
    @property
    def batch(self) -> int:
        return self.scheduler.batch

    @property
    def prefill_bucket(self) -> int:
        return self.scheduler.prefill_bucket

    @property
    def prefill_chunk(self) -> int:
        return self.scheduler.prefill_chunk

    @property
    def token_budget(self) -> int | None:
        return self.scheduler.token_budget

    @property
    def max_waiting(self) -> int | None:
        return self.scheduler.max_waiting

    @property
    def stall_patience(self) -> int:
        return self.scheduler.stall_patience

    @property
    def priorities(self) -> bool:
        return self.scheduler.priorities

    @property
    def kv_layout(self) -> str:
        return self.kv.layout

    @property
    def block_size(self) -> int:
        return self.kv.block_size

    @property
    def num_blocks(self) -> int | None:
        return self.kv.num_blocks

    @property
    def prefix_sharing(self) -> bool:
        return self.kv.prefix_sharing

    @property
    def decode_block(self) -> int | None:
        return self.kv.decode_block

    @property
    def matmul(self) -> str:
        return self.kernel.matmul

    @property
    def attention(self) -> str:
        return self.kernel.attention

    @property
    def abft(self) -> str:
        return self.kernel.abft

    @property
    def snapshot_dir(self) -> str | None:
        return self.durability.snapshot_dir

    @property
    def snapshot_every(self) -> int:
        return self.durability.snapshot_every

    @property
    def snapshot_keep(self) -> int:
        return self.durability.snapshot_keep

    @property
    def journal_fsync_every(self) -> int:
        return self.durability.journal_fsync_every

    @property
    def guard_nan(self) -> bool:
        return self.durability.guard_nan

    @property
    def kv_checksum(self) -> bool:
        return self.durability.kv_checksum

    @property
    def substrate_fallback(self) -> bool:
        return self.durability.substrate_fallback

    def resolved_num_blocks(self) -> int:
        if self.num_blocks is not None:
            return self.num_blocks
        return self.batch * self.max_len // self.block_size + 1  # + sink

    @classmethod
    def from_plan_knobs(
        cls,
        knobs,
        *,
        max_len: int,
        temperature: float = 0.0,
        seed: int = 0,
        kernel: KernelConfig | None = None,
        durability: DurabilityConfig | None = None,
    ) -> "ServeConfig":
        """Map planner knobs (core/serveplan.ServeKnobs) onto the nested
        sub-configs.  Under the contiguous layout the planner's block_size
        pins the decode kernel's online-softmax split (KVConfig.decode_block)
        rather than a physical pool block."""
        if knobs.kv_layout == "paged":
            kv = KVConfig(
                layout="paged", block_size=knobs.block_size,
                num_blocks=knobs.num_blocks,
            )
        else:
            kv = KVConfig(layout="contiguous", decode_block=knobs.block_size)
        return cls(
            max_len=max_len,
            temperature=temperature,
            seed=seed,
            scheduler=SchedulerConfig(
                batch=knobs.slots,
                prefill_chunk=knobs.prefill_chunk,
                token_budget=knobs.token_budget,
            ),
            kv=kv,
            kernel=kernel,
            durability=durability,
        )

    @classmethod
    def autotune(
        cls,
        model_cfg: ModelConfig,
        *,
        max_len: int = 256,
        workload=None,
        hardware=None,
        space=None,
        kv_budget_tokens: int | None = None,
        calibration=None,
        cache: bool | str = True,
        temperature: float = 0.0,
        seed: int = 0,
        kernel: KernelConfig | None = None,
        durability: DurabilityConfig | None = None,
    ) -> "ServeConfig":
        """Build a ServeConfig from the DSE planner (core/serveplan.py):
        sweep the joint (slots, layout, block_size, num_blocks,
        prefill_chunk, token_budget) space under an iso-HBM KV budget, and
        map the winning knobs onto the nested sub-configs.  The plan itself
        is attached as ``cfg.autotune_plan`` for provenance; winners persist
        in the REPRO_SERVE_PLAN_CACHE store, so repeat constructions are a
        cache hit.  Kernel/durability choices are not planned — pass them
        through unchanged."""
        from repro.core import serveplan  # planner is numpy-only; lazy

        plan = serveplan.plan_serve(
            model_cfg,
            max_len=max_len,
            workload=workload,
            hardware=hardware,
            space=space,
            kv_budget_tokens=kv_budget_tokens,
            calibration=calibration,
            cache=cache,
        )
        cfg = cls.from_plan_knobs(
            plan.knobs,
            max_len=max_len,
            temperature=temperature,
            seed=seed,
            kernel=kernel,
            durability=durability,
        )
        cfg.autotune_plan = plan
        return cfg


@dataclasses.dataclass
class _ReqInfo:
    """Host-side record of one request, alive from submit to pop_result."""

    rid: int
    prompt: np.ndarray
    budget: int                  # effective max_new_tokens
    priority: int
    deadline: int | None         # absolute engine step number, or None
    seq: int                     # arrival order (FIFO tie-break in-priority)
    status: RequestStatus = RequestStatus.WAITING
    reason: str = ""
    preemptions: int = 0
    # resolved sampling seed (Request.seed or ServeConfig.seed) and its
    # precomputed per-request PRNG base fold_in(PRNGKey(seed), rid); the
    # jitted programs fold the step index in on device, completing the
    # legacy fold_in(fold_in(PRNGKey(seed), rid), t) chain bit-for-bit
    seed: int = 0
    key: np.ndarray | None = None
    submitted: int = 0           # engine step count at submit
    ttft: int | None = None      # steps from submit to first emitted token
    on_token: TokenCallback | None = None  # per-request stream (not journaled)


@dataclasses.dataclass
class _SlotState:
    rid: int
    emitted: int                 # tokens generated so far (this occupancy)
    budget: int                  # effective max_new_tokens
    # preemption recovery: tokens already recorded before eviction.  While
    # emitted < replay the decode loop teacher-forces the recorded tokens
    # (asserting bitwise re-derivation) without re-emitting them.
    replay: int = 0
    # abft: checksum-failed steps survived while this request was live
    # (quarantined once it exceeds SDC_RETRY_BUDGET)
    sdc_retries: int = 0


@dataclasses.dataclass
class _PagedRow:
    """Block ownership of one live paged request (host side)."""

    blocks: list[int]            # logical block -> physical, len == total
    plen: int                    # prompt tokens
    n_shared_full: int           # leading full blocks aliased via the index
    tail_shared: bool            # partial prompt tail aliased (CoW pending)
    cow_dst: int | None          # pre-allocated CoW target for the tail


@dataclasses.dataclass
class _PrefillLane:
    """One mid-flight chunked prefill: the PREFILLING request holds a slot
    (and, paged, its blocks) while its prompt streams through the batch-1
    scratch cache chunk by chunk.  Nothing is published to the shared KV
    until install time, so dropping a lane needs no device writes."""

    rid: int
    slot: int
    filled: int = 0              # prompt tokens already through the scratch
    row: _PagedRow | None = None  # paged ownership (radix-registered at install)


def _pallas_mm(x: jax.Array, w: jax.Array) -> jax.Array:
    """(..., K) @ (K, N) through the schedule-driven Pallas matmul."""
    from repro.kernels.matmul.ops import matmul

    out = matmul(x.reshape(-1, x.shape[-1]), w)
    return out.reshape(x.shape[:-1] + (w.shape[-1],))


def _pallas_mm_abft(x: jax.Array, w: jax.Array) -> jax.Array:
    """ABFT-checked Pallas matmul: the kernel emits per-row-block column
    checksums verified in-program; the verdict joins the active
    AbftTrace's flags (the trace-level e^T check still runs on top, so
    the injected-fault path is covered on both substrates)."""
    from repro.arch import layers as L
    from repro.kernels.matmul.ops import matmul_abft

    out, bad = matmul_abft(x.reshape(-1, x.shape[-1]), w)
    trace = L._ABFT[0]
    if trace is not None:
        trace.flags.append(bad)
    return out.reshape(x.shape[:-1] + (w.shape[-1],))


# checksum-failed steps one request survives (each costs a rewind +
# oracle-substrate re-execution) before it is quarantined as the probable
# corruption source
SDC_RETRY_BUDGET = 2


class SDCUnlocalizedError(RuntimeError):
    """A detected silent-data-corruption could not be pinned to one
    request (the oracle-substrate retry still failed its checksums, or
    the weight fingerprint itself changed).  Raised BEFORE the step's
    tokens are emitted or journaled, so the newest snapshot + journal
    replay a state with no corrupt token in it: restore via
    ``recovery.restore_engine`` (with freshly loaded params) instead of
    serving wrong tokens."""


class Engine:
    """Continuous-batching engine over the model zoo's prefill/decode."""

    def __init__(self, cfg: ModelConfig, params: Any, scfg: ServeConfig):
        if cfg.family == "encdec":
            raise ValueError(
                "continuous batching serves decoder-only LMs; whisper-style "
                "encdec requests need per-request encoder state"
            )
        self.cfg = cfg
        self.model = build(cfg)
        self.params = params
        self.scfg = scfg
        self._abft = scfg.abft if scfg.abft != "off" else None
        if scfg.matmul == "pallas":
            self._impl = _pallas_mm_abft if self._abft else _pallas_mm
        else:
            self._impl = None
        self._attn = "flash" if scfg.attention == "flash" else None
        self._paged = scfg.kv_layout == "paged"

        if self._paged:
            if not kvcache.supports_paged(cfg):
                raise ValueError(
                    f"kv_layout='paged' needs all-global attention; "
                    f"{cfg.name} has ring/recurrent/hybrid caches"
                )
            nb = scfg.resolved_num_blocks()
            self.caches = kvcache.build_paged_caches(
                cfg, scfg.batch, scfg.max_len, nb, scfg.block_size
            )
            self.pool = kvcache.BlockPool(nb, scfg.block_size)
            self._axes = None
        else:
            self.caches = kvcache.build_caches(cfg, scfg.batch, scfg.max_len)
            self.pool = None
            self._axes = kvcache.slot_axes(cfg, scfg.max_len)
        self._free: deque[int] = deque(range(scfg.batch))
        # waiting rids, kept sorted by (-priority, seq): head = best request.
        # Preempted requests keep their original seq, so they re-enter ahead
        # of later arrivals of the same priority.
        self._waiting: list[int] = []
        self._reqs: dict[int, _ReqInfo] = {}
        self._slots: dict[int, _SlotState] = {}
        self._rows: dict[int, _PagedRow] = {}
        self._outputs: dict[int, list[int]] = {}
        self._next_rid = 0
        self._next_seq = 0
        self._step_no = 0
        self._stalled = 0            # consecutive idle no-progress steps
        self._cur_tok = np.zeros((scfg.batch,), np.int32)
        self._seed_roots: dict[int, jax.Array] = {}  # seed -> PRNGKey(seed)
        # scheduling evidence for the iso-memory benches plus the lifecycle
        # counters the chaos harness and fault-storm bench report
        self.stats = {
            "peak_active": 0,
            "admitted": 0,
            "preempted": 0,
            "recovered": 0,
            "cancelled": 0,
            "expired": 0,
            "rejected": 0,
            "shed": 0,
            "quarantined": 0,   # corruption guard: rows FAILED mid-decode
            "fallbacks": 0,     # substrate fallbacks taken (0 or 1)
            "snapshots": 0,     # recovery snapshots staged
            "sdc_detected": 0,  # abft: steps whose checksums flagged
            "sdc_retried": 0,   # abft: oracle-substrate step re-executions
        }

        model, impl, axes = self.model, self._impl, self._axes
        max_len = scfg.max_len
        sample_one = self._sampler()

        def first_tok(logits, keys):
            # per-row base keys come in precomputed (fold_in(PRNGKey(seed),
            # rid)); folding t=0 here completes the legacy key chain bitwise
            return jax.vmap(
                lambda lg, k: sample_one(lg, jax.random.fold_in(k, jnp.int32(0)))
            )(logits, keys)

        def admit_fn(params, toks, big, slots_, keys, true_lens):
            """Fused admission: prefill `n` prompts (right-padded rows mask
            their tail; exact rows mask nothing), scatter each into its
            slot, and sample each request's first token — one dispatch."""
            n = toks.shape[0]
            small = kvcache.build_caches(cfg, n, max_len)
            with L.matmul_override(impl):
                logits, small = model.prefill(
                    params, toks, small, last_index=true_lens - 1
                )
            small = kvcache.mask_prompt_tail(small, true_lens)
            for i in range(n):
                big = kvcache.slot_store(
                    big, kvcache.take_slot(small, i, axes), slots_[i], axes
                )
            return first_tok(logits, keys), big

        def paged_prefill_fn(params, toks, keys, true_lens):
            """Paged admission, phase 1: prefill into a contiguous scratch
            (the SAME program shape the contiguous oracle admits through,
            so first tokens and packed K/V stay bitwise comparable) and
            sample each request's first token.  Phase 2 packs the scratch
            into pool blocks row by row (`kvcache.paged_store_row_blocks`),
            skipping blocks aliased from the prefix index."""
            n = toks.shape[0]
            small = kvcache.build_caches(cfg, n, max_len)
            with L.matmul_override(impl):
                logits, small = model.prefill(
                    params, toks, small, last_index=true_lens - 1
                )
            return first_tok(logits, keys), {"k": small["k"], "v": small["v"]}

        # the KV cache pytree is DONATED: the ring scatter and admission
        # slot_store update the buffers in place instead of copying every
        # KV tensor per step.  The engine immediately rebinds self.caches
        # to the jit output, so the consumed input is never read again.
        # The paged helpers follow the same contract: pack/set/CoW are
        # donated scatters into the pool, never pool copies.
        # ---- abft state (kernels/abft.py) ----
        # fault operand: one-shot transient-SDC injection point threaded
        # through the jitted decode program (zeros = disarmed; the armed
        # and disarmed programs are the same executable)
        self._fault = np.zeros((8,), np.int32)
        self._abft_probe: dict[str, int] = {}  # trace-time check counts
        self._retry_fn = None       # oracle-substrate re-execution (lazy)
        self._rewind = None         # len-rewind program (lazy)
        self._wsums0 = None
        self._colstats = None
        if self._abft:
            from repro.kernels.abft import weight_colstats, weight_sums

            # per-leaf weight fingerprints, baselined ONCE here: ABFT
            # checksums can't see weight flips (both sides of the identity
            # use the corrupted operand), so decode re-reduces and compares
            # exactly — same jitted program on every scrub, bitwise stable
            self._wsums0 = jax.jit(weight_sums)(params)
            # static per-column |w| bounds for the checksum tolerance, so
            # the per-step check never re-reads the (immutable) weights
            self._colstats = jax.jit(weight_colstats)(params)
        self._decode = self._make_decode(self._attn)
        self._fallback_done = False
        self._admit_group = jax.jit(admit_fn, donate_argnums=(2,))
        self._paged_prefill = jax.jit(paged_prefill_fn)
        self._pack_row = jax.jit(kvcache.paged_store_row_blocks, donate_argnums=(0,))
        self._set_row = jax.jit(kvcache.paged_set_row, donate_argnums=(0,))
        self._cow = jax.jit(kvcache.paged_copy_block, donate_argnums=(0,))
        if self._paged:
            self._sink_row = np.zeros((scfg.max_len // scfg.block_size,), np.int32)
        else:
            self._sink_row = None

        # ---- token-level unified scheduler (prefill_chunk > 0) ----
        # Prompts stream through a persistent batch-1 contiguous scratch
        # cache in fixed (1, prefill_chunk) chunks: positions derive from
        # the scratch's length cursor (`positions=None` in logits_fn), so
        # chunk N continues exactly where chunk N-1 stopped and the K/V/
        # logits bits match a monolithic prefill of the whole prompt.
        # Install reuses the monolithic publication paths verbatim
        # (mask_prompt_tail + slot_store, or paged set-row + pack), which
        # is what makes the prefill_chunk=0 engine a bitwise oracle.
        self._chunk = scfg.prefill_chunk
        self._lane: _PrefillLane | None = None
        self._scratch = None
        if self._chunk:
            if not kvcache.supports_padded_prefill(cfg):
                raise ValueError(
                    f"prefill_chunk needs all-global attention (positions "
                    f"derive from the cache cursor and the final chunk is "
                    f"right-padded); {cfg.name} has ring/recurrent/hybrid "
                    f"caches — use monolithic admission (prefill_chunk=0)"
                )

            def chunk_fn(params, toks, scratch, last_index, key):
                """One fixed-shape prefill chunk through the scratch lane.
                A candidate first token is sampled every chunk at
                `last_index` (vmapped over the 1-row batch, mirroring the
                admission programs bit-for-bit); only the final chunk's
                survives on the host."""
                with L.matmul_override(impl):
                    x = L.embed(params["embed"], toks)
                    logits, scratch, _ = model.logits_fn(
                        params, x, positions=None, caches=scratch
                    )
                sel = jnp.take_along_axis(
                    logits, last_index[:, None, None], axis=1
                )[:, 0]
                return first_tok(sel, key[None]), scratch

            def install_fn(big, scratch, slot, true_lens):
                """Publish a completed lane into the contiguous ring — the
                exact monolithic admission path (tail mask + slot scatter),
                so the installed slot is bitwise the monolithic one."""
                small = kvcache.mask_prompt_tail(scratch, true_lens)
                return kvcache.slot_store(
                    big, kvcache.take_slot(small, 0, axes), slot, axes
                )

            self._chunk_step = jax.jit(chunk_fn, donate_argnums=(2,))
            self._install_slot = jax.jit(install_fn, donate_argnums=(0,))
            self._fresh_scratch = jax.jit(
                lambda: kvcache.build_caches(cfg, 1, max_len)
            )

        # optional per-physical-block checksum audit (paged only): host
        # mirror of |kpool|+|vpool| sums per block, verified after every
        # step against the blocks legally written that step
        self._kv_sums: np.ndarray | None = None
        self._pool_sums = None
        self._touched: set[int] = set()
        # abft localizes inter-step KV flips through the same per-block
        # fingerprints, so it arms them even without kv_checksum
        if scfg.kv_checksum or (self._abft and self._paged):

            def pool_sums_fn(caches):
                k = jnp.sum(
                    jnp.abs(caches["kpool"].astype(jnp.float32)),
                    axis=(0, 2, 3, 4),
                )
                v = jnp.sum(
                    jnp.abs(caches["vpool"].astype(jnp.float32)),
                    axis=(0, 2, 3, 4),
                )
                return k + v

            self._pool_sums = jax.jit(pool_sums_fn)
            self._refresh_kv_sums()

        # crash consistency: journal + periodic snapshots (serve/recovery)
        self.recovery = None
        if scfg.snapshot_dir:
            from repro.serve.recovery import RecoveryManager

            RecoveryManager.attach(
                self,
                scfg.snapshot_dir,
                every=scfg.snapshot_every,
                keep=scfg.snapshot_keep,
                fsync_every=scfg.journal_fsync_every,
            )

    def _sampler(self):
        temp = self.scfg.temperature

        def sample_one(logits: jax.Array, key: jax.Array) -> jax.Array:
            if temp <= 0:
                return jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return jax.random.categorical(key, logits / temp).astype(jnp.int32)

        return sample_one

    def _req_base_key(self, rid: int, seed: int) -> np.ndarray:
        """Per-request PRNG base ``fold_in(PRNGKey(seed), rid)``, computed
        once at submit.  The jitted programs fold the step index in on
        device, so with the default seed the full chain is bit-identical
        to the legacy ``fold_in(fold_in(PRNGKey(scfg.seed), rid), t)``."""
        root = self._seed_roots.get(seed)
        if root is None:
            root = self._seed_roots[seed] = jax.random.PRNGKey(seed)
        return np.asarray(jax.random.fold_in(root, rid), np.uint32)

    def _make_decode(self, attn):
        """Build the jitted decode program on substrate ``attn`` (rebuilt
        once by `_decode_call` on kernel failure).  Besides the sampled
        tokens it returns a per-row non-finite-logits flag — the
        corruption guard rides the token sync, costing no extra transfer.
        """
        model, impl, dblk = self.model, self._impl, self.scfg.decode_block
        sample_one = self._sampler()

        if self._abft:
            from repro.kernels.abft import AbftTrace, weight_sums

            from repro.kernels.abft import FAULT_SCRUB

            mode, wsums0, probe = self._abft, self._wsums0, self._abft_probe
            colstats = self._colstats

            def decode_abft_fn(params, toks, caches, keys, ts, fault):
                trace = AbftTrace(mode, fault, colstats)
                with (
                    L.matmul_override(impl),
                    L.attention_override(attn),
                    L.decode_block_override(dblk),
                    L.abft_override(trace),
                ):
                    logits, caches = model.decode_step(params, toks, caches)
                probe["mms"] = trace.mm_calls
                probe["attns"] = trace.attn_calls
                nxt = jax.vmap(
                    lambda lg, k, t: sample_one(lg, jax.random.fold_in(k, t))
                )(logits, keys, ts)
                bad = ~jnp.all(
                    jnp.isfinite(logits.astype(jnp.float32)), axis=-1
                )
                # full weight pass only on scrub steps (fault[FAULT_SCRUB],
                # set by the host on the scrub_every cadence) — it is the
                # one ABFT cost that scales with total params, not batch
                w_bad = jax.lax.cond(
                    fault[FAULT_SCRUB] != 0,
                    lambda: jnp.any(weight_sums(params) != wsums0),
                    lambda: jnp.zeros((), jnp.bool_),
                )
                flags = trace.any_bad().astype(jnp.int32) | (
                    w_bad.astype(jnp.int32) << 1
                )
                return (nxt, bad, flags), caches

            return jax.jit(decode_abft_fn, donate_argnums=(2,))

        def decode_fn(params, toks, caches, keys, ts):
            with (
                L.matmul_override(impl),
                L.attention_override(attn),
                L.decode_block_override(dblk),
            ):
                logits, caches = model.decode_step(params, toks, caches)
            nxt = jax.vmap(
                lambda lg, k, t: sample_one(lg, jax.random.fold_in(k, t))
            )(logits, keys, ts)
            bad = ~jnp.all(jnp.isfinite(logits.astype(jnp.float32)), axis=-1)
            return (nxt, bad), caches

        return jax.jit(decode_fn, donate_argnums=(2,))

    def _decode_call(self, *args):
        """Run the decode program, falling back ONCE to the oracle
        substrate on failure (flash -> masked xla attend; paged -> the
        gather twin, both reached by rebuilding with ``attn=None``).
        Pallas kernel failures surface at trace/compile time — before the
        donated caches are consumed — so the retry sees intact buffers."""
        try:
            return self._decode(*args)
        except Exception as e:
            if (
                self._fallback_done
                or not self.scfg.substrate_fallback
                or self._attn is None
            ):
                raise
            warnings.warn(
                f"decode substrate {self._attn!r} failed ({type(e).__name__}: "
                f"{e}); falling back to the oracle substrate once",
                RuntimeWarning,
                stacklevel=2,
            )
            self._fallback_done = True
            self._attn = None
            self._decode = self._make_decode(None)
            self.stats["fallbacks"] += 1
            return self._decode(*args)

    def _refresh_kv_sums(self) -> None:
        """(Re)baseline the per-block checksum mirror from the current
        device pools — at init and after a snapshot restore."""
        if self._pool_sums is not None:
            self._kv_sums = np.asarray(self._pool_sums(self.caches))

    # ---------------------------------------------------------- admission --
    def submit(self, req: Request) -> int:
        """Queue a request; returns its id.  Prompts longer than
        ``max_len - 1`` keep their most recent tokens; ``max_new_tokens`` is
        truncated so the request never outgrows its slot.  A full waiting
        queue (``ServeConfig.max_waiting``) REJECTs the submission instead
        of raising — poll :meth:`status` / :meth:`pop_result`."""
        rid = req.request_id if req.request_id is not None else self._next_rid
        if rid in self._reqs:
            raise ValueError(f"duplicate request_id {rid}")
        if req.deadline_steps is not None and req.deadline_steps < 0:
            raise ValueError(
                f"request {rid}: deadline_steps must be >= 0: "
                f"{req.deadline_steps}"
            )
        self._next_rid = max(self._next_rid, rid + 1)
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        max_len = self.scfg.max_len
        if len(prompt) >= max_len:
            prompt = prompt[-(max_len - 1) :]
        budget = min(int(req.max_new_tokens), max_len - len(prompt))
        if self._paged:
            # never let one request outgrow the whole pool: its admission
            # would wait forever for blocks that can't exist (deadlock),
            # and silently shrinking the budget would quietly diverge from
            # the contiguous oracle — reject loudly instead.  With the
            # default pool sizing (batch * max_len tokens) this can never
            # trigger: the max_len truncation above already bounds
            # prompt + budget to max_len <= capacity.
            cap_tokens = (self.pool.num_blocks - 1) * self.scfg.block_size
            if len(prompt) + budget > cap_tokens:
                raise ValueError(
                    f"request {rid} needs {len(prompt) + budget} KV tokens "
                    f"but the whole pool holds {cap_tokens}; grow "
                    f"num_blocks or shorten the request"
                )
        deadline = (
            self._step_no + req.deadline_steps
            if req.deadline_steps is not None
            else None
        )
        seed = self.scfg.seed if req.seed is None else int(req.seed)
        info = _ReqInfo(
            rid=rid,
            prompt=prompt,
            budget=budget,
            priority=int(req.priority),
            deadline=deadline,
            seq=self._next_seq,
            seed=seed,
            key=self._req_base_key(rid, seed),
            submitted=self._step_no,
            on_token=req.on_token,
        )
        self._next_seq += 1
        self._reqs[rid] = info
        self._outputs[rid] = []
        if budget <= 0 or len(prompt) == 0:
            self._finish(info, RequestStatus.FINISHED, "empty prompt or budget")
        elif (
            self.scfg.max_waiting is not None
            and len(self._waiting) >= self.scfg.max_waiting
        ):
            self.stats["rejected"] += 1
            self._finish(
                info,
                RequestStatus.REJECTED,
                f"queue full (max_waiting={self.scfg.max_waiting})",
            )
        else:
            self._enqueue(info)
        if self.recovery is not None:
            # journaled AFTER the outcome is known: the record carries the
            # terminal-at-submit status too, so replay needs no re-validation
            self.recovery.record_submit(info)
        return rid

    def _enqueue(self, info: _ReqInfo) -> None:
        bisect.insort(
            self._waiting,
            info.rid,
            key=lambda r: (-self._reqs[r].priority, self._reqs[r].seq),
        )

    def _finish(self, info: _ReqInfo, status: RequestStatus, reason: str) -> None:
        info.status = status
        info.reason = reason

    def _bucket_len(self, plen: int) -> int:
        scfg = self.scfg
        bucket = (
            scfg.prefill_bucket
            if kvcache.supports_padded_prefill(self.cfg)
            else 0
        )
        lpad = -(-plen // bucket) * bucket if bucket > 0 else plen
        if lpad > scfg.max_len:
            lpad = plen  # bucket would overflow the cache: exact length
        return lpad

    def _activate(self, info: _ReqInfo, slot: int, tok: int, on_token) -> bool:
        """Shared first-token bookkeeping; returns True when the request
        stays active (budget not exhausted at admission).  A recovering
        (preempted) request replays instead of emitting: its recorded
        first token must re-derive bitwise from the fresh prefill."""
        out = self._outputs[info.rid]
        replay = len(out)
        if replay:
            assert tok == out[0], (
                f"request {info.rid}: recovery re-prefill diverged at token "
                f"0 ({tok} != recorded {out[0]})"
            )
            self.stats["recovered"] += 1
        else:
            out.append(tok)
            if info.ttft is None:
                info.ttft = self._step_no - info.submitted
        self._cur_tok[slot] = tok
        info.status = RequestStatus.ACTIVE
        # the slot is registered BEFORE the callback runs so a callback
        # that cancels/preempts (stop sequences, client disconnects) goes
        # through the ordinary ACTIVE eviction path
        self._slots[slot] = _SlotState(
            rid=info.rid, emitted=1, budget=info.budget, replay=replay
        )
        done = info.budget == 1
        if not replay:
            self._emit_cbs(info, tok, 0, done, on_token)
        if info.status != RequestStatus.ACTIVE:
            return False  # callback ended it; slot already released
        if done:
            self._release_slot(slot)
            self._finish(info, RequestStatus.FINISHED, "")
            return False
        return True

    @staticmethod
    def _emit_cbs(
        info: _ReqInfo, tok: int, idx: int, done: bool, on_token
    ) -> None:
        """Deliver one emitted token to the per-request callback (if any)
        then the step-level one; either may cancel/preempt mid-delivery —
        callers re-check status afterwards exactly as before."""
        if info.on_token is not None:
            info.on_token(info.rid, tok, idx, done)
        if on_token is not None:
            on_token(info.rid, tok, idx, done)

    @staticmethod
    def _prompt_batch(lpad: int, infos: list[_ReqInfo]) -> tuple:
        """Right-pad one admission group's prompts into a (n, lpad) token
        batch plus per-row PRNG base keys / true lengths."""
        n = len(infos)
        toks = np.zeros((n, lpad), np.int32)
        keys = np.empty((n, 2), np.uint32)
        tlens = np.empty((n,), np.int32)
        for j, info in enumerate(infos):
            toks[j, : len(info.prompt)] = info.prompt
            keys[j], tlens[j] = info.key, len(info.prompt)
        return toks, keys, tlens

    def _admit_waiting(self, on_token: TokenCallback | None) -> bool:
        """Backfill every free slot from the queue.  Admissions sharing a
        prefill length run as ONE fused jitted call (prefill + tail mask +
        slot scatter + first-token sample); right-padding to
        ``prefill_bucket`` collapses mixed prompt lengths onto one compiled
        shape where that is exact (`kvcache.supports_padded_prefill`).
        Returns True when anything was admitted."""
        if self._paged:
            return self._admit_waiting_paged(on_token)
        groups: dict[int, list[tuple[_ReqInfo, int]]] = {}
        while self._free and self._waiting:
            info = self._reqs[self._waiting.pop(0)]
            slot = self._free.popleft()
            lpad = self._bucket_len(len(info.prompt))
            groups.setdefault(lpad, []).append((info, slot))

        for lpad, items in groups.items():
            toks, keys, tlens = self._prompt_batch(lpad, [it[0] for it in items])
            slots_ = np.asarray([it[1] for it in items], np.int32)
            toks0, self.caches = self._admit_group(
                self.params,
                jnp.asarray(toks),
                self.caches,
                jnp.asarray(slots_),
                jnp.asarray(keys),
                jnp.asarray(tlens),
            )
            toks0 = np.asarray(toks0)
            self.stats["admitted"] += len(items)
            for j, (info, slot) in enumerate(items):
                self._activate(info, slot, int(toks0[j]), on_token)
        self.stats["peak_active"] = max(self.stats["peak_active"], len(self._slots))
        return bool(groups)

    # ------------------------------------------------------ paged admission --
    def _admit_waiting_paged(self, on_token: TokenCallback | None) -> bool:
        """Paged admission: a request enters when a slot AND enough free
        blocks are available (strict order over (-priority, arrival) — the
        queue head never gets jumped).  Ownership is committed host-side
        first (prefix match -> retain aliases, allocate the rest, register
        this chain), then each prefill group runs as one jitted call and
        each row's private blocks are packed into the pool."""
        scfg = self.scfg
        bs = scfg.block_size
        n_blk = scfg.max_len // bs
        groups: dict[int, list[tuple[_ReqInfo, int, _PagedRow]]] = {}
        while self._free and self._waiting:
            info = self._reqs[self._waiting[0]]
            row = self._commit_row(info)
            if row is None:
                break  # head-of-line waits for completions to free blocks
            self._waiting.pop(0)
            slot = self._free.popleft()
            # monolithic admission packs in this same step, so the chain
            # can be published to the prefix index immediately
            self._register_chain(info, row)
            self._rows[slot] = row
            if self._kv_sums is not None:
                # checksum mode: admission packs (or aliases) these blocks
                # this step; aliased prefix blocks are untouched on device
                # but marking them is a harmless over-approximation
                self._touched.update(row.blocks)
            lpad = self._bucket_len(row.plen)
            groups.setdefault(lpad, []).append((info, slot, row))

        for lpad, items in groups.items():
            toks, keys, tlens = self._prompt_batch(lpad, [it[0] for it in items])
            toks0, scratch = self._paged_prefill(
                self.params,
                jnp.asarray(toks),
                jnp.asarray(keys),
                jnp.asarray(tlens),
            )
            toks0 = np.asarray(toks0)
            self.stats["admitted"] += len(items)
            for j, (info, slot, row) in enumerate(items):
                table_row = np.full((n_blk,), kvcache.SINK_BLOCK, np.int32)
                table_row[: len(row.blocks)] = row.blocks
                self.caches = self._set_row(
                    self.caches,
                    jnp.int32(slot),
                    jnp.asarray(table_row),
                    jnp.int32(row.plen),
                )
                n_prompt = -(-row.plen // bs)
                start = row.n_shared_full
                n_pack = n_prompt - start - (1 if row.tail_shared else 0)
                if n_pack > 0:
                    self.caches = self._pack_row(
                        self.caches,
                        scratch,
                        jnp.int32(j),
                        jnp.int32(start),
                        jnp.asarray(row.blocks[start : start + n_pack], jnp.int32),
                    )
                self._activate(info, slot, int(toks0[j]), on_token)
        self.stats["peak_active"] = max(self.stats["peak_active"], len(self._slots))
        return bool(groups)

    def _commit_row(self, info: _ReqInfo) -> _PagedRow | None:
        """Host-side block ownership for one paged admission: retain prefix
        aliases, allocate the rest, reserve the CoW target (so the first
        divergent write can never be starved by admissions racing it to
        the free list).  Returns None when the pool cannot satisfy the
        request right now — nothing is committed in that case."""
        scfg = self.scfg
        bs = scfg.block_size
        prompt, budget = info.prompt, info.budget
        plen = len(prompt)
        total = -(-(plen + budget) // bs)
        shared_full: list[int] = []
        shared_tail = None
        if scfg.prefix_sharing:
            shared_full, shared_tail = self.pool.match_prefix(prompt.tolist())
        n_shared = len(shared_full) + (1 if shared_tail is not None else 0)
        cow_needed = shared_tail is not None and budget > 1
        need = total - n_shared + (1 if cow_needed else 0)
        if need > self.pool.free_blocks:
            return None
        for b in shared_full:
            self.pool.retain(b)
        if shared_tail is not None:
            self.pool.retain(shared_tail)
        blocks = list(shared_full)
        if shared_tail is not None:
            blocks.append(shared_tail)
        while len(blocks) < total:
            blocks.append(self.pool.alloc())
        cow_dst = self.pool.alloc() if cow_needed else None
        return _PagedRow(
            blocks=blocks,
            plen=plen,
            n_shared_full=len(shared_full),
            tail_shared=shared_tail is not None,
            cow_dst=cow_dst,
        )

    def _register_chain(self, info: _ReqInfo, row: _PagedRow) -> None:
        """Publish this row's prompt blocks in the radix prefix index.
        Monolithic admission does this at commit time (it packs within the
        same step); chunked admission defers it to install time — a block
        whose K/V has not been packed yet must never be aliased by a
        concurrent admission."""
        if not self.scfg.prefix_sharing:
            return
        bs = self.scfg.block_size
        toks = info.prompt.tolist()
        n_full = row.plen // bs
        prev = -1
        for i in range(n_full):
            self.pool.register(prev, tuple(toks[i * bs : (i + 1) * bs]), row.blocks[i])
            prev = row.blocks[i]
        tail = tuple(toks[n_full * bs :])
        if tail and n_full < len(row.blocks):
            self.pool.register(prev, tail, row.blocks[n_full])

    # ----------------------------------------------- chunked prefill lane --
    def _start_lane(self) -> bool:
        """Claim the queue head for the scratch lane: reserve a slot (and,
        paged, commit block ownership) and mark it PREFILLING.  Returns
        False when no request can start (empty queue, no free slot, or a
        block-starved pool)."""
        if self._lane is not None or not self._waiting or not self._free:
            return False
        info = self._reqs[self._waiting[0]]
        row = None
        if self._paged:
            row = self._commit_row(info)
            if row is None:
                return False
        self._waiting.pop(0)
        slot = self._free.popleft()
        info.status = RequestStatus.PREFILLING
        self._scratch = self._fresh_scratch()
        self._lane = _PrefillLane(rid=info.rid, slot=slot, row=row)
        return True

    def _advance_lane(self):
        """Run ONE fixed-shape chunk of the lane's prompt through the
        scratch.  Only the final chunk is right-padded (intermediate
        chunks are always full, so the scratch length cursor that derives
        positions never overshoots mid-prompt).  Returns (done, candidate
        first token)."""
        lane = self._lane
        info = self._reqs[lane.rid]
        C = self._chunk
        plen = len(info.prompt)
        end = min(plen, lane.filled + C)
        toks = np.zeros((1, C), np.int32)
        toks[0, : end - lane.filled] = info.prompt[lane.filled : end]
        li = np.asarray([min(C - 1, max(0, plen - 1 - lane.filled))], np.int32)
        tok0, self._scratch = self._chunk_step(
            self.params,
            jnp.asarray(toks),
            self._scratch,
            jnp.asarray(li),
            jnp.asarray(info.key),
        )
        lane.filled = end
        return end >= plen, tok0

    def _install_lane(self, tok0, on_token: TokenCallback | None) -> None:
        """Publish a completed lane: install the scratch K/V through the
        EXACT monolithic publication path (contiguous tail-mask + slot
        scatter, or paged set-row + block pack), register the paged chain
        in the prefix index, and activate the request with its sampled
        first token — from here on it is indistinguishable from a
        monolithically admitted request."""
        lane = self._lane
        self._lane = None
        info = self._reqs[lane.rid]
        plen = len(info.prompt)
        slot = lane.slot
        if self._paged:
            row = lane.row
            bs = self.scfg.block_size
            n_blk = self.scfg.max_len // bs
            table_row = np.full((n_blk,), kvcache.SINK_BLOCK, np.int32)
            table_row[: len(row.blocks)] = row.blocks
            self.caches = self._set_row(
                self.caches,
                jnp.int32(slot),
                jnp.asarray(table_row),
                jnp.int32(plen),
            )
            n_prompt = -(-plen // bs)
            start = row.n_shared_full
            n_pack = n_prompt - start - (1 if row.tail_shared else 0)
            if n_pack > 0:
                self.caches = self._pack_row(
                    self.caches,
                    {"k": self._scratch["k"], "v": self._scratch["v"]},
                    jnp.int32(0),
                    jnp.int32(start),
                    jnp.asarray(row.blocks[start : start + n_pack], jnp.int32),
                )
            self._register_chain(info, row)
            self._rows[slot] = row
            if self._kv_sums is not None:
                self._touched.update(row.blocks)
        else:
            self.caches = self._install_slot(
                self.caches,
                self._scratch,
                jnp.int32(slot),
                jnp.asarray([plen], jnp.int32),
            )
        self.stats["admitted"] += 1
        self._activate(info, slot, int(np.asarray(tok0)[0]), on_token)
        self.stats["peak_active"] = max(self.stats["peak_active"], len(self._slots))

    def _drop_lane(self) -> None:
        """Release a mid-flight lane's resources.  No device writes are
        needed: install is the only publisher, so the device block table
        and slot caches were never touched — the slot and any committed
        blocks simply return to their free pools."""
        lane = self._lane
        self._lane = None
        if lane.row is not None:
            for b in lane.row.blocks:
                self.pool.release(b)
            if lane.row.cow_dst is not None:
                self.pool.release(lane.row.cow_dst)
        self._free.append(lane.slot)

    def _preempt_lane(self) -> None:
        """Chunk-granular preemption: a higher-priority arrival takes the
        lane between chunks.  The victim requeues PREEMPTED at its
        original arrival position; it has emitted zero tokens, so recovery
        is a plain re-prefill (through the prefix index when paged) —
        bitwise identical by determinism."""
        info = self._reqs[self._lane.rid]
        self._drop_lane()
        info.status = RequestStatus.PREEMPTED
        info.preemptions += 1
        self.stats["preempted"] += 1
        self._enqueue(info)

    def _schedule_chunks(self, on_token: TokenCallback | None) -> bool:
        """The unified scheduler's admission half: advance up to
        ``token_budget // prefill_chunk`` chunks this step — starting,
        installing, and (priority) preempting lanes at chunk granularity —
        then fall through to the shared decode of all live slots.  Returns
        True when any admission progress was made."""
        progressed = False
        budget = self.scfg.token_budget
        chunks_left = None if budget is None else budget // self._chunk
        while chunks_left is None or chunks_left > 0:
            if (
                self._lane is not None
                and self._waiting
                and self.scfg.priorities
                and self._reqs[self._waiting[0]].priority
                > self._reqs[self._lane.rid].priority
            ):
                self._preempt_lane()
                progressed = True
            if self._lane is None and not self._start_lane():
                break
            done, tok0 = self._advance_lane()
            progressed = True
            if chunks_left is not None:
                chunks_left -= 1
            if done:
                self._install_lane(tok0, on_token)
        return progressed

    def _resolve_cow(self) -> None:
        """Before rows write: give every slot still aliasing a shared
        prompt-tail block its pre-reserved private copy (first divergent
        write is about to land at ``plen``, inside that block)."""
        for slot in sorted(self._slots):
            row = self._rows.get(slot)
            if row is None or row.cow_dst is None:
                continue
            lb = row.plen // self.scfg.block_size
            src = row.blocks[lb]
            self.caches = self._cow(
                self.caches,
                jnp.int32(slot),
                jnp.int32(lb),
                jnp.int32(src),
                jnp.int32(row.cow_dst),
            )
            self.pool.release(src)
            if self._kv_sums is not None:
                self._touched.add(row.cow_dst)
            row.blocks[lb] = row.cow_dst
            row.cow_dst = None
            row.tail_shared = False

    def _evict_paged(self, slot: int) -> None:
        """Release a finished/cancelled/preempted row: repoint its device
        table at the sink (the always-full-batch decode keeps writing
        through dead rows, and these blocks are about to be reused) and
        return every owned block — including a still-pending CoW
        reservation — to the pool."""
        row = self._rows.pop(slot)
        self.caches = self._set_row(
            self.caches,
            jnp.int32(slot),
            jnp.asarray(self._sink_row),
            jnp.int32(0),
        )
        for b in row.blocks:
            self.pool.release(b)
        if row.cow_dst is not None:
            self.pool.release(row.cow_dst)

    def _release_slot(self, slot: int) -> None:
        """Evict a live slot for any reason (finish, cancel, deadline,
        preemption): paged rows release their blocks through the sink
        repoint, and the slot returns to the free ring for backfill."""
        del self._slots[slot]
        if self._paged:
            self._evict_paged(slot)
        self._free.append(slot)

    def _slot_of(self, rid: int) -> int:
        return next(s for s, st in self._slots.items() if st.rid == rid)

    def live_block_refs(self) -> dict[int, int]:
        """Physical block -> reference count implied by live rows (the
        ground truth the pool's refcounts must mirror; used by the fuzz
        suite's invariant checks)."""
        refs: dict[int, int] = {}
        rows = list(self._rows.values())
        if self._lane is not None and self._lane.row is not None:
            rows.append(self._lane.row)  # lane ownership commits at start
        for row in rows:
            for b in row.blocks:
                refs[b] = refs.get(b, 0) + 1
            if row.cow_dst is not None:
                refs[row.cow_dst] = refs.get(row.cow_dst, 0) + 1
        return refs

    # ---------------------------------------------------------- lifecycle --
    def status(self, rid: int) -> RequestStatus:
        info = self._reqs.get(rid)
        return RequestStatus.UNKNOWN if info is None else info.status

    def cancel(self, rid: int, reason: str = "cancelled") -> RequestStatus:
        """Cancel a request in any state: dequeue if waiting/preempted,
        evict-and-release-blocks if active.  Idempotent — cancelling a
        terminal (or unknown) request changes nothing and returns its
        current status.  Partial tokens stay retrievable via
        :meth:`pop_result`."""
        info = self._reqs.get(rid)
        if info is None:
            return RequestStatus.UNKNOWN
        if info.status in TERMINAL_STATUSES:
            return info.status
        if info.status == RequestStatus.ACTIVE:
            self._release_slot(self._slot_of(rid))
        elif info.status == RequestStatus.PREFILLING:
            self._drop_lane()  # nothing published yet: just return resources
        else:  # WAITING or PREEMPTED: sitting in the queue
            self._waiting.remove(rid)
        self.stats["cancelled"] += 1
        self._finish(info, RequestStatus.CANCELLED, reason)
        if self.recovery is not None:
            self.recovery.record_cancel(rid, reason)
        return RequestStatus.CANCELLED

    def preempt(self, rid: int) -> bool:
        """Forcibly evict an ACTIVE request: its blocks are released (table
        repointed at the sink) and it is requeued as PREEMPTED at its
        original arrival position.  On re-admission the prompt re-prefills
        through the prefix index and the already-generated tokens replay
        through the identical decode programs, so the resumed output is
        bitwise identical to an uninterrupted run.  Returns False for
        non-active requests."""
        info = self._reqs.get(rid)
        if info is None:
            return False
        if info.status == RequestStatus.PREFILLING:
            self._preempt_lane()
            return True
        if info.status != RequestStatus.ACTIVE:
            return False
        self._release_slot(self._slot_of(rid))
        info.status = RequestStatus.PREEMPTED
        info.preemptions += 1
        self.stats["preempted"] += 1
        self._enqueue(info)
        return True

    def _expire_deadlines(self) -> None:
        """FAIL every request whose deadline has passed, waiting or active,
        through the same eviction path as cancellation."""
        now = self._step_no
        for rid in [
            r
            for r in self._waiting
            if self._reqs[r].deadline is not None and now > self._reqs[r].deadline
        ]:
            self._waiting.remove(rid)
            self.stats["expired"] += 1
            self._finish(
                self._reqs[rid], RequestStatus.FAILED, "deadline expired in queue"
            )
        if self._lane is not None:
            info = self._reqs[self._lane.rid]
            if info.deadline is not None and now > info.deadline:
                self._drop_lane()
                self.stats["expired"] += 1
                self._finish(
                    info, RequestStatus.FAILED, "deadline expired while prefilling"
                )
        for slot in [
            s
            for s, st in sorted(self._slots.items())
            if self._reqs[st.rid].deadline is not None
            and now > self._reqs[st.rid].deadline
        ]:
            info = self._reqs[self._slots[slot].rid]
            self._release_slot(slot)
            self.stats["expired"] += 1
            self._finish(info, RequestStatus.FAILED, "deadline expired while active")

    def _blocks_needed(self, info: _ReqInfo) -> int:
        """Free blocks the paged admission of ``info`` would consume right
        now (worst-case reservation minus prefix aliases, plus a CoW
        target) — the same arithmetic `_admit_waiting_paged` commits."""
        bs = self.scfg.block_size
        total = -(-(len(info.prompt) + info.budget) // bs)
        if not self.scfg.prefix_sharing:
            return total
        shared_full, shared_tail = self.pool.match_prefix(info.prompt.tolist())
        n_shared = len(shared_full) + (1 if shared_tail is not None else 0)
        cow = shared_tail is not None and info.budget > 1
        return total - n_shared + (1 if cow else 0)

    def _preempt_pass(self) -> None:
        """Priority preemption: while the best waiting request is starved
        (no free slot, or — paged — not enough free blocks) and a strictly
        lower-priority request is active, evict the worst victim (lowest
        priority, then youngest) and retry.  Victims recover bitwise after
        re-admission, so a preemption that frees less than hoped (shared
        blocks stay referenced) costs replay latency, never correctness."""
        while self._waiting:
            head = self._reqs[self._waiting[0]]
            starved = not self._free or (
                self._paged and self._blocks_needed(head) > self.pool.free_blocks
            )
            if not starved:
                return
            victims = sorted(
                (self._reqs[st.rid].priority, -self._reqs[st.rid].seq, st.rid)
                for st in self._slots.values()
                if self._reqs[st.rid].priority < head.priority
            )
            if not victims:
                return
            self.preempt(victims[0][2])

    # ---------------------------------------------------------- integrity --
    def _quarantine(self, slot: int, reason: str) -> None:
        """Corruption response: FAIL the request in ``slot`` and release
        its resources through the ordinary eviction path — pool invariants
        hold and the other rows never notice (slot rows are
        computationally independent)."""
        info = self._reqs[self._slots[slot].rid]
        self._release_slot(slot)
        self.stats["quarantined"] += 1
        self._finish(info, RequestStatus.FAILED, reason)

    def _audit_kv_checksums(self) -> None:
        """kv_checksum mode: recompute per-physical-block sums and compare
        against last step's mirror.  A block that changed without a legal
        write this step (``self._touched``) is corrupt: every request
        referencing it is quarantined.  NaN sums compare equal to
        themselves here, so an already-quarantined poisoned block does not
        re-fire once it sits idle in the free list."""
        sums = np.asarray(self._pool_sums(self.caches))
        prev = self._kv_sums
        changed = (sums != prev) & ~(np.isnan(sums) & np.isnan(prev))
        if self._touched:
            changed[list(self._touched)] = False
        prefix = "sdc: " if self._abft else ""
        for b in np.nonzero(changed)[0]:
            b = int(b)
            owners = [
                s
                for s, row in self._rows.items()
                if b in row.blocks or row.cow_dst == b
            ]
            for s in owners:
                if s in self._slots:
                    self._quarantine(
                        s,
                        f"{prefix}KV corruption: block {b} checksum "
                        f"changed without a write",
                    )
        self._kv_sums = sums

    def arm_fault(
        self,
        site: int,
        call_idx: int,
        row: int,
        col: int,
        bit: int,
        layer: int = -1,
    ) -> None:
        """Arm the one-shot SDC injection operand for the next decode step
        (seeded chaos harness; see kernels/abft.py for the site codes, the
        ``col == -1`` largest-magnitude targeting, and the ``layer``
        semantics — ``-1`` targets checks outside the layer scan, e.g. the
        unembed GEMM).  The operand is cleared after the faulty pass, so
        the detect->retry re-execution models a *transient* flip and runs
        clean."""
        if not self._abft:
            raise ValueError(
                "arm_fault needs the abft pipeline: set "
                "KernelConfig.abft='checksum' (or 'paranoid')"
            )
        self._fault = np.array(
            [site, call_idx, row, col, bit, layer, 0, 0], np.int32
        )

    def _sdc_recover(self, flags: int, toks, keys, ts):
        """Detect -> localize -> retry.  Roll the donated caches back one
        position and re-execute the step on the oracle substrate with the
        fault operand disarmed: KV writes are positionally idempotent (the
        write position depends on lengths and tables, never on values), so
        the retry overwrites whatever KV the faulty pass poisoned.  A
        retry that still fails its checksums — or any weight-fingerprint
        mismatch — is unlocalizable: raise BEFORE emission, so the journal
        never records a poisoned token and the newest snapshot restores a
        corruption-free state."""
        self.stats["sdc_detected"] += 1
        if flags & 2:
            raise SDCUnlocalizedError(
                "weight fingerprint mismatch: parameter corruption cannot "
                "be retried away; restore from the newest snapshot with "
                "freshly loaded params"
            )
        # a step-level checksum cannot name the victim row, so every live
        # request is charged one retry; repeat offenders are quarantined
        # as the probable corruption source before the re-execution
        for s in sorted(self._slots):
            if self._slots[s].sdc_retries >= SDC_RETRY_BUDGET:
                self._quarantine(s, "sdc: retry budget exhausted")
            else:
                self._slots[s].sdc_retries += 1
        if self._rewind is None:
            self._rewind = jax.jit(
                lambda c: {**c, "len": c["len"] - 1}, donate_argnums=(0,)
            )
        if self._retry_fn is None:
            self._retry_fn = (
                self._decode if self._attn is None else self._make_decode(None)
            )
        self.caches = self._rewind(self.caches)
        self.stats["sdc_retried"] += 1
        # disarmed fault, but with the scrub flag set: the retry is the
        # one step that must rule out weight corruption regardless of the
        # scrub cadence before its checksum verdict is trusted
        from repro.kernels.abft import FAULT_SCRUB

        retry_fault = np.zeros((8,), np.int32)
        retry_fault[FAULT_SCRUB] = 1
        (nxt, bad, flags2), self.caches = self._retry_fn(
            self.params, toks, self.caches, keys, ts,
            jnp.asarray(retry_fault),
        )
        if int(flags2):
            raise SDCUnlocalizedError(
                "checksum failure persisted across the oracle-substrate "
                "retry: corruption is unlocalizable; restore from the "
                "newest snapshot"
            )
        return nxt, bad

    # -------------------------------------------------------------- drive --
    def step(self, on_token: TokenCallback | None = None) -> bool:
        """One engine iteration: expire deadlines, preempt for starved
        higher-priority arrivals, backfill free slots from the queue, then
        advance every occupied slot by one decode token.  Returns False
        once the engine is idle.  When a RecoveryManager is attached, the
        step's emitted-token deltas are journaled (and a snapshot staged on
        cadence) before control returns — the crash-durability boundary is
        the end of every step."""
        alive = self._step_core(on_token)
        if self.recovery is not None:
            self.recovery.after_step()
        return alive

    def _step_core(self, on_token: TokenCallback | None) -> bool:
        if self._abft and self._kv_sums is not None:
            # audit BEFORE decode, against the blocks the PREVIOUS step
            # legally wrote: an inter-step KV flip quarantines its owner
            # before the poisoned attention read, so the victim's partial
            # output stays a clean oracle prefix and survivors never see
            # the corrupt block
            self._audit_kv_checksums()
        self._step_no += 1
        self._touched = {kvcache.SINK_BLOCK}
        self._expire_deadlines()
        self._preempt_pass()
        admitted = False
        if self._chunk:
            admitted = self._schedule_chunks(on_token)
        else:
            while self._free and self._waiting:
                if not self._admit_waiting(on_token):
                    break  # paged: head of queue waits for free blocks
                admitted = True
        if self._paged:
            self._resolve_cow()
        if not self._slots:
            if self._lane is not None:
                # a mid-flight prefill lane IS progress: decode has nothing
                # to do yet, but the engine is anything but idle
                self._stalled = 0
                return True
            if not self._waiting:
                self._stalled = 0
                return False
            if admitted:
                # budget-1 admissions finished instantly: that is progress
                self._stalled = 0
            else:
                # zero active slots, zero admissions, a non-empty queue:
                # nothing inside the engine can free capacity.  Shed the
                # head after `stall_patience` such steps instead of
                # spinning forever on externally-held or leaked blocks.
                self._stalled += 1
                if self._stalled >= self.scfg.stall_patience:
                    info = self._reqs[self._waiting.pop(0)]
                    self.stats["shed"] += 1
                    self._finish(
                        info,
                        RequestStatus.REJECTED,
                        f"shed by watchdog: no admission progress in "
                        f"{self._stalled} idle steps",
                    )
                    self._stalled = 0
            return bool(self._waiting)
        self._stalled = 0

        B = self.scfg.batch
        keys = np.zeros((B, 2), np.uint32)
        ts = np.zeros((B,), np.int32)
        for s, st in self._slots.items():
            keys[s], ts[s] = self._reqs[st.rid].key, st.emitted
        if self._kv_sums is not None:
            # the one block each live row legally appends to this step:
            # decode writes KV at position plen + emitted - 1 (the first
            # generated token's KV lands on the next step's feed)
            bs = self.scfg.block_size
            for s, st in self._slots.items():
                row = self._rows[s]
                self._touched.add(row.blocks[(row.plen + st.emitted - 1) // bs])
        toks = jnp.asarray(self._cur_tok[:, None])
        jkeys, jts = jnp.asarray(keys), jnp.asarray(ts)
        if self._abft:
            fault = self._fault.copy()
            from repro.kernels.abft import FAULT_SCRUB

            fault[FAULT_SCRUB] = self._step_no % self.scfg.kernel.scrub_every == 0
            (nxt, bad, flags), self.caches = self._decode_call(
                self.params, toks, self.caches, jkeys, jts,
                jnp.asarray(fault),
            )
            self._fault = np.zeros((8,), np.int32)  # transient: one shot
            if int(flags):
                nxt, bad = self._sdc_recover(int(flags), toks, jkeys, jts)
        else:
            (nxt, bad), self.caches = self._decode_call(
                self.params, toks, self.caches, jkeys, jts
            )
        nxt = np.asarray(nxt)
        bad = np.asarray(bad)
        self._cur_tok = nxt.copy()
        if self.scfg.guard_nan and bad.any():
            # quarantine BEFORE emission: a poisoned row's sampled token is
            # garbage and must reach neither the output nor the journal
            for s in [s for s in sorted(self._slots) if bad[s]]:
                self._quarantine(
                    s, "non-finite logits: KV/activation corruption"
                )

        finished = []
        for s in sorted(self._slots):
            st = self._slots.get(s)
            if st is None:
                continue  # an on_token callback cancelled this row mid-loop
            tok = int(nxt[s])
            out = self._outputs[st.rid]
            if st.emitted < st.replay:
                # preemption recovery: the decode programs are
                # deterministic, so the replayed token must re-derive the
                # recorded one bitwise; it was already emitted pre-eviction
                assert tok == out[st.emitted], (
                    f"request {st.rid}: recovery replay diverged at token "
                    f"{st.emitted} ({tok} != recorded {out[st.emitted]})"
                )
                st.emitted += 1
                if st.emitted >= st.budget:
                    # crash recovery can replay a request to COMPLETION
                    # (it finished after the last snapshot): the journaled
                    # final token re-derives here and no fresh emission
                    # remains to trigger the ordinary finish path below
                    finished.append((s, st.rid))
                continue
            out.append(tok)
            st.emitted += 1
            done = st.emitted >= st.budget
            self._emit_cbs(self._reqs[st.rid], tok, st.emitted - 1, done, on_token)
            if done:
                finished.append((s, st.rid))
        for s, rid in finished:
            st = self._slots.get(s)
            if st is None or st.rid != rid:
                continue  # the done-callback already cancelled it
            self._release_slot(s)  # backfilled at the next step
            self._finish(self._reqs[rid], RequestStatus.FINISHED, "")
        if self._kv_sums is not None and not self._abft:
            self._audit_kv_checksums()
        return True

    def pop_result(self, rid: int) -> RequestResult:
        """Take a request's :class:`RequestResult`.  Terminal requests are
        consumed (their id becomes reusable); a live request's result is a
        non-consuming snapshot of its current status and partial tokens;
        an unknown id reports ``UNKNOWN`` instead of raising.  Long-running
        step()-driven servers must pop terminal results, or completed
        outputs accumulate without bound."""
        info = self._reqs.get(rid)
        if info is None:
            return RequestResult(
                RequestStatus.UNKNOWN,
                np.zeros((0,), np.int32),
                reason="request id never submitted (or already popped)",
            )
        tokens = np.asarray(self._outputs[rid], np.int32)
        result = RequestResult(
            info.status, tokens, info.reason, info.preemptions, info.ttft
        )
        if info.status in TERMINAL_STATUSES:
            del self._reqs[rid]
            del self._outputs[rid]
            if self.recovery is not None:
                self.recovery.record_pop(rid)
        return result

    def run(
        self,
        requests: list[Request] = (),
        on_token: TokenCallback | None = None,
    ) -> list[RequestResult]:
        """Submit ``requests``, drive the engine dry, and return each
        request's :class:`RequestResult` (in submission order; array-like,
        so legacy token-array callers keep working).  Returned results are
        evicted from the engine (their ids become reusable)."""
        rids = [self.submit(r) for r in requests]
        while self.step(on_token):
            pass
        return [self.pop_result(r) for r in rids]

    # legacy API (PR-2-era callers): identical signature, continuous core
    def generate(self, requests: list[Request]) -> list[RequestResult]:
        return self.run(requests)

    def close(self) -> None:
        """Flush and close the recovery journal (no-op without durability,
        idempotent).  Simulated crashes skip this on purpose — every
        journal record is already fsync'd at the step boundary that
        produced it."""
        if self.recovery is not None:
            self.recovery.close()
            self.recovery = None

    def __enter__(self) -> "Engine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class StaticEngine:
    """The pre-continuous static-batch engine, kept as the measured
    baseline: requests are packed into fixed batches, left-padded to the
    longest prompt, and decoded in lockstep to the largest
    ``max_new_tokens`` in the batch.  It shares the continuous engine's
    decode-attention substrate and donated caches, so the serve bench A/B
    measures scheduling, not kernels."""

    def __init__(self, cfg: ModelConfig, params: Any, scfg: ServeConfig):
        if scfg.kv_layout != "contiguous":
            # silently serving contiguous numbers under a paged config
            # would corrupt every A/B built on this baseline
            raise ValueError(
                "StaticEngine serves the contiguous layout only (fixed "
                "lockstep batches have no block pool); use Engine for "
                "kv_layout='paged', or drop kv_layout/num_blocks from "
                "ServeConfig for the static baseline"
            )
        self.cfg = cfg
        self.model = build(cfg)
        self.params = params
        self.scfg = scfg
        model = self.model
        impl = _pallas_mm if scfg.matmul == "pallas" else None
        attn = "flash" if scfg.attention == "flash" else None

        def prefill_fn(params, toks, caches):
            with L.matmul_override(impl):
                return model.prefill(params, toks, caches)

        def decode_fn(params, toks, caches):
            with L.matmul_override(impl), L.attention_override(attn):
                return model.decode_step(params, toks, caches)

        self._prefill = jax.jit(prefill_fn)
        # same matmul/attention substrates + donated caches as the
        # continuous engine, so the bench A/B isolates scheduling
        self._decode = jax.jit(decode_fn, donate_argnums=(2,))

    def _sample(self, logits: jax.Array, key: jax.Array) -> jax.Array:
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.scfg.temperature, axis=-1
        ).astype(jnp.int32)

    def _generate_batch(
        self,
        requests: list[Request],
        rids: list[int],
        on_token: TokenCallback | None,
    ) -> list[np.ndarray]:
        scfg = self.scfg
        plen = max(len(r.prompt) for r in requests)
        prompts = np.zeros((scfg.batch, plen), np.int32)
        for i, r in enumerate(requests):
            prompts[i, plen - len(r.prompt) :] = r.prompt  # left-pad
        max_new = max(r.max_new_tokens for r in requests)

        caches = self.model.init_caches(scfg.batch, scfg.max_len)
        logits, caches = self._prefill(self.params, jnp.asarray(prompts), caches)
        key = jax.random.PRNGKey(scfg.seed)
        outs = []
        tok = self._sample(logits, key)
        outs.append(np.asarray(tok))
        self._emit(requests, rids, outs, on_token)
        for _ in range(max_new - 1):
            key, sub = jax.random.split(key)
            logits, caches = self._decode(self.params, tok[:, None], caches)
            tok = self._sample(logits, sub)
            outs.append(np.asarray(tok))
            self._emit(requests, rids, outs, on_token)
        gen = np.stack(outs, axis=1)  # (B, max_new)
        return [gen[i, : r.max_new_tokens] for i, r in enumerate(requests)]

    @staticmethod
    def _emit(requests, rids, outs, on_token):
        if on_token is None:
            return
        t = len(outs) - 1
        for i, r in enumerate(requests):
            if t < r.max_new_tokens:
                on_token(rids[i], int(outs[-1][i]), t, t == r.max_new_tokens - 1)

    def generate(
        self,
        requests: list[Request],
        on_token: TokenCallback | None = None,
    ) -> list[np.ndarray]:
        """Serve in fixed batches of ``scfg.batch`` requests."""
        results: list[np.ndarray] = []
        B = self.scfg.batch
        for lo in range(0, len(requests), B):
            chunk = requests[lo : lo + B]
            rids = [
                r.request_id if r.request_id is not None else lo + i
                for i, r in enumerate(chunk)
            ]
            results.extend(self._generate_batch(chunk, rids, on_token))
        return results
