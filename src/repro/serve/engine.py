"""Continuous-batching serve engine: slot-based KV cache + async admission.

The paper's §6.3 lesson — allocate resources to match the delivered
throughput, don't leave them idle — recurs at request granularity in
serving.  The old engine padded every request in a static batch to the
slowest prompt and the largest ``max_new_tokens``; here the decode batch is
a fixed ring of ``batch`` KV *slots* (one compiled decode program,
shape-stable forever) and requests flow through it continuously:

  * **admission**: a waiting request is prefilled into a batch-1 cache and
    scattered into a free slot (`serve/kvcache.slot_store`), interleaved
    with decode steps;
  * **decode**: every step advances *all* occupied slots by one token;
  * **eviction + backfill**: a slot frees the moment its request finishes
    and is re-admitted from the queue on the next step — no drain barrier.

Sampling keys are derived per request as ``fold_in(fold_in(seed, rid), t)``
so outputs are bitwise-deterministic for a fixed seed regardless of arrival
order or slot assignment (slot rows are computationally independent).

The decode hot loop is memory-shaped (the paper's words-per-MAC argument at
serve granularity), so both of its memory sins are fixed here:

  * **flash-decoding attention** (``ServeConfig(attention="flash")``, the
    default): single-token attention routes through the ragged Pallas
    decode kernel (``kernels/flash_attention/decode_attention``; jnp twin
    on CPU) with per-slot live lengths traced, so each slot reads
    ``ceil(len/bk)`` KV blocks instead of scanning all ``max_len`` slots
    through a broadcast mask.  ``attention="xla"`` keeps the masked
    dense/blockwise oracle as the measured baseline.
  * **donated KV caches**: ``_decode``/``_admit_group`` donate the cache
    pytree, so the per-row ring scatter updates the buffers in place — no
    per-step copy of every KV tensor (the engine always rebinds
    ``self.caches`` to the jit output; the donated input is dead).

Decode GEMMs can be routed through the Pallas matmul with tile sizes from
the paper's blocking search (``core.mapper.choose_matmul_tiles``) exactly
like ``kernels/matmul/ops.py`` — enable with ``ServeConfig(matmul="pallas")``.

The pre-continuous static-batch loop survives as :class:`StaticEngine`, the
baseline that ``benchmarks/serve_bench.py`` measures against; it follows the
same ``attention`` setting so the A/B isolates scheduling.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.arch import layers as L
from repro.arch.model_zoo import build
from repro.configs.base import ModelConfig
from repro.serve import kvcache

# on_token(request_id, token, index, done)
TokenCallback = Callable[[int, int, int, bool], None]


@dataclasses.dataclass
class Request:
    prompt: np.ndarray           # (T,) int32
    max_new_tokens: int = 16
    # stable id for deterministic sampling; defaults to submission order
    request_id: int | None = None


@dataclasses.dataclass
class ServeConfig:
    batch: int = 4               # number of KV slots (decode batch width)
    max_len: int = 256
    temperature: float = 0.0
    seed: int = 0
    # >0: right-pad prompts to a multiple of this so prefill compiles once
    # per bucket, not once per length (global-attention models only; other
    # families silently fall back to exact-length prefill)
    prefill_bucket: int = 0
    # "xla" | "pallas": route projection GEMMs through the Pallas kernel
    # with mapper-chosen tiles (core.mapper.choose_matmul_tiles)
    matmul: str = "xla"
    # "flash" | "xla": decode-attention substrate.  "flash" (default) is
    # the ragged flash-decoding path (per-slot live lengths, KV reads
    # scale with live length); "xla" is the masked dense/blockwise oracle.
    attention: str = "flash"
    # "contiguous": one (slots, max_len) KV ring per layer — HBM is sized
    # by the worst case.  "paged": a refcounted block pool + per-row block
    # tables (serve/kvcache.BlockPool); capacity tracks LIVE tokens,
    # prompts sharing a prefix alias physical blocks, and `batch` becomes a
    # scheduling cap instead of a memory cap.  The contiguous layout is the
    # paged engine's bitwise differential oracle.
    kv_layout: str = "contiguous"
    # paged: tokens per physical KV block
    block_size: int = 16
    # paged: pool size per layer, INCLUDING the sink block.  None sizes the
    # pool to the contiguous layout's footprint (batch * max_len tokens)
    # plus the sink, which is what the equal-HBM benchmarks compare.
    num_blocks: int | None = None
    # paged: alias physical blocks across requests sharing a prompt prefix
    # (radix index + copy-on-write; see serve/kvcache.BlockPool)
    prefix_sharing: bool = True
    # pin the contiguous flash-decoding KV split (None = auto-tuned).  The
    # paged layout always splits at block_size; pinning the contiguous
    # oracle to the same value makes the two layouts' online-softmax
    # reductions identical, hence bitwise-comparable.
    decode_block: int | None = None

    def __post_init__(self):
        # silent fallbacks would report oracle numbers as flash (or xla
        # GEMMs as pallas) — reject anything outside the known substrates
        if self.matmul not in ("xla", "pallas"):
            raise ValueError(f"matmul must be 'xla' or 'pallas': {self.matmul!r}")
        if self.attention not in ("flash", "xla"):
            raise ValueError(
                f"attention must be 'flash' or 'xla': {self.attention!r}"
            )
        if self.kv_layout not in ("contiguous", "paged"):
            raise ValueError(
                f"kv_layout must be 'contiguous' or 'paged': {self.kv_layout!r}"
            )
        if self.kv_layout == "paged":
            if self.block_size < 1:
                raise ValueError(f"block_size must be >= 1: {self.block_size}")
            if self.max_len % self.block_size:
                raise ValueError(
                    f"max_len {self.max_len} must be a multiple of "
                    f"block_size {self.block_size}"
                )

    def resolved_num_blocks(self) -> int:
        if self.num_blocks is not None:
            return self.num_blocks
        return self.batch * self.max_len // self.block_size + 1  # + sink


@dataclasses.dataclass
class _SlotState:
    rid: int
    emitted: int                 # tokens generated so far
    budget: int                  # effective max_new_tokens


@dataclasses.dataclass
class _PagedRow:
    """Block ownership of one live paged request (host side)."""

    blocks: list[int]            # logical block -> physical, len == total
    plen: int                    # prompt tokens
    n_shared_full: int           # leading full blocks aliased via the index
    tail_shared: bool            # partial prompt tail aliased (CoW pending)
    cow_dst: int | None          # pre-allocated CoW target for the tail


def _pallas_mm(x: jax.Array, w: jax.Array) -> jax.Array:
    """(..., K) @ (K, N) through the schedule-driven Pallas matmul."""
    from repro.kernels.matmul.ops import matmul

    out = matmul(x.reshape(-1, x.shape[-1]), w)
    return out.reshape(x.shape[:-1] + (w.shape[-1],))


class Engine:
    """Continuous-batching engine over the model zoo's prefill/decode."""

    def __init__(self, cfg: ModelConfig, params: Any, scfg: ServeConfig):
        if cfg.family == "encdec":
            raise ValueError(
                "continuous batching serves decoder-only LMs; whisper-style "
                "encdec requests need per-request encoder state"
            )
        self.cfg = cfg
        self.model = build(cfg)
        self.params = params
        self.scfg = scfg
        self._impl = _pallas_mm if scfg.matmul == "pallas" else None
        self._attn = "flash" if scfg.attention == "flash" else None
        self._paged = scfg.kv_layout == "paged"

        if self._paged:
            if not kvcache.supports_paged(cfg):
                raise ValueError(
                    f"kv_layout='paged' needs all-global attention; "
                    f"{cfg.name} has ring/recurrent/hybrid caches"
                )
            nb = scfg.resolved_num_blocks()
            self.caches = kvcache.build_paged_caches(
                cfg, scfg.batch, scfg.max_len, nb, scfg.block_size
            )
            self.pool = kvcache.BlockPool(nb, scfg.block_size)
            self._axes = None
        else:
            self.caches = kvcache.build_caches(cfg, scfg.batch, scfg.max_len)
            self.pool = None
            self._axes = kvcache.slot_axes(cfg, scfg.max_len)
        self._free: deque[int] = deque(range(scfg.batch))
        self._waiting: deque[tuple[int, np.ndarray, int]] = deque()
        self._slots: dict[int, _SlotState] = {}
        self._rows: dict[int, _PagedRow] = {}
        self._outputs: dict[int, list[int]] = {}
        self._next_rid = 0
        self._cur_tok = np.zeros((scfg.batch,), np.int32)
        # scheduling evidence for the iso-memory benches: the peak number
        # of simultaneously active slots, and total admissions
        self.stats = {"peak_active": 0, "admitted": 0}

        model, impl, axes = self.model, self._impl, self._axes
        attn = self._attn
        max_len = scfg.max_len
        dblk = scfg.decode_block
        key0 = jax.random.PRNGKey(scfg.seed)
        temp = scfg.temperature

        def sample_one(logits: jax.Array, key: jax.Array) -> jax.Array:
            if temp <= 0:
                return jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return jax.random.categorical(key, logits / temp).astype(jnp.int32)

        def req_key(rid: jax.Array, t: jax.Array) -> jax.Array:
            return jax.random.fold_in(jax.random.fold_in(key0, rid), t)

        def decode_fn(params, toks, caches, rids, ts):
            with (
                L.matmul_override(impl),
                L.attention_override(attn),
                L.decode_block_override(dblk),
            ):
                logits, caches = model.decode_step(params, toks, caches)
            nxt = jax.vmap(lambda lg, r, t: sample_one(lg, req_key(r, t)))(
                logits, rids, ts
            )
            return nxt, caches

        def admit_fn(params, toks, big, slots_, rids, true_lens):
            """Fused admission: prefill `n` prompts (right-padded rows mask
            their tail; exact rows mask nothing), scatter each into its
            slot, and sample each request's first token — one dispatch."""
            n = toks.shape[0]
            small = kvcache.build_caches(cfg, n, max_len)
            with L.matmul_override(impl):
                logits, small = model.prefill(
                    params, toks, small, last_index=true_lens - 1
                )
            small = kvcache.mask_prompt_tail(small, true_lens)
            for i in range(n):
                big = kvcache.slot_store(
                    big, kvcache.take_slot(small, i, axes), slots_[i], axes
                )
            toks0 = jax.vmap(
                lambda lg, r: sample_one(lg, req_key(r, jnp.int32(0)))
            )(logits, rids)
            return toks0, big

        def paged_prefill_fn(params, toks, rids, true_lens):
            """Paged admission, phase 1: prefill into a contiguous scratch
            (the SAME program shape the contiguous oracle admits through,
            so first tokens and packed K/V stay bitwise comparable) and
            sample each request's first token.  Phase 2 packs the scratch
            into pool blocks row by row (`kvcache.paged_store_row_blocks`),
            skipping blocks aliased from the prefix index."""
            n = toks.shape[0]
            small = kvcache.build_caches(cfg, n, max_len)
            with L.matmul_override(impl):
                logits, small = model.prefill(
                    params, toks, small, last_index=true_lens - 1
                )
            toks0 = jax.vmap(
                lambda lg, r: sample_one(lg, req_key(r, jnp.int32(0)))
            )(logits, rids)
            return toks0, {"k": small["k"], "v": small["v"]}

        # the KV cache pytree is DONATED: the ring scatter and admission
        # slot_store update the buffers in place instead of copying every
        # KV tensor per step.  The engine immediately rebinds self.caches
        # to the jit output, so the consumed input is never read again.
        # The paged helpers follow the same contract: pack/set/CoW are
        # donated scatters into the pool, never pool copies.
        self._decode = jax.jit(decode_fn, donate_argnums=(2,))
        self._admit_group = jax.jit(admit_fn, donate_argnums=(2,))
        self._paged_prefill = jax.jit(paged_prefill_fn)
        self._pack_row = jax.jit(kvcache.paged_store_row_blocks, donate_argnums=(0,))
        self._set_row = jax.jit(kvcache.paged_set_row, donate_argnums=(0,))
        self._cow = jax.jit(kvcache.paged_copy_block, donate_argnums=(0,))
        if self._paged:
            self._sink_row = np.zeros((scfg.max_len // scfg.block_size,), np.int32)
        else:
            self._sink_row = None

    # ---------------------------------------------------------- admission --
    def submit(self, req: Request) -> int:
        """Queue a request; returns its id.  Prompts longer than
        ``max_len - 1`` keep their most recent tokens; ``max_new_tokens`` is
        truncated so the request never outgrows its slot."""
        rid = req.request_id if req.request_id is not None else self._next_rid
        if rid in self._outputs:
            raise ValueError(f"duplicate request_id {rid}")
        self._next_rid = max(self._next_rid, rid + 1)
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        max_len = self.scfg.max_len
        if len(prompt) >= max_len:
            prompt = prompt[-(max_len - 1) :]
        budget = min(int(req.max_new_tokens), max_len - len(prompt))
        if self._paged:
            # never let one request outgrow the whole pool: its admission
            # would wait forever for blocks that can't exist (deadlock),
            # and silently shrinking the budget would quietly diverge from
            # the contiguous oracle — reject loudly instead.  With the
            # default pool sizing (batch * max_len tokens) this can never
            # trigger: the max_len truncation above already bounds
            # prompt + budget to max_len <= capacity.
            cap_tokens = (self.pool.num_blocks - 1) * self.scfg.block_size
            if len(prompt) + budget > cap_tokens:
                raise ValueError(
                    f"request {rid} needs {len(prompt) + budget} KV tokens "
                    f"but the whole pool holds {cap_tokens}; grow "
                    f"num_blocks or shorten the request"
                )
        self._outputs[rid] = []
        if budget > 0 and len(prompt) > 0:
            self._waiting.append((rid, prompt, budget))
        return rid

    def _bucket_len(self, plen: int) -> int:
        scfg = self.scfg
        bucket = (
            scfg.prefill_bucket
            if kvcache.supports_padded_prefill(self.cfg)
            else 0
        )
        lpad = -(-plen // bucket) * bucket if bucket > 0 else plen
        if lpad > scfg.max_len:
            lpad = plen  # bucket would overflow the cache: exact length
        return lpad

    def _activate(self, rid, budget, slot, tok, on_token) -> bool:
        """Shared first-token bookkeeping; returns True when the request
        stays active (budget not exhausted at admission)."""
        self._outputs[rid].append(tok)
        self._cur_tok[slot] = tok
        done = budget == 1
        if on_token is not None:
            on_token(rid, tok, 0, done)
        if done:
            if self._paged:
                self._evict_paged(slot)
            self._free.append(slot)
            return False
        self._slots[slot] = _SlotState(rid=rid, emitted=1, budget=budget)
        return True

    @staticmethod
    def _prompt_batch(lpad: int, items: list) -> tuple:
        """Right-pad one admission group's prompts into a (n, lpad) token
        batch plus per-row request ids / true lengths.  Items are the
        group tuples of either admission path, led by (rid, prompt, ...)."""
        n = len(items)
        toks = np.zeros((n, lpad), np.int32)
        rids = np.empty((n,), np.int32)
        tlens = np.empty((n,), np.int32)
        for j, it in enumerate(items):
            rid, prompt = it[0], it[1]
            toks[j, : len(prompt)] = prompt
            rids[j], tlens[j] = rid, len(prompt)
        return toks, rids, tlens

    def _admit_waiting(self, on_token: TokenCallback | None) -> bool:
        """Backfill every free slot from the queue.  Admissions sharing a
        prefill length run as ONE fused jitted call (prefill + tail mask +
        slot scatter + first-token sample); right-padding to
        ``prefill_bucket`` collapses mixed prompt lengths onto one compiled
        shape where that is exact (`kvcache.supports_padded_prefill`).
        Returns True when anything was admitted."""
        if self._paged:
            return self._admit_waiting_paged(on_token)
        groups: dict[int, list[tuple[int, np.ndarray, int, int]]] = {}
        while self._free and self._waiting:
            rid, prompt, budget = self._waiting.popleft()
            slot = self._free.popleft()
            lpad = self._bucket_len(len(prompt))
            groups.setdefault(lpad, []).append((rid, prompt, budget, slot))

        for lpad, items in groups.items():
            toks, rids, tlens = self._prompt_batch(lpad, items)
            slots_ = np.asarray([it[3] for it in items], np.int32)
            toks0, self.caches = self._admit_group(
                self.params,
                jnp.asarray(toks),
                self.caches,
                jnp.asarray(slots_),
                jnp.asarray(rids),
                jnp.asarray(tlens),
            )
            toks0 = np.asarray(toks0)
            self.stats["admitted"] += len(items)
            for j, (rid, prompt, budget, slot) in enumerate(items):
                self._activate(rid, budget, slot, int(toks0[j]), on_token)
        self.stats["peak_active"] = max(self.stats["peak_active"], len(self._slots))
        return bool(groups)

    # ------------------------------------------------------ paged admission --
    def _admit_waiting_paged(self, on_token: TokenCallback | None) -> bool:
        """Paged admission: a request enters when a slot AND enough free
        blocks are available (strict FIFO — the queue head never gets
        jumped).  Ownership is committed host-side first (prefix match ->
        retain aliases, allocate the rest, register this chain), then each
        prefill group runs as one jitted call and each row's private blocks
        are packed into the pool."""
        scfg = self.scfg
        bs = scfg.block_size
        n_blk = scfg.max_len // bs
        groups: dict[int, list[tuple[int, np.ndarray, int, int, _PagedRow]]] = {}
        while self._free and self._waiting:
            rid, prompt, budget = self._waiting[0]
            plen = len(prompt)
            total = -(-(plen + budget) // bs)
            shared_full: list[int] = []
            shared_tail = None
            if scfg.prefix_sharing:
                shared_full, shared_tail = self.pool.match_prefix(prompt.tolist())
            n_shared = len(shared_full) + (1 if shared_tail is not None else 0)
            cow_needed = shared_tail is not None and budget > 1
            need = total - n_shared + (1 if cow_needed else 0)
            if need > self.pool.free_blocks:
                break  # head-of-line waits for completions to free blocks
            self._waiting.popleft()
            slot = self._free.popleft()
            for b in shared_full:
                self.pool.retain(b)
            if shared_tail is not None:
                self.pool.retain(shared_tail)
            blocks = list(shared_full)
            if shared_tail is not None:
                blocks.append(shared_tail)
            while len(blocks) < total:
                blocks.append(self.pool.alloc())
            # the CoW target is reserved NOW so the first divergent write
            # can never be starved by admissions racing it to the free list
            cow_dst = self.pool.alloc() if cow_needed else None
            if scfg.prefix_sharing:
                toks = prompt.tolist()
                n_full = plen // bs
                prev = -1
                for i in range(n_full):
                    self.pool.register(
                        prev, tuple(toks[i * bs : (i + 1) * bs]), blocks[i]
                    )
                    prev = blocks[i]
                tail = tuple(toks[n_full * bs :])
                if tail and n_full < total:
                    self.pool.register(prev, tail, blocks[n_full])
            row = _PagedRow(
                blocks=blocks,
                plen=plen,
                n_shared_full=len(shared_full),
                tail_shared=shared_tail is not None,
                cow_dst=cow_dst,
            )
            self._rows[slot] = row
            lpad = self._bucket_len(plen)
            groups.setdefault(lpad, []).append((rid, prompt, budget, slot, row))

        for lpad, items in groups.items():
            toks, rids, tlens = self._prompt_batch(lpad, items)
            toks0, scratch = self._paged_prefill(
                self.params,
                jnp.asarray(toks),
                jnp.asarray(rids),
                jnp.asarray(tlens),
            )
            toks0 = np.asarray(toks0)
            self.stats["admitted"] += len(items)
            for j, (rid, prompt, budget, slot, row) in enumerate(items):
                table_row = np.full((n_blk,), kvcache.SINK_BLOCK, np.int32)
                table_row[: len(row.blocks)] = row.blocks
                self.caches = self._set_row(
                    self.caches,
                    jnp.int32(slot),
                    jnp.asarray(table_row),
                    jnp.int32(row.plen),
                )
                n_prompt = -(-row.plen // bs)
                start = row.n_shared_full
                n_pack = n_prompt - start - (1 if row.tail_shared else 0)
                if n_pack > 0:
                    self.caches = self._pack_row(
                        self.caches,
                        scratch,
                        jnp.int32(j),
                        jnp.int32(start),
                        jnp.asarray(row.blocks[start : start + n_pack], jnp.int32),
                    )
                self._activate(rid, budget, slot, int(toks0[j]), on_token)
        self.stats["peak_active"] = max(self.stats["peak_active"], len(self._slots))
        return bool(groups)

    def _resolve_cow(self) -> None:
        """Before rows write: give every slot still aliasing a shared
        prompt-tail block its pre-reserved private copy (first divergent
        write is about to land at ``plen``, inside that block)."""
        for slot in sorted(self._slots):
            row = self._rows.get(slot)
            if row is None or row.cow_dst is None:
                continue
            lb = row.plen // self.scfg.block_size
            src = row.blocks[lb]
            self.caches = self._cow(
                self.caches,
                jnp.int32(slot),
                jnp.int32(lb),
                jnp.int32(src),
                jnp.int32(row.cow_dst),
            )
            self.pool.release(src)
            row.blocks[lb] = row.cow_dst
            row.cow_dst = None
            row.tail_shared = False

    def _evict_paged(self, slot: int) -> None:
        """Release a finished row: repoint its device table at the sink
        (the always-full-batch decode keeps writing through dead rows, and
        these blocks are about to be reused) and return every owned block
        to the pool."""
        row = self._rows.pop(slot)
        self.caches = self._set_row(
            self.caches,
            jnp.int32(slot),
            jnp.asarray(self._sink_row),
            jnp.int32(0),
        )
        for b in row.blocks:
            self.pool.release(b)
        if row.cow_dst is not None:
            self.pool.release(row.cow_dst)

    def live_block_refs(self) -> dict[int, int]:
        """Physical block -> reference count implied by live rows (the
        ground truth the pool's refcounts must mirror; used by the fuzz
        suite's invariant checks)."""
        refs: dict[int, int] = {}
        for row in self._rows.values():
            for b in row.blocks:
                refs[b] = refs.get(b, 0) + 1
            if row.cow_dst is not None:
                refs[row.cow_dst] = refs.get(row.cow_dst, 0) + 1
        return refs

    # -------------------------------------------------------------- drive --
    def step(self, on_token: TokenCallback | None = None) -> bool:
        """One engine iteration: backfill free slots from the queue, then
        advance every occupied slot by one decode token.  Returns False
        once the engine is idle."""
        while self._free and self._waiting:
            if not self._admit_waiting(on_token):
                break  # paged: head of queue waits for free blocks
        if self._paged:
            self._resolve_cow()
        if not self._slots:
            return bool(self._waiting)

        B = self.scfg.batch
        rids = np.zeros((B,), np.int32)
        ts = np.zeros((B,), np.int32)
        for s, st in self._slots.items():
            rids[s], ts[s] = st.rid, st.emitted
        nxt, self.caches = self._decode(
            self.params,
            jnp.asarray(self._cur_tok[:, None]),
            self.caches,
            jnp.asarray(rids),
            jnp.asarray(ts),
        )
        nxt = np.asarray(nxt)
        self._cur_tok = nxt.copy()

        finished = []
        for s in sorted(self._slots):
            st = self._slots[s]
            tok = int(nxt[s])
            self._outputs[st.rid].append(tok)
            st.emitted += 1
            done = st.emitted >= st.budget
            if on_token is not None:
                on_token(st.rid, tok, st.emitted - 1, done)
            if done:
                finished.append(s)
        for s in finished:
            del self._slots[s]
            if self._paged:
                self._evict_paged(s)
            self._free.append(s)  # backfilled at the next step
        return True

    def pop_result(self, rid: int) -> np.ndarray:
        """Take (and free) a request's accumulated tokens.  Long-running
        step()-driven servers must call this after a request's ``done``
        callback, or completed outputs accumulate without bound."""
        return np.asarray(self._outputs.pop(rid), np.int32)

    def run(
        self,
        requests: list[Request] = (),
        on_token: TokenCallback | None = None,
    ) -> list[np.ndarray]:
        """Submit ``requests``, drive the engine dry, and return each
        request's generated tokens (in submission order).  Returned results
        are evicted from the engine (their ids become reusable)."""
        rids = [self.submit(r) for r in requests]
        while self.step(on_token):
            pass
        return [self.pop_result(r) for r in rids]

    # legacy API (PR-2-era callers): identical signature, continuous core
    def generate(self, requests: list[Request]) -> list[np.ndarray]:
        return self.run(requests)


class StaticEngine:
    """The pre-continuous static-batch engine, kept as the measured
    baseline: requests are packed into fixed batches, left-padded to the
    longest prompt, and decoded in lockstep to the largest
    ``max_new_tokens`` in the batch.  It shares the continuous engine's
    decode-attention substrate and donated caches, so the serve bench A/B
    measures scheduling, not kernels."""

    def __init__(self, cfg: ModelConfig, params: Any, scfg: ServeConfig):
        if scfg.kv_layout != "contiguous":
            # silently serving contiguous numbers under a paged config
            # would corrupt every A/B built on this baseline
            raise ValueError("StaticEngine serves the contiguous layout only")
        self.cfg = cfg
        self.model = build(cfg)
        self.params = params
        self.scfg = scfg
        model = self.model
        impl = _pallas_mm if scfg.matmul == "pallas" else None
        attn = "flash" if scfg.attention == "flash" else None

        def prefill_fn(params, toks, caches):
            with L.matmul_override(impl):
                return model.prefill(params, toks, caches)

        def decode_fn(params, toks, caches):
            with L.matmul_override(impl), L.attention_override(attn):
                return model.decode_step(params, toks, caches)

        self._prefill = jax.jit(prefill_fn)
        # same matmul/attention substrates + donated caches as the
        # continuous engine, so the bench A/B isolates scheduling
        self._decode = jax.jit(decode_fn, donate_argnums=(2,))

    def _sample(self, logits: jax.Array, key: jax.Array) -> jax.Array:
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.scfg.temperature, axis=-1
        ).astype(jnp.int32)

    def _generate_batch(
        self,
        requests: list[Request],
        rids: list[int],
        on_token: TokenCallback | None,
    ) -> list[np.ndarray]:
        scfg = self.scfg
        plen = max(len(r.prompt) for r in requests)
        prompts = np.zeros((scfg.batch, plen), np.int32)
        for i, r in enumerate(requests):
            prompts[i, plen - len(r.prompt) :] = r.prompt  # left-pad
        max_new = max(r.max_new_tokens for r in requests)

        caches = self.model.init_caches(scfg.batch, scfg.max_len)
        logits, caches = self._prefill(self.params, jnp.asarray(prompts), caches)
        key = jax.random.PRNGKey(scfg.seed)
        outs = []
        tok = self._sample(logits, key)
        outs.append(np.asarray(tok))
        self._emit(requests, rids, outs, on_token)
        for _ in range(max_new - 1):
            key, sub = jax.random.split(key)
            logits, caches = self._decode(self.params, tok[:, None], caches)
            tok = self._sample(logits, sub)
            outs.append(np.asarray(tok))
            self._emit(requests, rids, outs, on_token)
        gen = np.stack(outs, axis=1)  # (B, max_new)
        return [gen[i, : r.max_new_tokens] for i, r in enumerate(requests)]

    @staticmethod
    def _emit(requests, rids, outs, on_token):
        if on_token is None:
            return
        t = len(outs) - 1
        for i, r in enumerate(requests):
            if t < r.max_new_tokens:
                on_token(rids[i], int(outs[-1][i]), t, t == r.max_new_tokens - 1)

    def generate(
        self,
        requests: list[Request],
        on_token: TokenCallback | None = None,
    ) -> list[np.ndarray]:
        """Serve in fixed batches of ``scfg.batch`` requests."""
        results: list[np.ndarray] = []
        B = self.scfg.batch
        for lo in range(0, len(requests), B):
            chunk = requests[lo : lo + B]
            rids = [
                r.request_id if r.request_id is not None else lo + i
                for i, r in enumerate(chunk)
            ]
            results.extend(self._generate_batch(chunk, rids, on_token))
        return results
