"""Crash-consistent serving: engine snapshots + a write-ahead journal.

A process crash (OOM kill, preemptible-VM reclaim, kernel panic) used to
lose everything PR 5/6 made precious: the paged block pools, the radix
prefix index, and every in-flight request.  This module makes that state
durable by composing the repo's two existing hard primitives:

  * the **atomic async checkpoint idiom** (`ckpt/checkpoint.py`): stage to
    host RAM synchronously at a step boundary, write npz + manifest on a
    background thread into a tmp dir, publish with one `os.rename` — a
    crash mid-write never corrupts the newest published snapshot;
  * the **bitwise teacher-forced replay path** (PR 6 preemption recovery):
    decode is deterministic and sampling folds ``(seed, rid, t)``, so
    recorded tokens re-derive bitwise through the same compiled programs
    regardless of scheduling drift after restart.

Durability contract
-------------------

``EngineSnapshot`` (on disk: ``snap_<gen>_<step>/state.npz +
manifest.json``) captures the FULL serving state at a step boundary: every
per-layer KV pool / block table / length tensor, ``_cur_tok``, the waiting
queue, per-request ``_ReqInfo`` (prompt, budget, priority, absolute
deadline, arrival seq, status, recorded tokens), slot states incl. replay
counters, paged row ownership, and the whole :class:`BlockPool` —
refcounts, free list, external holds, and the radix prefix index, so
restored admissions keep aliasing restored physical blocks.  The npz's
sha256 lives in the manifest; a snapshot that fails verification is
quarantined (renamed ``*.corrupt``) and recovery falls back to the next
older one, or to a cold journal-only replay.

The **write-ahead journal** (``wal_<gen>_<step>.jsonl``, one crc32-guarded
JSON record per line, fsync'd once per engine step and at every
submit/cancel/pop boundary) records what happened *between* snapshots:
submits (the reconstructed ``_ReqInfo`` fields — absolute deadline, not
the relative ``deadline_steps``), cancels, result pops, and per-step
emitted-token deltas.  The journal rotates at each snapshot, so

    recovery = newest valid snapshot
             + every journal segment at-or-after it, in (gen, step) order.

Restored requests that were ACTIVE at the snapshot resume decoding from
the restored KV; requests admitted after it re-prefill their prompts
through the restored prefix index; in both cases journaled tokens are
teacher-force replayed with the PR-6 per-step equality asserts — survivor
outputs are **bitwise identical** to the never-crashed run.  A torn final
journal line (crash mid-write) is detected by its crc and dropped, along
with anything after it.

What is NOT durable: tokens generated after the last fsync'd journal
record (at most one step), external ``BlockPool.reserve`` holds (the
holder was a co-tenant of the dead process, so restore releases them back
to the free list), and ``on_token`` callback delivery (replayed tokens
are not re-streamed, matching preemption-recovery semantics).

Generations: every restart increments ``gen`` (max on disk + 1), so a
restored engine's snapshot/segment names never collide with its ancestors'
and sort strictly after them; the anchor snapshot taken at restore folds
the replayed tail into the new generation, which is what makes *chained*
crashes (crash during or after recovery) recover correctly.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import threading
import zlib
from collections import deque
from typing import Any

import jax
import numpy as np

from repro.ckpt.checkpoint import _from_savable, _to_savable
from repro.serve.engine import (
    TERMINAL_STATUSES,
    Engine,
    RequestStatus,
    ServeConfig,
    _PagedRow,
    _ReqInfo,
    _SlotState,
)
from repro.serve.kvcache import BlockPool

_FORMAT = 1


class CorruptSnapshot(Exception):
    """A published snapshot failed integrity verification."""


# ------------------------------------------------------------- disk names --
def _snap_name(gen: int, step: int) -> str:
    return f"snap_{gen:04d}_{step:08d}"


def _wal_name(gen: int, step: int) -> str:
    return f"wal_{gen:04d}_{step:08d}.jsonl"


def _parse_key(name: str, prefix: str) -> tuple[int, int] | None:
    """(gen, step) from a snapshot/segment name; None for foreign files
    (tmp dirs, quarantined snapshots, strays)."""
    stem = name[len(prefix) :].removesuffix(".jsonl")
    parts = stem.split("_")
    if len(parts) != 2:
        return None
    try:
        return int(parts[0]), int(parts[1])
    except ValueError:
        return None


def _snapshot_keys(directory: str) -> list[tuple[int, int]]:
    out = []
    for name in os.listdir(directory):
        if name.startswith("snap_") and not name.endswith((".tmp", ".corrupt")):
            key = _parse_key(name, "snap_")
            if key is not None and os.path.isdir(os.path.join(directory, name)):
                out.append(key)
    return sorted(out)


def _segment_keys(directory: str) -> list[tuple[int, int]]:
    out = []
    for name in os.listdir(directory):
        if name.startswith("wal_") and name.endswith(".jsonl"):
            key = _parse_key(name, "wal_")
            if key is not None:
                out.append(key)
    return sorted(out)


def _disk_generations(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    return [g for g, _ in _snapshot_keys(directory) + _segment_keys(directory)]


# ---------------------------------------------------------------- journal --
class Journal:
    """Append-only crc32-per-line JSON log.  ``append`` buffers; ``commit``
    flushes and (subject to ``fsync_every``) fsyncs — the engine commits
    once per step, and forces a sync at submit/cancel/pop boundaries so
    client-visible events are never lost to a crash."""

    def __init__(self, path: str, fsync_every: int = 1):
        self.path = path
        self._f = open(path, "ab")
        self._fsync_every = max(1, int(fsync_every))
        self._commits_since_sync = 0
        self._dirty = False

    def append(self, rec: dict) -> None:
        body = json.dumps(rec, separators=(",", ":")).encode()
        self._f.write(b"%08x %s\n" % (zlib.crc32(body), body))
        self._dirty = True

    def commit(self, force: bool = False) -> None:
        if not self._dirty and not force:
            return
        self._f.flush()
        self._commits_since_sync += 1
        if force or self._commits_since_sync >= self._fsync_every:
            os.fsync(self._f.fileno())
            self._commits_since_sync = 0
        self._dirty = False

    def close(self) -> None:
        self.commit(force=True)
        self._f.close()


def read_journal(path: str) -> tuple[list[dict], int]:
    """Parse one segment; returns (records, torn_lines).  Reading stops at
    the first line whose crc or JSON fails — a crash mid-append tears only
    the final line, and nothing after a torn line is trustworthy."""
    recs: list[dict] = []
    torn = 0
    with open(path, "rb") as f:
        raw = f.read()
    for line in raw.split(b"\n"):
        if not line:
            continue
        try:
            crc, body = line.split(b" ", 1)
            if int(crc, 16) != zlib.crc32(body):
                raise ValueError("crc mismatch")
            recs.append(json.loads(body))
        except Exception:
            torn += 1
            break
    return recs, torn


def _submit_record(info: _ReqInfo) -> dict:
    # absolute deadline + effective budget + original seq: replay rebuilds
    # _ReqInfo directly instead of re-running submit()'s validation against
    # a drifted _step_no
    return {
        "t": "submit",
        "rid": info.rid,
        "prompt": [int(t) for t in info.prompt],
        "budget": info.budget,
        "priority": info.priority,
        "deadline": info.deadline,
        "seq": info.seq,
        "status": info.status.value,
        "reason": info.reason,
        "seed": info.seed,
        "submitted": info.submitted,
        "ttft": info.ttft,
    }


# ----------------------------------------------------------- snapshotting --
def _scfg_fingerprint(scfg: ServeConfig) -> dict:
    """The config fields a snapshot's device shapes and bitwise token
    stream depend on; restore refuses a mismatch loudly."""
    return {
        "batch": scfg.batch,
        "max_len": scfg.max_len,
        "temperature": scfg.temperature,
        "seed": scfg.seed,
        "prefill_bucket": scfg.prefill_bucket,
        "matmul": scfg.matmul,
        "attention": scfg.attention,
        "kv_layout": scfg.kv_layout,
        "block_size": scfg.block_size,
        "num_blocks": (
            scfg.resolved_num_blocks() if scfg.kv_layout == "paged" else None
        ),
        "prefix_sharing": scfg.prefix_sharing,
        "decode_block": scfg.decode_block,
    }


def _host_state(eng: Engine) -> dict:
    """Deep-copied, JSON-safe host bookkeeping — the background writer must
    see a frozen image while the engine keeps stepping.

    A mid-flight chunked-prefill lane is serialized as its request
    REQUEUED (WAITING, slot freed, committed blocks released in the
    persisted pool image): the lane has published nothing — zero tokens,
    no device block table or slot writes — so restore is a plain
    re-prefill, bitwise identical by determinism."""
    free = list(eng._free)
    waiting = list(eng._waiting)
    pool_state = eng.pool.to_state() if eng.pool is not None else None
    requeued: set[int] = set()
    lane = eng._lane
    if lane is not None:
        free.append(lane.slot)
        waiting = sorted(
            waiting + [lane.rid],
            key=lambda r: (-eng._reqs[r].priority, eng._reqs[r].seq),
        )
        requeued.add(lane.rid)
        if pool_state is not None and lane.row is not None:
            pool = BlockPool.from_state(pool_state)
            for b in lane.row.blocks:
                pool.release(b)
            if lane.row.cow_dst is not None:
                pool.release(lane.row.cow_dst)
            pool_state = pool.to_state()
    reqs = []
    for info in eng._reqs.values():
        rec = _submit_record(info)
        if info.rid in requeued:
            rec["status"] = RequestStatus.WAITING.value
        reqs.append(rec)
    return {
        "step_no": eng._step_no,
        "next_rid": eng._next_rid,
        "next_seq": eng._next_seq,
        "stalled": eng._stalled,
        "stats": dict(eng.stats),
        "free": free,
        "waiting": waiting,
        "reqs": reqs,
        "outputs": {str(rid): list(out) for rid, out in eng._outputs.items()},
        "slots": {
            str(s): {
                "rid": st.rid,
                "emitted": st.emitted,
                "budget": st.budget,
                "replay": st.replay,
            }
            for s, st in eng._slots.items()
        },
        "rows": {
            str(s): {
                "blocks": list(row.blocks),
                "plen": row.plen,
                "n_shared_full": row.n_shared_full,
                "tail_shared": row.tail_shared,
                "cow_dst": row.cow_dst,
            }
            for s, row in eng._rows.items()
        },
        "pool": pool_state,
    }


def _stage(eng: Engine) -> dict:
    """Synchronous device->host snapshot at a step boundary.  ``np.array``
    (not ``asarray``) forces a copy: the cache buffers are donated through
    the next decode step and may be rewritten in place while the
    background thread is still serializing."""
    leaves = jax.tree_util.tree_leaves(eng.caches)
    arrays = {
        f"cache_{i:04d}": np.array(jax.device_get(leaf))
        for i, leaf in enumerate(leaves)
    }
    arrays["cur_tok"] = eng._cur_tok.copy()
    meta = {
        "format": _FORMAT,
        "step": eng._step_no,
        "n_cache_leaves": len(leaves),
        "scfg": _scfg_fingerprint(eng.scfg),
        "host": _host_state(eng),
        "leaves": {
            k: [list(v.shape), str(v.dtype)] for k, v in arrays.items()
        },
    }
    return {"arrays": arrays, "meta": meta}


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _write_snapshot(directory: str, name: str, staged: dict, keep: int) -> str:
    """Background-thread body: npz + sha256'd manifest into a tmp dir,
    fsync everything, one rename to publish, then GC."""
    tmp = os.path.join(directory, name + ".tmp")
    shutil.rmtree(tmp, ignore_errors=True)
    os.makedirs(tmp)
    npz = os.path.join(tmp, "state.npz")
    np.savez(npz, **{k: _to_savable(v) for k, v in staged["arrays"].items()})
    with open(npz, "rb") as f:
        sha = hashlib.sha256(f.read()).hexdigest()
        os.fsync(f.fileno())
    manifest = dict(staged["meta"], sha256=sha)
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    final = os.path.join(directory, name)
    shutil.rmtree(final, ignore_errors=True)
    os.rename(tmp, final)
    _fsync_dir(directory)
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int) -> None:
    """Drop all but the newest ``keep`` snapshots, and every journal
    segment older than the oldest kept snapshot (segments at-or-after it
    are still needed for replay)."""
    snaps = _snapshot_keys(directory)
    if len(snaps) <= keep:
        return
    kept_floor = snaps[-keep]
    for key in snaps[:-keep]:
        shutil.rmtree(
            os.path.join(directory, _snap_name(*key)), ignore_errors=True
        )
    for key in _segment_keys(directory):
        if key < kept_floor:
            try:
                os.remove(os.path.join(directory, _wal_name(*key)))
            except OSError:
                pass


def _load_snapshot(directory: str, key: tuple[int, int]) -> dict:
    """Read + verify one published snapshot; raises CorruptSnapshot on any
    integrity failure (missing file, bad sha, unreadable npz)."""
    path = os.path.join(directory, _snap_name(*key))
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        npz = os.path.join(path, "state.npz")
        with open(npz, "rb") as f:
            sha = hashlib.sha256(f.read()).hexdigest()
        if sha != manifest.get("sha256"):
            raise CorruptSnapshot(
                f"{path}: state.npz sha256 {sha[:12]}… != manifest "
                f"{str(manifest.get('sha256'))[:12]}…"
            )
        with np.load(npz) as data:
            arrays = {
                k: _from_savable(data[k], manifest["leaves"][k][1])
                for k in data.files
            }
    except CorruptSnapshot:
        raise
    except Exception as e:
        raise CorruptSnapshot(f"{path}: unreadable snapshot ({e})") from e
    return {"arrays": arrays, "meta": manifest}


def _quarantine(directory: str, key: tuple[int, int]) -> str:
    """Rename a corrupt snapshot out of the recovery search path (kept on
    disk for forensics, never deleted by GC)."""
    src = os.path.join(directory, _snap_name(*key))
    dst = src + ".corrupt"
    n = 0
    while os.path.exists(dst):
        n += 1
        dst = f"{src}.corrupt{n}"
    os.rename(src, dst)
    return os.path.basename(dst)


# --------------------------------------------------------------- manager --
class RecoveryManager:
    """Engine-side durability driver: journals lifecycle events as they
    happen, commits the journal once per step, and stages + publishes a
    snapshot every ``every`` steps (staging is synchronous at the step
    boundary; serialization and the atomic publish run on a background
    thread).  Create via :meth:`attach`."""

    def __init__(
        self,
        eng: Engine,
        directory: str,
        every: int = 32,
        keep: int = 3,
        fsync_every: int = 1,
    ):
        os.makedirs(directory, exist_ok=True)
        self.eng = eng
        self.directory = directory
        self.every = max(1, int(every))
        self.keep = max(1, int(keep))
        self.fsync_every = max(1, int(fsync_every))
        self.gen = max(_disk_generations(directory), default=-1) + 1
        self._thread: threading.Thread | None = None
        # journaled token counts per rid: after_step appends only deltas
        self._logged = {rid: len(out) for rid, out in eng._outputs.items()}
        self._last_snap_step = eng._step_no
        self.journal = Journal(
            os.path.join(directory, _wal_name(self.gen, eng._step_no)),
            fsync_every=self.fsync_every,
        )

    @classmethod
    def attach(
        cls,
        eng: Engine,
        directory: str,
        every: int = 32,
        keep: int = 3,
        fsync_every: int = 1,
    ) -> "RecoveryManager":
        mgr = cls(eng, directory, every=every, keep=keep, fsync_every=fsync_every)
        eng.recovery = mgr
        if eng._step_no > 0 or eng._reqs:
            # restored (or mid-flight) engine: anchor the new generation
            # with an immediate snapshot so its journal segments replay
            # from a self-contained base even after older-gen GC
            mgr.snapshot()
        return mgr

    # ------------------------------------------------------------ hooks --
    def record_submit(self, info: _ReqInfo) -> None:
        self.journal.append(_submit_record(info))
        self._logged[info.rid] = len(self.eng._outputs[info.rid])
        self.journal.commit(force=True)  # durable before the submit acks

    def record_cancel(self, rid: int, reason: str) -> None:
        self.journal.append({"t": "cancel", "rid": rid, "reason": reason})
        self.journal.commit(force=True)

    def record_pop(self, rid: int) -> None:
        self.journal.append({"t": "pop", "rid": rid})
        self._logged.pop(rid, None)
        self.journal.commit(force=True)

    def after_step(self) -> None:
        """End-of-step hook: journal this step's emitted-token deltas,
        commit, and snapshot on cadence."""
        eng = self.eng
        for rid, out in eng._outputs.items():
            have = self._logged.get(rid, 0)
            if len(out) > have:
                self.journal.append(
                    {"t": "tok", "rid": rid, "toks": [int(t) for t in out[have:]]}
                )
                self._logged[rid] = len(out)
        self.journal.commit()
        if eng._step_no - self._last_snap_step >= self.every:
            self.snapshot()

    # --------------------------------------------------------- snapshot --
    def snapshot(self) -> None:
        """Stage now (synchronously, at a step boundary), publish in the
        background.  The journal rotates first, so the closed segment holds
        exactly the records up to this snapshot and the fresh one exactly
        those after it."""
        self.wait()
        eng = self.eng
        step = eng._step_no
        self.journal.close()
        self.journal = Journal(
            os.path.join(self.directory, _wal_name(self.gen, step)),
            fsync_every=self.fsync_every,
        )
        staged = _stage(eng)
        self._last_snap_step = step
        self._thread = threading.Thread(
            target=_write_snapshot,
            args=(self.directory, _snap_name(self.gen, step), staged, self.keep),
            daemon=True,
        )
        self._thread.start()
        eng.stats["snapshots"] += 1

    def wait(self) -> None:
        """Block until the in-flight snapshot write (if any) has published."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def close(self) -> None:
        self.wait()
        self.journal.close()


# ---------------------------------------------------------------- restore --
@dataclasses.dataclass
class RecoveryReport:
    """What a restore did — the launch CLI prints it, tests assert on it."""

    source: str                      # "snapshot" | "cold" | "fresh"
    snapshot_key: tuple | None       # (gen, step) restored from
    segments: int                    # journal segments replayed
    records: int                     # journal records applied
    torn_lines: int                  # crc-rejected (crash-torn) lines dropped
    resubmitted: int                 # requests rebuilt from submit records
    tokens_replayed: int             # journaled tokens appended past snapshot
    cancels: int
    pops: int
    quarantined: list[str]           # snapshots renamed *.corrupt this restore


def replay_lag(eng: Engine) -> int:
    """Tokens the engine still has to teacher-force re-derive before it has
    caught up with the journal: active-slot replay remainders plus recorded
    tokens of queued (not-yet-readmitted) requests.  Zero == fully caught
    up; `serve_bench` times recovery-to-readmit on this hitting zero."""
    lag = 0
    for st in eng._slots.values():
        lag += max(0, st.replay - st.emitted)
    for rid in eng._waiting:
        lag += len(eng._outputs.get(rid, ()))
    return lag


def _apply_snapshot(eng: Engine, snap: dict) -> None:
    meta = snap["meta"]
    want = _scfg_fingerprint(eng.scfg)
    got = meta["scfg"]
    diff = [k for k in want if want[k] != got.get(k)]
    if diff:
        raise ValueError(
            "snapshot was taken under an incompatible ServeConfig; "
            "differing fields: "
            + ", ".join(f"{k}: snapshot={got.get(k)!r} now={want[k]!r}"
                        for k in diff)
        )
    leaves, treedef = jax.tree_util.tree_flatten(eng.caches)
    n = meta["n_cache_leaves"]
    if n != len(leaves):
        raise ValueError(
            f"snapshot has {n} cache leaves, engine expects {len(leaves)}"
        )
    new_leaves = []
    for i, leaf in enumerate(leaves):
        arr = snap["arrays"][f"cache_{i:04d}"]
        if tuple(arr.shape) != tuple(leaf.shape) or str(arr.dtype) != str(
            leaf.dtype
        ):
            raise ValueError(
                f"snapshot cache leaf {i}: {arr.shape}/{arr.dtype} != "
                f"engine {tuple(leaf.shape)}/{leaf.dtype}"
            )
        new_leaves.append(jax.numpy.asarray(arr))
    eng.caches = jax.tree_util.tree_unflatten(treedef, new_leaves)
    eng._cur_tok = np.asarray(snap["arrays"]["cur_tok"], np.int32).copy()

    h = meta["host"]
    eng._step_no = int(h["step_no"])
    eng._next_rid = int(h["next_rid"])
    eng._next_seq = int(h["next_seq"])
    eng._stalled = int(h["stalled"])
    eng.stats = {**eng.stats, **{k: int(v) for k, v in h["stats"].items()}}
    eng._free = deque(int(s) for s in h["free"])
    eng._waiting = [int(r) for r in h["waiting"]]
    eng._reqs = {}
    for r in h["reqs"]:
        rid = int(r["rid"])
        seed = int(r.get("seed", eng.scfg.seed))
        ttft = r.get("ttft")
        eng._reqs[rid] = _ReqInfo(
            rid=rid,
            prompt=np.asarray(r["prompt"], np.int32),
            budget=int(r["budget"]),
            priority=int(r["priority"]),
            deadline=None if r["deadline"] is None else int(r["deadline"]),
            seq=int(r["seq"]),
            status=RequestStatus(r["status"]),
            reason=r.get("reason", ""),
            seed=seed,
            key=eng._req_base_key(rid, seed),
            submitted=int(r.get("submitted", 0)),
            ttft=None if ttft is None else int(ttft),
        )
    eng._outputs = {
        int(rid): [int(t) for t in out] for rid, out in h["outputs"].items()
    }
    eng._slots = {
        int(s): _SlotState(
            rid=int(st["rid"]),
            emitted=int(st["emitted"]),
            budget=int(st["budget"]),
            replay=int(st["replay"]),
        )
        for s, st in h["slots"].items()
    }
    eng._rows = {
        int(s): _PagedRow(
            blocks=[int(b) for b in row["blocks"]],
            plen=int(row["plen"]),
            n_shared_full=int(row["n_shared_full"]),
            tail_shared=bool(row["tail_shared"]),
            cow_dst=None if row["cow_dst"] is None else int(row["cow_dst"]),
        )
        for s, row in h["rows"].items()
    }
    if eng.pool is not None:
        eng.pool = BlockPool.from_state(h["pool"])


def _apply_records(
    eng: Engine, recs: list[dict], report: RecoveryReport
) -> list[int]:
    """Replay journal records in order.  Token appends and cancels commute
    per rid (appends extend the recorded output whether or not the request
    is already terminal; a cancel freezes status but never the recorded
    tokens), so cross-generation segment concatenation stays consistent.
    Returns the rids whose results were popped pre-crash (applied last —
    the client already consumed them)."""
    pops: list[int] = []
    for rec in recs:
        t = rec["t"]
        rid = int(rec["rid"])
        report.records += 1
        if t == "submit":
            if rid in eng._reqs:
                continue  # defensive: already present via snapshot
            seed = int(rec.get("seed", eng.scfg.seed))
            ttft = rec.get("ttft")
            info = _ReqInfo(
                rid=rid,
                prompt=np.asarray(rec["prompt"], np.int32),
                budget=int(rec["budget"]),
                priority=int(rec["priority"]),
                deadline=(
                    None if rec["deadline"] is None else int(rec["deadline"])
                ),
                seq=int(rec["seq"]),
                status=RequestStatus(rec["status"]),
                reason=rec.get("reason", ""),
                seed=seed,
                key=eng._req_base_key(rid, seed),
                submitted=int(rec.get("submitted", 0)),
                ttft=None if ttft is None else int(ttft),
            )
            eng._reqs[rid] = info
            eng._outputs[rid] = []
            eng._next_rid = max(eng._next_rid, rid + 1)
            eng._next_seq = max(eng._next_seq, info.seq + 1)
            if info.status == RequestStatus.WAITING:
                eng._enqueue(info)
            report.resubmitted += 1
        elif t == "tok":
            if rid in eng._outputs:
                toks = [int(x) for x in rec["toks"]]
                eng._outputs[rid].extend(toks)
                report.tokens_replayed += len(toks)
        elif t == "cancel":
            info = eng._reqs.get(rid)
            if info is not None and info.status not in TERMINAL_STATUSES:
                eng.cancel(rid, rec.get("reason", "cancelled"))
            report.cancels += 1
        elif t == "pop":
            pops.append(rid)
            report.pops += 1
    return pops


def restore_engine(
    cfg: Any,
    params: Any,
    scfg: ServeConfig,
    directory: str | None = None,
) -> tuple[Engine, RecoveryReport]:
    """Rebuild a crashed engine from ``directory`` (default:
    ``scfg.snapshot_dir``): load the newest snapshot that verifies
    (quarantining corrupt ones), replay every journal segment at-or-after
    it, re-apply pre-crash cancels/pops, and arm the PR-6 replay counters
    so the next ``step()`` calls teacher-force journaled tokens with
    bitwise equality asserts.  ``scfg`` must match the crashed engine's
    config (shape/seed fingerprint is verified).  When
    ``scfg.snapshot_dir`` is set, a fresh-generation RecoveryManager is
    attached and an anchor snapshot taken, so chained crashes recover too.
    """
    directory = directory or scfg.snapshot_dir
    if not directory:
        raise ValueError("restore_engine needs a directory or scfg.snapshot_dir")
    eng = Engine(
        cfg,
        params,
        dataclasses.replace(
            scfg,
            durability=dataclasses.replace(scfg.durability, snapshot_dir=None),
        ),
    )
    report = RecoveryReport(
        source="fresh",
        snapshot_key=None,
        segments=0,
        records=0,
        torn_lines=0,
        resubmitted=0,
        tokens_replayed=0,
        cancels=0,
        pops=0,
        quarantined=[],
    )
    os.makedirs(directory, exist_ok=True)

    chosen: tuple[int, int] | None = None
    snap = None
    for key in reversed(_snapshot_keys(directory)):
        try:
            snap = _load_snapshot(directory, key)
        except CorruptSnapshot:
            report.quarantined.append(_quarantine(directory, key))
            continue
        chosen = key
        break
    if chosen is not None:
        _apply_snapshot(eng, snap)
        report.source = "snapshot"
        report.snapshot_key = chosen
        if eng.pool is not None and eng.pool.external:
            # external reserve holders died with the crashed process
            eng.pool.unreserve(sorted(eng.pool.external))

    segments = [
        k for k in _segment_keys(directory) if chosen is None or k >= chosen
    ]
    pops: list[int] = []
    for key in segments:
        recs, torn = read_journal(os.path.join(directory, _wal_name(*key)))
        report.segments += 1
        report.torn_lines += torn
        pops.extend(_apply_records(eng, recs, report))
    if chosen is None and report.records:
        report.source = "cold"

    for rid in pops:
        info = eng._reqs.get(rid)
        if info is None:
            continue
        if info.status not in TERMINAL_STATUSES:
            # the client consumed this result before the crash; finish the
            # zombie through the ordinary release path and evict it
            eng.cancel(rid, "result popped before crash")
        eng.pop_result(rid)

    # arm PR-6 teacher-forced replay: active slots re-derive journaled
    # tokens in place; queued requests with recorded tokens recover through
    # _activate's replay path on re-admission
    for st in eng._slots.values():
        st.replay = len(eng._outputs[st.rid])
    eng._refresh_kv_sums()

    if scfg.snapshot_dir:
        RecoveryManager.attach(
            eng,
            directory,
            every=scfg.snapshot_every,
            keep=scfg.snapshot_keep,
            fsync_every=scfg.journal_fsync_every,
        )
        eng.scfg = scfg
    return eng, report
