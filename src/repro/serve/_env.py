"""Shared environment-knob parsing for the serve stack.

Every seeded harness (chaos, recovery, SDC, fuzz) reads its episode
counts and base seeds from environment variables; this module is the one
place that parsing lives so the error messages can't drift between
copies.
"""

from __future__ import annotations

import os


def env_int(name: str, default: int) -> int:
    """Parse an integer knob from the environment, rejecting garbage with
    an actionable message instead of a bare int() traceback."""
    raw = os.environ.get(name)
    if raw is None or not raw.strip():
        return default
    try:
        return int(raw.strip(), 10)
    except ValueError:
        raise ValueError(
            f"environment variable {name}={raw!r} is not an integer "
            f"(expected e.g. {name}={default})"
        ) from None
