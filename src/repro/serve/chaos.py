"""Seeded fault-injection chaos harness for the serve engine.

MAESTRO's data-centric framing (PAPERS.md) treats *reuse* as the invariant
an accelerator must protect; the paged engine's analogue is block
ownership — refcounts mirroring live rows, the free list and owned set
partitioning the pool, the prefix index never outliving its blocks, device
block tables mirroring host ownership.  This module attacks those
invariants on purpose: an episode drives a seeded workload through a real
:class:`~repro.serve.engine.Engine` while injecting deterministic faults
drawn from the same seed —

  * random **cancels** in every lifecycle state (including double-cancels,
    which must be idempotent no-ops);
  * **deadline storms** (a slice of each workload carries tight
    ``deadline_steps``);
  * forced **preemptions** of random active requests (exercising the
    release → requeue → re-prefill → bitwise replay recovery path);
  * external **block-pressure spikes** (`BlockPool.reserve` withholds free
    blocks for a few steps, starving admission exactly like a co-tenant
    would);
  * **admission stalls** emerging from the above, which the engine's
    watchdog must shed rather than livelock on.

After EVERY step the harness audits the full ownership story
(:func:`audit`), and at drain it checks the pool is leak-free and every
request's tokens agree **bitwise** with an unfaulted oracle run — full
output for FINISHED requests (preempted-and-recovered ones included), the
generated prefix for cancelled/expired/shed ones.  Episodes are pure
functions of ``(engine config, seed)``: a CI failure reproduces locally
from the seed printed in the assertion.

The oracle can be the contiguous engine with ``decode_block`` pinned to the
paged block size (the PR-4/5 differential idiom): sampling folds
``(seed, rid, t)`` — never batch-mates, arrival order, or slot — so the
unfaulted run is bitwise ground truth for any faulted interleaving.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.serve import kvcache
from repro.serve.engine import (
    TERMINAL_STATUSES,
    Engine,
    Request,
    RequestStatus,
)


@dataclasses.dataclass
class ChaosConfig:
    """Fault-schedule knobs; every random draw comes from the episode's
    seeded generator, so the same (config, seed) replays the same chaos."""

    n_requests: int = 10
    max_new: int = 8              # budgets drawn from [1, max_new]
    share_p: float = 0.5          # fraction extending a shared prefix
    p_cancel: float = 0.12        # per-step: cancel one live request
    p_dead_cancel: float = 0.05   # per-step: re-cancel a terminal request
    p_preempt: float = 0.12       # per-step: force-preempt one active
    p_spike: float = 0.08         # per-step: start a block-pressure spike
    spike_blocks: int = 6         # spike size upper bound
    spike_steps: int = 5          # spike duration upper bound
    p_deadline: float = 0.25      # per-request: attach a deadline
    deadline_lo: int = 2
    deadline_hi: int = 40
    p_priority: float = 0.3       # per-request: non-zero priority (1..3)
    burst_hi: int = 4             # submissions per step upper bound
    max_steps: int = 1000         # drain bound (fail = livelock)


@dataclasses.dataclass
class EpisodeReport:
    """What one episode did — aggregated by the test matrix to prove every
    fault type actually fired across the episode set."""

    seed: int
    steps: int
    statuses: dict[str, int]
    stats: dict[str, int]         # engine lifecycle counters


def check_device_tables(eng: Engine) -> None:
    """Device block tables of live rows must mirror host ownership
    (`_PagedRow.blocks`), every entry past the reserved span aimed at the
    sink.  A pending CoW is the one legal divergence: the device row still
    aims at the shared tail until ``_resolve_cow`` repoints it."""
    tables = np.asarray(eng.caches["table"][0])
    for slot, row in eng._rows.items():
        want = np.full((tables.shape[1],), kvcache.SINK_BLOCK, np.int32)
        want[: len(row.blocks)] = row.blocks
        got = tables[slot]
        if row.cow_dst is not None:
            lb = row.plen // eng.scfg.block_size
            want[lb] = got[lb]
        assert np.array_equal(got, want), (
            f"slot {slot}: device table {got.tolist()} != host ownership "
            f"{want.tolist()}"
        )


def audit(eng: Engine) -> None:
    """Full ownership/status consistency check, cheap enough to run after
    every step: pool refcounts mirror live rows (external reservations
    accounted), device tables mirror host tables, and every request id
    sits exactly where its status says."""
    if eng.pool is not None:
        eng.pool.assert_invariants(eng.live_block_refs())
        check_device_tables(eng)
    queued = set(eng._waiting)
    active = {st.rid for st in eng._slots.values()}
    assert not queued & active, f"rids both queued and active: {queued & active}"
    for rid, info in eng._reqs.items():
        if info.status in (RequestStatus.WAITING, RequestStatus.PREEMPTED):
            assert rid in queued, f"rid {rid} {info.status} but not queued"
        elif info.status == RequestStatus.ACTIVE:
            assert rid in active, f"rid {rid} ACTIVE but holds no slot"
        else:
            assert info.status in TERMINAL_STATUSES
            assert rid not in queued and rid not in active, (
                f"rid {rid} terminal ({info.status}) but still scheduled"
            )


def make_chaos_workload(
    rng: np.random.Generator, vocab: int, max_len: int, ccfg: ChaosConfig
) -> list[Request]:
    """Mixed prompts (a slice sharing prefixes, sometimes exactly — tail
    sharing + CoW under fire), random budgets, and the fault surface the
    scheduler has to honor: deadlines on ~p_deadline of them, priorities
    on ~p_priority."""
    prefixes = [
        rng.integers(0, vocab, int(rng.integers(8, max_len // 2))).astype(
            np.int32
        )
        for _ in range(3)
    ]
    reqs = []
    for i in range(ccfg.n_requests):
        if rng.random() < ccfg.share_p:
            pre = prefixes[int(rng.integers(len(prefixes)))]
            extra = int(rng.integers(0, 6))  # 0 => identical prompt
            prompt = np.concatenate(
                [pre, rng.integers(0, vocab, extra).astype(np.int32)]
            )
        else:
            prompt = rng.integers(
                0, vocab, int(rng.integers(1, max_len - 8))
            ).astype(np.int32)
        deadline = None
        if rng.random() < ccfg.p_deadline:
            deadline = int(rng.integers(ccfg.deadline_lo, ccfg.deadline_hi))
        priority = (
            int(rng.integers(1, 4)) if rng.random() < ccfg.p_priority else 0
        )
        reqs.append(
            Request(
                prompt[: max_len - 4],
                max_new_tokens=int(rng.integers(1, ccfg.max_new + 1)),
                request_id=i,
                priority=priority,
                deadline_steps=deadline,
            )
        )
    return reqs


def oracle_outputs(oracle: Engine, reqs: list[Request]) -> dict[int, list[int]]:
    """Ground-truth tokens per request: the same workload, stripped of
    deadlines/priorities (they only change *scheduling*, which sampling is
    independent of), through an unfaulted engine.  The oracle engine must
    share seed/temperature/max_len with the faulted one."""
    bare = [
        Request(r.prompt, r.max_new_tokens, request_id=r.request_id)
        for r in reqs
    ]
    outs = oracle.run(bare)
    for r, o in zip(bare, outs):
        assert o.status == RequestStatus.FINISHED, (
            f"oracle run must finish everything: rid {r.request_id} "
            f"ended {o.status}"
        )
    return {r.request_id: o.tolist() for r, o in zip(bare, outs)}


def run_episode(
    eng: Engine,
    oracle: dict[int, list[int]],
    reqs: list[Request],
    seed: int,
    ccfg: ChaosConfig,
) -> EpisodeReport:
    """Drive one seeded chaos episode through ``eng`` (reused across
    episodes — it must enter drained; compiled programs amortize).  Audits
    ownership after every step, then asserts leak-free drain and bitwise
    oracle agreement for every request."""
    assert not eng._reqs and not eng._slots and not eng._waiting, (
        "chaos episode needs a drained engine"
    )
    rng = np.random.default_rng(seed)
    stats0 = dict(eng.stats)  # engines are reused: report per-episode deltas
    pending = list(rng.permutation(len(reqs)))
    spikes: list[tuple[list[int], int]] = []   # (reserved blocks, expiry)
    steps = 0
    rids = [r.request_id for r in reqs]

    def live(statuses):
        return [r for r in rids if eng.status(r) in statuses]

    while pending or eng._slots or eng._waiting:
        for _ in range(int(rng.integers(0, ccfg.burst_hi + 1))):
            if pending:
                eng.submit(reqs[pending.pop(0)])
        # fault injection — all host-side, between steps, fully seeded
        if rng.random() < ccfg.p_cancel:
            victims = live(
                (
                    RequestStatus.WAITING,
                    RequestStatus.ACTIVE,
                    RequestStatus.PREEMPTED,
                )
            )
            if victims:
                eng.cancel(victims[int(rng.integers(len(victims)))])
        if rng.random() < ccfg.p_dead_cancel:
            dead = live(TERMINAL_STATUSES)
            if dead:
                rid = dead[int(rng.integers(len(dead)))]
                before = eng.status(rid)
                assert eng.cancel(rid) == before, "double-cancel not idempotent"
                assert eng.status(rid) == before
        if rng.random() < ccfg.p_preempt:
            actives = live((RequestStatus.ACTIVE,))
            if actives:
                eng.preempt(actives[int(rng.integers(len(actives)))])
        if eng.pool is not None and rng.random() < ccfg.p_spike:
            held = eng.pool.reserve(int(rng.integers(1, ccfg.spike_blocks + 1)))
            if held:
                expiry = steps + int(rng.integers(1, ccfg.spike_steps + 1))
                spikes.append((held, expiry))
        eng.step()
        steps += 1
        for held, expiry in [s for s in spikes if s[1] <= steps]:
            eng.pool.unreserve(held)
            spikes.remove((held, expiry))
        audit(eng)
        assert steps < ccfg.max_steps, (
            f"chaos episode seed={seed} failed to drain in {steps} steps "
            f"(livelock: watchdog/shedding broken?)"
        )
    for held, _ in spikes:
        eng.pool.unreserve(held)
    audit(eng)
    if eng.pool is not None:
        assert eng.pool.free_blocks == eng.pool.num_blocks - 1, (
            f"chaos episode seed={seed} leaked "
            f"{eng.pool.num_blocks - 1 - eng.pool.free_blocks} blocks"
        )

    statuses: dict[str, int] = {}
    for r in reqs:
        res = eng.pop_result(r.request_id)
        statuses[res.status.value] = statuses.get(res.status.value, 0) + 1
        want = oracle[r.request_id]
        got = res.tolist()
        if res.status == RequestStatus.FINISHED:
            assert got == want, (
                f"chaos episode seed={seed} rid {r.request_id} "
                f"(preemptions={res.preemptions}): FINISHED output {got} != "
                f"oracle {want}"
            )
        else:
            # cancelled / expired / shed mid-flight: whatever was generated
            # must still be the oracle's prefix, bitwise
            assert got == want[: len(got)], (
                f"chaos episode seed={seed} rid {r.request_id} "
                f"({res.status}): partial output {got} is not a prefix of "
                f"oracle {want}"
            )
    return EpisodeReport(
        seed=seed,
        steps=steps,
        statuses=statuses,
        stats={k: v - stats0.get(k, 0) for k, v in eng.stats.items()},
    )
