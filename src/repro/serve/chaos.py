"""Seeded fault-injection chaos harness for the serve engine.

MAESTRO's data-centric framing (PAPERS.md) treats *reuse* as the invariant
an accelerator must protect; the paged engine's analogue is block
ownership — refcounts mirroring live rows, the free list and owned set
partitioning the pool, the prefix index never outliving its blocks, device
block tables mirroring host ownership.  This module attacks those
invariants on purpose: an episode drives a seeded workload through a real
:class:`~repro.serve.engine.Engine` while injecting deterministic faults
drawn from the same seed —

  * random **cancels** in every lifecycle state (including double-cancels,
    which must be idempotent no-ops);
  * **deadline storms** (a slice of each workload carries tight
    ``deadline_steps``);
  * forced **preemptions** of random active requests (exercising the
    release → requeue → re-prefill → bitwise replay recovery path);
  * external **block-pressure spikes** (`BlockPool.reserve` withholds free
    blocks for a few steps, starving admission exactly like a co-tenant
    would);
  * **admission stalls** emerging from the above, which the engine's
    watchdog must shed rather than livelock on.

After EVERY step the harness audits the full ownership story
(:func:`audit`), and at drain it checks the pool is leak-free and every
request's tokens agree **bitwise** with an unfaulted oracle run — full
output for FINISHED requests (preempted-and-recovered ones included), the
generated prefix for cancelled/expired/shed ones.  Episodes are pure
functions of ``(engine config, seed)``: a CI failure reproduces locally
from the seed printed in the assertion.

The oracle can be the contiguous engine with ``decode_block`` pinned to the
paged block size (the PR-4/5 differential idiom): sampling folds
``(seed, rid, t)`` — never batch-mates, arrival order, or slot — so the
unfaulted run is bitwise ground truth for any faulted interleaving.
"""

from __future__ import annotations

import dataclasses
import os

import numpy as np

from repro.serve import kvcache, recovery
from repro.serve.engine import (
    TERMINAL_STATUSES,
    Engine,
    Request,
    RequestStatus,
    ServeConfig,
)

# the test matrices draw episode seeds as <env seed> + SEED_STRIDE + episode,
# so a failed episode's exact repro is <env var>=1 CHAOS_SEED=<seed - STRIDE>
SEED_STRIDE = 1000


def env_int(name: str, default: int) -> int:
    """Parse an integer knob from the environment, rejecting garbage with
    an actionable message instead of a bare int() traceback."""
    raw = os.environ.get(name)
    if raw is None or not raw.strip():
        return default
    try:
        return int(raw.strip(), 10)
    except ValueError:
        raise ValueError(
            f"environment variable {name}={raw!r} is not an integer "
            f"(expected e.g. {name}={default})"
        ) from None


def repro_command(
    seed: int,
    episodes_var: str = "CHAOS_EPISODES",
    target: str = "test-chaos",
) -> str:
    """The exact shell command that replays one episode of the seeded
    matrix (episode seeds are ``CHAOS_SEED + SEED_STRIDE + ep``)."""
    return f"{episodes_var}=1 CHAOS_SEED={seed - SEED_STRIDE} make {target}"


def episode_header(
    kind: str,
    seed: int,
    episodes_var: str = "CHAOS_EPISODES",
    target: str = "test-chaos",
) -> str:
    """Print (and return) the episode banner: seed, the generator's initial
    internal state (proof the episode is a pure function of the seed), and
    the one-line repro command a CI failure should be rerun with."""
    state = np.random.default_rng(seed).bit_generator.state["state"]["state"]
    cmd = repro_command(seed, episodes_var, target)
    print(
        f"[chaos] {kind} episode seed={seed} "
        f"pcg64_state={state:#x} repro: {cmd}",
        flush=True,
    )
    return cmd


@dataclasses.dataclass
class ChaosConfig:
    """Fault-schedule knobs; every random draw comes from the episode's
    seeded generator, so the same (config, seed) replays the same chaos."""

    n_requests: int = 10
    max_new: int = 8              # budgets drawn from [1, max_new]
    share_p: float = 0.5          # fraction extending a shared prefix
    p_cancel: float = 0.12        # per-step: cancel one live request
    p_dead_cancel: float = 0.05   # per-step: re-cancel a terminal request
    p_preempt: float = 0.12       # per-step: force-preempt one active
    p_spike: float = 0.08         # per-step: start a block-pressure spike
    spike_blocks: int = 6         # spike size upper bound
    spike_steps: int = 5          # spike duration upper bound
    p_deadline: float = 0.25      # per-request: attach a deadline
    deadline_lo: int = 2
    deadline_hi: int = 40
    p_priority: float = 0.3       # per-request: non-zero priority (1..3)
    burst_hi: int = 4             # submissions per step upper bound
    max_steps: int = 1000         # drain bound (fail = livelock)
    # crash-episode knobs (run_crash_episode only)
    p_pop: float = 0.15           # per-step: client pops a terminal result
    crash_hi: int = 24            # crash step drawn from [1, crash_hi]


@dataclasses.dataclass
class EpisodeReport:
    """What one episode did — aggregated by the test matrix to prove every
    fault type actually fired across the episode set."""

    seed: int
    steps: int
    statuses: dict[str, int]
    stats: dict[str, int]         # engine lifecycle counters


def check_device_tables(eng: Engine) -> None:
    """Device block tables of live rows must mirror host ownership
    (`_PagedRow.blocks`), every entry past the reserved span aimed at the
    sink.  A pending CoW is the one legal divergence: the device row still
    aims at the shared tail until ``_resolve_cow`` repoints it."""
    tables = np.asarray(eng.caches["table"][0])
    for slot, row in eng._rows.items():
        want = np.full((tables.shape[1],), kvcache.SINK_BLOCK, np.int32)
        want[: len(row.blocks)] = row.blocks
        got = tables[slot]
        if row.cow_dst is not None:
            lb = row.plen // eng.scfg.block_size
            want[lb] = got[lb]
        assert np.array_equal(got, want), (
            f"slot {slot}: device table {got.tolist()} != host ownership "
            f"{want.tolist()}"
        )


def audit(eng: Engine) -> None:
    """Full ownership/status consistency check, cheap enough to run after
    every step: pool refcounts mirror live rows (external reservations
    accounted), device tables mirror host tables, and every request id
    sits exactly where its status says."""
    if eng.pool is not None:
        eng.pool.assert_invariants(eng.live_block_refs())
        check_device_tables(eng)
    queued = set(eng._waiting)
    active = {st.rid for st in eng._slots.values()}
    assert not queued & active, f"rids both queued and active: {queued & active}"
    prefilling = set()
    if eng._lane is not None:
        prefilling = {eng._lane.rid}
        assert eng._lane.slot not in eng._slots, (
            f"lane slot {eng._lane.slot} double-booked by an active row"
        )
        assert eng._lane.slot not in eng._free, (
            f"lane slot {eng._lane.slot} still on the free ring"
        )
        assert not prefilling & (queued | active), (
            f"rid {eng._lane.rid} PREFILLING but also scheduled elsewhere"
        )
    for rid, info in eng._reqs.items():
        if info.status in (RequestStatus.WAITING, RequestStatus.PREEMPTED):
            assert rid in queued, f"rid {rid} {info.status} but not queued"
        elif info.status == RequestStatus.ACTIVE:
            assert rid in active, f"rid {rid} ACTIVE but holds no slot"
        elif info.status == RequestStatus.PREFILLING:
            assert rid in prefilling, f"rid {rid} PREFILLING but holds no lane"
        else:
            assert info.status in TERMINAL_STATUSES
            assert rid not in queued and rid not in active, (
                f"rid {rid} terminal ({info.status}) but still scheduled"
            )


def make_chaos_workload(
    rng: np.random.Generator, vocab: int, max_len: int, ccfg: ChaosConfig
) -> list[Request]:
    """Mixed prompts (a slice sharing prefixes, sometimes exactly — tail
    sharing + CoW under fire), random budgets, and the fault surface the
    scheduler has to honor: deadlines on ~p_deadline of them, priorities
    on ~p_priority."""
    prefixes = [
        rng.integers(0, vocab, int(rng.integers(8, max_len // 2))).astype(
            np.int32
        )
        for _ in range(3)
    ]
    reqs = []
    for i in range(ccfg.n_requests):
        if rng.random() < ccfg.share_p:
            pre = prefixes[int(rng.integers(len(prefixes)))]
            extra = int(rng.integers(0, 6))  # 0 => identical prompt
            prompt = np.concatenate(
                [pre, rng.integers(0, vocab, extra).astype(np.int32)]
            )
        else:
            prompt = rng.integers(
                0, vocab, int(rng.integers(1, max_len - 8))
            ).astype(np.int32)
        deadline = None
        if rng.random() < ccfg.p_deadline:
            deadline = int(rng.integers(ccfg.deadline_lo, ccfg.deadline_hi))
        priority = (
            int(rng.integers(1, 4)) if rng.random() < ccfg.p_priority else 0
        )
        reqs.append(
            Request(
                prompt[: max_len - 4],
                max_new_tokens=int(rng.integers(1, ccfg.max_new + 1)),
                request_id=i,
                priority=priority,
                deadline_steps=deadline,
            )
        )
    return reqs


def oracle_outputs(oracle: Engine, reqs: list[Request]) -> dict[int, list[int]]:
    """Ground-truth tokens per request: the same workload, stripped of
    deadlines/priorities (they only change *scheduling*, which sampling is
    independent of), through an unfaulted engine.  The oracle engine must
    share seed/temperature/max_len with the faulted one."""
    bare = [
        Request(r.prompt, r.max_new_tokens, request_id=r.request_id)
        for r in reqs
    ]
    outs = oracle.run(bare)
    for r, o in zip(bare, outs):
        assert o.status == RequestStatus.FINISHED, (
            f"oracle run must finish everything: rid {r.request_id} "
            f"ended {o.status}"
        )
    return {r.request_id: o.tolist() for r, o in zip(bare, outs)}


def run_episode(
    eng: Engine,
    oracle: dict[int, list[int]],
    reqs: list[Request],
    seed: int,
    ccfg: ChaosConfig,
) -> EpisodeReport:
    """Drive one seeded chaos episode through ``eng`` (reused across
    episodes — it must enter drained; compiled programs amortize).  Audits
    ownership after every step, then asserts leak-free drain and bitwise
    oracle agreement for every request."""
    assert (
        not eng._reqs and not eng._slots and not eng._waiting
        and eng._lane is None
    ), "chaos episode needs a drained engine"
    episode_header("fault", seed)
    rng = np.random.default_rng(seed)
    stats0 = dict(eng.stats)  # engines are reused: report per-episode deltas
    pending = list(rng.permutation(len(reqs)))
    spikes: list[tuple[list[int], int]] = []   # (reserved blocks, expiry)
    steps = 0
    rids = [r.request_id for r in reqs]

    def live(statuses):
        return [r for r in rids if eng.status(r) in statuses]

    while pending or eng._slots or eng._waiting or eng._lane is not None:
        for _ in range(int(rng.integers(0, ccfg.burst_hi + 1))):
            if pending:
                eng.submit(reqs[pending.pop(0)])
        # fault injection — all host-side, between steps, fully seeded
        if rng.random() < ccfg.p_cancel:
            victims = live(
                (
                    RequestStatus.WAITING,
                    RequestStatus.ACTIVE,
                    RequestStatus.PREFILLING,
                    RequestStatus.PREEMPTED,
                )
            )
            if victims:
                eng.cancel(victims[int(rng.integers(len(victims)))])
        if rng.random() < ccfg.p_dead_cancel:
            dead = live(TERMINAL_STATUSES)
            if dead:
                rid = dead[int(rng.integers(len(dead)))]
                before = eng.status(rid)
                assert eng.cancel(rid) == before, "double-cancel not idempotent"
                assert eng.status(rid) == before
        if rng.random() < ccfg.p_preempt:
            actives = live((RequestStatus.ACTIVE, RequestStatus.PREFILLING))
            if actives:
                eng.preempt(actives[int(rng.integers(len(actives)))])
        if eng.pool is not None and rng.random() < ccfg.p_spike:
            held = eng.pool.reserve(int(rng.integers(1, ccfg.spike_blocks + 1)))
            if held:
                expiry = steps + int(rng.integers(1, ccfg.spike_steps + 1))
                spikes.append((held, expiry))
        eng.step()
        steps += 1
        for held, expiry in [s for s in spikes if s[1] <= steps]:
            eng.pool.unreserve(held)
            spikes.remove((held, expiry))
        audit(eng)
        assert steps < ccfg.max_steps, (
            f"chaos episode seed={seed} failed to drain in {steps} steps "
            f"(livelock: watchdog/shedding broken?)"
        )
    for held, _ in spikes:
        eng.pool.unreserve(held)
    audit(eng)
    if eng.pool is not None:
        assert eng.pool.free_blocks == eng.pool.num_blocks - 1, (
            f"chaos episode seed={seed} leaked "
            f"{eng.pool.num_blocks - 1 - eng.pool.free_blocks} blocks"
        )

    statuses: dict[str, int] = {}
    for r in reqs:
        res = eng.pop_result(r.request_id)
        statuses[res.status.value] = statuses.get(res.status.value, 0) + 1
        want = oracle[r.request_id]
        got = res.tolist()
        if res.status == RequestStatus.FINISHED:
            assert got == want, (
                f"chaos episode seed={seed} rid {r.request_id} "
                f"(preemptions={res.preemptions}): FINISHED output {got} != "
                f"oracle {want}"
            )
        else:
            # cancelled / expired / shed mid-flight: whatever was generated
            # must still be the oracle's prefix, bitwise
            assert got == want[: len(got)], (
                f"chaos episode seed={seed} rid {r.request_id} "
                f"({res.status}): partial output {got} is not a prefix of "
                f"oracle {want}"
            )
    return EpisodeReport(
        seed=seed,
        steps=steps,
        statuses=statuses,
        stats={k: v - stats0.get(k, 0) for k, v in eng.stats.items()},
    )


# ---------------------------------------------------------- crash episodes --
@dataclasses.dataclass
class CrashEpisodeReport:
    """One kill-and-restore episode: where it crashed, what recovery found,
    and the post-restore outcome distribution."""

    seed: int
    crash_step: int               # simulated-kill step (0 = drained first)
    steps: int                    # total engine steps across both lives
    source: str                   # restore source: snapshot | cold | fresh
    statuses: dict[str, int]
    stats: dict[str, int]         # restored engine's lifecycle counters
    tokens_replayed: int
    quarantined: int              # snapshots renamed *.corrupt at restore
    popped_pre_crash: int
    corrupted: bool               # episode flipped bytes in newest snapshot


def corrupt_newest_snapshot(directory: str) -> bool:
    """Flip one byte inside the newest published snapshot's npz (simulating
    disk rot / torn sector), so restore must quarantine it and fall back.
    Returns False when no snapshot has been published yet."""
    keys = recovery._snapshot_keys(directory)
    if not keys:
        return False
    npz = os.path.join(directory, recovery._snap_name(*keys[-1]), "state.npz")
    with open(npz, "r+b") as f:
        f.seek(0, os.SEEK_END)
        pos = min(128, f.tell() - 1)
        f.seek(pos)
        byte = f.read(1)
        f.seek(pos)
        f.write(bytes([byte[0] ^ 0xFF]))
    return True


def run_crash_episode(
    cfg,
    params,
    scfg: ServeConfig,
    oracle: dict[int, list[int]],
    reqs: list[Request],
    seed: int,
    ccfg: ChaosConfig,
    p_corrupt: float = 0.25,
) -> CrashEpisodeReport:
    """One seeded kill-and-restore episode.  Phase 1 drives a fresh
    durable engine through the standard fault schedule (cancels,
    preemptions, block-pressure spikes, client result pops) until a
    seed-drawn crash step, then simulates a process kill: the engine is
    abandoned mid-flight — nothing is flushed beyond what the journal
    already fsync'd — and (with probability ``p_corrupt``) the newest
    snapshot's bytes are flipped to exercise quarantine fallback.  Phase 2
    restores from disk, audits ownership after every step while the same
    fault schedule continues, and asserts the run_episode endgame: zero
    leaked blocks and bitwise oracle agreement for every request —
    including results the client popped before the crash, which must NOT
    be resurrected by recovery."""
    assert scfg.snapshot_dir, "crash episodes need scfg.snapshot_dir"
    cmd = episode_header("crash", seed, "RECOVERY_EPISODES", "test-recovery")
    rng = np.random.default_rng(seed)
    eng = Engine(cfg, params, scfg)
    pending = list(rng.permutation(len(reqs)))
    rids = [r.request_id for r in reqs]
    spikes: list[tuple[list[int], int]] = []
    popped: dict[int, object] = {}
    steps = 0
    crash_step = int(rng.integers(1, ccfg.crash_hi + 1))

    def live(engine, statuses):
        return [r for r in rids if engine.status(r) in statuses]

    def drive(engine, stop_at):
        nonlocal steps
        while (
            pending or engine._slots or engine._waiting
            or engine._lane is not None
        ):
            if stop_at is not None and steps >= stop_at:
                return
            for _ in range(int(rng.integers(0, ccfg.burst_hi + 1))):
                if pending:
                    engine.submit(reqs[pending.pop(0)])
            if rng.random() < ccfg.p_cancel:
                victims = live(
                    engine,
                    (
                        RequestStatus.WAITING,
                        RequestStatus.ACTIVE,
                        RequestStatus.PREFILLING,
                        RequestStatus.PREEMPTED,
                    ),
                )
                if victims:
                    engine.cancel(victims[int(rng.integers(len(victims)))])
            if rng.random() < ccfg.p_preempt:
                actives = live(
                    engine, (RequestStatus.ACTIVE, RequestStatus.PREFILLING)
                )
                if actives:
                    engine.preempt(actives[int(rng.integers(len(actives)))])
            if engine.pool is not None and rng.random() < ccfg.p_spike:
                held = engine.pool.reserve(
                    int(rng.integers(1, ccfg.spike_blocks + 1))
                )
                if held:
                    expiry = steps + int(rng.integers(1, ccfg.spike_steps + 1))
                    spikes.append((held, expiry))
            engine.step()
            steps += 1
            for held, expiry in [s for s in spikes if s[1] <= steps]:
                engine.pool.unreserve(held)
                spikes.remove((held, expiry))
            if rng.random() < ccfg.p_pop:
                done = [
                    r
                    for r in live(engine, TERMINAL_STATUSES)
                    if r not in popped
                ]
                if done:
                    rid = done[int(rng.integers(len(done)))]
                    popped[rid] = engine.pop_result(rid)
            audit(engine)
            assert steps < ccfg.max_steps, (
                f"crash episode seed={seed} failed to drain in {steps} "
                f"steps (livelock); repro: {cmd}"
            )

    drive(eng, crash_step)
    crashed_mid_flight = bool(
        pending or eng._slots or eng._waiting or eng._lane is not None
    )
    # --- simulated kill: let the in-flight background snapshot publish
    # (the daemon thread shares our process and would finish anyway), then
    # abandon the engine without closing — the journal's fsync-per-step
    # contract is exactly what a real SIGKILL leaves behind.
    eng.recovery.wait()
    eng.recovery.journal._f.close()  # crash drops the fd, not the bytes
    corrupted = rng.random() < p_corrupt and corrupt_newest_snapshot(
        scfg.snapshot_dir
    )
    del eng
    spikes.clear()  # reserve holders died with the process

    eng2, report = recovery.restore_engine(cfg, params, scfg)
    audit(eng2)
    if corrupted:
        assert report.quarantined, (
            f"crash episode seed={seed}: corrupted newest snapshot was not "
            f"quarantined (restore source={report.source}); repro: {cmd}"
        )
    for rid in popped:
        assert eng2.status(rid) == RequestStatus.UNKNOWN, (
            f"crash episode seed={seed}: rid {rid} was popped before the "
            f"crash but recovery resurrected it; repro: {cmd}"
        )
    drive(eng2, None)
    for held, _ in spikes:
        eng2.pool.unreserve(held)
    spikes.clear()
    audit(eng2)
    if eng2.pool is not None:
        assert eng2.pool.free_blocks == eng2.pool.num_blocks - 1, (
            f"crash episode seed={seed} leaked "
            f"{eng2.pool.num_blocks - 1 - eng2.pool.free_blocks} blocks "
            f"across the crash; repro: {cmd}"
        )

    statuses: dict[str, int] = {}
    results = dict(popped)
    for r in reqs:
        if r.request_id not in results:
            results[r.request_id] = eng2.pop_result(r.request_id)
    for r in reqs:
        res = results[r.request_id]
        statuses[res.status.value] = statuses.get(res.status.value, 0) + 1
        want = oracle[r.request_id]
        got = res.tolist()
        if res.status == RequestStatus.FINISHED:
            assert got == want, (
                f"crash episode seed={seed} rid {r.request_id} "
                f"(preemptions={res.preemptions}, restore={report.source}): "
                f"FINISHED output {got} != oracle {want}; repro: {cmd}"
            )
        else:
            assert got == want[: len(got)], (
                f"crash episode seed={seed} rid {r.request_id} "
                f"({res.status}, restore={report.source}): partial output "
                f"{got} is not a prefix of oracle {want}; repro: {cmd}"
            )
    eng2.close()
    return CrashEpisodeReport(
        seed=seed,
        crash_step=crash_step if crashed_mid_flight else 0,
        steps=steps,
        source=report.source,
        statuses=statuses,
        stats=dict(eng2.stats),
        tokens_replayed=report.tokens_replayed,
        quarantined=len(report.quarantined),
        popped_pre_crash=len(popped),
        corrupted=corrupted,
    )
