"""Seeded fault-injection chaos harness for the serve engine.

MAESTRO's data-centric framing (PAPERS.md) treats *reuse* as the invariant
an accelerator must protect; the paged engine's analogue is block
ownership — refcounts mirroring live rows, the free list and owned set
partitioning the pool, the prefix index never outliving its blocks, device
block tables mirroring host ownership.  This module attacks those
invariants on purpose: an episode drives a seeded workload through a real
:class:`~repro.serve.engine.Engine` while injecting deterministic faults
drawn from the same seed —

  * random **cancels** in every lifecycle state (including double-cancels,
    which must be idempotent no-ops);
  * **deadline storms** (a slice of each workload carries tight
    ``deadline_steps``);
  * forced **preemptions** of random active requests (exercising the
    release → requeue → re-prefill → bitwise replay recovery path);
  * external **block-pressure spikes** (`BlockPool.reserve` withholds free
    blocks for a few steps, starving admission exactly like a co-tenant
    would);
  * **admission stalls** emerging from the above, which the engine's
    watchdog must shed rather than livelock on.

After EVERY step the harness audits the full ownership story
(:func:`audit`), and at drain it checks the pool is leak-free and every
request's tokens agree **bitwise** with an unfaulted oracle run — full
output for FINISHED requests (preempted-and-recovered ones included), the
generated prefix for cancelled/expired/shed ones.  Episodes are pure
functions of ``(engine config, seed)``: a CI failure reproduces locally
from the seed printed in the assertion.

The oracle can be the contiguous engine with ``decode_block`` pinned to the
paged block size (the PR-4/5 differential idiom): sampling folds
``(seed, rid, t)`` — never batch-mates, arrival order, or slot — so the
unfaulted run is bitwise ground truth for any faulted interleaving.
"""

from __future__ import annotations

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import abft
from repro.serve import kvcache, recovery
from repro.serve._env import env_int as env_int  # re-export (legacy import site)
from repro.serve.engine import (
    TERMINAL_STATUSES,
    Engine,
    Request,
    RequestStatus,
    ServeConfig,
)

# the test matrices draw episode seeds as <env seed> + SEED_STRIDE + episode,
# so a failed episode's exact repro is <env var>=1 CHAOS_SEED=<seed - STRIDE>
SEED_STRIDE = 1000


def repro_command(
    seed: int,
    episodes_var: str = "CHAOS_EPISODES",
    target: str = "test-chaos",
    seed_var: str = "CHAOS_SEED",
) -> str:
    """The exact shell command that replays one episode of the seeded
    matrix (episode seeds are ``<seed_var> + SEED_STRIDE + ep``)."""
    return f"{episodes_var}=1 {seed_var}={seed - SEED_STRIDE} make {target}"


def episode_header(
    kind: str,
    seed: int,
    episodes_var: str = "CHAOS_EPISODES",
    target: str = "test-chaos",
    seed_var: str = "CHAOS_SEED",
) -> str:
    """Print (and return) the episode banner: seed, the generator's initial
    internal state (proof the episode is a pure function of the seed), and
    the one-line repro command a CI failure should be rerun with."""
    state = np.random.default_rng(seed).bit_generator.state["state"]["state"]
    cmd = repro_command(seed, episodes_var, target, seed_var)
    print(
        f"[chaos] {kind} episode seed={seed} "
        f"pcg64_state={state:#x} repro: {cmd}",
        flush=True,
    )
    return cmd


@dataclasses.dataclass
class ChaosConfig:
    """Fault-schedule knobs; every random draw comes from the episode's
    seeded generator, so the same (config, seed) replays the same chaos."""

    n_requests: int = 10
    max_new: int = 8              # budgets drawn from [1, max_new]
    share_p: float = 0.5          # fraction extending a shared prefix
    p_cancel: float = 0.12        # per-step: cancel one live request
    p_dead_cancel: float = 0.05   # per-step: re-cancel a terminal request
    p_preempt: float = 0.12       # per-step: force-preempt one active
    p_spike: float = 0.08         # per-step: start a block-pressure spike
    spike_blocks: int = 6         # spike size upper bound
    spike_steps: int = 5          # spike duration upper bound
    p_deadline: float = 0.25      # per-request: attach a deadline
    deadline_lo: int = 2
    deadline_hi: int = 40
    p_priority: float = 0.3       # per-request: non-zero priority (1..3)
    burst_hi: int = 4             # submissions per step upper bound
    max_steps: int = 1000         # drain bound (fail = livelock)
    # crash-episode knobs (run_crash_episode only)
    p_pop: float = 0.15           # per-step: client pops a terminal result
    crash_hi: int = 24            # crash step drawn from [1, crash_hi]


@dataclasses.dataclass
class EpisodeReport:
    """What one episode did — aggregated by the test matrix to prove every
    fault type actually fired across the episode set."""

    seed: int
    steps: int
    statuses: dict[str, int]
    stats: dict[str, int]         # engine lifecycle counters


def check_device_tables(eng: Engine) -> None:
    """Device block tables of live rows must mirror host ownership
    (`_PagedRow.blocks`), every entry past the reserved span aimed at the
    sink.  A pending CoW is the one legal divergence: the device row still
    aims at the shared tail until ``_resolve_cow`` repoints it."""
    tables = np.asarray(eng.caches["table"][0])
    for slot, row in eng._rows.items():
        want = np.full((tables.shape[1],), kvcache.SINK_BLOCK, np.int32)
        want[: len(row.blocks)] = row.blocks
        got = tables[slot]
        if row.cow_dst is not None:
            lb = row.plen // eng.scfg.block_size
            want[lb] = got[lb]
        assert np.array_equal(got, want), (
            f"slot {slot}: device table {got.tolist()} != host ownership "
            f"{want.tolist()}"
        )


def audit(eng: Engine) -> None:
    """Full ownership/status consistency check, cheap enough to run after
    every step: pool refcounts mirror live rows (external reservations
    accounted), device tables mirror host tables, and every request id
    sits exactly where its status says."""
    if eng.pool is not None:
        eng.pool.assert_invariants(eng.live_block_refs())
        check_device_tables(eng)
    queued = set(eng._waiting)
    active = {st.rid for st in eng._slots.values()}
    assert not queued & active, f"rids both queued and active: {queued & active}"
    prefilling = set()
    if eng._lane is not None:
        prefilling = {eng._lane.rid}
        assert eng._lane.slot not in eng._slots, (
            f"lane slot {eng._lane.slot} double-booked by an active row"
        )
        assert eng._lane.slot not in eng._free, (
            f"lane slot {eng._lane.slot} still on the free ring"
        )
        assert not prefilling & (queued | active), (
            f"rid {eng._lane.rid} PREFILLING but also scheduled elsewhere"
        )
    for rid, info in eng._reqs.items():
        if info.status in (RequestStatus.WAITING, RequestStatus.PREEMPTED):
            assert rid in queued, f"rid {rid} {info.status} but not queued"
        elif info.status == RequestStatus.ACTIVE:
            assert rid in active, f"rid {rid} ACTIVE but holds no slot"
        elif info.status == RequestStatus.PREFILLING:
            assert rid in prefilling, f"rid {rid} PREFILLING but holds no lane"
        else:
            assert info.status in TERMINAL_STATUSES
            assert rid not in queued and rid not in active, (
                f"rid {rid} terminal ({info.status}) but still scheduled"
            )


def make_chaos_workload(
    rng: np.random.Generator, vocab: int, max_len: int, ccfg: ChaosConfig
) -> list[Request]:
    """Mixed prompts (a slice sharing prefixes, sometimes exactly — tail
    sharing + CoW under fire), random budgets, and the fault surface the
    scheduler has to honor: deadlines on ~p_deadline of them, priorities
    on ~p_priority."""
    prefixes = [
        rng.integers(0, vocab, int(rng.integers(8, max_len // 2))).astype(
            np.int32
        )
        for _ in range(3)
    ]
    reqs = []
    for i in range(ccfg.n_requests):
        if rng.random() < ccfg.share_p:
            pre = prefixes[int(rng.integers(len(prefixes)))]
            extra = int(rng.integers(0, 6))  # 0 => identical prompt
            prompt = np.concatenate(
                [pre, rng.integers(0, vocab, extra).astype(np.int32)]
            )
        else:
            prompt = rng.integers(
                0, vocab, int(rng.integers(1, max_len - 8))
            ).astype(np.int32)
        deadline = None
        if rng.random() < ccfg.p_deadline:
            deadline = int(rng.integers(ccfg.deadline_lo, ccfg.deadline_hi))
        priority = (
            int(rng.integers(1, 4)) if rng.random() < ccfg.p_priority else 0
        )
        reqs.append(
            Request(
                prompt[: max_len - 4],
                max_new_tokens=int(rng.integers(1, ccfg.max_new + 1)),
                request_id=i,
                priority=priority,
                deadline_steps=deadline,
            )
        )
    return reqs


def oracle_outputs(oracle: Engine, reqs: list[Request]) -> dict[int, list[int]]:
    """Ground-truth tokens per request: the same workload, stripped of
    deadlines/priorities (they only change *scheduling*, which sampling is
    independent of), through an unfaulted engine.  The oracle engine must
    share seed/temperature/max_len with the faulted one."""
    bare = [
        Request(r.prompt, r.max_new_tokens, request_id=r.request_id)
        for r in reqs
    ]
    outs = oracle.run(bare)
    for r, o in zip(bare, outs):
        assert o.status == RequestStatus.FINISHED, (
            f"oracle run must finish everything: rid {r.request_id} "
            f"ended {o.status}"
        )
    return {r.request_id: o.tolist() for r, o in zip(bare, outs)}


def run_episode(
    eng: Engine,
    oracle: dict[int, list[int]],
    reqs: list[Request],
    seed: int,
    ccfg: ChaosConfig,
) -> EpisodeReport:
    """Drive one seeded chaos episode through ``eng`` (reused across
    episodes — it must enter drained; compiled programs amortize).  Audits
    ownership after every step, then asserts leak-free drain and bitwise
    oracle agreement for every request."""
    assert (
        not eng._reqs and not eng._slots and not eng._waiting
        and eng._lane is None
    ), "chaos episode needs a drained engine"
    episode_header("fault", seed)
    rng = np.random.default_rng(seed)
    stats0 = dict(eng.stats)  # engines are reused: report per-episode deltas
    pending = list(rng.permutation(len(reqs)))
    spikes: list[tuple[list[int], int]] = []   # (reserved blocks, expiry)
    steps = 0
    rids = [r.request_id for r in reqs]

    def live(statuses):
        return [r for r in rids if eng.status(r) in statuses]

    while pending or eng._slots or eng._waiting or eng._lane is not None:
        for _ in range(int(rng.integers(0, ccfg.burst_hi + 1))):
            if pending:
                eng.submit(reqs[pending.pop(0)])
        # fault injection — all host-side, between steps, fully seeded
        if rng.random() < ccfg.p_cancel:
            victims = live(
                (
                    RequestStatus.WAITING,
                    RequestStatus.ACTIVE,
                    RequestStatus.PREFILLING,
                    RequestStatus.PREEMPTED,
                )
            )
            if victims:
                eng.cancel(victims[int(rng.integers(len(victims)))])
        if rng.random() < ccfg.p_dead_cancel:
            dead = live(TERMINAL_STATUSES)
            if dead:
                rid = dead[int(rng.integers(len(dead)))]
                before = eng.status(rid)
                assert eng.cancel(rid) == before, "double-cancel not idempotent"
                assert eng.status(rid) == before
        if rng.random() < ccfg.p_preempt:
            actives = live((RequestStatus.ACTIVE, RequestStatus.PREFILLING))
            if actives:
                eng.preempt(actives[int(rng.integers(len(actives)))])
        if eng.pool is not None and rng.random() < ccfg.p_spike:
            held = eng.pool.reserve(int(rng.integers(1, ccfg.spike_blocks + 1)))
            if held:
                expiry = steps + int(rng.integers(1, ccfg.spike_steps + 1))
                spikes.append((held, expiry))
        eng.step()
        steps += 1
        for held, expiry in [s for s in spikes if s[1] <= steps]:
            eng.pool.unreserve(held)
            spikes.remove((held, expiry))
        audit(eng)
        assert steps < ccfg.max_steps, (
            f"chaos episode seed={seed} failed to drain in {steps} steps "
            f"(livelock: watchdog/shedding broken?)"
        )
    for held, _ in spikes:
        eng.pool.unreserve(held)
    audit(eng)
    if eng.pool is not None:
        assert eng.pool.free_blocks == eng.pool.num_blocks - 1, (
            f"chaos episode seed={seed} leaked "
            f"{eng.pool.num_blocks - 1 - eng.pool.free_blocks} blocks"
        )

    statuses: dict[str, int] = {}
    for r in reqs:
        res = eng.pop_result(r.request_id)
        statuses[res.status.value] = statuses.get(res.status.value, 0) + 1
        want = oracle[r.request_id]
        got = res.tolist()
        if res.status == RequestStatus.FINISHED:
            assert got == want, (
                f"chaos episode seed={seed} rid {r.request_id} "
                f"(preemptions={res.preemptions}): FINISHED output {got} != "
                f"oracle {want}"
            )
        else:
            # cancelled / expired / shed mid-flight: whatever was generated
            # must still be the oracle's prefix, bitwise
            assert got == want[: len(got)], (
                f"chaos episode seed={seed} rid {r.request_id} "
                f"({res.status}): partial output {got} is not a prefix of "
                f"oracle {want}"
            )
    return EpisodeReport(
        seed=seed,
        steps=steps,
        statuses=statuses,
        stats={k: v - stats0.get(k, 0) for k, v in eng.stats.items()},
    )


# ---------------------------------------------------------- crash episodes --
@dataclasses.dataclass
class CrashEpisodeReport:
    """One kill-and-restore episode: where it crashed, what recovery found,
    and the post-restore outcome distribution."""

    seed: int
    crash_step: int               # simulated-kill step (0 = drained first)
    steps: int                    # total engine steps across both lives
    source: str                   # restore source: snapshot | cold | fresh
    statuses: dict[str, int]
    stats: dict[str, int]         # restored engine's lifecycle counters
    tokens_replayed: int
    quarantined: int              # snapshots renamed *.corrupt at restore
    popped_pre_crash: int
    corrupted: bool               # episode flipped bytes in newest snapshot


def corrupt_newest_snapshot(directory: str) -> bool:
    """Flip one byte inside the newest published snapshot's npz (simulating
    disk rot / torn sector), so restore must quarantine it and fall back.
    Returns False when no snapshot has been published yet."""
    keys = recovery._snapshot_keys(directory)
    if not keys:
        return False
    npz = os.path.join(directory, recovery._snap_name(*keys[-1]), "state.npz")
    with open(npz, "r+b") as f:
        f.seek(0, os.SEEK_END)
        pos = min(128, f.tell() - 1)
        f.seek(pos)
        byte = f.read(1)
        f.seek(pos)
        f.write(bytes([byte[0] ^ 0xFF]))
    return True


def run_crash_episode(
    cfg,
    params,
    scfg: ServeConfig,
    oracle: dict[int, list[int]],
    reqs: list[Request],
    seed: int,
    ccfg: ChaosConfig,
    p_corrupt: float = 0.25,
) -> CrashEpisodeReport:
    """One seeded kill-and-restore episode.  Phase 1 drives a fresh
    durable engine through the standard fault schedule (cancels,
    preemptions, block-pressure spikes, client result pops) until a
    seed-drawn crash step, then simulates a process kill: the engine is
    abandoned mid-flight — nothing is flushed beyond what the journal
    already fsync'd — and (with probability ``p_corrupt``) the newest
    snapshot's bytes are flipped to exercise quarantine fallback.  Phase 2
    restores from disk, audits ownership after every step while the same
    fault schedule continues, and asserts the run_episode endgame: zero
    leaked blocks and bitwise oracle agreement for every request —
    including results the client popped before the crash, which must NOT
    be resurrected by recovery."""
    assert scfg.snapshot_dir, "crash episodes need scfg.snapshot_dir"
    cmd = episode_header("crash", seed, "RECOVERY_EPISODES", "test-recovery")
    rng = np.random.default_rng(seed)
    eng = Engine(cfg, params, scfg)
    pending = list(rng.permutation(len(reqs)))
    rids = [r.request_id for r in reqs]
    spikes: list[tuple[list[int], int]] = []
    popped: dict[int, object] = {}
    steps = 0
    crash_step = int(rng.integers(1, ccfg.crash_hi + 1))

    def live(engine, statuses):
        return [r for r in rids if engine.status(r) in statuses]

    def drive(engine, stop_at):
        nonlocal steps
        while (
            pending or engine._slots or engine._waiting
            or engine._lane is not None
        ):
            if stop_at is not None and steps >= stop_at:
                return
            for _ in range(int(rng.integers(0, ccfg.burst_hi + 1))):
                if pending:
                    engine.submit(reqs[pending.pop(0)])
            if rng.random() < ccfg.p_cancel:
                victims = live(
                    engine,
                    (
                        RequestStatus.WAITING,
                        RequestStatus.ACTIVE,
                        RequestStatus.PREFILLING,
                        RequestStatus.PREEMPTED,
                    ),
                )
                if victims:
                    engine.cancel(victims[int(rng.integers(len(victims)))])
            if rng.random() < ccfg.p_preempt:
                actives = live(
                    engine, (RequestStatus.ACTIVE, RequestStatus.PREFILLING)
                )
                if actives:
                    engine.preempt(actives[int(rng.integers(len(actives)))])
            if engine.pool is not None and rng.random() < ccfg.p_spike:
                held = engine.pool.reserve(
                    int(rng.integers(1, ccfg.spike_blocks + 1))
                )
                if held:
                    expiry = steps + int(rng.integers(1, ccfg.spike_steps + 1))
                    spikes.append((held, expiry))
            engine.step()
            steps += 1
            for held, expiry in [s for s in spikes if s[1] <= steps]:
                engine.pool.unreserve(held)
                spikes.remove((held, expiry))
            if rng.random() < ccfg.p_pop:
                done = [
                    r
                    for r in live(engine, TERMINAL_STATUSES)
                    if r not in popped
                ]
                if done:
                    rid = done[int(rng.integers(len(done)))]
                    popped[rid] = engine.pop_result(rid)
            audit(engine)
            assert steps < ccfg.max_steps, (
                f"crash episode seed={seed} failed to drain in {steps} "
                f"steps (livelock); repro: {cmd}"
            )

    drive(eng, crash_step)
    crashed_mid_flight = bool(
        pending or eng._slots or eng._waiting or eng._lane is not None
    )
    # --- simulated kill: let the in-flight background snapshot publish
    # (the daemon thread shares our process and would finish anyway), then
    # abandon the engine without closing — the journal's fsync-per-step
    # contract is exactly what a real SIGKILL leaves behind.
    eng.recovery.wait()
    eng.recovery.journal._f.close()  # crash drops the fd, not the bytes
    corrupted = rng.random() < p_corrupt and corrupt_newest_snapshot(
        scfg.snapshot_dir
    )
    del eng
    spikes.clear()  # reserve holders died with the process

    eng2, report = recovery.restore_engine(cfg, params, scfg)
    audit(eng2)
    if corrupted:
        assert report.quarantined, (
            f"crash episode seed={seed}: corrupted newest snapshot was not "
            f"quarantined (restore source={report.source}); repro: {cmd}"
        )
    for rid in popped:
        assert eng2.status(rid) == RequestStatus.UNKNOWN, (
            f"crash episode seed={seed}: rid {rid} was popped before the "
            f"crash but recovery resurrected it; repro: {cmd}"
        )
    drive(eng2, None)
    for held, _ in spikes:
        eng2.pool.unreserve(held)
    spikes.clear()
    audit(eng2)
    if eng2.pool is not None:
        assert eng2.pool.free_blocks == eng2.pool.num_blocks - 1, (
            f"crash episode seed={seed} leaked "
            f"{eng2.pool.num_blocks - 1 - eng2.pool.free_blocks} blocks "
            f"across the crash; repro: {cmd}"
        )

    statuses: dict[str, int] = {}
    results = dict(popped)
    for r in reqs:
        if r.request_id not in results:
            results[r.request_id] = eng2.pop_result(r.request_id)
    for r in reqs:
        res = results[r.request_id]
        statuses[res.status.value] = statuses.get(res.status.value, 0) + 1
        want = oracle[r.request_id]
        got = res.tolist()
        if res.status == RequestStatus.FINISHED:
            assert got == want, (
                f"crash episode seed={seed} rid {r.request_id} "
                f"(preemptions={res.preemptions}, restore={report.source}): "
                f"FINISHED output {got} != oracle {want}; repro: {cmd}"
            )
        else:
            assert got == want[: len(got)], (
                f"crash episode seed={seed} rid {r.request_id} "
                f"({res.status}, restore={report.source}): partial output "
                f"{got} is not a prefix of oracle {want}; repro: {cmd}"
            )
    eng2.close()
    return CrashEpisodeReport(
        seed=seed,
        crash_step=crash_step if crashed_mid_flight else 0,
        steps=steps,
        source=report.source,
        statuses=statuses,
        stats=dict(eng2.stats),
        tokens_replayed=report.tokens_replayed,
        quarantined=len(report.quarantined),
        popped_pre_crash=len(popped),
        corrupted=corrupted,
    )


# ------------------------------------------------------------ SDC episodes --
# Seeded bit-flip (silent-data-corruption) injection against the ABFT
# pipeline (kernels/abft.py + Engine._sdc_recover).  Three fault surfaces:
#
#   * transient compute flips (matmul / attention outputs) ride the fault
#     operand *inside* the jitted decode program — armed via
#     ``Engine.arm_fault`` — and must be detected by the step's checksums
#     and healed by the oracle-substrate retry (survivors AND the victim
#     stay bitwise equal to the unfaulted oracle);
#   * persistent KV flips land host-side in the paged pool between steps
#     (``flip_kv_bit``) and must be caught by the per-block fingerprint
#     audit at the top of the next step, quarantining exactly the owning
#     request and leaking zero blocks;
#   * persistent weight flips (``flip_weight_bit``) are unlocalizable by
#     construction — both sides of e^T·(A·B) = (e^T·A)·B use the corrupt
#     operand — so the weight-fingerprint detector must raise
#     ``SDCUnlocalizedError`` BEFORE any poisoned token is emitted, and
#     the caller restores from the newest snapshot with pristine params.


@dataclasses.dataclass
class FaultPlan:
    """One scheduled injection: what to corrupt and (for compute faults)
    where the fault operand should aim.  ``kind`` is "matmul",
    "attention", or "kv"; compute-fault targeting (call_idx / layer / row
    / bit) is drawn by :func:`run_sdc_episode` once the engine's trace
    probe knows the step's check-site counts."""

    kind: str
    call_idx: int = 0
    layer: int = abft.FAULT_OUTER
    row: int = 0
    bit: int = 27
    fired: bool = False


def flip_kv_bit(
    eng: Engine, rng: np.random.Generator
) -> tuple[int, int] | None:
    """Flip the exponent MSB of one seeded element inside an owned,
    uniquely-referenced KV-pool block that was NOT legally written this
    step — exactly the corruption the per-block fingerprint audit owes a
    detection for at the top of the next step.  The exponent MSB
    guarantees an abs-sum delta of at least ~2.0 (0 -> 2.0; |v| < 2
    explodes by 2^128; |v| >= 2 collapses toward 0), so the fp32 block
    sum always changes representably.  Unique referencing (refcount 1,
    no CoW pending) pins the blast radius to one request: the audit
    quarantines the owner and every survivor must stay bitwise clean.

    Returns ``(victim_rid, block)`` or None when no block is eligible
    (e.g. every owned block was written this step)."""
    refs = eng.live_block_refs()
    cands = []
    for slot, row in sorted(eng._rows.items()):
        if slot not in eng._slots:
            continue  # lane/ghost rows: quarantine targets decode slots
        for b in row.blocks:
            if (
                refs.get(b, 0) == 1
                and b not in eng._touched
                and b != row.cow_dst
            ):
                cands.append((slot, b))
    if not cands:
        return None
    slot, block = cands[int(rng.integers(len(cands)))]
    kp = np.array(eng.caches["kpool"])
    flat = kp.reshape(kp.shape[0], kp.shape[1], -1)
    li = int(rng.integers(flat.shape[0]))
    ei = int(rng.integers(flat.shape[2]))
    cell = flat[li, block, ei : ei + 1]
    if cell.itemsize == 2:  # bf16: sign 15, exponent 14..7
        cell.view(np.uint16)[:] ^= np.uint16(1 << 14)
    else:  # f32: sign 31, exponent 30..23
        cell.view(np.uint32)[:] ^= np.uint32(1 << 30)
    eng.caches["kpool"] = jnp.asarray(kp)
    return eng._slots[slot].rid, block


def flip_weight_bit(params, rng: np.random.Generator) -> tuple[object, int]:
    """Return ``(corrupted_params, leaf_ordinal)``: a copy of the param
    pytree with the exponent MSB of one seeded element flipped in one
    seeded leaf.  Models persistent weight rot (a stuck DRAM cell under
    the model weights): the ABFT checksums cannot see it, so the engine's
    per-leaf weight fingerprint must — by raising
    :class:`~repro.serve.engine.SDCUnlocalizedError` on the next step."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    li = int(rng.integers(len(leaves)))
    leaf = np.array(leaves[li])
    flat = leaf.reshape(-1)
    cell = flat[int(rng.integers(flat.shape[0])) : ][:1]
    if cell.itemsize == 2:
        cell.view(np.uint16)[:] ^= np.uint16(1 << 14)
    else:
        cell.view(np.uint32)[:] ^= np.uint32(1 << 30)
    leaves = list(leaves)
    leaves[li] = jnp.asarray(leaf)
    return jax.tree_util.tree_unflatten(treedef, leaves), li


@dataclasses.dataclass
class SDCEpisodeReport:
    """One SDC episode's ledger, aggregated by the test matrix to prove
    every fault surface actually fired AND was caught."""

    seed: int
    steps: int
    injected: dict[str, int]      # faults that actually fired, by kind
    detected: int                 # checksum/fingerprint detections (compute)
    retried: int                  # oracle-substrate re-executions
    quarantined: int              # KV-flip quarantines
    statuses: dict[str, int]


def make_sdc_workload(
    rng: np.random.Generator, vocab: int, max_len: int, n_requests: int = 8
) -> list[Request]:
    """Plain seeded prompts (no deadlines/priorities — scheduling chaos is
    run_episode's job; here every divergence from the oracle must be the
    injector's doing)."""
    return [
        Request(
            rng.integers(0, vocab, int(rng.integers(4, max_len // 2))).astype(
                np.int32
            ),
            max_new_tokens=int(rng.integers(4, 12)),
            request_id=i,
        )
        for i in range(n_requests)
    ]


def run_sdc_episode(
    eng: Engine,
    oracle: dict[int, list[int]],
    reqs: list[Request],
    seed: int,
    n_compute: int | None = None,
    n_kv: int | None = None,
    max_steps: int = 400,
) -> SDCEpisodeReport:
    """One seeded SDC episode through a reused (drained) abft engine:
    drive the workload, firing ``n_compute`` transient compute flips (via
    the in-program fault operand) and ``n_kv`` persistent KV-pool flips
    (host-side) at seeded steps; ``None`` draws counts from the episode
    seed.  Asserts the full detect -> localize -> retry -> quarantine
    contract:

      * every fired compute fault is detected and retried exactly once
        (``n_compute <= SDC_RETRY_BUDGET`` here, so no budget quarantine
        muddies the ledger — the budget path has its own test);
      * every fired KV flip quarantines exactly its owning request, with
        an ``"sdc"``-prefixed FAILED reason;
      * a clean episode (0 faults) detects and quarantines NOTHING —
        zero false positives;
      * the pool drains leak-free and every FINISHED request agrees
        bitwise with the unfaulted oracle (quarantined ones are bitwise
        prefixes).
    """
    from repro.serve.engine import SDC_RETRY_BUDGET

    assert eng._abft, "run_sdc_episode needs KernelConfig.abft != 'off'"
    assert (
        not eng._reqs and not eng._slots and not eng._waiting
        and eng._lane is None
    ), "sdc episode needs a drained engine"
    cmd = episode_header("sdc", seed, "SDC_EPISODES", "test-sdc", "SDC_SEED")
    rng = np.random.default_rng(seed)
    stats0 = dict(eng.stats)
    if n_compute is None:
        n_compute = int(rng.integers(0, SDC_RETRY_BUDGET + 1))
    if n_kv is None:
        n_kv = int(rng.integers(0, 3))
    assert n_compute <= SDC_RETRY_BUDGET, (
        "per-episode compute faults beyond the retry budget would "
        "quarantine every live slot; test that path explicitly instead"
    )
    plans = [FaultPlan("matmul" if rng.random() < 0.5 else "attention")
             for _ in range(n_compute)]
    plans += [FaultPlan("kv") for _ in range(n_kv)]
    plans = [plans[i] for i in rng.permutation(len(plans))]
    pending = list(rng.permutation(len(reqs)))
    kv_victims: list[int] = []
    steps = 0
    next_fire = 1 + int(rng.integers(0, 3))

    def arm_compute(plan: FaultPlan) -> bool:
        # trace-time site counts (populated by the first abft step); the
        # lone out-of-scan matmul is the unembed GEMM at index mms-1
        mms = eng._abft_probe.get("mms", 0)
        attns = eng._abft_probe.get("attns", 0)
        live = sorted(eng._slots)
        if plan.kind == "attention":
            sampled = set(abft.sample_rows(eng.scfg.batch, eng._abft))
            live = [s for s in live if s in sampled]
            if not live or not attns:
                return False
            plan.call_idx = int(rng.integers(attns))
            plan.layer = int(rng.integers(eng.cfg.n_layers))
            site = abft.FAULT_ATTENTION
        else:
            if not live or not mms:
                return False
            if mms == 1 or rng.random() < 0.25:
                plan.call_idx, plan.layer = mms - 1, abft.FAULT_OUTER
            else:
                plan.call_idx = int(rng.integers(mms - 1))
                plan.layer = int(rng.integers(eng.cfg.n_layers))
            site = abft.FAULT_MATMUL
        plan.row = live[int(rng.integers(len(live)))]
        # exponent flips on the row's largest element (col = -1): the one
        # corruption class a bf16 checksum provably owes a detection for
        plan.bit = int(rng.integers(24, 30))
        eng.arm_fault(site, plan.call_idx, plan.row, -1, plan.bit, plan.layer)
        return True

    while pending or eng._slots or eng._waiting or eng._lane is not None:
        for _ in range(int(rng.integers(1, 4))):
            if pending:
                eng.submit(reqs[pending.pop(0)])
        if plans and steps >= next_fire and eng._slots:
            plan = plans[0]
            if plan.kind == "kv":
                hit = flip_kv_bit(eng, rng)
                if hit is not None:
                    kv_victims.append(hit[0])
                    plan.fired = True
            else:
                plan.fired = arm_compute(plan)
            if plan.fired:
                plans.pop(0)
                # gap >= 2: the previous fault's quarantine (if any) must
                # settle before the next fault picks a victim row
                next_fire = steps + 2 + int(rng.integers(0, 3))
            # ineligible this step (no live slots in the sampled-row set,
            # no flippable block): retry at the next step boundary
        eng.step()
        steps += 1
        audit(eng)
        assert steps < max_steps, (
            f"sdc episode seed={seed} failed to drain in {steps} steps; "
            f"repro: {cmd}"
        )
    audit(eng)
    assert eng.pool.free_blocks == eng.pool.num_blocks - 1, (
        f"sdc episode seed={seed} leaked "
        f"{eng.pool.num_blocks - 1 - eng.pool.free_blocks} blocks after "
        f"quarantine; repro: {cmd}"
    )

    for p in plans:  # anything left never found an eligible target
        assert not p.fired
    fired_compute = n_compute - sum(
        1 for p in plans if p.kind in ("matmul", "attention")
    )
    fired_kv = len(kv_victims)
    delta = {k: v - stats0.get(k, 0) for k, v in eng.stats.items()}
    assert delta["sdc_detected"] == fired_compute, (
        f"sdc episode seed={seed}: {fired_compute} compute faults fired "
        f"but {delta['sdc_detected']} were detected; repro: {cmd}"
    )
    assert delta["sdc_retried"] == fired_compute, (
        f"sdc episode seed={seed}: detection without the one-for-one "
        f"retry ({delta['sdc_retried']} != {fired_compute}); repro: {cmd}"
    )
    assert delta["quarantined"] == fired_kv, (
        f"sdc episode seed={seed}: {fired_kv} KV flips fired but "
        f"{delta['quarantined']} requests were quarantined; repro: {cmd}"
    )

    statuses: dict[str, int] = {}
    for r in reqs:
        res = eng.pop_result(r.request_id)
        statuses[res.status.value] = statuses.get(res.status.value, 0) + 1
        want = oracle[r.request_id]
        got = res.tolist()
        if res.status == RequestStatus.FINISHED:
            assert got == want, (
                f"sdc episode seed={seed} rid {r.request_id}: FINISHED "
                f"output {got} != oracle {want} (a fault survived "
                f"detection or the retry diverged); repro: {cmd}"
            )
        else:
            assert res.status == RequestStatus.FAILED, (
                f"sdc episode seed={seed} rid {r.request_id}: unexpected "
                f"terminal status {res.status}; repro: {cmd}"
            )
            assert r.request_id in kv_victims, (
                f"sdc episode seed={seed} rid {r.request_id}: FAILED but "
                f"never targeted by a KV flip ({res.reason!r}); "
                f"repro: {cmd}"
            )
            assert res.reason.startswith("sdc"), (
                f"sdc episode seed={seed} rid {r.request_id}: quarantine "
                f"reason {res.reason!r} not sdc-attributed; repro: {cmd}"
            )
            assert got == want[: len(got)], (
                f"sdc episode seed={seed} rid {r.request_id}: quarantined "
                f"prefix {got} diverged from oracle {want}; repro: {cmd}"
            )
    return SDCEpisodeReport(
        seed=seed,
        steps=steps,
        injected={
            "compute": fired_compute,
            "kv": fired_kv,
        },
        detected=delta["sdc_detected"],
        retried=delta["sdc_retried"],
        quarantined=delta["quarantined"],
        statuses=statuses,
    )
