"""Slot-based batched KV cache for the continuous-batching serve engine.

The decode-path cache construction that used to live inline in
``arch/transformer.py`` is carved out here as :func:`build_caches`, plus the
slot-pool primitives the engine needs:

  * ``build_caches(cfg, batch, max_len)`` — the full decoder-side cache
    pytree for every model family (uniform attention stacks, gemma-style
    local:global ring groups, rwkv/rglru recurrent states, hybrid groups).
    Every leaf carries the batch ("slot") axis, including per-row ``pos`` /
    ``len`` counters, so different rows can sit at different sequence
    positions.
  * ``slot_store(big, small, slot)`` — scatter a freshly prefilled
    batch-1 cache into slot ``slot`` of the persistent batched cache.  One
    compiled program handles admission for every slot index.
  * ``mask_prompt_tail(caches, true_len)`` — invalidate the garbage entries
    a right-padded (bucketed) prefill wrote past the real prompt length.
  * ``supports_padded_prefill(cfg)`` — whether bucketed prefill is exact
    for this config (global attention only: ring buffers and recurrent /
    capacity-routed states are polluted by pad tokens).

Slot semantics: admission fully overwrites a slot (the prefilled batch-1
cache starts from zeros, so stale K/V, ``pos`` sentinels and recurrent
states are all replaced); eviction is free — a dead slot keeps decoding
garbage that nothing reads, and the next admission overwrites it.

Donation contract: the engine donates this whole pytree through its jitted
decode/admission programs, so every per-step mutation must be expressible
as an in-place alias of the donated buffers — which is why the primitives
here are ``dynamic_update_slice`` scatters (``slot_store``) and the decode
ring write is a per-row ``.at[idx].set`` (layers.multihead_attention): XLA
aliases donated inputs to outputs and the KV tensors are never copied.
The ragged flash-decoding path additionally relies on the ring invariant
these writes maintain — live entries of every cache occupy exactly slots
``[0, min(len, size))`` — to reduce decode masking to one per-row length.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.arch import layers as L
from repro.arch import rglru as G
from repro.arch import rwkv as R
from repro.configs.base import ModelConfig


def _stack(n: int, f) -> Any:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *([f()] * n))


def build_caches(cfg: ModelConfig, batch: int, max_len: int) -> Any:
    """Decode caches for ``batch`` slots of ``max_len`` positions each."""
    if cfg.family == "hybrid":
        ng, rem = divmod(cfg.n_layers, cfg.rnn_per_attention + 1)
        groups = None
        if ng:
            groups = {
                "rnn": _stack(
                    ng,
                    lambda: _stack(
                        cfg.rnn_per_attention,
                        lambda: G.rglru_init_cache(cfg, batch),
                    ),
                ),
                "attn": _stack(
                    ng,
                    lambda: L.init_kv_cache(cfg, batch, max_len, cfg.sliding_window),
                ),
            }
        return {
            "groups": groups,
            "tail": _stack(rem, lambda: G.rglru_init_cache(cfg, batch))
            if rem
            else None,
        }
    if cfg.mixer == "rwkv6":
        return _stack(cfg.n_layers, lambda: R.rwkv_init_cache(cfg, batch))
    if cfg.global_every:
        ge = cfg.global_every
        ng = cfg.n_layers // ge
        n_tail = cfg.n_layers - ng * ge

        def local():
            return L.init_kv_cache(cfg, batch, max_len, cfg.sliding_window)

        return {
            "groups": {
                "local": _stack(ng, lambda: _stack(ge - 1, local)),
                "global": _stack(ng, lambda: L.init_kv_cache(cfg, batch, max_len)),
            },
            "tail": _stack(n_tail, local) if n_tail else None,
        }
    from repro.arch.transformer import layer_windows

    wins = layer_windows(cfg)
    per = [
        L.init_kv_cache(cfg, batch, max_len, None if int(w) >= 2**30 else int(w))
        for w in wins
    ]
    # stack layerwise: same cache sizes stack cleanly when homogeneous;
    # gemma-style mixed sizes are padded to the largest (ring semantics
    # keep the window correct).
    size = max(p["k"].shape[1] for p in per)

    def padded(p):
        s = p["k"].shape[1]
        if s == size:
            return p
        padk = jnp.zeros((batch, size - s) + p["k"].shape[2:], p["k"].dtype)
        return {
            "k": jnp.concatenate([p["k"], padk], 1),
            "v": jnp.concatenate([p["v"], padk], 1),
            "pos": jnp.concatenate(
                [p["pos"], jnp.full((batch, size - s), 10**9, jnp.int32)], 1
            ),
            "len": p["len"],
        }

    per = [padded(p) for p in per]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per)


def slot_axes(cfg: ModelConfig, max_len: int) -> Any:
    """Per-leaf index of the slot (batch) axis, located by building the
    cache pytree at two batch sizes and diffing shapes — robust to however
    many layer/group axes a family stacks in front (hybrid rnn leaves are
    ``(ng, rnn_per, B, ...)``, attention leaves ``(L, B, size, ...)``, …)."""
    s1 = jax.eval_shape(lambda: build_caches(cfg, 1, max_len))
    s2 = jax.eval_shape(lambda: build_caches(cfg, 2, max_len))

    def diff(a, b):
        return next(i for i, (x, y) in enumerate(zip(a.shape, b.shape)) if x != y)

    return jax.tree.map(diff, s1, s2)


def slot_store(big: Any, small: Any, slot: jax.Array, axes: Any) -> Any:
    """Write row 0 of a batch-1 cache pytree into slot ``slot`` of a
    batched one.  ``slot`` may be traced, so one jit of this function
    serves every admission; ``axes`` is the static tree from
    :func:`slot_axes`."""

    def put(b, s, ax):
        starts = [jnp.int32(0)] * b.ndim
        starts[ax] = jnp.asarray(slot, jnp.int32)
        return jax.lax.dynamic_update_slice(b, s.astype(b.dtype), starts)

    return jax.tree.map(put, big, small, axes)


def take_slot(caches: Any, row: int, axes: Any) -> Any:
    """Slice one slot out of a batched cache pytree, keeping the slot axis
    at extent 1 (the shape :func:`slot_store` expects back)."""
    return jax.tree.map(
        lambda leaf, ax: jax.lax.dynamic_slice_in_dim(leaf, row, 1, axis=ax),
        caches,
        axes,
    )


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
    return ""


def mask_prompt_tail(caches: Any, true_len: jax.Array) -> Any:
    """Invalidate cache entries a right-padded prefill wrote past the real
    prompt: ``pos`` returns to the +1e9 "empty" sentinel (the causal test
    masks those keys) and ``len`` rewinds to the true length.  Only valid
    for non-ring caches, where slot index == position.  ``true_len`` may be
    scalar or per-row ``(B,)`` (rows of a batched admission have different
    prompt lengths)."""
    tl = jnp.asarray(true_len, jnp.int32)

    def fix(path, leaf):
        name = _leaf_name(path)
        if name == "pos":
            idx = jnp.arange(leaf.shape[-1], dtype=jnp.int32)
            return jnp.where(idx >= tl[..., None], jnp.int32(10**9), leaf)
        if name == "len":
            return jnp.broadcast_to(tl, leaf.shape).astype(leaf.dtype)
        return leaf

    return jax.tree_util.tree_map_with_path(fix, caches)


def supports_padded_prefill(cfg: ModelConfig) -> bool:
    """Bucketed (right-padded) prefill is exact only when every layer is
    global attention: pad tokens never enter a key window (they are causally
    ahead and later masked by :func:`mask_prompt_tail`).  Ring buffers could
    be overwritten by pad slots, recurrent states integrate pad tokens, and
    MoE capacity routing lets pad tokens change real tokens' drop pattern."""
    return (
        cfg.family == "dense"
        and cfg.mixer == "attention"
        and cfg.sliding_window is None
        and cfg.moe is None
    )
