"""Slot-based batched KV cache for the continuous-batching serve engine.

The decode-path cache construction that used to live inline in
``arch/transformer.py`` is carved out here as :func:`build_caches`, plus the
slot-pool primitives the engine needs:

  * ``build_caches(cfg, batch, max_len)`` — the full decoder-side cache
    pytree for every model family (uniform attention stacks, gemma-style
    local:global ring groups, rwkv/rglru recurrent states, hybrid groups).
    Every leaf carries the batch ("slot") axis, including per-row ``pos`` /
    ``len`` counters, so different rows can sit at different sequence
    positions.
  * ``slot_store(big, small, slot)`` — scatter a freshly prefilled
    batch-1 cache into slot ``slot`` of the persistent batched cache.  One
    compiled program handles admission for every slot index.
  * ``mask_prompt_tail(caches, true_len)`` — invalidate the garbage entries
    a right-padded (bucketed) prefill wrote past the real prompt length.
  * ``supports_padded_prefill(cfg)`` — whether bucketed prefill is exact
    for this config (global attention only: ring buffers and recurrent /
    capacity-routed states are polluted by pad tokens).

Slot semantics: admission fully overwrites a slot (the prefilled batch-1
cache starts from zeros, so stale K/V, ``pos`` sentinels and recurrent
states are all replaced); eviction is free — a dead slot keeps decoding
garbage that nothing reads, and the next admission overwrites it.

The PAGED layout (``build_paged_caches`` + :class:`BlockPool` + the
``paged_*`` device ops, below) replaces the contiguous per-slot rings with
a refcounted block pool, per-row block tables and a radix prefix index —
see the "paged layout" section further down for the full contract.

Donation contract: the engine donates this whole pytree through its jitted
decode/admission programs, so every per-step mutation must be expressible
as an in-place alias of the donated buffers — which is why the primitives
here are ``dynamic_update_slice`` scatters (``slot_store``) and the decode
ring write is a per-row ``.at[idx].set`` (layers.multihead_attention): XLA
aliases donated inputs to outputs and the KV tensors are never copied.
The ragged flash-decoding path additionally relies on the ring invariant
these writes maintain — live entries of every cache occupy exactly slots
``[0, min(len, size))`` — to reduce decode masking to one per-row length.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.arch import layers as L
from repro.arch import rglru as G
from repro.arch import rwkv as R
from repro.configs.base import ModelConfig


def _stack(n: int, f) -> Any:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *([f()] * n))


def build_caches(cfg: ModelConfig, batch: int, max_len: int) -> Any:
    """Decode caches for ``batch`` slots of ``max_len`` positions each."""
    if cfg.family == "hybrid":
        ng, rem = divmod(cfg.n_layers, cfg.rnn_per_attention + 1)
        groups = None
        if ng:
            groups = {
                "rnn": _stack(
                    ng,
                    lambda: _stack(
                        cfg.rnn_per_attention,
                        lambda: G.rglru_init_cache(cfg, batch),
                    ),
                ),
                "attn": _stack(
                    ng,
                    lambda: L.init_kv_cache(cfg, batch, max_len, cfg.sliding_window),
                ),
            }
        return {
            "groups": groups,
            "tail": _stack(rem, lambda: G.rglru_init_cache(cfg, batch))
            if rem
            else None,
        }
    if cfg.mixer == "rwkv6":
        return _stack(cfg.n_layers, lambda: R.rwkv_init_cache(cfg, batch))
    if cfg.global_every:
        ge = cfg.global_every
        ng = cfg.n_layers // ge
        n_tail = cfg.n_layers - ng * ge

        def local():
            return L.init_kv_cache(cfg, batch, max_len, cfg.sliding_window)

        return {
            "groups": {
                "local": _stack(ng, lambda: _stack(ge - 1, local)),
                "global": _stack(ng, lambda: L.init_kv_cache(cfg, batch, max_len)),
            },
            "tail": _stack(n_tail, local) if n_tail else None,
        }
    from repro.arch.transformer import layer_windows

    wins = layer_windows(cfg)
    per = [
        L.init_kv_cache(cfg, batch, max_len, None if int(w) >= 2**30 else int(w))
        for w in wins
    ]
    # stack layerwise: same cache sizes stack cleanly when homogeneous;
    # gemma-style mixed sizes are padded to the largest (ring semantics
    # keep the window correct).
    size = max(p["k"].shape[1] for p in per)

    def padded(p):
        s = p["k"].shape[1]
        if s == size:
            return p
        padk = jnp.zeros((batch, size - s) + p["k"].shape[2:], p["k"].dtype)
        return {
            "k": jnp.concatenate([p["k"], padk], 1),
            "v": jnp.concatenate([p["v"], padk], 1),
            "pos": jnp.concatenate(
                [p["pos"], jnp.full((batch, size - s), 10**9, jnp.int32)], 1
            ),
            "len": p["len"],
        }

    per = [padded(p) for p in per]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per)


def slot_axes(cfg: ModelConfig, max_len: int) -> Any:
    """Per-leaf index of the slot (batch) axis, located by building the
    cache pytree at two batch sizes and diffing shapes — robust to however
    many layer/group axes a family stacks in front (hybrid rnn leaves are
    ``(ng, rnn_per, B, ...)``, attention leaves ``(L, B, size, ...)``, …)."""
    s1 = jax.eval_shape(lambda: build_caches(cfg, 1, max_len))
    s2 = jax.eval_shape(lambda: build_caches(cfg, 2, max_len))

    def diff(a, b):
        return next(i for i, (x, y) in enumerate(zip(a.shape, b.shape)) if x != y)

    return jax.tree.map(diff, s1, s2)


def slot_store(big: Any, small: Any, slot: jax.Array, axes: Any) -> Any:
    """Write row 0 of a batch-1 cache pytree into slot ``slot`` of a
    batched one.  ``slot`` may be traced, so one jit of this function
    serves every admission; ``axes`` is the static tree from
    :func:`slot_axes`."""

    def put(b, s, ax):
        starts = [jnp.int32(0)] * b.ndim
        starts[ax] = jnp.asarray(slot, jnp.int32)
        return jax.lax.dynamic_update_slice(b, s.astype(b.dtype), starts)

    return jax.tree.map(put, big, small, axes)


def take_slot(caches: Any, row: int, axes: Any) -> Any:
    """Slice one slot out of a batched cache pytree, keeping the slot axis
    at extent 1 (the shape :func:`slot_store` expects back)."""
    return jax.tree.map(
        lambda leaf, ax: jax.lax.dynamic_slice_in_dim(leaf, row, 1, axis=ax),
        caches,
        axes,
    )


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
    return ""


def mask_prompt_tail(caches: Any, true_len: jax.Array) -> Any:
    """Invalidate cache entries a right-padded prefill wrote past the real
    prompt: ``pos`` returns to the +1e9 "empty" sentinel (the causal test
    masks those keys) and ``len`` rewinds to the true length.  Only valid
    for non-ring caches, where slot index == position.  ``true_len`` may be
    scalar or per-row ``(B,)`` (rows of a batched admission have different
    prompt lengths)."""
    tl = jnp.asarray(true_len, jnp.int32)

    def fix(path, leaf):
        name = _leaf_name(path)
        if name == "pos":
            idx = jnp.arange(leaf.shape[-1], dtype=jnp.int32)
            return jnp.where(idx >= tl[..., None], jnp.int32(10**9), leaf)
        if name == "len":
            return jnp.broadcast_to(tl, leaf.shape).astype(leaf.dtype)
        return leaf

    return jax.tree_util.tree_map_with_path(fix, caches)


# ------------------------------------------------------------ paged layout --
#
# The contiguous layout above reserves ``slots x max_len`` KV positions per
# layer — HBM footprint is set by the worst-case sequence (the paper's §6.3
# over-provisioning, at serve granularity).  The paged layout carves the
# same HBM into fixed-size blocks:
#
#   kpool/vpool: (num_blocks, block_size, kv_heads, head_dim) per layer
#   table:       (batch, max_len // block_size) int32, logical -> physical
#   len:         (batch,) int32 live tokens per row
#
# Physical block 0 is the SINK: never allocated, evicted rows point every
# table entry at it so the always-full-batch decode program's garbage
# writes land somewhere harmless.  Host-side ownership (refcounts, the
# free list, the prefix index) lives in :class:`BlockPool`; the device
# pytree is mutated only through the donated pure ops below
# (`paged_store_row_blocks` / `paged_set_row` / `paged_copy_block`), so the
# decode loop keeps the PR-4 zero-copy donation contract.
#
# Prefix sharing: the pool keys each block by ``(previous physical block,
# tokens written in it)`` — a radix chain, vLLM-style.  Requests whose
# prompts share a leading run of full blocks alias those physical blocks
# (refcount += 1 each).  A partially-filled prompt tail block is shared
# only on an exact content match, and the *attaching* request copies it on
# its first divergent write (copy-on-write); the creating request never
# needs to — appends past the registered fill are masked for every sharer
# (they read only ``[0, their_len)``), so registered content is immutable
# by construction.


def supports_paged(cfg: ModelConfig) -> bool:
    """The paged layout stores one uniform KV pool per layer and masks
    purely by live length, so it requires every layer to be (global)
    attention: ring buffers (sliding windows), recurrent states and hybrid
    stacks have no block-table equivalent here.  MoE FFNs are fine — paging
    only touches the attention KV."""
    return (
        cfg.family in ("dense", "moe")
        and cfg.mixer == "attention"
        and cfg.sliding_window is None
        and not cfg.global_every
    )


def build_paged_caches(
    cfg: ModelConfig,
    batch: int,
    max_len: int,
    num_blocks: int,
    block_size: int,
) -> Any:
    """Paged decode caches: per-layer block pools stacked over layers, plus
    per-row block tables / lengths (replicated per layer so the layer scan
    slices one uniform pytree; the int32 metadata is negligible)."""
    if not supports_paged(cfg):
        raise ValueError(f"paged KV layout unsupported for {cfg.name}")
    if max_len % block_size:
        raise ValueError(
            f"max_len {max_len} not a multiple of block_size {block_size}"
        )
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    sd = jnp.dtype(cfg.dtype)
    n_blk = max_len // block_size
    L = cfg.n_layers

    return {
        "kpool": jnp.zeros((L, num_blocks, block_size, kv, hd), sd),
        "vpool": jnp.zeros((L, num_blocks, block_size, kv, hd), sd),
        "table": jnp.zeros((L, batch, n_blk), jnp.int32),
        "len": jnp.zeros((L, batch), jnp.int32),
    }


def paged_store_row_blocks(
    caches: Any,
    scratch: Any,
    row: jax.Array,
    start_lb: jax.Array,
    phys: jax.Array,
) -> Any:
    """Pack ``len(phys)`` consecutive logical blocks of a freshly prefilled
    contiguous scratch cache (leaves ``(L, n, S, kv, hd)``) into the pool
    blocks ``phys``, starting at logical block ``start_lb`` of scratch row
    ``row``.  All indices are traced — one compilation serves every
    admission per distinct block count.  ``caches`` is donated by the
    engine's jit of this function."""
    n_pack = phys.shape[0]
    bs = caches["kpool"].shape[2]
    L = caches["kpool"].shape[0]
    kv, hd = caches["kpool"].shape[3:]
    row = jnp.asarray(row, jnp.int32)
    start = jnp.asarray(start_lb, jnp.int32) * bs

    def pack(pool, src):
        blk = jax.lax.dynamic_slice(
            src,
            (jnp.int32(0), row, start, jnp.int32(0), jnp.int32(0)),
            (L, 1, n_pack * bs, kv, hd),
        )
        blocks = blk[:, 0].reshape(L, n_pack, bs, kv, hd)
        # (L, n_pack, bs, kv, hd) scattered at pool[:, phys]
        return pool.at[:, phys.astype(jnp.int32)].set(blocks.astype(pool.dtype))

    return {
        "kpool": pack(caches["kpool"], scratch["k"]),
        "vpool": pack(caches["vpool"], scratch["v"]),
        "table": caches["table"],
        "len": caches["len"],
    }


def paged_set_row(
    caches: Any, row: jax.Array, table_row: jax.Array, length: jax.Array
) -> Any:
    """Write one row's full block table + live length (admission fills it,
    eviction resets it to all-sink / zero).  ``row`` is traced — one
    compilation serves every slot."""
    row = jnp.asarray(row, jnp.int32)
    L = caches["table"].shape[0]
    tab = jnp.broadcast_to(
        table_row.astype(jnp.int32)[None, None, :],
        (L, 1, caches["table"].shape[2]),
    )
    table = jax.lax.dynamic_update_slice(
        caches["table"], tab, (jnp.int32(0), row, jnp.int32(0))
    )
    ln = jnp.broadcast_to(jnp.asarray(length, jnp.int32)[None, None], (L, 1))
    length_ = jax.lax.dynamic_update_slice(caches["len"], ln, (jnp.int32(0), row))
    return {
        "kpool": caches["kpool"],
        "vpool": caches["vpool"],
        "table": table,
        "len": length_,
    }


def paged_copy_block(
    caches: Any, row: jax.Array, lb: jax.Array, src: jax.Array, dst: jax.Array
) -> Any:
    """Copy-on-write: duplicate physical block ``src`` into ``dst`` across
    every layer's pools and repoint row ``row``'s logical block ``lb`` at
    the private copy."""
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)

    def cow(pool):
        blk = jnp.take(pool, src, axis=1)  # (L, bs, kv, hd)
        return jax.lax.dynamic_update_slice_in_dim(pool, blk[:, None], dst, axis=1)

    row = jnp.asarray(row, jnp.int32)
    lb = jnp.asarray(lb, jnp.int32)
    table = caches["table"].at[:, row, lb].set(dst)
    return {
        "kpool": cow(caches["kpool"]),
        "vpool": cow(caches["vpool"]),
        "table": table,
        "len": caches["len"],
    }


SINK_BLOCK = 0  # physical block 0: garbage target for dead rows, never owned


class BlockPool:
    """Host-side block ownership for the paged KV cache: a free list,
    per-block refcounts, and the radix-chain prefix index.

    The pool never touches device memory — it decides *which* physical
    blocks a request may read/write, and the engine turns those decisions
    into donated device ops.  Invariants (checked by
    :meth:`assert_invariants` and the fuzz suite):

      * refcount[b] == number of (live request, logical slot) references
        to b, for every non-sink block; sink refcount is never tracked.
      * the free list and the referenced set partition ``[1, num_blocks)``.
      * every prefix-index entry points at a block with refcount >= 1
        (releasing a block to zero drops its index entries), so an idle
        pool is fully free — no leak through the index.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError("need at least one allocatable block + sink")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.refcount = [0] * num_blocks
        self.free: list[int] = list(range(num_blocks - 1, 0, -1))  # pop() = 1
        # (prev_physical_block, tokens-in-block) -> physical block.  Full
        # blocks carry block_size tokens; a prompt tail carries fewer, so
        # key tuples of different fills never collide.
        self.index: dict[tuple[int, tuple[int, ...]], int] = {}
        self._keys_of: dict[int, list] = {}
        # blocks held by an EXTERNAL actor (chaos pressure spikes, a future
        # multi-tenant reservation API): invisible to the engine's rows but
        # accounted by assert_invariants so pressure never masquerades as a
        # leak.  Populated only via reserve()/unreserve().
        self.external: set[int] = set()

    @property
    def free_blocks(self) -> int:
        return len(self.free)

    def alloc(self) -> int:
        bid = self.free.pop()
        assert self.refcount[bid] == 0, bid
        self.refcount[bid] = 1
        return bid

    def retain(self, bid: int) -> None:
        assert self.refcount[bid] > 0, f"retain of unowned block {bid}"
        self.refcount[bid] += 1

    def release(self, bid: int) -> None:
        assert self.refcount[bid] > 0, f"release of unowned block {bid}"
        self.refcount[bid] -= 1
        if self.refcount[bid] == 0:
            for key in self._keys_of.pop(bid, ()):
                if self.index.get(key) == bid:
                    del self.index[key]
            self.free.append(bid)

    def reserve(self, n: int) -> list[int]:
        """Withhold up to ``n`` free blocks from the pool on behalf of an
        external actor (the chaos harness's pressure spikes; never the
        engine).  Returns the block ids actually reserved — fewer than
        ``n`` when the pool is drier than asked."""
        got = []
        for _ in range(min(n, len(self.free))):
            bid = self.alloc()
            self.external.add(bid)
            got.append(bid)
        return got

    def unreserve(self, bids: list[int]) -> None:
        """Return externally reserved blocks to the pool."""
        for bid in bids:
            assert bid in self.external, f"unreserve of non-reserved block {bid}"
            self.external.discard(bid)
            self.release(bid)

    def register(self, prev: int, tokens: tuple[int, ...], bid: int) -> None:
        """Expose a block's content to future prefix matches.  First
        registration wins; identical content admitted later simply fails to
        register (it already matched or races a live twin)."""
        key = (prev, tokens)
        if key not in self.index:
            self.index[key] = bid
            self._keys_of.setdefault(bid, []).append(key)

    def match_prefix(self, tokens: list[int]) -> tuple[list[int], int | None]:
        """Walk the radix chain over the prompt: returns (shared full
        blocks, shared-tail block or None).  The tail matches only when
        every full block matched and the partial content is identical."""
        bs = self.block_size
        shared: list[int] = []
        prev = -1
        n_full = len(tokens) // bs
        for i in range(n_full):
            bid = self.index.get((prev, tuple(tokens[i * bs : (i + 1) * bs])))
            if bid is None:
                return shared, None
            shared.append(bid)
            prev = bid
        tail = tokens[n_full * bs :]
        if not tail or len(shared) != n_full:
            return shared, None
        return shared, self.index.get((prev, tuple(tail)))

    def to_state(self) -> dict:
        """JSON-serializable snapshot of the full ownership state, prefix
        index included — the recovery manager embeds this in the engine
        snapshot manifest so a restored pool keeps aliasing the restored
        device blocks."""
        return {
            "num_blocks": self.num_blocks,
            "block_size": self.block_size,
            "refcount": list(self.refcount),
            "free": list(self.free),
            "external": sorted(self.external),
            "index": [
                [prev, list(tokens), bid]
                for (prev, tokens), bid in self.index.items()
            ],
        }

    @classmethod
    def from_state(cls, state: dict) -> "BlockPool":
        """Rebuild a pool from :meth:`to_state` output (``_keys_of`` is
        re-derived from the index)."""
        pool = cls(int(state["num_blocks"]), int(state["block_size"]))
        pool.refcount = [int(c) for c in state["refcount"]]
        pool.free = [int(b) for b in state["free"]]
        pool.external = {int(b) for b in state["external"]}
        pool.index = {}
        pool._keys_of = {}
        for prev, tokens, bid in state["index"]:
            pool.register(int(prev), tuple(int(t) for t in tokens), int(bid))
        return pool

    def assert_invariants(self, live_refs: dict[int, int]) -> None:
        """``live_refs``: physical block -> reference count derived from
        the engine's live rows.  Raises on any ownership drift."""
        for bid in range(1, self.num_blocks):
            want = live_refs.get(bid, 0) + (1 if bid in self.external else 0)
            assert self.refcount[bid] == want, (
                f"block {bid}: refcount {self.refcount[bid]} != live refs {want}"
            )
        free_set = set(self.free)
        assert len(free_set) == len(self.free), "free list has duplicates"
        assert SINK_BLOCK not in free_set, "sink block leaked into free list"
        for bid in free_set:
            assert self.refcount[bid] == 0, f"free block {bid} has refs"
        owned = {b for b, c in enumerate(self.refcount) if c > 0}
        assert owned | free_set == set(range(1, self.num_blocks)), (
            "free list + owned blocks do not partition the pool"
        )
        for key, bid in self.index.items():
            assert self.refcount[bid] > 0, (
                f"index entry {key} -> {bid} outlives its block"
            )


def supports_padded_prefill(cfg: ModelConfig) -> bool:
    """Bucketed (right-padded) prefill is exact only when every layer is
    global attention: pad tokens never enter a key window (they are causally
    ahead and later masked by :func:`mask_prompt_tail`).  Ring buffers could
    be overwritten by pad slots, recurrent states integrate pad tokens, and
    MoE capacity routing lets pad tokens change real tokens' drop pattern."""
    return (
        cfg.family == "dense"
        and cfg.mixer == "attention"
        and cfg.sliding_window is None
        and cfg.moe is None
    )
