"""Exact tile-granular memory-hierarchy simulator (validation oracle).

The paper validates its analytical model against post-synthesis ASIC designs
(<2% error, Fig 7).  No synthesis toolchain exists in this environment, so we
validate against an *exact* simulator instead: it tracks which child tile is
resident in each memory level for each tensor across every temporal loop
iteration of a schedule and counts actual reload traffic.  The stationarity
behaviour emerges from first principles here (a tile is re-fetched iff the
required tile id differs from the resident one), whereas reuse.py derives it
with closed-form products — agreement between the two on randomized schedules
(tests/test_reuse_model.py) is the repo's analogue of the paper's Fig 7.

Two engines, mirroring the costmodel.py batched/scalar split:

  * ``engine="scalar"`` — the original per-iteration Python odometer.  It
    walks all ``N = prod(trips)`` iterations and compares resident-tile keys
    one by one: O(N * levels * tensors) Python steps.  Kept verbatim as the
    differential oracle (tests/test_simulate.py proves the engines
    bit-identical on randomized schedules).

  * ``engine="vector"`` (default) — residency-change counting over the
    mixed-radix structure of the loop nest.  The resident-tile key of
    (level l, tensor T) is the tuple of odometer digits at the loop
    positions that are both at levels >= l and over dims relevant to T.
    Digit p of the odometer changes at iteration n exactly when the product
    of trips strictly inner to p divides n, and those suffix products are
    nested under divisibility — so "any key digit changed" collapses to
    "the suffix product inner to the *innermost* key position divides n".
    Reload counts therefore come from a handful of array reductions over
    the (levels x tensors x loop-positions) masks instead of an O(N) walk:

        reloads(l, T)     = N // suffix[max(key positions) + 1]
        first_touch(l, T) = prod(trips at key positions)   (distinct keys)

    O(levels * tensors * positions) total — independent of the iteration
    count, so the oracle now validates full-size layer schedules, not just
    toy bounds.

Only temporal schedules are simulated (spatial factors folded out by the
caller); the array-level multicast/hop terms are simple closed forms already.
"""

from __future__ import annotations

import math

import numpy as np

from repro.core.reuse import AccessCounts
from repro.core.schedule import Schedule

# Past this iteration count the int64 suffix products could wrap; the vector
# engine switches to Python big-int arithmetic (same formulas, still exact).
_INT64_SAFE_ITERS = 2 ** 62


def _loop_stack(schedule: Schedule) -> list[tuple[str, int, int]]:
    """Temporal loops outermost -> innermost: (dim, trip, level), trip > 1."""
    loops: list[tuple[str, int, int]] = []
    for l in range(len(schedule.levels) - 1, -1, -1):
        for d in reversed(schedule.order[l]):
            trip = schedule.tiling[d][l]
            if trip > 1:
                loops.append((d, trip, l))
    return loops


def simulate(schedule: Schedule, engine: str = "vector") -> AccessCounts:
    """Exact access counts for one schedule (see module docstring)."""
    if engine == "vector":
        return _simulate_vector(schedule)
    if engine == "scalar":
        return _simulate_scalar(schedule)
    raise ValueError(f"unknown simulate engine {engine!r}")


# ------------------------------------------------------------------ vector --


def _counts_to_access(
    schedule: Schedule,
    reloads: list[list[int]],
    first_touch: list[list[int]],
) -> AccessCounts:
    """Shared reloads/first-touch -> AccessCounts conversion (both engines)."""
    nest = schedule.nest
    L = len(schedule.levels)
    tensors = nest.tensors
    reads: list[dict[str, int]] = [dict() for _ in range(L)]
    writes: list[dict[str, int]] = [dict() for _ in range(L)]
    for l in range(L):
        child = schedule.child_tile(l)
        for ti, t in enumerate(tensors):
            elems = t.tile_elems(child)
            n = reloads[l][ti] * elems
            if t.output:
                writes[l][t.name] = n
                # each tile's first streaming up is write-only; later
                # re-streams read the partial back first
                reads[l][t.name] = n - first_touch[l][ti] * elems
            else:
                reads[l][t.name] = n
                writes[l][t.name] = 0
    return AccessCounts(
        reads=tuple(reads),
        writes=tuple(writes),
        hops={t.name: 0.0 for t in tensors},
        macs=nest.macs(),
        utilization=schedule.utilization(),
    )


def _simulate_vector(schedule: Schedule) -> AccessCounts:
    nest = schedule.nest
    L = len(schedule.levels)
    tensors = nest.tensors
    T = len(tensors)
    loops = _loop_stack(schedule)
    P = len(loops)
    total = math.prod(trip for _, trip, _ in loops)

    if P == 0:
        ones = [[1] * T for _ in range(L)]
        return _counts_to_access(schedule, ones, ones)

    if total >= _INT64_SAFE_ITERS:
        reloads, first = _mixed_radix_counts_bigint(loops, tensors, L, total)
        return _counts_to_access(schedule, reloads, first)

    trips = np.array([trip for _, trip, _ in loops], dtype=np.int64)
    lvls = np.array([l for _, _, l in loops], dtype=np.int64)
    rel = np.array(
        [[d in t.relevant for d, _, _ in loops] for t in tensors], dtype=bool
    )  # (T, P)
    # key[l, t, p]: loop position p feeds the resident-tile key of (l, t)
    key = (lvls[None, :] >= np.arange(L)[:, None])[:, None, :] & rel[None, :, :]

    # suffix[p] = product of trips at positions >= p (suffix[P] = 1)
    suffix = np.ones(P + 1, dtype=np.int64)
    suffix[:P] = np.cumprod(trips[::-1])[::-1]

    # innermost key position; -1 (empty key) maps to suffix[0] = N -> 1 reload
    m = np.where(key, np.arange(P)[None, None, :], -1).max(axis=2)  # (L, T)
    reloads = total // suffix[m + 1]
    first = np.where(key, trips[None, None, :], 1).prod(axis=2)

    # downstream arithmetic (reloads * tile elems) must stay arbitrary
    # precision like the scalar oracle, so hand back Python ints
    return _counts_to_access(
        schedule,
        [[int(reloads[l, ti]) for ti in range(T)] for l in range(L)],
        [[int(first[l, ti]) for ti in range(T)] for l in range(L)],
    )


def _mixed_radix_counts_bigint(
    loops: list[tuple[str, int, int]], tensors, L: int, total: int
) -> tuple[list[list[int]], list[list[int]]]:
    """Same formulas as the NumPy path in Python big-int arithmetic, for
    schedules whose iteration count exceeds exact int64 range."""
    P = len(loops)
    suffix = [1] * (P + 1)
    for p in range(P - 1, -1, -1):
        suffix[p] = suffix[p + 1] * loops[p][1]
    reloads = [[1] * len(tensors) for _ in range(L)]
    first = [[1] * len(tensors) for _ in range(L)]
    for l in range(L):
        for ti, t in enumerate(tensors):
            rel = t.relevant
            m = -1
            f = 1
            for p, (d, trip, ll) in enumerate(loops):
                if ll >= l and d in rel:
                    m = p
                    f *= trip
            reloads[l][ti] = total // suffix[m + 1]
            first[l][ti] = f
    return reloads, first


# ------------------------------------------------------------------ scalar --


def _simulate_scalar(schedule: Schedule) -> AccessCounts:
    """The original per-iteration odometer (differential oracle)."""
    nest = schedule.nest
    L = len(schedule.levels)
    loops = _loop_stack(schedule)
    n_loops = len(loops)
    counters = [0] * n_loops

    # Pre-compute, for every (level, tensor): which loop positions feed its id
    # (loops at levels >= level over dims relevant to the tensor).
    tensors = nest.tensors
    keys: list[list[list[int]]] = []  # [level][tensor] -> loop positions
    for l in range(L):
        keys.append(
            [
                [i for i, (d, _, ll) in enumerate(loops) if ll >= l and d in t.relevant]
                for t in tensors
            ]
        )

    resident: list[list[tuple | None]] = [[None] * len(tensors) for _ in range(L)]
    reloads = [[0] * len(tensors) for _ in range(L)]
    first_touch = [[0] * len(tensors) for _ in range(L)]
    seen: list[list[set]] = [[set() for _ in tensors] for _ in range(L)]

    total_iters = 1
    for _, trip, _ in loops:
        total_iters *= trip

    for _ in range(total_iters):
        for l in range(L):
            for ti in range(len(tensors)):
                key = tuple(counters[i] for i in keys[l][ti])
                if resident[l][ti] != key:
                    resident[l][ti] = key
                    reloads[l][ti] += 1
                    if key not in seen[l][ti]:
                        seen[l][ti].add(key)
                        first_touch[l][ti] += 1
        # odometer increment (innermost = last position)
        for i in range(n_loops - 1, -1, -1):
            counters[i] += 1
            if counters[i] < loops[i][1]:
                break
            counters[i] = 0

    return _counts_to_access(schedule, reloads, first_touch)
