"""Exact tile-granular memory-hierarchy simulator (validation oracle).

The paper validates its analytical model against post-synthesis ASIC designs
(<2% error, Fig 7).  No synthesis toolchain exists in this environment, so we
validate against an *exact* simulator instead: it walks every temporal loop
iteration of a schedule, tracks which child tile is resident in each memory
level for each tensor, and counts actual reload traffic.  The stationarity
behaviour emerges from first principles here (a tile is re-fetched iff the
required tile id differs from the resident one), whereas reuse.py derives it
with closed-form products — agreement between the two on randomized schedules
(tests/test_reuse_model.py) is the repo's analogue of the paper's Fig 7.

Only temporal schedules are simulated (spatial factors folded out by the
caller); the array-level multicast/hop terms are simple closed forms already.
Exact, but O(total temporal iterations): use small bounds.
"""

from __future__ import annotations

from repro.core.reuse import AccessCounts
from repro.core.schedule import Schedule


def simulate(schedule: Schedule) -> AccessCounts:
    nest = schedule.nest
    L = len(schedule.levels)

    # Loop list outermost -> innermost: (dim, trip, level)
    loops: list[tuple[str, int, int]] = []
    for l in range(L - 1, -1, -1):
        for d in reversed(schedule.order[l]):
            trip = schedule.tiling[d][l]
            if trip > 1:
                loops.append((d, trip, l))

    n_loops = len(loops)
    counters = [0] * n_loops

    # Pre-compute, for every (level, tensor): which loop positions feed its id
    # (loops at levels >= level over dims relevant to the tensor), and the
    # child-tile element count.
    tensors = nest.tensors
    keys: list[list[list[int]]] = []  # [level][tensor] -> loop positions
    child_elems: list[list[int]] = []
    for l in range(L):
        kt, ce = [], []
        child = schedule.child_tile(l)
        for t in tensors:
            rel = t.relevant
            kt.append(
                [i for i, (d, _, ll) in enumerate(loops) if ll >= l and d in rel]
            )
            ce.append(t.tile_elems(child))
        keys.append(kt)
        child_elems.append(ce)

    resident: list[list[tuple | None]] = [[None] * len(tensors) for _ in range(L)]
    reloads = [[0] * len(tensors) for _ in range(L)]
    first_touch = [[0] * len(tensors) for _ in range(L)]
    seen: list[list[set]] = [[set() for _ in tensors] for _ in range(L)]

    total_iters = 1
    for _, trip, _ in loops:
        total_iters *= trip

    for _ in range(total_iters):
        for l in range(L):
            for ti in range(len(tensors)):
                key = tuple(counters[i] for i in keys[l][ti])
                if resident[l][ti] != key:
                    resident[l][ti] = key
                    reloads[l][ti] += 1
                    if key not in seen[l][ti]:
                        seen[l][ti].add(key)
                        first_touch[l][ti] += 1
        # odometer increment (innermost = last position)
        for i in range(n_loops - 1, -1, -1):
            counters[i] += 1
            if counters[i] < loops[i][1]:
                break
            counters[i] = 0

    reads: list[dict[str, int]] = [dict() for _ in range(L)]
    writes: list[dict[str, int]] = [dict() for _ in range(L)]
    for l in range(L):
        for ti, t in enumerate(tensors):
            n = reloads[l][ti] * child_elems[l][ti]
            if t.output:
                writes[l][t.name] = n
                # each tile's first streaming up is write-only; later
                # re-streams read the partial back first
                reads[l][t.name] = n - first_touch[l][ti] * child_elems[l][ti]
            else:
                reads[l][t.name] = n
                writes[l][t.name] = 0

    return AccessCounts(
        reads=tuple(reads),
        writes=tuple(writes),
        hops={t.name: 0.0 for t in tensors},
        macs=nest.macs(),
        utilization=schedule.utilization(),
    )
