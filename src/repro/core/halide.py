"""Halide-style scheduling front-end (paper §4, Listing 1).

The paper expresses accelerators as Halide schedules:

    output.split(x, xo, xi, 8).split(y, yo, yi, 8)
          .reorder(xi, yi, r.z, r.y, r.x)
          .in_(ibuf).compute_at(output, xo)
          .unroll(xi, dim=0).systolic()
          .accelerate()

This module provides that fluent vocabulary and LOWERS it to the normalized
`Schedule` (core/schedule.py) the analytical model consumes - the same
split between user-facing language and compiler IR the paper builds.

Primitives (Table 2):
    split(dim, factor)        loop blocking: peel `factor` into the current
                              (innermost-unfinished) memory level
    at_level(name)            move the "cursor": subsequent splits define
                              the tile of this level
    reorder(*dims)            loop order (innermost first) at the cursor level
    store(name, capacity)     declare a memory level (in/compute_at fused:
                              buffers in this system always sit at the loop
                              that the level's tile implies)
    unroll(dim, factor, axis) spatial unrolling onto PE-array axis
                              (replication = repeated unroll on one axis)
    systolic()                tag the array as systolic (affects the hop
                              model's labeling only; energy model per §5)
    accelerate()              finalize -> Schedule
"""

from __future__ import annotations

import math
from typing import Sequence

from repro.core.loopnest import LoopNest
from repro.core.schedule import ArraySpec, MemLevel, Schedule


class HalideSchedule:
    def __init__(self, nest: LoopNest, array_dims: Sequence[int] = (1,)):
        self.nest = nest
        self.array = ArraySpec(dims=tuple(array_dims))
        self._levels: list[MemLevel] = []
        self._factors: list[dict[str, int]] = []   # per level
        self._orders: list[tuple[str, ...] | None] = []
        self._spatial: list[list[tuple[str, int]]] = [
            [] for _ in self.array.dims
        ]
        self._systolic = False
        self._cursor = -1

    # ------------------------------------------------------------ memory --
    def store(self, name: str, capacity_bytes: int | None = None,
              per_pe: bool = False, double_buffered: bool = True
              ) -> "HalideSchedule":
        """Declare the next memory level outward (RF first, DRAM last)."""
        self._levels.append(
            MemLevel(name, capacity_bytes, double_buffered=double_buffered,
                     per_pe=per_pe)
        )
        self._factors.append({})
        self._orders.append(None)
        self._cursor = len(self._levels) - 1
        return self

    def at_level(self, name: str) -> "HalideSchedule":
        self._cursor = next(
            i for i, l in enumerate(self._levels) if l.name == name
        )
        return self

    # ------------------------------------------------------------- loops --
    def split(self, dim: str, factor: int) -> "HalideSchedule":
        """Assign `factor` iterations of `dim` to the cursor level's tile."""
        assert self._cursor >= 0, "store() a level before split()"
        f = self._factors[self._cursor]
        f[dim] = f.get(dim, 1) * factor
        return self

    def reorder(self, *dims: str) -> "HalideSchedule":
        """Loop order at the cursor level, innermost first."""
        rest = [d for d in self.nest.dims if d not in dims]
        self._orders[self._cursor] = tuple(dims) + tuple(rest)
        return self

    def unroll(self, dim: str, factor: int, axis: int = 0) -> "HalideSchedule":
        """Spatially unroll `dim` by `factor` PEs on array axis `axis`."""
        self._spatial[axis].append((dim, factor))
        return self

    def systolic(self) -> "HalideSchedule":
        self._systolic = True
        return self

    # ---------------------------------------------------------- finalize --
    def accelerate(self) -> Schedule:
        """Lower to the normalized Schedule; the outermost level absorbs
        whatever iterations remain (the DRAM-resident loops)."""
        assert self._levels, "no memory levels declared"
        L = len(self._levels)
        sp = {d: 1 for d in self.nest.dims}
        for assigns in self._spatial:
            for d, f in assigns:
                sp[d] *= f
        tiling: dict[str, tuple[int, ...]] = {}
        for d in self.nest.dims:
            per = [self._factors[l].get(d, 1) for l in range(L)]
            inner = math.prod(per[:-1])
            need = math.ceil(self.nest.bounds[d] / sp[d])
            top = max(per[-1], math.ceil(need / inner))
            tiling[d] = tuple(per[:-1] + [top])
        orders = tuple(
            o if o is not None else tuple(self.nest.dims)
            for o in self._orders
        )
        return Schedule(
            nest=self.nest,
            levels=tuple(self._levels),
            tiling=tiling,
            order=orders,
            array=self.array,
            spatial=tuple(tuple(s) for s in self._spatial),
        )


def listing1_example(nest: LoopNest) -> Schedule:
    """The paper's Listing 1 schedule, in this front-end: split x and y by
    8 into a local buffer, reorder, and unroll 4 PEs systolically."""
    return (
        HalideSchedule(nest, array_dims=(4,))
        .store("RF", 512, per_pe=True, double_buffered=False)
        .store("ibuf", 128 * 1024)
        .split("X", 8).split("Y", 8)
        .reorder("FX", "FY", "C", "X", "Y")
        .store("DRAM", None)
        .at_level("RF")
        .unroll("X", 4, axis=0)
        .systolic()
        .accelerate()
    )
