"""Schedule -> TPU mapping: the paper's technique as a first-class feature.

Three nested scales (DESIGN.md §2):

  1. chip mesh   — spatial loop unrolling of model loops onto mesh axes.
                   `mesh_dataflow()` prices candidate assignments with the
                   same access-count machinery (collective traffic = the
                   "inter-PE hop" term at pod scale).
  2. HBM<->VMEM  — `choose_matmul_tiles()` runs the blocking search on a
                   2-level hierarchy (VMEM capacity, HBM unbounded) and
                   returns Pallas BlockSpec tile sizes for the kernels.
  3. MXU         — fixed 128x128 systolic C|K dataflow: tiles are rounded to
                   hardware alignment (8 sublanes x 128 lanes, 128x128 MXU).

This is where `core/` feeds `parallel/sharding.py` and `kernels/*/ops.py`.
"""

from __future__ import annotations

import dataclasses
import functools
import os

from repro.core import energy as en
from repro.core.blocking import search_blocking
from repro.core.dataflow import Dataflow
from repro.core.jsonstore import atomic_write_json, load_json_dict
from repro.core.loopnest import matmul_nest
from repro.core.schedule import ArraySpec, MemLevel

MXU_DIM = 128
SUBLANES = 8
LANES = 128


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def round_down_pow2(x: int, lo: int) -> int:
    p = lo
    while p * 2 <= x:
        p *= 2
    return p


@dataclasses.dataclass(frozen=True)
class MatmulTiles:
    """HBM->VMEM blocking for an (M, N, K) matmul: bm/bn/bk block sizes."""

    bm: int
    bn: int
    bk: int

    def vmem_bytes(self, dtype_bytes: int = 2) -> int:
        # A tile + B tile + accumulator tile (fp32), double-buffered operands
        return (
            2 * (self.bm * self.bk + self.bk * self.bn) * dtype_bytes
            + self.bm * self.bn * 4
        )

    def hbm_words(self, M: int, N: int, K: int) -> int:
        """HBM<->VMEM traffic (words) of an (M, N, K) matmul blocked at
        these tiles: A streams once per N-tile pass, B once per M-tile
        pass, C is written once (the K loop accumulates in VMEM).  With a
        serving-sized M <= bm the weight matrix B crosses HBM exactly once
        — the quantity the decode-step planner (core/serveplan.py) prices.
        """
        n_m = -(-M // self.bm)
        n_n = -(-N // self.bn)
        return M * K * n_n + K * N * n_m + M * N


# ------------------------------------------------------ tile-choice cache --
# Two layers: functools.lru_cache in-process, plus an on-disk JSON store so
# serving/tests across processes never re-run the blocking search for a
# shape already solved.  Override the location with REPRO_TILE_CACHE
# (set it to an empty string to disable persistence).

_TILE_CACHE_ENV = "REPRO_TILE_CACHE"
_TILE_CACHE_DEFAULT = os.path.join(
    os.path.expanduser("~"), ".cache", "repro-interstellar",
    "matmul_tiles.json",
)
# Bump whenever the search or alignment logic changes, so stale entries from
# an older algorithm are never served (the key embeds this token).
_TILE_CACHE_SCHEMA = "v1"


def _tile_cache_path() -> str | None:
    path = os.environ.get(_TILE_CACHE_ENV, _TILE_CACHE_DEFAULT)
    return path or None


def _store_tile(path: str, key: str, t: MatmulTiles) -> None:
    """Read-merge-replace so concurrent processes lose at most one entry;
    the rename keeps the file always parseable."""
    data = load_json_dict(path)
    data[key] = [t.bm, t.bn, t.bk]
    try:
        atomic_write_json(path, data)
    except OSError:
        pass  # cache is best-effort; the search result is still returned


def _valid_cached_tile(
    t: MatmulTiles, M: int, N: int, K: int, vmem_bytes: int, dtype_bytes: int
) -> bool:
    """A cache entry is only served if it could have come out of the search:
    positive tile sides, (SUBLANES, LANES) hardware alignment, no side
    larger than the padded problem, and a double-buffered working set that
    fits the VMEM budget.  Anything else — a corrupt file, a stale schema
    that slipped through the key, a hand-edited entry — would otherwise be
    handed straight to every decode GEMM as a Pallas BlockSpec (``bm=0``
    divides by zero inside the kernel grid; a misaligned or oversized tile
    fails lowering or silently spills)."""
    if not all(
        isinstance(v, int) and v > 0 for v in (t.bm, t.bn, t.bk)
    ):
        return False
    if t.bm % SUBLANES or t.bn % LANES or t.bk % LANES:
        return False
    if (
        t.bm > round_up(M, SUBLANES)
        or t.bn > round_up(N, LANES)
        or t.bk > round_up(K, LANES)
    ):
        return False
    if t.vmem_bytes(dtype_bytes) > vmem_bytes:
        # the minimal aligned tile is servable even when a degenerate
        # vmem budget can't fit it — the search itself can do no better,
        # and rejecting it would re-search (and re-store) forever
        return (t.bm, t.bn, t.bk) == (SUBLANES, LANES, LANES)
    return True


@functools.lru_cache(maxsize=512)
def choose_matmul_tiles(
    M: int,
    N: int,
    K: int,
    vmem_bytes: int = en.TPU_VMEM_BYTES // 4,
    dtype_bytes: int = 2,
) -> MatmulTiles:
    """Blocking-search-backed tile choice, aligned to MXU/VREG geometry.

    Runs the paper's blocking search on the (VMEM, HBM) 2-level hierarchy of
    the matmul nest, then aligns the winning tile to (8, 128) register tiling
    and the 128x128 MXU.  Falls back to a bandwidth-balanced analytic tile
    for degenerate shapes.  Results persist to an on-disk cache keyed by
    (M, N, K, vmem_bytes, dtype_bytes) — see REPRO_TILE_CACHE above — with
    the lru_cache as the in-process layer.  Cached values are validated
    (positivity, sublane/lane alignment, VMEM fit) before being served; a
    corrupt or stale entry falls back to the search and is overwritten.
    """
    path = _tile_cache_path()
    key = f"{_TILE_CACHE_SCHEMA}:{M},{N},{K},{vmem_bytes},{dtype_bytes}"
    if path:
        got = load_json_dict(path).get(key)
        if isinstance(got, (list, tuple)) and len(got) == 3:
            try:
                t = MatmulTiles(bm=int(got[0]), bn=int(got[1]), bk=int(got[2]))
            except (TypeError, ValueError):
                t = None
            if t is not None and _valid_cached_tile(
                t, M, N, K, vmem_bytes, dtype_bytes
            ):
                return t
        # fall through: the search result below overwrites the bad entry
    t = _search_matmul_tiles(M, N, K, vmem_bytes, dtype_bytes)
    if path:
        _store_tile(path, key, t)
    return t


def _search_matmul_tiles(
    M: int, N: int, K: int, vmem_bytes: int, dtype_bytes: int
) -> MatmulTiles:
    # Pad tiny dims up to hardware alignment before searching.
    Mp, Np, Kp = round_up(M, SUBLANES), round_up(N, LANES), round_up(K, LANES)
    nest = matmul_nest("mm", M=Mp, N=Np, K=Kp)
    levels = (
        MemLevel("VMEM", capacity_bytes=vmem_bytes, double_buffered=True),
        MemLevel("HBM", capacity_bytes=None),
    )
    try:
        res = search_blocking(
            nest, levels, ArraySpec(dims=(1,)),
            Dataflow(assigns=((),)), beam=12,
        )
        tile = res.best.schedule.cum_tile(0, include_spatial=False)
        bm, bn, bk = tile["M"], tile["N"], tile["K"]
    except ValueError:
        bm, bn, bk = MXU_DIM, MXU_DIM, MXU_DIM
    # Hardware alignment: sublane/lane multiples, MXU-friendly, clamp to dim.
    bm = min(Mp, max(SUBLANES, round_down_pow2(bm, SUBLANES)))
    bn = min(Np, max(LANES, round_down_pow2(bn, LANES)))
    bk = min(Kp, max(LANES, round_down_pow2(bk, LANES)))
    t = MatmulTiles(bm=bm, bn=bn, bk=bk)

    # Shrink (bm first, then bn/bk) until the working set fits, keeping the
    # hardware alignment the cache validator enforces (halving 24 -> 12
    # would break the SUBLANES multiple).
    def _half(v: int, align: int) -> int:
        return max(align, (v // 2) // align * align)

    while t.vmem_bytes(dtype_bytes) > vmem_bytes and t.bm > SUBLANES:
        t = MatmulTiles(bm=_half(t.bm, SUBLANES), bn=t.bn, bk=t.bk)
    while t.vmem_bytes(dtype_bytes) > vmem_bytes and t.bk > LANES:
        t = MatmulTiles(bm=t.bm, bn=t.bn, bk=_half(t.bk, LANES))
    while t.vmem_bytes(dtype_bytes) > vmem_bytes and t.bn > LANES:
        t = MatmulTiles(bm=t.bm, bn=_half(t.bn, LANES), bk=t.bk)
    return t


# --------------------------------------------------------------- mesh scale --


@dataclasses.dataclass(frozen=True)
class MeshDataflow:
    """Assignment of model loops to mesh axes = pod-scale spatial unrolling.

    axes: mesh axis name -> tuple of (loop name, shard factor), nearest-first
    (replication at pod scale, e.g. ('batch', 8)('seq', 2) on 'data').
    """

    axes: tuple[tuple[str, tuple[tuple[str, int], ...]], ...]

    def label(self) -> str:
        return " | ".join(
            f"{ax}:" + ("".join(d for d, _ in loops) or "-")
            for ax, loops in self.axes
        )


def mesh_dataflow_cost(
    *,
    flops: float,
    hbm_bytes: float,
    collective_bytes: float,
    n_chips: int,
    ici_links: int = 2,
) -> dict[str, float]:
    """The paper's E = sum acc_i * e_i at pod scale, in seconds: the three
    roofline terms (compute / memory / collective) under v5e constants."""
    return {
        "compute_s": flops / (n_chips * en.TPU_PEAK_FLOPS_BF16),
        "memory_s": hbm_bytes / (n_chips * en.TPU_HBM_BYTES_PER_S),
        "collective_s": collective_bytes
        / (n_chips * ici_links * en.TPU_ICI_BYTES_PER_S_PER_LINK),
    }


def dominant_term(cost: dict[str, float]) -> str:
    return max(cost, key=lambda k: cost[k])
