"""Loop-nest IR: the paper's seven nested CONV loops, generalized.

The paper (§3) observes that every dense DNN accelerator computes the same
seven-deep loop nest

    for b, k, c, y, x, fy, fx:
        O[b][k][x][y] += I[b][c][x+fx][y+fy] * W[k][c][fx][fy]

and that the accelerator design space is exactly the space of loop
transformations (blocking/reorder/spatial-unroll) of this nest.  We represent
the nest as a set of named dims with bounds, plus per-tensor *projections*
(which dims index each tensor).  Sliding-window reuse (the x/fx and y/fy
coupling) is expressed as `coupled` dim pairs: the tensor's extent along the
base dim is `tile(x) + tile(fx) - 1` (stride handled at projection time).

FC layers, matmuls, attention contractions, and MoE expert matmuls are the
same nest with some bounds set to 1 (paper §3) or with renamed dims, so a
single IR covers the paper's CONV/FC benchmarks *and* the LM-framework ops
that the TPU mapper schedules.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Mapping

# Canonical dim names for the 7-loop CONV nest (paper Algorithm 1).
CONV_DIMS = ("B", "K", "C", "Y", "X", "FY", "FX")


@dataclasses.dataclass(frozen=True)
class TensorRef:
    """A tensor touched by the nest.

    dims:     dims that directly index the tensor (affine, stride-1 in tiles).
    coupled:  mapping base_dim -> (filter_dim, stride): tensor extent along
              base_dim is  stride*(tile_base-1) + tile_filter  (halo).
    output:   True if the tensor is accumulated into (reduction semantics).
    """

    name: str
    dims: tuple[str, ...]
    coupled: Mapping[str, tuple[str, int]] = dataclasses.field(default_factory=dict)
    output: bool = False

    @property
    def relevant(self) -> frozenset[str]:
        """Dims whose iteration changes which tensor elements are touched."""
        rel = set(self.dims)
        for base, (filt, _stride) in self.coupled.items():
            rel.add(base)
            rel.add(filt)
        return frozenset(rel)

    def key(self) -> tuple:
        """Hashable structural identity (name excluded): used by search memos
        so repeated layer shapes in a network are solved once."""
        return (
            self.dims,
            tuple(sorted(self.coupled.items())),
            self.output,
        )

    def tile_elems(self, tile: Mapping[str, int]) -> int:
        """Elements of this tensor needed for a given iteration-space tile."""
        n = 1
        handled: set[str] = set()
        for base, (filt, stride) in self.coupled.items():
            n *= stride * (tile.get(base, 1) - 1) + tile.get(filt, 1)
            handled.add(base)
            handled.add(filt)
        for d in self.dims:
            if d not in handled:
                n *= tile.get(d, 1)
        return n


@dataclasses.dataclass(frozen=True)
class LoopNest:
    """A perfectly-nested dense contraction."""

    name: str
    bounds: Mapping[str, int]              # dim -> extent
    tensors: tuple[TensorRef, ...]
    reduction_dims: frozenset[str]         # dims summed over (irrelevant to O)

    def __post_init__(self):
        for t in self.tensors:
            for d in t.relevant:
                if d not in self.bounds:
                    raise ValueError(f"tensor {t.name} uses unknown dim {d}")
        outs = [t for t in self.tensors if t.output]
        if len(outs) != 1:
            raise ValueError("exactly one output tensor required")

    def key(self) -> tuple:
        """Hashable structural identity: nests with equal keys have identical
        search spaces and costs regardless of `name` (networks repeat layer
        shapes, so the optimizer's memo solves each shape once)."""
        return (
            tuple(self.bounds.items()),
            tuple(t.key() for t in self.tensors),
            tuple(sorted(self.reduction_dims)),
        )

    @property
    def dims(self) -> tuple[str, ...]:
        return tuple(self.bounds.keys())

    @property
    def output(self) -> TensorRef:
        return next(t for t in self.tensors if t.output)

    @property
    def inputs(self) -> tuple[TensorRef, ...]:
        return tuple(t for t in self.tensors if not t.output)

    def macs(self) -> int:
        """Total multiply-accumulates = product of all loop bounds."""
        return math.prod(self.bounds.values())

    def tensor(self, name: str) -> TensorRef:
        for t in self.tensors:
            if t.name == name:
                return t
        raise KeyError(name)

    def total_elems(self, name: str) -> int:
        return self.tensor(name).tile_elems(self.bounds)


def conv_nest(
    name: str,
    *,
    B: int,
    K: int,
    C: int,
    X: int,
    Y: int,
    FX: int,
    FY: int,
    stride: int = 1,
) -> LoopNest:
    """The paper's Algorithm-1 CONV nest.  X/Y are *output* extents."""
    bounds = {"B": B, "K": K, "C": C, "Y": Y, "X": X, "FY": FY, "FX": FX}
    I = TensorRef(
        "I",
        dims=("B", "C", "X", "Y", "FX", "FY"),
        coupled={"X": ("FX", stride), "Y": ("FY", stride)},
    )
    W = TensorRef("W", dims=("K", "C", "FX", "FY"))
    O = TensorRef("O", dims=("B", "K", "X", "Y"), output=True)
    return LoopNest(
        name=name,
        bounds=bounds,
        tensors=(I, W, O),
        reduction_dims=frozenset({"C", "FX", "FY"}),
    )


def fc_nest(name: str, *, B: int, C: int, K: int) -> LoopNest:
    """FC layer = CONV with X=Y=FX=FY=1 (paper §3): O[b,k] += I[b,c] W[k,c]."""
    return conv_nest(name, B=B, K=K, C=C, X=1, Y=1, FX=1, FY=1)


def matmul_nest(name: str, *, M: int, N: int, K: int) -> LoopNest:
    """Plain GEMM O[m,n] += A[m,k] B[k,n] — used by the TPU kernel mapper."""
    bounds = {"M": M, "N": N, "K": K}
    A = TensorRef("A", dims=("M", "K"))
    Bt = TensorRef("B", dims=("K", "N"))
    O = TensorRef("O", dims=("M", "N"), output=True)
    return LoopNest(
        name=name,
        bounds=bounds,
        tensors=(A, Bt, O),
        reduction_dims=frozenset({"K"}),
    )


def depthwise_nest(
    name: str, *, B: int, C: int, X: int, Y: int, FX: int, FY: int, stride: int = 1
) -> LoopNest:
    """Depthwise CONV (MobileNet): one filter per channel, no C-reduction.

    Modeled as the 7-loop nest with the channel dim acting as K (parallel) and
    C-loop = 1: O[b,k,x,y] += I[b,k,x+fx,y+fy] * W[k,fx,fy].
    """
    bounds = {"B": B, "K": C, "Y": Y, "X": X, "FY": FY, "FX": FX}
    I = TensorRef(
        "I",
        dims=("B", "K", "X", "Y", "FX", "FY"),
        coupled={"X": ("FX", stride), "Y": ("FY", stride)},
    )
    W = TensorRef("W", dims=("K", "FX", "FY"))
    O = TensorRef("O", dims=("B", "K", "X", "Y"), output=True)
    return LoopNest(
        name=name,
        bounds=bounds,
        tensors=(I, W, O),
        reduction_dims=frozenset({"FX", "FY"}),
    )


def divisors(n: int) -> list[int]:
    """Sorted divisors of n (used throughout blocking search)."""
    small, large = [], []
    i = 1
    while i * i <= n:
        if n % i == 0:
            small.append(i)
            if i != n // i:
                large.append(n // i)
        i += 1
    return small + large[::-1]
