"""Paper benchmark networks (§6.3): 4 CNNs, 3 LSTMs, 2 MLPs as loop nests.

CNNs at batch 16, MLPs at batch 128, matching the paper.  LSTM-M/L are the
Google seq2seq models with embedding sizes 500/1000; a cell step computes the
4-gate matmul [x;h](2E) x (2E,4E).  RHN (Recurrent Highway Network) uses the
published depth-10 cell with hidden 830 ("Variant A" on PTB).  MLPs follow
PRIME's benchmark suite.

Dims follow paper Algorithm 1: X/Y are OUTPUT extents; FC layers use only
(B, C, K) with the rest 1.
"""

from __future__ import annotations

from repro.core.loopnest import LoopNest, conv_nest, depthwise_nest, fc_nest


def alexnet(batch: int = 16) -> list[LoopNest]:
    B = batch
    return [
        conv_nest("conv1", B=B, K=96, C=3, X=55, Y=55, FX=11, FY=11, stride=4),
        conv_nest("conv2", B=B, K=256, C=96, X=27, Y=27, FX=5, FY=5),
        conv_nest("conv3", B=B, K=384, C=256, X=13, Y=13, FX=3, FY=3),
        conv_nest("conv4", B=B, K=384, C=384, X=13, Y=13, FX=3, FY=3),
        conv_nest("conv5", B=B, K=256, C=384, X=13, Y=13, FX=3, FY=3),
        fc_nest("fc6", B=B, C=9216, K=4096),
        fc_nest("fc7", B=B, C=4096, K=4096),
        fc_nest("fc8", B=B, C=4096, K=1000),
    ]


def alexnet_conv3(batch: int = 16) -> LoopNest:
    return alexnet(batch)[2]


def vgg16(batch: int = 16) -> list[LoopNest]:
    B = batch
    cfg = [  # (K, C, X=Y)
        (64, 3, 224), (64, 64, 224),
        (128, 64, 112), (128, 128, 112),
        (256, 128, 56), (256, 256, 56), (256, 256, 56),
        (512, 256, 28), (512, 512, 28), (512, 512, 28),
        (512, 512, 14), (512, 512, 14), (512, 512, 14),
    ]
    nets = [
        conv_nest(f"conv{i+1}", B=B, K=k, C=c, X=x, Y=x, FX=3, FY=3)
        for i, (k, c, x) in enumerate(cfg)
    ]
    nets += [
        fc_nest("fc14", B=B, C=25088, K=4096),
        fc_nest("fc15", B=B, C=4096, K=4096),
        fc_nest("fc16", B=B, C=4096, K=1000),
    ]
    return nets


def googlenet(batch: int = 16) -> list[LoopNest]:
    """Representative GoogLeNet layers incl. the paper's 4C3R example
    (inception-4c 3x3-reduce: 14x14x512 -> 128 via 1x1)."""
    B = batch
    return [
        conv_nest("conv1", B=B, K=64, C=3, X=112, Y=112, FX=7, FY=7, stride=2),
        conv_nest("conv2_red", B=B, K=64, C=64, X=56, Y=56, FX=1, FY=1),
        conv_nest("conv2", B=B, K=192, C=64, X=56, Y=56, FX=3, FY=3),
        conv_nest("3a_1x1", B=B, K=64, C=192, X=28, Y=28, FX=1, FY=1),
        conv_nest("3a_3x3", B=B, K=128, C=96, X=28, Y=28, FX=3, FY=3),
        conv_nest("4c_1x1", B=B, K=128, C=512, X=14, Y=14, FX=1, FY=1),
        conv_nest("4c3r", B=B, K=128, C=512, X=14, Y=14, FX=1, FY=1),
        conv_nest("4c_3x3", B=B, K=256, C=128, X=14, Y=14, FX=3, FY=3),
        conv_nest("5b_3x3", B=B, K=384, C=192, X=7, Y=7, FX=3, FY=3),
        fc_nest("fc", B=B, C=1024, K=1000),
    ]


def googlenet_4c3r(batch: int = 16) -> LoopNest:
    return next(n for n in googlenet(batch) if n.name == "4c3r")


def mobilenet(batch: int = 16) -> list[LoopNest]:
    """MobileNet v1 (1.0, 224): depthwise-separable stacks."""
    B = batch
    nets = [conv_nest("conv1", B=B, K=32, C=3, X=112, Y=112, FX=3, FY=3, stride=2)]
    # (C, X_out, stride) for each dw/pw pair
    cfg = [
        (32, 112, 1, 64), (64, 56, 2, 128), (128, 56, 1, 128),
        (128, 28, 2, 256), (256, 28, 1, 256), (256, 14, 2, 512),
        (512, 14, 1, 512), (512, 14, 1, 512), (512, 14, 1, 512),
        (512, 14, 1, 512), (512, 14, 1, 512), (512, 7, 2, 1024),
        (1024, 7, 1, 1024),
    ]
    for i, (c, x, s, k) in enumerate(cfg):
        nets.append(depthwise_nest(f"dw{i+2}", B=B, C=c, X=x, Y=x, FX=3, FY=3, stride=s))
        nets.append(conv_nest(f"pw{i+2}", B=B, K=k, C=c, X=x, Y=x, FX=1, FY=1))
    nets.append(fc_nest("fc", B=B, C=1024, K=1000))
    return nets


def lstm(name: str, embed: int, batch: int = 1, steps: int = 1) -> list[LoopNest]:
    """One LSTM cell step: [x;h] (2E) x (2E, 4E) gate matmul per step."""
    return [
        fc_nest(f"{name}_gates", B=batch * steps, C=2 * embed, K=4 * embed)
    ]


def lstm_m(batch: int = 1) -> list[LoopNest]:
    return lstm("lstm_m", 500, batch)


def lstm_l(batch: int = 1) -> list[LoopNest]:
    return lstm("lstm_l", 1000, batch)


def rhn(batch: int = 1) -> list[LoopNest]:
    """Recurrent Highway Network, depth-10, hidden 830 (Zilly et al.)."""
    H = 830
    layers = [fc_nest("rhn_in", B=batch, C=2 * H, K=2 * H)]
    layers += [fc_nest(f"rhn_d{i}", B=batch, C=H, K=2 * H) for i in range(9)]
    return layers


def mlp_m(batch: int = 128) -> list[LoopNest]:
    """PRIME MLP-M: 784-500-250-10."""
    B = batch
    return [
        fc_nest("fc1", B=B, C=784, K=500),
        fc_nest("fc2", B=B, C=500, K=250),
        fc_nest("fc3", B=B, C=250, K=10),
    ]


def mlp_l(batch: int = 128) -> list[LoopNest]:
    """PRIME MLP-L: 784-1500-1000-500-10."""
    B = batch
    return [
        fc_nest("fc1", B=B, C=784, K=1500),
        fc_nest("fc2", B=B, C=1500, K=1000),
        fc_nest("fc3", B=B, C=1000, K=500),
        fc_nest("fc4", B=B, C=500, K=10),
    ]


# ------------------------------------------------------------- DSE suite ----
# Scaled sweep workloads for the resource-allocation DSE (core/dse.py, paper
# Fig 10-12): one representative per network class, with bounds chosen so a
# full (hierarchy x layer x tiling x order) sweep finishes in benchmark
# wall-clock while keeping the paper's shape signatures (deep conv stacks
# with repeated layer shapes; wide single-matmul LSTM gates; tapering MLP).


def dse_cnn(batch: int = 4) -> list[LoopNest]:
    """Compact conv stack in the AlexNet/VGG mold (repeated mid-layers)."""
    B = batch
    return [
        conv_nest("c1", B=B, K=32, C=8, X=28, Y=28, FX=3, FY=3),
        conv_nest("c2", B=B, K=64, C=32, X=14, Y=14, FX=3, FY=3),
        conv_nest("c2b", B=B, K=64, C=32, X=14, Y=14, FX=3, FY=3),
        conv_nest("c3", B=B, K=64, C=64, X=7, Y=7, FX=3, FY=3),
        fc_nest("fc", B=B, C=3136, K=256),
    ]


def dse_lstm(batch: int = 4) -> list[LoopNest]:
    """LSTM-M-shaped gate matmul (paper: Google seq2seq embed 500) at a
    sweep-tractable embedding."""
    return lstm("dse_lstm", embed=256, batch=batch)


def dse_mlp(batch: int = 32) -> list[LoopNest]:
    """PRIME-style tapering MLP at sweep-tractable widths."""
    B = batch
    return [
        fc_nest("fc1", B=B, C=784, K=512),
        fc_nest("fc2", B=B, C=512, K=256),
        fc_nest("fc3", B=B, C=256, K=16),
    ]


DSE_SUITE = {
    "cnn": dse_cnn,
    "lstm": dse_lstm,
    "mlp": dse_mlp,
}


PAPER_BENCHMARKS = {
    "alexnet": alexnet,
    "vgg16": vgg16,
    "googlenet": googlenet,
    "mobilenet": mobilenet,
    "lstm_m": lstm_m,
    "lstm_l": lstm_l,
    "rhn": rhn,
    "mlp_m": mlp_m,
    "mlp_l": mlp_l,
}
