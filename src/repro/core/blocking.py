"""Loop-blocking search (paper §3.1/§6.1): the dominant knob.

Given a hardware skeleton (memory levels + PE array) and a dataflow (spatial
unrolling), search per-level tiling factors and per-level loop orders that
minimize the analytical energy.  The paper performs "a conservatively pruned
search over the full design space guided by domain-specific knowledge"; we
implement the same style:

  * per-level tile enumeration over divisors with monotone capacity pruning,
  * stratified subsampling when a level's choice count explodes (keeps both
    buffer-filling and tiny tiles - the former usually win, Obs 1),
  * loop orders chosen greedily per level from stationarity templates
    (irrelevant-dims-innermost per tensor) or exhaustive permutations when
    few dims are active.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
import random
from typing import Iterator, Sequence

import numpy as np

from repro.core.costmodel import BatchedCostModel, BatchOverflowError
from repro.core.dataflow import Dataflow
from repro.core.energy import CostTable, Report, evaluate
from repro.core.loopnest import LoopNest, TensorRef, divisors
from repro.core.reuse import analyze
from repro.core.schedule import ArraySpec, MemLevel, Schedule


# ------------------------------------------------------------------ orders --


def order_candidates(
    nest: LoopNest, active: Sequence[str], exhaustive_limit: int = 4
) -> list[tuple[str, ...]]:
    """Candidate loop orders (innermost-first) for one level.

    Only dims with trip > 1 ("active") matter; inactive dims are appended.
    If few are active, try all permutations; otherwise use stationarity
    templates: for each tensor, its irrelevant dims innermost (so it stays
    resident below), largest-trip-last inside groups.
    """
    inactive = [d for d in nest.dims if d not in active]
    if len(active) <= exhaustive_limit:
        return [tuple(p) + tuple(inactive) for p in itertools.permutations(active)]
    cands: list[tuple[str, ...]] = []
    seen = set()
    for t in nest.tensors:
        irr = [d for d in active if d not in t.relevant]
        rel = [d for d in active if d in t.relevant]
        cand = tuple(irr + rel + inactive)
        if cand not in seen:
            seen.add(cand)
            cands.append(cand)
    default = tuple(active) + tuple(inactive)
    if default not in seen:
        cands.append(default)
    return cands


def optimize_orders(schedule: Schedule, table: CostTable | None = None) -> Report:
    """Greedy per-level order selection, innermost level first, evaluating the
    full analytical energy at each step."""
    table = table or CostTable.asic_28nm(schedule)
    best = evaluate(schedule, table)
    orders = list(schedule.order)
    for l in range(len(schedule.levels)):
        active = [d for d in schedule.nest.dims if schedule.tiling[d][l] > 1]
        if not active:
            continue
        for cand in order_candidates(schedule.nest, active):
            trial_orders = list(orders)
            trial_orders[l] = cand
            trial = dataclasses.replace(schedule, order=tuple(trial_orders))
            rep = evaluate(trial, table)
            if rep.energy_pj < best.energy_pj:
                best = rep
                orders = trial_orders
    return best


# ------------------------------------------------------------------ tiling --


def _tile_choices(
    nest: LoopNest,
    rem: dict[str, int],
    base_tile: dict[str, int],
    capacity_words: int | None,
    double: bool,
    max_choices: int,
) -> list[dict[str, int]]:
    """Enumerate per-dim divisor factors whose cumulative footprint fits."""
    dims = sorted(rem, key=lambda d: -rem[d])
    out: list[dict[str, int]] = []

    def footprint(tile: dict[str, int]) -> int:
        full = {d: base_tile[d] * tile.get(d, 1) for d in nest.dims}
        words = sum(t.tile_elems(full) for t in nest.tensors)
        return words * (2 if double else 1)

    def rec(i: int, tile: dict[str, int]):
        if i == len(dims):
            out.append(dict(tile))
            return
        d = dims[i]
        for f in divisors(rem[d]):
            tile[d] = f
            if capacity_words is not None and footprint(tile) > capacity_words:
                del tile[d]
                break  # factors ascend; larger only grows footprint
            rec(i + 1, tile)
        tile.pop(d, None)

    rec(0, {})
    if len(out) > max_choices:
        # stratified subsample by footprint: keep spread from tiny to full
        out.sort(key=footprint)
        out = [out[i] for i in _strided_indices(len(out), max_choices)]
    return out


def iter_blockings(
    nest: LoopNest,
    levels: Sequence[MemLevel],
    array: ArraySpec,
    dataflow: Dataflow,
    word_bytes: int = 2,
    max_choices_per_level: int = 64,
    seed: int = 0,
) -> Iterator[Schedule]:
    """Yield valid blocked schedules (default orders; caller optimizes).

    Per-level choices are deterministically shuffled so that a truncated
    consumer (max_evals) still samples the whole space instead of a DFS
    corner.
    """
    L = len(levels)
    rng = random.Random(seed)
    spatial = dataflow.assigns
    sp_factor = {d: dataflow.factor(d) for d in nest.dims}
    top_rem = {
        d: math.ceil(nest.bounds[d] / sp_factor[d]) for d in nest.dims
    }
    boundary = next(
        (i for i, lvl in enumerate(levels) if not lvl.per_pe), len(levels)
    )

    def rec(l: int, rem: dict[str, int], chosen: list[dict[str, int]]):
        if l == L - 1:  # top level takes the remainder
            tiling = {}
            for d in nest.dims:
                per = [chosen[i].get(d, 1) for i in range(L - 1)] + [rem[d]]
                tiling[d] = tuple(per)
            yield Schedule(
                nest=nest,
                levels=tuple(levels),
                tiling=tiling,
                order=tuple(tuple(nest.dims) for _ in range(L)),
                array=array,
                spatial=spatial,
                word_bytes=word_bytes,
            )
            return
        cap = levels[l].capacity_bytes
        cap_words = None if cap is None else cap // word_bytes
        include_sp = l >= boundary
        base = {d: 1 for d in nest.dims}
        for i in range(l):
            for d in nest.dims:
                base[d] *= chosen[i].get(d, 1)
        if include_sp:
            for d in nest.dims:
                base[d] *= sp_factor[d]
        tiles = _tile_choices(
            nest, rem, base, cap_words, levels[l].double_buffered, max_choices_per_level
        )
        rng.shuffle(tiles)
        for tile in tiles:
            new_rem = {d: rem[d] // tile.get(d, 1) for d in nest.dims}
            yield from rec(l + 1, new_rem, chosen + [tile])

    yield from rec(0, top_rem, [])


def _strided_indices(n: int, k: int) -> list[int]:
    """<= k evenly-spaced indices into a length-n sequence (stratified
    subsample; callers sort by footprint first so the stride keeps a spread
    from tiny to full tiles).  Safe for k == 1 and k >= n."""
    if k >= n:
        return list(range(n))
    if k <= 1:
        return [0]
    return sorted({round(i * (n - 1) / (k - 1)) for i in range(k)})


def _footprint_words(
    nest: LoopNest, dims: tuple[str, ...], tiles: np.ndarray
) -> np.ndarray:
    """Vectorized sum-over-tensors tile footprint (words) for an (m, D)
    array of iteration-space tiles — the NumPy form of the `footprint`
    closure in `_tile_choices`."""
    idx = {d: i for i, d in enumerate(dims)}
    words = np.zeros(tiles.shape[0], dtype=np.int64)
    for t in nest.tensors:
        n = np.ones(tiles.shape[0], dtype=np.int64)
        handled: set[str] = set()
        for base, (filt, stride) in t.coupled.items():
            n = n * (stride * (tiles[:, idx[base]] - 1) + tiles[:, idx[filt]])
            handled.add(base)
            handled.add(filt)
        for d in t.dims:
            if d not in handled:
                n = n * tiles[:, idx[d]]
        words += n
    return words


def order_templates(nest: LoopNest) -> list[tuple[str, ...]]:
    """Uniform (all-levels) stationarity order templates: for each tensor,
    its irrelevant dims innermost so it stays resident below, plus the
    default order.  Trip-1 dims are transparent to stationarity, so these
    templates cover the classic weight/output/input-stationary orderings for
    every tiling at once — the frontier enumeration in
    :func:`enumerate_frontier` crosses tilings with them."""
    cands: list[tuple[str, ...]] = []
    seen: set[tuple[str, ...]] = set()
    for t in nest.tensors:
        irr = [d for d in nest.dims if d not in t.relevant]
        rel = [d for d in nest.dims if d in t.relevant]
        cand = tuple(irr + rel)
        if cand not in seen:
            seen.add(cand)
            cands.append(cand)
    if tuple(nest.dims) not in seen:
        cands.append(tuple(nest.dims))
    return cands


def enumerate_frontier(
    nest: LoopNest,
    levels: Sequence[MemLevel],
    array: ArraySpec,
    dataflow: Dataflow,
    max_choices_per_level: int = 48,
    word_bytes: int = 2,
    max_frontier: int = 32768,
) -> tuple[np.ndarray, np.ndarray]:
    """Enumerate a (tiling x order-template) candidate frontier as packed
    (n, L, D) tiling / order-index arrays for the batched cost engine.

    Same choice space as `iter_blockings`, but fully vectorized: per-level
    divisor cross-products, footprint filters and the stratified subsample
    all run as NumPy array ops, and no per-candidate Schedule object is ever
    constructed (deep hierarchies' cross-product frontiers would otherwise
    burn seconds of pure-Python object churn).  The running total is capped
    at ``max_frontier`` rows by an evenly-strided subsample over the
    footprint-stratified choice sets.

    The hierarchy-batched DSE sweep (core/dse.py) enumerates ONE frontier
    per nest against the most permissive capacities of a hierarchy family,
    prices it under every member's cost table in a single
    ``evaluate_hierarchies`` call, and masks per-member feasibility with the
    vectorized footprints — so pass the family's per-level maximum
    capacities in ``levels``.
    """
    L = len(levels)
    dims = tuple(nest.dims)
    D = len(dims)
    dim_idx = {d: i for i, d in enumerate(dims)}
    tmpls = order_templates(nest)
    K = len(tmpls)
    tmpl_rows = np.array(
        [[dim_idx[d] for d in o] for o in tmpls], dtype=np.int64
    )  # (K, D)

    sp = np.array([dataflow.factor(d) for d in dims], dtype=np.int64)
    top_rem = tuple(
        math.ceil(nest.bounds[d] / int(sp[j])) for j, d in enumerate(dims)
    )
    boundary = next(
        (i for i, lvl in enumerate(levels) if not lvl.per_pe), L
    )
    max_tilings = max(1, max_frontier // K)

    # Per-node choice sets, vectorized and memoized.  The cumulative base
    # tile at level l is fully determined by (l, rem) — base = top_rem/rem
    # (x spatial at shared levels) — so nodes reached along different paths
    # share their enumeration.
    _node_cache: dict[tuple[int, tuple[int, ...]], np.ndarray] = {}

    def node_choices(l: int, rem: tuple[int, ...]) -> np.ndarray:
        got = _node_cache.get((l, rem))
        if got is not None:
            return got
        # cross product of per-dim divisor factors, largest-dims first is
        # irrelevant here: footprints filter vectorized below
        grids = np.meshgrid(
            *[np.array(divisors(r), dtype=np.int64) for r in rem],
            indexing="ij",
        )
        combos = np.stack([g.ravel() for g in grids], axis=1)  # (m, D)
        base = np.array(
            [t // r for t, r in zip(top_rem, rem)], dtype=np.int64
        )
        if l >= boundary:
            base = base * sp
        words = _footprint_words(nest, dims, base[None, :] * combos)
        if levels[l].double_buffered:
            words = words * 2
        cap = levels[l].capacity_bytes
        if cap is not None:
            mask = words <= cap // word_bytes
            combos, words = combos[mask], words[mask]
        if len(combos) > max_choices_per_level:
            # stratified by footprint: keep spread from tiny to full tiles
            order = np.argsort(words, kind="stable")
            combos = combos[
                order[_strided_indices(len(combos), max_choices_per_level)]
            ]
        _node_cache[(l, rem)] = combos
        return combos

    # Level-synchronous frontier expansion: the whole partial-tiling frontier
    # advances one level per step, with choice sets shared across equal
    # remainders and the running total capped by an evenly-strided subsample
    # (choices are footprint-stratified, so the stride keeps the spread).
    prefix = np.empty((1, 0, D), dtype=np.int64)
    rems = np.array([top_rem], dtype=np.int64)
    for l in range(L - 1):
        uniq, inv = np.unique(rems, axis=0, return_inverse=True)
        parts_pre: list[np.ndarray] = []
        parts_rem: list[np.ndarray] = []
        for u_i in range(len(uniq)):
            choices = node_choices(l, tuple(int(x) for x in uniq[u_i]))
            if len(choices) == 0:
                continue  # dead branch: nothing fits this level
            pre = prefix[inv == u_i]
            k, m = len(pre), len(choices)
            tiled = np.tile(choices, (k, 1))
            parts_pre.append(
                np.concatenate(
                    [np.repeat(pre, m, axis=0), tiled[:, None, :]], axis=1
                )
            )
            parts_rem.append(uniq[u_i][None, :] // tiled)
        if not parts_pre:
            raise ValueError("no feasible blocking fits the memory hierarchy")
        prefix = np.concatenate(parts_pre)
        rems = np.concatenate(parts_rem)
        if len(prefix) > max_tilings:
            idx = _strided_indices(len(prefix), max_tilings)
            prefix, rems = prefix[idx], rems[idx]
    til = np.concatenate([prefix, rems[:, None, :]], axis=1)  # (m, L, D)
    m = til.shape[0]
    til = np.repeat(til, K, axis=0)                  # (m*K, L, D)
    odr = np.tile(
        np.repeat(tmpl_rows[:, None, :], L, axis=1), (m, 1, 1)
    )                                                # (m*K, L, D)
    return til, odr


@dataclasses.dataclass
class SearchResult:
    best: Report
    evaluated: int


def _level_energy(
    schedule: Schedule, table: CostTable, level: int
) -> float:
    """Energy contributed by accesses served at `level` (+ array hops when
    `level` is the array-feeding level).  Scalar oracle; the batched form is
    costmodel.BatchedCostModel.level_energy."""
    acc = analyze(schedule)
    e = acc.level_total(level) * table.level_pj[level]
    blevel = min(max(schedule.array_boundary, 1), len(schedule.levels) - 1)
    if level == blevel:
        e += sum(acc.hops.values()) * table.hop_pj
    return e


def _lb_elems(tensor: TensorRef, tile: dict[str, int]) -> int:
    """Lower bound on tile_elems that stays sound under any stride/halo
    configuration (min of the halo extent and the plain trip product)."""
    n = 1
    handled: set[str] = set()
    for base, (filt, stride) in tensor.coupled.items():
        b, f = tile.get(base, 1), tile.get(filt, 1)
        n *= min(stride * (b - 1) + f, b * f)
        handled.add(base)
        handled.add(filt)
    for d in tensor.dims:
        if d not in handled:
            n *= tile.get(d, 1)
    return n


def search_blocking(
    nest: LoopNest,
    levels: Sequence[MemLevel],
    array: ArraySpec,
    dataflow: Dataflow,
    table: CostTable | None = None,
    beam: int = 24,
    max_choices_per_level: int = 512,
    max_evals: int = 0,  # 0 = unlimited; else cap on mappings priced
    engine: str = "batched",
    prune: bool = True,
) -> SearchResult:
    """Top-down beam search with exact partial costs.

    Key property of the access model (reuse.py): the traffic served BY level l
    depends only on the tiling factors and loop orders at levels >= l (the
    child tile is then fixed by the remainder).  Choosing factors from the
    top (DRAM) inward therefore prices each level exactly when it is fixed —
    the paper's "domain-specific knowledge guided" pruned search made
    systematic.  A beam keeps the best partial hierarchies; per-level loop
    orders are optimized from stationarity templates as each level is fixed.

    The whole (tile x order) frontier of a level is priced in one batched
    call (costmodel.BatchedCostModel); `engine="scalar"` prices the same
    frontier through the scalar oracle instead (identical results, used for
    differential tests and benchmarks).  With `prune` a greedy dive first
    establishes an incumbent; beam expansions whose already-fixed cost plus
    an optimistic remainder (sound per-level traffic lower bounds + MAC
    energy) exceed it are skipped.  `max_evals > 0` bounds the total number
    of mappings priced (the search always keeps at least one candidate per
    level so it can complete).
    """
    L = len(levels)
    levels = tuple(levels)
    spatial = dataflow.assigns
    dims = tuple(nest.dims)
    D = len(dims)
    dim_idx = {d: i for i, d in enumerate(dims)}
    default_order = dims
    sp_factor = {d: dataflow.factor(d) for d in dims}
    full_rem = {d: math.ceil(nest.bounds[d] / sp_factor[d]) for d in dims}
    boundary = next((i for i, lvl in enumerate(levels) if not lvl.per_pe), L)
    tbl = table or CostTable.for_levels(levels)

    cm: BatchedCostModel | None = None
    if engine == "batched":
        try:
            cm = BatchedCostModel(
                nest, levels, array=array, spatial=spatial, table=tbl
            )
        except BatchOverflowError:
            cm = None  # fall back to the scalar oracle

    def sched_from(til: np.ndarray, odr: np.ndarray) -> Schedule:
        """Materialize a Schedule from (L, D) tiling/order-index matrices
        (values converted to Python ints so downstream scalar arithmetic
        stays arbitrary-precision)."""
        tiling = {
            d: tuple(int(til[l, j]) for l in range(L))
            for j, d in enumerate(dims)
        }
        order = tuple(
            tuple(dims[int(i)] for i in odr[l]) for l in range(L)
        )
        return Schedule(
            nest=nest, levels=levels, tiling=tiling, order=order,
            array=array, spatial=spatial,
        )

    # order tuple -> (D,) index row, cached (few distinct orders per search)
    _order_idx: dict[tuple, np.ndarray] = {}

    def order_row(order: tuple) -> np.ndarray:
        got = _order_idx.get(order)
        if got is None:
            got = _order_idx[order] = np.array(
                [dim_idx[d] for d in order], dtype=np.int64
            )
        return got

    # active-dims tuple -> candidate orders (order_candidates is pure)
    _ocands: dict[tuple, list] = {}

    def cands_for(active: tuple) -> list:
        got = _ocands.get(active)
        if got is None:
            got = _ocands[active] = (
                order_candidates(nest, list(active)) if active
                else [default_order]
            )
        return got

    def assemble(g_til, g_odr, sizes, cand_rows, level):
        """Stack per-group (L, D) matrices into per-row arrays, substituting
        each row's candidate order at `level`."""
        til = np.repeat(np.stack(g_til), sizes, axis=0)
        odr = np.repeat(np.stack(g_odr), sizes, axis=0)
        odr[:, level, :] = np.stack(cand_rows)
        return til, odr

    def price_level(til, odr, l) -> np.ndarray:
        if cm is not None:
            return cm.level_energy(til, odr, l)
        return np.array(
            [_level_energy(sched_from(til[i], odr[i]), tbl, l)
             for i in range(til.shape[0])]
        )

    def price_full(til, odr) -> np.ndarray:
        if cm is not None:
            return cm.energy(til, odr)
        return np.array(
            [evaluate(sched_from(til[i], odr[i]), tbl).energy_pj
             for i in range(til.shape[0])]
        )

    # ------------------------------------------------ pruning lower bounds --
    # Sound optimistic completion cost for a partial hierarchy.  Two facts:
    #   * stationarity only absorbs IRRELEVANT loops, so for tensor T the
    #     reload count at any unfixed level is at least the product of T's
    #     relevant trips among the already-fixed outer factors (rvec), and
    #   * per reload, covering the remainder region with child tiles streams
    #     at least elems_T(region) words through the level (per PE for
    #     per-PE levels).
    # Hence  lb(l) = pj[l] * mult(l) * sum_T rvec_T * elems_T(region)  and
    # MAC energy is fixed by the nest.
    used_pes = dataflow.used_pes()
    mac_e = nest.macs() * tbl.mac_pj
    rel_dims = [t.relevant for t in nest.tensors]
    T = len(nest.tensors)

    def _tile_rvec(tile: dict[str, int]) -> tuple[int, ...]:
        return tuple(
            math.prod(f for d, f in tile.items() if d in rel_dims[t_i])
            for t_i in range(T)
        )

    _region_cache: dict[tuple, tuple] = {}

    def _region_words(l: int, rem: dict[str, int]) -> tuple[int, tuple[int, ...]]:
        """(mult, per-tensor elems of the level-l remainder region)."""
        per_pe_ish = l < max(boundary, 1)
        key = (per_pe_ish, tuple(rem[d] for d in dims))
        got = _region_cache.get(key)
        if got is None:
            region = {
                d: rem[d] * (1 if per_pe_ish else sp_factor[d]) for d in dims
            }
            got = _region_cache[key] = tuple(
                _lb_elems(t, region) for t in nest.tensors
            )
        return (used_pes if per_pe_ish else 1), got

    # Level 0 admits a second, usually stronger bound: whatever the blocking,
    # the innermost trip>1 temporal loop breaks stationarity for every tensor
    # its dim is relevant to, and each dim is relevant to >= k0 tensors — so
    # at least k0 tensors stream one word per MAC-boundary trip.
    _trips_total = math.prod(full_rem.values())
    _k0 = min(
        (sum(d in r for r in rel_dims) for d in dims if full_rem[d] > 1),
        default=T,
    )
    _lb0_const = _k0 * _trips_total * used_pes * tbl.level_pj[0]

    def lb_level(l: int, rem: dict[str, int], rvec: tuple[int, ...]) -> float:
        mult, words = _region_words(l, rem)
        e = sum(r * w for r, w in zip(rvec, words)) * mult * tbl.level_pj[l]
        return max(e, _lb0_const) if l == 0 else e

    def lb_below(l: int, rem: dict[str, int], rvec: tuple[int, ...]) -> float:
        return sum(lb_level(lp, rem, rvec) for lp in range(l))

    # Per-(rem, level-choice) expansion metadata, memoized across entries,
    # levels and the dive/main passes:
    #   tiles_for(rem) -> [(tile_vec, tile_rvec, active, new_rem, rem_key)]
    #   footprint of the level-(l-1) child tile keyed by (shared?, new_rem)
    _tile_cache: dict[tuple, list] = {}
    _foot_cache: dict[tuple, int] = {}

    def tiles_for(rem: dict[str, int]) -> list:
        key = tuple(rem[d] for d in dims)
        got = _tile_cache.get(key)
        if got is None:
            base = {d: 1 for d in dims}
            got = []
            for tile in _tile_choices(
                nest, rem, base, None, False, max_choices_per_level
            ):
                tile_vec = np.array(
                    [tile.get(d, 1) for d in dims], dtype=np.int64
                )
                new_rem = {d: rem[d] // tile.get(d, 1) for d in dims}
                active = tuple(d for d in dims if tile.get(d, 1) > 1)
                got.append(
                    (tile_vec, _tile_rvec(tile), active, new_rem,
                     tuple(new_rem[d] for d in dims))
                )
            _tile_cache[key] = got
        return got

    def child_words(child_is_shared: bool, new_rem: dict, rem_key: tuple) -> int:
        key = (child_is_shared, rem_key)
        got = _foot_cache.get(key)
        if got is None:
            child_tile = {
                d: new_rem[d] * (sp_factor[d] if child_is_shared else 1)
                for d in dims
            }
            got = _foot_cache[key] = sum(
                t.tile_elems(child_tile) for t in nest.tensors
            )
        return got

    evaluated = 0
    budget = max_evals if max_evals and max_evals > 0 else None

    def run(width: int, incumbent: float) -> Report | None:
        nonlocal evaluated
        # beam entries: (partial_cost, til, odr, rem, rvec) with til/odr the
        # (L, D) tiling / order-index matrices of the fixed outer levels
        # (remainder parked at level 0, unfixed inner levels all-1/default).
        seed_til = np.ones((L, D), dtype=np.int64)
        seed_til[0] = [full_rem[d] for d in dims]
        seed_odr = np.tile(order_row(default_order), (L, 1))
        entries: list[tuple[float, np.ndarray, np.ndarray, dict, tuple]] = [
            (0.0, seed_til, seed_odr, dict(full_rem), (1,) * T)
        ]
        for l in range(L - 1, 0, -1):
            child_cap = levels[l - 1].capacity_bytes
            child_cap_words = (
                None if child_cap is None else child_cap // 2  # word_bytes=2
            )
            double = levels[l - 1].double_buffered
            child_is_shared = (l - 1) >= boundary
            g_til: list[np.ndarray] = []
            g_odr: list[np.ndarray] = []
            sizes: list[int] = []
            cand_rows: list[np.ndarray] = []
            groups: list[tuple] = []  # (cost, odr, new_rem, new_rvec, cands)
            n_rows = 0
            stop = False
            for cost, til, odr, rem, rvec in entries:
                if stop:
                    break
                if (
                    prune
                    and incumbent != math.inf
                    and cost + mac_e + lb_level(l, rem, rvec) > incumbent
                ):
                    continue
                lb_here = (
                    lb_level(l, rem, rvec) if incumbent != math.inf else 0.0
                )
                for tile_vec, tile_rvec, active, new_rem, rem_key in tiles_for(rem):
                    # child tile (everything still inside) must fit level l-1
                    if child_cap_words is not None:
                        words = child_words(child_is_shared, new_rem, rem_key)
                        if double:
                            words *= 2
                        if words > child_cap_words:
                            continue
                    new_rvec = tuple(r * f for r, f in zip(rvec, tile_rvec))
                    if prune and incumbent != math.inf:
                        optimistic = (
                            cost + mac_e + lb_here
                            + lb_below(l, new_rem, new_rvec)
                        )
                        if optimistic > incumbent:
                            continue
                    cands = cands_for(active)
                    if (
                        budget is not None
                        and groups
                        and evaluated + n_rows + len(cands) > budget
                    ):
                        stop = True
                        break
                    new_til = til.copy()
                    new_til[l] = tile_vec
                    new_til[0] = [new_rem[d] for d in dims]
                    g_til.append(new_til)
                    g_odr.append(odr)
                    sizes.append(len(cands))
                    cand_rows.extend(order_row(c) for c in cands)
                    n_rows += len(cands)
                    groups.append((cost, odr, new_rem, new_rvec, cands))
            if not groups:
                return None
            til_rows, odr_rows = assemble(g_til, g_odr, sizes, cand_rows, l)
            energies = price_level(til_rows, odr_rows, l)
            evaluated += n_rows
            nxt: list[tuple[float, np.ndarray, np.ndarray, dict, tuple]] = []
            start = 0
            for gi, (cost, odr, new_rem, new_rvec, cands) in enumerate(groups):
                k = sizes[gi]
                j = start + int(np.argmin(energies[start : start + k]))
                new_odr = odr.copy()
                new_odr[l] = cand_rows[j]
                nxt.append(
                    (cost + float(energies[j]), g_til[gi], new_odr,
                     new_rem, new_rvec)
                )
                start += k
            nxt.sort(key=lambda x: x[0])
            # dedup identical remainders (keep the cheapest) for beam diversity
            seen: set[tuple] = set()
            deduped: list[tuple] = []
            for e in nxt:
                rkey = tuple(e[3][d] for d in dims)
                if rkey in seen:
                    continue
                seen.add(rkey)
                deduped.append(e)
            entries = deduped[:width]

        # finalize: level-0 factors = remainder; optimize level-0 order.
        g_til, g_odr, sizes, cand_rows = [], [], [], []
        n_rows = 0
        for cost, til, odr, rem, _rvec in entries:
            active = tuple(d for d in dims if rem[d] > 1)
            cands = cands_for(active)
            if (
                budget is not None
                and g_til
                and evaluated + n_rows + len(cands) > budget
            ):
                break
            g_til.append(til)
            g_odr.append(odr)
            sizes.append(len(cands))
            cand_rows.extend(order_row(c) for c in cands)
            n_rows += len(cands)
        if not g_til:
            return None
        til_rows, odr_rows = assemble(g_til, g_odr, sizes, cand_rows, 0)
        energies = price_full(til_rows, odr_rows)
        evaluated += n_rows
        j = int(np.argmin(energies))
        return evaluate(sched_from(til_rows[j], odr_rows[j]), tbl)

    # Greedy dive establishes the branch-and-bound incumbent cheaply.
    dive_rep: Report | None = None
    incumbent = math.inf
    if prune:
        dive_rep = run(1, math.inf)
        if dive_rep is not None:
            incumbent = dive_rep.energy_pj
    best = run(beam, incumbent)
    if best is None:
        best = dive_rep
    if best is None:
        raise ValueError("no feasible blocking fits the memory hierarchy")
    if dive_rep is not None and dive_rep.energy_pj < best.energy_pj:
        best = dive_rep
    return SearchResult(best=best, evaluated=evaluated)
