"""Loop-blocking search (paper §3.1/§6.1): the dominant knob.

Given a hardware skeleton (memory levels + PE array) and a dataflow (spatial
unrolling), search per-level tiling factors and per-level loop orders that
minimize the analytical energy.  The paper performs "a conservatively pruned
search over the full design space guided by domain-specific knowledge"; we
implement the same style:

  * per-level tile enumeration over divisors with monotone capacity pruning,
  * stratified subsampling when a level's choice count explodes (keeps both
    buffer-filling and tiny tiles - the former usually win, Obs 1),
  * loop orders chosen greedily per level from stationarity templates
    (irrelevant-dims-innermost per tensor) or exhaustive permutations when
    few dims are active.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
import random
from typing import Iterator, Sequence

from repro.core.dataflow import Dataflow
from repro.core.energy import CostTable, Report, evaluate
from repro.core.loopnest import LoopNest, divisors
from repro.core.schedule import ArraySpec, MemLevel, Schedule


# ------------------------------------------------------------------ orders --


def order_candidates(
    nest: LoopNest, active: Sequence[str], exhaustive_limit: int = 4
) -> list[tuple[str, ...]]:
    """Candidate loop orders (innermost-first) for one level.

    Only dims with trip > 1 ("active") matter; inactive dims are appended.
    If few are active, try all permutations; otherwise use stationarity
    templates: for each tensor, its irrelevant dims innermost (so it stays
    resident below), largest-trip-last inside groups.
    """
    inactive = [d for d in nest.dims if d not in active]
    if len(active) <= exhaustive_limit:
        return [tuple(p) + tuple(inactive) for p in itertools.permutations(active)]
    cands: list[tuple[str, ...]] = []
    seen = set()
    for t in nest.tensors:
        irr = [d for d in active if d not in t.relevant]
        rel = [d for d in active if d in t.relevant]
        cand = tuple(irr + rel + inactive)
        if cand not in seen:
            seen.add(cand)
            cands.append(cand)
    default = tuple(active) + tuple(inactive)
    if default not in seen:
        cands.append(default)
    return cands


def optimize_orders(schedule: Schedule, table: CostTable | None = None) -> Report:
    """Greedy per-level order selection, innermost level first, evaluating the
    full analytical energy at each step."""
    table = table or CostTable.asic_28nm(schedule)
    best = evaluate(schedule, table)
    orders = list(schedule.order)
    for l in range(len(schedule.levels)):
        active = [d for d in schedule.nest.dims if schedule.tiling[d][l] > 1]
        if not active:
            continue
        for cand in order_candidates(schedule.nest, active):
            trial_orders = list(orders)
            trial_orders[l] = cand
            trial = dataclasses.replace(schedule, order=tuple(trial_orders))
            rep = evaluate(trial, table)
            if rep.energy_pj < best.energy_pj:
                best = rep
                orders = trial_orders
    return best


# ------------------------------------------------------------------ tiling --


def _tile_choices(
    nest: LoopNest,
    rem: dict[str, int],
    base_tile: dict[str, int],
    capacity_words: int | None,
    double: bool,
    max_choices: int,
) -> list[dict[str, int]]:
    """Enumerate per-dim divisor factors whose cumulative footprint fits."""
    dims = sorted(rem, key=lambda d: -rem[d])
    out: list[dict[str, int]] = []

    def footprint(tile: dict[str, int]) -> int:
        full = {d: base_tile[d] * tile.get(d, 1) for d in nest.dims}
        words = sum(t.tile_elems(full) for t in nest.tensors)
        return words * (2 if double else 1)

    def rec(i: int, tile: dict[str, int]):
        if i == len(dims):
            out.append(dict(tile))
            return
        d = dims[i]
        for f in divisors(rem[d]):
            tile[d] = f
            if capacity_words is not None and footprint(tile) > capacity_words:
                del tile[d]
                break  # factors ascend; larger only grows footprint
            rec(i + 1, tile)
        tile.pop(d, None)

    rec(0, {})
    if len(out) > max_choices:
        # stratified subsample by footprint: keep spread from tiny to full
        out.sort(key=footprint)
        idx = [round(i * (len(out) - 1) / (max_choices - 1)) for i in range(max_choices)]
        out = [out[i] for i in sorted(set(idx))]
    return out


def iter_blockings(
    nest: LoopNest,
    levels: Sequence[MemLevel],
    array: ArraySpec,
    dataflow: Dataflow,
    word_bytes: int = 2,
    max_choices_per_level: int = 64,
    seed: int = 0,
) -> Iterator[Schedule]:
    """Yield valid blocked schedules (default orders; caller optimizes).

    Per-level choices are deterministically shuffled so that a truncated
    consumer (max_evals) still samples the whole space instead of a DFS
    corner.
    """
    L = len(levels)
    rng = random.Random(seed)
    spatial = dataflow.assigns
    sp_factor = {d: dataflow.factor(d) for d in nest.dims}
    top_rem = {
        d: math.ceil(nest.bounds[d] / sp_factor[d]) for d in nest.dims
    }
    boundary = next(
        (i for i, lvl in enumerate(levels) if not lvl.per_pe), len(levels)
    )

    def rec(l: int, rem: dict[str, int], chosen: list[dict[str, int]]):
        if l == L - 1:  # top level takes the remainder
            tiling = {}
            for d in nest.dims:
                per = [chosen[i].get(d, 1) for i in range(L - 1)] + [rem[d]]
                tiling[d] = tuple(per)
            yield Schedule(
                nest=nest,
                levels=tuple(levels),
                tiling=tiling,
                order=tuple(tuple(nest.dims) for _ in range(L)),
                array=array,
                spatial=spatial,
                word_bytes=word_bytes,
            )
            return
        cap = levels[l].capacity_bytes
        cap_words = None if cap is None else cap // word_bytes
        include_sp = l >= boundary
        base = {d: 1 for d in nest.dims}
        for i in range(l):
            for d in nest.dims:
                base[d] *= chosen[i].get(d, 1)
        if include_sp:
            for d in nest.dims:
                base[d] *= sp_factor[d]
        tiles = _tile_choices(
            nest, rem, base, cap_words, levels[l].double_buffered, max_choices_per_level
        )
        rng.shuffle(tiles)
        for tile in tiles:
            new_rem = {d: rem[d] // tile.get(d, 1) for d in nest.dims}
            yield from rec(l + 1, new_rem, chosen + [tile])

    yield from rec(0, top_rem, [])


@dataclasses.dataclass
class SearchResult:
    best: Report
    evaluated: int


def _level_energy(
    schedule: Schedule, table: CostTable, level: int
) -> float:
    """Energy contributed by accesses served at `level` (+ array hops when
    `level` is the array-feeding level)."""
    from repro.core.reuse import analyze

    acc = analyze(schedule)
    e = acc.level_total(level) * table.level_pj[level]
    blevel = min(max(schedule.array_boundary, 1), len(schedule.levels) - 1)
    if level == blevel:
        e += sum(acc.hops.values()) * table.hop_pj
    return e


def search_blocking(
    nest: LoopNest,
    levels: Sequence[MemLevel],
    array: ArraySpec,
    dataflow: Dataflow,
    table: CostTable | None = None,
    beam: int = 24,
    max_choices_per_level: int = 512,
    max_evals: int = 0,  # kept for API compat; unused by the beam search
) -> SearchResult:
    """Top-down beam search with exact partial costs.

    Key property of the access model (reuse.py): the traffic served BY level l
    depends only on the tiling factors and loop orders at levels >= l (the
    child tile is then fixed by the remainder).  Choosing factors from the
    top (DRAM) inward therefore prices each level exactly when it is fixed —
    the paper's "domain-specific knowledge guided" pruned search made
    systematic.  A beam keeps the best partial hierarchies; per-level loop
    orders are optimized from stationarity templates as each level is fixed.
    """
    L = len(levels)
    levels = tuple(levels)
    spatial = dataflow.assigns
    sp_factor = {d: dataflow.factor(d) for d in nest.dims}
    full_rem = {d: math.ceil(nest.bounds[d] / sp_factor[d]) for d in nest.dims}
    boundary = next((i for i, lvl in enumerate(levels) if not lvl.per_pe), L)

    def mk_schedule(factors: dict[int, dict[str, int]], orders: list | None = None):
        """factors: level -> dim -> trip (levels fixed so far, top-down);
        remaining product goes to level 0 placeholder."""
        tiling = {}
        for d in nest.dims:
            per = [1] * L
            rem = full_rem[d]
            for l in range(L - 1, 0, -1):
                f = factors.get(l, {}).get(d, 1)
                per[l] = f
                rem //= f
            per[0] = rem
            tiling[d] = tuple(per)
        order = tuple(orders) if orders else tuple(tuple(nest.dims) for _ in range(L))
        return Schedule(
            nest=nest, levels=levels, tiling=tiling, order=order,
            array=array, spatial=spatial,
        )

    # seed: everything unassigned (all at level 0) — will be carved outward
    tbl = table or CostTable.asic_28nm(mk_schedule({}))

    # beam entries: (partial_cost, factors, orders, rem)
    entries: list[tuple[float, dict, list, dict]] = [
        (0.0, {}, [tuple(nest.dims)] * L, dict(full_rem))
    ]
    evaluated = 0

    for l in range(L - 1, 0, -1):
        child_cap = levels[l - 1].capacity_bytes
        child_cap_words = (
            None if child_cap is None else child_cap // 2  # word_bytes=2
        )
        child_is_shared = (l - 1) >= boundary
        nxt: list[tuple[float, dict, list, dict]] = []
        for cost, factors, orders, rem in entries:
            base = {d: 1 for d in nest.dims}  # factors at this level multiply rem-child
            for tile in _tile_choices(
                nest, rem, base, None, False, max_choices_per_level
            ):
                new_rem = {d: rem[d] // tile.get(d, 1) for d in nest.dims}
                # the child tile (everything still inside) must fit level l-1
                child_tile = {
                    d: new_rem[d] * (sp_factor[d] if child_is_shared else 1)
                    for d in nest.dims
                }
                if child_cap_words is not None:
                    words = sum(t.tile_elems(child_tile) for t in nest.tensors)
                    if levels[l - 1].double_buffered:
                        words *= 2
                    if words > child_cap_words:
                        continue
                new_factors = dict(factors)
                new_factors[l] = tile
                # pick the best order for this level by its exact energy
                active = [d for d in nest.dims if tile.get(d, 1) > 1]
                best_o, best_e = tuple(nest.dims), None
                for cand in order_candidates(nest, active) if active else [tuple(nest.dims)]:
                    trial_orders = list(orders)
                    trial_orders[l] = cand
                    sched = mk_schedule(new_factors, trial_orders)
                    e = _level_energy(sched, tbl, l)
                    evaluated += 1
                    if best_e is None or e < best_e:
                        best_e, best_o = e, cand
                new_orders = list(orders)
                new_orders[l] = best_o
                nxt.append((cost + best_e, new_factors, new_orders, new_rem))
        if not nxt:
            raise ValueError("no feasible blocking fits the memory hierarchy")
        nxt.sort(key=lambda x: x[0])
        # dedup identical remainders+cost to keep beam diverse
        entries = nxt[: beam]

    # finalize: level-0 factors = remainder; optimize level-0 order; evaluate.
    best: Report | None = None
    for cost, factors, orders, rem in entries:
        active = [d for d in nest.dims if rem[d] > 1]
        for cand in order_candidates(nest, active) if active else [tuple(nest.dims)]:
            trial_orders = list(orders)
            trial_orders[0] = cand
            sched = mk_schedule(factors, trial_orders)
            rep = evaluate(sched, tbl)
            evaluated += 1
            if best is None or rep.energy_pj < best.energy_pj:
                best = rep
    if best is None:
        raise ValueError("no feasible blocking fits the memory hierarchy")
    return SearchResult(best=best, evaluated=evaluated)
