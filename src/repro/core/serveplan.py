"""Serve-config planning: the whole serving configuration as a mapping space.

The paper's argument (§6.3) is that *resource allocation* — not dataflow —
dominates energy and performance, and this repo owns the machinery that
proves it for matmul tiles (blocking search, batched cost model, DSE Pareto
sweeps).  Yet the serving stack's own knobs — ``block_size``, ``num_blocks``,
``kv_splits``, slot count, ``prefill_chunk``, ``token_budget`` — were
hand-set.  This module closes that loop: one decode step of the served
transformer is modeled as Interstellar loop nests and priced analytically,
the joint knob space is swept under an iso-HBM constraint, and the winner is
persisted per ``(hardware, model, workload)`` the same way matmul tiles are
(``REPRO_TILE_CACHE`` -> ``REPRO_SERVE_PLAN_CACHE``).

One decode step, at steady state with ``rows`` live requests at mean context
``ctx``, costs:

  * **decode GEMMs** — qkv / attention-out / mlp / unembed nests with
    ``M = rows``: each is blocked by the paper's blocking search
    (``mapper.choose_matmul_tiles`` on the 2-level VMEM/HBM hierarchy) and
    its HBM traffic read off the winning tiles (``MatmulTiles.hbm_words``:
    with serving-sized ``M <= bm`` the weights cross HBM exactly once per
    step, the memory-bound serving regime);
  * **paged attention gather** — ``ceil(ctx / block_size)`` whole KV blocks
    per row per layer (tail-block fragmentation is the cost of a large
    block), block-table prefetch, and per-split online-softmax partials
    (``energy.attention_gather_cost``; the contiguous twin pins
    ``kv_splits = max_len / decode_block`` and pays the full combine);
  * **prefill lane** — chunked admission streams ``prefill_chunk``-token
    tiles through the scratch lane under ``token_budget``; steady-state
    turnover demands ``rows * prompt_len / decode_len`` prefill tokens per
    step, and a lane that cannot keep up caps occupancy (the admission-bound
    regime).  Monolithic admission (``prefill_chunk=0``) does the same total
    work but pays TTFT as one whole-prompt stall.

Throughput is the same max() roofline ``energy.evaluate`` uses — compute at
the ``ArraySpec`` MXU peak vs HBM words at the ``MemLevel`` bandwidth — plus
a :class:`Calibration` term (fixed per-step overhead + per-row cost) fitted
ONCE against measured steps (``benchmarks/serve_bench.py`` calibrates
against its own measured reference configs; ``benchmarks/roofline.py``
constants are the uncalibrated default).  Candidates are folded through
``dse.pareto_prune`` over (time-per-token, TTFT, energy-per-token) and the
winner maximizes predicted tokens/sec.

Feasibility is capacity-driven, like every Interstellar sweep: GEMM tiles
must fit VMEM (double-buffered), weights + the KV pool must fit HBM, and the
iso-HBM constraint sizes every candidate's pool from the same
``kv_budget_tokens`` so allocations — not budgets — are what is compared.

This module is numpy-only (no JAX): ``ServeConfig.autotune()``
(serve/engine.py) converts the planned knobs into an engine config, and
``launch/serve.py --autotune`` surfaces it on the CLI.
"""

from __future__ import annotations

import dataclasses
import hashlib
import math
import os

import numpy as np

from repro.core import energy as en
from repro.core.costmodel import attention_gather_words
from repro.core.dse import pareto_prune
from repro.core.jsonstore import atomic_write_json, load_json_dict
from repro.core.mapper import choose_matmul_tiles
from repro.core.schedule import ArraySpec, MemLevel

WORD_BYTES = 2  # bf16 serving, like the paper's 16-bit arithmetic (§5)

# Bump whenever the step model or the sweep changes, so stale plans from an
# older algorithm are never served out of the on-disk cache.
_PLAN_CACHE_SCHEMA = "v1"
_PLAN_CACHE_ENV = "REPRO_SERVE_PLAN_CACHE"
_PLAN_CACHE_DEFAULT = os.path.join(
    os.path.expanduser("~"), ".cache", "repro-interstellar",
    "serve_plans.json",
)


# -------------------------------------------------------------- hardware --


@dataclasses.dataclass(frozen=True)
class ServeHardware:
    """The serving chip as the paper would describe it: a fixed MXU array
    plus a 2-level (VMEM, HBM) memory hierarchy with capacities and
    bandwidths.  Defaults are the TPU v5e constants shared with
    benchmarks/roofline.py."""

    name: str = "tpu-v5e"
    hbm_bytes: int = en.TPU_HBM_BYTES
    hbm_bytes_per_s: float = en.TPU_HBM_BYTES_PER_S
    peak_flops: float = en.TPU_PEAK_FLOPS_BF16
    vmem_bytes: int = en.TPU_VMEM_BYTES
    array: ArraySpec = ArraySpec(dims=(128, 128))
    clock_hz: float = 940e6

    def levels(self) -> tuple[MemLevel, ...]:
        """The serve hierarchy in the core IR's own terms (words/cycle at
        the planner's clock), so the planner prices with the same MemLevel
        vocabulary as every other sweep in core/."""
        return (
            MemLevel(
                "VMEM",
                capacity_bytes=self.vmem_bytes,
                bandwidth_words_per_cycle=float("inf"),
                double_buffered=True,
            ),
            MemLevel(
                "HBM",
                capacity_bytes=self.hbm_bytes,
                bandwidth_words_per_cycle=(
                    self.hbm_bytes_per_s / self.clock_hz / WORD_BYTES
                ),
                double_buffered=False,
            ),
        )

    def fingerprint(self) -> tuple:
        return (
            self.name, self.hbm_bytes, round(self.hbm_bytes_per_s),
            round(self.peak_flops), self.vmem_bytes, self.array.dims,
            round(self.clock_hz),
        )


@dataclasses.dataclass(frozen=True)
class ServeWorkload:
    """What the planner optimizes for: offered concurrency and the shape of
    a request.  ``decode_len`` sets the steady-state admission turnover
    (each slot re-admits every ``decode_len`` steps)."""

    concurrency: int = 16
    prompt_len: int = 64
    decode_len: int = 64

    def __post_init__(self):
        if min(self.concurrency, self.prompt_len, self.decode_len) < 1:
            raise ValueError(f"workload fields must be >= 1: {self}")

    def mean_ctx(self, max_len: int) -> int:
        """Mean live KV length mid-decode: the whole prompt plus half the
        generated tokens, clamped into the ring."""
        return max(1, min(max_len - 1, self.prompt_len + self.decode_len // 2))

    def fingerprint(self) -> tuple:
        return (self.concurrency, self.prompt_len, self.decode_len)


@dataclasses.dataclass(frozen=True)
class Calibration:
    """Bridges the analytic roofline to measured steps with four host-side
    terms the roofline cannot see: a fixed per-step overhead (dispatch,
    host sync — dominant on CPU smoke runs, small on a real TPU), a
    per-row cost, a per-gathered-physical-block cost (the paged kernel's
    block-table indirection), and a fixed surcharge when the chunked
    prefill lane is armed (its extra program dispatch per step).  ``fit``
    solves them from measured (StepCost, seconds) reference pairs ONCE —
    anchors should span the features being fitted (two occupancies, a
    paged member, a chunked member) — and the planner then ranks every
    other candidate with the same terms."""

    step_overhead_s: float = 0.0
    per_row_s: float = 0.0
    per_block_s: float = 0.0
    chunk_overhead_s: float = 0.0

    @classmethod
    def fit(cls, pairs) -> "Calibration":
        """Least-squares ``measured = roofline + c0 + c1*rows +
        c2*paged_blocks + c3*chunked`` over measured reference steps.
        Features the anchor set cannot distinguish (zero spread across
        pairs) are dropped and fitted as 0; negative solutions clamp to 0
        — a measured step can't beat its own roofline."""
        if not pairs:
            return cls()
        resid = np.array([m - c.roofline_s for c, m in pairs], dtype=float)
        feats = np.stack(
            [
                np.ones(len(pairs)),
                np.array([c.rows for c, _ in pairs], dtype=float),
                np.array(
                    [c.paged_blocks for c, _ in pairs], dtype=float
                ),
                np.array(
                    [float(c.chunked) for c, _ in pairs], dtype=float
                ),
            ],
            axis=1,
        )
        use = [0] + [j for j in (1, 2, 3) if np.ptp(feats[:, j]) > 0]
        coef, *_ = np.linalg.lstsq(feats[:, use], resid, rcond=None)
        sol = [0.0, 0.0, 0.0, 0.0]
        for j, c in zip(use, coef):
            sol[j] = max(0.0, float(c))
        return cls(*sol)

    def fingerprint(self) -> tuple:
        return (
            round(self.step_overhead_s, 9),
            round(self.per_row_s, 12),
            round(self.per_block_s, 12),
            round(self.chunk_overhead_s, 9),
        )


# ----------------------------------------------------------------- knobs --


@dataclasses.dataclass(frozen=True)
class ServeKnobs:
    """The planned subset of ServeConfig: everything the sweep searches.
    ``ServeConfig.autotune`` maps these onto the nested sub-configs."""

    slots: int
    kv_layout: str = "paged"
    block_size: int = 16
    num_blocks: int | None = None      # paged pool size incl. the sink
    prefill_chunk: int = 0
    token_budget: int | None = None

    def kv_splits(self, max_len: int) -> int:
        """Online-softmax split count of the decode kernel: the paged grid
        splits at physical blocks; the contiguous twin pins its split to
        the same size (KVConfig.decode_block)."""
        return -(-max_len // self.block_size)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ServeKnobs":
        return cls(**{f.name: d[f.name] for f in dataclasses.fields(cls)})

    def validate(self, max_len: int) -> None:
        """Eager validation mirroring ServeConfig's own: a cached plan that
        fails here is stale/corrupt and must be re-searched, never served
        (the same defense choose_matmul_tiles applies to tile entries)."""
        if not isinstance(self.slots, int) or self.slots < 1:
            raise ValueError(f"slots must be a positive int: {self.slots!r}")
        if self.kv_layout not in ("paged", "contiguous"):
            raise ValueError(f"bad kv_layout: {self.kv_layout!r}")
        if not isinstance(self.block_size, int) or self.block_size < 1:
            raise ValueError(f"bad block_size: {self.block_size!r}")
        if max_len % self.block_size:
            raise ValueError(
                f"max_len {max_len} not a multiple of block_size "
                f"{self.block_size}"
            )
        if self.kv_layout == "paged":
            if self.num_blocks is not None and (
                not isinstance(self.num_blocks, int) or self.num_blocks < 2
            ):
                raise ValueError(f"bad num_blocks: {self.num_blocks!r}")
        elif self.num_blocks is not None:
            raise ValueError("num_blocks only applies to the paged layout")
        if not isinstance(self.prefill_chunk, int) or self.prefill_chunk < 0:
            raise ValueError(f"bad prefill_chunk: {self.prefill_chunk!r}")
        if self.prefill_chunk and max_len % self.prefill_chunk:
            raise ValueError(
                f"max_len {max_len} not a multiple of prefill_chunk "
                f"{self.prefill_chunk}"
            )
        if self.token_budget is not None:
            if self.prefill_chunk == 0:
                raise ValueError("token_budget requires prefill_chunk > 0")
            if (
                not isinstance(self.token_budget, int)
                or self.token_budget < self.prefill_chunk
            ):
                raise ValueError(f"bad token_budget: {self.token_budget!r}")


@dataclasses.dataclass(frozen=True)
class ServePlanSpace:
    """The swept joint space.  Every combination is enumerated, sized to
    the iso-HBM budget, and priced; infeasible points (VMEM/HBM overflow,
    zero admitted rows) are dropped like any other infeasible mapping."""

    slot_counts: tuple[int, ...] = (2, 4, 8, 16, 32)
    block_sizes: tuple[int, ...] = (8, 16, 32)
    layouts: tuple[str, ...] = ("paged", "contiguous")
    prefill_chunks: tuple[int, ...] = (0, 16, 32)
    # token_budget = multiplier * prefill_chunk (chunks advanced per step);
    # only meaningful for chunked points
    token_budget_chunks: tuple[int, ...] = (1,)

    def fingerprint(self) -> tuple:
        return (
            self.slot_counts, self.block_sizes, self.layouts,
            self.prefill_chunks, self.token_budget_chunks,
        )


# ------------------------------------------------------------- step model --


def decode_gemms(cfg) -> list[tuple[str, int, int, int]]:
    """The per-step GEMM nests of a dense decoder-only transformer:
    (name, N, K, multiplicity).  M is the live row count and comes from the
    schedule, not the model."""
    d, hd, L = cfg.d_model, cfg.resolved_head_dim, cfg.n_layers
    up = cfg.d_ff * (2 if cfg.mlp_act == "swiglu" else 1)
    return [
        ("qkv", (cfg.n_heads + 2 * cfg.n_kv_heads) * hd, d, L),
        ("attn_out", d, cfg.n_heads * hd, L),
        ("mlp_up", up, d, L),
        ("mlp_down", d, cfg.d_ff, L),
        ("unembed", cfg.vocab, d, 1),
    ]


def _check_dense(cfg) -> None:
    if getattr(cfg, "mixer", "attention") != "attention" or getattr(
        cfg, "moe", None
    ):
        raise ValueError(
            f"the serve planner models dense decoder-only decode steps; "
            f"{cfg.name!r} (mixer={getattr(cfg, 'mixer', '?')!r}, "
            f"moe={getattr(cfg, 'moe', None) is not None}) is out of scope"
        )


@dataclasses.dataclass(frozen=True)
class StepCost:
    """One steady-state decode step, before calibration."""

    rows: int            # live decode rows advanced per step
    admitted: int        # concurrency the KV capacity admits
    flops: float         # decode GEMMs + attention + amortized prefill
    hbm_words: float
    vmem_words: float
    kv_pool_bytes: int
    roofline_s: float    # max(compute, HBM bandwidth) — uncalibrated
    ttft_steps: float    # steps from admission to first token
    paged_blocks: float  # physical blocks gathered per step (0: contiguous)
    chunked: int         # 1 when the chunked prefill lane is armed
    breakdown: dict      # per-term HBM words

    def step_s(self, calib: Calibration) -> float:
        return (
            self.roofline_s
            + calib.step_overhead_s
            + calib.per_row_s * self.rows
            + calib.per_block_s * self.paged_blocks
            + calib.chunk_overhead_s * self.chunked
        )

    def tokens_per_s(self, calib: Calibration) -> float:
        return self.rows / self.step_s(calib)

    def ttft_s(self, calib: Calibration) -> float:
        return self.ttft_steps * self.step_s(calib)

    def energy_pj(self) -> float:
        return en.serve_step_energy_pj(
            macs=self.flops / 2.0,
            hbm_words=self.hbm_words,
            vmem_words=self.vmem_words,
            vmem_bytes=en.TPU_VMEM_BYTES,
        )


def price_decode_step(
    cfg,
    knobs: ServeKnobs,
    *,
    max_len: int,
    workload: ServeWorkload,
    hardware: ServeHardware | None = None,
) -> StepCost | None:
    """Price one steady-state decode step under the given knobs, or None
    when the point is infeasible (no admitted rows, or weights + pool
    overflow HBM).  See the module docstring for the model."""
    _check_dense(cfg)
    hw = hardware or ServeHardware()
    knobs.validate(max_len)
    d, hd, L = cfg.d_model, cfg.resolved_head_dim, cfg.n_layers
    ctx = workload.mean_ctx(max_len)
    bs = knobs.block_size
    kv_row_bytes = 2 * cfg.n_kv_heads * hd * WORD_BYTES  # K+V, one token

    # ---- KV capacity: what the layout admits at this context length ----
    if knobs.kv_layout == "paged":
        num_blocks = knobs.num_blocks
        if num_blocks is None:
            # the engine's own default: the contiguous footprint + sink
            num_blocks = knobs.slots * (max_len // bs) + 1
        row_blocks = -(-ctx // bs)
        admitted = (num_blocks - 1) // row_blocks
        kv_pool_bytes = L * num_blocks * bs * kv_row_bytes
    else:
        admitted = knobs.slots
        kv_pool_bytes = L * knobs.slots * max_len * kv_row_bytes
    rows = min(knobs.slots, workload.concurrency, admitted)
    if rows < 1:
        return None
    weight_bytes = cfg.params_count() * WORD_BYTES
    if weight_bytes + kv_pool_bytes > hw.hbm_bytes:
        return None

    # ---- decode GEMMs: blocked by the paper's search, traffic off tiles --
    gemm_words = 0.0
    gemm_flops = 0.0
    vmem_words = 0.0
    for _name, N, K, mult in decode_gemms(cfg):
        tiles = choose_matmul_tiles(rows, N, K, vmem_bytes=hw.vmem_bytes // 4)
        if tiles.vmem_bytes() > hw.vmem_bytes:
            return None
        gemm_words += mult * tiles.hbm_words(rows, N, K)
        gemm_flops += mult * 2.0 * rows * N * K
        # operand reads feed the MXU from VMEM; the output tile writes back
        vmem_words += mult * (2.0 * rows * N * K + rows * N)

    # ---- decode attention: gather + partials + this step's KV write ----
    att_row_words = float(
        attention_gather_words(
            np.int64(ctx),
            np.int64(bs),
            kv_heads=cfg.n_kv_heads,
            head_dim=hd,
            kv_splits=(
                None
                if knobs.kv_layout == "paged"
                else np.int64(knobs.kv_splits(max_len))
            ),
        )
    )
    att_words = rows * L * (
        att_row_words
        + 2 * cfg.n_heads * hd       # q read + attention output write
        + 2 * cfg.n_kv_heads * hd    # this token's K+V write
    )
    att_flops = rows * L * 4.0 * cfg.n_heads * hd * ctx
    vmem_words += att_words  # every gathered word crosses VMEM once

    # ---- prefill lane: steady-state admission turnover ----
    # each slot re-admits every decode_len steps, so admission must stream
    # prompt_len * rows / decode_len prefill tokens per step on average
    demand_tok = workload.prompt_len * rows / workload.decode_len
    if knobs.prefill_chunk > 0:
        budget = knobs.token_budget or knobs.prefill_chunk
        lane_tok_per_step = float(budget)
        if lane_tok_per_step < demand_tok:
            # admission-bound: occupancy sags until turnover matches the
            # lane's streaming rate
            rows = max(
                1,
                int(
                    rows * lane_tok_per_step / demand_tok
                ),
            )
            demand_tok = workload.prompt_len * rows / workload.decode_len
        chunks_per_step = max(1, budget // knobs.prefill_chunk)
        ttft_steps = math.ceil(
            math.ceil(workload.prompt_len / knobs.prefill_chunk)
            / chunks_per_step
        ) + 1.0
    else:
        # monolithic: the whole prompt lands in one fused admission step
        ttft_steps = 1.0
    prefill_tok = demand_tok
    # prefill rides the same step program, so weights are already paid by
    # the decode GEMM pass; the lane adds per-token KV writes plus its
    # causal attention reads (each prefill token attends to half the
    # prompt on average) and the matching compute
    prefill_words = prefill_tok * L * 2.0 * cfg.n_kv_heads * hd * (
        1.0 + workload.prompt_len / 2.0
    )
    prefill_flops = prefill_tok * (
        2.0 * cfg.params_count()
        + 4.0 * L * cfg.n_heads * hd * (workload.prompt_len / 2.0)
    )
    vmem_words += prefill_words

    # ---- embedding gathers for this step's input tokens ----
    embed_words = (rows + prefill_tok) * d

    hbm_words = gemm_words + att_words + prefill_words + embed_words
    flops = gemm_flops + att_flops + prefill_flops
    vmem_lvl, hbm_lvl = hw.levels()
    hbm_words_per_s = (
        hbm_lvl.bandwidth_words_per_cycle * hw.clock_hz
    )
    roofline_s = max(flops / hw.peak_flops, hbm_words / hbm_words_per_s)
    return StepCost(
        rows=rows,
        admitted=int(min(admitted, workload.concurrency)),
        flops=flops,
        hbm_words=hbm_words,
        vmem_words=vmem_words,
        kv_pool_bytes=kv_pool_bytes,
        roofline_s=roofline_s,
        ttft_steps=ttft_steps,
        paged_blocks=(
            float(rows * -(-ctx // bs))
            if knobs.kv_layout == "paged"
            else 0.0
        ),
        chunked=int(knobs.prefill_chunk > 0),
        breakdown={
            "gemm_words": gemm_words,
            "attention_words": att_words,
            "prefill_words": prefill_words,
            "embed_words": embed_words,
            "vmem_capacity_bytes": vmem_lvl.capacity_bytes,
        },
    )


# ----------------------------------------------------------------- sweep --


@dataclasses.dataclass(frozen=True)
class ServePoint:
    """One priced serve configuration: attribute names double as
    ``pareto_prune`` keys (minimization in every key)."""

    knobs: ServeKnobs
    cost: StepCost
    us_per_token: float
    ttft_ms: float
    energy_pj_per_token: float


def sweep_serve_space(
    cfg,
    *,
    max_len: int,
    workload: ServeWorkload | None = None,
    hardware: ServeHardware | None = None,
    space: ServePlanSpace | None = None,
    kv_budget_tokens: int | None = None,
    calibration: Calibration | None = None,
) -> list[ServePoint]:
    """Enumerate and price the joint serve-knob space under one iso-HBM KV
    budget.  ``kv_budget_tokens`` defaults to the largest contiguous
    member's footprint (``max(slot_counts) * max_len``), so every candidate
    — paged or contiguous — is compared at equal KV HBM, exactly the
    paper's iso-resource discipline; pass an explicit budget to plan for a
    different pool."""
    _check_dense(cfg)
    hw = hardware or ServeHardware()
    wl = workload or ServeWorkload()
    sp = space or ServePlanSpace()
    calib = calibration or Calibration()
    if kv_budget_tokens is None:
        kv_budget_tokens = max(sp.slot_counts) * max_len
    points: list[ServePoint] = []
    for layout in sp.layouts:
        for bs in sp.block_sizes:
            if max_len % bs:
                continue
            if layout == "paged":
                num_blocks = kv_budget_tokens // bs + 1
                if num_blocks < 2:
                    continue
            else:
                num_blocks = None
            for slots in sp.slot_counts:
                if layout == "contiguous" and slots * max_len > kv_budget_tokens:
                    continue  # iso-HBM: this member overflows the budget
                for chunk in sp.prefill_chunks:
                    if chunk and max_len % chunk:
                        continue
                    budgets = (
                        [m * chunk for m in sp.token_budget_chunks]
                        if chunk
                        else [None]
                    )
                    for budget in budgets:
                        knobs = ServeKnobs(
                            slots=slots,
                            kv_layout=layout,
                            block_size=bs,
                            num_blocks=num_blocks,
                            prefill_chunk=chunk,
                            token_budget=budget,
                        )
                        cost = price_decode_step(
                            cfg, knobs, max_len=max_len, workload=wl,
                            hardware=hw,
                        )
                        if cost is None:
                            continue
                        points.append(
                            ServePoint(
                                knobs=knobs,
                                cost=cost,
                                us_per_token=1e6
                                / cost.tokens_per_s(calib),
                                ttft_ms=1e3 * cost.ttft_s(calib),
                                energy_pj_per_token=cost.energy_pj()
                                / cost.rows,
                            )
                        )
    return points


# ------------------------------------------------------------------ plan --


@dataclasses.dataclass(frozen=True)
class ServePlan:
    """The sweep's winner plus its predicted stats and provenance."""

    knobs: ServeKnobs
    max_len: int
    predicted: dict
    source: str          # "search" | "cache"
    frontier_size: int = 0

    def as_dict(self) -> dict:
        return {
            "knobs": self.knobs.as_dict(),
            "max_len": self.max_len,
            "predicted": self.predicted,
            "frontier_size": self.frontier_size,
        }


def _plan_cache_path() -> str | None:
    path = os.environ.get(_PLAN_CACHE_ENV, _PLAN_CACHE_DEFAULT)
    return path or None


def _model_fingerprint(cfg) -> tuple:
    return (
        cfg.name, cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
        cfg.d_ff, cfg.vocab, cfg.resolved_head_dim, cfg.mlp_act,
        cfg.tie_embeddings,
    )


def _plan_key(
    cfg, max_len, workload, hardware, space, kv_budget_tokens, calibration
) -> str:
    desc = repr(
        (
            _PLAN_CACHE_SCHEMA,
            _model_fingerprint(cfg),
            max_len,
            workload.fingerprint(),
            hardware.fingerprint(),
            space.fingerprint(),
            kv_budget_tokens,
            calibration.fingerprint(),
        )
    )
    return hashlib.sha256(desc.encode()).hexdigest()[:32]


def _store_plan(path: str, key: str, plan: ServePlan) -> None:
    """Read-merge-replace, like mapper._store_tile: concurrent planners
    lose at most one entry, and the rename keeps the file parseable."""
    data = load_json_dict(path)
    data[key] = plan.as_dict()
    try:
        atomic_write_json(path, data)
    except OSError:
        pass  # cache is best-effort; the plan is still returned


def _load_plan(path: str, key: str, max_len: int) -> ServePlan | None:
    got = load_json_dict(path).get(key)
    if not isinstance(got, dict):
        return None
    try:
        knobs = ServeKnobs.from_dict(got["knobs"])
        knobs.validate(int(got["max_len"]))
        if int(got["max_len"]) != max_len:
            return None
    except (KeyError, TypeError, ValueError):
        return None  # corrupt/stale entry: re-search and overwrite
    return ServePlan(
        knobs=knobs,
        max_len=max_len,
        predicted=dict(got.get("predicted", {})),
        source="cache",
        frontier_size=int(got.get("frontier_size", 0)),
    )


def plan_serve(
    cfg,
    *,
    max_len: int = 256,
    workload: ServeWorkload | None = None,
    hardware: ServeHardware | None = None,
    space: ServePlanSpace | None = None,
    kv_budget_tokens: int | None = None,
    calibration: Calibration | None = None,
    ttft_ceiling_ms: float | None = None,
    cache: bool | str = True,
) -> ServePlan:
    """Sweep the joint serve-knob space and return the winner.

    The objective is predicted decode tokens/sec over the Pareto frontier
    (time-per-token, TTFT, energy-per-token); ``ttft_ceiling_ms`` filters
    the frontier first (the serving analogue of ``best_at_iso_throughput``'s
    throughput constraint — latency held, throughput optimized).  Winners
    persist per (hardware, model, workload, space, budget, calibration) in
    the JSON store named by ``REPRO_SERVE_PLAN_CACHE`` (same defense as the
    tile cache: entries are validated before being served, and a corrupt
    entry is re-searched and overwritten).  Pass ``cache=False`` to force a
    fresh search, or a path string to use a specific store."""
    wl = workload or ServeWorkload()
    hw = hardware or ServeHardware()
    sp = space or ServePlanSpace()
    calib = calibration or Calibration()
    if kv_budget_tokens is None:
        kv_budget_tokens = max(sp.slot_counts) * max_len

    path = cache if isinstance(cache, str) else (
        _plan_cache_path() if cache else None
    )
    key = _plan_key(cfg, max_len, wl, hw, sp, kv_budget_tokens, calib)
    if path:
        got = _load_plan(path, key, max_len)
        if got is not None:
            return got

    points = sweep_serve_space(
        cfg, max_len=max_len, workload=wl, hardware=hw, space=sp,
        kv_budget_tokens=kv_budget_tokens, calibration=calib,
    )
    if not points:
        raise ValueError(
            f"no feasible serve configuration for {cfg.name!r} at "
            f"max_len={max_len} under kv_budget_tokens={kv_budget_tokens} "
            f"(every swept point overflowed HBM/VMEM or admitted 0 rows)"
        )
    frontier = pareto_prune(
        points, keys=("us_per_token", "ttft_ms", "energy_pj_per_token")
    )
    eligible = frontier
    if ttft_ceiling_ms is not None:
        ok = [p for p in frontier if p.ttft_ms <= ttft_ceiling_ms]
        if ok:
            eligible = ok  # no eligible point: fall back to the frontier
    best = min(eligible, key=lambda p: (p.us_per_token, p.ttft_ms))
    plan = ServePlan(
        knobs=best.knobs,
        max_len=max_len,
        predicted={
            "tokens_per_s": best.cost.tokens_per_s(calib),
            "us_per_token": best.us_per_token,
            "ttft_ms": best.ttft_ms,
            "energy_pj_per_token": best.energy_pj_per_token,
            "rows": best.cost.rows,
            "admitted": best.cost.admitted,
            "kv_pool_bytes": best.cost.kv_pool_bytes,
            "hbm_words_per_step": best.cost.hbm_words,
            "swept_points": len(points),
        },
        source="search",
        frontier_size=len(frontier),
    )
    if path:
        _store_plan(path, key, plan)
    return plan
