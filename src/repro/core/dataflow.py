"""Dataflow taxonomy: spatial loop unrolling U | V with replication (paper §3.2).

A dataflow names which loops are unrolled on each physical dimension of the
PE array:  `U | V` unrolls loop U vertically and V horizontally; replication
(`U W | V`) maps several loops to one physical dim, nearest-first, to recover
utilization (paper Fig 2/3).  Table 1 of the paper:

    output stationary   X | Y
    weight stationary   FX | FY
    row stationary      FY | Y
    weight stationary   C | K     (TPU-style; used by the paper's optimizer)

`enumerate_dataflows` generates all (L choose 2) primary choices; `replicate`
greedily fills leftover PEs with additional loops, exactly the paper's fix
for under-utilization.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Sequence

from repro.core.loopnest import LoopNest, divisors
from repro.core.schedule import ArraySpec

# Canonical names from paper Table 1 (for reporting).
NAMED_DATAFLOWS = {
    ("X", "Y"): "output-stationary X|Y",
    ("FX", "FY"): "weight-stationary FX|FY",
    ("FY", "Y"): "row-stationary FY|Y",
    ("C", "K"): "weight-stationary C|K",
}


@dataclasses.dataclass(frozen=True)
class Dataflow:
    """Spatial assignment: per array dim, ordered (loop, factor) pairs."""

    assigns: tuple[tuple[tuple[str, int], ...], ...]

    @property
    def primary(self) -> tuple[str, ...]:
        return tuple(a[0][0] if a else "-" for a in self.assigns)

    def label(self) -> str:
        parts = []
        for a in self.assigns:
            parts.append("".join(d for d, _ in a) or "-")
        tag = "|".join(parts)
        name = NAMED_DATAFLOWS.get(self.primary)
        return f"{tag} ({name})" if name else tag

    def factor(self, dim: str) -> int:
        f = 1
        for a in self.assigns:
            for d, s in a:
                if d == dim:
                    f *= s
        return f

    def used_pes(self) -> int:
        return math.prod(
            math.prod(f for _, f in a) if a else 1 for a in self.assigns
        )


def _best_factor(bound: int, budget: int) -> int:
    """Largest divisor of `bound` <= budget (>=1)."""
    best = 1
    for d in divisors(bound):
        if d <= budget:
            best = d
    return best


def _fill_dim(
    nest: LoopNest,
    primary: str,
    capacity: int,
    replication_pool: Sequence[str],
    remaining: dict[str, int],
) -> tuple[tuple[str, int], ...]:
    """Map `primary` on a physical dim of size `capacity`; replicate greedily
    from `replication_pool` (largest-first) to fill leftover PEs."""
    assigns: list[tuple[str, int]] = []
    f = _best_factor(remaining[primary], capacity)
    if f > 1:
        assigns.append((primary, f))
        remaining[primary] //= f
        capacity //= f
    for d in sorted(replication_pool, key=lambda d: -remaining[d]):
        if capacity <= 1:
            break
        g = _best_factor(remaining[d], capacity)
        if g > 1:
            assigns.append((d, g))
            remaining[d] //= g
            capacity //= g
    return tuple(assigns)


def make_dataflow(
    nest: LoopNest,
    array: ArraySpec,
    primary: Sequence[str],
    replication: bool = True,
) -> Dataflow:
    """Build a dataflow with primaries `primary` (one per array dim), greedily
    replicated if requested."""
    remaining = dict(nest.bounds)
    assigns = []
    for a, p in enumerate(primary):
        pool = (
            [d for d in nest.dims if d != p and d not in primary]
            if replication
            else []
        )
        assigns.append(
            _fill_dim(nest, p, array.dims[a], pool, remaining)
        )
    return Dataflow(assigns=tuple(assigns))


def enumerate_dataflows(
    nest: LoopNest,
    array: ArraySpec,
    replication: bool = True,
    min_bound: int = 2,
) -> list[Dataflow]:
    """All single-primary-per-dim dataflows (paper: (L choose d) choices)."""
    dims = [d for d in nest.dims if nest.bounds[d] >= min_bound]
    out = []
    seen = set()
    for combo in itertools.permutations(dims, len(array.dims)):
        df = make_dataflow(nest, array, combo, replication=replication)
        key = df.assigns
        if key in seen:
            continue
        seen.add(key)
        out.append(df)
    return out
