"""Cost models: E = sum_i #acc_i * e_i  (paper §5, Table 3) + perf roofline.

Two cost tables:

  * ASIC 28 nm (paper Table 3) — used for the faithful reproduction of every
    figure in §6.  Energy per 16-bit access:
        RF:    0.03 pJ @ 16 B, linear in size        (0.03 * size/16)
        SRAM:  6 pJ @ 32 KB, x1.5 per size doubling  (6 * 1.5^log2(S/32K))
        MAC:   0.075 pJ      hop: 0.035 pJ           DRAM: 200 pJ
  * TPU v5e — time-per-byte table for the mapper/roofline (197 TFLOP/s bf16,
    819 GB/s HBM, ~50 GB/s/link ICI, ~  VMEM modeled as compute-rate-matched).

The performance model is the same max() roofline the paper uses implicitly
("keeping throughput constant"): latency = max(compute, each level's
bandwidth term), assuming double-buffered overlap (paper Fig 5).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Mapping

from repro.core.reuse import AccessCounts, analyze
from repro.core.schedule import Schedule

# ------------------------------------------------------------------ tables --

RF_BASE_BYTES = 16
RF_BASE_PJ = 0.03
SRAM_BASE_BYTES = 32 * 1024
SRAM_BASE_PJ = 6.0
SRAM_DOUBLING = 1.5
MAC_PJ = 0.075
HOP_PJ = 0.035
DRAM_PJ = 200.0
RF_SRAM_CROSSOVER_BYTES = 4096  # below this, model as RF; above, as SRAM


def asic_access_energy_pj(capacity_bytes: int | None) -> float:
    """Energy per 16-bit access for a memory of the given capacity."""
    if capacity_bytes is None:
        return DRAM_PJ
    if capacity_bytes <= RF_SRAM_CROSSOVER_BYTES:
        return RF_BASE_PJ * capacity_bytes / RF_BASE_BYTES
    return SRAM_BASE_PJ * SRAM_DOUBLING ** math.log2(capacity_bytes / SRAM_BASE_BYTES)


@dataclasses.dataclass(frozen=True)
class CostTable:
    """Energy per access per level + datapath/communication costs."""

    level_pj: tuple[float, ...]
    mac_pj: float = MAC_PJ
    hop_pj: float = HOP_PJ

    @classmethod
    def for_levels(cls, levels) -> "CostTable":
        """Paper Table 3 energies for a hierarchy, independent of any
        schedule — build once per hardware config and share across the
        whole layer/blocking sweep (the table depends only on capacities)."""
        return cls(
            level_pj=tuple(
                asic_access_energy_pj(lvl.capacity_bytes) for lvl in levels
            )
        )

    @classmethod
    def asic_28nm(cls, schedule: Schedule) -> "CostTable":
        return cls.for_levels(schedule.levels)


# -------------------------------------------------------------- abft cost --


@dataclasses.dataclass(frozen=True)
class AbftCost:
    """Extra work the ABFT column checksum (kernels/abft.py) adds to one
    (M, N, K) matmul blocked at row-block ``bm``: the e^T·A checksum row
    is *appended to A* and rides the product GEMM (one extra output row
    per row block — the classical Huang–Abraham construction), every
    output element is reduced into a column sum, and the tolerance comes
    from static per-column |B| stats precomputed once at init (so B is
    never re-read per call).  Counted separately from the base matmul so
    schedule sweeps can report the surcharge as a ratio."""

    macs: int   # the fused checksum row: one extra GEMM row per row block
    adds: int   # column sums of C + the e^T reduction of A + compares
    words: int  # A read by the e^T reduction + checksum rows, top level

    @property
    def ops(self) -> int:
        return self.macs + self.adds


def abft_matmul_cost(M: int, N: int, K: int, bm: int) -> AbftCost:
    """Count the fused checksum side-channel.  Everything is
    O(M·K + K·N + M·N) arithmetic but only O(M·K + N) *traffic* — the
    O(M·K·N) product is never redone (the Huang–Abraham identity) and B
    is never re-read (the checksum row shares the product's weight pass;
    the tolerance scale is static).  On a memory-bound serving step the
    traffic term is the one that matters."""
    nrb = -(-M // bm)
    return AbftCost(
        # (e^T·A)·B per row block, fused as one extra GEMM output row
        macs=nrb * K * N,
        # in-kernel column sums (each output element reduced once), the
        # e^T column reduction of A, and the final compares
        adds=M * N + M * K + nrb * N,
        # A re-read by the e^T reduction, checksum rows written + read;
        # B rides the product's own pass, so it never re-crosses the top
        words=M * K + 2 * nrb * N,
    )


def abft_energy_pj(cost: AbftCost, table: CostTable) -> float:
    """Price the surcharge under a paper Table-3 cost table: arithmetic at
    MAC cost (an fp32 add/compare is bounded above by a MAC) and traffic
    at the outermost level's per-access energy — the checksum row
    streams its extra operands once and never tiles into the hierarchy."""
    return cost.ops * table.mac_pj + cost.words * table.level_pj[-1]


# ------------------------------------------------- serve decode traffic --


@dataclasses.dataclass(frozen=True)
class AttentionGatherCost:
    """Per-row, per-layer decode-attention HBM traffic under the paged (or
    split-pinned contiguous) flash-decoding kernel, as a function of
    ``(block_size, kv_splits, live length)`` — the quantity the serve-config
    planner (core/serveplan.py) sweeps.  All counts are 16-bit words."""

    kv_words: int       # K+V reads, padded to whole blocks (fragmentation)
    table_words: int    # block-table entries prefetched for the row
    partial_words: int  # per-split online-softmax partials (m, l, acc)

    @property
    def words(self) -> int:
        return self.kv_words + self.table_words + self.partial_words


def attention_gather_cost(
    ctx_len: int,
    *,
    block_size: int,
    kv_heads: int,
    head_dim: int,
    kv_splits: int | None = None,
) -> AttentionGatherCost:
    """Count one row's decode-attention gather for one layer.

    The kernel reads ``ceil(ctx_len / block_size)`` whole KV blocks per kv
    head (the tail block is read in full even when mostly dead — the
    internal-fragmentation cost of a large ``block_size``), prefetches that
    many block-table entries, and writes+combines one ``(head_dim + 2)``
    online-softmax partial per split per kv head (``m``, ``l``, and the
    accumulator row).  ``kv_splits`` defaults to the live block count (the
    paged kernel's grid skips dead splits); the contiguous twin pins it to
    ``max_len / decode_block`` and pays the full combine."""
    if ctx_len < 1 or block_size < 1:
        raise ValueError(
            f"ctx_len and block_size must be >= 1: {ctx_len}, {block_size}"
        )
    blocks = -(-ctx_len // block_size)
    splits = blocks if kv_splits is None else max(kv_splits, 1)
    return AttentionGatherCost(
        kv_words=2 * blocks * block_size * kv_heads * head_dim,
        table_words=blocks,
        # each split's partial is written by the split pass and read by the
        # combine pass, hence the factor 2
        partial_words=2 * splits * kv_heads * (head_dim + 2),
    )


def serve_step_energy_pj(
    macs: float, hbm_words: float, vmem_words: float, vmem_bytes: int
) -> float:
    """Paper Table-3 pricing of one decode step: MACs at datapath cost, HBM
    words at DRAM cost, VMEM words at the SRAM energy of the given capacity
    — the E = sum #acc_i * e_i contraction with the serve hierarchy's two
    levels.  Used by core/serveplan.py to report energy-per-token next to
    the throughput roofline."""
    return (
        macs * MAC_PJ
        + hbm_words * DRAM_PJ
        + vmem_words * asic_access_energy_pj(vmem_bytes)
    )


# TPU v5e constants (per chip) — shared with benchmarks/roofline.py.
TPU_PEAK_FLOPS_BF16 = 197e12
TPU_HBM_BYTES_PER_S = 819e9
TPU_ICI_BYTES_PER_S_PER_LINK = 50e9
TPU_VMEM_BYTES = 64 * 1024 * 1024          # usable VMEM working-set budget
TPU_HBM_BYTES = 16 * 1024**3


# ------------------------------------------------------------------ report --


@dataclasses.dataclass(frozen=True)
class Report:
    """Energy/perf evaluation of one schedule under one cost table."""

    schedule: Schedule
    access: AccessCounts
    energy_pj: float
    breakdown_pj: Mapping[str, float]      # per level name + "mac" + "array"
    cycles: float
    utilization: float

    @property
    def edp(self) -> float:
        return self.energy_pj * self.cycles

    def tops_per_watt(self, freq_hz: float = 400e6) -> float:
        """2 ops per MAC; paper reports TOPs/W at 400 MHz designs."""
        joules = self.energy_pj * 1e-12
        seconds = self.cycles / freq_hz
        watts = joules / seconds
        return (2 * self.access.macs / seconds) / watts / 1e12


def evaluate(
    schedule: Schedule,
    table: CostTable | None = None,
    access: AccessCounts | None = None,
) -> Report:
    table = table or CostTable.asic_28nm(schedule)
    acc = access if access is not None else analyze(schedule)

    breakdown: dict[str, float] = {}
    total = 0.0
    for l, lvl in enumerate(schedule.levels):
        n = acc.level_total(l)
        e = n * table.level_pj[l]
        breakdown[lvl.name] = e
        total += e
    mac_e = acc.macs * table.mac_pj
    hop_e = sum(acc.hops.values()) * table.hop_pj
    breakdown["mac"] = mac_e
    breakdown["array"] = hop_e
    total += mac_e + hop_e

    # perf: each PE does 1 MAC/cycle; levels stream at their bandwidth.
    compute_cycles = schedule.temporal_trips()
    cycles = float(compute_cycles)
    for l, lvl in enumerate(schedule.levels):
        bw = lvl.bandwidth_words_per_cycle
        if math.isfinite(bw):
            cycles = max(cycles, acc.level_total(l) / bw)

    return Report(
        schedule=schedule,
        access=acc,
        energy_pj=total,
        breakdown_pj=breakdown,
        cycles=cycles,
        utilization=acc.utilization,
    )
