"""Schedules: blocking + reorder + spatial unrolling + memory placement.

This is the normalized form that the paper's Halide schedule primitives lower
to (paper §4, Table 2):

    split / reorder      -> per-level tiling factors + per-level loop order
    in / compute_at      -> the memory-level structure (which buffer holds
                            which tile, introduced at which loop)
    unroll (+ systolic)  -> spatial assignment of loops to PE-array dims,
                            with replication = several loops on one dim
    accelerate           -> the scope (the whole nest here)

A `Schedule` fully determines the access counts at every memory level (see
reuse.py) and therefore energy/performance under a cost table (energy.py).
`halide.py` provides the paper-facing fluent front-end that lowers to this.

Level convention: index 0 is the innermost (RF next to the MAC), the last
level is off-chip DRAM/HBM.  The PE array sits between level 0 and level 1:
level-1 buffers feed the whole array; level-0 buffers are per-PE.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Sequence

from repro.core.loopnest import LoopNest


@dataclasses.dataclass(frozen=True)
class MemLevel:
    """One level of the storage hierarchy.

    capacity_bytes: None means unbounded (DRAM/HBM).
    bandwidth_words_per_cycle: words/cycle this level can deliver to its child
        (array-wide).  Used for the performance roofline.
    double_buffered: reserve 2x capacity to overlap fill with compute
        (paper Fig 5).
    """

    name: str
    capacity_bytes: int | None = None
    bandwidth_words_per_cycle: float = float("inf")
    double_buffered: bool = True
    # True for levels private to one PE (register files).  Per-PE levels must
    # form a prefix of the hierarchy; the PE array sits between the last
    # per-PE level and the first shared level.
    per_pe: bool = False


@dataclasses.dataclass(frozen=True)
class ArraySpec:
    """Physical PE array: one entry per spatial dimension (1D or 2D)."""

    dims: tuple[int, ...]  # e.g. (16, 16)

    @property
    def num_pes(self) -> int:
        return math.prod(self.dims)


@dataclasses.dataclass(frozen=True)
class Schedule:
    nest: LoopNest
    levels: tuple[MemLevel, ...]
    # tiling[d] = per-level temporal factors, innermost (level 0) first.
    # product(tiling[d]) * spatial factor of d  must equal padded bound of d.
    tiling: Mapping[str, tuple[int, ...]]
    # order[l] = dims innermost-first at level l (all dims must appear once).
    order: tuple[tuple[str, ...], ...]
    array: ArraySpec = ArraySpec(dims=(1,))
    # spatial[a] = loops unrolled on array dim a, ordered nearest-first
    # (replication = len > 1, paper Fig 2/3).  (dim, factor) pairs.
    spatial: tuple[tuple[tuple[str, int], ...], ...] = ((),)
    word_bytes: int = 2  # 16-bit arithmetic throughout the paper (§5)

    # ---------------------------------------------------------- validation --
    def __post_init__(self):
        L = len(self.levels)
        flags = [lvl.per_pe for lvl in self.levels]
        if any(flags[i] and not all(flags[:i]) for i in range(L)):
            raise ValueError("per-PE levels must form a prefix of the hierarchy")
        if len(self.order) != L:
            raise ValueError(f"need one loop order per level ({L}), got {len(self.order)}")
        if len(self.spatial) != len(self.array.dims):
            raise ValueError("one spatial assignment per array dim required")
        for d in self.nest.dims:
            if d not in self.tiling:
                raise ValueError(f"dim {d} missing from tiling")
            if len(self.tiling[d]) != L:
                raise ValueError(f"tiling[{d}] must have {L} factors")
        for l, o in enumerate(self.order):
            if sorted(o) != sorted(self.nest.dims):
                raise ValueError(f"order at level {l} must be a permutation of dims")
        for a, assigns in enumerate(self.spatial):
            cap = self.array.dims[a]
            used = math.prod(f for _, f in assigns) if assigns else 1
            if used > cap:
                raise ValueError(
                    f"spatial dim {a}: {used} PEs assigned but only {cap} available"
                )
        for d in self.nest.dims:
            total = math.prod(self.tiling[d]) * self.spatial_factor(d)
            if total < self.nest.bounds[d]:
                raise ValueError(
                    f"dim {d}: tiling*spatial={total} < bound {self.nest.bounds[d]}"
                )

    # ------------------------------------------------------------- queries --
    @property
    def array_boundary(self) -> int:
        """Index of the first shared (non-per-PE) level: the PE array sits
        between levels `array_boundary - 1` and `array_boundary`.  If no level
        is marked per-PE, the array feeds straight from level 0 (boundary 0),
        i.e. level 0 is shared."""
        for i, lvl in enumerate(self.levels):
            if not lvl.per_pe:
                return i
        return len(self.levels)

    def used_pes(self) -> int:
        return math.prod(
            math.prod(f for _, f in assigns) if assigns else 1
            for assigns in self.spatial
        )

    def spatial_factor(self, dim: str) -> int:
        f = 1
        for assigns in self.spatial:
            for d, s in assigns:
                if d == dim:
                    f *= s
        return f

    def spatial_dims(self) -> frozenset[str]:
        return frozenset(d for assigns in self.spatial for d, _ in assigns)

    def padded_bound(self, dim: str) -> int:
        return math.prod(self.tiling[dim]) * self.spatial_factor(dim)

    def padded_macs(self) -> int:
        return math.prod(self.padded_bound(d) for d in self.nest.dims)

    def temporal_trips(self) -> int:
        """Total temporal iterations = padded MACs / PEs actually used."""
        return math.prod(
            math.prod(self.tiling[d]) for d in self.nest.dims
        )

    def utilization(self) -> float:
        """Active-PE ratio x padding efficiency (paper Fig 9)."""
        pad = self.nest.macs() / self.padded_macs()
        return (self.used_pes() / self.array.num_pes) * pad

    def cum_tile(self, level: int, include_spatial: bool) -> dict[str, int]:
        """Iteration-space tile covered by levels 0..level (inclusive)."""
        tile: dict[str, int] = {}
        for d in self.nest.dims:
            t = math.prod(self.tiling[d][: level + 1]) if level >= 0 else 1
            if include_spatial:
                t *= self.spatial_factor(d)
            tile[d] = t
        return tile

    def child_tile(self, level: int) -> dict[str, int]:
        """Tile streamed between `level` and its child (see module docstring).

        child of level 0 = one MAC operand; child of the array-boundary level
        = the array-wide union of the per-PE tiles below it; child of any
        other level l = the level l-1 tile.
        """
        if level == 0:
            return {d: 1 for d in self.nest.dims}
        return self.cum_tile(level - 1, include_spatial=(level > self.array_boundary - 1))

    def loops_at_and_above(self, level: int) -> list[tuple[str, int]]:
        """Temporal loops from the innermost loop of `level` to the top,
        innermost-first: (dim, trip) with trip = tiling factor at that level."""
        out: list[tuple[str, int]] = []
        for l in range(level, len(self.levels)):
            for d in self.order[l]:
                out.append((d, self.tiling[d][l]))
        return out

    def footprint_bytes(self, level: int) -> int:
        """Bytes buffered at `level` (sum over tensors, incl. double buffer).

        Shared levels hold the array-wide tile of levels <= l; per-PE levels
        hold the per-PE tile (capacity_bytes for them is per-PE capacity).
        """
        tile = self.cum_tile(level, include_spatial=(level >= self.array_boundary))
        total = 0
        for t in self.nest.tensors:
            total += t.tile_elems(tile) * self.word_bytes
        lvl = self.levels[level]
        return total * (2 if lvl.double_buffered else 1)

    def fits(self) -> bool:
        for l, lvl in enumerate(self.levels):
            if lvl.capacity_bytes is None:
                continue
            if self.footprint_bytes(l) > lvl.capacity_bytes:
                return False
        return True

    def key(self) -> tuple:
        """Hashable structural identity: two schedules with equal keys have
        identical access counts/energy.  Used by the search memo caches."""
        return (
            self.nest.key(),
            self.levels,
            tuple((d, self.tiling[d]) for d in self.nest.dims),
            self.order,
            self.array.dims,
            self.spatial,
            self.word_bytes,
        )

    def as_arrays(self) -> tuple[list[list[int]], list[list[int]]]:
        """(tiling, order-index) matrices for the batched cost engine.

        Both are L x D nested lists, level 0 first; order rows hold indices
        into `nest.dims`, innermost-first.  See costmodel.BatchedCostModel.
        """
        dims = self.nest.dims
        idx = {d: i for i, d in enumerate(dims)}
        til = [[self.tiling[d][l] for d in dims] for l in range(len(self.levels))]
        orders = [[idx[d] for d in self.order[l]] for l in range(len(self.levels))]
        return til, orders

    def describe(self) -> str:
        """Human-readable schedule, paper-style."""
        lines = [f"nest {self.nest.name}: bounds {dict(self.nest.bounds)}"]
        for a, assigns in enumerate(self.spatial):
            if assigns:
                lines.append(
                    f"  array dim {a}: "
                    + " ".join(f"{d}:{f}" for d, f in assigns)
                )
        for l in range(len(self.levels) - 1, -1, -1):
            active = [
                f"{d}:{self.tiling[d][l]}"
                for d in reversed(self.order[l])
                if self.tiling[d][l] > 1
            ]
            lines.append(f"  {self.levels[l].name}: " + (" ".join(active) or "-"))
        return "\n".join(lines)


def uniform_order(nest: LoopNest, order: Sequence[str], num_levels: int) -> tuple:
    return tuple(tuple(order) for _ in range(num_levels))


def flat_schedule(
    nest: LoopNest,
    levels: Sequence[MemLevel],
    array: ArraySpec | None = None,
    spatial: Sequence[Sequence[tuple[str, int]]] | None = None,
    order: Sequence[str] | None = None,
) -> Schedule:
    """Degenerate schedule: everything at the top level (no blocking).

    Spatial factors, if given, are peeled off the top-level tiling.
    """
    array = array or ArraySpec(dims=(1,))
    spatial = tuple(tuple(s) for s in (spatial or [()] * len(array.dims)))
    L = len(levels)
    tiling: dict[str, tuple[int, ...]] = {}
    sp_factor = {d: 1 for d in nest.dims}
    for assigns in spatial:
        for d, f in assigns:
            sp_factor[d] *= f
    for d in nest.dims:
        top = math.ceil(nest.bounds[d] / sp_factor[d])
        tiling[d] = tuple([1] * (L - 1) + [top])
    o = tuple(order or nest.dims)
    return Schedule(
        nest=nest,
        levels=tuple(levels),
        tiling=tiling,
        order=uniform_order(nest, o, L),
        array=array,
        spatial=spatial,
    )
