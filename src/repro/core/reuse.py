"""Analytical per-level access-count model (paper §5).

The paper computes total memory energy as

    E = sum_i  #acc_i * e_i        with   #acc_i driven by per-level reuse

where the reuse at each level follows from the blocked/reordered loop nest.
We implement the standard order-dependent stationarity model (equivalent to
the Interstellar/Timeloop accounting):

  * The level-l buffer of tensor T holds exactly the child tile (the tile
    defined by all tiling factors at levels < l, plus the spatial factors
    once the boundary crosses the PE array).
  * Walking the temporal loops upward from the level-l boundary, the child
    tile stays resident ("stationary") across consecutive innermost loops
    that are IRRELEVANT to T (they do not change which elements are needed);
    the first relevant loop - and everything above it - forces a re-stream.

        reloads(T, l) = (prod of all temporal trips at levels >= l)
                        / (prod of trips of the consecutive innermost
                           irrelevant loops at the boundary)

        reads_at(l, T) = reloads(T, l) * |child tile of T at l|

  * The output tensor accumulates: every re-stream is a write of partial
    sums up the hierarchy plus a read back later, except each element's
    final value which is written once and never read back:

        writes_at(l, O) = updates(l)            (= reloads * child tile)
        reads_at(l, O)  = updates(l) - |O|      (clamped at 0)

  * Level 0 is the per-PE register file: its access count is the per-MAC
    operand traffic (the boundary is the MAC datapath itself).  The same
    formula applies with level -1 defined as a single element; innermost
    stationary operands (e.g. weight-stationary) are held in the operand
    latch and do not re-read the RF, which matches the paper's note that
    MAC activity factors are low under stationary patterns.

  * The PE array is the paper's extra "inter-PE" level: data whose spatially
    unrolled dims are irrelevant is multicast (hop energy per extra PE
    traversed); spatially unrolled reduction dims accumulate outputs across
    PEs (systolic drains).  Replicated loops mapped farther on the same
    physical dim pay proportionally longer hop distances (paper Fig 3).

Sliding-window (X/FX) halos enter through TensorRef.tile_elems; partial-tile
overlap reuse between adjacent tiles is not exploited, consistent with the
double-buffered hardware the paper generates.

Validated exactly against the tile-granular simulator in simulate.py
(tests/test_reuse_model.py, incl. hypothesis property sweeps).
"""

from __future__ import annotations

import dataclasses
from typing import Mapping

from repro.core.loopnest import TensorRef
from repro.core.schedule import Schedule


@dataclasses.dataclass(frozen=True)
class AccessCounts:
    """Per-level, per-tensor access counts for one schedule."""

    # reads[l][tensor_name] = number of word reads served BY level l
    reads: tuple[Mapping[str, int], ...]
    writes: tuple[Mapping[str, int], ...]
    # array-level inter-PE hop-weighted word transfers per tensor
    hops: Mapping[str, float]
    macs: int
    utilization: float

    def level_total(self, level: int) -> int:
        return sum(self.reads[level].values()) + sum(self.writes[level].values())


def stationarity(schedule: Schedule, tensor: TensorRef, level: int) -> int:
    """Product of trips of consecutive innermost loops irrelevant to tensor,
    walking upward from the level-`level` boundary.  Trip-1 loops are
    transparent (they do not break stationarity).

    This (with `reloads`) is the semantic definition the batched engine in
    costmodel.py vectorizes; keep the two in lockstep.
    """
    rel = tensor.relevant
    reuse = 1
    for dim, trip in schedule.loops_at_and_above(level):
        if trip == 1:
            continue
        if dim in rel:
            break
        reuse *= trip
    return reuse


def reloads(schedule: Schedule, tensor: TensorRef, level: int) -> int:
    """Times the level-`level` child tile of `tensor` is re-streamed."""
    total = 1
    for _, trip in schedule.loops_at_and_above(level):
        total *= trip
    return total // stationarity(schedule, tensor, level)


# Backwards-compatible private aliases.
_stationarity = stationarity
_reloads = reloads


def analyze(schedule: Schedule) -> AccessCounts:
    nest = schedule.nest
    L = len(schedule.levels)
    reads: list[dict[str, int]] = [dict() for _ in range(L)]
    writes: list[dict[str, int]] = [dict() for _ in range(L)]

    out = nest.output
    total_out = out.tile_elems(
        {d: schedule.padded_bound(d) for d in nest.dims}
    )
    boundary = schedule.array_boundary
    used_pes = schedule.used_pes()
    # Spatial unrolling of reduction dims means every PE produces partials for
    # the SAME outputs: per-PE first-touch totals multiply accordingly.
    red_spatial = 1
    for assigns in schedule.spatial:
        for d, s in assigns:
            if d in nest.reduction_dims:
                red_spatial *= s

    for l in range(L):
        child = schedule.child_tile(l)
        # Levels below the array boundary are per-PE: every active PE issues
        # its own accesses in parallel (so does the MAC datapath at level 0).
        mult = used_pes if l < max(boundary, 1) else 1
        for t in nest.tensors:
            child_elems = t.tile_elems(child)
            n = _reloads(schedule, t, l) * child_elems * mult
            if t.output:
                first = total_out * (red_spatial if l < max(boundary, 1) else 1)
                writes[l][t.name] = n
                reads[l][t.name] = max(0, n - first)
            else:
                reads[l][t.name] = n
                writes[l][t.name] = 0

    # ----------------------------------------------------- array (inter-PE) --
    # Multicast: tensors for which a spatially unrolled dim is irrelevant are
    # broadcast along that physical dim.  Hop-weighted cost: a chain multicast
    # to s PEs costs (s - 1) hops per word; loops mapped farther out on the
    # same physical dim (replication) multiply the distance by the product of
    # nearer factors (paper Fig 3: inter-group hops cost more).
    hops: dict[str, float] = {}
    blevel = min(max(boundary, 1), L - 1)  # level feeding the array
    for t in nest.tensors:
        rel = t.relevant
        h = 0.0
        for assigns in schedule.spatial:
            dist_scale = 1  # product of nearer (left) factors on this dim
            for dim, s in assigns:
                if s > 1:
                    irrelevant = dim not in rel
                    reduction = t.output and dim in nest.reduction_dims
                    if irrelevant or reduction:
                        # words entering the array once fan out (inputs) or
                        # partial sums drain across PEs (outputs)
                        base = (
                            reads[blevel][t.name]
                            if not t.output
                            else writes[blevel][t.name]
                        )
                        h += base * (s - 1) * dist_scale
                dist_scale *= s
        hops[t.name] = h

    return AccessCounts(
        reads=tuple(reads),
        writes=tuple(writes),
        hops=hops,
        macs=nest.macs(),
        utilization=schedule.utilization(),
    )
