"""Memory-resource-allocation design-space exploration (paper §6.3, Fig 10-12).

The paper's headline quantitative result is that re-allocating the *memory*
resources of a fixed PE array — register-file bytes vs on-chip buffer bytes,
one- vs two-level register hierarchies — changes total energy by up to 4.2x
(CNNs), 1.6x (LSTMs) and 1.8x (MLPs) at constant throughput:

  * Fig 10: energy of the best blocking as a function of per-level buffer
    capacity — each capacity point requires a full blocking search, so the
    sweep is a (hierarchy x layer x tiling x order) product space;
  * Fig 11: one- vs two-level register hierarchies at iso total capacity;
  * Fig 12: the iso-throughput resource-allocation frontier across whole
    networks, from which the 4.2x/1.6x/1.8x ratios are read.

This module is that sweep as a subsystem.  The engine exploits the central
factoring of the analytical model: access *counts* depend only on the
schedule (tiling/order/spatial) and the hierarchy's structure (level count,
per-PE prefix) — never on level capacities — while capacities enter only
through per-access energies and feasibility.  So an iso-structure family of
hierarchies shares one candidate frontier (enumerated against the family's
most permissive capacities) and one counts pass; each member then costs one
``level_totals @ level_pj`` contraction plus a vectorized footprint mask
(costmodel.BatchedCostModel.evaluate_hierarchies).  Pricing H hierarchies is
therefore ~H times cheaper than running H blocking searches, which is what
`optimize_network` does sequentially.

Results accumulate into Pareto frontiers over (energy, cycles) with
incremental dominance pruning, and every priced (nest x hierarchy-family)
block can be persisted to an on-disk JSON cache so interrupted or repeated
sweeps are incremental.

Multi-network sweeps fan out over a ``concurrent.futures`` process pool
(``workers > 0``): each distinct nest's frontier pricing is an independent
task.
"""

from __future__ import annotations

import dataclasses
import hashlib
import math
import multiprocessing
import os
from concurrent.futures import ProcessPoolExecutor
from typing import Sequence

import numpy as np

from repro.core.blocking import enumerate_frontier
from repro.core.costmodel import BatchedCostModel
from repro.core.energy import CostTable
from repro.core.jsonstore import atomic_write_json, load_json_dict
from repro.core.loopnest import LoopNest
from repro.core.optimizer import HardwareConfig, candidate_hierarchies, ck_dataflow
from repro.core.schedule import ArraySpec, MemLevel

WORD_BYTES = 2  # 16-bit arithmetic throughout the paper (§5)


# ------------------------------------------------------------------ points --


@dataclasses.dataclass(frozen=True)
class DesignPoint:
    """One resource allocation priced over a whole network."""

    hw: HardwareConfig
    energy_pj: float
    cycles: float

    @property
    def edp(self) -> float:
        return self.energy_pj * self.cycles


def dominates(a: Sequence[float], b: Sequence[float]) -> bool:
    """a dominates b: no worse in every objective, better in at least one."""
    return all(x <= y for x, y in zip(a, b)) and any(
        x < y for x, y in zip(a, b)
    )


def pareto_prune(
    points: Sequence[DesignPoint],
    keys: tuple[str, ...] = ("energy_pj", "cycles"),
) -> list[DesignPoint]:
    """Incremental non-dominated frontier (minimization in every key).

    Points are folded in one at a time: a new point is discarded if any
    frontier member dominates it, otherwise it evicts the members it
    dominates.  Ties (equal vectors) are all kept — never drops a
    non-dominated point (property-tested against the brute-force filter in
    tests/test_dse.py).
    """
    frontier: list[DesignPoint] = []
    vecs: list[tuple[float, ...]] = []
    for p in points:
        v = tuple(getattr(p, k) for k in keys)
        if any(dominates(q, v) for q in vecs):
            continue
        keep = [i for i, q in enumerate(vecs) if not dominates(v, q)]
        frontier = [frontier[i] for i in keep] + [p]
        vecs = [vecs[i] for i in keep] + [v]
    return frontier


# Relative tolerance for the iso-throughput cycle comparison.  The limit is
# the float product ``baseline.cycles * slack``, and a point whose cycles
# were computed through a different sequence of float ops can land one ulp
# above a mathematically-equal limit — most visibly the baseline itself,
# which must always qualify at slack=1.0.
ISO_CYCLES_RTOL = 1e-9


def best_at_iso_throughput(
    points: Sequence[DesignPoint],
    baseline: DesignPoint,
    slack: float = 1.0,
) -> DesignPoint:
    """Lowest-energy point whose cycle count stays within ``slack`` x the
    baseline's — the paper's "keeping throughput constant" constraint (the
    PE array is fixed across the sweep, so cycles differ only through the
    bandwidth roofline).

    The comparison carries a relative epsilon (``ISO_CYCLES_RTOL``) so float
    ties — ``cycles == baseline.cycles * slack`` up to rounding — qualify;
    without it the baseline itself can fail its own constraint at
    ``slack=1.0`` when the product rounds below ``baseline.cycles``.  When
    no point qualifies, the error reports the nearest miss and the slack
    that would admit it instead of discarding the sweep's context."""
    if not points:
        raise ValueError(
            "no design points to choose from (empty sweep — every "
            "candidate hierarchy was infeasible or unpriceable)"
        )
    limit = baseline.cycles * slack
    ok = [p for p in points if p.cycles <= limit * (1.0 + ISO_CYCLES_RTOL)]
    if not ok:
        nearest = min(points, key=lambda p: p.cycles)
        need = (
            nearest.cycles / baseline.cycles
            if baseline.cycles > 0
            else math.inf
        )
        raise ValueError(
            f"no design point meets the throughput constraint: limit "
            f"{limit:.6g} cycles ({slack:g}x baseline {baseline.cycles:.6g});"
            f" nearest miss is {nearest.hw.name!r} at {nearest.cycles:.6g} "
            f"cycles ({nearest.cycles - limit:.6g} over — needs slack >= "
            f"{need:.9g}) out of {len(points)} swept points"
        )
    return min(ok, key=lambda p: p.energy_pj)


# ------------------------------------------------------------------- cache --


class SweepCache:
    """On-disk JSON store of priced (nest x hierarchy-family) blocks.

    Keys hash the nest structure, the family's hierarchy descriptors and the
    enumeration parameters, so re-runs of an interrupted or extended sweep
    only price new blocks.  Writes are atomic (tmp + rename) and
    **merge-on-write**: a flush re-reads the file and folds this process's
    new entries into whatever other sweep processes have published since we
    loaded it (the same read-merge-replace idiom as ``mapper._store_tile``),
    so concurrent sweeps sharing a cache file never clobber each other's
    priced blocks.

    Writes are also batched: ``put`` only marks the entry dirty, and the
    file is rewritten once per ``flush_every`` new entries plus a final
    :meth:`flush` at the end of the sweep — not once per put, which made a
    long sweep's cache I/O O(N^2) in the number of blocks.  An interrupted
    sweep therefore loses at most the last ``flush_every - 1`` priced
    blocks, never the merged prefix."""

    def __init__(self, path: str | None, flush_every: int = 16):
        if flush_every < 1:
            raise ValueError(f"flush_every must be >= 1: {flush_every}")
        self.path = path
        self.flush_every = flush_every
        self._dirty: dict[str, dict] = {}
        self._data: dict[str, dict] = {}
        if path and os.path.exists(path):
            self._data = load_json_dict(path)

    def get(self, key: str) -> dict | None:
        return self._data.get(key)

    def put(self, key: str, value: dict) -> None:
        self._data[key] = value
        self._dirty[key] = value
        if self.path and len(self._dirty) >= self.flush_every:
            self.flush()

    def flush(self) -> None:
        """Publish dirty entries: re-read the file, merge our new entries
        over it, write atomically.  Entries published by other processes
        since load are both preserved on disk and folded into this
        instance, so later ``get``s see them too.  Best-effort like the
        tile cache: an unwritable path keeps the in-memory results."""
        if not self.path or not self._dirty:
            return
        on_disk = load_json_dict(self.path)
        on_disk.update(self._dirty)
        try:
            atomic_write_json(self.path, on_disk)
        except OSError:
            return  # keep entries dirty; a later flush may succeed
        self._data = {**on_disk, **self._data}
        self._dirty = {}


# Bump whenever the enumeration or cost-model arithmetic changes, so stale
# priced blocks from an older algorithm are never served from a cache file.
_SWEEP_CACHE_SCHEMA = "v1"


def _block_key(
    nest: LoopNest,
    array: ArraySpec,
    hws: Sequence[HardwareConfig],
    max_choices_per_level: int,
    max_frontier: int,
) -> str:
    desc = repr(
        (
            _SWEEP_CACHE_SCHEMA,
            nest.key(),
            array.dims,
            tuple(
                (hw.rf_bytes, hw.buffer_bytes, hw.dram_bandwidth_words_per_cycle)
                for hw in hws
            ),
            max_choices_per_level,
            max_frontier,
        )
    )
    return hashlib.sha256(desc.encode()).hexdigest()[:32]


# ------------------------------------------------------------------- sweep --


def _family_signature(hw: HardwareConfig) -> tuple:
    """Hierarchies with equal signatures share level structure (count +
    per-PE prefix + double-buffer flags) and hence share access counts."""
    return (len(hw.rf_bytes), len(hw.buffer_bytes))


def _family_levels(hws: Sequence[HardwareConfig]) -> tuple[MemLevel, ...]:
    """The family's most permissive hierarchy: per-level max capacity (the
    enumeration superset; members mask their own feasibility)."""
    mats = [hw.levels() for hw in hws]
    out = []
    for l, lvl in enumerate(mats[0]):
        caps = [m[l].capacity_bytes for m in mats]
        cap = None if any(c is None for c in caps) else max(caps)
        out.append(dataclasses.replace(lvl, capacity_bytes=cap))
    return tuple(out)


def _price_nest_block(
    nest: LoopNest,
    array: ArraySpec,
    hws: Sequence[HardwareConfig],
    max_choices_per_level: int,
    max_frontier: int,
) -> dict:
    """Price one nest against one iso-structure hierarchy family.

    Returns per-hierarchy best energy/cycles (+inf where no candidate fits)
    as plain lists so results are JSON-cacheable and pool-transportable.
    A family whose most permissive capacities fit no blocking at all, or a
    nest whose counts overflow the batched engine's exact range
    (BatchOverflowError), yields all-infeasible rows — mirroring how
    `optimize_network` skips hierarchies it cannot price, instead of
    aborting the whole sweep.
    """
    df = ck_dataflow(nest, array)
    levels_max = _family_levels(hws)
    try:
        til, odr = enumerate_frontier(
            nest, levels_max, array, df,
            max_choices_per_level=max_choices_per_level,
            max_frontier=max_frontier,
        )
        cm = BatchedCostModel(
            nest, levels_max, array=array, spatial=df.assigns,
            table=CostTable.for_levels(levels_max),
        )
    except ValueError:  # includes BatchOverflowError
        return {
            "energy_pj": [math.inf] * len(hws),
            "cycles": [math.inf] * len(hws),
            "n_candidates": 0,
        }
    tables = [CostTable.for_levels(hw.levels()) for hw in hws]
    bandwidths = np.array(
        [
            [lvl.bandwidth_words_per_cycle for lvl in hw.levels()]
            for hw in hws
        ]
    )
    rep = cm.evaluate_hierarchies(til, odr, tables, bandwidths=bandwidths)
    foot = rep.footprint_words * WORD_BYTES  # (n, L) bytes, un-doubled
    energies, cycles = [], []
    for h, hw in enumerate(hws):
        feasible = np.ones(til.shape[0], dtype=bool)
        for l, lvl in enumerate(hw.levels()):
            if lvl.capacity_bytes is None:
                continue
            need = foot[:, l] * (2 if lvl.double_buffered else 1)
            feasible &= need <= lvl.capacity_bytes
        if not feasible.any():
            energies.append(math.inf)
            cycles.append(math.inf)
            continue
        e = np.where(feasible, rep.energy_pj[h], math.inf)
        j = int(np.argmin(e))
        energies.append(float(e[j]))
        cycles.append(float(rep.cycles[h, j]))
    return {"energy_pj": energies, "cycles": cycles, "n_candidates": int(til.shape[0])}


def _pool_task(args) -> tuple[str, dict]:
    key, nest, array, hws, mcpl, max_frontier = args
    return key, _price_nest_block(nest, array, hws, mcpl, max_frontier)


def sweep_allocations(
    layers: Sequence[LoopNest],
    array: ArraySpec,
    hw_candidates: Sequence[HardwareConfig] | None = None,
    *,
    two_level_rf: bool = False,
    max_choices_per_level: int = 48,
    max_frontier: int = 32768,
    workers: int = 0,
    cache: SweepCache | str | None = None,
) -> list[DesignPoint]:
    """Price every candidate resource allocation over a whole network.

    The hierarchy-batched engine: hierarchies are grouped into iso-structure
    families; each distinct layer shape is enumerated once per family and
    priced under every member in a single 4-D
    (hierarchies x candidates x levels x dims) call.  ``workers > 0`` fans
    the per-nest pricing tasks out over a process pool.  Pass ``cache`` (a
    path or SweepCache) to persist priced blocks; re-runs skip them.

    Returns one DesignPoint per feasible hierarchy (network totals), in the
    candidate order.  Feed the result to :func:`pareto_prune` /
    :func:`best_at_iso_throughput`.
    """
    hws = list(hw_candidates or candidate_hierarchies(array, two_level_rf))
    if isinstance(cache, str):
        cache = SweepCache(cache)

    # distinct nests with multiplicity (networks repeat layer shapes)
    shape_mult: dict[tuple, int] = {}
    shape_nest: dict[tuple, LoopNest] = {}
    for n in layers:
        k = n.key()
        shape_mult[k] = shape_mult.get(k, 0) + 1
        shape_nest.setdefault(k, n)

    families: dict[tuple, list[int]] = {}
    for i, hw in enumerate(hws):
        families.setdefault(_family_signature(hw), []).append(i)

    # assemble the (nest x family) block task list, consulting the cache
    tasks = []
    blocks: dict[tuple[tuple, tuple], dict] = {}
    for sig, idxs in families.items():
        fam = [hws[i] for i in idxs]
        for k, nest in shape_nest.items():
            ckey = _block_key(
                nest, array, fam, max_choices_per_level, max_frontier
            )
            got = cache.get(ckey) if cache else None
            if got is not None:
                blocks[(k, sig)] = got
            else:
                tasks.append(
                    (ckey, nest, array, fam, max_choices_per_level,
                     max_frontier)
                )

    if tasks:
        task_by_key = {t[0]: t for t in tasks}

        def record(ckey: str, blk: dict) -> None:
            # batched persistence: the cache flushes every `flush_every`
            # priced blocks (and once more below), so an interrupted sweep
            # resumes from all but the newest unflushed blocks
            _k, nest, _array, fam, _m, _mf = task_by_key[ckey]
            blocks[(nest.key(), _family_signature(fam[0]))] = blk
            if cache:
                cache.put(ckey, blk)

        try:
            if workers > 0:
                # spawn (not fork): callers may have JAX or other thread
                # pools live in the parent, and fork() under threads can
                # deadlock
                with ProcessPoolExecutor(
                    max_workers=workers,
                    mp_context=multiprocessing.get_context("spawn"),
                ) as pool:
                    for ckey, blk in pool.map(_pool_task, tasks):
                        record(ckey, blk)
            else:
                for t in tasks:
                    record(*_pool_task(t))
        finally:
            if cache:
                cache.flush()

    points: list[DesignPoint] = []
    for sig, idxs in families.items():
        for pos, i in enumerate(idxs):
            total_e = 0.0
            total_c = 0.0
            for k, mult in shape_mult.items():
                blk = blocks[(k, sig)]
                total_e += blk["energy_pj"][pos] * mult
                total_c += blk["cycles"][pos] * mult
            if math.isfinite(total_e):
                points.append(
                    DesignPoint(hw=hws[i], energy_pj=total_e, cycles=total_c)
                )
    return points
