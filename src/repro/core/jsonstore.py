"""Tiny shared atomic JSON-file store (tile cache, DSE sweep cache).

Load is defensive (missing/corrupt files read as empty); writes go through
tmp + rename so readers never see a torn file.
"""

from __future__ import annotations

import json
import os
import tempfile


def load_json_dict(path: str) -> dict:
    """The file's dict contents, or {} on any read/parse problem."""
    try:
        with open(path) as f:
            data = json.load(f)
        return data if isinstance(data, dict) else {}
    except (OSError, json.JSONDecodeError):
        return {}


def atomic_write_json(path: str, data: dict) -> None:
    """Write atomically (tmp + rename); creates parent dirs.  Raises OSError
    on failure after cleaning up the tmp file — callers decide whether the
    store is best-effort."""
    d = os.path.dirname(path) or "."
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(data, f)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
