"""Interstellar core: loop-nest scheduling, analytical model, optimizer.

Public API surface re-exported for convenience; see DESIGN.md §3.
"""

from repro.core.blocking import (
    SearchResult,
    enumerate_frontier,
    iter_blockings,
    search_blocking,
)
from repro.core.costmodel import (
    BatchedCostModel,
    BatchOverflowError,
    BatchReport,
    HierarchySweepReport,
)
from repro.core.dataflow import Dataflow, enumerate_dataflows, make_dataflow
from repro.core.dse import (
    DesignPoint,
    SweepCache,
    best_at_iso_throughput,
    pareto_prune,
    sweep_allocations,
)
from repro.core.energy import CostTable, Report, evaluate
from repro.core.loopnest import (
    LoopNest,
    TensorRef,
    conv_nest,
    depthwise_nest,
    fc_nest,
    matmul_nest,
)
from repro.core.mapper import MatmulTiles, choose_matmul_tiles
from repro.core.optimizer import (
    HardwareConfig,
    NetworkResult,
    evaluate_network,
    eyeriss_like,
    optimize_layer,
    optimize_network,
    tpu_like,
)
from repro.core.reuse import AccessCounts, analyze
from repro.core.schedule import ArraySpec, MemLevel, Schedule, flat_schedule
from repro.core.simulate import simulate

__all__ = [
    "AccessCounts", "ArraySpec", "BatchOverflowError", "BatchReport",
    "BatchedCostModel", "CostTable", "Dataflow", "DesignPoint",
    "HardwareConfig", "HierarchySweepReport", "LoopNest", "MatmulTiles",
    "MemLevel", "NetworkResult", "Report", "Schedule", "SearchResult",
    "SweepCache", "TensorRef", "analyze", "best_at_iso_throughput",
    "choose_matmul_tiles", "conv_nest", "depthwise_nest",
    "enumerate_dataflows", "enumerate_frontier", "evaluate",
    "evaluate_network", "eyeriss_like", "fc_nest", "flat_schedule",
    "iter_blockings", "make_dataflow", "matmul_nest", "optimize_layer",
    "optimize_network", "pareto_prune", "search_blocking", "simulate",
    "sweep_allocations", "tpu_like",
]
