"""The paper's efficient optimizer (§6.3) + baseline hardware configs.

Given a DNN (list of loop nests), an energy cost model, and constraints, find
the hardware resource allocation + per-layer schedules minimizing total
energy at constant throughput.  Pruning per the paper:

  Obs 1: fix the dataflow to C|K (with replication) and search only blocking.
  Obs 2: consider only memory hierarchies where adjacent on-chip level sizes
         sit within a ratio band (~4-16x), so no level dominates energy.

Baselines (paper Fig 14): an Eyeriss-like mobile chip (16x16 PEs, 512 B RF,
128 KB buffer) and a TPU-like cloud chip (128x128 PEs, 8 B reg, 64 KB L1,
28 MB L2).
"""

from __future__ import annotations

import dataclasses
import multiprocessing
from concurrent.futures import ProcessPoolExecutor
from typing import Sequence

from repro.core.blocking import SearchResult, search_blocking
from repro.core.dataflow import Dataflow, make_dataflow
from repro.core.energy import CostTable, Report
from repro.core.loopnest import LoopNest
from repro.core.schedule import ArraySpec, MemLevel


@dataclasses.dataclass(frozen=True)
class HardwareConfig:
    """Resource allocation: the (N, S1, S2, ...) axis of paper Fig 1."""

    name: str
    array: ArraySpec
    rf_bytes: tuple[int, ...]          # per-PE register levels, inner first
    buffer_bytes: tuple[int, ...]      # shared on-chip buffers, inner first
    dram_bandwidth_words_per_cycle: float = 16.0

    def levels(self) -> tuple[MemLevel, ...]:
        lv: list[MemLevel] = []
        for i, b in enumerate(self.rf_bytes):
            lv.append(MemLevel(f"RF{i}" if len(self.rf_bytes) > 1 else "RF",
                               capacity_bytes=b, double_buffered=False,
                               per_pe=True))
        for i, b in enumerate(self.buffer_bytes):
            lv.append(MemLevel(f"BUF{i}" if len(self.buffer_bytes) > 1 else "BUF",
                               capacity_bytes=b, double_buffered=True))
        lv.append(MemLevel("DRAM", capacity_bytes=None,
                           bandwidth_words_per_cycle=self.dram_bandwidth_words_per_cycle))
        return tuple(lv)


def eyeriss_like() -> HardwareConfig:
    """Paper's mobile baseline: Eyeriss-like hierarchy."""
    return HardwareConfig(
        name="eyeriss-like",
        array=ArraySpec(dims=(16, 16)),
        rf_bytes=(512,),
        buffer_bytes=(128 * 1024,),
    )


def tpu_like() -> HardwareConfig:
    """Paper's cloud baseline: 128x128 array, 8 B reg, 64 KB L1, 28 MB L2."""
    return HardwareConfig(
        name="tpu-like",
        array=ArraySpec(dims=(128, 128)),
        rf_bytes=(8,),
        buffer_bytes=(64 * 1024, 28 * 1024 * 1024),
    )


@dataclasses.dataclass
class LayerResult:
    nest: LoopNest
    report: Report
    dataflow: Dataflow


@dataclasses.dataclass
class NetworkResult:
    hw: HardwareConfig
    layers: list[LayerResult]

    @property
    def total_energy_pj(self) -> float:
        return sum(l.report.energy_pj for l in self.layers)

    @property
    def total_cycles(self) -> float:
        return sum(l.report.cycles for l in self.layers)

    @property
    def total_macs(self) -> int:
        return sum(l.nest.macs() for l in self.layers)

    def tops_per_watt(self, freq_hz: float = 400e6) -> float:
        seconds = self.total_cycles / freq_hz
        watts = self.total_energy_pj * 1e-12 / seconds
        return (2 * self.total_macs / seconds) / watts / 1e12


def ck_dataflow(nest: LoopNest, array: ArraySpec) -> Dataflow:
    """Obs 1: the C|K dataflow (with replication fill) used by the optimizer.
    For nests without a C-reduction (depthwise), fall back to K|X."""
    if nest.bounds.get("C", 1) > 1:
        return make_dataflow(nest, array, ("C", "K"), replication=True)
    primaries = [d for d in ("K", "X", "Y", "B") if nest.bounds.get(d, 1) > 1]
    primaries = (primaries + ["K", "X"])[: len(array.dims)]
    return make_dataflow(nest, array, tuple(primaries), replication=True)


# Blocking searches memoized across the hardware sweep: networks repeat
# layer shapes (and sweeps revisit hierarchies), so each structurally
# identical (nest, levels, dataflow, search-params) instance is solved once.
_SEARCH_CACHE: dict[tuple, SearchResult] = {}


def clear_search_cache() -> None:
    _SEARCH_CACHE.clear()


def optimize_layer(
    nest: LoopNest,
    hw: HardwareConfig,
    dataflow: Dataflow | None = None,
    max_evals: int = 0,  # 0 = exhaustive beam search; >0 caps mappings priced
    table: CostTable | None = None,
    beam: int = 24,
    cache: bool = True,
) -> LayerResult:
    df = dataflow or ck_dataflow(nest, hw.array)
    levels = hw.levels()
    tbl = table or CostTable.for_levels(levels)
    key = (
        nest.key(), levels, hw.array.dims, df.assigns, beam, max_evals,
        tbl.level_pj, tbl.mac_pj, tbl.hop_pj,
    )
    res = _SEARCH_CACHE.get(key) if cache else None
    if res is None:
        res = search_blocking(
            nest, levels, hw.array, df, table=tbl,
            beam=beam, max_evals=max_evals,
        )
        if cache:
            _SEARCH_CACHE[key] = res
    rep = res.best
    if rep.schedule.nest is not nest:
        # structural cache hit from an identically-shaped layer: re-label the
        # schedule with this layer's nest so names in reports stay correct
        rep = dataclasses.replace(
            rep, schedule=dataclasses.replace(rep.schedule, nest=nest)
        )
    return LayerResult(nest=nest, report=rep, dataflow=df)


def evaluate_network(
    layers: Sequence[LoopNest],
    hw: HardwareConfig,
    max_evals_per_layer: int = 0,
) -> NetworkResult:
    # One hierarchy -> one cost table, shared across every layer search.
    table = CostTable.for_levels(hw.levels())
    return NetworkResult(
        hw=hw,
        layers=[
            optimize_layer(n, hw, max_evals=max_evals_per_layer, table=table)
            for n in layers
        ],
    )


# ----------------------------------------------------------- hw search -----

RF_CHOICES = (16, 32, 64, 128, 256, 512)
BUF_CHOICES = tuple(k * 1024 for k in (32, 64, 128, 256, 512))


def candidate_hierarchies(
    array: ArraySpec,
    two_level_rf: bool = True,
    ratio_band: tuple[int, int] = (4, 16),
) -> list[HardwareConfig]:
    """Obs 2 pruning: adjacent on-chip sizes within the ratio band.

    The RF->buffer ratio is taken per-array-total (paper: RF level capacity is
    per-PE; the balance rule compares total level capacities).
    """
    out: list[HardwareConfig] = []
    n_pe = array.num_pes
    lo, hi = ratio_band
    for rf in RF_CHOICES:
        rf_levels_opts: list[tuple[int, ...]] = [(rf,)]
        if two_level_rf:
            for rf0 in RF_CHOICES:
                if lo <= rf // rf0 <= hi:
                    rf_levels_opts.append((rf0, rf))
        for rf_levels in rf_levels_opts:
            for buf in BUF_CHOICES:
                total_rf = rf_levels[-1] * n_pe
                if not (lo <= buf / total_rf <= hi):
                    continue
                out.append(
                    HardwareConfig(
                        name=f"rf{'+'.join(str(b) for b in rf_levels)}-buf{buf//1024}k",
                        array=array,
                        rf_bytes=rf_levels,
                        buffer_bytes=(buf,),
                    )
                )
    return out


def _eval_network_task(args) -> NetworkResult | None:
    """Process-pool task: one hierarchy priced over the whole network
    (module-level so it pickles; infeasible hierarchies return None)."""
    layers, hw, max_evals = args
    try:
        return evaluate_network(layers, hw, max_evals)
    except ValueError:
        return None


def optimize_network(
    layers: Sequence[LoopNest],
    array: ArraySpec,
    two_level_rf: bool = False,
    max_evals_per_layer: int = 0,
    hw_candidates: Sequence[HardwareConfig] | None = None,
    workers: int = 0,
) -> NetworkResult:
    """The efficient optimizer: search hardware x blocking under Obs 1+2.

    ``workers > 0`` fans the per-hierarchy network evaluations out over a
    ``concurrent.futures`` process pool (each worker keeps its own search
    memo, so repeated layer shapes are still solved once per process).  For
    capacity-only sweeps over many hierarchies, the hierarchy-batched engine
    in core/dse.py is the much faster path: it shares one candidate frontier
    and one counts pass across a whole iso-structure family.
    """
    cands = list(hw_candidates or candidate_hierarchies(array, two_level_rf))
    tasks = [(list(layers), hw, max_evals_per_layer) for hw in cands]

    def reduce_best(results) -> NetworkResult | None:
        # streamed: only the running best NetworkResult stays alive
        best: NetworkResult | None = None
        for res in results:
            if res is None:
                continue
            if best is None or res.total_energy_pj < best.total_energy_pj:
                best = res
        return best

    if workers > 0:
        # spawn (not fork): callers may have JAX or other thread pools
        # live in the parent, and fork() under threads can deadlock
        with ProcessPoolExecutor(
            max_workers=workers,
            mp_context=multiprocessing.get_context("spawn"),
        ) as pool:
            best = reduce_best(pool.map(_eval_network_task, tasks))
    else:
        best = reduce_best(_eval_network_task(t) for t in tasks)
    if best is None:
        raise ValueError("no feasible hardware configuration found")
    return best
