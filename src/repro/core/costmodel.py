"""Batched NumPy cost-model engine: reuse.py + energy.py over many schedules.

The scalar pair `analyze()` (reuse.py) and `evaluate()` (energy.py) walk one
schedule at a time with Python dicts — fine as an oracle, hopeless as the
inner loop of a mapping search that prices hundreds of thousands of
(hardware x layer x tile x order) candidates.  This module evaluates the same
model over a *batch* of candidates at once:

  * tilings become an ``(n, L, D)`` int64 tensor (n candidates, L memory
    levels, D loop dims),
  * per-level loop orders become an ``(n, L, D)`` index tensor (innermost
    first, values index into ``nest.dims``),
  * reloads / stationarity / footprints / hops become vectorized reductions
    over those tensors, and the per-level energies a single dot with the
    ``CostTable`` vector.

All candidates in a batch share the nest, the memory hierarchy, the PE array
and the spatial (dataflow) assignment — exactly the shape of a blocking
search frontier.  Counts are computed in int64 and energies in float64 with
the *same operation ordering* as the scalar path, so results are bit-identical
to `evaluate()`; `tests/test_costmodel.py` enforces this differentially on
randomized schedules.  The scalar path remains the semantic oracle (see
`reuse.stationarity` / `reuse.reloads` for the model definition).

Schedules whose counts could overflow int64 (or lose float exactness past
2**53 in the hop accumulation) raise :class:`BatchOverflowError` at
construction; callers fall back to the scalar oracle.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.core.energy import CostTable
from repro.core.loopnest import LoopNest
from repro.core.schedule import ArraySpec, MemLevel, Schedule

# Rows per internal chunk: bounds peak memory of the (n, L*D) intermediates.
_CHUNK = 32768

# Safety margin for int64 count arithmetic (and exact float accumulation).
_MAX_COUNT = 2 ** 52


class BatchOverflowError(ValueError):
    """Counts for this nest/hierarchy may exceed exact integer range."""


@dataclasses.dataclass(frozen=True)
class BatchReport:
    """Vectorized analogue of (AccessCounts, Report) for a batch.

    Index [i] of every array corresponds to candidate i of the batch.
    """

    energy_pj: np.ndarray       # (n,)   float64
    level_totals: np.ndarray    # (n, L) int64, reads+writes served by level
    reads: np.ndarray           # (n, L, T) int64, T = len(nest.tensors)
    writes: np.ndarray          # (n, L, T) int64
    hops: np.ndarray            # (n, T) float64 hop-weighted word transfers
    cycles: np.ndarray          # (n,)   float64
    utilization: np.ndarray     # (n,)   float64
    macs: int


@dataclasses.dataclass(frozen=True)
class HierarchySweepReport:
    """One counts pass priced under H cost tables (the DSE inner product).

    Access counts depend only on (tiling, order, spatial, per-PE structure)
    — never on level capacities — so a whole iso-structure family of memory
    hierarchies shares the count tensors and differs only in the final
    ``level_totals @ level_pj`` contraction.  ``energy_pj[h, i]`` is
    candidate i priced under hierarchy h's cost table, bit-identical to
    ``evaluate()`` under that table.
    """

    energy_pj: np.ndarray         # (H, n) float64
    cycles: np.ndarray            # (H, n) float64
    # Count-side fields are hierarchy-independent for shared (n, L, D)
    # candidates: (n, L) / (n,).  With per-hierarchy 4-D candidate blocks
    # they gain a leading axis: (H, n, L) / (H, n).
    level_totals: np.ndarray      # int64
    footprint_words: np.ndarray   # int64 (un-doubled words, see
    #                               footprint_words(): caller applies
    #                               word_bytes and double-buffer factors)
    utilization: np.ndarray       # float64
    macs: int


class BatchedCostModel:
    """Prices batches of candidate schedules sharing one (nest, hw, dataflow).

    Parameters mirror `Schedule` minus the per-candidate tiling/order, which
    arrive as arrays at evaluation time.  `pack()` converts `Schedule`
    objects to those arrays for differential testing.
    """

    def __init__(
        self,
        nest: LoopNest,
        levels: Sequence[MemLevel],
        array: ArraySpec | None = None,
        spatial: tuple = ((),),
        table: CostTable | None = None,
        word_bytes: int = 2,
    ):
        self.nest = nest
        self.levels = tuple(levels)
        self.array = array or ArraySpec(dims=tuple(1 for _ in spatial))
        self.spatial = tuple(tuple(a) for a in spatial)
        self.word_bytes = word_bytes
        self.table = table or CostTable.for_levels(self.levels)

        self.dims = tuple(nest.dims)
        self.D = len(self.dims)
        self.L = len(self.levels)
        self.dim_index = {d: i for i, d in enumerate(self.dims)}
        self.tensors = nest.tensors
        self.T = len(self.tensors)
        self.out_i = next(i for i, t in enumerate(self.tensors) if t.output)

        flags = [lvl.per_pe for lvl in self.levels]
        if any(flags[i] and not all(flags[:i]) for i in range(self.L)):
            raise ValueError("per-PE levels must form a prefix of the hierarchy")
        self.boundary = next(
            (i for i, lvl in enumerate(self.levels) if not lvl.per_pe), self.L
        )
        self.blevel = min(max(self.boundary, 1), self.L - 1)

        sp_factor = {d: 1 for d in self.dims}
        used_pes = 1
        red_spatial = 1
        for assigns in self.spatial:
            for d, s in assigns:
                sp_factor[d] *= s
                used_pes *= s
                if d in nest.reduction_dims:
                    red_spatial *= s
        self.sp = np.array([sp_factor[d] for d in self.dims], dtype=np.int64)
        self.used_pes = used_pes
        self.red_spatial = red_spatial

        # per-tensor structure: relevance vector + coupled/uncoupled split
        self.rel_vecs: list[np.ndarray] = []
        self.coupled: list[list[tuple[int, int, int]]] = []
        self.plain: list[list[int]] = []
        for t in self.tensors:
            rel = t.relevant
            self.rel_vecs.append(
                np.array([d in rel for d in self.dims], dtype=bool)
            )
            pairs = []
            handled: set[str] = set()
            for base, (filt, stride) in t.coupled.items():
                pairs.append((self.dim_index[base], self.dim_index[filt], stride))
                handled.add(base)
                handled.add(filt)
            self.coupled.append(pairs)
            self.plain.append(
                [self.dim_index[d] for d in t.dims if d not in handled]
            )

        self.pj = tuple(self.table.level_pj)
        if len(self.pj) != self.L:
            raise ValueError("cost table does not match hierarchy depth")
        self.macs = nest.macs()

        # overflow guard: largest hop distance term and the padded-MAC limit
        # below which every count the model produces stays in exact range
        hop_scale = 1
        for assigns in self.spatial:
            dist = 1
            for _, s in assigns:
                hop_scale = max(hop_scale, (s - 1) * dist)
                dist *= s
        # 2**D covers sliding-window halo inflation of tile_elems
        self._max_padded_macs = _MAX_COUNT / (
            self.used_pes * hop_scale * (2 ** self.D)
        )
        self.check_range(
            {
                d: math.ceil(nest.bounds[d] / int(self.sp[j]))
                for j, d in enumerate(self.dims)
            }
        )

    # -------------------------------------------------------------- helpers --

    def _elems(self, t_i: int, tile: np.ndarray) -> np.ndarray:
        """Vectorized TensorRef.tile_elems over a (n, D) tile array."""
        n = np.ones(tile.shape[0], dtype=np.int64)
        for base, filt, stride in self.coupled[t_i]:
            n = n * (stride * (tile[:, base] - 1) + tile[:, filt])
        for d in self.plain[t_i]:
            n = n * tile[:, d]
        return n

    def check_range(self, full_rem: dict[str, int]) -> None:
        """Raise BatchOverflowError if counts could exceed exact range.

        `full_rem` is the per-dim product of all temporal factors (constant
        across a search frontier: factors always multiply to the padded
        bound).  The coarse bound dominates every count and hop term the
        model produces.  Called automatically at construction with the
        nest's own bounds; `_counts` re-checks each batch's actual padded
        sizes, so tilings that pad beyond the nest bounds are caught too.
        """
        padded = 1
        for d in self.dims:
            padded *= full_rem[d] * int(self.sp[self.dim_index[d]])
        if padded > self._max_padded_macs:
            raise BatchOverflowError(
                f"counts for nest {self.nest.name} may overflow the batched "
                "engine; use the scalar oracle"
            )

    def pack(
        self, schedules: Sequence[Schedule]
    ) -> tuple[np.ndarray, np.ndarray]:
        """Convert Schedule objects to (tilings, orders) arrays."""
        n = len(schedules)
        til = np.empty((n, self.L, self.D), dtype=np.int64)
        orders = np.empty((n, self.L, self.D), dtype=np.int64)
        for i, s in enumerate(schedules):
            t_m, o_m = s.as_arrays()
            til[i] = t_m
            orders[i] = o_m
        return til, orders

    # ------------------------------------------------------------- pricing --

    def evaluate(self, tilings: np.ndarray, orders: np.ndarray) -> BatchReport:
        """Full batched analyze()+evaluate(): energies, counts, cycles."""
        tilings = np.asarray(tilings, dtype=np.int64)
        orders = np.asarray(orders, dtype=np.int64)
        n = tilings.shape[0]
        parts = [
            self._evaluate_chunk(tilings[i : i + _CHUNK], orders[i : i + _CHUNK])
            for i in range(0, n, _CHUNK)
        ]
        if not parts:
            z = np.zeros(0)
            zi = np.zeros((0, self.L), np.int64)
            return BatchReport(z, zi, np.zeros((0, self.L, self.T), np.int64),
                               np.zeros((0, self.L, self.T), np.int64),
                               np.zeros((0, self.T)), z, z, self.macs)
        if len(parts) == 1:
            return parts[0]
        return BatchReport(
            *(np.concatenate([getattr(p, f.name) for p in parts])
              for f in dataclasses.fields(BatchReport)[:-1]),
            self.macs,
        )

    def energy(self, tilings: np.ndarray, orders: np.ndarray) -> np.ndarray:
        return self.evaluate(tilings, orders).energy_pj

    def _counts(self, til: np.ndarray, orders: np.ndarray):
        """Core vectorized access-count model for one chunk.

        Returns (reads, writes, cum, suffix) with reads/writes (n, L, T).
        """
        n = til.shape[0]
        L, D, T = self.L, self.D, self.T
        P = L * D

        # trips of the flattened temporal loop stack, innermost first
        trips = np.take_along_axis(til, orders, axis=2).reshape(n, P)
        # suffix[p] = product of trips at positions >= p  (suffix[P] = 1)
        suffix = np.ones((n, P + 1), dtype=np.int64)
        suffix[:, :-1] = np.cumprod(trips[:, ::-1], axis=1)[:, ::-1]

        # guard the whole chunk in float (immune to int64 wraparound)
        padded_f = (til.astype(np.float64).prod(axis=1) * self.sp).prod(axis=1)
        if padded_f.max(initial=0.0) > self._max_padded_macs:
            raise BatchOverflowError(
                f"tilings for nest {self.nest.name} exceed the batched "
                "engine's exact integer range; use the scalar oracle"
            )

        cum = np.cumprod(til, axis=1)          # (n, L, D) tiles through level l
        padded = cum[:, -1, :] * self.sp       # (n, D)

        # child tile streamed into each level (see Schedule.child_tile)
        childs: list[np.ndarray] = []
        for l in range(L):
            if l == 0:
                childs.append(np.ones((n, D), dtype=np.int64))
            else:
                c = cum[:, l - 1, :]
                if l >= self.boundary:
                    c = c * self.sp
                childs.append(c)

        total_out = self._elems(self.out_i, padded)
        reads = np.zeros((n, L, T), dtype=np.int64)
        writes = np.zeros((n, L, T), dtype=np.int64)
        for t_i, t in enumerate(self.tensors):
            relpos = self.rel_vecs[t_i][orders].reshape(n, P)
            brk = np.cumsum(relpos & (trips > 1), axis=1)  # inclusive count
            for l in range(L):
                l0 = l * D
                base = brk[:, l0] - (relpos[:, l0] & (trips[:, l0] > 1))
                keep = (brk[:, l0:] - base[:, None]) == 0
                stat = np.where(keep, trips[:, l0:], 1).prod(axis=1)
                reloads = suffix[:, l0] // stat
                mult = self.used_pes if l < max(self.boundary, 1) else 1
                acc = reloads * self._elems(t_i, childs[l]) * mult
                if t.output:
                    first = total_out * (
                        self.red_spatial if l < max(self.boundary, 1) else 1
                    )
                    writes[:, l, t_i] = acc
                    reads[:, l, t_i] = np.maximum(0, acc - first)
                else:
                    reads[:, l, t_i] = acc
        return reads, writes, padded, suffix

    def _hops(self, reads: np.ndarray, writes: np.ndarray) -> np.ndarray:
        """Hop-weighted inter-PE transfers, same accumulation order as
        reuse.analyze (exact-float: integer terms below 2**53)."""
        n = reads.shape[0]
        hops = np.zeros((n, self.T))
        for t_i, t in enumerate(self.tensors):
            rel = t.relevant
            h = np.zeros(n)
            for assigns in self.spatial:
                dist = 1
                for dim, s in assigns:
                    if s > 1:
                        irrelevant = dim not in rel
                        reduction = t.output and dim in self.nest.reduction_dims
                        if irrelevant or reduction:
                            base = (
                                reads[:, self.blevel, t_i]
                                if not t.output
                                else writes[:, self.blevel, t_i]
                            )
                            h = h + base * ((s - 1) * dist)
                    dist *= s
            hops[:, t_i] = h
        return hops

    def _evaluate_chunk(self, til, orders) -> BatchReport:
        reads, writes, padded, suffix = self._counts(til, orders)
        hops = self._hops(reads, writes)
        n = til.shape[0]

        level_totals = reads.sum(axis=2) + writes.sum(axis=2)  # (n, L)
        total = np.zeros(n)
        for l in range(self.L):
            total = total + level_totals[:, l] * self.pj[l]
        hsum = np.zeros(n)
        for t_i in range(self.T):
            hsum = hsum + hops[:, t_i]
        total = total + (self.macs * self.table.mac_pj + hsum * self.table.hop_pj)

        cycles = suffix[:, 0].astype(np.float64)  # temporal trips
        for l, lvl in enumerate(self.levels):
            bw = lvl.bandwidth_words_per_cycle
            if math.isfinite(bw):
                cycles = np.maximum(cycles, level_totals[:, l] / bw)

        padded_macs = padded.prod(axis=1)
        util = (self.used_pes / self.array.num_pes) * (self.macs / padded_macs)

        return BatchReport(
            energy_pj=total,
            level_totals=level_totals,
            reads=reads,
            writes=writes,
            hops=hops,
            cycles=cycles,
            utilization=util,
            macs=self.macs,
        )

    # --------------------------------------------------- hierarchy sweeps --

    def footprint_words(self, tilings: np.ndarray) -> np.ndarray:
        """Vectorized Schedule.footprint_bytes, in raw words: (n, L) sums of
        per-tensor tile elements at each level (spatial factors folded in at
        and above the array boundary).  Callers apply ``word_bytes`` and each
        hierarchy's double-buffer factor — those are the only parts of the
        footprint that vary across an iso-structure hierarchy family."""
        tilings = np.asarray(tilings, dtype=np.int64)
        n = tilings.shape[0]
        words = np.zeros((n, self.L), dtype=np.int64)
        cum = np.cumprod(tilings, axis=1)
        for l in range(self.L):
            tile = cum[:, l, :]
            if l >= self.boundary:
                tile = tile * self.sp
            for t_i in range(self.T):
                words[:, l] += self._elems(t_i, tile)
        return words

    def evaluate_hierarchies(
        self,
        tilings: np.ndarray,
        orders: np.ndarray,
        tables: Sequence[CostTable],
        bandwidths: np.ndarray | None = None,
    ) -> HierarchySweepReport:
        """Price one candidate frontier under H hierarchies' cost tables.

        ``tilings``/``orders`` are the usual (n, L, D) arrays — or 4-D
        (H, n, L, D) when each hierarchy brings its own candidates, in which
        case counts are computed per hierarchy block.  ``bandwidths`` is an
        optional (H, L) words-per-cycle array for the roofline (defaults to
        the constructor levels' bandwidths for every hierarchy).
        """
        tilings = np.asarray(tilings, dtype=np.int64)
        orders = np.asarray(orders, dtype=np.int64)
        H = len(tables)
        for tbl in tables:
            if len(tbl.level_pj) != self.L:
                raise ValueError("cost table does not match hierarchy depth")
        if tilings.ndim == 4:
            if tilings.shape[0] != H:
                raise ValueError("4-D tilings must have one block per table")
            parts = [
                self.evaluate_hierarchies(
                    tilings[h], orders[h], [tables[h]],
                    None if bandwidths is None else bandwidths[h : h + 1],
                )
                for h in range(H)
            ]
            # count-side fields gain a leading hierarchy axis here — (H, n, L)
            # and (H, n) — because each block has its own candidates, unlike
            # the shared-candidate 3-D path where they are (n, L)/(n,)
            return HierarchySweepReport(
                energy_pj=np.concatenate([p.energy_pj for p in parts]),
                cycles=np.concatenate([p.cycles for p in parts]),
                level_totals=np.stack([p.level_totals for p in parts]),
                footprint_words=np.stack(
                    [p.footprint_words for p in parts]
                ),
                utilization=np.stack([p.utilization for p in parts]),
                macs=self.macs,
            )

        n = tilings.shape[0]
        if bandwidths is None:
            bandwidths = np.tile(
                [lvl.bandwidth_words_per_cycle for lvl in self.levels], (H, 1)
            )
        energy = np.empty((H, n))
        cycles = np.empty((H, n))
        level_totals = np.empty((n, self.L), dtype=np.int64)
        util = np.empty(n)
        for i in range(0, n, _CHUNK):
            til, odr = tilings[i : i + _CHUNK], orders[i : i + _CHUNK]
            reads, writes, padded, suffix = self._counts(til, odr)
            hops = self._hops(reads, writes)
            lt = reads.sum(axis=2) + writes.sum(axis=2)  # (chunk, L)
            hsum = np.zeros(til.shape[0])
            for t_i in range(self.T):
                hsum = hsum + hops[:, t_i]
            trips = suffix[:, 0].astype(np.float64)
            sl = slice(i, i + til.shape[0])
            level_totals[sl] = lt
            util[sl] = (self.used_pes / self.array.num_pes) * (
                self.macs / padded.prod(axis=1)
            )
            # same accumulation order as the scalar evaluate() under each
            # table, so per-hierarchy energies stay bit-identical
            for h, tbl in enumerate(tables):
                tot = np.zeros(til.shape[0])
                for l in range(self.L):
                    tot = tot + lt[:, l] * tbl.level_pj[l]
                energy[h, sl] = tot + (
                    self.macs * tbl.mac_pj + hsum * tbl.hop_pj
                )
                cyc = trips.copy()
                for l in range(self.L):
                    bw = float(bandwidths[h, l])
                    if math.isfinite(bw):
                        cyc = np.maximum(cyc, lt[:, l] / bw)
                cycles[h, sl] = cyc
        return HierarchySweepReport(
            energy_pj=energy,
            cycles=cycles,
            level_totals=level_totals,
            footprint_words=self.footprint_words(tilings),
            utilization=util,
            macs=self.macs,
        )

    def abft_energy_pj(self, tilings: np.ndarray) -> np.ndarray:
        """Per-candidate ABFT checksum surcharge (energy.abft_matmul_cost)
        for a matmul nest: each candidate's row-block is the M footprint
        it keeps resident below the outermost level, which is exactly the
        ``bm`` matmul_pallas_abft emits one checksum row per.  Lets the
        blocking sweep report checked-matmul energy as base + surcharge
        without re-counting the O(M·K·N) product."""
        dims = {d: i for i, d in enumerate(self.dims)}
        if not {"M", "N", "K"} <= set(dims):
            raise ValueError(
                f"abft pricing needs a matmul nest with M/N/K dims, got "
                f"{self.dims}"
            )
        tilings = np.asarray(tilings, dtype=np.int64)
        M = self.nest.bounds["M"]
        N = self.nest.bounds["N"]
        K = self.nest.bounds["K"]
        t_outer = np.maximum(tilings[:, -1, dims["M"]], 1)
        bm = np.maximum(-(-M // t_outer), 1)
        nrb = -(-M // bm)
        ops = (nrb * K * N) + (M * N + M * K + nrb * N)
        words = M * K + 2 * nrb * N
        return ops * self.table.mac_pj + words * self.pj[-1]

    def level_energy(
        self, tilings: np.ndarray, orders: np.ndarray, level: int
    ) -> np.ndarray:
        """Energy of accesses served BY `level` (+ array hops when `level`
        feeds the PE array) — the batched form of blocking._level_energy."""
        tilings = np.asarray(tilings, dtype=np.int64)
        orders = np.asarray(orders, dtype=np.int64)
        n = tilings.shape[0]
        out = np.empty(n)
        for i in range(0, n, _CHUNK):
            til, odr = tilings[i : i + _CHUNK], orders[i : i + _CHUNK]
            reads, writes, _, _ = self._counts(til, odr)
            lt = reads[:, level, :].sum(axis=1) + writes[:, level, :].sum(axis=1)
            e = lt * self.pj[level]
            if level == self.blevel:
                hops = self._hops(reads, writes)
                hsum = np.zeros(til.shape[0])
                for t_i in range(self.T):
                    hsum = hsum + hops[:, t_i]
                e = e + hsum * self.table.hop_pj
            out[i : i + len(e)] = e
        return out


# ----------------------------------------------- serve decode-step pricing --
# Vectorized twin of energy.attention_gather_cost: one call prices the
# decode-attention gather for a whole grid of (block_size, kv_splits, ctx)
# candidates — the serve-config planner (core/serveplan.py) sweeps hundreds
# of knob combinations, and this keeps that sweep a single numpy pass the
# same way evaluate_hierarchies keeps the allocation sweep batched.
# tests/test_autotune.py asserts random-case parity with the scalar.


def attention_gather_words(
    ctx_len: np.ndarray,
    block_size: np.ndarray,
    *,
    kv_heads: int,
    head_dim: int,
    kv_splits: np.ndarray | None = None,
) -> np.ndarray:
    """Per-row per-layer decode-attention words for broadcastable arrays of
    live context lengths, block sizes and split counts (see
    energy.attention_gather_cost for the count definitions)."""
    ctx = np.asarray(ctx_len, dtype=np.int64)
    bs = np.asarray(block_size, dtype=np.int64)
    if (ctx < 1).any() or (bs < 1).any():
        raise ValueError("ctx_len and block_size must be >= 1")
    blocks = -(-ctx // bs)
    splits = blocks if kv_splits is None else np.maximum(
        np.asarray(kv_splits, dtype=np.int64), 1
    )
    kv_words = 2 * blocks * bs * kv_heads * head_dim
    partial_words = 2 * splits * kv_heads * (head_dim + 2)
    return kv_words + blocks + partial_words
