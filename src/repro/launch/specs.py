"""input_specs(): ShapeDtypeStruct stand-ins for every model input.

No device allocation - these drive jit(...).lower() for the dry-run.
Per the assignment: modality frontends are stubs, so whisper gets
precomputed frame embeddings and llava gets patch embeddings.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.arch.model_zoo import build
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig
from repro.configs.registry import get
from repro.train import optim

# cells skipped per the assignment rule: long_500k needs sub-quadratic
# attention -> only SSM / hybrid / local:global archs run it.
def cell_is_live(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False
    return True


def live_cells() -> list[tuple[str, str]]:
    from repro.configs.registry import ARCHS

    out = []
    for arch in sorted(ARCHS):
        for sname in SHAPES:
            if cell_is_live(ARCHS[arch], SHAPES[sname]):
                out.append((arch, sname))
    return out


def choose_microbatches(cfg: ModelConfig, shape: ShapeConfig, n_dp: int) -> int:
    """Smallest power-of-two microbatch count bounding per-device residual
    activation memory (L x B_mb x S x D x 2 bytes with per-layer remat) to
    ~2 GB."""
    if cfg.microbatch_override:
        return cfg.microbatch_override
    budget = 2 * 1024**3
    b_local = max(shape.global_batch // n_dp, 1)
    mb = 1
    layers = cfg.n_layers + cfg.encoder_layers
    while mb < b_local:
        resid = layers * (b_local // mb) * shape.seq_len * cfg.d_model * 2
        if resid <= budget:
            break
        mb *= 2
    return mb


def params_shapes(cfg: ModelConfig) -> Any:
    model = build(cfg)
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


def opt_shapes(params: Any) -> Any:
    return jax.eval_shape(optim.init_state, params)


def cache_shapes(cfg: ModelConfig, batch: int, max_len: int) -> Any:
    """Decode/prefill cache shapes.  Decoder-only families build straight
    from the serve engine's slot-cache module so the dry-run lowers exactly
    what the continuous-batching engine allocates; encdec keeps its model
    hook (cross-attention carries encoder state alongside)."""
    if cfg.family == "encdec":
        model = build(cfg)
        return jax.eval_shape(lambda: model.init_caches(batch, max_len))
    from repro.serve.kvcache import build_caches

    return jax.eval_shape(lambda: build_caches(cfg, batch, max_len))


def input_specs(
    arch: str, shape_name: str, n_dp: int = 1, cfg: ModelConfig | None = None
) -> dict[str, Any]:
    """Returns {kind, batch: {...}, caches?, microbatches} of
    ShapeDtypeStructs for the given cell."""
    cfg = cfg or get(arch)
    shape = SHAPES[shape_name]
    i32 = jnp.int32
    bf16 = jnp.dtype(cfg.dtype)
    out: dict[str, Any] = {"cfg": cfg, "shape": shape}

    if shape.kind == "train":
        mb = choose_microbatches(cfg, shape, n_dp)
        b = shape.global_batch
        bm = b // mb
        tok = jax.ShapeDtypeStruct((mb, bm, shape.seq_len), i32)
        batch = {"tokens": tok, "labels": tok}
        if cfg.family == "encdec":
            batch["frames"] = jax.ShapeDtypeStruct(
                (mb, bm, cfg.encoder_seq, cfg.d_model), bf16
            )
        if cfg.family == "vlm":
            batch["patches"] = jax.ShapeDtypeStruct(
                (mb, bm, cfg.n_patches, cfg.patch_dim), bf16
            )
        out.update(batch=batch, microbatches=mb)
    elif shape.kind == "prefill":
        b = shape.global_batch
        batch = {"tokens": jax.ShapeDtypeStruct((b, shape.seq_len), i32)}
        if cfg.family == "encdec":
            batch["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), bf16
            )
        if cfg.family == "vlm":
            batch["patches"] = jax.ShapeDtypeStruct(
                (b, cfg.n_patches, cfg.patch_dim), bf16
            )
        out.update(
            batch=batch,
            caches=cache_shapes(cfg, b, shape.seq_len),
        )
    else:  # decode
        b = shape.global_batch
        out.update(
            batch={"tokens": jax.ShapeDtypeStruct((b, 1), i32)},
            caches=cache_shapes(cfg, b, shape.seq_len),
        )
        if cfg.family == "encdec":
            out["enc_out"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), bf16
            )
    return out
