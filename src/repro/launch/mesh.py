"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required because the dry-run forces 512
host devices via XLA_FLAGS before any jax import, while tests/benches must
see the single real CPU device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod; 2 pods for the multi-pod dry-run."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally, as a (data, model) mesh (1,1 on CPU)."""
    n = jax.device_count()
    return jax.make_mesh((n, 1), ("data", "model"))
