import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every live (architecture x input shape) cell and both production meshes
(16x16 single pod, 2x16x16 multi-pod), this driver:

  1. builds the step function (train_step or serve_step per the shape kind),
  2. jit-lowers with explicit in/out shardings from parallel/sharding.py and
     .compile()s - sharding mismatches / OOM / unsupported collectives fail
     here, which is the point,
  3. records compiled.memory_analysis() (per-device fit proof),
  4. reconstructs whole-program cost from cost_analysis() with the A/B trick
     (XLA counts while-loop bodies once and reports per-device numbers):
     lower the model at 1 and 2 scan units -> body = c2 - c1, then
     total = c1 + (units - 1) * body,
  5. parses collective bytes (all-gather/all-reduce/reduce-scatter/
     all-to-all/collective-permute) from the compiled HLO with the same A/B
     reconstruction,
  6. writes experiments/dryrun/<arch>__<shape>__<mesh>.json for
     benchmarks/roofline.py.

Run:  PYTHONPATH=src python -m repro.launch.dryrun --all
      PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b --shape train_4k --multi-pod
"""

import argparse
import dataclasses
import gzip
import json
import re
import time
import traceback

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES
from repro.configs.registry import get
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs, live_cells
from repro.parallel.sharding import ShardingPlan
from repro.launch.steps import make_decode_step, make_prefill_step, make_train_step
from repro.launch import specs as specs_mod
from repro.parallel import policy
from repro.train import optim

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum result bytes of collective ops in (post-SPMD) HLO text.

    Accounting: all-reduce counted 2x result bytes (ring reduce+broadcast);
    others 1x result bytes.  Async pairs: only the -start op is counted.
    """
    out = {c: 0.0 for c in COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        rhs = s.split("=", 1)[1]
        op, pos = None, -1
        for c in COLLECTIVES:
            m = re.search(rf"\b{c}(-start)?\(", rhs)
            if m:
                op, pos = c, m.start()
                break
        if op is None:
            continue
        # result shape(s) sit between '=' and the op name
        result_part = rhs[:pos]
        nbytes = 0.0
        for dt, dims in _SHAPE_RE.findall(result_part):
            if dt not in DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * DTYPE_BYTES[dt]
        mult = 2.0 if op == "all-reduce" else 1.0
        out[op] += mult * nbytes
    out["total"] = sum(out[c] for c in COLLECTIVES)
    return out


def scan_unit(cfg) -> int:
    """Layers per scan step (the A/B reconstruction unit)."""
    if cfg.family == "hybrid":
        return cfg.rnn_per_attention + 1
    return 1


def cached_scan_unit(cfg) -> int:
    if cfg.family == "hybrid":
        return cfg.rnn_per_attention + 1
    if cfg.global_every:
        return cfg.global_every
    return 1


def variant_cfg(cfg, n_units: int, unit: int):
    n = n_units * unit
    kw = {"n_layers": n}
    if cfg.family == "encdec":
        kw["encoder_layers"] = n
    return dataclasses.replace(cfg, **kw)


def build_cell(arch: str, shape_name: str, mesh, cfg=None):
    """Build (step_fn, args, in_shardings, out_shardings, donate) for a cell."""
    plan = ShardingPlan(mesh)
    n_dp = plan.axis_size(plan.dp_axes)
    sp = input_specs(arch, shape_name, n_dp=n_dp, cfg=cfg)
    cfg = cfg or sp["cfg"]
    shape = sp["shape"]

    params = specs_mod.params_shapes(cfg)
    serve_tp = shape.kind != "train" and cfg.serve_tp_params
    pspec = plan.param_spec(params, fsdp=not serve_tp)
    named = plan.named

    if shape.kind == "train":
        opt = specs_mod.opt_shapes(params)
        ospec = plan.opt_state_spec(pspec)
        batch = sp["batch"]
        # batch leaves are (mb, bm, ...): shard dim 1 over DP
        def bspec(leaf):
            spec = [None] * len(leaf.shape)
            if leaf.shape[1] % n_dp == 0:
                spec[1] = plan.dp_axes
            return P(*spec)
        bspecs = jax.tree.map(bspec, batch)
        step = make_train_step(
            cfg, optim.AdamWConfig(),
            accum_spec=pspec if cfg.shard_grad_accum else None,
        )
        mspec = {"grad_norm": P(), "lr": P(), "loss": P()}
        return dict(
            fn=step,
            args=(params, opt, batch),
            in_sh=(named(pspec), named(ospec), named(bspecs)),
            out_sh=(named(pspec), named(ospec), named(mspec)),
            donate=(0, 1),
            cfg=cfg,
        )

    caches = sp["caches"]
    # rebuild cache shapes under the variant cfg
    caches = specs_mod.cache_shapes(cfg, shape.global_batch, shape.seq_len)
    cspec = plan.cache_spec(caches)
    V = cfg.vocab
    lspec = P(
        plan.dp_axes if shape.global_batch % n_dp == 0 else None,
        "model" if V % plan.axis_size("model") == 0 else None,
    )
    if shape.kind == "prefill":
        batch = sp["batch"]
        bspecs = plan.batch_spec(batch)
        step = make_prefill_step(cfg)
        out_sh = (named(lspec), named(cspec))
        if cfg.family == "encdec":
            enc_spec = P(
                plan.dp_axes, None,
                "model" if cfg.d_model % plan.axis_size("model") == 0 else None,
            )
            out_sh = (named(lspec), named(cspec), named(enc_spec))
        return dict(
            fn=step,
            args=(params, batch, caches),
            in_sh=(named(pspec), named(bspecs), named(cspec)),
            out_sh=out_sh,
            donate=(2,),
            cfg=cfg,
        )

    # decode
    tokens = sp["batch"]["tokens"]
    tspec = P(plan.dp_axes if tokens.shape[0] % n_dp == 0 else None, None)
    step = make_decode_step(cfg)
    args = [params, tokens, caches]
    in_sh = [named(pspec), named(tspec), named(cspec)]
    if cfg.family == "encdec":
        enc = sp["enc_out"]
        espec = P(
            plan.dp_axes if enc.shape[0] % n_dp == 0 else None, None,
            "model" if cfg.d_model % plan.axis_size("model") == 0 else None,
        )
        args.append(enc)
        in_sh.append(named(espec))
    return dict(
        fn=step,
        args=tuple(args),
        in_sh=tuple(in_sh),
        out_sh=(named(lspec), named(cspec)),
        donate=(2,),
        cfg=cfg,
    )


def lower_compile(cell):
    t0 = time.time()
    jitted = jax.jit(
        cell["fn"],
        in_shardings=cell["in_sh"],
        out_shardings=cell["out_sh"],
        donate_argnums=cell["donate"],
    )
    lowered = jitted.lower(*cell["args"])
    compiled = lowered.compile()
    return compiled, time.time() - t0


def cost_of(compiled) -> dict:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        # jax >= 0.4.30 returns a one-element list of per-program dicts
        ca = ca[0] if ca else {}
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
    }


def apply_overrides(cfg, overrides: dict[str, str]):
    """--set key=value config overrides for §Perf variants."""
    kw = {}
    for k, v in overrides.items():
        cur = getattr(cfg, k)
        if isinstance(cur, bool):
            kw[k] = v in ("1", "true", "True")
        elif isinstance(cur, int):
            kw[k] = int(v)
        else:
            kw[k] = v
    return dataclasses.replace(cfg, **kw)


def run_cell(
    arch: str, shape_name: str, multi_pod: bool,
    overrides: dict[str, str] | None = None,
    rules: dict[str, str] | None = None,
) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    shape = SHAPES[shape_name]
    full_cfg = get(arch)
    if overrides:
        full_cfg = apply_overrides(full_cfg, overrides)
    unit = (
        scan_unit(full_cfg)
        if shape.kind == "train"
        else cached_scan_unit(full_cfg)
    )
    n_units_full = full_cfg.n_layers // unit

    with mesh, policy.activate(mesh, rules):
        # full model: the fit proof + compile-success gate
        cell = build_cell(arch, shape_name, mesh, cfg=full_cfg)
        compiled_full, t_full = lower_compile(cell)
        mem = compiled_full.memory_analysis()
        cost_full = cost_of(compiled_full)

        # A/B variants for whole-program reconstruction
        c1_cfg = variant_cfg(full_cfg, 1, unit)
        c2_cfg = variant_cfg(full_cfg, 2, unit)
        cell1 = build_cell(arch, shape_name, mesh, cfg=c1_cfg)
        cell2 = build_cell(arch, shape_name, mesh, cfg=c2_cfg)
        comp1, _ = lower_compile(cell1)
        comp2, _ = lower_compile(cell2)
        cost1, cost2 = cost_of(comp1), cost_of(comp2)
        hlo1, hlo2 = comp1.as_text(), comp2.as_text()
        coll1 = collective_bytes(hlo1)
        coll2 = collective_bytes(hlo2)

    recon = {}
    for k in ("flops", "bytes"):
        body = cost2[k] - cost1[k]
        recon[k] = cost1[k] + max(body, 0.0) * (n_units_full - 1)
    coll = {}
    for k in coll1:
        body = coll2[k] - coll1[k]
        coll[k] = coll1[k] + max(body, 0.0) * (n_units_full - 1)

    return {
        "_hlo1_gz": hlo1,  # swapped for a gz sidecar path at write time
        "_hlo2_gz": hlo2,
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": 512 if multi_pod else 256,
        "overrides": overrides or {},
        "rules": rules or {},
        "kind": shape.kind,
        "compile_s": round(t_full, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_estimate_bytes": mem.argument_size_in_bytes
            + mem.temp_size_in_bytes
            + mem.output_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        # per-device numbers (XLA convention), scan bodies re-multiplied
        "cost_per_device": recon,
        "cost_raw": {"full": cost_full, "c1": cost1, "c2": cost2},
        "collective_bytes_per_device": coll,
        "scan_units": n_units_full,
        "microbatches": (
            jax.tree.leaves(cell["args"][2])[0].shape[0]
            if shape.kind == "train"
            else 1
        ),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="", help="suffix for variant records")
    ap.add_argument("--set", action="append", default=[],
                    metavar="KEY=VALUE", help="ModelConfig override")
    ap.add_argument("--rule", action="append", default=[],
                    metavar="LOGICAL=AXIS", help="activation-sharding rule")
    args = ap.parse_args()

    overrides = dict(kv.split("=", 1) for kv in getattr(args, "set"))
    rules = {}
    for kv in args.rule:
        k, v = kv.split("=", 1)
        rules[k] = None if v in ("none", "None") else v

    os.makedirs(args.out, exist_ok=True)
    if args.all:
        cells = [
            (a, s, mp)
            for (a, s) in live_cells()
            for mp in (False, True)
        ]
    else:
        cells = [(args.arch, args.shape, args.multi_pod)]

    failures = []
    for arch, shape_name, mp in cells:
        tag = f"{arch}__{shape_name}__{'2x16x16' if mp else '16x16'}"
        if args.tag:
            tag += f"__{args.tag}"
        path = os.path.join(args.out, tag + ".json")
        if args.all and os.path.exists(path):
            print(f"[skip] {tag}")
            continue
        t0 = time.time()
        try:
            rec = run_cell(arch, shape_name, mp, overrides, rules)
            # HLO text saved as gz sidecars for offline re-analysis
            for key, suffix in (("_hlo1_gz", ".c1.hlo.gz"),
                                ("_hlo2_gz", ".c2.hlo.gz")):
                txt = rec.pop(key)
                with gzip.open(path.replace(".json", suffix), "wt") as gf:
                    gf.write(txt)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            print(
                f"[ok]   {tag}  compile={rec['compile_s']}s "
                f"peak/dev={rec['memory']['peak_estimate_bytes']/2**30:.2f}GiB "
                f"t={time.time()-t0:.0f}s"
            )
        except Exception as e:
            failures.append((tag, repr(e)))
            print(f"[FAIL] {tag}: {e}")
            traceback.print_exc()
    if failures:
        print(f"{len(failures)} FAILURES:")
        for t, e in failures:
            print(" ", t, e[:200])
        raise SystemExit(1)
    print("all cells green")


if __name__ == "__main__":
    main()
