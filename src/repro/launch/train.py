"""Production training launcher.

On real hardware this runs under `jax.distributed.initialize()` with one
process per host; on this container it runs the same code on the local
mesh.  The step function, sharding plan, data pipeline, checkpointing and
straggler monitor are identical to the dry-run's - the dry-run proves this
program lowers for the production meshes.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m-smoke --steps 20
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get
from repro.data.pipeline import DataConfig, Pipeline
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.parallel import policy
from repro.parallel.sharding import ShardingPlan
from repro.train import optim
from repro.train.loop import StragglerMonitor
from repro.ckpt import checkpoint as ckpt
from repro.arch.model_zoo import build


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m-smoke")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get(args.arch)
    mesh = make_host_mesh()
    plan = ShardingPlan(mesh)
    model = build(cfg)

    with mesh, policy.activate(mesh):
        params = model.init(jax.random.PRNGKey(0))
        opt_state = optim.init_state(params)
        pspec = plan.param_spec(jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params))
        params = jax.device_put(params, plan.named(pspec))
        opt_state = jax.device_put(
            opt_state, plan.named(plan.opt_state_spec(pspec)))

        step_fn = jax.jit(
            make_train_step(cfg, optim.AdamWConfig(
                lr=3e-3, warmup_steps=10, total_steps=args.steps)),
            donate_argnums=(0, 1),
        )
        dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                          global_batch=args.batch)
        pipe = Pipeline(dcfg)
        monitor = StragglerMonitor()
        saver = ckpt.AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
        mb = args.microbatches
        try:
            for step, batch in pipe:
                if step >= args.steps:
                    break
                t0 = time.perf_counter()
                shaped = {
                    k: jnp.asarray(v).reshape((mb, -1) + v.shape[1:])
                    for k, v in batch.items()
                }
                params, opt_state, metrics = step_fn(params, opt_state, shaped)
                dt = time.perf_counter() - t0
                monitor.record(step, dt)
                if step % 5 == 0:
                    print(f"step {step} loss {float(metrics['loss']):.4f} "
                          f"{dt*1e3:.0f}ms")
                if saver and (step + 1) % args.ckpt_every == 0:
                    saver.save_async(step + 1,
                                     {"params": params, "opt": opt_state},
                                     extra={"next_step": step + 1})
        finally:
            pipe.close()
            if saver:
                saver.wait()
        print(f"done; stragglers: {len(monitor.flagged)}")


if __name__ == "__main__":
    main()
