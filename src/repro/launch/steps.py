"""Step-function builders shared by the dry-run, train and serve launchers.

All step functions are pure (params/opt/caches in -> out) so they can be
jit-compiled with explicit in/out shardings for the production mesh.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.arch.model_zoo import build
from repro.configs.base import ModelConfig
from repro.train import optim


def make_loss_fn(cfg: ModelConfig) -> Callable:
    model = build(cfg)
    if cfg.family == "encdec":
        return lambda p, b: model.loss(
            p, b["frames"], b["tokens"], b["labels"]
        )
    if cfg.family == "vlm":
        return lambda p, b: model.loss(
            p, b["tokens"], b["labels"], patches=b["patches"]
        )
    return lambda p, b: model.loss(p, b["tokens"], b["labels"])


def make_train_step(
    cfg: ModelConfig,
    ocfg: optim.AdamWConfig,
    accum_spec: Any | None = None,
) -> Callable:
    """Microbatched train step.  Batch leaves are pre-shaped
    (microbatches, per_mb_batch, ...) - grad-accumulated with lax.scan so
    live activation memory is one microbatch.

    accum_spec (§Perf, grok hillclimb): PartitionSpec tree pinning the grad
    accumulator (and each microbatch's grads) to the PARAM sharding.  Without
    it XLA reshards the scan carry via replicate-then-partition, i.e. a full
    fp32-gradient all-reduce EVERY microbatch; with it the per-mb reduction
    is a reduce-scatter of the already-sharded gradients.
    """
    loss_fn = make_loss_fn(cfg)

    def constrain(tree):
        if accum_spec is None:
            return tree
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s),
            tree, accum_spec,
        )

    def step(params, opt_state, batch):
        mb = jax.tree.leaves(batch)[0].shape[0]

        def mb_body(acc, b):
            loss, g = jax.value_and_grad(loss_fn)(params, b)
            g = constrain(g)
            gacc, lacc = acc
            gacc = constrain(jax.tree.map(
                lambda a, x: a + x.astype(jnp.float32), gacc, g
            ))
            return (gacc, lacc + loss), None

        zero = constrain(jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        ))
        (gsum, lsum), _ = jax.lax.scan(
            mb_body, (zero, jnp.zeros((), jnp.float32)), batch
        )
        grads = jax.tree.map(lambda g: g / mb, gsum)
        params, opt_state, metrics = optim.apply_updates(
            ocfg, params, grads, opt_state
        )
        metrics["loss"] = lsum / mb
        return params, opt_state, metrics

    return step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    model = build(cfg)
    if cfg.family == "encdec":
        def step(params, batch, caches):
            logits, (caches, enc_out) = model.prefill(
                params, batch["frames"], batch["tokens"], caches
            )
            return logits, caches, enc_out
        return step
    if cfg.family == "vlm":
        def step(params, batch, caches):
            logits, caches = model.prefill(
                params, batch["tokens"], caches, patches=batch["patches"]
            )
            return logits, caches
        return step

    def step(params, batch, caches):
        return model.prefill(params, batch["tokens"], caches)

    return step


def make_decode_step(cfg: ModelConfig) -> Callable:
    model = build(cfg)
    if cfg.family == "encdec":
        def step(params, tokens, caches, enc_out):
            logits, (caches, enc_out) = model.decode_step(
                params, tokens, (caches, enc_out)
            )
            return logits, caches
        return step

    def step(params, tokens, caches):
        return model.decode_step(params, tokens, caches)

    return step
