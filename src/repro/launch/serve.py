"""Production serving launcher (local-mesh variant of the decode dry-run).

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m-smoke
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.arch.model_zoo import build
from repro.configs.registry import get
from repro.serve.engine import Engine, Request, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m-smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--requests", type=int, default=3)
    args = ap.parse_args()

    cfg = get(args.arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(cfg, params,
                    ServeConfig(batch=args.batch, max_len=args.max_len))
    rng = np.random.default_rng(0)
    reqs = [
        Request(rng.integers(0, cfg.vocab, rng.integers(3, 16)).astype(np.int32),
                max_new_tokens=args.new_tokens)
        for _ in range(args.requests)
    ]
    import time

    t0 = time.perf_counter()
    outs = engine.generate(reqs)
    dt = time.perf_counter() - t0
    total_new = sum(len(o) for o in outs)
    print(f"served {len(reqs)} requests, {total_new} tokens, "
          f"{dt:.2f}s ({total_new/dt:.1f} tok/s)")
    for i, o in enumerate(outs):
        print(f"  req{i}: {o.tolist()}")


if __name__ == "__main__":
    main()
