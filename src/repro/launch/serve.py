"""Production serving launcher on the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m-smoke

Generates a mixed-length synthetic workload, streams tokens through the
slot-based engine, and reports throughput plus per-token latency.  Pass
``--static`` to run the padded static-batch baseline instead (same workload,
same slot count) for an A/B on the spot.

Durability: ``--snapshot-dir DIR`` arms crash consistency (atomic engine
snapshots every ``--snapshot-every`` steps plus a write-ahead journal,
serve/recovery.py).  After a crash — try SIGKILL mid-run — relaunch with
``--resume`` and the same flags: the engine restores from the newest valid
snapshot, teacher-forces the journaled tokens back (bitwise identical to
the never-crashed run), and finishes the in-flight requests.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.arch.model_zoo import build
from repro.configs.registry import get
from repro.serve.engine import (
    DurabilityConfig,
    Engine,
    KernelConfig,
    KVConfig,
    Request,
    SchedulerConfig,
    ServeConfig,
    StaticEngine,
)


def make_workload(
    cfg, n: int, max_new: int, seed: int = 0, deadline: int | None = None
) -> list[Request]:
    rng = np.random.default_rng(seed)
    return [
        Request(
            rng.integers(0, cfg.vocab, rng.integers(3, 16)).astype(np.int32),
            max_new=int(rng.integers(max(2, max_new // 4), max_new + 1)),
            request_id=i,
            deadline_steps=deadline,
        )
        for i in range(n)
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m-smoke")
    ap.add_argument("--slots", "--batch", dest="slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prefill-bucket", type=int, default=16)
    ap.add_argument("--matmul", choices=("xla", "pallas"), default="xla")
    ap.add_argument("--attention", choices=("flash", "xla"), default="flash",
                    help="decode-attention substrate: ragged flash-decoding "
                         "or the masked dense/blockwise oracle")
    ap.add_argument("--abft", choices=("off", "checksum", "paranoid"),
                    default="off",
                    help="silent-data-corruption defense "
                         "(KernelConfig.abft): 'checksum' arms "
                         "checksum-carrying matmuls, a sampled attention "
                         "fingerprint, and a periodic weight scrub — "
                         "flagged steps are retried and, if the fault "
                         "persists, the offending request is quarantined; "
                         "'paranoid' re-verifies every step on the dense "
                         "oracle")
    ap.add_argument("--scrub-every", type=int, default=1,
                    help="abft: steps between full weight-fingerprint "
                         "scrubs (1 = every step; larger values amortize "
                         "the scrub read at the cost of up to N-1 steps "
                         "of weight-flip detection latency)")
    ap.add_argument("--kv-layout", choices=("contiguous", "paged"),
                    default="contiguous",
                    help="KV cache layout (ServeConfig.kv_layout): "
                         "'contiguous' reserves slots x max_len positions "
                         "per layer; 'paged' carves the same HBM into "
                         "refcounted fixed-size blocks with per-request "
                         "block tables, so capacity tracks live tokens, "
                         "prompts sharing a prefix alias physical blocks "
                         "(copy-on-write), and --slots becomes a pure "
                         "scheduling cap.  Requires all-global attention; "
                         "the contiguous layout is the paged engine's "
                         "bitwise differential oracle")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged: tokens per physical KV block "
                         "(max-len must be a multiple)")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="paged: pool blocks per layer incl. the sink "
                         "(default: the contiguous footprint, "
                         "slots*max_len/block_size + 1)")
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="paged: disable the radix prefix index "
                         "(every request gets private blocks)")
    ap.add_argument("--max-waiting", type=int, default=None,
                    help="bound the waiting queue: overflow submissions "
                         "end REJECTED immediately (load shedding); "
                         "default unbounded")
    ap.add_argument("--stall-patience", type=int, default=64,
                    help="consecutive no-progress idle steps before the "
                         "watchdog sheds the queue head instead of "
                         "livelocking")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="unified scheduler: split admission prefills into "
                         "fixed chunks of this many tokens and interleave "
                         "them with decode steps (0 = monolithic admission, "
                         "the bitwise oracle; max-len must be a multiple)")
    ap.add_argument("--token-budget", type=int, default=None,
                    help="max prefill tokens advanced per engine step "
                         "(requires --prefill-chunk; default unlimited). "
                         "Lower budgets flatten decode ITL under admission "
                         "storms at the cost of TTFT")
    ap.add_argument("--deadline-steps", type=int, default=None,
                    help="per-request deadline in engine steps; expired "
                         "requests end FAILED with their partial output")
    ap.add_argument("--snapshot-dir", default=None,
                    help="arm crash consistency: atomic engine snapshots "
                         "plus a write-ahead journal under this directory "
                         "(created if missing); relaunch with --resume to "
                         "recover after a crash")
    ap.add_argument("--snapshot-every", type=int, default=32,
                    help="steps between snapshots (journal records land "
                         "every step regardless)")
    ap.add_argument("--resume", action="store_true",
                    help="restore from --snapshot-dir instead of submitting "
                         "a fresh workload: replay the journal, print the "
                         "recovery report, and finish the in-flight requests")
    ap.add_argument("--static", action="store_true",
                    help="run the padded static-batch baseline instead")
    ap.add_argument("--autotune", action="store_true",
                    help="plan the serving knobs with the DSE planner "
                         "(core/serveplan.py): sweep slots / kv layout / "
                         "block_size / num_blocks / prefill_chunk / "
                         "token_budget under an iso-HBM KV budget, take the "
                         "Pareto winner, and serve with it.  Overrides "
                         "--slots/--kv-layout/--block-size/--num-blocks/"
                         "--prefill-chunk/--token-budget; kernel and "
                         "durability flags still apply.  Winning plans "
                         "persist in REPRO_SERVE_PLAN_CACHE")
    ap.add_argument("--concurrency", type=int, default=None,
                    help="autotune: offered concurrency to plan for "
                         "(default: --requests)")
    args = ap.parse_args()
    if args.resume and not args.snapshot_dir:
        ap.error("--resume requires --snapshot-dir")
    if args.autotune and args.static:
        ap.error("--autotune plans the continuous engine (drop --static)")
    if args.static and (args.snapshot_dir or args.resume):
        ap.error("--snapshot-dir/--resume need the continuous engine "
                 "(drop --static)")
    if args.abft != "off" and args.kv_layout != "paged":
        ap.error("--abft localizes corruption through the paged pool's "
                 "per-block fingerprints (add --kv-layout paged)")

    cfg = get(args.arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.autotune:
        from repro.core.serveplan import ServeWorkload

        scfg = ServeConfig.autotune(
            cfg,
            max_len=args.max_len,
            workload=ServeWorkload(
                concurrency=args.concurrency or args.requests,
                prompt_len=16,
                decode_len=max(2, args.new_tokens),
            ),
            temperature=args.temperature,
            seed=args.seed,
            kernel=KernelConfig(
                matmul=args.matmul, attention=args.attention,
                abft=args.abft, scrub_every=args.scrub_every,
            ),
            durability=DurabilityConfig(
                snapshot_dir=args.snapshot_dir,
                snapshot_every=args.snapshot_every,
            ),
        )
        plan = scfg.autotune_plan
        pred = plan.predicted
        print(
            f"[autotune] {plan.source}: slots={scfg.batch} "
            f"kv={scfg.kv_layout}/bs={scfg.kv.block_size}"
            f"/nb={scfg.kv.num_blocks} "
            f"chunk={scfg.prefill_chunk} budget={scfg.token_budget} "
            f"(predicted {pred.get('tokens_per_s', 0):.0f} tok/s over "
            f"{pred.get('swept_points', '?')} swept points, "
            f"frontier {plan.frontier_size})"
        )
    else:
        scfg = ServeConfig(
            max_len=args.max_len, temperature=args.temperature,
            seed=args.seed,
            scheduler=SchedulerConfig(
                batch=args.slots, prefill_bucket=args.prefill_bucket,
                prefill_chunk=args.prefill_chunk,
                token_budget=args.token_budget,
                max_waiting=args.max_waiting,
                stall_patience=args.stall_patience,
            ),
            kv=KVConfig(
                layout=args.kv_layout, block_size=args.block_size,
                num_blocks=args.num_blocks,
                prefix_sharing=not args.no_prefix_sharing,
            ),
            kernel=KernelConfig(
                matmul=args.matmul, attention=args.attention,
                abft=args.abft, scrub_every=args.scrub_every,
            ),
            durability=DurabilityConfig(
                snapshot_dir=args.snapshot_dir,
                snapshot_every=args.snapshot_every,
            ),
        )

    t0 = time.perf_counter()
    stamps: dict[int, list[float]] = {}

    def on_token(rid, tok, idx, done):
        stamps.setdefault(rid, []).append(time.perf_counter() - t0)

    if args.resume:
        from repro.serve import recovery

        eng, report = recovery.restore_engine(cfg, params, scfg)
        print(
            f"[resume] source={report.source} snapshot={report.snapshot_key} "
            f"segments={report.segments} records={report.records} "
            f"torn={report.torn_lines}"
        )
        print(
            f"[resume] resubmitted={report.resubmitted} "
            f"tokens_replayed={report.tokens_replayed} "
            f"cancels={report.cancels} pops={report.pops} "
            f"quarantined={report.quarantined or '[]'}"
        )
        n_reqs = len(eng._reqs)
        rids = sorted(eng._reqs)
        with eng:
            while eng.step(on_token):
                pass
            outs = [eng.pop_result(r) for r in rids]
    elif args.static:
        reqs = make_workload(
            cfg, args.requests, args.new_tokens, args.seed,
            deadline=args.deadline_steps,
        )
        n_reqs = len(reqs)
        outs = StaticEngine(cfg, params, scfg).generate(reqs, on_token=on_token)
    else:
        reqs = make_workload(
            cfg, args.requests, args.new_tokens, args.seed,
            deadline=args.deadline_steps,
        )
        n_reqs = len(reqs)
        with Engine(cfg, params, scfg) as eng:
            outs = eng.run(reqs, on_token=on_token)
    dt = time.perf_counter() - t0

    total_new = sum(len(o) for o in outs)
    deltas = [
        b - a
        for ts in stamps.values()
        for a, b in zip([0.0] + ts[:-1], ts)
    ]
    deltas.sort()
    p50 = deltas[len(deltas) // 2] if deltas else 0.0
    p95 = deltas[min(len(deltas) - 1, int(len(deltas) * 0.95))] if deltas else 0.0
    mode = (
        "static" if args.static else "resume" if args.resume else "continuous"
    )
    print(
        f"[{mode}] served {n_reqs} requests, {total_new} tokens, "
        f"{dt:.2f}s ({total_new / dt:.1f} tok/s, "
        f"per-token p50={p50 * 1e3:.1f}ms p95={p95 * 1e3:.1f}ms)"
    )
    if args.static:
        for i, o in enumerate(outs):
            print(f"  req{i}: {o.tolist()}")
    else:
        # continuous results are typed (RequestResult): summarize terminal
        # statuses so deadline expiry / load shedding is visible at a glance
        counts: dict[str, int] = {}
        for o in outs:
            counts[o.status.value] = counts.get(o.status.value, 0) + 1
        print("  statuses: " + ", ".join(
            f"{k}={v}" for k, v in sorted(counts.items())
        ))
        for i, o in enumerate(outs):
            why = f" ({o.reason})" if o.reason else ""
            print(f"  req{i} [{o.status.value}{why}]: {o.tolist()}")


if __name__ == "__main__":
    main()
