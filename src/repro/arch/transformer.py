"""Decoder-only LM assembled from the family mixers.

One Model object per config exposing:

    init(key)                          -> params pytree
    loss(params, tokens, labels)       -> scalar CE (+ MoE aux)
    prefill(params, tokens)            -> (logits_last, caches)
    decode_step(params, token, caches) -> (logits, caches)
    init_caches(batch, max_len)        -> caches pytree

Layers are scanned (jax.lax.scan) over stacked parameters so the 512-device
dry-run compiles one layer body; heterogeneous layer patterns are handled
per family:

  * dense / moe:    uniform stack.
  * gemma3 (5 local : 1 global):  uniform params; the per-layer sliding
    window is a scanned int32 input (a huge window == global attention), so
    the pattern costs no extra code paths.
  * rwkv6:          uniform stack of WKV mixers.
  * recurrentgemma: layers grouped (rnn, rnn, attention); the group is
    scanned, remainder layers are applied unrolled.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.arch import layers as L
from repro.arch import moe as M
from repro.arch import rglru as G
from repro.arch import rwkv as R
from repro.configs.base import ModelConfig

GLOBAL_WINDOW = jnp.int32(2**30)  # "window" that never masks = global attn


def remat_policy_of(cfg: ModelConfig):
    """jax.checkpoint policy from the config knob (EXPERIMENTS.md §Perf)."""
    if cfg.remat_policy == "dots":
        return jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    return None  # full remat: save nothing


def layer_windows(cfg: ModelConfig):
    """(n_layers,) int32 sliding windows; 2^30 marks global layers.
    Returned as numpy (static config), converted to jnp at scan sites."""
    import numpy as np

    if cfg.sliding_window is None:
        return np.full((cfg.n_layers,), 2**30, np.int32)
    if not cfg.global_every:
        return np.full((cfg.n_layers,), cfg.sliding_window, np.int32)
    w = []
    for i in range(cfg.n_layers):
        is_global = (i + 1) % cfg.global_every == 0
        w.append(2**30 if is_global else cfg.sliding_window)
    return np.asarray(w, np.int32)


# ------------------------------------------------------------ layer bodies --


def attn_block_init(key: jax.Array, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 3)
    p = {
        "ln1": L.rmsnorm_init(cfg.d_model),
        "attn": L.attention_init(ks[0], cfg),
        "ln2": L.rmsnorm_init(cfg.d_model),
    }
    if cfg.moe is not None:
        p["moe"] = M.moe_init(ks[1], cfg)
    else:
        p["mlp"] = L.mlp_init(ks[1], cfg)
    return p


def attn_block_apply(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    window: jax.Array | int | None,
    positions: jax.Array | None,
    cache: dict | None,
    ragged_ok: bool | None = None,
) -> tuple[jax.Array, dict | None, jax.Array]:
    h, new_cache = L.multihead_attention(
        p["attn"], cfg, L.rmsnorm(p["ln1"], x, cfg.norm_eps),
        positions=positions, causal=True, window=window, cache=cache,
        ragged_ok=ragged_ok,
    )
    x = x + h
    z = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe is not None:
        f, aux = M.moe_apply(p["moe"], cfg, z)
    else:
        f = L.mlp(p["mlp"], cfg, z)
    return x + f, new_cache, aux


def rwkv_block_init(key: jax.Array, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model),
        "wkv": R.rwkv_init(ks[0], cfg),
        "ln2": L.rmsnorm_init(cfg.d_model),
        "mlp": L.mlp_init(ks[1], cfg),
    }


def rwkv_block_apply(p, cfg, x, *, cache):
    h, new_cache = R.rwkv_mix(p["wkv"], cfg, L.rmsnorm(p["ln1"], x, cfg.norm_eps), cache)
    x = x + h
    f = L.mlp(p["mlp"], cfg, L.rmsnorm(p["ln2"], x, cfg.norm_eps))
    return x + f, new_cache, jnp.zeros((), jnp.float32)


def rnn_block_init(key: jax.Array, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model),
        "rnn": G.rglru_init(ks[0], cfg),
        "ln2": L.rmsnorm_init(cfg.d_model),
        "mlp": L.mlp_init(ks[1], cfg),
    }


def rnn_block_apply(p, cfg, x, *, cache):
    h, new_cache = G.rglru_block(p["rnn"], cfg, L.rmsnorm(p["ln1"], x, cfg.norm_eps), cache)
    x = x + h
    f = L.mlp(p["mlp"], cfg, L.rmsnorm(p["ln2"], x, cfg.norm_eps))
    return x + f, new_cache, jnp.zeros((), jnp.float32)


# ------------------------------------------------------------------- model --


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------- params --
    def init(self, key: jax.Array) -> dict:
        cfg = self.cfg
        ke, kl, kf = jax.random.split(key, 3)
        params: dict[str, Any] = {"embed": L.embedding_init(ke, cfg)}
        if cfg.family == "hybrid":
            ng, rem = divmod(cfg.n_layers, cfg.rnn_per_attention + 1)
            gkeys = jax.random.split(kl, max(ng, 1))

            def group_init(k):
                ks = jax.random.split(k, cfg.rnn_per_attention + 1)
                return {
                    "rnn": jax.vmap(lambda kk: rnn_block_init(kk, cfg))(
                        ks[: cfg.rnn_per_attention]
                    ),
                    "attn": attn_block_init(ks[-1], cfg),
                }

            params["groups"] = jax.vmap(group_init)(gkeys[:ng])
            rkeys = jax.random.split(kf, max(rem, 1))
            params["tail"] = (
                jax.vmap(lambda kk: rnn_block_init(kk, cfg))(rkeys[:rem])
                if rem
                else {}
            )
        else:
            block_init = (
                rwkv_block_init if cfg.mixer == "rwkv6" else attn_block_init
            )
            lkeys = jax.random.split(kl, cfg.n_layers)
            params["layers"] = jax.vmap(lambda kk: block_init(kk, cfg))(lkeys)
        params["final_ln"] = L.rmsnorm_init(cfg.d_model)
        if cfg.family == "vlm" and cfg.n_patches:
            params["patch_proj"] = jax.nn.initializers.normal(
                0.02, dtype=jnp.dtype(cfg.dtype)
            )(kf, (cfg.patch_dim, cfg.d_model))
        return params

    # ------------------------------------------------------------ forward --
    def _backbone(
        self,
        params: dict,
        x: jax.Array,
        positions: jax.Array | None,
        caches: Any | None,
        remat: bool,
    ) -> tuple[jax.Array, Any, jax.Array]:
        cfg = self.cfg

        if cfg.family == "hybrid":
            return self._backbone_hybrid(params, x, positions, caches, remat)
        if cfg.global_every and caches is not None:
            # local:global mixed caches have heterogeneous sizes -> grouped
            # scan so ring buffers stay window-sized (a 1k-window layer must
            # not allocate 500k slots).
            return self._backbone_local_global(params, x, positions, caches)

        if cfg.mixer == "rwkv6":
            def body(x, p_c):
                p, c = p_c
                y, nc, aux = rwkv_block_apply(p, cfg, x, cache=c)
                return y, (nc, aux)
        else:
            # the scan traces per-layer windows, so the ragged-decode ring
            # invariant (ring extent <= window, with stacked caches padded
            # to the largest extent) is checked statically here, over ALL
            # scanned layers, and passed down as a hint
            ragged = None
            if caches is not None:
                if "kpool" in caches:
                    # paged caches exist only for all-global configs
                    # (kvcache.supports_paged), so the invariant is free
                    ragged = True
                else:
                    size = caches["k"].shape[2]
                    ragged = bool((layer_windows(cfg) >= size).all())

            def body(x, p_c_w_i):
                p, c, w, li = p_c_w_i
                trace = L.abft_active()
                if trace is not None:
                    trace.layer = li
                y, nc, aux = attn_block_apply(
                    p, cfg, x, window=w, positions=positions, cache=c,
                    ragged_ok=ragged,
                )
                # a scanned body must not leak traced values through the
                # trace's Python-side flag list: drain the layer's ABFT
                # verdicts into a scanned output instead
                flag = (
                    trace.drain() if trace is not None
                    else jnp.zeros((), jnp.bool_)
                )
                return y, (nc, aux, flag)

        if remat:
            body = jax.checkpoint(body, policy=remat_policy_of(cfg))

        if cfg.mixer == "rwkv6":
            xs = (params["layers"], caches)
            x, (new_caches, auxs) = jax.lax.scan(body, x, xs)
        else:
            xs = (
                params["layers"], caches, jnp.asarray(layer_windows(cfg)),
                jnp.arange(cfg.n_layers),
            )
            x, (new_caches, auxs, flags) = jax.lax.scan(body, x, xs)
            trace = L.abft_active()
            if trace is not None:
                trace.layer = None
                trace.flags.append(jnp.any(flags))
        return x, new_caches, jnp.sum(auxs)

    def _split_groups(self, params):
        """Reshape stacked layer params (L, ...) into local/global groups."""
        cfg = self.cfg
        ge = cfg.global_every
        ng = cfg.n_layers // ge
        body = ng * ge

        def grouped(leaf):
            g = leaf[:body].reshape((ng, ge) + leaf.shape[1:])
            return g

        g = jax.tree.map(grouped, params["layers"])
        p_local = jax.tree.map(lambda l: l[:, : ge - 1], g)
        p_global = jax.tree.map(lambda l: l[:, ge - 1], g)
        p_tail = jax.tree.map(lambda l: l[body:], params["layers"])
        n_tail = cfg.n_layers - body
        return p_local, p_global, p_tail, n_tail

    def _backbone_local_global(self, params, x, positions, caches):
        cfg = self.cfg
        p_local, p_global, p_tail, n_tail = self._split_groups(params)

        def local_sub(x, pc):
            p, c = pc
            y, nc, _ = attn_block_apply(
                p, cfg, x, window=cfg.sliding_window,
                positions=positions, cache=c,
            )
            return y, nc

        def group_body(x, pcg):
            pl, pg, c = pcg
            x, nlocal = jax.lax.scan(local_sub, x, (pl, c["local"]))
            x, nglobal, _ = attn_block_apply(
                pg, cfg, x, window=None, positions=positions,
                cache=c["global"],
            )
            return x, {"local": nlocal, "global": nglobal}

        x, ngroups = jax.lax.scan(
            group_body, x, (p_local, p_global, caches["groups"])
        )
        ntail = None
        if n_tail:
            x, ntail = jax.lax.scan(local_sub, x, (p_tail, caches["tail"]))
        new_caches = {"groups": ngroups, "tail": ntail}
        return x, new_caches, jnp.zeros((), jnp.float32)

    def _backbone_hybrid(self, params, x, positions, caches, remat):
        cfg = self.cfg

        def group_body(x, p_c):
            p, c = p_c
            aux = jnp.zeros((), jnp.float32)

            def rnn_sub(x, pc):
                pp, cc = pc
                y, nc, _ = rnn_block_apply(pp, cfg, x, cache=cc)
                return y, nc

            x, nrnn = jax.lax.scan(
                rnn_sub, x, (p["rnn"], c["rnn"] if c is not None else None)
            )
            y, nattn, _ = attn_block_apply(
                p["attn"], cfg, x,
                window=cfg.sliding_window, positions=positions,
                cache=c["attn"] if c is not None else None,
            )
            return y, ({"rnn": nrnn, "attn": nattn}, aux)

        if remat:
            group_body = jax.checkpoint(group_body, policy=remat_policy_of(cfg))
        gcaches = caches["groups"] if caches is not None else None
        x, (ngroups, auxs) = jax.lax.scan(
            group_body, x, (params["groups"], gcaches)
        )
        ntail = None
        if params.get("tail"):
            def tail_sub(x, pc):
                pp, cc = pc
                y, nc = (lambda r: (r[0], r[1]))(
                    rnn_block_apply(pp, cfg, x, cache=cc)[:2]
                )
                return y, nc
            tcaches = caches["tail"] if caches is not None else None
            x, ntail = jax.lax.scan(tail_sub, x, (params["tail"], tcaches))
        new_caches = (
            {"groups": ngroups, "tail": ntail} if caches is not None else None
        )
        return x, new_caches, jnp.sum(auxs)

    def logits_fn(
        self, params: dict, x: jax.Array, positions=None, caches=None,
        remat: bool = False,
    ):
        cfg = self.cfg
        x, new_caches, aux = self._backbone(params, x, positions, caches, remat)
        x = L.rmsnorm(params["final_ln"], x, cfg.norm_eps)
        return L.unembed(params["embed"], x), new_caches, aux

    # -------------------------------------------------------------- train --
    def loss(
        self, params: dict, tokens: jax.Array, labels: jax.Array,
        patches: jax.Array | None = None, remat: bool = True,
    ) -> jax.Array:
        cfg = self.cfg
        x = L.embed(params["embed"], tokens)
        if cfg.family == "vlm" and patches is not None:
            px = patches @ params["patch_proj"]
            x = jnp.concatenate([px, x], axis=1)
            labels = jnp.concatenate(
                [jnp.full(px.shape[:2], -1, labels.dtype), labels], axis=1
            )
        logits, _, aux = self.logits_fn(params, x, remat=remat)
        return L.cross_entropy(logits, labels) + 0.01 * aux

    # -------------------------------------------------------------- serve --
    def init_caches(self, batch: int, max_len: int) -> Any:
        # construction lives with the slot-cache machinery in serve/kvcache
        from repro.serve.kvcache import build_caches

        return build_caches(self.cfg, batch, max_len)

    def prefill(
        self, params: dict, tokens: jax.Array, caches: Any,
        patches: jax.Array | None = None,
        last_index: jax.Array | None = None,
    ):
        """last_index: per-row index of the last real token, for prompts
        right-padded to a bucket length (default: the final position)."""
        cfg = self.cfg
        x = L.embed(params["embed"], tokens)
        if cfg.family == "vlm" and patches is not None:
            px = patches @ params["patch_proj"]
            x = jnp.concatenate([px, x], axis=1)
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)
        with L.prefill_aligned():
            logits, caches, _ = self.logits_fn(
                params, x, positions=positions, caches=caches
            )
        if last_index is None:
            return logits[:, -1], caches
        sel = jnp.take_along_axis(
            logits, last_index.astype(jnp.int32)[:, None, None], axis=1
        )[:, 0]
        return sel, caches

    def decode_step(self, params: dict, tokens: jax.Array, caches: Any):
        """tokens: (B, 1) -> (logits (B, V), new caches)."""
        x = L.embed(params["embed"], tokens)
        logits, caches, _ = self.logits_fn(params, x, caches=caches)
        return logits[:, -1], caches
