"""Attention compute paths.

Four implementations with one contract:

  * dense       — einsum + softmax, for short sequences (scores materialize).
  * blockwise   — lax.scan over (q-block, kv-block) tiles with online softmax
                  (flash-attention algorithm in portable XLA).  This is the
                  default for long sequences; it is also the pure-jnp oracle
                  shape for the Pallas kernel in kernels/flash_attention.
  * Pallas      — kernels/flash_attention (TPU target); opt-in via ops.py.
  * flash-decoding — the serve decode hot path: one query token per slot
                  attends over a ragged KV prefix
                  (kernels/flash_attention/decode_attention).  Dispatched by
                  :func:`attend` when the caller passes per-slot
                  ``decode_lengths`` and opts in with ``decode_impl="flash"``;
                  the position-masked dense/blockwise path below stays the
                  differential oracle for it.

All paths take grouped-query tensors:
    q: (B, Tq, KV, G, hd)   k/v: (B, Tk, KV, hd)
and an additive mask recipe (causal flag + optional sliding window + kv
length for padded decode caches), and return (B, Tq, KV, G, hd).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _bias_block(
    q_pos: jax.Array,   # (bq,) or (B, bq) for per-slot decode caches
    k_pos: jax.Array,   # (bk,) or (B, bk)
    causal: bool,
    window: int | None,
    kv_len: jax.Array | None,
) -> jax.Array:
    """Additive mask; broadcasting over a leading batch dim when either
    position vector is per-batch (continuous-batching slot caches)."""
    qp = q_pos.astype(jnp.int32)
    kp = k_pos.astype(jnp.int32)
    diff = qp[..., :, None] - kp[..., None, :]
    ok = jnp.ones(diff.shape, bool)
    if causal:
        ok &= diff >= 0
    if window is not None:
        ok &= diff < window
    if kv_len is not None:
        ok &= kp[..., None, :] < kv_len
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _add_bias(scores: jax.Array, bias: jax.Array) -> jax.Array:
    """scores: (B, KV, G, Tq, Tk); bias: (Tq, Tk) or (B, Tq, Tk)."""
    if bias.ndim == 2:
        return scores + bias
    return scores + bias[:, None, None]


def dense_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    q_pos: jax.Array,
    k_pos: jax.Array,
    causal: bool = True,
    window: int | None = None,
    kv_len: jax.Array | None = None,
) -> jax.Array:
    B, Tq, KV, G, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k).astype(jnp.float32) * scale
    scores = _add_bias(scores, _bias_block(q_pos, k_pos, causal, window, kv_len))
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgqs,bskd->bqkgd", probs, v)


def blockwise_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    q_pos: jax.Array,
    k_pos: jax.Array,
    causal: bool = True,
    window: int | None = None,
    kv_len: jax.Array | None = None,
    block_q: int = 512,
    block_k: int = 1024,
    causal_skip: bool = False,
) -> jax.Array:
    """Online-softmax tiled attention; O(block_q*block_k) live scores.

    causal_skip (§Perf): unrolls the q-block loop and statically truncates
    each q block's kv scan to the causal frontier - halves prefill attention
    FLOPs vs. computing fully-masked blocks.  Requires aligned positions
    (q_pos == k_pos == arange), which the caller guarantees.
    """
    B, Tq, KV, G, hd = q.shape
    Tk = k.shape[1]
    scale = 1.0 / math.sqrt(hd)

    bq = min(block_q, Tq)
    bk = min(block_k, Tk)
    # pad to multiples (padded keys masked off via kv_len/k_pos handling)
    pq = (-Tq) % bq
    pk = (-Tk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0), (0, 0)))
        pad_q = ((0, 0),) * (q_pos.ndim - 1) + ((0, pq),)
        q_pos = jnp.pad(q_pos, pad_q, mode="edge")
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        pad_k = ((0, 0),) * (k_pos.ndim - 1) + ((0, pk),)
        k_pos = jnp.pad(k_pos, pad_k, constant_values=-(10**9))
    nq, nk = q.shape[1] // bq, k.shape[1] // bk

    qb = q.reshape(B, nq, bq, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
    kb = k.reshape(B, nk, bk, KV, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nk, bk, KV, hd).transpose(1, 0, 2, 3, 4)
    # position blocks: (nq, bq) shared, or (nq, B, bq) per-batch
    if q_pos.ndim == 2:
        qpb = q_pos.reshape(B, nq, bq).transpose(1, 0, 2)
    else:
        qpb = q_pos.reshape(nq, bq)
    if k_pos.ndim == 2:
        kpb = k_pos.reshape(B, nk, bk).transpose(1, 0, 2)
    else:
        kpb = k_pos.reshape(nk, bk)

    @jax.checkpoint
    def q_block(qi, qp, kbs, vbs, kps):
        """One q block against a stack of kv blocks (kbs: (n,B,bk,KV,hd))."""

        # checkpointed: the backward pass recomputes each block's scores
        # instead of stacking (bq x bk) probability residuals per step -
        # this IS the flash-attention backward, expressed with remat.
        @jax.checkpoint
        def kv_step(carry, kv_in):
            m, l, acc = carry
            ki, vi, kp = kv_in
            s = jnp.einsum("bqkgd,bskd->bkgqs", qi, ki).astype(jnp.float32)
            s = _add_bias(s * scale, _bias_block(qp, kp, causal, window, kv_len))
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(qi.dtype), vi
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, bq), jnp.float32)
        a0 = jnp.zeros((B, KV, G, bq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kbs, vbs, kps))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.astype(qi.dtype)  # (B,KV,G,bq,hd)

    if causal_skip and causal:
        # static causal frontier per q block (positions are aligned aranges)
        blocks = []
        for i in range(nq):
            hi = min(nk, ((i + 1) * bq + bk - 1) // bk)
            blocks.append(
                q_block(qb[i], qpb[i], kb[:hi], vb[:hi], kpb[:hi])
            )
        ob = jnp.stack(blocks)
    else:
        def q_step(_, q_in):
            qi, qp = q_in
            return None, q_block(qi, qp, kb, vb, kpb)

        _, ob = jax.lax.scan(q_step, None, (qb, qpb))
    # ob: (nq, B, KV, G, bq, hd) -> (B, nq, bq, KV, G, hd) -> (B, T, ...)
    out = ob.transpose(1, 0, 4, 2, 3, 5).reshape(B, nq * bq, KV, G, hd)
    return out[:, :Tq]


def attend(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    q_pos: jax.Array,
    k_pos: jax.Array,
    causal: bool = True,
    window: int | None = None,
    kv_len: jax.Array | None = None,
    dense_threshold: int = 2048 * 2048,
    causal_skip: bool = False,
    decode_lengths: jax.Array | None = None,
    decode_impl: str | None = None,
    decode_block: int | None = None,
) -> jax.Array:
    """Dispatch dense vs blockwise by live-score size — or, for cached
    single-token decode, the ragged flash-decoding kernel.

    ``decode_lengths`` (per-row live KV slot counts, ``(B,)`` int32) plus
    ``decode_impl="flash"`` routes Tq==1 through
    ``kernels.flash_attention.ops.decode_attention``, which masks *only* by
    slot index < length.  That single ragged bound is equivalent to this
    module's full causal + window + empty-sentinel mask recipe under the
    serve ring invariant (``slot(pos) = pos % size`` with ``size <=
    window``): live slots hold exactly the positions ``new_len - min(
    new_len, size) .. new_len - 1``, all of which pass the causal test
    against ``q_pos = new_len - 1`` and sit inside the window, while empty
    or overwritten-pad slots lie at indices >= ``min(new_len, size)``.
    Callers must NOT pass ``decode_lengths`` when that invariant does not
    hold (layers.multihead_attention gates on it).  The masked dense path
    below is the differential oracle for the kernel.  ``decode_block``
    pins the kernel's KV split (None = auto-tuned); the paged serve engine
    pins the contiguous oracle to its block size so both layouts reduce in
    the same order (bitwise differential contract)."""
    Tq, Tk = q.shape[1], k.shape[1]
    if decode_lengths is not None and decode_impl == "flash" and Tq == 1:
        from repro.kernels.flash_attention.ops import decode_attention

        return decode_attention(q[:, 0], k, v, decode_lengths,
                                bk=decode_block)[:, None]
    if Tq * Tk <= dense_threshold:
        return dense_attention(
            q, k, v, q_pos=q_pos, k_pos=k_pos, causal=causal, window=window,
            kv_len=kv_len,
        )
    return blockwise_attention(
        q, k, v, q_pos=q_pos, k_pos=k_pos, causal=causal, window=window,
        kv_len=kv_len, causal_skip=causal_skip,
    )
