"""build(config) -> model object (Model or EncDecModel)."""

from __future__ import annotations

from repro.arch.encdec import EncDecModel
from repro.arch.transformer import Model
from repro.configs.base import ModelConfig


def build(cfg: ModelConfig):
    if cfg.family == "encdec":
        return EncDecModel(cfg)
    return Model(cfg)
