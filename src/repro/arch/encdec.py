"""Whisper-style encoder-decoder backbone.

Per the assignment spec the conv/mel frontend is a STUB: `input_specs`
provides precomputed frame embeddings (B, T_enc, d_model).  The encoder is a
bidirectional transformer over frames; the decoder is a causal transformer
with cross-attention into the encoder output.  Decode shapes exercise the
decoder's self-attention KV cache (cross K/V are computed from the cached
encoder output).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.arch import layers as L
from repro.configs.base import ModelConfig


def enc_block_init(key: jax.Array, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model),
        "attn": L.attention_init(ks[0], cfg),
        "ln2": L.rmsnorm_init(cfg.d_model),
        "mlp": L.mlp_init(ks[1], cfg),
    }


def dec_block_init(key: jax.Array, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model),
        "self_attn": L.attention_init(ks[0], cfg),
        "ln_x": L.rmsnorm_init(cfg.d_model),
        "cross_attn": L.attention_init(ks[1], cfg),
        "ln2": L.rmsnorm_init(cfg.d_model),
        "mlp": L.mlp_init(ks[2], cfg),
    }


@dataclasses.dataclass(frozen=True)
class EncDecModel:
    cfg: ModelConfig

    def init(self, key: jax.Array) -> dict:
        cfg = self.cfg
        ke, k1, k2 = jax.random.split(key, 3)
        ekeys = jax.random.split(k1, cfg.encoder_layers)
        dkeys = jax.random.split(k2, cfg.n_layers)
        return {
            "embed": L.embedding_init(ke, cfg),
            "enc_layers": jax.vmap(lambda k: enc_block_init(k, cfg))(ekeys),
            "dec_layers": jax.vmap(lambda k: dec_block_init(k, cfg))(dkeys),
            "enc_ln": L.rmsnorm_init(cfg.d_model),
            "final_ln": L.rmsnorm_init(cfg.d_model),
        }

    def encode(self, params: dict, frames: jax.Array, remat: bool = False):
        """frames: (B, T_enc, D) precomputed embeddings (frontend stub)."""
        cfg = self.cfg

        def body(x, p):
            h, _ = L.multihead_attention(
                p["attn"], cfg, L.rmsnorm(p["ln1"], x, cfg.norm_eps),
                causal=False,
            )
            x = x + h
            f = L.mlp(p["mlp"], cfg, L.rmsnorm(p["ln2"], x, cfg.norm_eps))
            return x + f, None

        if remat:
            from repro.arch.transformer import remat_policy_of

            body = jax.checkpoint(body, policy=remat_policy_of(cfg))
        x, _ = jax.lax.scan(body, frames, params["enc_layers"])
        return L.rmsnorm(params["enc_ln"], x, cfg.norm_eps)

    def _decoder(
        self, params, x, enc_out, positions, caches, remat: bool
    ):
        cfg = self.cfg

        def body(x, p_c):
            p, c = p_c
            h, nc = L.multihead_attention(
                p["self_attn"], cfg, L.rmsnorm(p["ln1"], x, cfg.norm_eps),
                positions=positions, causal=True, cache=c,
            )
            x = x + h
            h, _ = L.multihead_attention(
                p["cross_attn"], cfg, L.rmsnorm(p["ln_x"], x, cfg.norm_eps),
                kv_x=enc_out, causal=False, use_rope=False,
            )
            x = x + h
            f = L.mlp(p["mlp"], cfg, L.rmsnorm(p["ln2"], x, cfg.norm_eps))
            return x + f, nc

        if remat:
            from repro.arch.transformer import remat_policy_of

            body = jax.checkpoint(body, policy=remat_policy_of(cfg))
        x, ncaches = jax.lax.scan(body, x, (params["dec_layers"], caches))
        return x, ncaches

    def loss(
        self, params: dict, frames: jax.Array, tokens: jax.Array,
        labels: jax.Array, remat: bool = True,
    ) -> jax.Array:
        cfg = self.cfg
        enc_out = self.encode(params, frames, remat=remat)
        x = L.embed(params["embed"], tokens)
        x, _ = self._decoder(params, x, enc_out, None, None, remat)
        logits = L.unembed(params["embed"], L.rmsnorm(params["final_ln"], x, cfg.norm_eps))
        return L.cross_entropy(logits, labels)

    def init_caches(self, batch: int, max_len: int) -> Any:
        per = [
            L.init_kv_cache(self.cfg, batch, max_len)
            for _ in range(self.cfg.n_layers)
        ]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *per)

    def prefill(self, params, frames, tokens, caches):
        enc_out = self.encode(params, frames)
        positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
        x = L.embed(params["embed"], tokens)
        x, caches = self._decoder(params, x, enc_out, positions, caches, False)
        x = L.rmsnorm(params["final_ln"], x, self.cfg.norm_eps)
        return L.unembed(params["embed"], x)[:, -1], (caches, enc_out)

    def decode_step(self, params, tokens, state):
        caches, enc_out = state
        x = L.embed(params["embed"], tokens)
        x, caches = self._decoder(params, x, enc_out, None, caches, False)
        x = L.rmsnorm(params["final_ln"], x, self.cfg.norm_eps)
        return L.unembed(params["embed"], x)[:, -1], (caches, enc_out)
