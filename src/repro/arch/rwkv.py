"""RWKV-6 (Finch) token mixing: data-dependent decay WKV recurrence.

Per head (key dim Dk, value dim Dv), with state S in R^{Dk x Dv}:

    o_t = r_t . (S_{t-1} + u (x) (k_t v_t^T))
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    w_t = exp(-exp(w0 + tanh(x_w A) B))        (the Finch data-dependent decay)

Training/prefill runs a chunked scan (remat inside each chunk) so the
backward pass stores only chunk-boundary states; decode updates the state
one token at a time.  kernels/linear_scan implements the same recurrence as
a Pallas TPU kernel; this module is its semantic reference.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

HEAD_K = 64  # RWKV-6 uses 64-dim heads

LORA_R = 64


def rwkv_head_count(cfg: ModelConfig) -> int:
    return cfg.d_model // HEAD_K


def rwkv_init(key: jax.Array, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    h = rwkv_head_count(cfg)
    sd = jnp.dtype(cfg.dtype)
    init = partial(jax.nn.initializers.normal(0.02 / math.sqrt(d)), dtype=sd)
    ks = jax.random.split(key, 10)
    return {
        # static token-shift lerp coefficients per stream
        "mu": jnp.full((5, d), 0.5, jnp.float32),  # r, k, v, g, w
        "wr": init(ks[0], (d, d)),
        "wk": init(ks[1], (d, d)),
        "wv": init(ks[2], (d, d)),
        "wg": init(ks[3], (d, d)),
        "w0": jnp.full((d,), -3.0, jnp.float32),
        "w_lora_a": init(ks[4], (d, LORA_R)),
        "w_lora_b": jnp.zeros((LORA_R, d), sd),
        "u": jax.nn.initializers.normal(0.5, dtype=jnp.float32)(
            ks[5], (h, HEAD_K)
        ),
        "ln_scale": jnp.ones((d,), jnp.float32),
        "wo": init(ks[6], (d, d)),
    }


def _streams(params: dict, x: jax.Array, x_prev: jax.Array):
    """Token-shift lerp for the five streams; x/(B,T,D), x_prev shifted."""
    mu = params["mu"].astype(x.dtype)
    mix = lambda i: x + (x_prev - x) * mu[i]
    xr, xk, xv, xg, xw = (mix(i) for i in range(5))
    r = xr @ params["wr"]
    k = xk @ params["wk"]
    v = xv @ params["wv"]
    g = jax.nn.silu(xg @ params["wg"])
    logw = -jnp.exp(
        params["w0"].astype(jnp.float32)
        + (jnp.tanh(xw.astype(jnp.float32) @ params["w_lora_a"].astype(jnp.float32))
           @ params["w_lora_b"].astype(jnp.float32))
    )
    w = jnp.exp(logw)  # in (0, 1)
    return r, k, v, g, w


def _wkv_step(state, inputs, u):
    """state: (B,H,Dk,Dv); inputs r,k,v,w: (B,H,Dk|Dv)."""
    r, k, v, w = inputs
    kv = k[..., :, None] * v[..., None, :]                  # (B,H,Dk,Dv)
    o = jnp.einsum("bhk,bhkv->bhv", r, state + u[..., None] * kv)
    state = w[..., :, None] * state + kv
    return state, o


def wkv_scan(
    r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
    u: jax.Array, state: jax.Array, chunk: int = 64,
) -> tuple[jax.Array, jax.Array]:
    """(B,T,H,Dk) streams -> (out (B,T,H,Dv), final state).

    Outer scan over chunks with rematerialized inner scans: backward-pass
    memory is one state per chunk boundary instead of per step.
    """
    B, T, H, Dk = r.shape
    Dv = v.shape[-1]
    pad = (-T) % chunk
    if pad:
        zs = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zs(r), zs(k), zs(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
    nc = (T + pad) // chunk
    # (nc, chunk, B, H, D)
    resh = lambda a: a.reshape(B, nc, chunk, H, -1).transpose(1, 2, 0, 3, 4)
    rc, kc, vc, wc = resh(r), resh(k), resh(v), resh(w)

    @jax.checkpoint
    def chunk_step(s, xs):
        rs, ks, vs, ws = xs

        def step(s, x):
            return _wkv_step(s, x, u)

        s, o = jax.lax.scan(step, s, (rs, ks, vs, ws))
        return s, o

    state, out = jax.lax.scan(chunk_step, state, (rc, kc, vc, wc))
    out = out.reshape(nc * chunk, B, H, Dv).transpose(1, 0, 2, 3)
    return out[:, :T], state


def rwkv_mix(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,                       # (B, T, D)
    cache: dict | None = None,          # {"state": (B,H,Dk,Dv), "x_prev": (B,D)}
) -> tuple[jax.Array, dict | None]:
    B, T, D = x.shape
    H = rwkv_head_count(cfg)
    if cache is not None:
        prev_tok = cache["x_prev"][:, None, :]
    else:
        prev_tok = jnp.zeros((B, 1, D), x.dtype)
    x_prev = jnp.concatenate([prev_tok, x[:, :-1]], axis=1)

    r, k, v, g, w = _streams(params, x, x_prev)
    hs = lambda a: a.reshape(B, T, H, HEAD_K)
    r, k, v, w = hs(r), hs(k), hs(v), hs(w.astype(x.dtype))
    u = params["u"].astype(jnp.float32)

    state = (
        cache["state"]
        if cache is not None
        else jnp.zeros((B, H, HEAD_K, HEAD_K), jnp.float32)
    )
    out, state = wkv_scan(
        r.astype(jnp.float32), k.astype(jnp.float32),
        v.astype(jnp.float32), w.astype(jnp.float32), u, state,
    )
    o = out.reshape(B, T, D).astype(x.dtype)
    # group-norm per head approximated by rms over D, then gate
    of = o.astype(jnp.float32)
    o = (of * jax.lax.rsqrt(jnp.mean(of * of, -1, keepdims=True) + 1e-6)
         * params["ln_scale"]).astype(x.dtype)
    o = o * g
    y = o @ params["wo"]
    new_cache = None
    if cache is not None:
        new_cache = {"state": state, "x_prev": x[:, -1]}
    return y, new_cache


def rwkv_init_cache(cfg: ModelConfig, batch: int) -> dict:
    H = rwkv_head_count(cfg)
    return {
        "state": jnp.zeros((batch, H, HEAD_K, HEAD_K), jnp.float32),
        "x_prev": jnp.zeros((batch, cfg.d_model), jnp.dtype(cfg.dtype)),
    }
