"""Shared neural layers: norms, RoPE, GQA attention, MLPs, embeddings.

Pure-function style: each layer is (init(key, cfg) -> params) plus
(apply(params, x, ...) -> y) with params as plain dict pytrees, so sharding
rules (parallel/sharding.py) can address them by path and jax.eval_shape can
build the dry-run without allocation.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ------------------------------------------------------------------- norms --


def rmsnorm_init(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"]).astype(x.dtype)


# -------------------------------------------------------------------- RoPE --


def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> tuple:
    """positions: (...,) int32 -> cos/sin of shape (..., head_dim//2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., T, H, D). cos/sin: (..., T, D//2) broadcast over heads."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate((x1 * c - x2 * s, x2 * c + x1 * s), axis=-1).astype(
        x.dtype
    )


# --------------------------------------------------------------- attention --


def attention_init(key: jax.Array, cfg: ModelConfig) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    sd = dtype_of(cfg)
    init = partial(jax.nn.initializers.normal(0.02 / math.sqrt(d)), dtype=sd)
    return {
        "wq": init(ks[0], (d, h * hd)),
        "wk": init(ks[1], (d, kv * hd)),
        "wv": init(ks[2], (d, kv * hd)),
        "wo": init(ks[3], (h * hd, d)),
    }


# set by Model.prefill while tracing a prefill-from-position-zero, which
# makes cached-attention positions aligned aranges (enables causal_skip)
_PREFILL_ALIGNED = [False]


class prefill_aligned:
    def __enter__(self):
        _PREFILL_ALIGNED[0] = True

    def __exit__(self, *a):
        _PREFILL_ALIGNED[0] = False


# trace-time overrides, each a one-slot stack swapped for the duration of
# a `with` block:
#   * matmul_override: route the dense projection GEMMs through the Pallas
#     kernel with mapper-chosen tiles (kernels/matmul/ops.py).  None =
#     plain jnp dot (the training/default path, bit-identical to before).
#   * attention_override: route cached single-token decode attention
#     through the ragged flash-decoding kernel
#     (kernels/flash_attention/decode_attention) with per-slot live
#     lengths instead of the broadcast position mask.  None = the
#     dense/blockwise oracle path.
#   * decode_block_override: pin the KV-axis split of ragged decode
#     attention (the ``bk`` the contiguous twin iterates in).  The paged
#     serve engine pins the contiguous oracle to its block size so the two
#     layouts run the *same* online-softmax reduction order — the bitwise
#     differential contract.  None = auto (`ops._pick_decode_bk`).
_MATMUL_IMPL: list = [None]
_ATTENTION_IMPL: list = [None]
_DECODE_BLOCK: list = [None]
#   * _ABFT: an AbftTrace (kernels/abft.py) or None.  When set, every
#     projection routed through _mm gets a column-checksum verify and the
#     paged decode-attention output gets a sampled-row fingerprint check;
#     the trace also carries the seeded fault operand for SDC injection.
_ABFT: list = [None]


class _override:
    def __init__(self, slot: list, value):
        self._slot = slot
        self._value = value

    def __enter__(self):
        self._prev = self._slot[0]
        self._slot[0] = self._value

    def __exit__(self, *a):
        self._slot[0] = self._prev


def matmul_override(impl) -> _override:
    return _override(_MATMUL_IMPL, impl)


def attention_override(impl: str | None) -> _override:
    if impl not in (None, "flash"):
        # anything unrecognized would silently run the oracle while the
        # caller believes the kernel is active
        raise ValueError(f"attention impl must be None or 'flash': {impl!r}")
    return _override(_ATTENTION_IMPL, impl)


def decode_block_override(bk: int | None) -> _override:
    if bk is not None and (not isinstance(bk, int) or bk < 1):
        raise ValueError(f"decode block must be a positive int: {bk!r}")
    return _override(_DECODE_BLOCK, bk)


def abft_override(trace) -> _override:
    return _override(_ABFT, trace)


def abft_active():
    """The installed AbftTrace, if any (scan bodies consult it to drain
    per-layer verdicts and to tag the traced layer index)."""
    return _ABFT[0]


def _mm(x: jax.Array, w: jax.Array) -> jax.Array:
    impl = _MATMUL_IMPL[0]
    trace = _ABFT[0]
    if trace is not None:
        # the trace owns the matmul: it appends the e^T·x checksum row so
        # the ABFT reference rides the product GEMM (kernels/abft.py)
        return trace.mm(x, w, impl)
    return x @ w if impl is None else impl(x, w)


def init_kv_cache(
    cfg: ModelConfig, batch: int, max_len: int, window: int | None = None
) -> dict:
    """Per-layer KV cache.  Sliding-window layers get a ring buffer of the
    window size (a 500k-token context must not allocate 500k slots for a
    1k-window layer).

    ``pos``/``len`` are PER BATCH ROW so each row can sit at a different
    sequence position — the slot-based continuous-batching engine
    (serve/kvcache.py) relies on this to admit/evict requests one slot at a
    time while decode stays one shape-stable compiled program."""
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    size = max_len if window is None else min(max_len, window)
    sd = dtype_of(cfg)
    return {
        "k": jnp.zeros((batch, size, kv, hd), sd),
        "v": jnp.zeros((batch, size, kv, hd), sd),
        # empty slots carry position +1e9 so the causal test masks them
        "pos": jnp.full((batch, size), 10**9, jnp.int32),
        "len": jnp.zeros((batch,), jnp.int32),
    }


def multihead_attention(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,                     # (B, Tq, D)
    *,
    kv_x: jax.Array | None = None,    # cross-attention source (B, Tk, D)
    positions: jax.Array | None = None,   # absolute q positions (Tq,)
    causal: bool = True,
    window: int | None = None,
    use_rope: bool = True,
    cache: dict | None = None,
    ragged_ok: bool | None = None,
) -> tuple[jax.Array, dict | None]:
    """GQA attention; with `cache` given, appends this step's K/V into the
    (ring) buffer and attends over it.  Returns (out, new_cache).

    ``ragged_ok`` asserts the ring invariant the flash-decoding path needs
    (every live cache slot is inside the layer's window — true whenever the
    ring extent <= window).  None = derive it locally from a static
    ``window``; scanned (traced) windows must pass the hint explicitly or
    the decode stays on the oracle path."""
    from repro.arch.attention import attend

    B, Tq, D = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    src = x if kv_x is None else kv_x
    Tk = src.shape[1]

    from repro.parallel.policy import shard

    q = shard(_mm(x, params["wq"]), "batch", "seq", "heads").reshape(
        B, Tq, h, hd
    )
    k = shard(_mm(src, params["wk"]), "batch", "seq", "kv_heads").reshape(
        B, Tk, kv, hd
    )
    v = shard(_mm(src, params["wv"]), "batch", "seq", "kv_heads").reshape(
        B, Tk, kv, hd
    )

    if positions is None:
        if cache is not None:
            # per-row base: rows of a slot cache sit at different positions
            positions = cache["len"][:, None] + jnp.arange(Tq, dtype=jnp.int32)
        else:
            positions = jnp.arange(Tq, dtype=jnp.int32)
    k_pos = positions if kv_x is None else jnp.arange(Tk, dtype=jnp.int32)
    if use_rope:
        qc, qs = rope_angles(positions, hd, cfg.rope_theta)
        q = apply_rope(q, qc, qs)
        kc, ks_ = rope_angles(k_pos, hd, cfg.rope_theta)
        k = apply_rope(k, kc, ks_)

    new_cache = None
    kv_len = None
    decode_lengths = None
    attn_impl = _ATTENTION_IMPL[0]
    if cache is not None and "kpool" in cache:
        # paged slot cache: this token's K/V scatter through the block
        # table into the shared pool at (table[row, pos // bs], pos % bs).
        # The engine guarantees exclusive write ownership of that block
        # (fresh allocation or copy-on-write before the step), so rows
        # never collide; evicted rows aim every table entry at the sink
        # block, whose garbage nothing live reads.
        if Tq != 1 or kv_x is not None:
            raise ValueError(
                "paged KV caches serve single-token decode only; admission "
                "prefills into a contiguous scratch and packs blocks"
            )
        bs = cache["kpool"].shape[1]
        p_ins = cache["len"]                        # (B,) write positions
        phys = jnp.take_along_axis(
            cache["table"], (p_ins // bs)[:, None], axis=1
        )[:, 0]
        kpool = cache["kpool"].at[phys, p_ins % bs].set(
            k[:, 0].astype(cache["kpool"].dtype)
        )
        vpool = cache["vpool"].at[phys, p_ins % bs].set(
            v[:, 0].astype(cache["vpool"].dtype)
        )
        new_cache = {
            "kpool": kpool, "vpool": vpool,
            "table": cache["table"], "len": p_ins + 1,
        }
        from repro.kernels.flash_attention.ops import decode_attention_paged

        g = h // kv
        qg = q.reshape(B, kv, g, hd)
        ctx = decode_attention_paged(
            qg,
            kpool, vpool, cache["table"], p_ins + 1,
            # supports_paged admits only all-global configs, so the scanned
            # per-layer window (traced here) is always the 2^30 sentinel
            window=None,
            # "flash" -> backend auto (Pallas on TPU, jnp twin on CPU);
            # oracle-mode engines pin the exact gather twin
            impl=None if attn_impl == "flash" else "xla",
        )
        trace = _ABFT[0]
        if trace is not None:
            ctx = trace.check_paged_attention(
                ctx, qg, kpool, vpool, cache["table"], p_ins + 1
            )
        return _mm(ctx.reshape(B, Tq, h * hd), params["wo"]), new_cache
    if cache is not None:
        size = cache["k"].shape[1]
        # per-row insert positions (rows may differ under slot batching)
        p_ins = jnp.broadcast_to(
            positions if positions.ndim == 2 else positions[None, :], (B, Tq)
        )
        k_ins, v_ins = k, v
        if Tk > size:  # ring smaller than the insert: keep the last `size`
            k_ins, v_ins, p_ins = k[:, -size:], v[:, -size:], p_ins[:, -size:]
        # ring invariant: slot(pos) = pos % size, independently per row
        slots = p_ins % size
        row_set = jax.vmap(lambda buf, idx, val: buf.at[idx].set(val))
        ck = row_set(cache["k"], slots, k_ins)
        cv = row_set(cache["v"], slots, v_ins)
        cpos = row_set(cache["pos"], slots, p_ins)
        new_cache = {
            "k": ck, "v": cv, "pos": cpos, "len": cache["len"] + Tq,
        }
        k, v, k_pos = ck, cv, cpos
        # ragged flash-decoding: one query token per slot attends over
        # live cache slots [0, min(len + 1, size)) — equivalent to the
        # position-mask recipe when the ring extent fits the window (see
        # attention.attend).  Scanned traced windows can't be checked
        # here; those callers pass ragged_ok from static layer metadata.
        if attn_impl is not None and Tq == 1 and kv_x is None:
            if ragged_ok is None:
                ragged_ok = window is None or (
                    not isinstance(window, jax.Array) and size <= int(window)
                )
            if ragged_ok:
                decode_lengths = jnp.minimum(new_cache["len"], size)

    g = h // kv
    qg = q.reshape(B, Tq, kv, g, hd)
    # static causal-frontier skip needs aligned arange positions (no cache).
    # NOTE (§Perf, refuted path): enabling it for aligned prefill-with-cache
    # (_PREFILL_ALIGNED) produces an XLA SPMD verifier INTERNAL error - the
    # unrolled q-blocks + in-loop cache scatter combination is rejected by
    # the partitioner, so the skip stays train/cache-free only.
    skip_ok = cfg.causal_skip and kv_x is None and cache is None
    ctx = attend(
        qg, k, v, q_pos=positions, k_pos=k_pos, causal=causal,
        window=window, kv_len=kv_len, causal_skip=skip_ok,
        decode_lengths=decode_lengths, decode_impl=attn_impl,
        decode_block=_DECODE_BLOCK[0],
    ).reshape(B, Tq, h * hd)
    return _mm(ctx, params["wo"]), new_cache


# -------------------------------------------------------------------- MLPs --


def mlp_init(key: jax.Array, cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    sd = dtype_of(cfg)
    init = partial(jax.nn.initializers.normal(0.02 / math.sqrt(d)), dtype=sd)
    ks = jax.random.split(key, 3)
    p = {"w_in": init(ks[0], (d, f)), "w_out": init(ks[1], (f, d))}
    if cfg.mlp_act == "swiglu":
        p["w_gate"] = init(ks[2], (d, f))
    return p


def mlp(params: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    from repro.parallel.policy import shard

    h = shard(_mm(x, params["w_in"]), "batch", "seq", "ff")
    if cfg.mlp_act == "swiglu":
        h = jax.nn.silu(
            shard(_mm(x, params["w_gate"]), "batch", "seq", "ff")
        ) * h
    else:
        h = jax.nn.gelu(h)
    return shard(_mm(h, params["w_out"]), "batch", "seq", "embed")


# -------------------------------------------------------------- embeddings --


def embedding_init(key: jax.Array, cfg: ModelConfig) -> dict:
    sd = dtype_of(cfg)
    p = {
        "tok": jax.nn.initializers.normal(0.02, dtype=sd)(
            key, (cfg.vocab, cfg.d_model)
        )
    }
    if not cfg.tie_embeddings:
        p["unembed"] = jax.nn.initializers.normal(0.02, dtype=sd)(
            jax.random.fold_in(key, 1), (cfg.d_model, cfg.vocab)
        )
    return p


def embed(params: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["tok"], tokens, axis=0)


def unembed(params: dict, x: jax.Array) -> jax.Array:
    from repro.parallel.policy import shard

    if "unembed" in params:
        out = _mm(x, params["unembed"])
    else:
        out = _mm(x, params["tok"].T)
    names = ("batch", "seq", "vocab")[-out.ndim:]
    return shard(out, *names)


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token CE in fp32; labels < 0 are masked out."""
    lf = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(
        lf, jnp.maximum(labels, 0)[..., None], axis=-1
    )[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((logz - ll) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
