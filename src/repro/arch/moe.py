"""Mixture-of-Experts: top-k router + sort-based capacity dispatch.

Dispatch is the sort/scatter formulation (no (tokens x experts x capacity)
one-hot blow-up): flatten tokens, route top-k, rank tokens within their
expert via a sort, scatter into an (E * C, D) buffer, run the batched expert
FFN as one einsum over the stacked expert weights, and combine with gather +
gate weighting.  Tokens over capacity are dropped (standard Switch-style).

Sharding: expert weights are stacked (E, D, F) so the FFN hidden dim F can be
tensor-parallel over the 'model' axis and the stack FSDP-sharded over 'data';
tokens stay on their data shard (no all-to-all in the baseline plan).  An
expert-parallel all_to_all variant is evaluated in the §Perf hillclimb.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def moe_init(key: jax.Array, cfg: ModelConfig) -> dict:
    assert cfg.moe is not None
    d, e, f = cfg.d_model, cfg.moe.num_experts, cfg.moe.d_expert
    sd = jnp.dtype(cfg.dtype)
    init = partial(jax.nn.initializers.normal(0.02 / math.sqrt(d)), dtype=sd)
    ks = jax.random.split(key, 4)
    p = {
        "router": jax.nn.initializers.normal(0.02, dtype=jnp.float32)(
            ks[0], (d, e)
        ),
        "w_in": init(ks[1], (e, d, f)),
        "w_out": init(ks[2], (e, f, d)),
    }
    if cfg.mlp_act == "swiglu":
        p["w_gate"] = init(ks[3], (e, d, f))
    return p


def moe_apply(
    params: dict, cfg: ModelConfig, x: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss).

    Dispatch is PER ROW (per sequence): every row routes its own S*K
    assignments into its own (E, C_row) capacity slots.  With the batch dim
    sharded over the data axis this keeps routing, scatter, expert compute
    and combine entirely shard-local - the naive flat-token formulation made
    XLA replicate the dispatch buffer and all-reduce fp32 expert-activation
    gradients across the data axis every microbatch (§Perf, grok hillclimb:
    the single largest collective in the baseline profile).
    """
    assert cfg.moe is not None
    B, S, D = x.shape
    E, K = cfg.moe.num_experts, cfg.moe.top_k
    SK = S * K

    from repro.parallel.policy import shard

    logits = (x.astype(jnp.float32) @ params["router"])   # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)       # (B, S, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # load-balancing auxiliary loss (Switch): E * <f_e * p_e>
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_ids, E, dtype=jnp.float32), axis=2),
        axis=(0, 1),
    ) / K
    aux = E * jnp.sum(me * ce)

    C = max(1, int(math.ceil(SK * cfg.moe.capacity_factor / E)))

    # rank within expert, per row
    flat_e = expert_ids.reshape(B, SK)
    sort_idx = jnp.argsort(flat_e, axis=1)
    sorted_e = jnp.take_along_axis(flat_e, sort_idx, axis=1)
    counts = jnp.sum(jax.nn.one_hot(flat_e, E, dtype=jnp.int32), axis=1)
    starts = jnp.cumsum(counts, axis=1) - counts          # (B, E)
    rank = (
        jnp.arange(SK, dtype=jnp.int32)[None, :]
        - jnp.take_along_axis(starts, sorted_e, axis=1)
    )
    keep = rank < C
    slot = sorted_e * C + jnp.minimum(rank, C - 1)        # (B, SK)
    token_of = sort_idx // K                              # (B, SK)

    rows = jnp.arange(B)[:, None]
    vals = jnp.where(
        keep[..., None],
        jnp.take_along_axis(x, token_of[..., None], axis=1),
        0,
    )
    dispatched = jnp.zeros((B, E * C, D), x.dtype).at[rows, slot].set(vals)
    de = shard(
        dispatched.reshape(B, E, C, D), "batch", "expert", None, "embed"
    )

    h = jnp.einsum("becd,edf->becf", de, params["w_in"])
    h = shard(h, "batch", "expert", None, "ff")
    if cfg.mlp_act == "swiglu":
        g = jnp.einsum("becd,edf->becf", de, params["w_gate"])
        h = jax.nn.silu(shard(g, "batch", "expert", None, "ff")) * h
    else:
        h = jax.nn.gelu(h)
    eo = jnp.einsum("becf,efd->becd", h, params["w_out"])
    eo = shard(eo, "batch", "expert", None, "embed").reshape(B, E * C, D)

    gathered = eo[rows, slot]                              # (B, SK, D)
    w = jnp.where(
        keep,
        jnp.take_along_axis(gate_vals.reshape(B, SK), sort_idx, axis=1),
        0.0,
    )
    out = jnp.zeros((B, S, D), jnp.float32)
    out = out.at[rows, token_of].add(
        gathered.astype(jnp.float32) * w[..., None]
    )
    return out.astype(x.dtype), aux
