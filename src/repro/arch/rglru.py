"""RG-LRU recurrent block (RecurrentGemma / Griffin).

    a_t = exp(-c * softplus(Lambda) * sigmoid(W_a x_t))
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (sigmoid(W_i x_t) * x_t)

wrapped in the Griffin recurrent block:
    y = GeLU(W_y x)  ;  z = conv1d(W_x x)  ;  z = RG-LRU(z)
    out = W_o (y * z)

Chunked scan with remat, same memory strategy as rwkv.wkv_scan; the Pallas
kernels/linear_scan implements the diagonal recurrence on TPU.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

RGLRU_C = 8.0


def rglru_init(key: jax.Array, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    w = cfg.rnn_width or d
    sd = jnp.dtype(cfg.dtype)
    init = partial(jax.nn.initializers.normal(0.02 / math.sqrt(d)), dtype=sd)
    ks = jax.random.split(key, 6)
    return {
        "w_y": init(ks[0], (d, w)),
        "w_x": init(ks[1], (d, w)),
        "conv": jax.nn.initializers.normal(0.02, dtype=sd)(
            ks[2], (cfg.conv1d_width, w)
        ),
        "w_a": init(ks[3], (w, w)),
        "w_i": init(ks[4], (w, w)),
        # Lambda init so that a^c spans (0.9, 0.999), Griffin appendix
        "lam": jnp.linspace(0.9, 4.0, w, dtype=jnp.float32),
        "w_o": init(ks[5], (w, d)),
    }


def _causal_conv1d(
    z: jax.Array, kernel: jax.Array, prev: jax.Array | None
) -> tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. z: (B,T,W), kernel: (K,W).
    prev: (B,K-1,W) history for decode; returns (out, new history)."""
    B, T, Wd = z.shape
    K = kernel.shape[0]
    if prev is None:
        prev = jnp.zeros((B, K - 1, Wd), z.dtype)
    zp = jnp.concatenate([prev, z], axis=1)
    out = jnp.zeros_like(z)
    for i in range(K):
        out = out + zp[:, i : i + T] * kernel[K - 1 - i]
    return out, zp[:, -(K - 1):]


def rglru_scan(
    a: jax.Array, gx: jax.Array, h0: jax.Array, chunk: int = 64
) -> tuple[jax.Array, jax.Array]:
    """h_t = a_t*h_{t-1} + sqrt(1-a_t^2)*gx_t ; a,gx: (B,T,W) fp32."""
    B, T, Wd = a.shape
    pad = (-T) % chunk
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        gx = jnp.pad(gx, ((0, 0), (0, pad), (0, 0)))
    nc = a.shape[1] // chunk
    ac = a.reshape(B, nc, chunk, Wd).transpose(1, 2, 0, 3)
    gc = gx.reshape(B, nc, chunk, Wd).transpose(1, 2, 0, 3)

    @jax.checkpoint
    def chunk_step(h, xs):
        aa, gg = xs

        def step(h, x):
            at, gt = x
            h = at * h + jnp.sqrt(jnp.maximum(1.0 - at * at, 0.0)) * gt
            return h, h

        return jax.lax.scan(step, h, (aa, gg))

    h, out = jax.lax.scan(chunk_step, h0, (ac, gc))
    out = out.reshape(nc * chunk, B, Wd).transpose(1, 0, 2)
    return out[:, :T], h


def rglru_block(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,                  # (B, T, D)
    cache: dict | None = None,     # {"h": (B,W), "conv": (B,K-1,W)}
) -> tuple[jax.Array, dict | None]:
    B, T, D = x.shape
    y = jax.nn.gelu(x @ params["w_y"])
    z = x @ params["w_x"]
    z, conv_hist = _causal_conv1d(
        z, params["conv"], cache["conv"] if cache is not None else None
    )
    zf = z.astype(jnp.float32)
    log_a = (
        -RGLRU_C
        * jax.nn.softplus(params["lam"])
        * jax.nn.sigmoid(zf @ params["w_a"].astype(jnp.float32))
    )
    a = jnp.exp(log_a)
    gate_in = jax.nn.sigmoid(zf @ params["w_i"].astype(jnp.float32)) * zf
    h0 = (
        cache["h"]
        if cache is not None
        else jnp.zeros((B, a.shape[-1]), jnp.float32)
    )
    out, h = rglru_scan(a, gate_in, h0)
    out = out.astype(x.dtype) * y
    res = out @ params["w_o"]
    new_cache = None
    if cache is not None:
        new_cache = {"h": h, "conv": conv_hist}
    return res, new_cache


def rglru_init_cache(cfg: ModelConfig, batch: int) -> dict:
    w = cfg.rnn_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv1d_width - 1, w), jnp.dtype(cfg.dtype)),
    }
