"""Ragged flash-decoding Pallas kernel (single-query decode attention).

Flash-decoding is the FlashAttention online-softmax recurrence split along
the KV axis — the paper's blocked-loop-nest story applied to the serve hot
loop.  One query token per (slot, kv head) attends over a ragged prefix of
the slot's KV cache:

  * grid ``(batch * kv_heads, kv_splits)``: rows are independent; the KV
    split axis is innermost and sequential, so the online-softmax partials
    (running max / normalizer / fp32 accumulator) live in VMEM scratch and
    are combined across splits without materializing per-split outputs.
  * per-row KV **lengths are a scalar-prefetch operand** (SMEM, available
    before the body runs): a traced ``(B,)`` int32, so lengths changing
    every decode step never recompiles, and the k/v index maps alias every
    block past ``ceil(len/bk)`` to the last live block — consecutive equal
    block indices elide the HBM->VMEM copy, so each slot only *reads*
    ``ceil(len/bk)`` KV blocks.  Dead blocks also skip compute via
    ``pl.when``.
  * GQA is resolved **inside** the kernel: q rows are ``(G, d)`` groups and
    the k/v index maps divide the row id by ``kv_heads`` — KV tiles are
    fetched once per kv head, never broadcast G-fold beforehand.

k/v come in the serve engine's native cache layout ``(B, S, KV, d)`` so the
donated decode loop hands the ring buffers to the kernel with zero copies.

Masking contract: a row's live keys are exactly cache slots
``[0, lengths[b])``, with ``lengths`` clamped to ``[1, S]`` — the serve
loop always scatters the current token before attending, so a live row has
at least one key (length 0 is NOT a fully-masked row here; the dense ref
is the place that models it).  The serve ring invariant (``slot(pos) = pos % size``
with ``size <= window``) makes that single ragged bound equivalent to the
causal + sliding-window + empty-slot mask recipe of ``arch.attention`` —
see ``arch/attention.attend``'s decode dispatch for the derivation.

:func:`decode_attention_xla` is the kernel's jnp twin for CPU serving: the
same blocked online-softmax recurrence, vectorized over rows, with a
``lax.while_loop`` whose trip count is ``ceil(max(lengths)/bk)`` — decode
step time scales with the *live* length, not ``max_len``.  Contributions of
a fully-masked block are exactly zero (``exp(NEG_INF - m)`` underflows and
the correction factor is ``exp(0)``), so padding rows to the batch max is
bitwise-neutral, which keeps batched serving bitwise-equal to solo runs.

Paged variants (:func:`flash_decode_paged_pallas`,
:func:`decode_attention_paged_xla`): k/v live in a shared **block pool**
``(num_blocks, block_size, KV, d)`` instead of a dense per-slot axis, and
each row carries a **block table** ``(B, max_blocks)`` mapping its logical
block ``j`` to a physical pool block.  The grid stays
``(batch * kv_heads, kv_splits)`` with ``kv_splits == max_blocks``; the
only change is that the k/v index maps go through the table — a second
scalar-prefetch operand — so block-table *contents* never recompile, and
dead splits alias to the row's last live **physical** block exactly like
the dense variant.  Because the KV split boundary is the block boundary,
the paged recurrence visits the same logical key ranges in the same order
as the dense kernel at ``bk == block_size``: outputs are bitwise equal,
which is what lets the contiguous serve engine act as the paged engine's
differential oracle.  An optional ``window`` additionally masks
``k_idx <= length - 1 - window`` for sliding-window rows (position ==
logical index in the paged layout; there is no ring).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_attention.flash_attention import (
    NEG_INF,
    finalize_out,
    last_live_block,
    reset_carry,
)


def _decode_kernel(
    lens_ref,                     # SMEM (B,) int32 scalar-prefetch
    q_ref,                        # (1, G, d)
    k_ref,                        # (1, bk, 1, d)
    v_ref,                        # (1, bk, 1, d)
    o_ref,                        # (1, G, d)
    m_ref, l_ref, acc_ref,        # VMEM scratch: (G,), (G,), (G, d) fp32
    *, kv_heads: int, bk: int, n_k: int, scale: float,
):
    bh = pl.program_id(0)
    j = pl.program_id(1)
    length = lens_ref[bh // kv_heads]

    @pl.when(j == 0)
    def _init():
        reset_carry(m_ref, l_ref, acc_ref)

    @pl.when(j * bk < length)
    def _live():
        q = q_ref[0]                      # (G, d)
        k = k_ref[0, :, 0, :]             # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                         # (G, bk)
        k_idx = j * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_idx < length, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0, :, 0, :],
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
        )

    @pl.when(j == n_k - 1)
    def _store():
        finalize_out(o_ref, l_ref, acc_ref)


def flash_decode_pallas(
    q: jax.Array,         # (B, KV, G, d) one query token per (slot, head)
    k: jax.Array,         # (B, S, KV, d) native cache layout
    v: jax.Array,         # (B, S, KV, d)
    lengths: jax.Array,   # (B,) int32 live KV slots per row (traced)
    *,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, KV, G, d = q.shape
    S = k.shape[1]
    assert S % bk == 0, (S, bk)
    n_k = S // bk
    scale = 1.0 / math.sqrt(d)
    lengths = jnp.clip(lengths.astype(jnp.int32), 1, S)

    def kv_block(bh, j, lens):
        last = last_live_block(lens[bh // KV], bk)
        return (bh // KV, jnp.minimum(j, last), bh % KV, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B * KV, n_k),
        in_specs=[
            pl.BlockSpec((1, G, d), lambda bh, j, lens: (bh, 0, 0)),
            pl.BlockSpec((1, bk, 1, d), kv_block),
            pl.BlockSpec((1, bk, 1, d), kv_block),
        ],
        out_specs=pl.BlockSpec((1, G, d), lambda bh, j, lens: (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, d), jnp.float32),
        ],
    )
    kern = functools.partial(
        _decode_kernel, kv_heads=KV, bk=bk, n_k=n_k, scale=scale,
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * KV, G, d), q.dtype),
        interpret=interpret,
    )(lengths, q.reshape(B * KV, G, d), k, v)
    return out.reshape(B, KV, G, d)


def decode_attention_xla(
    q: jax.Array,         # (B, KV, G, d)
    k: jax.Array,         # (B, S, KV, d)
    v: jax.Array,         # (B, S, KV, d)
    lengths: jax.Array,   # (B,) int32
    *,
    bk: int = 128,
) -> jax.Array:
    """The kernel's jnp twin: same blocked recurrence, rows vectorized,
    while-loop trip count = the batch's deepest live split."""
    B, KV, G, d = q.shape
    S = k.shape[1]
    assert S % bk == 0, (S, bk)
    scale = 1.0 / math.sqrt(d)
    lengths = jnp.clip(lengths.astype(jnp.int32), 1, S)
    n_live = jnp.max((lengths + bk - 1) // bk)

    def body(state):
        j, m, l, acc = state
        kb = jax.lax.dynamic_slice_in_dim(k, j * bk, bk, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, j * bk, bk, axis=1)
        s = jnp.einsum(
            "bhgd,bshd->bhgs", q, kb, preferred_element_type=jnp.float32
        ) * scale                                       # (B, KV, G, bk)
        k_idx = j * bk + jnp.arange(bk, dtype=jnp.int32)
        live = k_idx[None, :] < lengths[:, None]        # (B, bk)
        s = jnp.where(live[:, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhgs,bshd->bhgd", p.astype(v.dtype), vb,
            preferred_element_type=jnp.float32,
        )
        return j + 1, m_new, l, acc

    state = (
        jnp.int32(0),
        jnp.full((B, KV, G), NEG_INF, jnp.float32),
        jnp.zeros((B, KV, G), jnp.float32),
        jnp.zeros((B, KV, G, d), jnp.float32),
    )
    _, _, l, acc = jax.lax.while_loop(lambda st: st[0] < n_live, body, state)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


# ------------------------------------------------------------ paged variants


def _paged_live(k_idx, length, window):
    """Live-key predicate for paged decode: logical index < length, plus an
    optional sliding window against the query position ``length - 1``
    (logical index == absolute position in the paged layout)."""
    ok = k_idx < length
    if window is not None:
        ok &= k_idx > length - 1 - window
    return ok


def _paged_decode_kernel(
    lens_ref,                     # SMEM (B,) int32 scalar-prefetch
    table_ref,                    # SMEM (B, n_blk) int32 scalar-prefetch
    q_ref,                        # (1, G, d)
    k_ref,                        # (1, bs, 1, d) one physical pool block
    v_ref,                        # (1, bs, 1, d)
    o_ref,                        # (1, G, d)
    m_ref, l_ref, acc_ref,        # VMEM scratch: (G,), (G,), (G, d) fp32
    *, kv_heads: int, bs: int, n_blk: int, scale: float, window: int | None,
):
    bh = pl.program_id(0)
    j = pl.program_id(1)
    length = lens_ref[bh // kv_heads]

    @pl.when(j == 0)
    def _init():
        reset_carry(m_ref, l_ref, acc_ref)

    @pl.when(j * bs < length)
    def _live():
        q = q_ref[0]                      # (G, d)
        k = k_ref[0, :, 0, :]             # (bs, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                         # (G, bs)
        k_idx = j * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(_paged_live(k_idx, length, window), s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0, :, 0, :],
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
        )

    @pl.when(j == n_blk - 1)
    def _store():
        finalize_out(o_ref, l_ref, acc_ref)


def flash_decode_paged_pallas(
    q: jax.Array,         # (B, KV, G, d) one query token per (row, head)
    kpool: jax.Array,     # (num_blocks, bs, KV, d) shared block pool
    vpool: jax.Array,     # (num_blocks, bs, KV, d)
    tables: jax.Array,    # (B, n_blk) int32 logical -> physical block
    lengths: jax.Array,   # (B,) int32 live tokens per row (traced)
    *,
    window: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    B, KV, G, d = q.shape
    bs = kpool.shape[1]
    n_blk = tables.shape[1]
    scale = 1.0 / math.sqrt(d)
    lengths = jnp.clip(lengths.astype(jnp.int32), 1, n_blk * bs)
    tables = tables.astype(jnp.int32)

    def kv_block(bh, j, lens, tabs):
        b = bh // KV
        last = last_live_block(lens[b], bs)
        return (tabs[b, jnp.minimum(j, last)], 0, bh % KV, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B * KV, n_blk),
        in_specs=[
            pl.BlockSpec((1, G, d), lambda bh, j, lens, tabs: (bh, 0, 0)),
            pl.BlockSpec((1, bs, 1, d), kv_block),
            pl.BlockSpec((1, bs, 1, d), kv_block),
        ],
        out_specs=pl.BlockSpec((1, G, d), lambda bh, j, lens, tabs: (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, d), jnp.float32),
        ],
    )
    kern = functools.partial(
        _paged_decode_kernel,
        kv_heads=KV, bs=bs, n_blk=n_blk, scale=scale, window=window,
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * KV, G, d), q.dtype),
        interpret=interpret,
    )(lengths, tables, q.reshape(B * KV, G, d), kpool, vpool)
    return out.reshape(B, KV, G, d)


def decode_attention_paged_xla(
    q: jax.Array,         # (B, KV, G, d)
    kpool: jax.Array,     # (num_blocks, bs, KV, d)
    vpool: jax.Array,     # (num_blocks, bs, KV, d)
    tables: jax.Array,    # (B, n_blk) int32
    lengths: jax.Array,   # (B,) int32
    *,
    window: int | None = None,
) -> jax.Array:
    """Gather-based jnp twin of the paged kernel: the same blocked
    recurrence as :func:`decode_attention_xla` with the KV block fetched
    through the block table (one ``(B,)`` gather per live split) instead of
    a dynamic slice.  At ``bk == block_size`` the two twins are bitwise
    equal on equal logical contents — the paged serve engine's differential
    oracle rests on this."""
    B, KV, G, d = q.shape
    bs = kpool.shape[1]
    scale = 1.0 / math.sqrt(d)
    lengths = jnp.clip(lengths.astype(jnp.int32), 1, tables.shape[1] * bs)
    tables = tables.astype(jnp.int32)
    n_live = jnp.max((lengths + bs - 1) // bs)

    def body(state):
        j, m, l, acc = state
        phys = jax.lax.dynamic_slice_in_dim(tables, j, 1, axis=1)[:, 0]
        kb = jnp.take(kpool, phys, axis=0)              # (B, bs, KV, d)
        vb = jnp.take(vpool, phys, axis=0)
        s = jnp.einsum(
            "bhgd,bshd->bhgs", q, kb, preferred_element_type=jnp.float32
        ) * scale                                       # (B, KV, G, bs)
        k_idx = j * bs + jnp.arange(bs, dtype=jnp.int32)
        live = _paged_live(k_idx[None, :], lengths[:, None], window)
        s = jnp.where(live[:, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhgs,bshd->bhgd", p.astype(vpool.dtype), vb,
            preferred_element_type=jnp.float32,
        )
        return j + 1, m_new, l, acc

    state = (
        jnp.int32(0),
        jnp.full((B, KV, G), NEG_INF, jnp.float32),
        jnp.zeros((B, KV, G), jnp.float32),
        jnp.zeros((B, KV, G, d), jnp.float32),
    )
    _, _, l, acc = jax.lax.while_loop(lambda st: st[0] < n_live, body, state)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)
