"""Flash attention Pallas kernel (online softmax, TPU tiling).

Grid (BH, Tq/bq, Tk/bk) with the KV dimension innermost; running max /
normalizer / fp32 accumulator live in VMEM scratch across KV steps.  The
causal/sliding-window mask is computed from absolute positions derived from
the grid indices (plus a q_offset for cached decode), so no S x S mask
tensor ever materializes - the kernel is the Pallas twin of
arch/attention.blockwise_attention, which doubles as its oracle.

Two variants share the body math:

  * static: ``q_offset``/``kv_len`` baked as Python ints — the prefill fast
    path (offset 0, full keys; a shape-derived kv_len covers block padding).
  * dynamic: ``q_offset``/``kv_len`` are a traced ``(2,)`` int32
    scalar-prefetch operand, so cached-decode calls at every distinct
    length share ONE compilation; k/v blocks past ``ceil(kv_len/bk)`` are
    aliased to the last live block (eliding the fetch) and skip compute.

GQA is resolved in the kernel: q rows are ``B*KV*G`` while k/v rows are
``B*KV``, and the k/v index maps divide the q row id by ``g`` — the KV
tensors are never broadcast G-fold in HBM.

Per DESIGN.md: TPU adaptation keeps the MXU busy with (bq x d) @ (d x bk)
score tiles and (bq x bk) @ (bk x d) value tiles; bq/bk default to the
hardware-aligned blocks the core blocking search picks for the score matmul.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def reset_carry(m_ref, l_ref, acc_ref):
    """Reset the online-softmax running state at the first KV step."""
    m_ref[...] = jnp.full_like(m_ref, NEG_INF)
    l_ref[...] = jnp.zeros_like(l_ref)
    acc_ref[...] = jnp.zeros_like(acc_ref)


def finalize_out(o_ref, l_ref, acc_ref):
    """Normalize the accumulator into the output block at the last step."""
    o_ref[0, ...] = (
        acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
    ).astype(o_ref.dtype)


def last_live_block(length, bk: int):
    """Index of the last KV block holding live keys; index maps alias dead
    grid steps to it, so the block index never changes past the live
    region and the pipeline elides those fetches."""
    return jnp.maximum((length + bk - 1) // bk - 1, 0)


def _update(
    q_ref, k_ref, v_ref, m_ref, l_ref, acc_ref,
    *, i: int, j: int, bq: int, bk: int, scale: float,
    causal: bool, window: int | None, q_offset, kv_len,
):
    """One (q-block, kv-block) online-softmax step; offset/len may be
    Python ints (static kernel) or traced scalars (dynamic kernel)."""
    q = q_ref[0]                      # (bq, d)
    k = k_ref[0]                      # (bk, d)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                         # (bq, bk)

    q_pos = q_offset + i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        ok &= q_pos >= k_pos
    if window is not None:
        ok &= (q_pos - k_pos) < window
    if kv_len is not None:
        ok &= k_pos < kv_len
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, n_k: int, bq: int, bk: int, scale: float,
    causal: bool, window: int | None, q_offset: int, kv_len: int | None,
):
    j = pl.program_id(2)
    i = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        reset_carry(m_ref, l_ref, acc_ref)

    _update(
        q_ref, k_ref, v_ref, m_ref, l_ref, acc_ref,
        i=i, j=j, bq=bq, bk=bk, scale=scale,
        causal=causal, window=window, q_offset=q_offset, kv_len=kv_len,
    )

    @pl.when(j == n_k - 1)
    def _store():
        finalize_out(o_ref, l_ref, acc_ref)


def _flash_kernel_dyn(
    info_ref,                     # SMEM (2,) int32: [q_offset, kv_len]
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, n_k: int, bq: int, bk: int, scale: float,
    causal: bool, window: int | None,
):
    j = pl.program_id(2)
    i = pl.program_id(1)
    kv_len = info_ref[1]

    @pl.when(j == 0)
    def _init():
        reset_carry(m_ref, l_ref, acc_ref)

    @pl.when(j * bk < kv_len)
    def _live():
        _update(
            q_ref, k_ref, v_ref, m_ref, l_ref, acc_ref,
            i=i, j=j, bq=bq, bk=bk, scale=scale,
            causal=causal, window=window,
            q_offset=info_ref[0], kv_len=kv_len,
        )

    @pl.when(j == n_k - 1)
    def _store():
        finalize_out(o_ref, l_ref, acc_ref)


def flash_attention_pallas(
    q: jax.Array,       # (BH, Tq, d) with BH = BKV * g
    k: jax.Array,       # (BKV, Tk, d)
    v: jax.Array,       # (BKV, Tk, d)
    *,
    bq: int = 256,
    bk: int = 512,
    causal: bool = True,
    window: int | None = None,
    q_offset: int | jax.Array = 0,
    kv_len: int | jax.Array | None = None,
    g: int = 1,
    interpret: bool = False,
) -> jax.Array:
    BH, Tq, d = q.shape
    BKV, Tk, _ = k.shape
    assert BH == BKV * g, (BH, BKV, g)
    bq = min(bq, Tq)
    bk = min(bk, Tk)
    assert Tq % bq == 0 and Tk % bk == 0, ((Tq, Tk), (bq, bk))
    n_k = Tk // bk
    scale = 1.0 / math.sqrt(d)
    dynamic = isinstance(q_offset, jax.Array) or isinstance(kv_len, jax.Array)

    scratch = [
        pltpu.VMEM((bq,), jnp.float32),
        pltpu.VMEM((bq,), jnp.float32),
        pltpu.VMEM((bq, d), jnp.float32),
    ]
    if not dynamic:
        kern = functools.partial(
            _flash_kernel, n_k=n_k, bq=bq, bk=bk, scale=scale,
            causal=causal, window=window, q_offset=q_offset, kv_len=kv_len,
        )
        return pl.pallas_call(
            kern,
            grid=(BH, Tq // bq, n_k),
            in_specs=[
                pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
                pl.BlockSpec((1, bk, d), lambda b, i, j: (b // g, j, 0)),
                pl.BlockSpec((1, bk, d), lambda b, i, j: (b // g, j, 0)),
            ],
            out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            out_shape=jax.ShapeDtypeStruct((BH, Tq, d), q.dtype),
            scratch_shapes=scratch,
            interpret=interpret,
        )(q, k, v)

    info = jnp.stack([
        jnp.asarray(q_offset, jnp.int32),
        jnp.asarray(Tk if kv_len is None else kv_len, jnp.int32),
    ])

    def kv_block(b, i, j, info):
        return (b // g, jnp.minimum(j, last_live_block(info[1], bk)), 0)

    kern = functools.partial(
        _flash_kernel_dyn, n_k=n_k, bq=bq, bk=bk, scale=scale,
        causal=causal, window=window,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(BH, Tq // bq, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j, info: (b, i, 0)),
            pl.BlockSpec((1, bk, d), kv_block),
            pl.BlockSpec((1, bk, d), kv_block),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j, info: (b, i, 0)),
        scratch_shapes=scratch,
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((BH, Tq, d), q.dtype),
        interpret=interpret,
    )(info, q, k, v)
