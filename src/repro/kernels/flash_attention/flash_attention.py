"""Flash attention Pallas kernel (online softmax, TPU tiling).

Grid (BH, Tq/bq, Tk/bk) with the KV dimension innermost; running max /
normalizer / fp32 accumulator live in VMEM scratch across KV steps.  The
causal/sliding-window mask is computed from absolute positions derived from
the grid indices (plus a static q_offset for cached decode), so no S x S
mask tensor ever materializes - the kernel is the Pallas twin of
arch/attention.blockwise_attention, which doubles as its oracle.

Per DESIGN.md: TPU adaptation keeps the MXU busy with (bq x d) @ (d x bk)
score tiles and (bq x bk) @ (bk x d) value tiles; bq/bk default to the
hardware-aligned blocks the core blocking search picks for the score matmul.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, n_k: int, bq: int, bk: int, scale: float,
    causal: bool, window: int | None, q_offset: int, kv_len: int | None,
):
    j = pl.program_id(2)
    i = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                      # (bq, d)
    k = k_ref[0]                      # (bk, d)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                         # (bq, bk)

    q_pos = q_offset + i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        ok &= q_pos >= k_pos
    if window is not None:
        ok &= (q_pos - k_pos) < window
    if kv_len is not None:
        ok &= k_pos < kv_len
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(j == n_k - 1)
    def _store():
        o_ref[0, ...] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,       # (BH, Tq, d)
    k: jax.Array,       # (BH, Tk, d)
    v: jax.Array,       # (BH, Tk, d)
    *,
    bq: int = 256,
    bk: int = 512,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    kv_len: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    BH, Tq, d = q.shape
    Tk = k.shape[1]
    bq = min(bq, Tq)
    bk = min(bk, Tk)
    assert Tq % bq == 0 and Tk % bk == 0, ((Tq, Tk), (bq, bk))
    n_k = Tk // bk
    scale = 1.0 / math.sqrt(d)
    kern = functools.partial(
        _flash_kernel, n_k=n_k, bq=bq, bk=bk, scale=scale,
        causal=causal, window=window, q_offset=q_offset, kv_len=kv_len,
    )
    return pl.pallas_call(
        kern,
        grid=(BH, Tq // bq, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Tq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
