"""Pure-jnp oracle for flash attention (dense softmax, fp32)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(
    q: jax.Array,       # (BH, Tq, d)
    k: jax.Array,       # (BH, Tk, d)
    v: jax.Array,       # (BH, Tk, d)
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    kv_len: int | None = None,
) -> jax.Array:
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) / math.sqrt(d)
    q_pos = q_offset + jnp.arange(q.shape[1])[:, None]
    k_pos = jnp.arange(k.shape[1])[None, :]
    ok = jnp.ones(s.shape[1:], bool)
    if causal:
        ok &= q_pos >= k_pos
    if window is not None:
        ok &= (q_pos - k_pos) < window
    if kv_len is not None:
        ok &= k_pos < kv_len
    s = jnp.where(ok[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p.astype(v.dtype), v).astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,        # (B, KV, G, d)
    k: jax.Array,        # (B, S, KV, d)
    v: jax.Array,        # (B, S, KV, d)
    lengths: jax.Array,  # (B,) int32
) -> jax.Array:
    """Dense-softmax oracle for ragged flash-decoding: row b attends over
    exactly cache slots [0, lengths[b])."""
    d = q.shape[-1]
    s = jnp.einsum("bhgd,bshd->bhgs", q, k).astype(jnp.float32) / math.sqrt(d)
    live = jnp.arange(k.shape[1])[None, :] < lengths[:, None]   # (B, S)
    s = jnp.where(live[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum(
        "bhgs,bshd->bhgd", p.astype(v.dtype), v
    ).astype(q.dtype)


def decode_attention_paged_ref(
    q: jax.Array,        # (B, KV, G, d)
    kpool: jax.Array,    # (num_blocks, bs, KV, d)
    vpool: jax.Array,    # (num_blocks, bs, KV, d)
    tables: jax.Array,   # (B, n_blk) int32
    lengths: jax.Array,  # (B,) int32
    *,
    window: int | None = None,
) -> jax.Array:
    """Dense oracle for paged decode: materialize each row's logical KV
    sequence by gathering its block-table chain out of the pool, then run
    the dense masked softmax (optionally sliding-window; logical index ==
    absolute position in the paged layout)."""
    B = q.shape[0]
    bs = kpool.shape[1]
    n_blk = tables.shape[1]
    # (B, n_blk, bs, KV, d) -> (B, S, KV, d) dense per-row sequences
    k = jnp.take(kpool, tables, axis=0).reshape(B, n_blk * bs, *kpool.shape[2:])
    v = jnp.take(vpool, tables, axis=0).reshape(B, n_blk * bs, *vpool.shape[2:])
    d = q.shape[-1]
    s = jnp.einsum("bhgd,bshd->bhgs", q, k).astype(jnp.float32) / math.sqrt(d)
    k_idx = jnp.arange(k.shape[1])[None, :]
    live = k_idx < lengths[:, None]                             # (B, S)
    if window is not None:
        live &= k_idx > (lengths[:, None] - 1 - window)
    s = jnp.where(live[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum(
        "bhgs,bshd->bhgd", p.astype(v.dtype), v
    ).astype(q.dtype)
