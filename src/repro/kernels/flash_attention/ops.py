"""jit'd GQA-aware wrappers over the flash attention kernels.

Two entry points:

  * :func:`flash_attention` — prefill/training attention.  KV heads are
    indexed *inside* the kernel (q rows `B*KV*G`, k/v rows `B*KV`), never
    broadcast to the G query groups in HBM.  ``q_offset``/``kv_len`` ride
    as a traced scalar-prefetch operand whenever they are non-trivial, so
    distinct cached lengths share one compilation; the plain
    (offset 0, full keys) prefill keeps the fully static fast path.
  * :func:`decode_attention` — the serve engine's ragged flash-decoding
    path: one query token per slot, per-row live lengths traced, cache-
    native ``(B, S, KV, d)`` k/v layout (zero copies on the donated decode
    loop).  KV-axis tile sizes come from the paper's blocking search
    (``core.mapper.choose_matmul_tiles`` on the score matmul).  On CPU the
    default substrate is the kernel's jnp twin
    (``decode_attention_xla``, while-loop over live splits); pass
    ``impl="pallas"`` (+ ``interpret=True`` off-TPU) to run the kernel body
    itself, as the differential tests do.
"""

from __future__ import annotations

import functools
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.decode_attention import (
    decode_attention_paged_xla,
    decode_attention_xla,
    flash_decode_paged_pallas,
    flash_decode_pallas,
)
from repro.kernels.flash_attention.flash_attention import flash_attention_pallas


def _should_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _split_heads(q, k, v):
    """(B,Tq,KV,G,d)/(B,Tk,KV,d) -> row-major (B*KV*G,Tq,d)/(B*KV,Tk,d).
    No GQA broadcast: the kernel's k/v index maps divide q rows by G."""
    B, Tq, KV, G, d = q.shape
    Tk = k.shape[1]
    qf = q.transpose(0, 2, 3, 1, 4).reshape(B * KV * G, Tq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, Tk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, Tk, d)
    return qf, kf, vf


def _pad_blocks(qf, kf, vf, Tq, Tk, bq, bk):
    pq, pk = (-Tq) % bq, (-Tk) % bk
    if pq:
        qf = jnp.pad(qf, ((0, 0), (0, pq), (0, 0)))
    if pk:
        kf = jnp.pad(kf, ((0, 0), (0, pk), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pk), (0, 0)))
    return qf, kf, vf, pk


@partial(
    jax.jit, static_argnames=("causal", "window", "bq", "bk", "interpret")
)
def _fa_static(q, k, v, *, causal, window, bq, bk, interpret):
    B, Tq, KV, G, d = q.shape
    Tk = k.shape[1]
    bq_, bk_ = min(bq, Tq), min(bk, Tk)
    qf, kf, vf = _split_heads(q, k, v)
    qf, kf, vf, pk = _pad_blocks(qf, kf, vf, Tq, Tk, bq_, bk_)
    out = flash_attention_pallas(
        qf, kf, vf, bq=bq_, bk=bk_, causal=causal, window=window,
        q_offset=0, kv_len=Tk if pk else None, g=G, interpret=interpret,
    )
    out = out[:, :Tq].reshape(B, KV, G, Tq, d).transpose(0, 3, 1, 2, 4)
    return out


@partial(
    jax.jit, static_argnames=("causal", "window", "bq", "bk", "interpret")
)
def _fa_dynamic(q, k, v, q_offset, kv_len, *, causal, window, bq, bk,
                interpret):
    B, Tq, KV, G, d = q.shape
    Tk = k.shape[1]
    bq_, bk_ = min(bq, Tq), min(bk, Tk)
    qf, kf, vf = _split_heads(q, k, v)
    qf, kf, vf, _ = _pad_blocks(qf, kf, vf, Tq, Tk, bq_, bk_)
    out = flash_attention_pallas(
        qf, kf, vf, bq=bq_, bk=bk_, causal=causal, window=window,
        q_offset=q_offset, kv_len=jnp.minimum(kv_len, Tk), g=G,
        interpret=interpret,
    )
    out = out[:, :Tq].reshape(B, KV, G, Tq, d).transpose(0, 3, 1, 2, 4)
    return out


def flash_attention(
    q: jax.Array,       # (B, Tq, KV, G, d) grouped-query layout
    k: jax.Array,       # (B, Tk, KV, d)
    v: jax.Array,       # (B, Tk, KV, d)
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int | jax.Array = 0,
    kv_len: int | jax.Array | None = None,
    bq: int = 256,
    bk: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Returns (B, Tq, KV, G, d).  Non-trivial ``q_offset``/``kv_len``
    (Python ints included) are traced, so every cached length shares one
    compiled program; the trivial prefill case stays fully static."""
    interp = _should_interpret() if interpret is None else interpret
    static = (
        not isinstance(q_offset, jax.Array)
        and int(q_offset) == 0
        and not isinstance(kv_len, jax.Array)
        and kv_len is None
    )
    if static:
        return _fa_static(
            q, k, v, causal=causal, window=window, bq=bq, bk=bk,
            interpret=interp,
        )
    Tk = k.shape[1]
    return _fa_dynamic(
        q, k, v,
        jnp.asarray(q_offset, jnp.int32),
        jnp.asarray(Tk if kv_len is None else kv_len, jnp.int32),
        causal=causal, window=window, bq=bq, bk=bk, interpret=interp,
    )


@functools.lru_cache(maxsize=None)
def _pick_decode_bk(S: int, G: int, d: int, impl: str) -> int:
    """KV-axis block for the decode score matmul (M=G, N=S, K=d), from the
    paper's blocking search, clamped to a divisor of the cache extent so
    the ring buffer is never padded (padding would copy the donated KV).

    The search optimizes VMEM reuse, but the ragged skip granularity is
    ceil(len/bk) — one giant block would always read the whole cache,
    defeating flash-decoding's point — so the tile is capped per substrate:
    512 for the Pallas kernel (DMA efficiency still wants wide blocks) and
    64 for the jnp twin, where a while-loop iteration is cheap and typical
    live lengths are far below the cache extent (measured on the serve
    shapes: bk=64 halves the op time vs the dense oracle where bk=512
    loses to it)."""
    from repro.core.mapper import choose_matmul_tiles

    t = choose_matmul_tiles(max(G, 8), S, d)
    cap = 512 if impl == "pallas" else 64
    b = max(8, min(t.bn, cap, S))
    while S % b:
        b -= 1
    return b


def decode_attention(
    q: jax.Array,         # (B, KV, G, d) one query token per slot
    k: jax.Array,         # (B, S, KV, d) cache-native layout
    v: jax.Array,         # (B, S, KV, d)
    lengths: jax.Array,   # (B,) int32 live KV slots per row (traced)
    *,
    bk: int | None = None,
    impl: str | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Ragged flash-decoding; returns (B, KV, G, d).

    ``impl``: "pallas" (the kernel; interpret-mode off TPU), "xla" (its jnp
    twin — the CPU serving default), or None for backend auto-dispatch.

    ``lengths`` are clamped to ``[1, S]``: a decode step always writes the
    current token before attending (the serve ring invariant), so a live
    row has at least one key, and — unlike ``decode_attention_ref`` —
    length 0 is treated as 1, not as a fully-masked row.
    """
    B, KV, G, d = q.shape
    S = k.shape[1]
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    bk_ = _pick_decode_bk(S, G, d, impl) if bk is None else max(1, min(bk, S))
    while S % bk_:
        bk_ -= 1
    if impl == "xla":
        return decode_attention_xla(q, k, v, lengths, bk=bk_)
    interp = _should_interpret() if interpret is None else interpret
    return _run_decode_kernel(
        q,
        lambda qp: flash_decode_pallas(qp, k, v, lengths, bk=bk_, interpret=interp),
        interp,
    )


def _run_decode_kernel(q, kern, interp: bool):
    """Shared decode-kernel epilogue: sublane-align the q group axis on TPU
    (pad G up to a multiple of 8, slice the pad back off the output)."""
    G = q.shape[2]
    Gp = G if interp else -(-G // 8) * 8
    qp = q if Gp == G else jnp.pad(q, ((0, 0), (0, 0), (0, Gp - G), (0, 0)))
    out = kern(qp)
    return out if Gp == G else out[:, :, :G]


def decode_attention_paged(
    q: jax.Array,         # (B, KV, G, d) one query token per row
    kpool: jax.Array,     # (num_blocks, bs, KV, d) shared block pool
    vpool: jax.Array,     # (num_blocks, bs, KV, d)
    tables: jax.Array,    # (B, n_blk) int32 logical -> physical block
    lengths: jax.Array,   # (B,) int32 live tokens per row (traced)
    *,
    window: int | None = None,
    impl: str | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Block-table-indirect ragged flash-decoding; returns (B, KV, G, d).

    The KV split size is the pool's block size (splits are physical
    blocks), so there is no ``bk`` knob: the logical reduction order is
    fixed by the paged layout, which is what makes this path bitwise
    comparable to the contiguous twin at ``bk == block_size``.  ``impl``
    dispatches exactly like :func:`decode_attention`."""
    B, KV, G, d = q.shape
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "xla":
        return decode_attention_paged_xla(
            q, kpool, vpool, tables, lengths, window=window
        )
    interp = _should_interpret() if interpret is None else interpret
    return _run_decode_kernel(
        q,
        lambda qp: flash_decode_paged_pallas(
            qp, kpool, vpool, tables, lengths, window=window, interpret=interp
        ),
        interp,
    )
