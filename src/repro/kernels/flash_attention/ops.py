"""jit'd GQA-aware wrapper over the flash attention kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas


def _should_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "kv_len", "bq", "bk",
                     "interpret"),
)
def flash_attention(
    q: jax.Array,       # (B, Tq, KV, G, d) grouped-query layout
    k: jax.Array,       # (B, Tk, KV, d)
    v: jax.Array,       # (B, Tk, KV, d)
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    kv_len: int | None = None,
    bq: int = 256,
    bk: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Returns (B, Tq, KV, G, d).  KV heads are broadcast to the G query
    groups before the kernel (the fused-GQA variant is a §Perf follow-up)."""
    B, Tq, KV, G, d = q.shape
    Tk = k.shape[1]
    interp = _should_interpret() if interpret is None else interpret
    qf = q.transpose(0, 2, 3, 1, 4).reshape(B * KV * G, Tq, d)
    kf = jnp.broadcast_to(
        k.transpose(0, 2, 1, 3)[:, :, None], (B, KV, G, Tk, d)
    ).reshape(B * KV * G, Tk, d)
    vf = jnp.broadcast_to(
        v.transpose(0, 2, 1, 3)[:, :, None], (B, KV, G, Tk, d)
    ).reshape(B * KV * G, Tk, d)
    bq_, bk_ = min(bq, Tq), min(bk, Tk)
    pq, pk = (-Tq) % bq_, (-Tk) % bk_
    if pq:
        qf = jnp.pad(qf, ((0, 0), (0, pq), (0, 0)))
    if pk:
        kf = jnp.pad(kf, ((0, 0), (0, pk), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pk), (0, 0)))
        kv_len = Tk if kv_len is None else min(kv_len, Tk)
    out = flash_attention_pallas(
        qf, kf, vf, bq=bq_, bk=bk_, causal=causal, window=window,
        q_offset=q_offset, kv_len=kv_len, interpret=interp,
    )
    out = out[:, :Tq].reshape(B, KV, G, Tq, d).transpose(0, 3, 1, 2, 4)
    return out
