"""Algorithm-based fault tolerance (ABFT) checks for the serve kernels.

The classic Huang–Abraham column-checksum identity: for C = A·B,

    e^T · C  ==  (e^T · A) · B        (e = ones)

holds in exact arithmetic, so any corruption of a single C element
breaks the equality by exactly the corrupted delta.  The trace computes
the right side the way Huang–Abraham originally did: the checksum row
e^T·A is *appended to A* and rides the same GEMM as the product, so the
reference costs one extra output row — no second pass over B, which on
a memory-bound decode step is the entire overhead budget.  (At M == 1
the augmentation is known to perturb XLA's GEMV dispatch and the first
output row with it, so the trace falls back to a separate e^T·A GEMV
there; the product matmul itself is never altered — served tokens stay
bitwise identical to an ABFT-off engine.)

In floating point the two sides are *differently ordered* fp32 sums, so
the check compares within a calibrated tolerance (see
``tests/test_sdc.py`` for the calibration property test):

    |e^T·C - (e^T·A)·B|  <=  ATOL + (RTOL + eps(A))·S + eps(C)·(e^T·|C| + |ref|)

where the scale S bounds (e^T·|A|)·|B| per column *without re-reading B*:
``S_j = min(max_k|a_k| · colabs_j, sum_k|a_k| · colmax_j)`` from the
static per-column stats ``colabs_j = sum_k|B_kj|`` / ``colmax_j =
max_k|B_kj|`` that :func:`weight_colstats` precomputes once at engine
init (weights never change while serving; a dynamic |A|·|B| twin would
cost another full weight pass per step).  The RTOL term covers
reordered-fp32 roundoff (relative rms ~= eps32/sqrt2 of the abs-sum
scale, independent of K; RTOL = 1e-5 leaves ~20x margin over the
5-sigma tail).  The eps(dtype) terms charge the one rounding of each C
element — and of the checksum row — to a low-precision output dtype
(bf16 unit roundoff 2^-9 per element; we charge 2^-8 for margin).

For decode attention there is no checksum identity (softmax is
nonlinear), so the check is a sampled *output fingerprint*: recompute k
rows of the paged online-softmax on the XLA twin — which is bitwise
equal to the served kernel on equal inputs (the repo's differential
oracle rests on this) — and compare.

:class:`AbftTrace` is the trace-scoped recorder the engine installs via
``layers.abft_override``.  It also owns the *fault operand*: an int32
vector threaded through the jitted decode program that can flip one bit
of one designated intermediate, so injection rides the same executable
as clean runs (armed and disarmed steps are bitwise identical programs).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

# Calibrated fp32 checksum tolerance (property-tested in tests/test_sdc.py).
ABFT_RTOL = 1e-5
ABFT_ATOL = 1e-6

# Fault-operand layout: (site, call_idx, row, col, bit, layer, scrub, 0)
# int32.  The transformer backbone scans its layers, so every in-layer
# check site shares one trace-time call_idx across layers; `layer` narrows
# injection to a single layer (-1 = a call outside the scan, e.g. the
# unembed GEMM).  `scrub` (slot 6) is not an injection field: it tells the
# decode program to run the full weight-fingerprint pass this step — the
# host sets it on the ``KernelConfig.scrub_every`` cadence, riding the
# existing operand so armed/disarmed/scrubbed steps share one executable.
FAULT_LEN = 8
FAULT_SCRUB = 6        # operand slot carrying the scrub-this-step flag
FAULT_NONE = 0
FAULT_MATMUL = 1       # flip out[row, col] of matmul call #call_idx
FAULT_ATTENTION = 2    # flip ctx[row, col] of attention call #call_idx
FAULT_OUTER = -1       # `layer` value for checks outside the layer scan

# Rows fingerprinted per attention call in "checksum" mode ("paranoid"
# checks every row).
SAMPLE_ROWS = 4


def no_fault() -> jnp.ndarray:
    """A disarmed fault operand (site FAULT_NONE matches no check site)."""
    return jnp.zeros((FAULT_LEN,), jnp.int32)


def sample_rows(batch: int, mode: str, k: int = SAMPLE_ROWS) -> list[int]:
    """Deterministic row sample for the attention fingerprint."""
    if mode == "paranoid" or batch <= k:
        return list(range(batch))
    return [i * batch // k for i in range(k)]


def weight_sums(params) -> jax.Array:
    """Per-leaf abs-sum fingerprint of a param pytree, as one (n_leaves,)
    fp32 vector.  Compared *exactly* against an init-time baseline (same
    jitted reduction every step, so bitwise reproducible): ABFT checksums
    cannot see weight corruption — both sides of e^T·(A·B) = (e^T·A)·B
    use the corrupted B — so weights get their own detector."""
    leaves = jax.tree_util.tree_leaves(params)
    return jnp.stack(
        [jnp.sum(jnp.abs(x), dtype=jnp.float32) for x in leaves]
    )


def weight_colstats(params) -> dict[str, tuple[jax.Array, jax.Array]]:
    """Static per-column bounds of every matrix-shaped param leaf, for the
    checksum tolerance: ``{"KxN": (colabs, colmax)}`` with
    ``colabs_j = sum_k|w_kj|`` and ``colmax_j = max_k|w_kj|`` (fp32,
    shape (N,)).  Computed once at engine init — weights are immutable
    while serving, so the per-step tolerance never has to re-read them.

    Lookups key on the *shape* of the operand a projection actually
    contracts with, so each leaf registers its trailing-2D slice in both
    orientations (the unembed multiplies by ``tok.T``); leading axes
    (the layer-scan stack) and same-shaped leaves are merged by
    elementwise max — a sound upper bound for whichever slice a given
    call uses, merely looser where shapes collide."""
    stats: dict[str, tuple[jax.Array, jax.Array]] = {}

    def add(key, colabs, colmax):
        if key in stats:
            a0, m0 = stats[key]
            colabs, colmax = jnp.maximum(a0, colabs), jnp.maximum(m0, colmax)
        stats[key] = (colabs, colmax)

    for x in jax.tree_util.tree_leaves(params):
        if x.ndim < 2:
            continue
        K, N = x.shape[-2], x.shape[-1]
        ab = jnp.abs(x.reshape(-1, K, N).astype(jnp.float32))
        add(f"{K}x{N}", jnp.max(jnp.sum(ab, 1), 0), jnp.max(ab, (0, 1)))
        add(f"{N}x{K}", jnp.max(jnp.sum(ab, 2), 0), jnp.max(ab, (0, 2)))
    return stats


def _flip_bit_f32(v: jax.Array, bit: jax.Array) -> jax.Array:
    """Flip one bit of the fp32 representation of scalar ``v``."""
    u = lax.bitcast_convert_type(v.astype(jnp.float32), jnp.uint32)
    u = u ^ (jnp.uint32(1) << bit.astype(jnp.uint32))
    return lax.bitcast_convert_type(u, jnp.float32)


def _maybe_flip(a2d: jax.Array, fault: jax.Array, site: int, idx: int, gate):
    """Return ``a2d`` with one bit of ``a2d[row % R, col % C]`` flipped when
    the fault operand targets (site, idx); otherwise writes the unchanged
    value back, so the disarmed program is bitwise identical to one with
    no fault plumbing at all.  Bits >= 16 survive the round-trip through
    fp32 exactly for bf16 arrays (bf16 is the top half of fp32).

    ``col == -1`` targets the largest-magnitude element of the row: a
    magnitude-*decreasing* exponent flip on a tiny element produces a
    delta below bf16's legitimate rounding noise — physically undetectable
    by any checksum — so the seeded harness aims where detection is owed."""
    R, C = a2d.shape
    inject = (fault[0] == site) & (fault[1] == idx) & gate
    r = fault[2] % R
    c = jnp.where(
        fault[3] < 0,
        jnp.argmax(jnp.abs(a2d[fault[2] % R].astype(jnp.float32))).astype(
            jnp.int32
        ),
        fault[3] % C,
    )
    v = a2d[r, c]
    fv = _flip_bit_f32(v, fault[4]).astype(a2d.dtype)
    return a2d.at[r, c].set(jnp.where(inject, fv, v))


def _out_eps(dtype) -> float:
    """Per-element rounding charge for a low-precision product output
    (0 for fp32: its roundoff is covered by the RTOL·scale_in term)."""
    if dtype == jnp.float32:
        return 0.0
    if dtype == jnp.bfloat16:
        return 2.0 ** -8
    return float(jnp.finfo(dtype).eps)


def mm_check(x2: jax.Array, w: jax.Array, out2: jax.Array) -> jax.Array:
    """Column-checksum verdict for one 2D matmul: True iff the output's
    column sums disagree with (e^T·x)·w beyond the calibrated tolerance.
    All operands 2D; comparison in fp32.

    This is the *standalone* (re-read-w) form used by the calibration
    property test and as :meth:`AbftTrace.mm`'s fallback when no
    precomputed column stats cover ``w``; the engine's hot path fuses
    the reference into the product GEMM instead."""
    x32 = x2.astype(jnp.float32)
    w32 = w.astype(jnp.float32)
    o32 = out2.astype(jnp.float32)
    got = jnp.sum(o32, axis=0)
    ref = jnp.sum(x32, axis=0) @ w32
    scale_in = jnp.sum(jnp.abs(x32), axis=0) @ jnp.abs(w32)
    tol = ABFT_ATOL + ABFT_RTOL * scale_in
    eps = _out_eps(out2.dtype)
    if eps:
        tol = tol + eps * jnp.sum(jnp.abs(o32), axis=0)
    return jnp.any(jnp.abs(got - ref) > tol)


class AbftTrace:
    """Trace-scoped ABFT recorder.

    Built fresh for each decode trace (inside the jitted function), so the
    ``mm_calls``/``attn_calls`` counters advance at *trace time* — the
    fault operand's ``call_idx`` addresses "the N-th matmul of the step"
    stably across retraces.  ``flags`` collects one boolean verdict per
    check; :meth:`any_bad` reduces them for the host.

    The transformer backbone scans its layers, so flags appended inside
    the scan body would leak its trace scope: the body drains them per
    layer via :meth:`drain` into a scanned output, and ``layer`` (set by
    the body to the traced layer index, None outside the scan) gates
    injection to the fault operand's target layer."""

    def __init__(self, mode: str, fault: jax.Array, colstats=None):
        assert mode in ("checksum", "paranoid"), mode
        self.mode = mode
        self.fault = fault
        self.colstats = colstats or {}
        self.mm_calls = 0
        self.attn_calls = 0
        self.layer = None
        self.flags: list[jax.Array] = []

    def _gate(self):
        """Injection gate for the current scope: the fault's target layer
        must match the scanned layer index (or FAULT_OUTER outside)."""
        if self.layer is None:
            return self.fault[5] == jnp.int32(FAULT_OUTER)
        return self.fault[5] == self.layer

    def drain(self) -> jax.Array:
        """OR-reduce and clear the flags accumulated in the current scope
        (called by scan bodies so no tracer outlives its trace)."""
        out = functools.reduce(
            jnp.logical_or, self.flags, jnp.zeros((), jnp.bool_)
        )
        self.flags = []
        return out

    # ------------------------------------------------------------ matmul --
    def mm(self, x, w, impl=None):
        """Compute, verify and possibly fault-inject one ``x @ w``.  The
        checksum row e^T·x is appended to x so the reference rides the
        product GEMM itself (the classical Huang–Abraham construction) —
        per-row independence keeps the product rows bitwise identical to
        the unaugmented matmul, so an ABFT engine serves the same tokens
        as an ABFT-off one.  M == 1 is the observed exception (XLA's
        GEMV dispatch re-blocks when a row is appended): there the
        reference runs as its own GEMV and the product is untouched.
        Returns ``out`` with any injection applied so a flipped bit
        genuinely corrupts the downstream computation."""
        idx = self.mm_calls
        self.mm_calls += 1
        mm_fn = (lambda a, b: a @ b) if impl is None else impl
        x2 = x.reshape(-1, x.shape[-1])
        M = x2.shape[0]
        a32 = jnp.sum(x2.astype(jnp.float32), axis=0)
        a = a32.astype(x2.dtype)
        if M >= 2:
            fused = mm_fn(jnp.concatenate([x2, a[None]], axis=0), w)
            out2, ref = fused[:M], fused[M].astype(jnp.float32)
        else:
            out2 = mm_fn(x2, w)
            ref = mm_fn(a[None], w)[0].astype(jnp.float32)
        out2 = _maybe_flip(out2, self.fault, FAULT_MATMUL, idx, self._gate())
        o32 = out2.astype(jnp.float32)
        got = jnp.sum(o32, axis=0)
        key = f"{w.shape[0]}x{w.shape[1]}"
        if key in self.colstats:
            colabs, colmax = self.colstats[key]
            scale = jnp.minimum(
                jnp.max(jnp.abs(a32)) * colabs,
                jnp.sum(jnp.abs(a32)) * colmax,
            )
            tol = ABFT_ATOL + (ABFT_RTOL + _out_eps(x2.dtype)) * scale
            eps = _out_eps(out2.dtype)
            if eps:
                tol = tol + eps * (jnp.sum(jnp.abs(o32), axis=0) + jnp.abs(ref))
            self.flags.append(jnp.any(jnp.abs(got - ref) > tol))
        else:
            # no static stats for this operand (standalone trace, or an
            # unregistered shape): fall back to the re-read-w tolerance
            self.flags.append(mm_check(x2, w, out2))
        return out2.reshape(x.shape[:-1] + (w.shape[-1],))

    # --------------------------------------------------------- attention --
    def check_paged_attention(self, ctx, q, kpool, vpool, tables, lengths):
        """Fingerprint-check one paged decode-attention output ``ctx``
        (shape (B, KV, G, d)) by recomputing ``k`` sampled rows on the XLA
        twin, which is bitwise-equal to the served kernel on equal logical
        contents.  Returns ``ctx`` with any injection applied."""
        from repro.kernels.flash_attention.ops import decode_attention_paged

        idx = self.attn_calls
        self.attn_calls += 1
        B = ctx.shape[0]
        c2 = ctx.reshape(B, -1)
        c2 = _maybe_flip(c2, self.fault, FAULT_ATTENTION, idx, self._gate())
        ctx = c2.reshape(ctx.shape)
        rows = jnp.asarray(sample_rows(B, self.mode))
        ref = decode_attention_paged(
            q[rows], kpool, vpool, tables[rows], lengths[rows], impl="xla"
        ).astype(jnp.float32)
        got = ctx[rows].astype(jnp.float32)
        scale = jnp.max(jnp.abs(ref))
        self.flags.append(
            jnp.any(jnp.abs(got - ref) > ABFT_ATOL + ABFT_RTOL * scale)
        )
        return ctx

    # ------------------------------------------------------------ reduce --
    def any_bad(self) -> jax.Array:
        """Scalar bool: did any check this trace fail?"""
        return functools.reduce(
            jnp.logical_or, self.flags, jnp.zeros((), jnp.bool_)
        )
