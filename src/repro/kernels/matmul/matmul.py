"""Blocked matmul Pallas kernel (TPU target; interpret=True on CPU).

Grid (M/bm, N/bn, K/bk) with K innermost; a fp32 VMEM scratch accumulates
partial products across K steps (output-stationary at the VMEM level - the
C|K dataflow of the paper pinned by the MXU, with the K reduction blocked
exactly as core/blocking chooses).  Block sizes come from
core.mapper.choose_matmul_tiles, i.e. the paper's blocking search on the
(VMEM, HBM) two-level hierarchy.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _matmul_abft_kernel(a_ref, b_ref, o_ref, c_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)
        # column checksum of this (bm, bn) output block, pre-cast: one fp32
        # row per row-block so corruption localizes to a row-block and the
        # (i, j) output blocks are still each written exactly once.
        c_ref[...] = jnp.sum(acc_ref[...], axis=0, keepdims=True)


def matmul_pallas_abft(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int,
    bn: int,
    bk: int,
    interpret: bool = False,
    out_dtype=None,
) -> tuple[jax.Array, jax.Array]:
    """ABFT variant of :func:`matmul_pallas`: also emits the per-row-block
    column checksums e^T·C as an (M/bm, N) fp32 array, summed from the
    fp32 accumulator (so the check is independent of the output cast)."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (
        (M, N, K), (bm, bn, bk)
    )
    n_k = K // bk
    out_dtype = out_dtype or a.dtype
    return pl.pallas_call(
        functools.partial(_matmul_abft_kernel, n_k=n_k),
        grid=(M // bm, N // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, N), out_dtype),
            jax.ShapeDtypeStruct((M // bm, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)


def matmul_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int,
    bn: int,
    bk: int,
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    """a: (M, K) @ b: (K, N) -> (M, N).  Dims must divide by the blocks."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (
        (M, N, K), (bm, bn, bk)
    )
    n_k = K // bk
    out_dtype = out_dtype or a.dtype
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=(M // bm, N // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
