"""jit'd public wrapper: schedule-driven tile choice + padding + dispatch.

On CPU (this container) the kernel body runs in interpret mode; on TPU it
compiles to Mosaic.  Tile sizes come from the paper's blocking search
(core.mapper.choose_matmul_tiles) unless overridden.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.mapper import MatmulTiles, choose_matmul_tiles
from repro.kernels.abft import ABFT_ATOL, ABFT_RTOL
from repro.kernels.matmul.matmul import matmul_pallas, matmul_pallas_abft


def _should_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("tiles", "interpret"))
def matmul(
    a: jax.Array,
    b: jax.Array,
    tiles: MatmulTiles | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """General (M, K) x (K, N): pads to tile multiples and unpads."""
    M, K = a.shape
    _, N = b.shape
    t = tiles or choose_matmul_tiles(M, N, K)
    interp = _should_interpret() if interpret is None else interpret
    bm, bn, bk = min(t.bm, M), min(t.bn, N), min(t.bk, K)
    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    ap = jnp.pad(a, ((0, pm), (0, pk))) if (pm or pk) else a
    bp = jnp.pad(b, ((0, pk), (0, pn))) if (pk or pn) else b
    out = matmul_pallas(ap, bp, bm=bm, bn=bn, bk=bk, interpret=interp)
    return out[:M, :N]


@partial(jax.jit, static_argnames=("tiles", "interpret"))
def matmul_abft(
    a: jax.Array,
    b: jax.Array,
    tiles: MatmulTiles | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """ABFT-checked :func:`matmul`: returns ``(out, bad)`` where ``bad`` is
    a scalar bool — True iff the kernel's per-row-block column checksums
    e^T·C disagree with the O(K·N/bm)-cost reference (e^T·A)·B beyond the
    calibrated fp32 tolerance.  Zero-padded rows/cols are checksum-neutral,
    so padding needs no special-casing."""
    M, K = a.shape
    _, N = b.shape
    t = tiles or choose_matmul_tiles(M, N, K)
    interp = _should_interpret() if interpret is None else interpret
    bm, bn, bk = min(t.bm, M), min(t.bn, N), min(t.bk, K)
    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    ap = jnp.pad(a, ((0, pm), (0, pk))) if (pm or pk) else a
    bp = jnp.pad(b, ((0, pk), (0, pn))) if (pk or pn) else b
    out, checks = matmul_pallas_abft(
        ap, bp, bm=bm, bn=bn, bk=bk, interpret=interp
    )
    a32 = ap.astype(jnp.float32)
    b32 = bp.astype(jnp.float32)
    nrb = ap.shape[0] // bm
    ref = a32.reshape(nrb, bm, ap.shape[1]).sum(axis=1) @ b32
    scale = (
        jnp.abs(a32).reshape(nrb, bm, ap.shape[1]).sum(axis=1) @ jnp.abs(b32)
    )
    bad = jnp.any(jnp.abs(checks - ref) > ABFT_ATOL + ABFT_RTOL * scale)
    return out[:M, :N], bad
