"""jit'd public wrapper: schedule-driven tile choice + padding + dispatch.

On CPU (this container) the kernel body runs in interpret mode; on TPU it
compiles to Mosaic.  Tile sizes come from the paper's blocking search
(core.mapper.choose_matmul_tiles) unless overridden.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.mapper import MatmulTiles, choose_matmul_tiles
from repro.kernels.matmul.matmul import matmul_pallas


def _should_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("tiles", "interpret"))
def matmul(
    a: jax.Array,
    b: jax.Array,
    tiles: MatmulTiles | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """General (M, K) x (K, N): pads to tile multiples and unpads."""
    M, K = a.shape
    _, N = b.shape
    t = tiles or choose_matmul_tiles(M, N, K)
    interp = _should_interpret() if interpret is None else interpret
    bm, bn, bk = min(t.bm, M), min(t.bn, N), min(t.bk, K)
    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    ap = jnp.pad(a, ((0, pm), (0, pk))) if (pm or pk) else a
    bp = jnp.pad(b, ((0, pk), (0, pn))) if (pk or pn) else b
    out = matmul_pallas(ap, bp, bm=bm, bn=bn, bk=bk, interpret=interp)
    return out[:M, :N]
