"""Direct CONV2D Pallas kernel - the paper's Algorithm-1 nest on TPU.

TPU adaptation of the 7-loop nest (DESIGN.md §2): the MXU fixes the intra-
chip dataflow to C|K, so the kernel blocks C and K for VMEM (the paper's
loop blocking), unrolls FX/FY as static loops (their trip counts are tiny),
and maps the X*Y pixels onto the MXU rows:

    grid (B, K/bk, C/bc)  - C innermost, accumulating in fp32 VMEM scratch
    x block (1, H_in, W_in, bc)   w block (FX, FY, bc, bk)
    out block (1, Ho, Wo, bk)
    inner: for fy, fx:  (Ho*Wo, bc) @ (bc, bk)  ->  MXU

This mirrors exactly what core/blocking chooses for a (VMEM, HBM) hierarchy:
the (bc, bk) tile is the level-0 tile of the C|K schedule.  NHWC layout,
stride 1 (strided layers fall back to ref/XLA in ops.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _conv_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_c: int, FX: int, FY: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    _, Ho, Wo, bk = o_ref.shape
    x = x_ref[0]          # (H_in, W_in, bc)
    w = w_ref[...]        # (FX, FY, bc, bk)
    acc = acc_ref[...]    # (Ho * Wo, bk)
    for fy in range(FY):        # fy walks the first (H) spatial dim
        for fx in range(FX):    # fx walks the second (W) spatial dim
            win = x[fy : fy + Ho, fx : fx + Wo, :].reshape(Ho * Wo, -1)
            acc += jax.lax.dot_general(
                win, w[fy, fx], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
    acc_ref[...] = acc

    @pl.when(c == n_c - 1)
    def _store():
        o_ref[0, ...] = acc_ref[...].reshape(Ho, Wo, bk).astype(o_ref.dtype)


def conv2d_pallas(
    x: jax.Array,    # (B, H_in, W_in, C)   H_in = Ho + FX - 1 (valid conv)
    w: jax.Array,    # (FX, FY, C, K)
    *,
    bc: int,
    bk: int,
    interpret: bool = False,
) -> jax.Array:
    B, H_in, W_in, C = x.shape
    FX, FY, C2, K = w.shape
    assert C == C2
    assert C % bc == 0 and K % bk == 0, ((C, K), (bc, bk))
    Ho, Wo = H_in - FX + 1, W_in - FY + 1
    n_c = C // bc
    kern = functools.partial(_conv_kernel, n_c=n_c, FX=FX, FY=FY)
    return pl.pallas_call(
        kern,
        grid=(B, K // bk, n_c),
        in_specs=[
            pl.BlockSpec((1, H_in, W_in, bc), lambda b, k, c: (b, 0, 0, c)),
            pl.BlockSpec((FX, FY, bc, bk), lambda b, k, c: (0, 0, c, k)),
        ],
        out_specs=pl.BlockSpec((1, Ho, Wo, bk), lambda b, k, c: (b, 0, 0, k)),
        out_shape=jax.ShapeDtypeStruct((B, Ho, Wo, K), x.dtype),
        scratch_shapes=[pltpu.VMEM((Ho * Wo, bk), jnp.float32)],
        interpret=interpret,
    )(x, w)
