"""jit'd conv2d wrapper: schedule-driven (bc, bk) + fallbacks.

Block sizes come from the paper's blocking search on the CONV nest with a
(VMEM, HBM) hierarchy (core.blocking): the level-0 C/K factors are the
kernel's (bc, bk).  Strided convs fall back to the XLA reference (the
assigned LM architectures only exercise stride 1; the paper's strided CONV1
layers are analyzed by the analytical model, not this kernel).
"""

from __future__ import annotations

import functools

import jax

from repro.core.blocking import search_blocking
from repro.core.dataflow import Dataflow
from repro.core.loopnest import conv_nest
from repro.core.mapper import round_down_pow2
from repro.core.schedule import ArraySpec, MemLevel
from repro.core import energy as en
from repro.kernels.conv2d.conv2d import conv2d_pallas
from repro.kernels.conv2d.ref import conv2d_ref


def _should_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.lru_cache(maxsize=256)
def choose_conv_blocks(
    B: int, Ho: int, Wo: int, C: int, K: int, FX: int, FY: int,
    vmem_bytes: int = en.TPU_VMEM_BYTES // 8,
) -> tuple[int, int]:
    """Run the blocking search on the conv nest; return (bc, bk)."""
    nest = conv_nest("conv", B=1, K=K, C=C, X=Ho, Y=Wo, FX=FX, FY=FY)
    levels = (
        MemLevel("VMEM", capacity_bytes=vmem_bytes, double_buffered=True),
        MemLevel("HBM", capacity_bytes=None),
    )
    try:
        res = search_blocking(
            nest, levels, ArraySpec(dims=(1,)), Dataflow(assigns=((),)),
            beam=8,
        )
        tile = res.best.schedule.cum_tile(0, include_spatial=False)
        bc, bk = tile["C"], tile["K"]
    except ValueError:
        bc, bk = 128, 128
    # hardware alignment: powers of two, lane multiples where possible
    bc = max(1, min(C, round_down_pow2(bc, 1)))
    bk = max(1, min(K, round_down_pow2(bk, 1)))
    while C % bc:
        bc //= 2
    while K % bk:
        bk //= 2
    return max(bc, 1), max(bk, 1)


@functools.partial(jax.jit, static_argnames=("stride", "interpret"))
def conv2d(
    x: jax.Array,     # (B, H_in, W_in, C)
    w: jax.Array,     # (FX, FY, C, K)
    stride: int = 1,
    interpret: bool | None = None,
) -> jax.Array:
    if stride != 1:
        return conv2d_ref(x, w, stride=stride)
    B, H_in, W_in, C = x.shape
    FX, FY, _, K = w.shape
    Ho, Wo = H_in - FX + 1, W_in - FY + 1
    bc, bk = choose_conv_blocks(B, Ho, Wo, C, K, FX, FY)
    interp = _should_interpret() if interpret is None else interpret
    return conv2d_pallas(x, w, bc=bc, bk=bk, interpret=interp)
