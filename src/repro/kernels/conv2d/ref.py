"""Pure-jnp oracle: valid conv, NHWC x (FX, FY, C, K)."""

import jax
import jax.numpy as jnp


def conv2d_ref(x: jax.Array, w: jax.Array, stride: int = 1) -> jax.Array:
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out.astype(x.dtype)
