"""Linear-recurrence Pallas kernels: diagonal scan (RG-LRU) + WKV-6.

Two recurrences, both sequential in T but embarrassingly parallel across
(batch, channel/head) - exactly the dims the grid parallelizes:

  diagonal:  h_t = a_t * h_{t-1} + x_t                    (RG-LRU, per chan)
      grid (B, D/bd); block (1, T, bd); h carried in VMEM scratch; the T
      loop is a jax.lax.fori_loop inside the kernel (VPU elementwise work).

  wkv6:      o_t = r_t . (S_{t-1} + u * k_t (x) v_t)      (RWKV-6, per head)
             S_t = diag(w_t) S_{t-1} + k_t (x) v_t
      grid (B, H); S (Dk, Dv) in VMEM scratch; per-step outer products and
      row-vector contractions on the VPU/MXU.

The hardware-adaptation note (DESIGN.md): a GPU kernel would assign one
thread per channel; on TPU the (8,128) VREG tiling wants the channel dim
contiguous in lanes, which both layouts provide ((T, bd) and (Dk, Dv)).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ------------------------------------------------------------ diagonal scan


def _diag_kernel(a_ref, x_ref, h0_ref, o_ref, hT_ref):
    T = a_ref.shape[1]

    def step(t, h):
        h = a_ref[0, t, :] * h + x_ref[0, t, :]
        o_ref[0, t, :] = h
        return h

    h = jax.lax.fori_loop(0, T, step, h0_ref[0, :])
    hT_ref[0, :] = h


def linear_scan_pallas(
    a: jax.Array,     # (B, T, D) fp32
    x: jax.Array,     # (B, T, D) fp32
    h0: jax.Array,    # (B, D) fp32
    *,
    bd: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    B, T, D = a.shape
    bd = min(bd, D)
    assert D % bd == 0, (D, bd)
    out, hT = pl.pallas_call(
        _diag_kernel,
        grid=(B, D // bd),
        in_specs=[
            pl.BlockSpec((1, T, bd), lambda b, d: (b, 0, d)),
            pl.BlockSpec((1, T, bd), lambda b, d: (b, 0, d)),
            pl.BlockSpec((1, bd), lambda b, d: (b, d)),
        ],
        out_specs=[
            pl.BlockSpec((1, T, bd), lambda b, d: (b, 0, d)),
            pl.BlockSpec((1, bd), lambda b, d: (b, d)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, D), jnp.float32),
            jax.ShapeDtypeStruct((B, D), jnp.float32),
        ],
        interpret=interpret,
    )(a, x, h0)
    return out, hT


# ------------------------------------------------------------------- WKV-6


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sT_ref, s_ref):
    T = r_ref.shape[2]
    s_ref[...] = s0_ref[0, 0]

    def step(t, _):
        r = r_ref[0, 0, t, :]            # (Dk,)
        kk = k_ref[0, 0, t, :]
        vv = v_ref[0, 0, t, :]           # (Dv,)
        ww = w_ref[0, 0, t, :]
        kv = kk[:, None] * vv[None, :]   # (Dk, Dv)
        s = s_ref[...]
        o_ref[0, 0, t, :] = jnp.sum(
            (s + u_ref[0, :][:, None] * kv) * r[:, None], axis=0
        )
        s_ref[...] = ww[:, None] * s + kv
        return 0

    jax.lax.fori_loop(0, T, step, 0)
    sT_ref[0, 0] = s_ref[...]


def wkv6_pallas(
    r: jax.Array,     # (B, H, T, Dk) fp32
    k: jax.Array,     # (B, H, T, Dk)
    v: jax.Array,     # (B, H, T, Dv)
    w: jax.Array,     # (B, H, T, Dk) decay in (0, 1)
    u: jax.Array,     # (H, Dk) bonus
    s0: jax.Array,    # (B, H, Dk, Dv)
    *,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    B, H, T, Dk = r.shape
    Dv = v.shape[-1]
    out, sT = pl.pallas_call(
        _wkv_kernel,
        grid=(B, H),
        in_specs=[
            pl.BlockSpec((1, 1, T, Dk), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, T, Dk), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, T, Dv), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, T, Dk), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, Dk), lambda b, h: (h, 0)),
            pl.BlockSpec((1, 1, Dk, Dv), lambda b, h: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, T, Dv), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, Dk, Dv), lambda b, h: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, T, Dv), jnp.float32),
            jax.ShapeDtypeStruct((B, H, Dk, Dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((Dk, Dv), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return out, sT
