"""Pure-jnp oracles for the linear-scan kernels."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def linear_scan_ref(
    a: jax.Array, x: jax.Array, h0: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """h_t = a_t * h_{t-1} + x_t over axis 1; returns (outs, h_T)."""

    def step(h, ax):
        at, xt = ax
        h = at * h + xt
        return h, h

    hT, out = jax.lax.scan(
        step, h0, (a.transpose(1, 0, 2), x.transpose(1, 0, 2))
    )
    return out.transpose(1, 0, 2), hT


def wkv6_ref(
    r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
    u: jax.Array, s0: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """RWKV-6 recurrence, (B, H, T, D) layout; returns (out, s_T)."""

    def step(s, inp):
        rt, kt, vt, wt = inp  # (B,H,Dk)/(B,H,Dv)
        kv = kt[..., :, None] * vt[..., None, :]
        o = jnp.einsum("bhk,bhkv->bhv", rt, s + u[..., None] * kv)
        s = wt[..., :, None] * s + kv
        return s, o

    tfirst = lambda z: z.transpose(2, 0, 1, 3)
    sT, out = jax.lax.scan(step, s0, (tfirst(r), tfirst(k), tfirst(v), tfirst(w)))
    return out.transpose(1, 2, 0, 3), sT
