"""jit'd wrappers for the linear-scan kernels."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.linear_scan.linear_scan import (
    linear_scan_pallas,
    wkv6_pallas,
)


def _should_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("bd", "interpret"))
def linear_scan(a, x, h0, bd: int = 256, interpret: bool | None = None):
    interp = _should_interpret() if interpret is None else interpret
    D = a.shape[-1]
    bd = min(bd, D)
    while D % bd:
        bd //= 2
    return linear_scan_pallas(a, x, h0, bd=max(bd, 1), interpret=interp)


@partial(jax.jit, static_argnames=("interpret",))
def wkv6(r, k, v, w, u, s0, interpret: bool | None = None):
    interp = _should_interpret() if interpret is None else interpret
    return wkv6_pallas(r, k, v, w, u, s0, interpret=interp)
