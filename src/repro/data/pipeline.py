"""Deterministic, shardable synthetic token pipeline.

Production shape without production data: every batch is derived from
(seed, step, host) counters, so

  * restarts resume mid-epoch exactly (checkpoint stores the step),
  * each data-parallel host generates only its shard (no central loader),
  * prefetch runs on a background thread with a bounded queue,
  * a configurable per-host delay injector simulates stragglers for the
    fault-tolerance tests (train/loop.py's straggler monitor).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0
    # markov-ish structure so loss can actually decrease in examples
    structure: float = 0.8


def _host_batch(cfg: DataConfig, step: int) -> dict[str, np.ndarray]:
    per_host = cfg.global_batch // cfg.num_hosts
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_id])
    )
    b = per_host
    toks = rng.integers(0, cfg.vocab, (b, cfg.seq_len + 1), dtype=np.int32)
    # inject learnable structure: with prob `structure`, next token is a
    # deterministic function of the previous one
    if cfg.structure > 0:
        nxt = (toks[:, :-1] * 31 + 7) % cfg.vocab
        mask = rng.random((b, cfg.seq_len)) < cfg.structure
        toks[:, 1:] = np.where(mask, nxt, toks[:, 1:])
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class Pipeline:
    """Background-prefetching iterator over deterministic steps."""

    def __init__(
        self,
        cfg: DataConfig,
        start_step: int = 0,
        prefetch: int = 2,
        delay_s: float = 0.0,
    ):
        self.cfg = cfg
        self._step = start_step
        self._delay = delay_s
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            if self._delay:
                time.sleep(self._delay)
            batch = _host_batch(self.cfg, step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[tuple[int, dict[str, np.ndarray]]]:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)


def batch_at(cfg: DataConfig, step: int) -> dict[str, np.ndarray]:
    """Pure function view (used by tests + elastic resume validation)."""
    return _host_batch(cfg, step)
