"""Sharded checkpointing: npz shards + JSON manifest, async save, elastic
restore.

Layout:
    <dir>/step_000123/
        manifest.json        {step, leaf paths, shapes, dtypes, data config}
        shard_<host>.npz     this host's param/opt leaves (flattened paths)

Design points for the 1000+-node story:
  * per-host shard files: each host writes only the leaves (or leaf slices)
    it owns - no single-writer bottleneck;
  * async: `save_async` snapshots to host RAM (device_get) synchronously,
    then writes to disk on a background thread so the train loop resumes
    immediately (write bandwidth overlaps compute);
  * atomic publish: shards are written into a tmp dir, renamed at the end -
    a crash mid-save never corrupts the latest checkpoint;
  * elastic restore: leaves are re-sharded onto whatever mesh the restore
    runs under (jax.device_put with the new sharding), so restarting on a
    different pod count works.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import ml_dtypes
import numpy as np


def _to_savable(arr: np.ndarray) -> np.ndarray:
    """npz cannot store bfloat16; view as uint16 (dtype kept in manifest)."""
    if arr.dtype == ml_dtypes.bfloat16:
        return arr.view(np.uint16)
    return arr


def _from_savable(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    if dtype_str == "bfloat16":
        return arr.view(ml_dtypes.bfloat16)
    return arr


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _tree_def(tree: Any):
    return jax.tree_util.tree_structure(tree)


def save(
    directory: str,
    step: int,
    tree: Any,
    extra: dict | None = None,
    host_id: int = 0,
) -> str:
    """Synchronous checkpoint write; returns the final path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + f".tmp{host_id}"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, f"shard_{host_id}.npz"),
             **{k: _to_savable(v) for k, v in flat.items()})
    manifest = {
        "step": step,
        "leaves": {k: [list(v.shape), str(v.dtype)] for k, v in flat.items()},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


class AsyncCheckpointer:
    """Snapshot synchronously, write on a background thread."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, step: int, tree: Any, extra: dict | None = None):
        self.wait()
        # snapshot now (device -> host) so training can mutate state freely
        flat = _flatten(tree)

        def _write():
            final = os.path.join(self.directory, f"step_{step:08d}")
            tmp = final + ".tmp0"
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "shard_0.npz"),
                     **{k: _to_savable(v) for k, v in flat.items()})
            manifest = {
                "step": step,
                "leaves": {
                    k: [list(v.shape), str(v.dtype)] for k, v in flat.items()
                },
                "extra": extra or {},
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(list_steps(self.directory))
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:08d}"),
                ignore_errors=True,
            )


def list_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith((".tmp0",)):
            try:
                out.append(int(name.split("_")[1]))
            except ValueError:
                continue
    return sorted(out)


def latest_step(directory: str) -> int | None:
    steps = list_steps(directory)
    return steps[-1] if steps else None


def restore(
    directory: str,
    like: Any,
    step: int | None = None,
    shardings: Any = None,
) -> tuple[Any, dict]:
    """Restore into the structure of `like`; optionally placing each leaf
    with `shardings` (elastic re-shard onto the current mesh)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "shard_0.npz"))
    leaves_with_path = jax.tree_util.tree_flatten_with_path(like)[0]
    tdef = _tree_def(like)
    shard_leaves = (
        jax.tree.leaves(shardings) if shardings is not None else None
    )
    out = []
    for i, (p, leaf) in enumerate(leaves_with_path):
        key = "/".join(
            str(getattr(q, "key", getattr(q, "idx", q))) for q in p
        )
        arr = _from_savable(data[key], manifest["leaves"][key][1])
        if shard_leaves is not None:
            arr = jax.device_put(arr, shard_leaves[i])
        else:
            arr = jax.numpy.asarray(arr)
        out.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    return tdef.unflatten(out), manifest["extra"]
