"""Quickstart: the paper's workflow end-to-end in 60 lines.

1. Describe a CONV layer as the seven-loop nest (paper Algorithm 1).
2. Pick a dataflow (spatial unrolling) and hardware (memory hierarchy).
3. Search loop blockings with the analytical model; inspect the schedule.
4. Cross-check the model against the exact simulator.
5. Map the same machinery to a TPU matmul tile choice.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses

from repro.core import (
    ArraySpec,
    MemLevel,
    analyze,
    choose_matmul_tiles,
    conv_nest,
    make_dataflow,
    search_blocking,
    simulate,
)

# 1. the algorithm: AlexNet CONV3 as a loop nest
nest = conv_nest("conv3", B=16, K=384, C=256, X=13, Y=13, FX=3, FY=3)
print(f"nest: {dict(nest.bounds)}  MACs={nest.macs()/1e9:.2f}G")

# 2. hardware skeleton (Eyeriss-like) + C|K dataflow with replication
levels = (
    MemLevel("RF", 512, double_buffered=False, per_pe=True),
    MemLevel("BUF", 128 * 1024),
    MemLevel("DRAM", None),
)
array = ArraySpec(dims=(16, 16))
dataflow = make_dataflow(nest, array, ("C", "K"))
print("dataflow:", dataflow.label(), "PEs used:", dataflow.used_pes())

# 3. blocking search (the paper's schedule optimization)
result = search_blocking(nest, levels, array, dataflow, beam=8)
report = result.best
print(f"best energy: {report.energy_pj/1e6:.0f} uJ  "
      f"utilization: {report.utilization:.2f}")
print(report.schedule.describe())
print("breakdown (uJ):",
      {k: round(v / 1e6, 1) for k, v in report.breakdown_pj.items()})

# 4. validate the analytical model against the exact simulator
#    (fold the spatial dims into the top level for the temporal simulator)
sched = report.schedule
temporal = dataclasses.replace(
    sched,
    tiling={
        d: tuple(
            f * (sched.spatial_factor(d) if i == len(levels) - 1 else 1)
            for i, f in enumerate(sched.tiling[d])
        )
        for d in nest.dims
    },
    array=ArraySpec(dims=(1,)),
    spatial=((),),
)
assert analyze(temporal).reads == simulate(temporal).reads
print("analytical model == exact simulator: OK")

# 5. the same blocking engine picks Pallas tiles for a TPU matmul
tiles = choose_matmul_tiles(M=4096, N=14336, K=4096)
print(f"TPU matmul tiles for (4096x14336x4096): bm={tiles.bm} "
      f"bn={tiles.bn} bk={tiles.bk}  VMEM={tiles.vmem_bytes()/2**20:.1f} MiB")
