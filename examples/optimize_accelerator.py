"""Design-space exploration: the paper's efficient optimizer (§6.3).

Runs the Obs1+Obs2-pruned hardware x blocking search for a DNN and prints
the optimized accelerator config + energy vs the Eyeriss-like baseline -
examples/quickstart.py at network scale.

Run:  PYTHONPATH=src python examples/optimize_accelerator.py [--net alexnet]
"""

import argparse

from repro.core import ArraySpec, eyeriss_like
from repro.core.networks import PAPER_BENCHMARKS
from repro.core.optimizer import candidate_hierarchies, evaluate_network


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--net", default="alexnet", choices=sorted(PAPER_BENCHMARKS))
    ap.add_argument("--evals", type=int, default=800)
    args = ap.parse_args()

    layers = PAPER_BENCHMARKS[args.net]()
    base_hw = eyeriss_like()
    base = evaluate_network(layers, base_hw, args.evals)
    print(f"{args.net}: baseline ({base_hw.name}) "
          f"energy={base.total_energy_pj/1e6:.0f} uJ "
          f"TOPs/W={base.tops_per_watt():.2f}")

    best = None
    for hw in candidate_hierarchies(ArraySpec(dims=(16, 16)),
                                    two_level_rf=False):
        try:
            res = evaluate_network(layers, hw, args.evals)
        except ValueError:
            continue
        if best is None or res.total_energy_pj < best.total_energy_pj:
            best = res
            print(f"  new best: {hw.name:20s} "
                  f"{res.total_energy_pj/1e6:.0f} uJ "
                  f"({base.total_energy_pj/res.total_energy_pj:.2f}x)")
    print(f"optimized: {best.hw.name}  "
          f"gain={base.total_energy_pj/best.total_energy_pj:.2f}x  "
          f"TOPs/W={best.tops_per_watt():.2f}")
    # per-layer winning schedules
    for lr in best.layers[:3]:
        print(f"--- {lr.nest.name}: {lr.dataflow.label()}")
        print(lr.report.schedule.describe())


if __name__ == "__main__":
    main()
