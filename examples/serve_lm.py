"""Serving example: streaming requests through the continuous-batching
engine (slot-based KV cache, prefill/decode interleaving), including the
request lifecycle — typed results, mid-flight cancellation, deadlines.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import numpy as np

import jax

from repro.arch.model_zoo import build
from repro.configs.registry import get
from repro.serve.engine import Engine, Request, ServeConfig


def main():
    cfg = get("smollm-360m-smoke")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(cfg, params, ServeConfig(batch=4, max_len=128))

    rng = np.random.default_rng(0)
    requests = [
        Request(rng.integers(0, cfg.vocab, n).astype(np.int32),
                max_new_tokens=m)
        for n, m in ((5, 8), (12, 16), (3, 4))
    ]
    # a deadline-bound request: FAILs with its partial output if it cannot
    # finish within 6 engine steps
    requests.append(
        Request(rng.integers(0, cfg.vocab, 6).astype(np.int32),
                max_new_tokens=24, deadline_steps=6)
    )

    def on_token(rid, tok, idx, done):
        tail = "  <done>" if done else ""
        print(f"  stream req{rid}[{idx}] = {tok}{tail}")

    rids = [engine.submit(r) for r in requests]
    engine.step(on_token)
    engine.step(on_token)
    # the client for request 1 hung up two steps in: cancel mid-flight —
    # its slot frees immediately and is backfilled on the next step
    print(f"cancel req{rids[1]} -> {engine.cancel(rids[1]).value}")
    while engine.step(on_token):
        pass

    for i, rid in enumerate(rids):
        res = engine.pop_result(rid)  # typed: (status, tokens, reason, ...)
        why = f" ({res.reason})" if res.reason else ""
        print(f"request {rid}: prompt_len={len(requests[i].prompt)} "
              f"status={res.status.value}{why} generated={res.tolist()}")


if __name__ == "__main__":
    main()
