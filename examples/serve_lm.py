"""Serving example: streaming requests through the continuous-batching
engine (slot-based KV cache, prefill/decode interleaving), including the
request lifecycle — typed results, mid-flight cancellation, deadlines —
and crash recovery: a durable engine is killed mid-decode, restored from
its snapshot + write-ahead journal, and finishes every request with
exactly the tokens the uncrashed run would have produced.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import shutil
import tempfile

import numpy as np

import jax

from repro.arch.model_zoo import build
from repro.configs.registry import get
from repro.serve import recovery
from repro.serve.engine import (
    DurabilityConfig,
    Engine,
    Request,
    SchedulerConfig,
    ServeConfig,
)


def main():
    cfg = get("smollm-360m-smoke")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    requests = [
        Request(rng.integers(0, cfg.vocab, n).astype(np.int32), max_new=m)
        for n, m in ((5, 8), (12, 16), (3, 4))
    ]
    # a deadline-bound request: FAILs with its partial output if it cannot
    # finish within 6 engine steps
    requests.append(
        Request(rng.integers(0, cfg.vocab, 6).astype(np.int32),
                max_new=24, deadline_steps=6)
    )

    def on_token(rid, tok, idx, done):
        tail = "  <done>" if done else ""
        print(f"  stream req{rid}[{idx}] = {tok}{tail}")

    # the engine is a context manager: __exit__ drains durability workers
    # and releases the KV pool even if the block raises
    with Engine(
        cfg, params,
        ServeConfig(max_len=128, scheduler=SchedulerConfig(batch=4)),
    ) as engine:
        rids = [engine.submit(r) for r in requests]
        engine.step(on_token)
        engine.step(on_token)
        # the client for request 1 hung up two steps in: cancel mid-flight —
        # its slot frees immediately and is backfilled on the next step
        print(f"cancel req{rids[1]} -> {engine.cancel(rids[1]).value}")
        while engine.step(on_token):
            pass

        for i, rid in enumerate(rids):
            res = engine.pop_result(rid)  # typed: (status, tokens, ...)
            why = f" ({res.reason})" if res.reason else ""
            print(f"request {rid}: prompt_len={len(requests[i].prompt)} "
                  f"status={res.status.value}{why} "
                  f"ttft_steps={res.ttft_steps} generated={res.tolist()}")

    # ---- unified scheduler: chunked prefill interleaved with decode -------
    # prefill_chunk tiles each admission prefill into fixed-size chunks and
    # token_budget caps how many prefill tokens advance per step, so decode
    # latency stays flat while long prompts trickle in.  With the budget
    # unset and chunk >= prompt it degenerates to monolithic admission —
    # outputs are bitwise identical either way.
    print("\n--- unified scheduler (chunked prefill) demo ---")
    with Engine(
        cfg, params,
        ServeConfig(
            max_len=128,
            scheduler=SchedulerConfig(
                batch=4, prefill_chunk=16, token_budget=16
            ),
        ),
    ) as chunked:
        long_prompt = rng.integers(0, cfg.vocab, 100).astype(np.int32)
        rid = chunked.submit(Request(long_prompt, max_new=4))
        while True:
            alive = chunked.step()
            status = chunked.status(rid).value
            if status == "PREFILLING":
                print(f"  req{rid} PREFILLING (16-token chunks under budget)")
            if not alive:
                break
        res = chunked.pop_result(rid)
        print(f"request {rid}: status={res.status.value} "
              f"ttft_steps={res.ttft_steps} generated={res.tolist()}")

    # ---- kill and resume: crash-consistent serving (serve/recovery.py) ----
    # A snapshot_dir arms durability: atomic snapshots every snapshot_every
    # steps plus a per-step write-ahead journal.  Killing the process (here:
    # abandoning the engine object without close()) loses nothing — restore
    # replays the journal with teacher forcing, so survivors finish bitwise
    # identical to a run that never crashed.
    print("\n--- crash / resume demo ---")
    snapdir = tempfile.mkdtemp(prefix="serve_lm_snap_")
    base = dict(
        max_len=128, temperature=0.8, seed=7,
        scheduler=SchedulerConfig(batch=4),
    )
    scfg = ServeConfig(
        durability=DurabilityConfig(snapshot_dir=snapdir, snapshot_every=4),
        **base,
    )
    requests = [
        Request(rng.integers(0, cfg.vocab, n).astype(np.int32),
                max_new=m, request_id=100 + i)
        for i, (n, m) in enumerate(((6, 12), (9, 16), (4, 10)))
    ]
    # sampling folds in (request_id, position) only, so a plain engine with
    # the same seed is a valid never-crashed oracle
    oracle = {r.request_id: o.tolist()
              for r, o in zip(requests, Engine(cfg, params,
                                               ServeConfig(**base)).run(
                  [Request(r.prompt, max_new=r.max_new,
                           request_id=r.request_id) for r in requests]))}

    doomed = Engine(cfg, params, scfg)
    for r in requests:
        doomed.submit(r)
    for _ in range(6):  # past one snapshot, mid-decode
        doomed.step()
    doomed.recovery.wait()
    del doomed  # simulated SIGKILL: no close(), no flush, just gone

    engine, report = recovery.restore_engine(cfg, params, scfg)
    print(f"restored from {report.source} snapshot={report.snapshot_key}: "
          f"replayed {report.tokens_replayed} journaled tokens")
    while engine.step():
        pass
    for r in requests:
        res = engine.pop_result(r.request_id)
        match = "bitwise-identical" if res.tolist() == oracle[r.request_id] \
            else "MISMATCH"
        print(f"request {r.request_id}: status={res.status.value} "
              f"{match} to the never-crashed run")
    engine.close()
    shutil.rmtree(snapdir, ignore_errors=True)


if __name__ == "__main__":
    main()
