"""Serving example: streaming requests through the continuous-batching
engine (slot-based KV cache, prefill/decode interleaving).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import numpy as np

import jax

from repro.arch.model_zoo import build
from repro.configs.registry import get
from repro.serve.engine import Engine, Request, ServeConfig


def main():
    cfg = get("smollm-360m-smoke")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(cfg, params, ServeConfig(batch=4, max_len=128))

    rng = np.random.default_rng(0)
    requests = [
        Request(rng.integers(0, cfg.vocab, n).astype(np.int32),
                max_new_tokens=m)
        for n, m in ((5, 8), (12, 16), (3, 4))
    ]

    def on_token(rid, tok, idx, done):
        tail = "  <done>" if done else ""
        print(f"  stream req{rid}[{idx}] = {tok}{tail}")

    outs = engine.run(requests, on_token=on_token)
    for i, out in enumerate(outs):
        print(f"request {i}: prompt_len={len(requests[i].prompt)} "
              f"generated={out.tolist()}")


if __name__ == "__main__":
    main()
