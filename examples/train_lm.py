"""End-to-end training driver (deliverable b): train a ~100M-param LM for a
few hundred steps on synthetic structured data, with checkpointing and
straggler monitoring.  Defaults are CPU-sized; --arch accepts any registry
id (use the -smoke variants on CPU).

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
      PYTHONPATH=src python examples/train_lm.py --arch smollm-360m --steps 300   # ~real scale
"""

import argparse

from repro.configs.registry import get
from repro.data.pipeline import DataConfig
from repro.train import optim
from repro.train.loop import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m-smoke")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = get(args.arch)
    dcfg = DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch
    )
    tcfg = TrainConfig(
        steps=args.steps,
        microbatches=args.microbatches,
        ckpt_every=50,
        ckpt_dir=args.ckpt_dir,
        log_every=10,
        opt=optim.AdamWConfig(
            lr=args.lr, warmup_steps=20, total_steps=args.steps
        ),
    )
    out = train(cfg, dcfg, tcfg)
    losses = out["losses"]
    print(
        f"done: loss {losses[0]:.3f} -> {losses[-1]:.3f} over "
        f"{len(losses)} steps; stragglers flagged: {len(out['stragglers'])}"
    )


if __name__ == "__main__":
    main()
