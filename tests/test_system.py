"""End-to-end system tests: training convergence, checkpoint-restart
equivalence, straggler detection, serving."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.registry import get
from repro.data.pipeline import DataConfig
from repro.serve.engine import Engine, Request, ServeConfig
from repro.train import optim
from repro.train.loop import TrainConfig, train


def _small_cfgs(steps=14, ckpt_dir=None, microbatches=1):
    mcfg = get("smollm-360m-smoke")
    dcfg = DataConfig(vocab=mcfg.vocab, seq_len=16, global_batch=4)
    tcfg = TrainConfig(
        steps=steps,
        microbatches=microbatches,
        ckpt_every=5,
        ckpt_dir=ckpt_dir,
        opt=optim.AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=steps),
    )
    return mcfg, dcfg, tcfg


@pytest.mark.slow
def test_training_reduces_loss():
    """Assert learning on held-out data, not on the per-step loss trace.

    Each step's reported loss is measured on a *different* random batch, and
    at this scale the batch-to-batch loss spread under near-init params
    (~0.02-0.05 nats std) exceeds the expected improvement over a handful of
    steps — so the old ``losses[-1] < losses[0]`` check was a coin flip (the
    seed failure: 5.522 -> 5.534 while mean held-out loss improved).  Instead
    compare the mean loss over a pool of fixed never-trained-on batches
    before vs after training (30 steps moves it ~0.03 nats, an order of
    magnitude above any numeric jitter); with fixed seeds this is
    deterministic.
    """
    from repro.arch.model_zoo import build
    from repro.data.pipeline import batch_at

    steps = 30
    mcfg, dcfg, tcfg = _small_cfgs(steps=steps)
    model = build(mcfg)
    held = [
        {k: jnp.asarray(v) for k, v in batch_at(dcfg, 10_000 + i).items()}
        for i in range(16)
    ]
    loss_fn = jax.jit(model.loss)

    def held_out_loss(params):
        return float(
            np.mean([float(loss_fn(params, b["tokens"], b["labels"]))
                     for b in held])
        )

    init = model.init(jax.random.PRNGKey(0))  # same seed train() uses
    before = held_out_loss(init)
    out = train(mcfg, dcfg, tcfg)
    losses = out["losses"]
    assert len(losses) == steps
    assert np.isfinite(losses).all()
    after = held_out_loss(out["final_params"])
    assert after < before, f"no learning: held-out {before} -> {after}"


@pytest.mark.slow
def test_microbatched_step_matches_plain():
    """Grad accumulation over microbatches must match the single-batch step."""
    from repro.arch.model_zoo import build
    from repro.data.pipeline import batch_at
    from repro.train.loop import make_train_step

    mcfg = get("smollm-360m-smoke")
    model = build(mcfg)
    params = model.init(jax.random.PRNGKey(0))
    state = optim.init_state(params)
    dcfg = DataConfig(vocab=mcfg.vocab, seq_len=16, global_batch=4)
    batch = {k: jnp.asarray(v) for k, v in batch_at(dcfg, 0).items()}
    t1 = TrainConfig(microbatches=1, opt=optim.AdamWConfig(lr=1e-3))
    t2 = TrainConfig(microbatches=2, opt=optim.AdamWConfig(lr=1e-3))
    p1, _, m1 = make_train_step(model, t1)(params, state, batch)
    p2, _, m2 = make_train_step(model, t2)(
        model.init(jax.random.PRNGKey(0)), optim.init_state(params), batch
    )
    d = jax.tree.map(
        lambda a, b: float(
            jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))
        ),
        p1, p2,
    )
    assert max(jax.tree.leaves(d)) < 5e-2  # bf16 accumulation tolerance
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), abs=2e-2)


@pytest.mark.slow
def test_checkpoint_restart_equivalence(tmp_path):
    """Crash after 10 steps + resume == uninterrupted run (deterministic
    data) - the core fault-tolerance property."""
    ckpt_dir = str(tmp_path / "ck")
    mcfg, dcfg, tcfg = _small_cfgs(steps=10, ckpt_dir=ckpt_dir)
    train(mcfg, dcfg, tcfg)  # writes ckpt at step 10

    mcfg, dcfg, tcfg2 = _small_cfgs(steps=14, ckpt_dir=ckpt_dir)
    resumed = train(mcfg, dcfg, tcfg2, resume=True)

    mcfg, dcfg, tcfg3 = _small_cfgs(steps=14)
    straight = train(mcfg, dcfg, tcfg3)

    diffs = jax.tree.map(
        lambda a, b: float(
            jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))
        ),
        resumed["final_params"], straight["final_params"],
    )
    assert max(jax.tree.leaves(diffs)) < 5e-2


def test_straggler_detection():
    from repro.train.loop import StragglerMonitor

    mon = StragglerMonitor(factor=3.0)
    for s in range(8):
        mon.record(s, 0.01)
    assert not mon.flagged
    mon.record(8, 0.2)  # 20x median
    assert mon.flagged and mon.flagged[0][0] == 8


def test_serving_engine_batched():
    mcfg = get("smollm-360m-smoke")
    from repro.arch.model_zoo import build

    model = build(mcfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(mcfg, params, ServeConfig(batch=3, max_len=64))
    reqs = [
        Request(np.array([1, 2, 3], np.int32), max_new_tokens=4),
        Request(np.array([5, 6], np.int32), max_new_tokens=6),
    ]
    outs = eng.generate(reqs)
    assert outs[0].shape == (4,)
    assert outs[1].shape == (6,)
    assert all((o >= 0).all() and (o < mcfg.vocab).all() for o in outs)
