"""Halide front-end lowering + hlo_cost parser + roofline model tests."""

import os

import pytest

from repro.core import analyze, conv_nest, evaluate, simulate
from repro.core.halide import HalideSchedule, listing1_example

DRYRUN = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "experiments", "dryrun",
)


def test_listing1_lowers_and_evaluates():
    nest = conv_nest("l1", B=1, K=64, C=3, X=16, Y=16, FX=5, FY=5)
    sched = listing1_example(nest)
    assert sched.cum_tile(1, include_spatial=False)["X"] == 8
    assert sched.spatial_factor("X") == 4
    rep = evaluate(sched)
    assert rep.energy_pj > 0


def test_split_accumulates_and_top_absorbs():
    nest = conv_nest("t", B=2, K=8, C=4, X=8, Y=8, FX=1, FY=1)
    s = (
        HalideSchedule(nest)
        .store("RF", 512, per_pe=True, double_buffered=False)
        .split("X", 2).split("X", 2)     # accumulates to 4
        .store("DRAM", None)
        .accelerate()
    )
    assert s.tiling["X"] == (4, 2)       # top absorbs the remainder
    assert s.padded_bound("X") == 8


def test_halide_schedule_matches_simulator():
    nest = conv_nest("t", B=2, K=4, C=2, X=4, Y=4, FX=1, FY=1)
    s = (
        HalideSchedule(nest)
        .store("RF", None, per_pe=True, double_buffered=False)
        .split("X", 2).split("K", 2).reorder("X", "K")
        .store("BUF", None)
        .split("C", 2).split("B", 2)
        .store("DRAM", None)
        .accelerate()
    )
    a, b = analyze(s), simulate(s)
    assert a.reads == b.reads and a.writes == b.writes


# --------------------------------------------------------------- hlo_cost


def test_hlo_cost_parser_synthetic():
    from benchmarks.hlo_cost import HloCost

    text = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %y = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%i2, %y)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %z = s32[] constant(0)
  %init = (s32[], f32[8,8]) tuple(%z, %a)
  %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%w_alias
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""
    text = text.replace("body=%w_alias", "body=%body")
    h = HloCost(text)
    cost = h.entry_cost()
    # dot: 2 * 64 * 8 = 1024 flops, x10 trips (+ tiny adds)
    assert 10_000 <= cost["flops"] <= 11_000
    assert cost["coll"]["total"] == 0


@pytest.mark.skipif(
    not os.path.exists(
        os.path.join(DRYRUN, "granite-8b__train_4k__16x16.c1.hlo.gz")
    ),
    reason="dry-run sidecars not generated",
)
def test_hlo_cost_on_real_sidecar_matches_hand_math():
    """granite-8b train reconstruction within 5% of analytic matmul count."""
    import json

    from benchmarks.hlo_cost import cost_of_file

    c1 = cost_of_file(os.path.join(DRYRUN, "granite-8b__train_4k__16x16.c1.hlo.gz"))
    c2 = cost_of_file(os.path.join(DRYRUN, "granite-8b__train_4k__16x16.c2.hlo.gz"))
    rec = json.load(open(os.path.join(DRYRUN, "granite-8b__train_4k__16x16.json")))
    total = c1["flops"] + (c2["flops"] - c1["flops"]) * (rec["scan_units"] - 1)
    D, F, T, L, mb = 4096, 14336, 4096, 36, rec["microbatches"]
    qkvo = 2 * T * (2 * D * 32 * 128 + 2 * D * 8 * 128) / 16
    ffn = 2 * T * 3 * D * F / 16
    attn = 4 * T * T * 32 * 128 / 16
    hand = 4 * (qkvo + ffn + attn) * L * mb
    assert abs(total - hand) / hand < 0.05


def test_roofline_model_flops_families():
    from benchmarks.roofline import model_flops

    # sliding-window archs cap attention kv_len
    g = model_flops("gemma3-12b", "prefill_32k")
    d = model_flops("deepseek-7b", "prefill_32k")
    assert g > 0 and d > 0
    # rwkv has no attention-context term
    r = model_flops("rwkv6-1.6b", "decode_32k")
    from repro.configs.registry import get

    assert r == pytest.approx(
        2.0 * get("rwkv6-1.6b").active_params_count() * 128, rel=1e-6
    )
