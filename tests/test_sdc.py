"""Silent-data-corruption defense in depth (kernels/abft.py +
Engine._sdc_recover + serve/chaos.py's SDC episode layer).

Three layers of evidence:

  * a *calibration property test* for the ABFT column-checksum tolerance:
    200 seeded clean matmuls across every shipped tile config must raise
    zero false positives, while seeded single-bit flips in the exponent /
    high-mantissa range of an output's largest row element must ALL be
    caught (lower bits on bf16 outputs drown in legitimate rounding — see
    kernels/abft.py's docstring for why that boundary is physical);
  * seeded *engine episodes* (``sdc`` mark): transient compute flips ride
    the in-program fault operand and must be detected + healed by the
    oracle-substrate retry (every survivor bitwise equal to the unfaulted
    oracle); persistent KV-pool flips must quarantine exactly the owning
    request, leak-free; clean episodes must detect nothing;
  * *unlocalizable corruption*: a persistent weight flip must raise
    ``SDCUnlocalizedError`` BEFORE any poisoned token is emitted, and the
    newest-snapshot restore (with pristine params) must finish the
    workload bitwise-intact.

Default episode counts are small; ``make test-sdc`` cranks SDC_EPISODES
and CI shards the seed space via SDC_SEED.  Any failure prints its
episode seed; replay with ``SDC_EPISODES=1 SDC_SEED=<seed> make test-sdc``.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import sdc_episodes, sdc_seed
from repro.arch.model_zoo import build
from repro.configs.registry import get
from repro.core.mapper import MatmulTiles
from repro.kernels import abft
from repro.kernels.matmul.ops import matmul_abft
from repro.serve import chaos, recovery
from repro.serve.engine import (
    SDC_RETRY_BUDGET,
    DurabilityConfig,
    Engine,
    KernelConfig,
    KVConfig,
    Request,
    RequestStatus,
    SchedulerConfig,
    SDCUnlocalizedError,
    ServeConfig,
)

MAX_LEN = 64
BS = 8

# every tile shape the mapper's blocking search actually ships for the
# serve-path GEMM sizes (projection, MLP, unembed) — the checksum kernel's
# per-row-block granularity must calibrate at each of them
SHIPPED_TILES = [
    MatmulTiles(64, 128, 128),
    MatmulTiles(128, 128, 64),
    MatmulTiles(32, 256, 128),
    MatmulTiles(128, 64, 256),
]


@pytest.fixture(scope="module")
def smol():
    cfg = get("smollm-360m-smoke")
    params = build(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


# ------------------------------------------------- checksum calibration --
def _mk_operands(rng, tiles, dtype):
    """One clean seeded matmul at 2x the tile in every dim (so the kernel
    revisits row blocks and the padding paths both stay honest)."""
    m, n, k = 2 * tiles.bm, 2 * tiles.bn, 2 * tiles.bk
    a = jnp.asarray(rng.uniform(-1, 1, (m, k)).astype(np.float32)).astype(dtype)
    b = jnp.asarray(rng.uniform(-1, 1, (k, n)).astype(np.float32)).astype(dtype)
    return a, b


@pytest.mark.sdc
def test_checksum_zero_false_positives_200_clean_matmuls():
    """The calibrated tolerance must never flag a clean product: 200
    seeded matmuls cycling through every shipped tile config and both
    serve dtypes, each through the real checksum-emitting Pallas kernel."""
    for i in range(200):
        tiles = SHIPPED_TILES[i % len(SHIPPED_TILES)]
        dtype = jnp.bfloat16 if i % 2 else jnp.float32
        rng = np.random.default_rng(10_000 + i)
        a, b = _mk_operands(rng, tiles, dtype)
        out, bad = matmul_abft(a, b, tiles=tiles)
        assert not bool(bad), (
            f"false positive: clean matmul flagged (seed={10_000 + i}, "
            f"tiles={tiles}, dtype={dtype.__name__})"
        )
        assert out.dtype == dtype and out.shape == (a.shape[0], b.shape[1])


@pytest.mark.sdc
def test_checksum_catches_injected_bit_flips():
    """Single-bit flips on an output row's largest element, across the
    exponent and high-mantissa range, must ALL break the checksum: f32
    bits 20..30 (high mantissa through exponent MSB) and bf16-surviving
    bits 23..29.  Targeting the max element is what the seeded harness
    does too — magnitude-decreasing flips on tiny elements sit below the
    output dtype's own rounding noise and are physically undetectable."""
    missed = []
    for i in range(60):
        tiles = SHIPPED_TILES[i % len(SHIPPED_TILES)]
        dtype = jnp.bfloat16 if i % 2 else jnp.float32
        bits = range(23, 30) if dtype == jnp.bfloat16 else range(20, 31)
        rng = np.random.default_rng(20_000 + i)
        a, b = _mk_operands(rng, tiles, dtype)
        out = np.asarray(
            (a.astype(jnp.float32) @ b.astype(jnp.float32)).astype(dtype)
        )
        row = int(rng.integers(out.shape[0]))
        col = int(np.argmax(np.abs(out[row].astype(np.float32))))
        bit = int(rng.choice(list(bits)))
        u = np.float32(out[row, col]).view(np.uint32) ^ np.uint32(1 << bit)
        flipped = np.array(out)
        flipped[row, col] = u.view(np.float32).astype(out.dtype)
        verdict = abft.mm_check(
            jnp.asarray(np.asarray(a)), jnp.asarray(np.asarray(b)),
            jnp.asarray(flipped),
        )
        if not bool(verdict):
            missed.append((20_000 + i, str(tiles), dtype.__name__, bit))
    assert not missed, f"undetected injected flips: {missed}"


# --------------------------------------------------------- engine setup --
def _sdc_pair(cfg, params, mode, **kernel_extra):
    common = dict(max_len=MAX_LEN, temperature=0.7, seed=5)
    sched = SchedulerConfig(batch=3, prefill_bucket=16, stall_patience=6)
    eng = Engine(
        cfg,
        params,
        ServeConfig(
            scheduler=sched,
            kv=KVConfig(layout="paged", block_size=BS),
            kernel=KernelConfig(abft=mode, **kernel_extra),
            **common,
        ),
    )
    oracle_eng = Engine(
        cfg,
        params,
        ServeConfig(
            scheduler=SchedulerConfig(batch=3, prefill_bucket=16),
            kv=KVConfig(decode_block=BS),
            **common,
        ),
    )
    return eng, oracle_eng


# ------------------------------------------------------- seeded episodes --
@pytest.mark.sdc
def test_sdc_episode_matrix(smol):
    """Seeded bit-flip episodes across both abft modes; the per-episode
    fault mix cycles deterministically so every surface (compute flip, KV
    flip, mixed, clean) fires regardless of the episode count."""
    cfg, params = smol
    setups = [
        ("checksum", *_sdc_pair(cfg, params, "checksum")),
        ("paranoid", *_sdc_pair(cfg, params, "paranoid")),
    ]
    # (n_compute, n_kv) per episode — explicit so a 2-episode default run
    # still exercises both fault surfaces
    mixes = [(1, 1), (2, 1), (1, 2), (0, 1), (2, 0), (1, 1)]
    n = sdc_episodes(4)
    base = sdc_seed()
    reports = []
    for ep in range(n):
        mode, eng, oracle_eng = setups[ep % len(setups)]
        n_compute, n_kv = mixes[ep % len(mixes)]
        seed = base + chaos.SEED_STRIDE + ep
        rng = np.random.default_rng(seed)
        reqs = chaos.make_sdc_workload(rng, cfg.vocab, MAX_LEN)
        oracle = chaos.oracle_outputs(oracle_eng, reqs)
        reports.append(
            chaos.run_sdc_episode(
                eng, oracle, reqs, seed, n_compute=n_compute, n_kv=n_kv
            )
        )
    fired_compute = sum(r.injected["compute"] for r in reports)
    fired_kv = sum(r.injected["kv"] for r in reports)
    assert fired_compute > 0, "no compute fault ever fired"
    assert fired_kv > 0, "no KV flip ever fired"
    # 100% detection: run_sdc_episode asserts the per-episode ledger;
    # re-assert the aggregate so a silently-skipped episode can't hide
    assert sum(r.detected for r in reports) == fired_compute
    assert sum(r.quarantined for r in reports) == fired_kv
    assert sum(r.statuses.get("FINISHED", 0) for r in reports) > 0, (
        "no request ever survived an SDC episode"
    )


@pytest.mark.sdc
def test_sdc_clean_episode_zero_false_positives(smol):
    """A fault-free episode through the armed pipeline must detect,
    retry, and quarantine NOTHING — and (via the driver's oracle
    comparison) serve tokens bitwise identical to the unarmed engine."""
    cfg, params = smol
    eng, oracle_eng = _sdc_pair(cfg, params, "checksum")
    seed = sdc_seed() + chaos.SEED_STRIDE + 777
    rng = np.random.default_rng(seed)
    reqs = chaos.make_sdc_workload(rng, cfg.vocab, MAX_LEN)
    oracle = chaos.oracle_outputs(oracle_eng, reqs)
    rep = chaos.run_sdc_episode(eng, oracle, reqs, seed, n_compute=0, n_kv=0)
    assert rep.detected == 0 and rep.retried == 0 and rep.quarantined == 0
    assert rep.statuses == {"FINISHED": len(reqs)}


@pytest.mark.sdc
def test_sdc_retry_budget_exhaustion_quarantines(smol):
    """Repeated detections charge every live slot (a step-level checksum
    cannot name the victim row); the (SDC_RETRY_BUDGET+1)-th detection
    must quarantine the survivors as the probable corruption source
    instead of retrying forever."""
    cfg, params = smol
    eng, oracle_eng = _sdc_pair(cfg, params, "checksum")
    rng = np.random.default_rng(99)
    reqs = [
        Request(
            rng.integers(0, cfg.vocab, 12).astype(np.int32),
            max_new_tokens=24,
            request_id=i,
        )
        for i in range(2)
    ]
    oracle = chaos.oracle_outputs(oracle_eng, reqs)
    for r in reqs:
        eng.submit(r)
    eng.step()  # admit + populate the trace probe
    n_mm = eng._abft_probe["mms"]
    for hit in range(SDC_RETRY_BUDGET + 1):
        assert eng._slots, "victims finished before the budget ran out"
        eng.arm_fault(abft.FAULT_MATMUL, n_mm - 1, 0, -1, 27)
        eng.step()
        chaos.audit(eng)
        eng.step()  # one clean step between hits
        chaos.audit(eng)
    assert eng.stats["sdc_detected"] == SDC_RETRY_BUDGET + 1
    assert eng.stats["sdc_retried"] == SDC_RETRY_BUDGET + 1
    assert eng.stats["quarantined"] == len(reqs)
    while eng.step():
        chaos.audit(eng)
    assert eng.pool.free_blocks == eng.pool.num_blocks - 1
    for r in reqs:
        res = eng.pop_result(r.request_id)
        assert res.status == RequestStatus.FAILED
        assert res.reason == "sdc: retry budget exhausted"
        # every token emitted before quarantine came from a healed step
        assert res.tolist() == oracle[r.request_id][: len(res)]


@pytest.mark.sdc
def test_sdc_weight_corruption_raises_then_restores(smol, tmp_path):
    """Persistent weight rot is unlocalizable by construction (both sides
    of the checksum identity use the corrupt operand): the weight
    fingerprint must raise SDCUnlocalizedError BEFORE the step emits or
    journals anything, and restoring from the newest snapshot with
    pristine params must finish every request bitwise-intact."""
    cfg, params = smol
    common = dict(max_len=MAX_LEN, temperature=0.7, seed=5)
    scfg = ServeConfig(
        scheduler=SchedulerConfig(batch=3, prefill_bucket=16),
        kv=KVConfig(layout="paged", block_size=BS),
        kernel=KernelConfig(abft="checksum"),
        durability=DurabilityConfig(
            snapshot_dir=str(tmp_path / "snaps"),
            snapshot_every=2,
            snapshot_keep=2,
        ),
        **common,
    )
    oracle_eng = Engine(
        cfg,
        params,
        ServeConfig(
            scheduler=SchedulerConfig(batch=3, prefill_bucket=16),
            kv=KVConfig(decode_block=BS),
            **common,
        ),
    )
    rng = np.random.default_rng(41)
    reqs = [
        Request(
            rng.integers(0, cfg.vocab, 10).astype(np.int32),
            max_new_tokens=16,
            request_id=i,
        )
        for i in range(3)
    ]
    oracle = chaos.oracle_outputs(oracle_eng, reqs)
    eng = Engine(cfg, params, scfg)
    for r in reqs:
        eng.submit(r)
    for _ in range(5):  # past snapshot_every: a snapshot has published
        eng.step()
        chaos.audit(eng)
    assert eng._slots, "workload drained before the flip landed"
    eng.params, leaf = chaos.flip_weight_bit(eng.params, rng)
    with pytest.raises(SDCUnlocalizedError, match="weight fingerprint"):
        eng.step()
    assert eng.stats["sdc_detected"] == 1
    # simulated operator response: abandon the poisoned process (journal
    # bytes survive, fd dropped) and restore with freshly loaded params
    eng.recovery.wait()
    eng.recovery.journal._f.close()
    del eng
    eng2, report = recovery.restore_engine(cfg, params, scfg)
    chaos.audit(eng2)
    assert report.source in ("snapshot", "cold")
    while eng2.step():
        chaos.audit(eng2)
    assert eng2.pool.free_blocks == eng2.pool.num_blocks - 1
    for r in reqs:
        res = eng2.pop_result(r.request_id)
        assert res.status == RequestStatus.FINISHED, (
            f"rid {r.request_id}: {res.status} ({res.reason!r})"
        )
        assert res.tolist() == oracle[r.request_id], (
            f"rid {r.request_id} diverged after weight-corruption restore"
        )
    eng2.close()


# ------------------------------------------------------------ guardrails --
def test_arm_fault_requires_abft(smol):
    cfg, params = smol
    eng = Engine(
        cfg,
        params,
        ServeConfig(
            scheduler=SchedulerConfig(batch=2),
            kv=KVConfig(layout="paged", block_size=BS),
            max_len=MAX_LEN,
        ),
    )
    with pytest.raises(ValueError, match="abft"):
        eng.arm_fault(abft.FAULT_MATMUL, 0, 0, -1, 27)
    eng.close()


def test_abft_requires_paged_layout():
    with pytest.raises(ValueError, match="paged"):
        ServeConfig(kernel=KernelConfig(abft="checksum"), max_len=MAX_LEN)


def test_abft_mode_validated():
    with pytest.raises(ValueError, match="abft"):
        KernelConfig(abft="extra-paranoid")


def test_scrub_every_validated():
    with pytest.raises(ValueError, match="scrub_every"):
        KernelConfig(scrub_every=0)


@pytest.mark.sdc
def test_weight_scrub_cadence_catches_flip_within_period(smol):
    """At ``scrub_every=N`` the full weight-fingerprint pass runs on every
    N-th step only: a weight flip landing between scrubs must still raise
    SDCUnlocalizedError within N steps (the amortization trades detection
    latency, never detection)."""
    cfg, params = smol
    scrub = 3
    eng, oracle_eng = _sdc_pair(cfg, params, "checksum", scrub_every=scrub)
    oracle_eng.close()
    rng = np.random.default_rng(4242)
    reqs = [
        Request(
            rng.integers(0, cfg.vocab, 10).astype(np.int32),
            max_new_tokens=40,
            request_id=i,
        )
        for i in range(2)
    ]
    for r in reqs:
        eng.submit(r)
    eng.step()
    eng.params, _leaf = chaos.flip_weight_bit(eng.params, rng)
    steps = 0
    with pytest.raises(SDCUnlocalizedError):
        for _ in range(2 * scrub):
            steps += 1
            eng.step()
    assert steps <= scrub, (
        f"weight flip took {steps} steps to surface at scrub_every={scrub}"
    )
    eng.close()


# ----------------------------------------------------- cost-model parity --
def test_abft_cost_batched_matches_scalar():
    """The blocking sweep's vectorized ABFT surcharge
    (costmodel.BatchedCostModel.abft_energy_pj) must agree exactly with
    the scalar pricing (energy.abft_matmul_cost) — the two encode the
    same fused-checksum accounting in different files."""
    import random

    from repro.core.costmodel import BatchedCostModel
    from repro.core.energy import CostTable, abft_energy_pj, abft_matmul_cost
    from repro.core.loopnest import matmul_nest
    from repro.core.schedule import MemLevel, Schedule

    def splits(rng, bound, n):
        out, rem = [], bound
        for _ in range(n - 1):
            f = rng.choice([d for d in range(1, rem + 1) if rem % d == 0])
            out.append(f)
            rem //= f
        out.append(rem)
        return tuple(out)

    rng = random.Random(77)
    levels = (
        MemLevel("RF", None, double_buffered=False, per_pe=True),
        MemLevel("BUF", None),
        MemLevel("DRAM", None),
    )
    table = CostTable.for_levels(levels)
    for _ in range(10):
        M = rng.choice([32, 64, 96, 128])
        N = rng.choice([64, 128, 256])
        K = rng.choice([64, 128, 256])
        nest = matmul_nest("mm", M=M, N=N, K=K)
        scheds = [
            Schedule(
                nest=nest,
                levels=levels,
                tiling={
                    d: splits(rng, nest.bounds[d], 3) for d in nest.dims
                },
                order=tuple(
                    tuple(rng.sample(list(nest.dims), len(nest.dims)))
                    for _ in range(3)
                ),
            )
            for _ in range(4)
        ]
        cm = BatchedCostModel(nest, levels)
        til, _ = cm.pack(scheds)
        got = cm.abft_energy_pj(til)
        m_i = cm.dims.index("M")
        for j in range(len(scheds)):
            t_outer = max(int(til[j, -1, m_i]), 1)
            bm = max(-(-M // t_outer), 1)
            want = abft_energy_pj(abft_matmul_cost(M, N, K, bm), table)
            assert got[j] == want
