"""Decode-path correctness: prefill+decode_step must reproduce the
teacher-forced forward logits for every family (KV rings, recurrent states,
cross-attention caches)."""

import jax
import jax.numpy as jnp
import pytest

from repro.arch import layers as L
from repro.arch.model_zoo import build
from repro.configs.registry import ARCHS, get

TOL = 0.06  # bf16 accumulation noise


@pytest.mark.slow
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_matches_forward(arch):
    key = jax.random.PRNGKey(0)
    cfg = get(arch + "-smoke")
    model = build(cfg)
    params = model.init(key)
    B, S = 2, 12
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)

    if cfg.family == "encdec":
        frames = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model)
        ).astype(jnp.bfloat16)
        enc = model.encode(params, frames)
        x = L.embed(params["embed"], toks)
        xx, _ = model._decoder(params, x, enc, jnp.arange(S), None, False)
        full = L.unembed(
            params["embed"], L.rmsnorm(params["final_ln"], xx, cfg.norm_eps)
        )
        caches = model.init_caches(B, 32)
        _, state = model.prefill(params, frames, toks[:, : S - 1], caches)
        got, _ = model.decode_step(params, toks[:, S - 1 : S], state)
    elif cfg.family == "vlm":
        patches = jax.random.normal(
            key, (B, cfg.n_patches, cfg.patch_dim)
        ).astype(jnp.bfloat16)
        px = patches @ params["patch_proj"]
        x = jnp.concatenate([px, L.embed(params["embed"], toks)], axis=1)
        full, _, _ = model.logits_fn(params, x)
        caches = model.init_caches(B, 64)
        _, caches = model.prefill(
            params, toks[:, : S - 1], caches, patches=patches
        )
        got, _ = model.decode_step(params, toks[:, S - 1 : S], caches)
    else:
        x = L.embed(params["embed"], toks)
        full, _, _ = model.logits_fn(params, x)
        caches = model.init_caches(B, 32)
        _, caches = model.prefill(params, toks[:, : S - 1], caches)
        got, _ = model.decode_step(params, toks[:, S - 1 : S], caches)

    err = float(
        jnp.max(
            jnp.abs(
                got.astype(jnp.float32) - full[:, -1].astype(jnp.float32)
            )
        )
    )
    assert err < TOL, f"{arch}: decode diverges from forward by {err}"


def test_ring_cache_window_semantics():
    """A ring cache of size W must attend over exactly the last W tokens."""
    key = jax.random.PRNGKey(1)
    cfg = get("gemma3-12b-smoke")  # window 8
    model = build(cfg)
    params = model.init(key)
    B, S = 1, 20  # > 2x window: the ring has wrapped
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    x = L.embed(params["embed"], toks)
    full, _, _ = model.logits_fn(params, x)
    caches = model.init_caches(B, 64)
    _, caches = model.prefill(params, toks[:, : S - 1], caches)
    got, _ = model.decode_step(params, toks[:, S - 1 : S], caches)
    err = float(
        jnp.max(jnp.abs(got.astype(jnp.float32) - full[:, -1].astype(jnp.float32)))
    )
    assert err < TOL


def test_multistep_decode_consistency():
    key = jax.random.PRNGKey(2)
    cfg = get("granite-8b-smoke")
    model = build(cfg)
    params = model.init(key)
    B, S, n_dec = 2, 10, 4
    toks = jax.random.randint(key, (B, S + n_dec), 0, cfg.vocab)
    x = L.embed(params["embed"], toks)
    full, _, _ = model.logits_fn(params, x)
    caches = model.init_caches(B, 32)
    _, caches = model.prefill(params, toks[:, :S], caches)
    for i in range(n_dec):
        got, caches = model.decode_step(params, toks[:, S + i : S + i + 1], caches)
        ref = full[:, S + i - 1 + 1]  # logits after consuming token S+i
        err = float(jnp.max(jnp.abs(got.astype(jnp.float32) - ref.astype(jnp.float32))))
        assert err < TOL, f"step {i}: {err}"
