"""Continuous-batching serve engine: slot admission/eviction/backfill,
truncation, determinism, and the slot-cache primitives."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.arch.model_zoo import build
from repro.configs.registry import get
from repro.serve import kvcache
from repro.serve.engine import (
    DurabilityConfig,
    Engine,
    KernelConfig,
    KVConfig,
    Request,
    RequestResult,
    RequestStatus,
    SchedulerConfig,
    ServeConfig,
    StaticEngine,
)


@pytest.fixture(scope="module")
def smol():
    cfg = get("smollm-360m-smoke")
    params = build(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _reqs(cfg, spec, seed=0):
    rng = np.random.default_rng(seed)
    return [
        Request(
            rng.integers(0, cfg.vocab, n).astype(np.int32),
            max_new_tokens=m,
            request_id=i,
        )
        for i, (n, m) in enumerate(spec)
    ]


def test_empty_request_list(smol):
    cfg, params = smol
    eng = Engine(cfg, params, ServeConfig(batch=2, max_len=32))
    assert eng.run([]) == []


def test_slot_exhaustion_backfill_ordering(smol):
    """5 requests through 2 slots: admissions stay FIFO and never exceed
    completions + slot count (a request only enters when a slot frees)."""
    cfg, params = smol
    eng = Engine(cfg, params, ServeConfig(batch=2, max_len=32))
    reqs = _reqs(cfg, [(5, 4), (7, 6), (3, 3), (6, 5), (4, 4)])
    admitted, completed = [], []

    def on_token(rid, tok, idx, done):
        if idx == 0:
            admitted.append(rid)
            assert len(admitted) <= len(completed) + 2, (
                "admitted a request with no free slot"
            )
        if done:
            completed.append(rid)

    outs = eng.run(reqs, on_token=on_token)
    assert admitted == [0, 1, 2, 3, 4]  # FIFO backfill
    assert sorted(completed) == [0, 1, 2, 3, 4]
    assert [len(o) for o in outs] == [4, 6, 3, 5, 4]


def test_out_of_order_completion(smol):
    """A short request finishes first; its slot is backfilled while the
    long request keeps decoding."""
    cfg, params = smol
    eng = Engine(cfg, params, ServeConfig(batch=2, max_len=48))
    reqs = _reqs(cfg, [(5, 12), (5, 2), (5, 3)])
    events = []
    outs = eng.run(
        reqs,
        on_token=lambda rid, tok, idx, done: events.append((rid, idx, done)),
    )
    done_order = [rid for rid, _, done in events if done]
    assert done_order == [1, 2, 0]
    # request 2 was admitted strictly before request 0 finished
    admit_2 = events.index((2, 0, False))
    done_0 = events.index((0, 11, True))
    assert admit_2 < done_0
    assert [len(o) for o in outs] == [12, 2, 3]


def test_max_new_tokens_and_max_len_truncation(smol):
    cfg, params = smol
    scfg = ServeConfig(batch=2, max_len=16)
    eng = Engine(cfg, params, scfg)
    rng = np.random.default_rng(3)
    long_prompt = rng.integers(0, cfg.vocab, 30).astype(np.int32)
    outs = eng.run(
        [
            # prompt 10 + max_new 20 > max_len 16: generation stops at 6
            Request(rng.integers(0, cfg.vocab, 10).astype(np.int32), 20),
            # prompt 30 >= max_len: keeps the last 15 tokens, 1-token budget
            Request(long_prompt, 20),
            Request(rng.integers(0, cfg.vocab, 4).astype(np.int32), 3),
        ]
    )
    assert [len(o) for o in outs] == [6, 1, 3]
    # the truncated prompt behaves exactly like its explicit suffix
    solo = Engine(cfg, params, scfg).run([Request(long_prompt[-15:], 20)])
    assert np.array_equal(solo[0], outs[1])


def test_nonpositive_budget_returns_empty(smol):
    cfg, params = smol
    eng = Engine(cfg, params, ServeConfig(batch=2, max_len=32))
    outs = eng.run(
        [Request(np.array([1, 2, 3], np.int32), 0), Request(np.array([4], np.int32), 2)]
    )
    assert outs[0].shape == (0,)
    assert outs[1].shape == (2,)


def test_bitwise_determinism_across_arrival_order(smol):
    """Fixed seed + explicit request ids: outputs are bitwise identical
    whatever the submission order, slot count, or prefill bucketing."""
    cfg, params = smol
    spec = [(5, 6), (12, 9), (3, 4), (7, 5), (9, 8), (4, 7)]
    base = _reqs(cfg, spec, seed=5)

    def run(order, batch, bucket=0):
        eng = Engine(
            cfg,
            params,
            ServeConfig(
                batch=batch,
                max_len=64,
                temperature=0.8,
                seed=11,
                prefill_bucket=bucket,
            ),
        )
        outs = eng.run([base[i] for i in order])
        return {order[j]: outs[j].tolist() for j in range(len(order))}

    a = run([0, 1, 2, 3, 4, 5], 3)
    b = run([5, 2, 0, 4, 1, 3], 3)
    c = run([0, 1, 2, 3, 4, 5], 2)
    d = run([3, 1, 5, 0, 2, 4], 4, bucket=16)
    assert a == b == c == d


def test_slot_isolation_matches_solo_run(smol):
    """A request's tokens don't depend on its batch-mates (per-slot cache
    independence) — continuous batched output == solo output, bitwise."""
    cfg, params = smol
    scfg = ServeConfig(batch=3, max_len=64, temperature=0.7, seed=2)
    reqs = _reqs(cfg, [(5, 8), (12, 16), (3, 4), (7, 6), (9, 12)], seed=1)
    outs = Engine(cfg, params, scfg).run(reqs)
    for i in (0, 2, 4):
        solo = Engine(cfg, params, scfg).run([reqs[i]])[0]
        assert np.array_equal(solo, outs[i]), f"request {i} not isolated"


def test_greedy_matches_static_engine(smol):
    """Greedy continuous output == the static-batch baseline when the
    static batch needs no left-padding (equal prompt lengths)."""
    cfg, params = smol
    scfg = ServeConfig(batch=2, max_len=48)
    reqs = _reqs(cfg, [(6, 5), (6, 7), (6, 4), (6, 6)], seed=2)
    cont = Engine(cfg, params, scfg).run(reqs)
    stat = StaticEngine(cfg, params, scfg).generate(reqs)
    for c, s in zip(cont, stat):
        assert np.array_equal(c, s)


@pytest.mark.parametrize(
    "arch", ["gemma3-12b", "rwkv6-1.6b", "recurrentgemma-2b", "granite-moe-1b-a400m"]
)
def test_families_slot_isolation(arch):
    """Ring-buffer, recurrent, hybrid and MoE caches all survive slot
    admission/eviction: batched output == solo output, bitwise."""
    cfg = get(arch + "-smoke")
    params = build(cfg).init(jax.random.PRNGKey(0))
    scfg = ServeConfig(batch=2, max_len=32, temperature=0.5, seed=3)
    rng = np.random.default_rng(4)
    reqs = [
        Request(rng.integers(0, cfg.vocab, n).astype(np.int32), m, request_id=i)
        for i, (n, m) in enumerate([(6, 5), (9, 7), (4, 4)])
    ]
    outs = Engine(cfg, params, scfg).run(reqs)
    solo = Engine(cfg, params, scfg).run([reqs[1]])[0]
    assert np.array_equal(solo, outs[1])
    assert [len(o) for o in outs] == [5, 7, 4]


def test_engine_rejects_encdec():
    cfg = get("whisper-medium-smoke")
    with pytest.raises(ValueError):
        Engine(cfg, None, ServeConfig())


def test_attention_substrates_agree(smol):
    """Flash-decoding engine output == masked-oracle engine output (greedy):
    the ragged kernel path is a substrate swap, not a semantics change."""
    cfg, params = smol
    reqs = _reqs(cfg, [(5, 8), (12, 6), (3, 10), (7, 5)], seed=7)
    flash = Engine(
        cfg, params, ServeConfig(batch=2, max_len=48, attention="flash")
    ).run(reqs)
    oracle = Engine(
        cfg, params, ServeConfig(batch=2, max_len=48, attention="xla")
    ).run(reqs)
    for f, o in zip(flash, oracle):
        assert np.array_equal(f, o)


def test_decode_buffers_donated(smol):
    """The decode loop must update the KV caches in place: every cache
    buffer keeps its address across steps (donation aliased the pytree,
    no per-step copy)."""
    cfg, params = smol
    eng = Engine(cfg, params, ServeConfig(batch=2, max_len=32))
    rng = np.random.default_rng(9)
    for i in range(2):
        eng.submit(
            Request(
                rng.integers(0, cfg.vocab, 6).astype(np.int32), 8, request_id=i
            )
        )
    eng.step()  # admission + first decode step (compiles)
    eng.step()  # warm steady-state step
    before = sorted(
        leaf.unsafe_buffer_pointer() for leaf in jax.tree.leaves(eng.caches)
    )
    eng.step()
    after = sorted(
        leaf.unsafe_buffer_pointer() for leaf in jax.tree.leaves(eng.caches)
    )
    assert before == after, "decode step re-allocated donated KV buffers"
    while eng.step():
        pass


# ---------------------------------------------------- request lifecycle --


def test_cancel_in_every_state(smol):
    """cancel() dequeues WAITING requests, evicts ACTIVE ones (slot frees
    for backfill), no-ops on terminal/unknown ids, and keeps partial
    tokens retrievable."""
    cfg, params = smol
    scfg = ServeConfig(batch=1, max_len=32)
    eng = Engine(cfg, params, scfg)
    rng = np.random.default_rng(17)
    prompts = [rng.integers(0, cfg.vocab, 6).astype(np.int32) for _ in range(3)]
    for i, p in enumerate(prompts):
        eng.submit(Request(p, 8, request_id=i))
    eng.step()  # 0 active; 1, 2 waiting
    assert eng.status(0) == RequestStatus.ACTIVE
    assert eng.cancel(1) == RequestStatus.CANCELLED  # waiting-state
    eng.step()
    eng.step()
    assert eng.cancel(0) == RequestStatus.CANCELLED  # active-state
    assert eng.status(0) == RequestStatus.CANCELLED
    eng.step()  # slot backfills with request 2
    assert eng.status(2) == RequestStatus.ACTIVE
    while eng.step():
        pass
    # partial tokens of the active-cancel are the oracle's prefix
    solo = Engine(cfg, params, scfg).run([Request(prompts[0], 8, request_id=0)])
    part = eng.pop_result(0)
    assert part.status == RequestStatus.CANCELLED
    assert 1 <= len(part) < 8
    assert np.array_equal(part.tokens, solo[0].tokens[: len(part)])
    assert len(eng.pop_result(1)) == 0
    assert eng.pop_result(2).status == RequestStatus.FINISHED
    assert eng.cancel(42) == RequestStatus.UNKNOWN


def test_deadline_expires_waiting_and_active(smol):
    """deadline_steps bounds a request's wall-step lifetime: expiry in the
    queue yields FAILED with no tokens; expiry while active evicts with
    the generated prefix intact (bitwise oracle prefix)."""
    cfg, params = smol
    scfg = ServeConfig(batch=1, max_len=32)
    eng = Engine(cfg, params, scfg)
    rng = np.random.default_rng(19)
    pa = rng.integers(0, cfg.vocab, 6).astype(np.int32)
    pb = rng.integers(0, cfg.vocab, 6).astype(np.int32)
    eng.submit(Request(pa, 10, request_id=0))           # hogs the only slot
    eng.submit(Request(pb, 10, request_id=1, deadline_steps=3))
    while eng.step():
        pass
    assert eng.status(0) == RequestStatus.FINISHED
    rb = eng.pop_result(1)
    assert rb.status == RequestStatus.FAILED and "queue" in rb.reason
    assert len(rb) == 0

    eng2 = Engine(cfg, params, scfg)
    eng2.submit(Request(pa, 10, request_id=0, deadline_steps=4))
    while eng2.step():
        pass
    ra = eng2.pop_result(0)
    assert ra.status == RequestStatus.FAILED and "active" in ra.reason
    assert 1 <= len(ra) < 10
    solo = Engine(cfg, params, scfg).run([Request(pa, 10, request_id=0)])[0]
    assert np.array_equal(ra.tokens, solo.tokens[: len(ra)])
    with pytest.raises(ValueError, match="deadline"):
        eng2.submit(Request(pa, 4, request_id=9, deadline_steps=-1))


def test_bounded_queue_rejects_overflow(smol):
    """max_waiting bounds the queue: overflow submissions terminate
    REJECTED immediately (no exception — poll the status), and everyone
    already queued still completes."""
    cfg, params = smol
    eng = Engine(cfg, params, ServeConfig(batch=1, max_len=32, max_waiting=2))
    rng = np.random.default_rng(31)
    rids = [
        eng.submit(Request(rng.integers(0, cfg.vocab, 5).astype(np.int32), 3,
                           request_id=i))
        for i in range(4)
    ]
    # slot not granted until step(): 0,1 queued; 2 hits the bound
    assert eng.status(rids[2]) == RequestStatus.REJECTED
    assert eng.status(rids[3]) == RequestStatus.REJECTED
    assert eng.stats["rejected"] == 2
    while eng.step():
        pass
    assert eng.pop_result(rids[0]).status == RequestStatus.FINISHED
    assert eng.pop_result(rids[1]).status == RequestStatus.FINISHED
    rej = eng.pop_result(rids[2])
    assert rej.status == RequestStatus.REJECTED and "queue full" in rej.reason
    assert len(rej) == 0


def test_watchdog_sheds_stalled_queue(smol):
    """Zero active slots + zero admission progress for stall_patience
    steps (here: the pool is externally drained) must shed the queue head
    REJECTED instead of livelocking."""
    cfg, params = smol
    eng = Engine(
        cfg,
        params,
        ServeConfig(
            batch=2,
            max_len=32,
            kv_layout="paged",
            block_size=16,
            stall_patience=3,
        ),
    )
    held = eng.pool.reserve(eng.pool.free_blocks)  # external pressure
    rng = np.random.default_rng(37)
    eng.submit(Request(rng.integers(0, cfg.vocab, 5).astype(np.int32), 4,
                       request_id=0))
    assert eng.step() and eng.status(0) == RequestStatus.WAITING
    assert eng.step() and eng.status(0) == RequestStatus.WAITING
    # third consecutive stalled step: the watchdog sheds the head and the
    # engine reports idle (queue drained by shedding)
    assert not eng.step()
    res = eng.pop_result(0)
    assert res.status == RequestStatus.REJECTED and "watchdog" in res.reason
    assert eng.stats["shed"] == 1
    assert not eng.step()  # queue empty: engine is idle again
    eng.pool.unreserve(held)
    eng.pool.assert_invariants({})
    assert eng.pool.free_blocks == eng.pool.num_blocks - 1


def test_priority_preemption_recovers_bitwise(smol):
    """A starved higher-priority arrival preempts the lowest-priority
    active request; the victim requeues, re-admits, replays its recorded
    tokens without re-emitting, and finishes bitwise identical to an
    uninterrupted run."""
    cfg, params = smol
    scfg = ServeConfig(batch=1, max_len=48, temperature=0.6, seed=13)
    eng = Engine(cfg, params, scfg)
    rng = np.random.default_rng(41)
    pl = rng.integers(0, cfg.vocab, 8).astype(np.int32)
    ph = rng.integers(0, cfg.vocab, 8).astype(np.int32)
    events = []
    cb = lambda rid, tok, idx, done: events.append((rid, idx, tok, done))
    eng.submit(Request(pl, 12, request_id=0, priority=0))
    eng.step(cb)
    eng.step(cb)  # low-prio holds the only slot, 2 tokens out
    eng.submit(Request(ph, 4, request_id=1, priority=5))
    while eng.step(cb):
        pass
    assert eng.stats["preempted"] == 1 and eng.stats["recovered"] == 1
    rl, rh = eng.pop_result(0), eng.pop_result(1)
    assert rh.status == RequestStatus.FINISHED and rh.preemptions == 0
    assert rl.status == RequestStatus.FINISHED and rl.preemptions == 1
    # the high-priority request finished before the victim resumed
    done_order = [rid for rid, _, _, done in events if done]
    assert done_order == [1, 0]
    # every token index of the victim was emitted exactly once (replay
    # suppressed re-emission), in order
    lo_idx = [idx for rid, idx, _, _ in events if rid == 0]
    assert lo_idx == list(range(12))
    # bitwise identical to the uninterrupted run
    solo = Engine(cfg, params, scfg).run([Request(pl, 12, request_id=0)])[0]
    assert np.array_equal(rl.tokens, solo.tokens)
    solo_h = Engine(cfg, params, scfg).run([Request(ph, 4, request_id=1)])[0]
    assert np.array_equal(rh.tokens, solo_h.tokens)


def test_equal_priority_never_preempts(smol):
    """Preemption requires STRICTLY higher priority — equal-priority
    arrivals wait their turn (no thrash)."""
    cfg, params = smol
    eng = Engine(cfg, params, ServeConfig(batch=1, max_len=32))
    rng = np.random.default_rng(43)
    eng.submit(Request(rng.integers(0, cfg.vocab, 5).astype(np.int32), 6,
                       request_id=0, priority=2))
    eng.step()
    eng.submit(Request(rng.integers(0, cfg.vocab, 5).astype(np.int32), 4,
                       request_id=1, priority=2))
    while eng.step():
        pass
    assert eng.stats["preempted"] == 0
    assert eng.pop_result(0).status == RequestStatus.FINISHED


def test_pop_result_typed_and_array_like(smol):
    """pop_result never raises: UNKNOWN for unseen/popped ids, a
    non-consuming snapshot for live ids, a consuming terminal result
    otherwise — and RequestResult quacks like the old raw token array."""
    cfg, params = smol
    eng = Engine(cfg, params, ServeConfig(batch=1, max_len=32))
    assert eng.pop_result(7).status == RequestStatus.UNKNOWN
    rng = np.random.default_rng(47)
    eng.submit(Request(rng.integers(0, cfg.vocab, 5).astype(np.int32), 4,
                       request_id=0))
    snap = eng.pop_result(0)  # live: snapshot, not consumed
    assert snap.status == RequestStatus.WAITING and len(snap) == 0
    eng.step()
    assert eng.pop_result(0).status == RequestStatus.ACTIVE
    while eng.step():
        pass
    res = eng.pop_result(0)
    assert res.status == RequestStatus.FINISHED
    # array-likeness: everything the pre-lifecycle callers did still works
    assert isinstance(res, RequestResult)
    assert res.shape == (4,) and len(res) == 4
    assert res.tolist() == list(res.tokens) and res[0] == res.tokens[0]
    assert np.array_equal(np.asarray(res), res.tokens)
    assert [int(t) for t in res] == res.tolist()
    # consumed: the id is free again
    assert eng.pop_result(0).status == RequestStatus.UNKNOWN
    eng.submit(Request(rng.integers(0, cfg.vocab, 5).astype(np.int32), 2,
                       request_id=0))
    while eng.step():
        pass
    assert eng.pop_result(0).status == RequestStatus.FINISHED


def test_serveconfig_lifecycle_validation():
    with pytest.raises(ValueError, match="batch"):
        ServeConfig(batch=0)
    with pytest.raises(ValueError, match="max_waiting"):
        ServeConfig(max_waiting=0)
    with pytest.raises(ValueError, match="stall_patience"):
        ServeConfig(stall_patience=0)
    with pytest.raises(ValueError, match="num_blocks"):
        ServeConfig(kv_layout="paged", max_len=64, block_size=16, num_blocks=1)
    with pytest.raises(ValueError, match="num_blocks"):
        ServeConfig(max_len=64, num_blocks=8)  # contiguous: meaningless
    with pytest.raises(ValueError, match="decode_block"):
        ServeConfig(
            kv_layout="paged", max_len=64, block_size=16, decode_block=32
        )
    # pinning decode_block == block_size is the documented oracle idiom
    ServeConfig(kv_layout="paged", max_len=64, block_size=16, decode_block=16)


# ------------------------------------------------ nested config / shims --


def test_flat_kwargs_map_to_nested_and_warn_once():
    with pytest.warns(DeprecationWarning) as rec:
        flat = ServeConfig(
            batch=3, max_len=64, kv_layout="paged", block_size=16,
            matmul="xla", snapshot_every=8,
        )
    assert len([w for w in rec if w.category is DeprecationWarning]) == 1
    nested = ServeConfig(
        max_len=64,
        scheduler=SchedulerConfig(batch=3),
        kv=KVConfig(layout="paged", block_size=16),
        kernel=KernelConfig(matmul="xla"),
        durability=DurabilityConfig(snapshot_every=8),
    )
    assert flat == nested
    # flat read-through properties keep the old spelling alive
    assert flat.batch == 3 and flat.kv_layout == "paged"
    assert flat.block_size == 16 and flat.snapshot_every == 8


def test_unknown_flat_kwarg_rejected():
    with pytest.raises(TypeError, match="blocksize"):
        ServeConfig(blocksize=16)


def test_nested_validation_is_eager():
    with pytest.raises(ValueError, match="prefill_chunk"):
        SchedulerConfig(prefill_chunk=-1)
    with pytest.raises(ValueError, match="token_budget"):
        ServeConfig(token_budget=64)  # only meaningful with chunked prefill
    with pytest.raises(ValueError, match="token_budget"):
        ServeConfig(prefill_chunk=16, token_budget=8)  # budget < one chunk
    with pytest.raises(ValueError, match="multiple of prefill_chunk"):
        ServeConfig(max_len=100, prefill_chunk=16)
    with pytest.raises(ValueError, match="kv_layout"):
        KVConfig(layout="bogus")
    with pytest.raises(ValueError, match="matmul"):
        KernelConfig(matmul="cuda")


def test_flat_replace_and_fingerprint_compat():
    from repro.serve.recovery import _scfg_fingerprint

    with pytest.warns(DeprecationWarning):
        flat = ServeConfig(batch=2, max_len=64, kv_layout="paged", block_size=16)
    nested = ServeConfig(
        max_len=64,
        scheduler=SchedulerConfig(batch=2),
        kv=KVConfig(layout="paged", block_size=16),
    )
    # old-flat and new-nested spellings of the same engine fingerprint equal
    assert _scfg_fingerprint(flat) == _scfg_fingerprint(nested)
    # dataclasses.replace with top-level and (shimmed) flat keys still works
    assert dataclasses.replace(nested, seed=5).seed == 5
    with pytest.warns(DeprecationWarning):
        r = dataclasses.replace(nested, stall_patience=7)
    assert r.stall_patience == 7 and r.kv == nested.kv
    # chunking is pure scheduling: the bitwise stream (and so the snapshot
    # fingerprint) is unchanged
    chunked = dataclasses.replace(
        nested, scheduler=SchedulerConfig(batch=2, prefill_chunk=16)
    )
    assert _scfg_fingerprint(chunked) == _scfg_fingerprint(nested)


def test_request_dataclass_and_kwargs_shim():
    p = np.asarray([1, 2, 3], np.int32)
    r = Request(p, max_new_tokens=5)
    assert r.max_new == 5 and r.max_new_tokens == 5
    assert Request(p).max_new == 16
    with pytest.raises(TypeError, match="max_new"):
        Request(p, max_new=4, max_new_tokens=5)
    with pytest.raises(dataclasses.FrozenInstanceError):
        r.max_new = 9


# ------------------------------------- unified scheduler (chunked prefill) --


@pytest.mark.parametrize("layout", ["contiguous", "paged"])
def test_chunked_prefill_bitwise_vs_monolithic(smol, layout):
    """The tentpole invariant: chunked prefill under any (chunk, budget) is
    pure scheduling — outputs agree bitwise with monolithic admission (the
    degenerate prefill_chunk=0 engine), per layout."""
    cfg, params = smol
    kv = KVConfig(layout=layout, block_size=16) if layout == "paged" \
        else KVConfig()
    base = dict(max_len=64, temperature=0.8, seed=11, kv=kv)
    spec = [(5, 6), (37, 9), (3, 4), (23, 5), (58, 4), (12, 7)]
    mono = Engine(
        cfg, params, ServeConfig(scheduler=SchedulerConfig(batch=3), **base)
    ).run(_reqs(cfg, spec, seed=5))
    assert all(m.status == RequestStatus.FINISHED for m in mono)
    # chunk >= longest prompt with no budget degenerates to monolithic;
    # chunk=8 at budget=8 is maximal interleaving (one chunk per step)
    for chunk, budget in ((8, 8), (16, 32), (64, None)):
        outs = Engine(
            cfg,
            params,
            ServeConfig(
                scheduler=SchedulerConfig(
                    batch=3, prefill_chunk=chunk, token_budget=budget
                ),
                **base,
            ),
        ).run(_reqs(cfg, spec, seed=5))
        for i, (m, c) in enumerate(zip(mono, outs)):
            assert c.status == RequestStatus.FINISHED
            assert np.array_equal(m.tokens, c.tokens), (
                f"chunk={chunk} budget={budget} rid {i}: "
                f"{c.tolist()} != monolithic {m.tolist()}"
            )


def test_prefilling_status_observable_and_ttft(smol):
    """A budget-bound long prompt is observable PREFILLING (non-consuming
    pop_result snapshot included) for exactly ceil(plen/chunk) steps, and
    ttft_steps reports submit->first-token in engine steps."""
    cfg, params = smol
    eng = Engine(
        cfg,
        params,
        ServeConfig(
            max_len=64,
            scheduler=SchedulerConfig(batch=2, prefill_chunk=8, token_budget=8),
        ),
    )
    rng = np.random.default_rng(23)
    rid = eng.submit(Request(rng.integers(0, cfg.vocab, 40).astype(np.int32), 4))
    seen = 0
    while eng.status(rid) in (RequestStatus.WAITING, RequestStatus.PREFILLING):
        snap = eng.pop_result(rid)  # live snapshot, not consumed
        assert len(snap) == 0
        eng.step()
        if eng.status(rid) == RequestStatus.PREFILLING:
            seen += 1
    assert seen == 4  # ceil(40/8) = 5 chunks, ACTIVE right after the 5th
    while eng.step():
        pass
    res = eng.pop_result(rid)
    assert res.status == RequestStatus.FINISHED
    assert res.ttft_steps == 5 and len(res) == 4


def test_preempt_mid_prefill_recovers_bitwise(smol):
    """An interactive arrival takes the lane between chunks: the bulk
    victim drops its half-built scratch (blocks released, zero tokens
    emitted), requeues, re-prefills later, and still finishes bitwise
    identical to an undisturbed run."""
    cfg, params = smol
    scfg = ServeConfig(
        max_len=64,
        temperature=0.7,
        seed=13,
        scheduler=SchedulerConfig(batch=1, prefill_chunk=8, token_budget=8),
        kv=KVConfig(layout="paged", block_size=16),
    )
    eng = Engine(cfg, params, scfg)
    rng = np.random.default_rng(29)
    bulk = rng.integers(0, cfg.vocab, 40).astype(np.int32)
    inter = rng.integers(0, cfg.vocab, 6).astype(np.int32)
    eng.submit(Request(bulk, 5, request_id=0, priority=0))
    eng.step()  # one 8-token chunk in
    assert eng.status(0) == RequestStatus.PREFILLING
    eng.submit(Request(inter, 3, request_id=1, priority=5))
    eng.step()  # priority takeover at the chunk boundary
    assert eng.status(0) == RequestStatus.PREEMPTED
    assert eng.status(1) in (RequestStatus.PREFILLING, RequestStatus.ACTIVE)
    while eng.step():
        pass
    r0, r1 = eng.pop_result(0), eng.pop_result(1)
    assert r1.status == RequestStatus.FINISHED and r1.preemptions == 0
    assert r0.status == RequestStatus.FINISHED and r0.preemptions == 1
    solo = Engine(cfg, params, scfg).run([Request(bulk, 5, request_id=0)])[0]
    assert np.array_equal(r0.tokens, solo.tokens)
    solo1 = Engine(cfg, params, scfg).run([Request(inter, 3, request_id=1)])[0]
    assert np.array_equal(r1.tokens, solo1.tokens)
    assert eng.pool.free_blocks == eng.pool.num_blocks - 1, "leaked blocks"


def test_cancel_and_deadline_mid_prefill(smol):
    """cancel() and deadline expiry both reach a PREFILLING request: the
    lane drops with zero tokens, blocks return to the pool, and the slot
    backfills."""
    cfg, params = smol
    scfg = ServeConfig(
        max_len=64,
        scheduler=SchedulerConfig(batch=1, prefill_chunk=8, token_budget=8),
        kv=KVConfig(layout="paged", block_size=16),
    )
    eng = Engine(cfg, params, scfg)
    rng = np.random.default_rng(31)
    long_p = rng.integers(0, cfg.vocab, 40).astype(np.int32)
    eng.submit(Request(long_p, 4, request_id=0))
    eng.step()
    assert eng.status(0) == RequestStatus.PREFILLING
    assert eng.cancel(0) == RequestStatus.CANCELLED
    assert len(eng.pop_result(0)) == 0
    assert eng.pool.free_blocks == eng.pool.num_blocks - 1

    eng.submit(Request(long_p, 4, request_id=1, deadline_steps=2))
    while eng.step():
        pass
    res = eng.pop_result(1)
    assert res.status == RequestStatus.FAILED
    assert "prefilling" in res.reason
    assert eng.pool.free_blocks == eng.pool.num_blocks - 1


def test_per_request_seed_and_on_token(smol):
    """Request.seed overrides the engine seed for that request's sampling
    chain (engine-seed-independent), and Request.on_token streams tokens
    without a step-level callback."""
    cfg, params = smol
    rng = np.random.default_rng(37)
    p = rng.integers(0, cfg.vocab, 9).astype(np.int32)

    def out(engine_seed, req_seed):
        scfg = ServeConfig(
            max_len=64, temperature=0.9, seed=engine_seed,
            scheduler=SchedulerConfig(batch=1),
        )
        return Engine(cfg, params, scfg).run(
            [Request(p, 12, request_id=0, seed=req_seed)]
        )[0].tolist()

    base = out(3, None)
    assert out(3, 3) == base          # explicit seed == engine default
    assert out(99, 3) == base         # request seed wins over engine seed
    assert out(3, 4) != base          # different seed, different stream

    events = []
    eng = Engine(
        cfg, params,
        ServeConfig(max_len=64, scheduler=SchedulerConfig(batch=1)),
    )
    eng.submit(Request(
        p, 4, request_id=0,
        on_token=lambda rid, tok, idx, done: events.append((rid, idx, done)),
    ))
    while eng.step():
        pass
    assert [e[1] for e in events] == [0, 1, 2, 3]
    assert events[-1][2] and all(rid == 0 for rid, _, _ in events)


# ----------------------------------------------------- kvcache primitives --


def test_slot_store_take_roundtrip():
    cfg = get("recurrentgemma-2b-smoke")  # hybrid: deepest axis variety
    axes = kvcache.slot_axes(cfg, 16)
    big = kvcache.build_caches(cfg, 3, 16)
    small = jax.tree.map(
        lambda leaf, ax: jnp.ones_like(
            jax.lax.dynamic_slice_in_dim(leaf, 0, 1, axis=ax)
        ),
        big,
        axes,
    )
    big2 = kvcache.slot_store(big, small, jnp.int32(1), axes)
    got = kvcache.take_slot(big2, 1, axes)
    assert all(
        bool(jnp.all(a == b))
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(small))
    )
    # other slots untouched
    other = kvcache.take_slot(big2, 0, axes)
    ref = kvcache.take_slot(big, 0, axes)
    assert all(
        bool(jnp.all(a == b))
        for a, b in zip(jax.tree.leaves(other), jax.tree.leaves(ref))
    )


def test_mask_prompt_tail_per_row():
    cfg = get("smollm-360m-smoke")
    caches = kvcache.build_caches(cfg, 2, 8)
    # pretend a padded prefill filled all 8 positions on both rows
    caches = jax.tree_util.tree_map_with_path(
        lambda p, leaf: (
            jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32), leaf.shape)
            if kvcache._leaf_name(p) == "pos"
            else leaf
        ),
        caches,
    )
    fixed = kvcache.mask_prompt_tail(caches, jnp.asarray([3, 5]))

    def leafdict(tree):
        return {
            kvcache._leaf_name(p): leaf
            for p, leaf in jax.tree_util.tree_leaves_with_path(tree)
        }

    pos = leafdict(fixed)["pos"]  # (layers, 2, 8)
    assert np.array_equal(np.asarray(pos[0, 0]), [0, 1, 2] + [10**9] * 5)
    assert np.array_equal(np.asarray(pos[0, 1]), [0, 1, 2, 3, 4] + [10**9] * 3)
    assert np.array_equal(np.asarray(leafdict(fixed)["len"][0]), [3, 5])


def test_supports_padded_prefill_matrix():
    assert kvcache.supports_padded_prefill(get("smollm-360m-smoke"))
    assert not kvcache.supports_padded_prefill(get("gemma3-12b-smoke"))
    assert not kvcache.supports_padded_prefill(get("rwkv6-1.6b-smoke"))
    assert not kvcache.supports_padded_prefill(get("granite-moe-1b-a400m-smoke"))


# ------------------------------------------------- RequestResult protocol --


def test_request_result_array_protocol():
    """Legacy-caller compatibility contract: pre-lifecycle code treated
    pop_result's return as a raw token array, so the typed result must
    keep behaving like one."""
    toks = np.asarray([5, 9, 2], np.int32)
    res = RequestResult(RequestStatus.FINISHED, toks, reason="", preemptions=1)
    # __array__: dtype passthrough, dtype coercion, and copy semantics
    arr = np.asarray(res)
    assert arr.dtype == np.int32 and np.array_equal(arr, toks)
    assert np.asarray(res, np.int64).dtype == np.int64
    copied = np.array(res, copy=True)
    copied[0] = -1
    assert res.tokens[0] == 5, "__array__(copy=True) must not alias tokens"
    # sized/iterable/indexable surface
    assert len(res) == 3
    assert list(res) == [5, 9, 2]
    assert res[1] == 9 and res[-1] == 2
    assert res.shape == (3,)
    assert res.tolist() == [5, 9, 2] and type(res.tolist()[0]) is int
    # elementwise ordering dunders, the `(out >= 0).all()` idiom
    assert (res >= 0).all() and not (res < 0).any()
    assert np.array_equal(res > 4, [True, True, False])
    assert np.array_equal(res <= 5, [True, False, True])
    assert np.array_equal(res, toks)


def test_request_result_empty_and_numpy_interop():
    res = RequestResult(RequestStatus.REJECTED, np.zeros((0,), np.int32))
    assert len(res) == 0 and res.tolist() == [] and res.shape == (0,)
    assert (res >= 0).all()  # vacuous truth, but must not raise
    full = RequestResult(RequestStatus.FINISHED, np.asarray([1, 2], np.int32))
    assert int(np.sum(full)) == 3  # reductions go through __array__
    assert np.concatenate([full, full]).tolist() == [1, 2, 1, 2]
