"""Differential tests: the vectorized exact simulator vs the odometer.

The vector engine derives reload counts from the mixed-radix structure of
the loop nest (simulate.py module docstring); the per-iteration odometer is
the semantic definition.  They must agree *bit-exactly* on every schedule —
counts are integers.  Randomized property sweep in the style of
tests/test_costmodel.py (pure `random`, no hypothesis dependency).
"""

import importlib
import random

from repro.core.loopnest import conv_nest, divisors, fc_nest, matmul_nest
from repro.core.reuse import analyze
from repro.core.schedule import MemLevel, Schedule
from repro.core.simulate import simulate

# repro.core re-exports the simulate *function*; fetch the module for
# monkeypatching its guard constant
sim = importlib.import_module("repro.core.simulate")


def _rand_splits(rng, bound, n):
    out = []
    rem = bound
    for _ in range(n - 1):
        f = rng.choice(divisors(rem))
        out.append(f)
        rem //= f
    out.append(rem)
    return tuple(out)


def _random_schedule(rng) -> Schedule:
    kind = rng.choice(["conv", "mm", "fc"])
    if kind == "conv":
        nest = conv_nest(
            "r",
            B=rng.choice([1, 2]), K=rng.choice([1, 2, 4]),
            C=rng.choice([1, 2, 3]), X=rng.choice([1, 2, 4]),
            Y=rng.choice([1, 2]), FX=rng.choice([1, 3]),
            FY=rng.choice([1, 2]), stride=rng.choice([1, 2]),
        )
    elif kind == "mm":
        nest = matmul_nest(
            "r", M=rng.choice([2, 4]), N=rng.choice([2, 4]),
            K=rng.choice([2, 8]),
        )
    else:
        nest = fc_nest("r", B=2, C=4, K=4)
    L = rng.choice([2, 3, 4])
    ppe = rng.choice([0, 1]) if L >= 3 else 0
    levels = tuple(
        MemLevel(f"L{i}", None, double_buffered=False, per_pe=(i < ppe))
        for i in range(L)
    )
    tiling = {d: _rand_splits(rng, nest.bounds[d], L) for d in nest.dims}
    orders = tuple(
        tuple(rng.sample(list(nest.dims), len(nest.dims))) for _ in range(L)
    )
    return Schedule(nest=nest, levels=levels, tiling=tiling, order=orders)


def test_vector_matches_odometer_randomized():
    """Property sweep: AccessCounts equality on every field."""
    rng = random.Random(20260728)
    for _ in range(150):
        s = _random_schedule(rng)
        assert simulate(s, engine="vector") == simulate(s, engine="scalar")


def test_default_engine_is_vector_and_matches_analytical():
    """The default engine must stay consistent with the analytical model
    (the repo's Fig-7 analogue) on a full-size layer the odometer could
    never walk (~10^8 iterations)."""
    nest = conv_nest("big", B=4, K=64, C=64, X=28, Y=28, FX=3, FY=3)
    levels = (
        MemLevel("RF", None, double_buffered=False, per_pe=True),
        MemLevel("BUF", None),
        MemLevel("DRAM", None),
    )
    tiling = {
        "B": (1, 2, 2), "K": (4, 4, 4), "C": (2, 4, 8), "X": (2, 7, 2),
        "Y": (4, 7, 1), "FX": (3, 1, 1), "FY": (1, 3, 1),
    }
    order = (
        ("C", "FX", "FY", "K", "B", "X", "Y"),
        ("K", "X", "C", "B", "Y", "FX", "FY"),
        ("B", "K", "C", "X", "Y", "FX", "FY"),
    )
    s = Schedule(nest=nest, levels=levels, tiling=tiling, order=order)
    assert s.temporal_trips() > 10 ** 7
    a = analyze(s)
    v = simulate(s)  # default engine
    assert v.reads == a.reads
    assert v.writes == a.writes


def test_bigint_path_matches_numpy_path(monkeypatch):
    """Schedules past the int64 guard take the Python big-int path; force it
    low and check both paths agree."""
    rng = random.Random(7)
    for _ in range(40):
        s = _random_schedule(rng)
        fast = simulate(s, engine="vector")
        monkeypatch.setattr(sim, "_INT64_SAFE_ITERS", 1)
        big = simulate(s, engine="vector")
        monkeypatch.undo()
        assert fast == big


def test_huge_bounds_stay_exact():
    """Counts beyond int64 range must come out exact (Python ints)."""
    nest = matmul_nest("huge", M=2 ** 30, N=2 ** 30, K=2 ** 30)
    levels = (
        MemLevel("BUF", None, double_buffered=False),
        MemLevel("DRAM", None),
    )
    tiling = {d: (2 ** 15, 2 ** 15) for d in nest.dims}
    order = (("M", "N", "K"), ("K", "M", "N"))
    s = Schedule(nest=nest, levels=levels, tiling=tiling, order=order)
    assert s.temporal_trips() > sim._INT64_SAFE_ITERS  # takes the bigint path
    acc = simulate(s)
    a = analyze(s)
    assert acc.reads == a.reads and acc.writes == a.writes
    # level-0 streams of A re-load every trip here: far beyond int64 range
    assert acc.reads[0]["A"] == a.reads[0]["A"] > 2 ** 63
