"""Optimizer-level tests: Obs-2 ratio-band pruning, search memoization
across the hardware sweep, and the end-to-end regression that the rewritten
(batched + pruned + deduped) search returns the same best energies as the
seed scalar implementation.
"""

import pytest

from repro.core.loopnest import conv_nest, fc_nest
from repro.core.optimizer import (
    BUF_CHOICES,
    RF_CHOICES,
    HardwareConfig,
    _SEARCH_CACHE,
    candidate_hierarchies,
    clear_search_cache,
    evaluate_network,
    optimize_layer,
    optimize_network,
)
from repro.core.schedule import ArraySpec

ARR16 = ArraySpec(dims=(16, 16))


# ------------------------------------------------------------ Obs-2 pruning


def test_ratio_band_actually_prunes():
    """The buf/total-RF band must be enforced on both sides (the seed's
    filter was a tautology and never pruned)."""
    cands = candidate_hierarchies(ARR16, two_level_rf=False)
    assert cands
    # strictly fewer than the unpruned cross product
    assert len(cands) < len(RF_CHOICES) * len(BUF_CHOICES)
    for hw in cands:
        ratio = hw.buffer_bytes[0] / (hw.rf_bytes[-1] * ARR16.num_pes)
        assert 4 <= ratio <= 16, hw.name


def test_ratio_band_candidate_counts():
    """Counts follow directly from the band arithmetic on the choice grids."""
    assert len(candidate_hierarchies(ARR16, two_level_rf=False)) == 14
    assert len(candidate_hierarchies(ARR16, two_level_rf=True)) == 32


def test_two_level_rf_band():
    for hw in candidate_hierarchies(ARR16, two_level_rf=True):
        if len(hw.rf_bytes) == 2:
            ratio = hw.rf_bytes[1] / hw.rf_bytes[0]
            assert 4 <= ratio <= 16


# ----------------------------------------------------------- memoization


def test_layer_search_memoized_across_sweep():
    clear_search_cache()
    arr = ArraySpec(dims=(4, 4))
    hw = HardwareConfig("hw", arr, rf_bytes=(64,), buffer_bytes=(32 * 1024,))
    a = conv_nest("a", B=1, K=8, C=8, X=8, Y=8, FX=3, FY=3)
    b = conv_nest("b", B=1, K=8, C=8, X=8, Y=8, FX=3, FY=3)  # same shape
    r1 = optimize_layer(a, hw, max_evals=0)
    n_after_first = len(_SEARCH_CACHE)
    r2 = optimize_layer(b, hw, max_evals=0)
    assert len(_SEARCH_CACHE) == n_after_first  # structural hit, no new entry
    assert r1.report.energy_pj == r2.report.energy_pj
    # different hierarchy -> new entry
    hw2 = HardwareConfig("hw2", arr, rf_bytes=(128,), buffer_bytes=(64 * 1024,))
    optimize_layer(a, hw2, max_evals=0)
    assert len(_SEARCH_CACHE) == n_after_first + 1
    clear_search_cache()


# ----------------------------------------------------------- regression


def test_optimize_network_matches_seed_energy():
    """End-to-end regression: the batched+pruned optimizer returns exactly
    the energies the seed scalar implementation produced on this net
    (captured from the pre-rewrite code with an unlimited eval budget)."""
    layers = [
        conv_nest("c1", B=1, K=8, C=8, X=8, Y=8, FX=3, FY=3),
        conv_nest("c2", B=1, K=16, C=8, X=8, Y=8, FX=3, FY=3),
        conv_nest("c1b", B=1, K=8, C=8, X=8, Y=8, FX=3, FY=3),
        fc_nest("f1", B=1, C=64, K=32),
    ]
    arr = ArraySpec(dims=(4, 4))
    hws = [
        HardwareConfig("hwA", arr, rf_bytes=(64,), buffer_bytes=(32 * 1024,)),
        HardwareConfig("hwB", arr, rf_bytes=(128,), buffer_bytes=(64 * 1024,)),
    ]
    clear_search_cache()
    res = optimize_network(layers, arr, hw_candidates=hws,
                           max_evals_per_layer=0)
    assert res.hw.name == "hwA"
    assert res.total_energy_pj == pytest.approx(1976486.24, abs=1e-6, rel=0)
    per_layer = [l.report.energy_pj for l in res.layers]
    assert per_layer == pytest.approx(
        [423484.16, 686968.32, 423484.16, 442549.6], abs=1e-6, rel=0
    )
    # the repeated c1 shape must have been solved once
    assert [l.report.energy_pj for l in res.layers][0] == per_layer[2]
    clear_search_cache()


def test_evaluate_network_budget_plumbed():
    """max_evals_per_layer reaches the search as a real budget."""
    layers = [conv_nest("c", B=1, K=16, C=16, X=8, Y=8, FX=3, FY=3)]
    arr = ArraySpec(dims=(4, 4))
    hw = HardwareConfig("hw", arr, rf_bytes=(64,), buffer_bytes=(32 * 1024,))
    clear_search_cache()
    full = evaluate_network(layers, hw, max_evals_per_layer=0)
    clear_search_cache()
    tight = evaluate_network(layers, hw, max_evals_per_layer=300)
    clear_search_cache()
    assert tight.total_energy_pj >= full.total_energy_pj
    assert tight.layers[0].report.schedule.fits()
