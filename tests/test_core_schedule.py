"""Unit tests: schedule validation, dataflow taxonomy, blocking search,
energy tables, optimizer pruning."""


import pytest

from repro.core import (
    ArraySpec,
    MemLevel,
    Schedule,
    conv_nest,
    enumerate_dataflows,
    evaluate,
    eyeriss_like,
    fc_nest,
    flat_schedule,
    make_dataflow,
    matmul_nest,
    optimize_layer,
    search_blocking,
)
from repro.core.blocking import iter_blockings
from repro.core.energy import asic_access_energy_pj
from repro.core.optimizer import candidate_hierarchies, ck_dataflow

LEVELS = (
    MemLevel("RF", 512, double_buffered=False, per_pe=True),
    MemLevel("BUF", 128 * 1024),
    MemLevel("DRAM", None),
)


# ----------------------------------------------------------------- schedule


def test_schedule_rejects_bad_tiling():
    nest = matmul_nest("mm", M=8, N=8, K=8)
    with pytest.raises(ValueError):
        Schedule(
            nest=nest,
            levels=LEVELS,
            tiling={"M": (1, 1, 4), "N": (1, 1, 8), "K": (1, 1, 8)},  # M short
            order=(("M", "N", "K"),) * 3,
        )


def test_schedule_rejects_nonprefix_per_pe():
    nest = matmul_nest("mm", M=2, N=2, K=2)
    bad = (
        MemLevel("A", None, per_pe=False),
        MemLevel("B", None, per_pe=True),
        MemLevel("C", None),
    )
    with pytest.raises(ValueError):
        Schedule(
            nest=nest, levels=bad,
            tiling={"M": (1, 1, 2), "N": (1, 1, 2), "K": (1, 1, 2)},
            order=(("M", "N", "K"),) * 3,
        )


def test_spatial_capacity_enforced():
    nest = conv_nest("t", B=1, K=64, C=64, X=4, Y=4, FX=1, FY=1)
    arr = ArraySpec(dims=(4, 4))
    with pytest.raises(ValueError):
        flat_schedule(
            nest, LEVELS, array=arr,
            spatial=[[("K", 8)], [("C", 4)]],  # 8 > 4 rows
        )


def test_footprint_halo():
    """Input tiles carry the sliding-window halo: (x + fx - 1)."""
    nest = conv_nest("t", B=1, K=1, C=1, X=8, Y=8, FX=3, FY=3)
    tile = {"B": 1, "K": 1, "C": 1, "X": 4, "Y": 4, "FX": 3, "FY": 3}
    assert nest.tensor("I").tile_elems(tile) == 6 * 6
    assert nest.tensor("W").tile_elems(tile) == 9
    assert nest.tensor("O").tile_elems(tile) == 16


def test_utilization_replication_paper_fig2():
    """Paper Fig 2: unrolling C=3 on a 16-dim alone -> 3/16 utilization;
    replicating X by 5 -> 15/16."""
    nest = conv_nest("t", B=1, K=8, C=3, X=55, Y=55, FX=3, FY=3)
    arr = ArraySpec(dims=(16,))
    lone = flat_schedule(nest, LEVELS, array=arr, spatial=[[("C", 3)]])
    repl = flat_schedule(nest, LEVELS, array=arr, spatial=[[("C", 3), ("X", 5)]])
    assert lone.utilization() == pytest.approx(3 / 16)
    assert repl.utilization() == pytest.approx(15 / 16)


# ----------------------------------------------------------------- dataflow


def test_dataflow_labels():
    nest = conv_nest("t", B=4, K=16, C=16, X=8, Y=8, FX=3, FY=3)
    arr = ArraySpec(dims=(16, 16))
    df = make_dataflow(nest, arr, ("C", "K"), replication=False)
    assert "C|K" in df.label()
    assert df.factor("C") == 16 and df.factor("K") == 16


def test_dataflow_enumeration_counts():
    """Unblocked CONV on a 2D array: up to L*(L-1) ordered primary pairs."""
    nest = conv_nest("t", B=4, K=16, C=16, X=8, Y=8, FX=3, FY=3)
    arr = ArraySpec(dims=(4, 4))
    dfs = enumerate_dataflows(nest, arr, replication=False)
    assert len(dfs) >= 21  # paper: C(7,2) unordered = 21
    labels = {d.label() for d in dfs}
    assert len(labels) == len(dfs)


def test_replication_fills_array():
    nest = conv_nest("t", B=1, K=8, C=3, X=50, Y=50, FX=3, FY=3)
    arr = ArraySpec(dims=(16, 16))
    df_no = make_dataflow(nest, arr, ("C", "K"), replication=False)
    df_yes = make_dataflow(nest, arr, ("C", "K"), replication=True)
    assert df_yes.used_pes() > df_no.used_pes()


# ----------------------------------------------------------------- blocking


def test_blocking_capacity_respected():
    nest = conv_nest("t", B=4, K=32, C=32, X=8, Y=8, FX=3, FY=3)
    arr = ArraySpec(dims=(4, 4))
    df = make_dataflow(nest, arr, ("C", "K"))
    res = search_blocking(nest, LEVELS, arr, df, beam=8)
    assert res.best.schedule.fits()


def test_blocking_beats_flat():
    nest = conv_nest("t", B=4, K=32, C=32, X=8, Y=8, FX=3, FY=3)
    arr = ArraySpec(dims=(4, 4))
    df = make_dataflow(nest, arr, ("C", "K"))
    res = search_blocking(nest, LEVELS, arr, df, beam=8)
    flat = evaluate(
        flat_schedule(nest, LEVELS, array=arr, spatial=df.assigns)
    )
    assert res.best.energy_pj < flat.energy_pj


def test_iter_blockings_valid():
    nest = fc_nest("fc", B=4, C=64, K=64)
    arr = ArraySpec(dims=(4, 4))
    df = make_dataflow(nest, arr, ("C", "K"))
    n = 0
    for s in iter_blockings(nest, LEVELS, arr, df, max_choices_per_level=8):
        assert s.fits()
        n += 1
        if n >= 50:
            break
    assert n > 0


# ------------------------------------------------------------------- energy


def test_table3_values():
    """Paper Table 3 energy points reproduce exactly."""
    assert asic_access_energy_pj(16) == pytest.approx(0.03)
    assert asic_access_energy_pj(64) == pytest.approx(0.12)
    assert asic_access_energy_pj(512) == pytest.approx(0.96)
    assert asic_access_energy_pj(32 * 1024) == pytest.approx(6.0)
    assert asic_access_energy_pj(128 * 1024) == pytest.approx(13.5)
    assert asic_access_energy_pj(512 * 1024) == pytest.approx(30.375)
    assert asic_access_energy_pj(None) == pytest.approx(200.0)


# ---------------------------------------------------------------- optimizer


def test_ck_dataflow_handles_depthwise():
    from repro.core import depthwise_nest

    nest = depthwise_nest("dw", B=2, C=32, X=8, Y=8, FX=3, FY=3)
    df = ck_dataflow(nest, ArraySpec(dims=(4, 4)))
    assert df.used_pes() > 1


def test_candidate_hierarchies_ratio_band():
    arr = ArraySpec(dims=(16, 16))
    cands = candidate_hierarchies(arr, two_level_rf=True)
    assert cands
    for hw in cands:
        if len(hw.rf_bytes) == 2:
            ratio = hw.rf_bytes[1] / hw.rf_bytes[0]
            assert 4 <= ratio <= 16


def test_optimize_layer_small():
    nest = conv_nest("t", B=2, K=16, C=16, X=8, Y=8, FX=3, FY=3)
    res = optimize_layer(nest, eyeriss_like(), max_evals=200)
    assert res.report.energy_pj > 0
    assert res.report.schedule.fits()
