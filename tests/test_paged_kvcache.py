"""Paged KV cache: property-based differential traces + pool invariants.

The contiguous slot engine is the paged engine's *oracle*: with the
contiguous flash-decoding KV split pinned to the paged block size
(``ServeConfig.decode_block``), both layouts run the same online-softmax
reduction over the same logical keys, so every generated token must be
**bitwise** equal.  The hypothesis-style suites here drive seeded random
traces of admit/decode/evict/backfill — mixed prompt lengths, shared
prefixes, tail-sharing CoW, capacity-starved admission — and assert that
equality plus the block-pool ownership invariants (refcounts mirror live
rows, free list + owned blocks partition the pool, the prefix index never
outlives its blocks) after every step.

Seeds are fixed so CI is reproducible; crank the trace count locally with
``FUZZ_EXAMPLES=N make test-fuzz``.
"""

import jax
import numpy as np
import pytest

from conftest import fuzz_examples
from repro.arch.model_zoo import build
from repro.configs.registry import get
from repro.serve import kvcache
from repro.serve.engine import Engine, Request, ServeConfig


@pytest.fixture(scope="module")
def smol():
    cfg = get("smollm-360m-smoke")
    params = build(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _paged_scfg(bs=16, batch=3, max_len=64, **kw):
    # bucketed prefill (exact for this all-global smoke model) keeps the
    # randomized traces from compiling one prefill per distinct length
    kw.setdefault("prefill_bucket", 16)
    return ServeConfig(
        batch=batch, max_len=max_len, kv_layout="paged", block_size=bs, **kw
    )


def _oracle_scfg(bs=16, batch=3, max_len=64, **kw):
    # the contiguous oracle pins its decode KV split to the paged block
    # size: identical reduction order => bitwise-comparable outputs
    kw.setdefault("prefill_bucket", 16)
    return ServeConfig(
        batch=batch, max_len=max_len, attention="flash", decode_block=bs, **kw
    )


def _random_workload(rng, cfg, n, max_len, *, share_p=0.5, prefix_pool=3):
    """Mixed random prompts; ``share_p`` of them extend one of a few shared
    prefixes (sometimes exactly — exercising tail sharing + CoW)."""
    prefixes = [
        rng.integers(0, cfg.vocab, int(rng.integers(8, max_len // 2))).astype(
            np.int32
        )
        for _ in range(prefix_pool)
    ]
    reqs = []
    for i in range(n):
        if rng.random() < share_p:
            pre = prefixes[int(rng.integers(len(prefixes)))]
            extra = int(rng.integers(0, 6))  # 0 => identical prompt
            prompt = np.concatenate(
                [pre, rng.integers(0, cfg.vocab, extra).astype(np.int32)]
            )
        else:
            prompt = rng.integers(
                0, cfg.vocab, int(rng.integers(1, max_len - 8))
            ).astype(np.int32)
        reqs.append(
            Request(
                prompt[: max_len - 4],
                max_new_tokens=int(rng.integers(1, 10)),
                request_id=i,
            )
        )
    return reqs


def _check_pool(eng):
    eng.pool.assert_invariants(eng.live_block_refs())


def _check_device_tables(eng):
    """The device block tables of live rows must mirror the host
    ownership (`_PagedRow.blocks`), with every entry past the reserved
    span aimed at the sink."""
    tables = np.asarray(eng.caches["table"][0])
    for slot, row in eng._rows.items():
        want = np.full((tables.shape[1],), kvcache.SINK_BLOCK, np.int32)
        nb = len(row.blocks)
        want[:nb] = row.blocks
        got = tables[slot]
        # a pending CoW is the one legal divergence: the device row still
        # aims at the shared tail until _resolve_cow repoints it
        if row.cow_dst is not None:
            lb = row.plen // eng.scfg.block_size
            want[lb] = got[lb]
        assert np.array_equal(got, want), (slot, got, want)


# ---------------------------------------------------- differential traces --


@pytest.mark.fuzz
def test_paged_matches_contiguous_oracle_fuzz(smol):
    """Randomized traces: paged engine output must be bitwise equal to the
    contiguous oracle, and the pool must be fully free after drain."""
    cfg, params = smol
    for ex in range(fuzz_examples(3)):
        rng = np.random.default_rng(100 + ex)
        bs = int(rng.choice([8, 16]))
        batch = int(rng.integers(2, 5))
        temp = float(rng.choice([0.0, 0.8]))
        reqs = _random_workload(rng, cfg, int(rng.integers(6, 12)), 64)
        kw = dict(bs=bs, batch=batch, temperature=temp, seed=int(ex))
        outs_c = Engine(cfg, params, _oracle_scfg(**kw)).run(reqs)
        paged = Engine(cfg, params, _paged_scfg(**kw))
        outs_p = paged.run(reqs)
        for i, (c, p) in enumerate(zip(outs_c, outs_p)):
            assert np.array_equal(c, p), (
                f"example {ex} request {i}: paged {p.tolist()} != "
                f"oracle {c.tolist()}"
            )
        _check_pool(paged)
        assert paged.pool.free_blocks == paged.pool.num_blocks - 1, (
            "pool not fully free after drain"
        )


@pytest.mark.fuzz
def test_pool_invariants_hold_after_every_step(smol):
    """Step-granular ownership audit: refcounts, free list, index liveness
    and the device-table mirror are checked after every engine step of a
    shared-prefix trace."""
    cfg, params = smol
    rng = np.random.default_rng(7)
    reqs = _random_workload(rng, cfg, 8, 64, share_p=0.7)
    eng = Engine(cfg, params, _paged_scfg(batch=3))
    for r in reqs:
        eng.submit(r)
    _check_pool(eng)
    steps = 0
    while eng.step():
        _check_pool(eng)
        _check_device_tables(eng)
        steps += 1
        assert steps < 500, "engine failed to drain"
    _check_pool(eng)
    assert eng.pool.free_blocks == eng.pool.num_blocks - 1


def test_blocks_not_slots_gate_admission(smol):
    """A block-starved pool stalls admission (strict FIFO) without
    deadlock or corruption: slots stay idle while blocks are scarce, every
    request completes, outputs still match the oracle bitwise."""
    cfg, params = smol
    rng = np.random.default_rng(11)
    reqs = [
        Request(
            rng.integers(0, cfg.vocab, 20).astype(np.int32),
            max_new_tokens=8,
            request_id=i,
        )
        for i in range(5)
    ]
    # pool of 5 usable blocks @ bs=16 (cap 80 tokens) but 4 slots: at most
    # two 28-token requests (2 blocks each) can be live at once
    scfg = _paged_scfg(batch=4, max_len=64, num_blocks=6)
    paged = Engine(cfg, params, scfg)
    outs_p = paged.run(reqs)
    outs_c = Engine(cfg, params, _oracle_scfg(batch=4, max_len=64)).run(reqs)
    for c, p in zip(outs_c, outs_p):
        assert np.array_equal(c, p)
    assert paged.stats["peak_active"] <= 2 < scfg.batch
    _check_pool(paged)
    assert paged.pool.free_blocks == 5


def test_prefix_sharing_aliases_and_cow(smol):
    """Concurrent requests over one prompt: full prefix blocks alias in
    every live table (refcount == #sharers), an exact-prompt twin shares
    the partial tail block, and its first decode write resolves the
    pre-reserved copy-on-write block — after which tables diverge."""
    cfg, params = smol
    rng = np.random.default_rng(5)
    pre = rng.integers(0, cfg.vocab, 40).astype(np.int32)  # 2 full + tail 8
    eng = Engine(cfg, params, _paged_scfg(batch=3, bs=16))
    eng.submit(Request(pre.copy(), 6, request_id=0))
    eng.submit(Request(pre.copy(), 6, request_id=1))       # exact twin
    eng.submit(
        Request(
            np.concatenate([pre, rng.integers(0, cfg.vocab, 3).astype(np.int32)]),
            6,
            request_id=2,
        )
    )
    # admission only (no decode write yet): peek ownership mid-step by
    # driving admission through a zero-budget...: use step() once, which
    # admits AND decodes; so check aliasing from the recorded rows after
    # the first step — CoW has already resolved for the twin by then.
    rows_before = None

    class Snap:
        def __call__(self, rid, tok, idx, done):
            nonlocal rows_before
            if rows_before is None:
                rows_before = {
                    s: (list(r.blocks), r.tail_shared, r.cow_dst)
                    for s, r in eng._rows.items()
                }

    eng.step(on_token=Snap())  # admission snapshot fires at first token
    blocks = {s: b for s, (b, _, _) in rows_before.items()}
    tails = {s: t for s, (_, t, _) in rows_before.items()}
    cows = {s: c for s, (_, _, c) in rows_before.items()}
    s0, s1, s2 = sorted(blocks)
    # full prefix blocks aliased by all three
    assert blocks[s0][:2] == blocks[s1][:2] == blocks[s2][:2]
    # the exact twin aliased the partial tail too, with a reserved CoW dst
    assert blocks[s1][2] == blocks[s0][2]
    assert tails[s1] and cows[s1] is not None
    # request 2 extends past the tail content: its tail block is private
    assert blocks[s2][2] != blocks[s0][2]
    # after the first decode step the twin's CoW resolved: private tail
    row1 = eng._rows[s1]
    assert row1.cow_dst is None and not row1.tail_shared
    assert row1.blocks[2] == cows[s1]
    assert eng.pool.refcount[blocks[s0][2]] == 1  # back to creator-only
    _check_pool(eng)
    while eng.step():
        pass
    assert eng.pool.free_blocks == eng.pool.num_blocks - 1


def test_evict_then_readmit_same_slot(smol):
    """Cancellation frees a slot mid-flight; the next admission reuses
    that same slot and must see no stale state: fresh table row, fresh
    blocks, output bitwise equal to a solo run, zero leak at drain."""
    cfg, params = smol
    eng = Engine(cfg, params, _paged_scfg(batch=2, bs=16))
    rng = np.random.default_rng(21)
    prompts = [rng.integers(0, cfg.vocab, 12).astype(np.int32) for _ in range(3)]
    eng.submit(Request(prompts[0], 12, request_id=0))
    eng.submit(Request(prompts[1], 12, request_id=1))
    eng.step()
    eng.step()
    victim_slot = next(s for s, st in eng._slots.items() if st.rid == 0)
    eng.cancel(0)
    _check_pool(eng)
    eng.submit(Request(prompts[2], 6, request_id=2))
    eng.step()
    assert next(s for s, st in eng._slots.items() if st.rid == 2) == victim_slot
    _check_pool(eng)
    _check_device_tables(eng)
    while eng.step():
        pass
    solo = Engine(cfg, params, _paged_scfg(batch=2, bs=16)).run(
        [Request(prompts[2], 6, request_id=2)]
    )[0]
    assert np.array_equal(eng.pop_result(2), solo)
    assert eng.pool.free_blocks == eng.pool.num_blocks - 1


def test_cancel_during_pending_cow_releases_reservation(smol):
    """Adversarial interleaving: an exact-prompt twin is cancelled from
    its own first-token callback — AFTER admission reserved its CoW block
    but BEFORE ``_resolve_cow`` ran.  The eviction must release both the
    shared tail reference and the pending CoW reservation, leaving the
    creator untouched."""
    cfg, params = smol
    eng = Engine(cfg, params, _paged_scfg(batch=2, bs=16))
    rng = np.random.default_rng(23)
    pre = rng.integers(0, cfg.vocab, 40).astype(np.int32)  # 2 full + tail 8
    eng.submit(Request(pre.copy(), 10, request_id=0))
    eng.step()  # request 0 active, its prompt chain registered
    eng.submit(Request(pre.copy(), 10, request_id=1))  # exact twin
    seen = {}

    def cb(rid, tok, idx, done):
        if rid == 1 and idx == 0:
            slot = next(s for s, st in eng._slots.items() if st.rid == 1)
            row = eng._rows[slot]
            assert row.tail_shared and row.cow_dst is not None
            seen["cow"] = row.cow_dst
            seen["tail"] = row.blocks[2]
            from repro.serve.engine import RequestStatus

            assert eng.cancel(1) == RequestStatus.CANCELLED

    eng.step(on_token=cb)
    assert "cow" in seen, "twin admission callback never fired"
    _check_pool(eng)
    _check_device_tables(eng)
    # CoW reservation back in the free list; tail back to creator-only
    assert eng.pool.refcount[seen["cow"]] == 0
    assert eng.pool.refcount[seen["tail"]] == 1
    while eng.step():
        pass
    solo = Engine(cfg, params, _paged_scfg(batch=2, bs=16)).run(
        [Request(pre.copy(), 10, request_id=0)]
    )[0]
    assert np.array_equal(eng.pop_result(0), solo)
    assert eng.pool.free_blocks == eng.pool.num_blocks - 1


def test_double_cancel_idempotent(smol):
    """Cancelling twice (or cancelling FINISHED/unknown ids) is a no-op
    reporting the existing terminal status — clients can fire-and-forget
    cancels without racing completions."""
    from repro.serve.engine import RequestStatus

    cfg, params = smol
    eng = Engine(cfg, params, _paged_scfg(batch=1, bs=16))
    rng = np.random.default_rng(29)
    eng.submit(Request(rng.integers(0, cfg.vocab, 8).astype(np.int32), 8, request_id=0))
    eng.submit(Request(rng.integers(0, cfg.vocab, 8).astype(np.int32), 8, request_id=1))
    eng.step()  # 0 active, 1 waiting (single slot)
    assert eng.cancel(1) == RequestStatus.CANCELLED  # waiting-state cancel
    assert eng.cancel(1) == RequestStatus.CANCELLED  # double-cancel: no-op
    assert eng.stats["cancelled"] == 1
    assert eng.cancel(0) == RequestStatus.CANCELLED  # active-state cancel
    assert eng.cancel(0) == RequestStatus.CANCELLED
    assert eng.stats["cancelled"] == 2
    assert eng.cancel(99) == RequestStatus.UNKNOWN
    _check_pool(eng)
    assert eng.pool.free_blocks == eng.pool.num_blocks - 1
    res = eng.pop_result(0)
    assert res.status == RequestStatus.CANCELLED and len(res) >= 1
    # popped: the id is gone, a third cancel reports UNKNOWN
    assert eng.cancel(0) == RequestStatus.UNKNOWN


@pytest.mark.fuzz
def test_lifecycle_fuzz_cancel_preempt_invariants(smol):
    """The step-granular trace fuzzer, extended with lifecycle events:
    seeded random cancels (any state) and forced preemptions land between
    steps while the ownership invariants and the device-table mirror are
    audited after every step.  Survivors must match the unfaulted oracle
    bitwise; everyone else must hold an oracle prefix."""
    from repro.serve.engine import RequestStatus, TERMINAL_STATUSES

    cfg, params = smol
    for ex in range(fuzz_examples(3)):
        rng = np.random.default_rng(300 + ex)
        reqs = _random_workload(rng, cfg, 8, 64, share_p=0.6)
        kw = dict(bs=8, batch=3, temperature=0.7, seed=int(ex))
        oracle = {
            r.request_id: o.tolist()
            for r, o in zip(reqs, Engine(cfg, params, _oracle_scfg(**kw)).run(reqs))
        }
        eng = Engine(cfg, params, _paged_scfg(**kw))
        for r in reqs:
            eng.submit(r)
        steps = 0
        while eng._slots or eng._waiting:
            if rng.random() < 0.2:
                live = [
                    r.request_id
                    for r in reqs
                    if eng.status(r.request_id) not in TERMINAL_STATUSES
                ]
                if live:
                    eng.cancel(live[int(rng.integers(len(live)))])
            if rng.random() < 0.2:
                actives = [
                    r.request_id
                    for r in reqs
                    if eng.status(r.request_id) == RequestStatus.ACTIVE
                ]
                if actives:
                    eng.preempt(actives[int(rng.integers(len(actives)))])
            eng.step()
            _check_pool(eng)
            _check_device_tables(eng)
            steps += 1
            assert steps < 600, "trace failed to drain"
        assert eng.pool.free_blocks == eng.pool.num_blocks - 1, "block leak"
        for r in reqs:
            res = eng.pop_result(r.request_id)
            got, want = res.tolist(), oracle[r.request_id]
            if res.status == RequestStatus.FINISHED:
                assert got == want, (ex, r.request_id, res.preemptions)
            else:
                assert got == want[: len(got)], (ex, r.request_id, res.status)


def test_paged_flash_and_xla_substrates_agree(smol):
    """attention='flash' (backend auto) and attention='xla' (pinned gather
    twin) are substrate swaps on the paged layout, not semantics changes."""
    cfg, params = smol
    rng = np.random.default_rng(13)
    reqs = _random_workload(rng, cfg, 6, 64)
    a = Engine(cfg, params, _paged_scfg(attention="flash")).run(reqs)
    b = Engine(cfg, params, _paged_scfg(attention="xla")).run(reqs)
    for x, y in zip(a, b):
        assert np.array_equal(x, y)


def test_oversized_request_rejected(smol):
    """A request whose worst-case KV footprint exceeds the whole pool can
    never be admitted: submit must raise instead of deadlocking the queue
    or silently shrinking the budget (which would diverge from the
    contiguous oracle)."""
    cfg, params = smol
    # 2 usable blocks @ bs=16 -> 32-token capacity
    eng = Engine(cfg, params, _paged_scfg(batch=2, max_len=64, num_blocks=3))
    with pytest.raises(ValueError, match="pool"):
        eng.submit(
            Request((np.arange(30) % cfg.vocab).astype(np.int32), 10)
        )


def test_paged_rejects_unsupported_families(smol):
    for arch in ("gemma3-12b-smoke", "rwkv6-1.6b-smoke",
                 "recurrentgemma-2b-smoke"):
        cfg = get(arch)
        with pytest.raises(ValueError, match="paged"):
            Engine(cfg, None, _paged_scfg())


def test_serveconfig_validation():
    with pytest.raises(ValueError, match="kv_layout"):
        ServeConfig(kv_layout="ring")
    with pytest.raises(ValueError, match="multiple"):
        ServeConfig(kv_layout="paged", max_len=100, block_size=16)
    scfg = ServeConfig(batch=2, max_len=64, kv_layout="paged", block_size=16)
    assert scfg.resolved_num_blocks() == 2 * 64 // 16 + 1  # + sink


# --------------------------------------------------- block-pool unit tests --


def test_block_pool_alloc_release_roundtrip():
    pool = kvcache.BlockPool(6, 4)
    a, b = pool.alloc(), pool.alloc()
    assert a != b and kvcache.SINK_BLOCK not in (a, b)
    pool.retain(a)
    pool.release(a)
    assert pool.refcount[a] == 1
    pool.release(a)
    pool.release(b)
    pool.assert_invariants({})
    assert pool.free_blocks == 5


def test_block_pool_prefix_index_lifecycle():
    pool = kvcache.BlockPool(8, 4)
    toks = list(range(10))  # 2 full blocks + tail of 2
    b0, b1, bt = pool.alloc(), pool.alloc(), pool.alloc()
    pool.register(-1, tuple(toks[0:4]), b0)
    pool.register(b0, tuple(toks[4:8]), b1)
    pool.register(b1, tuple(toks[8:10]), bt)
    assert pool.match_prefix(toks) == ([b0, b1], bt)
    assert pool.match_prefix(toks[:8]) == ([b0, b1], None)
    assert pool.match_prefix(toks[:9]) == ([b0, b1], None)  # tail != exact
    assert pool.match_prefix(toks[:4] + [99] * 4) == ([b0], None)
    # releasing a block drops its index entries (and breaks the chain)
    pool.release(b1)
    assert pool.match_prefix(toks) == ([b0], None)
    pool.release(b0)
    pool.release(bt)
    pool.assert_invariants({})


def test_block_pool_refcount_drift_detected():
    pool = kvcache.BlockPool(4, 4)
    bid = pool.alloc()
    with pytest.raises(AssertionError, match="refcount"):
        pool.assert_invariants({})  # engine claims nothing owns `bid`
    pool.release(bid)
    pool.assert_invariants({})


def test_block_pool_reserve_unreserve_accounting():
    """The external-hold contract pinned directly: partial grants when the
    pool runs dry, holds invisible to engine refs but accounted by the
    invariant check, and double-/never-reserved unreserves rejected."""
    pool = kvcache.BlockPool(6, 4)
    held = pool.reserve(3)
    assert len(held) == 3 and set(held) <= set(range(1, 6))
    assert pool.free_blocks == 2
    pool.assert_invariants({})  # external holds aren't engine-owned refs
    more = pool.reserve(10)  # drier than asked: partial grant, no raise
    assert len(more) == 2 and pool.free_blocks == 0
    assert pool.reserve(1) == []  # bone dry: empty grant
    pool.unreserve(more)
    assert pool.free_blocks == 2
    with pytest.raises(AssertionError, match="non-reserved"):
        pool.unreserve(more)  # double-unreserve must not double-free
    with pytest.raises(AssertionError, match="non-reserved"):
        pool.unreserve([kvcache.SINK_BLOCK])  # sink is never reservable
    engine_owned = pool.alloc()
    with pytest.raises(AssertionError, match="non-reserved"):
        pool.unreserve([engine_owned])  # engine refs can't exit via holds
    pool.release(engine_owned)
    pool.unreserve(held)
    assert pool.free_blocks == 5
    pool.assert_invariants({})


def test_block_pool_state_roundtrip_json():
    """to_state/from_state rebuild refcounts, free order, external holds,
    and the radix index — through a JSON encode, since the recovery
    manifest embeds the state as JSON."""
    import json

    pool = kvcache.BlockPool(8, 4)
    a, b = pool.alloc(), pool.alloc()
    pool.register(-1, (1, 2, 3, 4), a)
    pool.register(a, (5, 6), b)
    pool.retain(b)
    pool.reserve(2)
    clone = kvcache.BlockPool.from_state(
        json.loads(json.dumps(pool.to_state()))
    )
    assert clone.refcount == pool.refcount
    assert clone.free == pool.free  # order matters: pop() parity
    assert clone.external == pool.external
    assert clone.index == pool.index
    assert clone.match_prefix([1, 2, 3, 4, 5, 6]) == ([a], b)
    clone.assert_invariants({a: 1, b: 2})


# ------------------------------------------------------- serve-engine fuzz --


@pytest.mark.fuzz
def test_serve_engine_stress_no_leak_deterministic(smol):
    """Satellite stress fuzz: 200+ requests arriving in seeded random
    bursts (prefix-sharing waves included), driven through a small paged
    pool.  Asserts (a) zero block leak once drained, (b) outputs bitwise
    identical under an arrival-order permutation, (c) the pool buffers are
    donation-stable across the whole run (no silent reallocation)."""
    cfg, params = smol
    n_requests = max(200, 50 * fuzz_examples(4))
    rng = np.random.default_rng(42)
    reqs = _random_workload(rng, cfg, n_requests, 64, share_p=0.4)

    def drive(order_seed):
        order = np.random.default_rng(order_seed).permutation(len(reqs))
        eng = Engine(
            cfg, params, _paged_scfg(batch=4, bs=8, temperature=0.6, seed=9)
        )
        submitted = 0
        outs = {}
        pointers = None
        pending = list(order)
        while submitted < len(reqs) or eng._slots or eng._waiting:
            burst = int(rng.integers(0, 5))
            for _ in range(min(burst, len(pending))):
                i = pending.pop(0)
                eng.submit(reqs[i])
                submitted += 1
            progressed = eng.step()
            if pointers is None and submitted > 4:
                pointers = sorted(
                    leaf.unsafe_buffer_pointer()
                    for name in ("kpool", "vpool")
                    for leaf in [eng.caches[name]]
                )
            if not progressed and submitted == len(reqs):
                break
        assert pointers == sorted(
            leaf.unsafe_buffer_pointer()
            for name in ("kpool", "vpool")
            for leaf in [eng.caches[name]]
        ), "pool buffers were reallocated mid-run (donation broke)"
        _check_pool(eng)
        assert eng.pool.free_blocks == eng.pool.num_blocks - 1, "block leak"
        for r in reqs:
            outs[r.request_id] = eng.pop_result(r.request_id).tolist()
        assert eng.stats["admitted"] >= len(reqs)
        return outs

    a = drive(order_seed=0)
    b = drive(order_seed=1)  # different arrival order, same requests
    assert a == b, "outputs depend on arrival order"
