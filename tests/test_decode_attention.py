"""Ragged flash-decoding differential tests.

The Pallas kernel body runs in interpret mode on CPU (so CI exercises the
real kernel, not just its jnp twin) against three oracles: the dense ragged
reference, `arch.attention.dense_attention` with the serve engine's
position-mask recipe (global and sliding-window ring caches), and the
`decode_attention_xla` while-loop twin that CPU serving uses.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.arch.attention import dense_attention
from repro.kernels.flash_attention.decode_attention import (
    decode_attention_paged_xla,
    decode_attention_xla,
)
from repro.kernels.flash_attention.ops import (
    decode_attention,
    decode_attention_paged,
    flash_attention,
)
from repro.kernels.flash_attention.ref import (
    decode_attention_paged_ref,
    decode_attention_ref,
)

KEY = jax.random.PRNGKey(0)


def _qkv(B, S, KV, G, d, dtype=jnp.float32):
    q = jax.random.normal(KEY, (B, KV, G, d), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, KV, d), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, KV, d), dtype)
    return q, k, v


def _tol(dtype):
    return (
        dict(rtol=2e-2, atol=2e-2)
        if dtype == jnp.bfloat16
        else dict(rtol=2e-5, atol=2e-5)
    )


# --------------------------------------------------- kernel vs dense oracle


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bk", [8, 16, 32])  # 4 / 2 / 1 kv splits
def test_decode_kernel_ragged_lengths(bk, dtype):
    B, S, KV, G, d = 4, 32, 2, 2, 16
    q, k, v = _qkv(B, S, KV, G, d, dtype)
    lengths = jnp.asarray([1, 7, 13, 32], jnp.int32)
    want = decode_attention_ref(q, k, v, lengths)
    got = decode_attention(
        q, k, v, lengths, bk=bk, impl="pallas", interpret=True
    )
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **_tol(dtype),
    )


@pytest.mark.parametrize("KV,G", [(1, 1), (1, 4), (2, 2), (3, 1), (2, 4)])
def test_decode_kernel_gqa_ratios(KV, G):
    """KV heads are indexed inside the kernel — every grouping ratio must
    agree with the reference (which broadcasts explicitly)."""
    B, S, d = 3, 24, 8
    q, k, v = _qkv(B, S, KV, G, d)
    lengths = jnp.asarray([3, 24, 11], jnp.int32)
    want = decode_attention_ref(q, k, v, lengths)
    got = decode_attention(
        q, k, v, lengths, bk=8, impl="pallas", interpret=True
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5
    )


def test_decode_xla_twin_matches_kernel():
    """The while-loop jnp twin (the CPU serving substrate) computes the
    same blocked recurrence as the kernel body."""
    B, S, KV, G, d = 3, 64, 2, 2, 16
    q, k, v = _qkv(B, S, KV, G, d)
    lengths = jnp.asarray([5, 40, 64], jnp.int32)
    a = decode_attention(q, k, v, lengths, bk=16, impl="pallas",
                         interpret=True)
    b = decode_attention_xla(q, k, v, lengths, bk=16)
    np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6
    )


@pytest.mark.parametrize("impl", ["pallas", "xla"])
def test_decode_batched_bitwise_equals_solo(impl):
    """Slot isolation at the kernel level: a row's output must be bitwise
    identical whether it decodes alone or batched with longer rows (dead
    blocks contribute exactly zero) — the property the serve engine's
    solo-vs-batched determinism suite rests on."""
    B, S, KV, G, d = 3, 32, 2, 2, 16
    q, k, v = _qkv(B, S, KV, G, d)
    lengths = jnp.asarray([4, 19, 32], jnp.int32)
    kw = dict(bk=8, impl=impl, interpret=(impl == "pallas") or None)
    batched = decode_attention(q, k, v, lengths, **kw)
    for i in range(B):
        solo = decode_attention(
            q[i : i + 1], k[i : i + 1], v[i : i + 1], lengths[i : i + 1], **kw
        )
        assert np.array_equal(np.asarray(solo[0]), np.asarray(batched[i])), i


# ------------------------------------------- vs the serve-engine mask recipe


def test_decode_matches_engine_mask_global():
    """Global-attention slot cache: ragged length == the engine's
    causal + empty-sentinel position mask (dense_attention oracle)."""
    B, S, KV, G, d = 3, 16, 2, 2, 8
    q, k, v = _qkv(B, S, KV, G, d)
    lengths = jnp.asarray([2, 9, 16], jnp.int32)  # live slots incl. new tok
    idx = jnp.arange(S, dtype=jnp.int32)[None, :]
    k_pos = jnp.where(idx < lengths[:, None], idx, 10**9)  # empty sentinel
    q_pos = lengths[:, None] - 1                           # current token
    want = dense_attention(
        q[:, None].transpose(0, 1, 2, 3, 4).reshape(B, 1, KV, G, d),
        k, v, q_pos=q_pos, k_pos=k_pos, causal=True,
    )[:, 0]
    got = decode_attention(
        q, k, v, lengths, bk=8, impl="pallas", interpret=True
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5
    )


@pytest.mark.parametrize("new_len", [3, 8, 13, 20])
def test_decode_matches_engine_mask_sliding_window_ring(new_len):
    """Sliding-window ring cache (size == window): the ring invariant
    slot(p) = p % size makes the single ragged bound equivalent to the
    causal + window mask over the ring's absolute positions."""
    W = 8  # ring size == window
    B, KV, G, d = 1, 2, 2, 8
    q, k, v = _qkv(B, W, KV, G, d)
    # absolute position living in each ring slot after new_len writes
    slots = np.full((W,), 10**9, np.int64)
    for p in range(new_len):
        slots[p % W] = p
    k_pos = jnp.asarray(slots[None, :], jnp.int32)
    q_pos = jnp.asarray([[new_len - 1]], jnp.int32)
    want = dense_attention(
        q[:, None].reshape(B, 1, KV, G, d), k, v,
        q_pos=q_pos, k_pos=k_pos, causal=True, window=W,
    )[:, 0]
    lengths = jnp.asarray([min(new_len, W)], jnp.int32)
    got = decode_attention(
        q, k, v, lengths, bk=4, impl="pallas", interpret=True
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5
    )


# ------------------------------------------------- paged (block-table) path


def _paged_inputs(rng, B, KV, G, d, bs, n_blk, num_blocks, *, alias=False):
    """Random pool + per-row block tables.  Physical order is shuffled (a
    row's chain is non-monotonic in pool order) and, with ``alias=True``,
    rows share leading blocks like prefix-cached requests do."""
    q = jax.random.normal(KEY, (B, KV, G, d))
    kpool = jax.random.normal(jax.random.fold_in(KEY, 1), (num_blocks, bs, KV, d))
    vpool = jax.random.normal(jax.random.fold_in(KEY, 2), (num_blocks, bs, KV, d))
    if alias:
        shared = rng.permutation(num_blocks)[: n_blk // 2]
        tables = np.stack([
            np.concatenate([
                shared,
                rng.permutation(
                    [b for b in range(num_blocks) if b not in shared]
                )[: n_blk - len(shared)],
            ])
            for _ in range(B)
        ])
    else:
        tables = np.stack(
            [rng.permutation(num_blocks)[:n_blk] for _ in range(B)]
        )
    return q, kpool, vpool, jnp.asarray(tables, jnp.int32)


@pytest.mark.parametrize("KV,G", [(1, 1), (1, 4), (2, 2), (3, 1), (2, 4)])
def test_paged_kernel_gqa_vs_dense_oracle(KV, G):
    """Random non-monotonic block tables, every GQA grouping: the kernel
    body (interpret mode) must agree with the gather-then-dense oracle."""
    B, d, bs, n_blk = 3, 8, 8, 4
    rng = np.random.default_rng(0)
    q, kpool, vpool, tables = _paged_inputs(rng, B, KV, G, d, bs, n_blk, 12)
    lengths = jnp.asarray([3, 17, 32], jnp.int32)
    want = decode_attention_paged_ref(q, kpool, vpool, tables, lengths)
    got = decode_attention_paged(
        q, kpool, vpool, tables, lengths, impl="pallas", interpret=True
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5
    )


@pytest.mark.parametrize("window", [None, 3, 8, 21])
@pytest.mark.parametrize("bs", [4, 8, 16])  # 8 / 4 / 2 kv splits
def test_paged_kernel_windows_and_splits(window, bs):
    """Sliding windows x split counts against the dense oracle (logical
    index == absolute position: the window masks the oldest keys)."""
    B, KV, G, d = 2, 2, 2, 16
    n_blk = 32 // bs
    rng = np.random.default_rng(1)
    q, kpool, vpool, tables = _paged_inputs(rng, B, KV, G, d, bs, n_blk, 10)
    lengths = jnp.asarray([5, 29], jnp.int32)
    want = decode_attention_paged_ref(
        q, kpool, vpool, tables, lengths, window=window
    )
    got = decode_attention_paged(
        q, kpool, vpool, tables, lengths, window=window,
        impl="pallas", interpret=True,
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5
    )


def test_paged_aliased_prefix_blocks_rows_agree():
    """Rows aliasing the same leading physical blocks (prefix sharing)
    read them through their own tables: each row must match the oracle,
    and rows with identical logical content must agree bitwise."""
    B, KV, G, d, bs, n_blk = 4, 2, 2, 8, 8, 4
    rng = np.random.default_rng(2)
    q, kpool, vpool, tables = _paged_inputs(
        rng, B, KV, G, d, bs, n_blk, 12, alias=True
    )
    # rows 2 and 3: same query, same table, same length -> bitwise twins
    q = q.at[3].set(q[2])
    tables = tables.at[3].set(tables[2])
    lengths = jnp.asarray([7, 25, 13, 13], jnp.int32)
    want = decode_attention_paged_ref(q, kpool, vpool, tables, lengths)
    got = decode_attention_paged(
        q, kpool, vpool, tables, lengths, impl="pallas", interpret=True
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5
    )
    assert np.array_equal(np.asarray(got[2]), np.asarray(got[3]))


@pytest.mark.parametrize("impl", ["pallas", "xla"])
def test_paged_twin_matches_kernel_and_contiguous(impl):
    """The gather twin computes the kernel's recurrence, and both equal the
    contiguous twin bitwise at bk == block_size on the same logical keys —
    the property the serve engine's paged-vs-contiguous oracle rests on."""
    B, KV, G, d, bs, n_blk = 3, 2, 2, 16, 8, 4
    rng = np.random.default_rng(3)
    q, kpool, vpool, tables = _paged_inputs(rng, B, KV, G, d, bs, n_blk, 16)
    lengths = jnp.asarray([2, 19, 32], jnp.int32)
    kw = dict(impl=impl, interpret=(impl == "pallas") or None)
    got = decode_attention_paged(q, kpool, vpool, tables, lengths, **kw)
    k_dense = jnp.take(kpool, tables, axis=0).reshape(B, n_blk * bs, KV, d)
    v_dense = jnp.take(vpool, tables, axis=0).reshape(B, n_blk * bs, KV, d)
    dense_twin = decode_attention_xla(q, k_dense, v_dense, lengths, bk=bs)
    if impl == "xla":
        assert np.array_equal(np.asarray(got), np.asarray(dense_twin))
        assert np.array_equal(
            np.asarray(got),
            np.asarray(
                decode_attention_paged_xla(q, kpool, vpool, tables, lengths)
            ),
        )
    else:
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(dense_twin), rtol=1e-6, atol=1e-6
        )


# ---------------------------------------------------------- compile economy


def test_decode_lengths_do_not_recompile():
    """Lengths are a traced scalar-prefetch operand: one compilation must
    serve every ragged length."""
    B, S, KV, G, d = 2, 32, 1, 2, 8
    q, k, v = _qkv(B, S, KV, G, d)

    fn = jax.jit(
        lambda q, k, v, lens: decode_attention(
            q, k, v, lens, bk=8, impl="pallas", interpret=True
        )
    )
    for a, b in [(1, 2), (7, 31), (32, 15)]:
        fn(q, k, v, jnp.asarray([a, b], jnp.int32)).block_until_ready()
    if hasattr(fn, "_cache_size"):
        assert fn._cache_size() == 1


def test_paged_table_contents_do_not_recompile():
    """Block tables ride as a scalar-prefetch operand: remapping every
    logical block to new physical blocks (admission, CoW, eviction churn)
    must reuse the one compiled program."""
    B, KV, G, d, bs, n_blk = 2, 1, 2, 8, 8, 4
    rng = np.random.default_rng(4)
    q, kpool, vpool, tables = _paged_inputs(rng, B, KV, G, d, bs, n_blk, 12)

    fn = jax.jit(
        lambda q, kp, vp, t, lens: decode_attention_paged(
            q, kp, vp, t, lens, impl="pallas", interpret=True
        )
    )
    for seed, (a, b) in [(0, (1, 2)), (1, (7, 31)), (2, (32, 15))]:
        t = jnp.asarray(
            np.stack([
                np.random.default_rng(seed).permutation(12)[:n_blk]
                for _ in range(B)
            ]),
            jnp.int32,
        )
        fn(q, kpool, vpool, t, jnp.asarray([a, b], jnp.int32)).block_until_ready()
    if hasattr(fn, "_cache_size"):
        assert fn._cache_size() == 1


def test_prefill_flash_traced_kv_len_no_recompile():
    """Satellite fix: flash_attention's q_offset/kv_len ride as traced
    operands — distinct cached lengths share one compiled program and
    match the per-length results bitwise."""
    B, Tq, Tk, KV, G, d = 1, 8, 64, 2, 2, 16
    q = jax.random.normal(KEY, (B, Tq, KV, G, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 3), (B, Tk, KV, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 4), (B, Tk, KV, d))

    fn = jax.jit(
        lambda q, k, v, off, kl: flash_attention(
            q, k, v, q_offset=off, kv_len=kl, bq=8, bk=16
        )
    )
    outs = {}
    for off in (10, 30, 50):
        outs[off] = fn(
            q, k, v, jnp.int32(off), jnp.int32(off + Tq)
        ).block_until_ready()
    if hasattr(fn, "_cache_size"):
        assert fn._cache_size() == 1
    # each traced-length result equals the eager per-length call
    for off, got in outs.items():
        want = flash_attention(
            q, k, v, q_offset=off, kv_len=off + Tq, bq=8, bk=16
        )
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6
        )
