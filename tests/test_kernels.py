"""Per-kernel correctness: pallas_call (interpret=True on CPU) vs the
pure-jnp oracle across shape/dtype sweeps (deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.conv2d.ops import conv2d
from repro.kernels.conv2d.ref import conv2d_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.linear_scan.ops import linear_scan, wkv6
from repro.kernels.linear_scan.ref import linear_scan_ref, wkv6_ref
from repro.kernels.matmul.ops import matmul
from repro.kernels.matmul.ref import matmul_ref

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-4, atol=2e-4
    )


# ------------------------------------------------------------------ matmul


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "M,N,K", [(128, 128, 128), (256, 384, 512), (64, 128, 256), (100, 130, 70)]
)
def test_matmul_shapes(M, N, K, dtype):
    a = jax.random.normal(KEY, (M, K), dtype)
    b = jax.random.normal(jax.random.fold_in(KEY, 1), (K, N), dtype)
    got = matmul(a, b)
    want = matmul_ref(a, b)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


def test_matmul_explicit_tiles():
    from repro.core.mapper import MatmulTiles

    a = jax.random.normal(KEY, (256, 256), jnp.float32)
    b = jax.random.normal(KEY, (256, 256), jnp.float32)
    got = matmul(a, b, tiles=MatmulTiles(bm=64, bn=128, bk=128))
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(matmul_ref(a, b)), rtol=2e-4, atol=2e-4
    )


def test_matmul_tiles_fit_vmem():
    from repro.core.mapper import choose_matmul_tiles

    for M, N, K in [(4096, 14336, 4096), (512, 512, 512), (32768, 128, 4096)]:
        t = choose_matmul_tiles(M, N, K)
        assert t.vmem_bytes() <= 16 * 1024 * 1024
        assert t.bm % 8 == 0 and t.bn % 128 == 0 and t.bk % 128 == 0


# ----------------------------------------------------------------- conv2d


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,C,K,F", [(1, 8, 8, 16, 3), (2, 13, 16, 8, 3), (1, 6, 4, 4, 1),
                  (2, 10, 3, 5, 5)]
)
def test_conv2d_shapes(B, H, C, K, F, dtype):
    x = jax.random.normal(KEY, (B, H + F - 1, H + F - 1, C), dtype)
    w = jax.random.normal(jax.random.fold_in(KEY, 2), (F, F, C, K), dtype)
    got = conv2d(x, w)
    want = conv2d_ref(x, w)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


def test_conv2d_strided_fallback():
    x = jax.random.normal(KEY, (1, 11, 11, 4), jnp.float32)
    w = jax.random.normal(KEY, (3, 3, 4, 8), jnp.float32)
    got = conv2d(x, w, stride=2)
    want = conv2d_ref(x, w, stride=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


# --------------------------------------------------------- flash attention


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("Tq,Tk,window", [
    (128, 128, None), (256, 256, None), (128, 128, 32), (64, 192, None),
])
def test_flash_attention(Tq, Tk, window, dtype):
    B, KV, G, d = 2, 2, 2, 32
    q = jax.random.normal(KEY, (B, Tq, KV, G, d), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 3), (B, Tk, KV, d), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 4), (B, Tk, KV, d), dtype)
    got = flash_attention(q, k, v, window=window, bq=64, bk=64)
    # oracle on flattened heads
    qf = q.transpose(0, 2, 3, 1, 4).reshape(B * KV * G, Tq, d)
    kf = jnp.broadcast_to(
        k.transpose(0, 2, 1, 3)[:, :, None], (B, KV, G, Tk, d)
    ).reshape(B * KV * G, Tk, d)
    vf = jnp.broadcast_to(
        v.transpose(0, 2, 1, 3)[:, :, None], (B, KV, G, Tk, d)
    ).reshape(B * KV * G, Tk, d)
    want = flash_attention_ref(qf, kf, vf, window=window).reshape(
        B, KV, G, Tq, d
    ).transpose(0, 3, 1, 2, 4)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=3e-2, atol=3e-2,
    )


def test_flash_attention_q_offset_decode():
    """Cached decode: q at absolute offset attends causally over kv_len."""
    BH, Tk, d = 2, 128, 32
    q = jax.random.normal(KEY, (1, 8, 1, 2, d), jnp.float32)
    k = jax.random.normal(KEY, (1, Tk, 1, d), jnp.float32)
    v = jax.random.normal(KEY, (1, Tk, 1, d), jnp.float32)
    got = flash_attention(q, k, v, q_offset=100, kv_len=108, bq=8, bk=64)
    qf = q.transpose(0, 2, 3, 1, 4).reshape(2, 8, d)
    kf = jnp.broadcast_to(k.transpose(0, 2, 1, 3)[:, :, None], (1, 1, 2, Tk, d)).reshape(2, Tk, d)
    vf = jnp.broadcast_to(v.transpose(0, 2, 1, 3)[:, :, None], (1, 1, 2, Tk, d)).reshape(2, Tk, d)
    want = flash_attention_ref(qf, kf, vf, q_offset=100, kv_len=108).reshape(
        1, 1, 2, 8, d
    ).transpose(0, 3, 1, 2, 4)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
    )


def test_blockwise_attention_matches_dense():
    """The XLA blockwise path (model default) vs dense softmax."""
    from repro.arch.attention import blockwise_attention, dense_attention

    B, T, KV, G, d = 1, 96, 2, 2, 16
    q = jax.random.normal(KEY, (B, T, KV, G, d), jnp.float32)
    k = jax.random.normal(KEY, (B, T, KV, d), jnp.float32)
    v = jax.random.normal(KEY, (B, T, KV, d), jnp.float32)
    pos = jnp.arange(T)
    a = dense_attention(q, k, v, q_pos=pos, k_pos=pos)
    b = blockwise_attention(q, k, v, q_pos=pos, k_pos=pos, block_q=32,
                            block_k=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                               atol=2e-4)


# -------------------------------------------------------------- linear scan


@pytest.mark.parametrize("T,D", [(16, 64), (33, 256), (128, 128)])
def test_linear_scan(T, D):
    B = 2
    a = jax.nn.sigmoid(jax.random.normal(KEY, (B, T, D)))
    x = jax.random.normal(jax.random.fold_in(KEY, 5), (B, T, D))
    h0 = jax.random.normal(jax.random.fold_in(KEY, 6), (B, D))
    got, hT = linear_scan(a, x, h0)
    want, hT_want = linear_scan_ref(a, x, h0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hT_want), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("T,Dk,Dv", [(8, 16, 16), (32, 64, 64), (17, 32, 64)])
def test_wkv6_kernel(T, Dk, Dv):
    B, H = 2, 3
    r = jax.random.normal(KEY, (B, H, T, Dk))
    k = jax.random.normal(jax.random.fold_in(KEY, 7), (B, H, T, Dk))
    v = jax.random.normal(jax.random.fold_in(KEY, 8), (B, H, T, Dv))
    w = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(KEY, 9), (B, H, T, Dk)))
    u = jax.random.normal(jax.random.fold_in(KEY, 10), (H, Dk))
    s0 = jax.random.normal(jax.random.fold_in(KEY, 11), (B, H, Dk, Dv))
    got, sT = wkv6(r, k, v, w, u, s0)
    want, sT_want = wkv6_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sT_want), rtol=2e-4,
                               atol=2e-4)


def test_wkv_scan_model_path_matches_kernel():
    """arch/rwkv.wkv_scan (chunked remat scan) vs the Pallas wkv6 kernel."""
    from repro.arch.rwkv import wkv_scan

    B, T, H, D = 1, 40, 2, 16
    r = jax.random.normal(KEY, (B, T, H, D))
    k = jax.random.normal(jax.random.fold_in(KEY, 12), (B, T, H, D))
    v = jax.random.normal(jax.random.fold_in(KEY, 13), (B, T, H, D))
    w = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(KEY, 14), (B, T, H, D)))
    u = jax.random.normal(jax.random.fold_in(KEY, 15), (H, D))
    s0 = jnp.zeros((B, H, D, D))
    out_model, s_model = wkv_scan(r, k, v, w, u, s0, chunk=16)
    tfirst = lambda z: z.transpose(0, 2, 1, 3)
    out_kern, s_kern = wkv6(tfirst(r), tfirst(k), tfirst(v), tfirst(w), u, s0)
    np.testing.assert_allclose(
        np.asarray(tfirst(out_model)), np.asarray(out_kern), rtol=2e-4, atol=2e-4
    )
    np.testing.assert_allclose(
        np.asarray(s_model), np.asarray(s_kern), rtol=2e-4, atol=2e-4
    )


def test_blockwise_causal_skip_matches():
    """§Perf causal_skip variant must be numerically identical."""
    from repro.arch.attention import blockwise_attention

    B, T, KV, G, d = 1, 128, 2, 2, 16
    q = jax.random.normal(KEY, (B, T, KV, G, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 20), (B, T, KV, d), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(KEY, 21), (B, T, KV, d), jnp.float32)
    pos = jnp.arange(T)
    a = blockwise_attention(q, k, v, q_pos=pos, k_pos=pos, block_q=32,
                            block_k=32, causal_skip=False)
    b = blockwise_attention(q, k, v, q_pos=pos, k_pos=pos, block_q=32,
                            block_k=32, causal_skip=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.slow
def test_remat_policy_dots_same_loss():
    """remat_policy='dots' changes memory, not math."""
    import dataclasses

    from repro.arch.model_zoo import build
    from repro.configs.registry import get

    cfg = get("smollm-360m-smoke")
    cfg2 = dataclasses.replace(cfg, remat_policy="dots")
    m1, m2 = build(cfg), build(cfg2)
    params = m1.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    l1 = m1.loss(params, toks, toks)
    l2 = m2.loss(params, toks, toks)
    assert float(l1) == pytest.approx(float(l2), abs=1e-3)
    g1 = jax.grad(lambda p: m1.loss(p, toks, toks))(params)
    g2 = jax.grad(lambda p: m2.loss(p, toks, toks))(params)
    d = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        g1, g2,
    )
    assert max(jax.tree.leaves(d)) < 1e-2
