"""Seeded fault-injection chaos episodes (serve/chaos.py).

Each episode drives a random workload through a reused engine while a
seeded schedule injects cancels, double-cancels, deadline storms, forced
preemptions, and external block-pressure spikes; ownership invariants are
audited after every step and the drained end state must agree bitwise
with an unfaulted oracle (see serve/chaos.py's module docstring).

The in-suite default is a small episode count; the acceptance matrix is
``make test-chaos`` (CHAOS_EPISODES=200), and CI shards the seed space via
CHAOS_SEED.  Any failure prints the episode seed; replay it locally with
``CHAOS_EPISODES=1 CHAOS_SEED=<seed> make test-chaos``.
"""

import dataclasses

import jax
import numpy as np
import pytest

from conftest import chaos_episodes, chaos_seed, recovery_episodes
from repro.arch.model_zoo import build
from repro.configs.registry import get
from repro.serve import chaos
from repro.serve.engine import Engine, RequestStatus, ServeConfig

MAX_LEN = 64
BS = 8


@pytest.fixture(scope="module")
def smol():
    cfg = get("smollm-360m-smoke")
    params = build(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _setups(cfg, params):
    """Four reused (faulted engine, oracle engine) pairs: an ample paged
    pool, a block-starved paged pool (admission waits and preemption must
    free real capacity), the contiguous engine (the lifecycle layer is
    layout-agnostic), and the chunked unified scheduler (budget-bound
    prefill lanes mid-flight across steps — cancels/preemptions/spikes
    land on PREFILLING requests too).  Oracles pin the contiguous decode
    split to the paged block size, the PR-5 bitwise-differential idiom."""
    common = dict(
        batch=3,
        max_len=MAX_LEN,
        temperature=0.7,
        seed=5,
        prefill_bucket=16,
    )
    oracle_scfg = ServeConfig(attention="flash", decode_block=BS, **common)
    paged = dict(kv_layout="paged", block_size=BS, **common)
    return [
        (
            "paged-ample",
            Engine(cfg, params, ServeConfig(stall_patience=6, **paged)),
            Engine(cfg, params, oracle_scfg),
        ),
        (
            "paged-chunked",
            Engine(
                cfg,
                params,
                ServeConfig(
                    prefill_chunk=BS,
                    token_budget=BS,
                    stall_patience=6,
                    **paged,
                ),
            ),
            Engine(cfg, params, oracle_scfg),
        ),
        (
            # 11 usable blocks (88 tokens) against 3 slots wanting up to
            # 64 each: admission is perpetually block-starved, spikes can
            # drain the pool to zero, the watchdog must shed, and
            # priority preemption is the only way heads ever jump
            "paged-starved",
            Engine(
                cfg,
                params,
                ServeConfig(
                    num_blocks=12, stall_patience=4, max_waiting=8, **paged
                ),
            ),
            Engine(cfg, params, oracle_scfg),
        ),
        (
            "contiguous",
            Engine(cfg, params, ServeConfig(stall_patience=6, **common)),
            Engine(cfg, params, ServeConfig(**common)),
        ),
    ]


@pytest.mark.chaos
def test_chaos_episode_matrix(smol):
    cfg, params = smol
    setups = _setups(cfg, params)
    n = chaos_episodes(24)
    base = chaos_seed()
    ccfg = chaos.ChaosConfig()
    reports = []
    for ep in range(n):
        name, eng, oracle_eng = setups[ep % len(setups)]
        seed = base + 1000 + ep
        rng = np.random.default_rng(seed)
        reqs = chaos.make_chaos_workload(rng, cfg.vocab, MAX_LEN, ccfg)
        oracle = chaos.oracle_outputs(oracle_eng, reqs)
        reports.append(chaos.run_episode(eng, oracle, reqs, seed, ccfg))

    # every fault class must actually have fired somewhere in the matrix —
    # a chaos suite whose faults never land is a green light worth nothing
    total = {}
    for rep in reports:
        for k, v in rep.stats.items():
            total[k] = total.get(k, 0) + v
    assert total["cancelled"] > 0, "no cancellation ever fired"
    assert total["preempted"] > 0, "no preemption ever fired"
    assert total["recovered"] > 0, "no preempted request ever recovered"
    assert total["expired"] > 0, "no deadline ever expired"
    finished = sum(r.statuses.get("FINISHED", 0) for r in reports)
    assert finished > 0, "no request ever survived the chaos"


@pytest.mark.chaos
def test_chaos_episode_replays_identically(smol):
    """An episode is a pure function of (engine config, seed): the same
    seed must produce the same steps, statuses, and lifecycle counters —
    this is what makes a CI chaos failure reproducible from its seed."""
    cfg, params = smol
    ccfg = chaos.ChaosConfig()
    seed = chaos_seed() + 77

    def once():
        eng = Engine(
            cfg,
            params,
            ServeConfig(
                batch=3,
                max_len=MAX_LEN,
                kv_layout="paged",
                block_size=BS,
                temperature=0.7,
                seed=5,
                prefill_bucket=16,
                stall_patience=6,
            ),
        )
        oracle_eng = Engine(
            cfg,
            params,
            ServeConfig(
                batch=3,
                max_len=MAX_LEN,
                attention="flash",
                decode_block=BS,
                temperature=0.7,
                seed=5,
                prefill_bucket=16,
            ),
        )
        rng = np.random.default_rng(seed)
        reqs = chaos.make_chaos_workload(rng, cfg.vocab, MAX_LEN, ccfg)
        oracle = chaos.oracle_outputs(oracle_eng, reqs)
        return chaos.run_episode(eng, oracle, reqs, seed, ccfg)

    a, b = once(), once()
    assert (a.steps, a.statuses, a.stats) == (b.steps, b.statuses, b.stats)


@pytest.mark.recovery
def test_crash_restart_episode_matrix(smol, tmp_path):
    """Kill-and-restore chaos: every episode builds a durable engine
    (snapshot + journal on disk), drives it through the standard fault
    schedule, simulates a process kill at a seed-drawn step (sometimes
    also flipping bytes in the newest snapshot), restores, and finishes
    the workload — auditing ownership every step and requiring bitwise
    oracle agreement for every surviving request.  Default episode count
    is small (each episode compiles a fresh engine pair); CI cranks it
    via ``make test-recovery`` (RECOVERY_EPISODES) across the CHAOS_SEED
    matrix."""
    cfg, params = smol
    common = dict(
        batch=3, max_len=MAX_LEN, temperature=0.7, seed=5, prefill_bucket=16
    )
    paged = dict(kv_layout="paged", block_size=BS, **common)
    durable = dict(snapshot_every=4, snapshot_keep=2)
    setups = [
        ("paged-ample", ServeConfig(stall_patience=6, **paged, **durable)),
        (
            "paged-chunked",
            ServeConfig(
                prefill_chunk=BS,
                token_budget=BS,
                stall_patience=6,
                **paged,
                **durable,
            ),
        ),
        (
            "paged-starved",
            ServeConfig(
                num_blocks=12,
                stall_patience=4,
                max_waiting=8,
                **paged,
                **durable,
            ),
        ),
        ("contiguous", ServeConfig(stall_patience=6, **common, **durable)),
    ]
    oracle_eng = Engine(
        cfg, params, ServeConfig(attention="flash", decode_block=BS, **common)
    )
    n = recovery_episodes(2)
    base = chaos_seed()
    ccfg = chaos.ChaosConfig()
    reports = []
    for ep in range(n):
        name, scfg = setups[ep % len(setups)]
        seed = base + 1000 + ep
        rng = np.random.default_rng(seed)
        reqs = chaos.make_chaos_workload(rng, cfg.vocab, MAX_LEN, ccfg)
        oracle = chaos.oracle_outputs(oracle_eng, reqs)
        scfg = dataclasses.replace(
            scfg, snapshot_dir=str(tmp_path / f"ep{ep:03d}")
        )
        reports.append(
            chaos.run_crash_episode(
                cfg, params, scfg, oracle, reqs, seed, ccfg
            )
        )
    assert all(r.steps > 0 for r in reports)
    assert any(r.source in ("snapshot", "cold") for r in reports), (
        "no episode ever restored anything"
    )
    finished = sum(r.statuses.get("FINISHED", 0) for r in reports)
    assert finished > 0, "no request ever survived a crash"
    if n >= 3:
        assert any(r.source == "snapshot" for r in reports), (
            "no episode restored from a snapshot"
        )
        assert any(r.tokens_replayed > 0 for r in reports), (
            "no episode replayed journaled tokens"
        )


@pytest.mark.chaos
def test_chaos_spike_starves_then_recovers(smol):
    """Deterministic spike scenario: an external reservation takes the
    whole pool mid-flight; admission stalls (requests wait, nothing is
    corrupted), and releasing the reservation lets the queue drain with
    bitwise-intact outputs."""
    cfg, params = smol
    eng = Engine(
        cfg,
        params,
        ServeConfig(
            batch=2,
            max_len=MAX_LEN,
            kv_layout="paged",
            block_size=BS,
            prefill_bucket=16,
            stall_patience=100,  # out of reach: the stall must NOT shed
        ),
    )
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, 20).astype(np.int32) for _ in range(3)]
    from repro.serve.engine import Request

    for i, p in enumerate(prompts):
        eng.submit(Request(p, 6, request_id=i))
    eng.step()  # admit what fits
    held = eng.pool.reserve(eng.pool.free_blocks)  # drain the pool
    for _ in range(8):
        eng.step()
        # keep the pool at zero: grab blocks the moment finishers free them
        held += eng.pool.reserve(eng.pool.free_blocks)
        chaos.audit(eng)
    assert eng.status(2) == RequestStatus.WAITING, "admission should stall"
    eng.pool.unreserve(held)
    while eng.step():
        chaos.audit(eng)
    assert eng.status(2) == RequestStatus.FINISHED
    solo = Engine(
        cfg,
        params,
        ServeConfig(
            batch=2,
            max_len=MAX_LEN,
            attention="flash",
            decode_block=BS,
            prefill_bucket=16,
        ),
    ).run([Request(prompts[2], 6, request_id=2)])[0]
    assert np.array_equal(eng.pop_result(2), solo)
    assert eng.pool.free_blocks == eng.pool.num_blocks - 1
