"""Validation of the analytical access model against the exact simulator.

This is the repo's analogue of the paper's Fig 7 (<2% vs post-synthesis):
here the agreement is exact by construction of the stationarity semantics,
checked on hand-built schedules and hypothesis-randomized ones.
"""


import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.loopnest import conv_nest, fc_nest, matmul_nest
from repro.core.reuse import analyze
from repro.core.schedule import MemLevel, Schedule
from repro.core.simulate import simulate

LEVELS3 = (
    MemLevel("RF", 512, double_buffered=False, per_pe=True),
    MemLevel("BUF", 128 * 1024),
    MemLevel("DRAM", None),
)


def _assert_match(sched: Schedule):
    a = analyze(sched)
    s = simulate(sched)
    assert a.reads == s.reads, f"reads mismatch\n{a.reads}\nvs sim\n{s.reads}"
    assert a.writes == s.writes, f"writes mismatch\n{a.writes}\nvs sim\n{s.writes}"


def test_conv_basic():
    nest = conv_nest("t", B=2, K=4, C=3, X=4, Y=4, FX=3, FY=3)
    tiling = {
        "B": (1, 2, 1), "K": (2, 1, 2), "C": (1, 3, 1), "Y": (2, 2, 1),
        "X": (1, 4, 1), "FY": (3, 1, 1), "FX": (3, 1, 1),
    }
    order = (("FX", "FY", "C", "X", "Y", "K", "B"),) * 3
    _assert_match(Schedule(nest=nest, levels=LEVELS3, tiling=tiling, order=order))


def test_output_stationary_order():
    nest = matmul_nest("mm", M=4, N=4, K=8)
    tiling = {"M": (2, 1, 2), "N": (2, 2, 1), "K": (2, 2, 2)}
    # K innermost at every level -> output stationary
    order = (("K", "M", "N"),) * 3
    _assert_match(Schedule(nest=nest, levels=LEVELS3, tiling=tiling, order=order))


def test_weight_stationary_order():
    nest = matmul_nest("mm", M=8, N=4, K=4)
    tiling = {"M": (2, 2, 2), "N": (1, 4, 1), "K": (2, 1, 2)}
    order = (("M", "K", "N"), ("M", "N", "K"), ("N", "M", "K"))
    _assert_match(Schedule(nest=nest, levels=LEVELS3, tiling=tiling, order=order))


def test_fc_layer():
    nest = fc_nest("fc", B=4, C=8, K=8)
    tiling = {
        "B": (2, 2, 1), "K": (2, 2, 2), "C": (2, 1, 4),
        "X": (1, 1, 1), "Y": (1, 1, 1), "FX": (1, 1, 1), "FY": (1, 1, 1),
    }
    order = (("C", "K", "B", "X", "Y", "FX", "FY"),) * 3
    _assert_match(Schedule(nest=nest, levels=LEVELS3, tiling=tiling, order=order))


def test_four_level_hierarchy():
    nest = conv_nest("t", B=2, K=4, C=4, X=4, Y=2, FX=1, FY=1)
    levels = (
        MemLevel("RF0", 32, double_buffered=False, per_pe=True),
        MemLevel("RF1", 256, double_buffered=False, per_pe=True),
        MemLevel("BUF", 64 * 1024),
        MemLevel("DRAM", None),
    )
    tiling = {
        "B": (1, 2, 1, 1), "K": (2, 1, 2, 1), "C": (1, 2, 1, 2),
        "Y": (2, 1, 1, 1), "X": (1, 2, 2, 1),
        "FY": (1, 1, 1, 1), "FX": (1, 1, 1, 1),
    }
    order = (
        ("K", "C", "B", "X", "Y", "FX", "FY"),
        ("C", "B", "X", "K", "Y", "FX", "FY"),
        ("X", "K", "C", "B", "Y", "FX", "FY"),
        ("B", "C", "K", "X", "Y", "FX", "FY"),
    )
    _assert_match(Schedule(nest=nest, levels=levels, tiling=tiling, order=order))


# ------------------------------------------------------ property-based sweep


def _factor_splits(draw, bound: int, n_levels: int) -> tuple[int, ...]:
    """Random split of `bound` into n_levels factors (product == bound)."""
    factors = []
    rem = bound
    for _ in range(n_levels - 1):
        divs = [d for d in range(1, rem + 1) if rem % d == 0]
        f = draw(st.sampled_from(divs))
        factors.append(f)
        rem //= f
    factors.append(rem)
    return tuple(factors)


@st.composite
def random_schedule(draw):
    dims = {
        "B": draw(st.sampled_from([1, 2, 3])),
        "K": draw(st.sampled_from([1, 2, 4])),
        "C": draw(st.sampled_from([1, 2, 3])),
        "X": draw(st.sampled_from([1, 2, 4])),
        "Y": draw(st.sampled_from([1, 2])),
        "FX": draw(st.sampled_from([1, 3])),
        "FY": draw(st.sampled_from([1, 2])),
    }
    nest = conv_nest("rand", **dims)
    n_levels = draw(st.sampled_from([2, 3, 4]))
    per_pe_depth = 1 if n_levels < 4 else draw(st.sampled_from([1, 2]))
    levels = tuple(
        MemLevel(f"L{i}", None, double_buffered=False, per_pe=(i < per_pe_depth))
        for i in range(n_levels)
    )
    tiling = {d: _factor_splits(draw, b, n_levels) for d, b in dims.items()}
    orders = tuple(
        tuple(draw(st.permutations(list(dims)))) for _ in range(n_levels)
    )
    return Schedule(nest=nest, levels=levels, tiling=tiling, order=orders)


@settings(max_examples=120, deadline=None)
@given(random_schedule())
def test_model_matches_simulator(sched):
    _assert_match(sched)


def test_rf_counts_scale_with_pes():
    """Per-PE levels multiply by active PE count (paper: every MAC fetches
    operands from its own RF)."""
    from repro.core.dataflow import make_dataflow
    from repro.core.schedule import ArraySpec

    nest = conv_nest("t", B=2, K=8, C=8, X=4, Y=4, FX=1, FY=1)
    arr = ArraySpec(dims=(2, 2))
    df = make_dataflow(nest, arr, ("C", "K"), replication=False)
    tiling = {
        "B": (1, 1, 2), "K": (2, 2, 1), "C": (1, 2, 2),
        "X": (2, 2, 1), "Y": (4, 1, 1), "FX": (1, 1, 1), "FY": (1, 1, 1),
    }
    order = (tuple(nest.dims),) * 3
    s = Schedule(
        nest=nest, levels=LEVELS3, tiling=tiling, order=order,
        array=arr, spatial=df.assigns,
    )
    acc = analyze(s)
    # total level-0 reads for I must equal reloads * used_pes
    assert s.used_pes() == 4
    per_pe_macs = s.temporal_trips()
    assert acc.reads[0]["I"] <= per_pe_macs * 4
    assert acc.reads[0]["I"] >= per_pe_macs  # at least one PE's worth
    # MAC-level accounting: total I reads across PEs == padded MACs when no
    # innermost stationarity
    total_macs = s.padded_macs()
    assert acc.reads[0]["W"] <= total_macs
