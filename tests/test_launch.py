"""Launch/dry-run machinery tests: spec builders, HLO collective parser,
cell accounting, and one real (subprocess) dry-run cell."""

import json
import os
import subprocess
import sys

import jax
import pytest

from repro.configs.base import SHAPES
from repro.configs.registry import get
from repro.launch.dryrun import collective_bytes, scan_unit, variant_cfg
from repro.launch.specs import (
    cell_is_live,
    choose_microbatches,
    input_specs,
    live_cells,
    params_shapes,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_live_cells_count():
    cells = live_cells()
    # 10 archs x 4 shapes - 7 long_500k skips (only gemma3/rwkv6/
    # recurrentgemma are sub-quadratic) = 33
    assert len(cells) == 33
    longs = [a for a, s in cells if s == "long_500k"]
    assert sorted(longs) == ["gemma3-12b", "recurrentgemma-2b",
                             "rwkv6-1.6b"]


def test_collective_parser():
    hlo = """
  %ar = f32[128,256]{1,0} all-reduce(f32[128,256] %x), replica_groups={}
  %ag.1 = bf16[64,512]{1,0} all-gather-start(bf16[64,32] %y)
  %cp = u8[1024]{0} collective-permute(u8[1024] %z)
  %notacoll = f32[4]{0} add(f32[4] %a, f32[4] %b)
"""
    got = collective_bytes(hlo)
    assert got["all-reduce"] == 2 * 128 * 256 * 4
    assert got["all-gather"] == 64 * 512 * 2
    assert got["collective-permute"] == 1024
    assert got["total"] == sum(
        got[c] for c in
        ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
         "collective-permute")
    )


def test_microbatch_choice_bounds_memory():
    cfg = get("grok-1-314b")
    mb = choose_microbatches(cfg, SHAPES["train_4k"], n_dp=32)
    b_local = 256 // 32
    resid = cfg.n_layers * (b_local // mb) * 4096 * cfg.d_model * 2
    # fits the budget, or microbatching is already maxed (1 seq/device)
    assert resid <= 2 * 1024**3 or mb == b_local
    # small model needs no microbatching
    assert choose_microbatches(get("smollm-360m"), SHAPES["train_4k"], 32) <= 2


@pytest.mark.parametrize("arch", ["granite-8b", "whisper-medium",
                                  "llava-next-34b", "rwkv6-1.6b"])
def test_input_specs_shapes(arch):
    sp = input_specs(arch, "train_4k", n_dp=32)
    tok = sp["batch"]["tokens"]
    assert tok.shape[0] * tok.shape[1] == 256  # mb x bm == global batch
    assert tok.shape[2] == 4096
    cfg = get(arch)
    if cfg.family == "encdec":
        assert sp["batch"]["frames"].shape[-2] == cfg.encoder_seq
    if cfg.family == "vlm":
        assert sp["batch"]["patches"].shape[-2] == cfg.n_patches

    spd = input_specs(arch, "decode_32k", n_dp=32)
    assert spd["batch"]["tokens"].shape == (128, 1)
    assert "caches" in spd


def test_variant_cfg_scales_layers():
    cfg = get("granite-8b")
    assert variant_cfg(cfg, 2, scan_unit(cfg)).n_layers == 2
    w = get("whisper-medium")
    v = variant_cfg(w, 1, scan_unit(w))
    assert v.n_layers == 1 and v.encoder_layers == 1
    h = get("recurrentgemma-2b")
    u = (h.rnn_per_attention + 1)
    assert variant_cfg(h, 2, u).n_layers == 2 * u


def test_params_shapes_no_allocation():
    import math

    shapes = params_shapes(get("grok-1-314b"))
    total = sum(
        math.prod(l.shape) for l in jax.tree.leaves(shapes)
    )
    assert total > 250e9  # ~314B params without ever allocating


def test_model_flops_sanity():
    from benchmarks.roofline import model_flops

    train = model_flops("granite-8b", "train_4k")
    prefill = model_flops("granite-8b", "prefill_32k")
    decode = model_flops("granite-8b", "decode_32k")
    assert train > prefill > decode > 0
    # MoE active < dense at same scale
    g = get("grok-1-314b")
    assert g.active_params_count() < g.params_count() / 2


@pytest.mark.slow
def test_dryrun_single_cell_subprocess(tmp_path):
    """Real dry-run cell end-to-end (512 fake devices in a subprocess)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "smollm-360m", "--shape", "prefill_32k",
         "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=560,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    rec = json.load(
        open(tmp_path / "smollm-360m__prefill_32k__16x16.json")
    )
    assert rec["cost_per_device"]["flops"] > 0
    assert rec["memory"]["peak_estimate_bytes"] < 16 * 1024**3
