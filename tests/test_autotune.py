"""Serve-config planner + tuning-path cache tests.

Four layers of guarantees:

  * the on-disk matmul-tile cache validates entries before serving them — a
    corrupt/stale value falls back to the blocking search and is overwritten
    (regression for the trust-any-3-int bug);
  * the scalar (energy.attention_gather_cost) and vectorized
    (costmodel.attention_gather_words) decode-gather counts agree, the
    PR-wide scalar/vector parity idiom;
  * core/serveplan.py: the sweep respects the iso-HBM budget, planning is
    deterministic, the plan cache round-trips and re-plans over corrupt
    entries, calibration fits recover known overheads, and admission-bound
    token budgets cap occupancy;
  * ServeConfig.autotune() yields a config a real Engine serves with.
"""

import json
import os
import random

import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import energy as en
from repro.core import mapper
from repro.core import serveplan as sp
from repro.core.costmodel import attention_gather_words
from repro.core.jsonstore import load_json_dict


def _tiny_model(**kw) -> ModelConfig:
    base = dict(
        name="plan-tiny", family="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=256,
    )
    base.update(kw)
    return ModelConfig(**base)


# ------------------------------------------------ tile-cache validation ----


def _tile_key(M, N, K, vmem_bytes, dtype_bytes=2):
    return (
        f"{mapper._TILE_CACHE_SCHEMA}:{M},{N},{K},{vmem_bytes},{dtype_bytes}"
    )


@pytest.mark.parametrize(
    "bad",
    [
        [0, 128, 128],            # bm=0: divides the kernel grid by zero
        [-8, 128, 128],           # negative
        [12, 128, 128],           # bm not a SUBLANES multiple
        [8, 100, 128],            # bn not a LANES multiple
        [8, 128, 1 << 20],        # VMEM overflow
        [1 << 20, 128, 128],      # larger than the padded problem
        ["x", 128, 128],          # non-numeric entry
    ],
    ids=["zero", "negative", "sublane", "lane", "vmem", "oversize", "type"],
)
def test_tile_cache_rejects_corrupt_entry(tmp_path, monkeypatch, bad):
    """Regression (pre-fix: any 3-int on-disk entry was trusted verbatim):
    a corrupt tile-cache value must fall back to the search and be
    overwritten with the searched tile."""
    path = tmp_path / "tiles.json"
    M, N, K, vmem = 8, 256, 256, en.TPU_VMEM_BYTES // 4
    key = _tile_key(M, N, K, vmem)
    path.write_text(json.dumps({key: bad}))
    monkeypatch.setenv("REPRO_TILE_CACHE", str(path))
    mapper.choose_matmul_tiles.cache_clear()
    t = mapper.choose_matmul_tiles(M, N, K, vmem)
    assert mapper._valid_cached_tile(t, M, N, K, vmem, 2), t
    assert [t.bm, t.bn, t.bk] != bad
    # and the bad entry was overwritten with the searched one
    assert load_json_dict(str(path))[key] == [t.bm, t.bn, t.bk]


def test_tile_cache_serves_valid_entry(tmp_path, monkeypatch):
    """A legitimate cached tile is served as-is (no re-search churn)."""
    path = tmp_path / "tiles.json"
    M, N, K, vmem = 8, 256, 256, en.TPU_VMEM_BYTES // 4
    key = _tile_key(M, N, K, vmem)
    path.write_text(json.dumps({key: [8, 128, 128]}))
    monkeypatch.setenv("REPRO_TILE_CACHE", str(path))
    mapper.choose_matmul_tiles.cache_clear()
    t = mapper.choose_matmul_tiles(M, N, K, vmem)
    assert (t.bm, t.bn, t.bk) == (8, 128, 128)


def test_search_results_pass_their_own_validator(tmp_path, monkeypatch):
    """The search must never store a tile its own validator rejects (else
    every process re-searches forever); exercised across shapes including
    ones whose VMEM-overflow shrink loop used to break alignment."""
    monkeypatch.setenv("REPRO_TILE_CACHE", str(tmp_path / "tiles.json"))
    mapper.choose_matmul_tiles.cache_clear()
    rng = random.Random(7)
    for _ in range(20):
        M = rng.randrange(1, 64)
        N = rng.randrange(1, 2048)
        K = rng.randrange(1, 2048)
        vmem = rng.choice([1 << 16, 1 << 18, 1 << 20])
        t = mapper.choose_matmul_tiles(M, N, K, vmem)
        assert mapper._valid_cached_tile(t, M, N, K, vmem, 2), (M, N, K, vmem, t)


# ---------------------------------------------- gather-count parity --------


def test_attention_gather_scalar_vector_parity():
    """costmodel.attention_gather_words == energy.attention_gather_cost
    elementwise over random (ctx, block_size, splits) grids."""
    rng = random.Random(1234)
    for _ in range(50):
        ctx = rng.randrange(1, 512)
        bs = rng.choice([1, 8, 16, 32, 64])
        kv_heads = rng.choice([1, 2, 8])
        head_dim = rng.choice([32, 64, 128])
        splits = rng.choice([None, 1, 4, 64])
        want = en.attention_gather_cost(
            ctx, block_size=bs, kv_heads=kv_heads, head_dim=head_dim,
            kv_splits=splits,
        ).words
        got = attention_gather_words(
            ctx, bs, kv_heads=kv_heads, head_dim=head_dim, kv_splits=splits
        )
        assert int(got) == want
    # vectorized over a grid in one call
    ctxs = np.array([1, 31, 32, 33, 500])
    got = attention_gather_words(ctxs, 32, kv_heads=2, head_dim=64)
    want = [
        en.attention_gather_cost(int(c), block_size=32, kv_heads=2,
                                 head_dim=64).words
        for c in ctxs
    ]
    assert got.tolist() == want


def test_attention_gather_fragmentation_monotone():
    """Bigger blocks never reduce the KV read: the tail block is read whole,
    so kv_words is non-decreasing in block_size at fixed context (totals
    can dip because fewer splits mean fewer softmax partials)."""
    ctx = 100
    kv_words = [
        en.attention_gather_cost(ctx, block_size=bs, kv_heads=2,
                                 head_dim=64).kv_words
        for bs in (8, 16, 32, 64)
    ]
    assert kv_words == sorted(kv_words)


# ------------------------------------------------------- serveplan ---------


def test_sweep_respects_iso_hbm_budget():
    """Every swept point's usable KV pool fits the shared token budget —
    the iso-HBM discipline that makes candidates comparable."""
    cfg = _tiny_model()
    budget = 8 * 64
    pts = sp.sweep_serve_space(cfg, max_len=64, kv_budget_tokens=budget)
    assert len(pts) >= 8
    for p in pts:
        k = p.knobs
        if k.kv_layout == "paged":
            usable_tokens = (k.num_blocks - 1) * k.block_size
        else:
            usable_tokens = k.slots * 64
        assert usable_tokens <= budget, (k, usable_tokens)
        assert p.cost.rows >= 1
        assert p.us_per_token > 0 and p.ttft_ms > 0


def test_plan_deterministic_and_cache_roundtrip(tmp_path):
    cfg = _tiny_model()
    path = str(tmp_path / "plans.json")
    p1 = sp.plan_serve(cfg, max_len=64, cache=path)
    p2 = sp.plan_serve(cfg, max_len=64, cache=path)
    p3 = sp.plan_serve(cfg, max_len=64, cache=False)
    assert p1.source == "search" and p2.source == "cache"
    assert p1.knobs == p2.knobs == p3.knobs
    assert p2.predicted["tokens_per_s"] == pytest.approx(
        p1.predicted["tokens_per_s"]
    )


def test_plan_cache_corrupt_entry_replans(tmp_path):
    """A corrupt/stale plan entry must be re-searched and overwritten, the
    same defense the tile cache applies."""
    cfg = _tiny_model()
    path = str(tmp_path / "plans.json")
    p1 = sp.plan_serve(cfg, max_len=64, cache=path)
    data = load_json_dict(path)
    (key,) = data.keys()
    data[key]["knobs"]["block_size"] = -5  # corrupt in place
    with open(path, "w") as f:
        json.dump(data, f)
    p2 = sp.plan_serve(cfg, max_len=64, cache=path)
    assert p2.source == "search"
    assert p2.knobs == p1.knobs
    # and the entry on disk is healthy again
    assert load_json_dict(path)[key]["knobs"]["block_size"] == p1.knobs.block_size


def test_plan_key_separates_workloads(tmp_path):
    """Different (workload, budget) tuples get different cache slots."""
    cfg = _tiny_model()
    path = str(tmp_path / "plans.json")
    sp.plan_serve(cfg, max_len=64, cache=path)
    sp.plan_serve(
        cfg, max_len=64, cache=path,
        workload=sp.ServeWorkload(concurrency=2, prompt_len=8, decode_len=8),
    )
    assert len(load_json_dict(path)) == 2


def test_knob_validation_rejects_garbage():
    good = sp.ServeKnobs(slots=4, kv_layout="paged", block_size=16,
                         num_blocks=17)
    good.validate(64)
    bad = [
        sp.ServeKnobs(slots=0, block_size=16),
        sp.ServeKnobs(slots=4, kv_layout="ring", block_size=16),
        sp.ServeKnobs(slots=4, block_size=48),          # 64 % 48 != 0
        sp.ServeKnobs(slots=4, block_size=16, num_blocks=1),
        sp.ServeKnobs(slots=4, kv_layout="contiguous", block_size=16,
                      num_blocks=8),
        sp.ServeKnobs(slots=4, block_size=16, prefill_chunk=0,
                      token_budget=16),
        sp.ServeKnobs(slots=4, block_size=16, prefill_chunk=32,
                      token_budget=16),
    ]
    for k in bad:
        with pytest.raises(ValueError):
            k.validate(64)


def test_calibration_fit_recovers_overhead():
    cfg = _tiny_model()
    mk = lambda slots: sp.price_decode_step(
        cfg,
        sp.ServeKnobs(slots=slots, kv_layout="contiguous", block_size=16),
        max_len=64, workload=sp.ServeWorkload(),
    )
    cost = mk(4)
    assert cost is not None
    # one anchor: pure overhead
    calib = sp.Calibration.fit([(cost, cost.roofline_s + 3e-3)])
    assert calib.step_overhead_s == pytest.approx(3e-3)
    assert calib.per_row_s == 0.0
    # two anchors at different rows: overhead + per-row slope
    cost8 = mk(8)
    pairs = [
        (cost, cost.roofline_s + 2e-3 + 1e-4 * cost.rows),
        (cost8, cost8.roofline_s + 2e-3 + 1e-4 * cost8.rows),
    ]
    calib2 = sp.Calibration.fit(pairs)
    assert calib2.step_overhead_s == pytest.approx(2e-3, rel=1e-6)
    assert calib2.per_row_s == pytest.approx(1e-4, rel=1e-6)
    # a measured step can't beat its roofline: negative residuals clamp
    calib3 = sp.Calibration.fit([(cost, cost.roofline_s * 0.5)])
    assert calib3.step_overhead_s == 0.0


def test_calibration_fit_paged_and_chunked_terms():
    """Anchors spanning layout and lane features recover the
    per-gathered-block and chunked-lane surcharges exactly."""
    cfg = _tiny_model()
    wl = sp.ServeWorkload()
    mk = lambda **kw: sp.price_decode_step(
        cfg, sp.ServeKnobs(**kw), max_len=64, workload=wl
    )
    anchors = [
        mk(slots=2, kv_layout="contiguous", block_size=16),
        mk(slots=16, kv_layout="contiguous", block_size=16),
        mk(slots=16, kv_layout="paged", block_size=16, num_blocks=65),
        mk(slots=16, kv_layout="paged", block_size=16, num_blocks=65,
           prefill_chunk=16, token_budget=16),
    ]
    true = sp.Calibration(
        step_overhead_s=1e-3, per_row_s=5e-5, per_block_s=2e-5,
        chunk_overhead_s=4e-4,
    )
    pairs = [(c, c.step_s(true)) for c in anchors]
    got = sp.Calibration.fit(pairs)
    assert got.step_overhead_s == pytest.approx(1e-3, rel=1e-6)
    assert got.per_row_s == pytest.approx(5e-5, rel=1e-6)
    assert got.per_block_s == pytest.approx(2e-5, rel=1e-6)
    assert got.chunk_overhead_s == pytest.approx(4e-4, rel=1e-6)


def test_admission_bound_budget_caps_occupancy():
    """A starved prefill budget must cap steady-state rows (the
    admission-bound regime), and with a per-step overhead that shows up as
    lower predicted throughput."""
    cfg = _tiny_model()
    wl = sp.ServeWorkload(concurrency=16, prompt_len=64, decode_len=4)
    mk = lambda budget: sp.price_decode_step(
        cfg,
        sp.ServeKnobs(slots=16, kv_layout="paged", block_size=16,
                      num_blocks=200, prefill_chunk=16, token_budget=budget),
        max_len=128, workload=wl,
    )
    starved, fed = mk(16), mk(256)
    assert starved.rows < fed.rows
    calib = sp.Calibration(step_overhead_s=1e-3)
    assert starved.tokens_per_s(calib) < fed.tokens_per_s(calib)


def test_infeasible_pool_is_dropped():
    """A paged pool too small for even one request admits zero rows and is
    dropped, not priced."""
    cfg = _tiny_model()
    knobs = sp.ServeKnobs(slots=4, kv_layout="paged", block_size=16,
                          num_blocks=2)
    wl = sp.ServeWorkload(concurrency=4, prompt_len=60, decode_len=4)
    assert sp.price_decode_step(cfg, knobs, max_len=64, workload=wl) is None


def test_planner_rejects_non_dense_models():
    moe = _tiny_model(moe={"n_experts": 4, "top_k": 2})
    with pytest.raises(ValueError, match="dense decoder-only"):
        sp.plan_serve(moe, max_len=64, cache=False)


# ---------------------------------------------------- engine integration ---


def test_autotuned_config_serves():
    """ServeConfig.autotune() must hand Engine a config it can actually
    serve with — the closed loop, end to end on a tiny model."""
    jax = pytest.importorskip("jax")
    from repro.arch.model_zoo import build
    from repro.serve.engine import Engine, Request, ServeConfig

    cfg = _tiny_model()
    scfg = ServeConfig.autotune(
        cfg, max_len=64,
        workload=sp.ServeWorkload(concurrency=3, prompt_len=8, decode_len=4),
    )
    plan = scfg.autotune_plan
    assert plan.predicted["tokens_per_s"] > 0
    assert scfg.batch == plan.knobs.slots
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = [
        Request(np.array([3, 5, 7], dtype=np.int32), max_new=4, request_id=i)
        for i in range(3)
    ]
    with Engine(cfg, params, scfg) as eng:
        outs = eng.run(reqs)
    assert all(len(o) == 4 for o in outs)
