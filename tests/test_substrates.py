"""Substrate tests: optimizer, data pipeline, checkpointing, gradient
compression, sharding plan rules."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.ckpt import checkpoint as ckpt
from repro.data.pipeline import DataConfig, Pipeline, batch_at
from repro.parallel import collectives as C
from repro.parallel.sharding import ShardingPlan
from repro.train import optim


# ------------------------------------------------------------------- optim


def test_adamw_minimizes_quadratic():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = optim.init_state(params)
    cfg = optim.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                            total_steps=200)
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state, _ = optim.apply_updates(cfg, params, g, state)
    assert float(loss(params)) < 1e-2


def test_grad_clip():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = optim.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(200.0)
    assert float(optim.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_lr_schedule_shape():
    cfg = optim.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_ratio=0.1)
    lrs = [float(optim.lr_schedule(cfg, jnp.asarray(s))) for s in
           (0, 5, 10, 50, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1, rel=1e-3)


# -------------------------------------------------------------------- data


def test_data_deterministic():
    cfg = DataConfig(vocab=128, seq_len=16, global_batch=4)
    a = batch_at(cfg, 7)
    b = batch_at(cfg, 7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = batch_at(cfg, 8)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_host_shards_differ():
    base = dict(vocab=128, seq_len=16, global_batch=8, num_hosts=2)
    a = batch_at(DataConfig(host_id=0, **base), 3)
    b = batch_at(DataConfig(host_id=1, **base), 3)
    assert a["tokens"].shape[0] == 4
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_pipeline_prefetch_and_resume():
    cfg = DataConfig(vocab=64, seq_len=8, global_batch=2)
    p = Pipeline(cfg, start_step=5)
    step, batch = next(p)
    p.close()
    assert step == 5
    np.testing.assert_array_equal(batch["tokens"], batch_at(cfg, 5)["tokens"])


# -------------------------------------------------------------------- ckpt


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "b": {"c": jnp.ones((4,), jnp.bfloat16)},
    }
    ckpt.save(str(tmp_path), 3, tree, extra={"next_step": 3})
    out, extra = ckpt.restore(str(tmp_path), tree)
    assert extra["next_step"] == 3
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert out["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_async_and_gc(tmp_path):
    saver = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    tree = {"w": jnp.zeros((3,))}
    for s in (1, 2, 3, 4):
        saver.save_async(s, tree)
    saver.wait()
    assert ckpt.list_steps(str(tmp_path)) == [3, 4]


def test_checkpoint_atomic_overwrite(tmp_path):
    tree = {"w": jnp.zeros((2,))}
    ckpt.save(str(tmp_path), 1, tree)
    tree2 = {"w": jnp.ones((2,))}
    ckpt.save(str(tmp_path), 1, tree2)
    out, _ = ckpt.restore(str(tmp_path), tree)
    np.testing.assert_array_equal(np.asarray(out["w"]), [1.0, 1.0])


# ------------------------------------------------------------- compression


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_quantize_roundtrip_error_bound(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (512,)) * 10
    y = C.quantize_roundtrip(x)
    # per-block scale = max/127: error <= scale/2 <= max|x|/254
    bound = float(jnp.max(jnp.abs(x))) / 254 + 1e-6
    assert float(jnp.max(jnp.abs(x - y))) <= bound * 1.01


def test_error_feedback_reduces_bias():
    key = jax.random.PRNGKey(0)
    g = {"w": jax.random.normal(key, (1024,))}
    res = C.init_residual(g)
    # accumulate N compressed steps with feedback: sum approximates N*g
    total = jnp.zeros((1024,))
    for _ in range(16):
        gq, res = C.error_feedback_update(g, res)
        total = total + gq["w"]
    err = float(jnp.max(jnp.abs(total / 16 - g["w"])))
    naive = C.quantize_roundtrip(g["w"])
    naive_err = float(jnp.max(jnp.abs(naive - g["w"])))
    assert err <= naive_err  # feedback cannot be worse than naive
    assert err < 0.05


# ----------------------------------------------------------- sharding plan


def _mesh2():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_param_rules_shapes():
    plan = ShardingPlan(_mesh2())
    shapes = {
        "embed": {"tok": jax.ShapeDtypeStruct((256, 64), jnp.bfloat16)},
        "layers": {
            "attn": {"wq": jax.ShapeDtypeStruct((2, 64, 64), jnp.bfloat16)},
            "mlp": {"w_out": jax.ShapeDtypeStruct((2, 128, 64), jnp.bfloat16)},
        },
    }
    specs = plan.param_spec(shapes)
    from jax.sharding import PartitionSpec as P

    assert specs["embed"]["tok"] == P("model", "data")
    # stacked layer dim gets a leading None
    assert specs["layers"]["attn"]["wq"] == P(None, "data", "model")
    assert specs["layers"]["mlp"]["w_out"] == P(None, "model", "data")


def test_uneven_dims_fall_back_to_replication():
    class FakeMesh:
        axis_names = ("model",)
        shape = {"model": 16}

    plan = ShardingPlan(FakeMesh())
    from jax.sharding import PartitionSpec as P

    spec = plan._fit((49155, 64), ("model", None))
    assert spec == P(None, None)  # 49155 % 16 != 0 -> replicated
    spec2 = plan._fit((49152, 64), ("model", None))
    assert spec2 == P("model", None)
