"""Shared test fixtures."""

import os

import pytest


@pytest.fixture(autouse=True, scope="session")
def _isolated_tile_cache(tmp_path_factory):
    """Point the on-disk matmul-tile cache at a per-session tmp dir so tests
    never read from (or pollute) the user's real ~/.cache.  An explicitly
    exported REPRO_TILE_CACHE still wins."""
    if "REPRO_TILE_CACHE" not in os.environ:
        path = tmp_path_factory.mktemp("tile-cache") / "matmul_tiles.json"
        os.environ["REPRO_TILE_CACHE"] = str(path)


def fuzz_examples(default: int) -> int:
    """Example count for the seeded randomized (``fuzz``-marked) suites:
    ``default`` in CI (fixed seeds keep runs reproducible), cranked locally
    via ``FUZZ_EXAMPLES=N make test-fuzz``."""
    return int(os.environ.get("FUZZ_EXAMPLES", default))


def chaos_episodes(default: int) -> int:
    """Episode count for the ``chaos``-marked fault-injection suites: a
    small ``default`` inside the full test run, cranked to the acceptance
    matrix by ``make test-chaos`` (CHAOS_EPISODES=200)."""
    return int(os.environ.get("CHAOS_EPISODES", default))


def chaos_seed() -> int:
    """Base seed for the chaos episode matrix; CI runs the named chaos
    step once per CHAOS_SEED value, so episodes never repeat across the
    matrix while every failure reproduces from its printed seed."""
    return int(os.environ.get("CHAOS_SEED", 0))
