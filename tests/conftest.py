"""Shared test fixtures."""

import os

import pytest

from repro.serve._env import env_int


@pytest.fixture(autouse=True, scope="session")
def _isolated_tile_cache(tmp_path_factory):
    """Point the on-disk matmul-tile cache at a per-session tmp dir so tests
    never read from (or pollute) the user's real ~/.cache.  An explicitly
    exported REPRO_TILE_CACHE still wins."""
    if "REPRO_TILE_CACHE" not in os.environ:
        path = tmp_path_factory.mktemp("tile-cache") / "matmul_tiles.json"
        os.environ["REPRO_TILE_CACHE"] = str(path)
    if "REPRO_SERVE_PLAN_CACHE" not in os.environ:
        path = tmp_path_factory.mktemp("plan-cache") / "serve_plans.json"
        os.environ["REPRO_SERVE_PLAN_CACHE"] = str(path)


def _env_int(name: str, default: int) -> int:
    """Env-knob parsing that fails as a usage error (one clear line, no
    traceback) when someone exports CHAOS_EPISODES=lots."""
    try:
        return env_int(name, default)
    except ValueError as e:
        raise pytest.UsageError(str(e)) from None


def fuzz_examples(default: int) -> int:
    """Example count for the seeded randomized (``fuzz``-marked) suites:
    ``default`` in CI (fixed seeds keep runs reproducible), cranked locally
    via ``FUZZ_EXAMPLES=N make test-fuzz``."""
    return _env_int("FUZZ_EXAMPLES", default)


def chaos_episodes(default: int) -> int:
    """Episode count for the ``chaos``-marked fault-injection suites: a
    small ``default`` inside the full test run, cranked to the acceptance
    matrix by ``make test-chaos`` (CHAOS_EPISODES=200)."""
    return _env_int("CHAOS_EPISODES", default)


def recovery_episodes(default: int) -> int:
    """Episode count for the ``recovery``-marked crash-restart suites;
    smaller defaults than chaos (each episode compiles a fresh engine
    pair), cranked by ``make test-recovery`` (RECOVERY_EPISODES)."""
    return _env_int("RECOVERY_EPISODES", default)


def sdc_episodes(default: int) -> int:
    """Episode count for the ``sdc``-marked bit-flip injection suites:
    small ``default`` inside the full run, cranked by ``make test-sdc``
    (SDC_EPISODES)."""
    return _env_int("SDC_EPISODES", default)


def sdc_seed() -> int:
    """Base seed for the SDC bit-flip episode matrix (CI shards it the
    same way the chaos jobs shard CHAOS_SEED)."""
    return _env_int("SDC_SEED", 0)


def chaos_seed() -> int:
    """Base seed for the chaos episode matrix; CI runs the named chaos
    step once per CHAOS_SEED value, so episodes never repeat across the
    matrix while every failure reproduces from its printed seed."""
    return _env_int("CHAOS_SEED", 0)
