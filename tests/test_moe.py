"""MoE dispatch correctness: the sort/scatter capacity dispatch must match a
dense per-token reference when capacity is ample, and degrade gracefully
(drops, not corruption) when tight."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.arch.moe import moe_apply, moe_init
from repro.configs.base import ModelConfig, MoEConfig

KEY = jax.random.PRNGKey(0)


def _cfg(E=4, K=2, cf=8.0, act="swiglu"):
    return ModelConfig(
        name="t", family="moe", n_layers=1, d_model=32, n_heads=4,
        n_kv_heads=2, d_ff=64, vocab=64, head_dim=8,
        moe=MoEConfig(num_experts=E, top_k=K, d_expert=16,
                      capacity_factor=cf),
        mlp_act=act,
    )


def dense_reference(params, cfg, x):
    """Every expert on every token, gate-weighted top-k combine."""
    B, S, D = x.shape
    E, K = cfg.moe.num_experts, cfg.moe.top_k
    logits = x.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, K)
    gates = gates / jnp.sum(gates, -1, keepdims=True)
    # per-expert FFN on all tokens
    h = jnp.einsum("bsd,edf->besf", x, params["w_in"])
    if cfg.mlp_act == "swiglu":
        h = jax.nn.silu(
            jnp.einsum("bsd,edf->besf", x, params["w_gate"])
        ) * h
    else:
        h = jax.nn.gelu(h)
    eo = jnp.einsum("besf,efd->besd", h, params["w_out"])
    onehot = jax.nn.one_hot(ids, E, dtype=jnp.float32)     # (B,S,K,E)
    w = jnp.einsum("bske,bsk->bse", onehot, gates)
    return jnp.einsum("besd,bse->bsd", eo.astype(jnp.float32), w)


@pytest.mark.parametrize("act", ["swiglu", "gelu"])
@pytest.mark.parametrize("E,K", [(4, 2), (8, 2), (4, 4)])
def test_moe_matches_dense_reference(E, K, act):
    cfg = _cfg(E=E, K=K, cf=float(E), act=act)  # capacity ample: no drops
    params = moe_init(KEY, cfg)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 16, 32),
                          jnp.float32).astype(jnp.bfloat16)
    out, aux = moe_apply(params, cfg, x)
    ref = dense_reference(params, cfg, x)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=5e-2, atol=5e-2,
    )
    assert float(aux) > 0


def test_moe_tight_capacity_drops_not_corrupts():
    cfg = _cfg(E=4, K=2, cf=0.5)
    params = moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 16, 32), jnp.bfloat16)
    out, _ = moe_apply(params, cfg, x)
    assert bool(jnp.all(jnp.isfinite(out.astype(jnp.float32))))
    # dropped tokens pass through as zeros (residual add keeps their stream)
    dense_reference(params, cfg, x)  # reference path must stay finite too
    # at cf=0.5 some tokens differ from the reference; none may be NaN/huge
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32)))) < 1e3


def test_moe_rows_route_independently():
    """Row r's output must not depend on other rows (shard-local dispatch)."""
    cfg = _cfg(E=4, K=2, cf=4.0)
    params = moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (3, 8, 32), jnp.bfloat16)
    full, _ = moe_apply(params, cfg, x)
    solo, _ = moe_apply(params, cfg, x[1:2])
    np.testing.assert_allclose(
        np.asarray(full[1:2], np.float32), np.asarray(solo, np.float32),
        rtol=1e-3, atol=1e-3,
    )
