"""Per-architecture smoke tests (deliverable f): reduced same-family configs,
one forward/train step on CPU, asserting output shapes + finite values."""

import jax
import jax.numpy as jnp
import pytest

from repro.arch.model_zoo import build
from repro.configs.registry import ARCHS, get

ARCH_IDS = sorted(ARCHS)


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_loss(arch, key):
    cfg = get(arch + "-smoke")
    model = build(cfg)
    params = model.init(key)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    labels = jax.random.randint(key, (B, S), 0, cfg.vocab)
    if cfg.family == "encdec":
        frames = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model)
        ).astype(jnp.bfloat16)
        loss = model.loss(params, frames, toks, labels)
    elif cfg.family == "vlm":
        patches = jax.random.normal(
            key, (B, cfg.n_patches, cfg.patch_dim)
        ).astype(jnp.bfloat16)
        loss = model.loss(params, toks, labels, patches=patches)
    else:
        loss = model.loss(params, toks, labels)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_grad_step(arch, key):
    cfg = get(arch + "-smoke")
    model = build(cfg)
    params = model.init(key)
    B, S = 2, 8
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    labels = jax.random.randint(key, (B, S), 0, cfg.vocab)

    if cfg.family == "encdec":
        frames = jnp.zeros((B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        fn = lambda p: model.loss(p, frames, toks, labels)
    elif cfg.family == "vlm":
        patches = jnp.zeros((B, cfg.n_patches, cfg.patch_dim), jnp.bfloat16)
        fn = lambda p: model.loss(p, toks, labels, patches=patches)
    else:
        fn = lambda p: model.loss(p, toks, labels)
    loss, grads = jax.value_and_grad(fn)(params)
    assert bool(jnp.isfinite(loss))
    gleaves = jax.tree.leaves(grads)
    assert gleaves, "no grads"
    for g in gleaves:
        assert bool(jnp.all(jnp.isfinite(g.astype(jnp.float32)))), arch


@pytest.mark.parametrize(
    "arch",
    ["granite-8b", "gemma3-12b", "rwkv6-1.6b", "recurrentgemma-2b",
     "grok-1-314b", "whisper-medium"],
)
def test_params_count_positive(arch):
    cfg = get(arch)
    n = cfg.params_count()
    assert n > 0
    assert cfg.active_params_count() <= n


def test_full_config_dims_match_assignment():
    """Spot-check exact dims from the assignment sheet."""
    g = get("granite-8b")
    assert (g.n_layers, g.d_model, g.n_heads, g.n_kv_heads, g.d_ff, g.vocab) \
        == (36, 4096, 32, 8, 14336, 49152)
    gr = get("grok-1-314b")
    assert gr.moe.num_experts == 8 and gr.moe.top_k == 2
    gm = get("granite-moe-1b-a400m")
    assert gm.moe.num_experts == 32 and gm.moe.top_k == 8
    assert gm.vocab == 49155
    g3 = get("gemma3-12b")
    assert g3.vocab == 262144 and g3.global_every == 6
    rg = get("recurrentgemma-2b")
    assert rg.n_kv_heads == 1 and rg.rnn_per_attention == 2
