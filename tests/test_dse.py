"""DSE engine tests: hierarchy-batched pricing, Pareto pruning, caching.

Three layers of guarantees:

  * the 4-D pricing call (BatchedCostModel.evaluate_hierarchies) is
    bit-identical to the scalar evaluate() under every cost table, and its
    vectorized footprints match Schedule.footprint_bytes;
  * pareto_prune never drops a non-dominated point (property test against
    the brute-force filter);
  * sweep_allocations agrees with the sequential optimize_network loop on
    the best allocation and is incremental through SweepCache.
"""

import random

import numpy as np
import pytest

from repro.core import dse as dse_mod
from repro.core.costmodel import BatchedCostModel
from repro.core.dse import (
    DesignPoint,
    best_at_iso_throughput,
    dominates,
    pareto_prune,
    sweep_allocations,
)
from repro.core.energy import CostTable, evaluate
from repro.core.loopnest import conv_nest, fc_nest
from repro.core.optimizer import (
    HardwareConfig,
    clear_search_cache,
    optimize_network,
)
from repro.core.schedule import ArraySpec, MemLevel, Schedule

from test_costmodel import _random_case


# ------------------------------------------------- 4-D pricing bit-exactness


def test_evaluate_hierarchies_matches_scalar():
    """energy/cycles under H tables == scalar evaluate() per table; the
    shared footprint columns == Schedule.footprint_bytes."""
    rng = random.Random(31337)
    checked = 0
    while checked < 30:
        try:
            s = _random_case(rng)
        except ValueError:
            continue
        cm = BatchedCostModel(
            s.nest, s.levels, array=s.array, spatial=s.spatial
        )
        L = len(s.levels)
        tables = [
            CostTable(level_pj=tuple(float(l + 1) * f for l in range(L)))
            for f in (0.5, 1.0, 7.25)
        ]
        til, odr = cm.pack([s])
        rep = cm.evaluate_hierarchies(til, odr, tables)
        for h, tbl in enumerate(tables):
            ref = evaluate(s, tbl)
            assert rep.energy_pj[h, 0] == ref.energy_pj
            assert rep.cycles[h, 0] == ref.cycles
        for l in range(L):
            want = s.footprint_bytes(l)
            got = int(rep.footprint_words[0, l]) * s.word_bytes
            if s.levels[l].double_buffered:
                got *= 2
            assert got == want
        checked += 1


def test_evaluate_hierarchies_4d_blocks():
    """(H, n, L, D) input: block h priced under table h only."""
    nest = conv_nest("t", B=2, K=4, C=4, X=4, Y=4, FX=3, FY=3)
    levels = (
        MemLevel("RF", None, double_buffered=False, per_pe=True),
        MemLevel("BUF", None),
        MemLevel("DRAM", None),
    )
    t1 = {"B": (1, 2, 1), "K": (2, 1, 2), "C": (4, 1, 1), "X": (1, 2, 2),
          "Y": (2, 2, 1), "FX": (3, 1, 1), "FY": (1, 3, 1)}
    t2 = {"B": (2, 1, 1), "K": (1, 4, 1), "C": (1, 2, 2), "X": (4, 1, 1),
          "Y": (1, 1, 4), "FX": (1, 1, 3), "FY": (3, 1, 1)}
    orders = (tuple(nest.dims),) * 3
    a = Schedule(nest=nest, levels=levels, tiling=t1, order=orders)
    b = Schedule(nest=nest, levels=levels, tiling=t2, order=orders)
    cm = BatchedCostModel(nest, levels)
    til_a, odr_a = cm.pack([a])
    til_b, odr_b = cm.pack([b])
    til4 = np.stack([til_a, til_b])
    odr4 = np.stack([odr_a, odr_b])
    tables = [
        CostTable(level_pj=(1.0, 2.0, 3.0)),
        CostTable(level_pj=(10.0, 20.0, 30.0)),
    ]
    rep = cm.evaluate_hierarchies(til4, odr4, tables)
    assert rep.energy_pj.shape == (2, 1)
    assert rep.energy_pj[0, 0] == evaluate(a, tables[0]).energy_pj
    assert rep.energy_pj[1, 0] == evaluate(b, tables[1]).energy_pj
    # count-side fields gain the leading hierarchy axis for 4-D blocks
    assert rep.footprint_words.shape == (2, 1, 3)
    assert rep.level_totals.shape == (2, 1, 3)
    assert rep.utilization.shape == (2, 1)
    for l in range(3):
        dbl = 2 if levels[l].double_buffered else 1
        assert int(rep.footprint_words[0, 0, l]) * a.word_bytes * dbl == (
            a.footprint_bytes(l)
        )
        assert int(rep.footprint_words[1, 0, l]) * b.word_bytes * dbl == (
            b.footprint_bytes(l)
        )


# --------------------------------------------------------------- pareto ----


def _brute_force_frontier(points, keys=("energy_pj", "cycles")):
    vecs = [tuple(getattr(p, k) for k in keys) for p in points]
    return [
        p
        for p, v in zip(points, vecs)
        if not any(dominates(q, v) for q in vecs)
    ]


def test_pareto_never_drops_nondominated():
    """Property: incremental prune == brute-force non-dominated filter
    (as sets), across random point clouds with many ties."""
    rng = random.Random(99)
    for trial in range(200):
        n = rng.randrange(1, 25)
        pts = [
            DesignPoint(
                hw=HardwareConfig(
                    f"h{i}", ArraySpec(dims=(1,)), (16,), (1024,)
                ),
                energy_pj=float(rng.randrange(1, 6)),
                cycles=float(rng.randrange(1, 6)),
            )
            for i in range(n)
        ]
        got = pareto_prune(pts)
        want = _brute_force_frontier(pts)
        key = lambda p: (p.energy_pj, p.cycles, p.hw.name)
        assert sorted(map(key, got)) == sorted(map(key, want)), (
            f"trial {trial}: frontier mismatch"
        )


def test_pareto_keeps_ties():
    mk = lambda name, e, c: DesignPoint(
        hw=HardwareConfig(name, ArraySpec(dims=(1,)), (16,), (1024,)),
        energy_pj=e, cycles=c,
    )
    pts = [mk("a", 1.0, 2.0), mk("b", 1.0, 2.0), mk("c", 2.0, 1.0),
           mk("d", 2.0, 2.0)]
    got = {p.hw.name for p in pareto_prune(pts)}
    assert got == {"a", "b", "c"}


def test_best_at_iso_throughput():
    mk = lambda name, e, c: DesignPoint(
        hw=HardwareConfig(name, ArraySpec(dims=(1,)), (16,), (1024,)),
        energy_pj=e, cycles=c,
    )
    base = mk("base", 10.0, 100.0)
    fast_cheap = mk("fc", 4.0, 90.0)
    slow_cheaper = mk("sc", 2.0, 200.0)
    best = best_at_iso_throughput([base, fast_cheap, slow_cheaper], base)
    assert best.hw.name == "fc"
    with pytest.raises(ValueError):
        best_at_iso_throughput([slow_cheaper], base, slack=0.5)


# ---------------------------------------------------------------- sweep ----


def _tiny_setup():
    arr = ArraySpec(dims=(4, 4))
    layers = [
        conv_nest("c1", B=1, K=16, C=8, X=7, Y=7, FX=3, FY=3),
        conv_nest("c1b", B=1, K=16, C=8, X=7, Y=7, FX=3, FY=3),
        fc_nest("fc", B=1, C=128, K=32),
    ]
    hws = [
        HardwareConfig(f"rf{rf}-buf{buf//1024}k", arr, (rf,), (buf,))
        for rf in (64, 256) for buf in (16 * 1024, 64 * 1024)
    ]
    return arr, layers, hws


def test_sweep_matches_sequential_optimizer():
    """Best allocation from the batched sweep == sequential optimize_network
    on the same grid, with near-identical best energy (the frontier and the
    beam search may pick slightly different schedules)."""
    arr, layers, hws = _tiny_setup()
    pts = sweep_allocations(layers, arr, hws)
    assert len(pts) == len(hws)  # all feasible here
    best = min(pts, key=lambda p: p.energy_pj)
    clear_search_cache()
    seq = optimize_network(layers, arr, hw_candidates=hws)
    assert best.hw.name == seq.hw.name
    assert best.energy_pj == pytest.approx(seq.total_energy_pj, rel=0.05)
    # the sweep can never beat an exhaustive-er search by much; sanity bound
    assert best.energy_pj >= seq.total_energy_pj * 0.95


def test_sweep_cache_is_incremental(tmp_path, monkeypatch):
    """Second run with the same cache prices nothing and returns the same
    points; extending the grid prices only the new blocks."""
    arr, layers, hws = _tiny_setup()
    path = str(tmp_path / "dse_cache.json")

    calls = []
    real = dse_mod._price_nest_block

    def counting(*args, **kw):
        calls.append(1)
        return real(*args, **kw)

    monkeypatch.setattr(dse_mod, "_price_nest_block", counting)

    pts1 = sweep_allocations(layers, arr, hws[:2], cache=path)
    first = len(calls)
    assert first > 0

    pts2 = sweep_allocations(layers, arr, hws[:2], cache=path)
    assert len(calls) == first  # everything served from disk
    key = lambda p: (p.hw.name, p.energy_pj, p.cycles)
    assert sorted(map(key, pts1)) == sorted(map(key, pts2))

    sweep_allocations(layers, arr, hws, cache=path)
    assert len(calls) > first  # only the extended family re-priced


def test_sweep_skips_unpriceable_blocks():
    """A family the engine cannot price (here: counts overflow the batched
    engine's exact range) yields infeasible rows instead of aborting the
    sweep — the priceable hierarchies still come back."""
    from repro.core.loopnest import matmul_nest

    arr, layers, hws = _tiny_setup()
    huge = matmul_nest("huge", M=2 ** 20, N=2 ** 20, K=2 ** 20)
    pts = sweep_allocations([huge], arr, hws)
    assert pts == []  # nothing priceable, nothing returned, no crash
    pts = sweep_allocations(layers, arr, hws)
    assert len(pts) == len(hws)


def test_sweep_process_pool_matches_serial():
    arr, layers, hws = _tiny_setup()
    serial = sweep_allocations(layers, arr, hws, workers=0)
    pooled = sweep_allocations(layers, arr, hws, workers=2)
    key = lambda p: (p.hw.name, p.energy_pj, p.cycles)
    assert sorted(map(key, serial)) == sorted(map(key, pooled))


# ------------------------------------------------- pareto property suite ----


def test_pareto_idempotent_and_shuffle_invariant():
    """Property: the frontier is a fixed point of pareto_prune, is
    independent of input order, and contains no internally dominated pair."""
    rng = random.Random(4242)
    mk = lambda i, e, c: DesignPoint(
        hw=HardwareConfig(f"h{i}", ArraySpec(dims=(1,)), (16,), (1024,)),
        energy_pj=e, cycles=c,
    )
    key = lambda p: (p.energy_pj, p.cycles, p.hw.name)
    for trial in range(100):
        pts = [
            mk(i, float(rng.randrange(1, 8)), float(rng.randrange(1, 8)))
            for i in range(rng.randrange(1, 30))
        ]
        front = pareto_prune(pts)
        # idempotence: pruning the frontier is a no-op
        assert sorted(map(key, pareto_prune(front))) == sorted(map(key, front))
        # shuffle invariance: the frontier is a function of the set
        shuffled = pts[:]
        rng.shuffle(shuffled)
        assert sorted(map(key, pareto_prune(shuffled))) == sorted(
            map(key, front)
        )
        # internal non-dominance: no member strictly dominates another
        for p in front:
            for q in front:
                assert not dominates(
                    (p.energy_pj, p.cycles), (q.energy_pj, q.cycles)
                ), f"trial {trial}: frontier member dominates another"


# ------------------------------------------------------ sweep-cache fixes ----


def test_sweep_cache_concurrent_merge(tmp_path):
    """Regression (pre-fix: put() rewrote the file from one process's
    in-memory view): two cache instances on the same path must merge, not
    clobber — B flushing after A must preserve A's entries."""
    path = str(tmp_path / "cache.json")
    a = dse_mod.SweepCache(path)
    b = dse_mod.SweepCache(path)  # opened before A writes, like a 2nd proc
    a.put("k1", {"v": 1})
    a.flush()
    b.put("k2", {"v": 2})
    b.flush()
    fresh = dse_mod.SweepCache(path)
    assert fresh.get("k1") == {"v": 1}, "A's entry was clobbered by B"
    assert fresh.get("k2") == {"v": 2}


def test_sweep_cache_batched_flush(tmp_path, monkeypatch):
    """Regression (pre-fix: one full-file rewrite per put, O(N^2) I/O over
    a long sweep): N puts land in at most ceil(N / flush_every) writes,
    with the remainder picked up by the final flush()."""
    writes = []
    real = dse_mod.atomic_write_json

    def counting(path, data):
        writes.append(len(data))
        return real(path, data)

    monkeypatch.setattr(dse_mod, "atomic_write_json", counting)
    path = str(tmp_path / "cache.json")
    c = dse_mod.SweepCache(path, flush_every=16)
    for i in range(40):
        c.put(f"k{i}", {"v": i})
    assert len(writes) == 2  # at 16 and 32 dirty entries
    c.flush()
    assert len(writes) == 3
    fresh = dse_mod.SweepCache(path)
    assert all(fresh.get(f"k{i}") == {"v": i} for i in range(40))
    c.flush()  # nothing dirty: no write
    assert len(writes) == 3


def test_sweep_flushes_cache_on_completion(tmp_path):
    """sweep_allocations must leave every priced block on disk even though
    puts are batched (the flush rides a finally, so partial sweeps keep
    their work too)."""
    arr, layers, hws = _tiny_setup()
    path = str(tmp_path / "cache.json")
    sweep_allocations(layers, arr, hws[:2], cache=path)
    from repro.core.jsonstore import load_json_dict

    assert len(load_json_dict(path)) > 0


# ------------------------------------------- iso-throughput diagnostics ----


def test_best_at_iso_nearest_miss_diagnostics():
    """An unsatisfiable constraint must name the nearest miss and the slack
    that would admit it, not raise bare (pre-fix: no context at all)."""
    mk = lambda name, e, c: DesignPoint(
        hw=HardwareConfig(name, ArraySpec(dims=(1,)), (16,), (1024,)),
        energy_pj=e, cycles=c,
    )
    base = mk("base", 10.0, 100.0)
    slow = mk("slow", 2.0, 200.0)
    slower = mk("slower", 1.0, 400.0)
    with pytest.raises(ValueError, match=r"nearest miss is 'slow'"):
        best_at_iso_throughput([slow, slower], base, slack=0.5)
    with pytest.raises(ValueError, match=r"needs slack >= 2"):
        best_at_iso_throughput([slow, slower], base, slack=0.5)
    with pytest.raises(ValueError, match=r"empty sweep"):
        best_at_iso_throughput([], base)


def test_best_at_iso_float_tie_qualifies():
    """Regression (pre-fix: `cycles <= baseline.cycles * slack` with exact
    float compare): a candidate sitting exactly at the constraint must
    qualify even when the slack multiplication rounds down — here
    0.3 * (1/3) < 0.1 in binary floating point."""
    mk = lambda name, e, c: DesignPoint(
        hw=HardwareConfig(name, ArraySpec(dims=(1,)), (16,), (1024,)),
        energy_pj=e, cycles=c,
    )
    base = mk("base", 10.0, 0.3)
    exactly_at_limit = mk("tie", 1.0, 0.1)
    assert 0.3 * (1 / 3) < 0.1  # the float hazard this guards against
    best = best_at_iso_throughput([exactly_at_limit], base, slack=1 / 3)
    assert best.hw.name == "tie"
    # and the baseline itself always qualifies at slack=1.0
    assert best_at_iso_throughput([base], base).hw.name == "base"
