"""Differential tests: the batched cost engine vs the scalar oracle.

The batched engine (costmodel.py) must match `analyze()`/`evaluate()`
*bit-exactly* on every schedule — counts are integers and the float
accumulation order is mirrored.  Randomized property sweep in the spirit of
the hypothesis suite in test_reuse_model.py (pure `random` so the test runs
without the hypothesis package).
"""

import math
import random

import pytest

from repro.core.blocking import _level_energy, search_blocking
from repro.core.costmodel import BatchedCostModel, BatchOverflowError
from repro.core.dataflow import Dataflow, make_dataflow
from repro.core.energy import CostTable, evaluate
from repro.core.loopnest import conv_nest, fc_nest, matmul_nest
from repro.core.schedule import ArraySpec, MemLevel, Schedule


def _rand_splits(rng, bound, n):
    out = []
    rem = bound
    for _ in range(n - 1):
        divs = [d for d in range(1, rem + 1) if rem % d == 0]
        f = rng.choice(divs)
        out.append(f)
        rem //= f
    out.append(rem)
    return tuple(out)


def _random_case(rng):
    kind = rng.choice(["conv", "mm", "fc"])
    if kind == "conv":
        nest = conv_nest(
            "r",
            B=rng.choice([1, 2]), K=rng.choice([1, 2, 4]),
            C=rng.choice([1, 2, 3]), X=rng.choice([1, 2, 4]),
            Y=rng.choice([1, 2]), FX=rng.choice([1, 3]),
            FY=rng.choice([1, 2]), stride=rng.choice([1, 2]),
        )
    elif kind == "mm":
        nest = matmul_nest(
            "r", M=rng.choice([2, 4]), N=rng.choice([2, 4]),
            K=rng.choice([2, 8]),
        )
    else:
        nest = fc_nest("r", B=2, C=4, K=4)
    L = rng.choice([2, 3, 4])
    ppe = rng.choice([0, 1]) if L >= 3 else 0
    levels = tuple(
        MemLevel(
            f"L{i}", None, double_buffered=False, per_pe=(i < ppe),
            bandwidth_words_per_cycle=rng.choice([float("inf"), 4.0]),
        )
        for i in range(L)
    )
    if rng.random() < 0.5:
        arr = ArraySpec(dims=(2, 2))
        big = [d for d in nest.dims if nest.bounds[d] > 1]
        prim = rng.sample(big, k=min(2, len(big))) if big else list(nest.dims)[:2]
        while len(prim) < 2:
            prim.append(nest.dims[0])
        df = make_dataflow(nest, arr, tuple(prim),
                           replication=rng.random() < 0.5)
        spatial = df.assigns
    else:
        arr = ArraySpec(dims=(1,))
        spatial = ((),)
    spf = {d: 1 for d in nest.dims}
    for a in spatial:
        for d, s in a:
            spf[d] *= s
    tiling = {
        d: _rand_splits(rng, math.ceil(nest.bounds[d] / spf[d]), L)
        for d in nest.dims
    }
    orders = tuple(
        tuple(rng.sample(list(nest.dims), len(nest.dims))) for _ in range(L)
    )
    return Schedule(
        nest=nest, levels=levels, tiling=tiling, order=orders,
        array=arr, spatial=spatial,
    )


def test_batched_matches_scalar_randomized():
    """Property sweep: exact equality of every reported quantity."""
    rng = random.Random(1234)
    checked = 0
    while checked < 60:
        try:
            s = _random_case(rng)
        except ValueError:
            continue
        rep = evaluate(s)
        acc = rep.access
        cm = BatchedCostModel(
            s.nest, s.levels, array=s.array, spatial=s.spatial
        )
        til, odr = cm.pack([s])
        b = cm.evaluate(til, odr)
        assert b.energy_pj[0] == rep.energy_pj
        assert b.cycles[0] == rep.cycles
        assert b.utilization[0] == rep.utilization
        for l in range(len(s.levels)):
            for t_i, t in enumerate(s.nest.tensors):
                assert b.reads[0, l, t_i] == acc.reads[l][t.name]
                assert b.writes[0, l, t_i] == acc.writes[l][t.name]
        for t_i, t in enumerate(s.nest.tensors):
            assert b.hops[0, t_i] == acc.hops[t.name]
        checked += 1


def test_batched_level_energy_matches_scalar():
    rng = random.Random(7)
    checked = 0
    while checked < 20:
        try:
            s = _random_case(rng)
        except ValueError:
            continue
        tbl = CostTable.for_levels(s.levels)
        cm = BatchedCostModel(
            s.nest, s.levels, array=s.array, spatial=s.spatial, table=tbl
        )
        til, odr = cm.pack([s])
        for l in range(len(s.levels)):
            assert cm.level_energy(til, odr, l)[0] == _level_energy(s, tbl, l)
        checked += 1


def test_batched_batch_consistency():
    """A batch of n schedules prices identically to n batches of 1."""
    rng = random.Random(99)
    nest = conv_nest("t", B=2, K=4, C=4, X=4, Y=4, FX=3, FY=3)
    levels = (
        MemLevel("RF", None, double_buffered=False, per_pe=True),
        MemLevel("BUF", None),
        MemLevel("DRAM", None),
    )
    scheds = []
    while len(scheds) < 16:
        tiling = {d: _rand_splits(rng, nest.bounds[d], 3) for d in nest.dims}
        orders = tuple(
            tuple(rng.sample(list(nest.dims), len(nest.dims)))
            for _ in range(3)
        )
        scheds.append(
            Schedule(nest=nest, levels=levels, tiling=tiling, order=orders)
        )
    cm = BatchedCostModel(nest, levels)
    til, odr = cm.pack(scheds)
    batch = cm.energy(til, odr)
    singles = [cm.energy(til[i : i + 1], odr[i : i + 1])[0] for i in range(16)]
    assert list(batch) == singles
    assert singles == [evaluate(s).energy_pj for s in scheds]


def test_search_engines_identical():
    """Batched and scalar search paths return the same best schedule."""
    nest = conv_nest("t", B=2, K=16, C=16, X=8, Y=8, FX=3, FY=3)
    levels = (
        MemLevel("RF", 512, double_buffered=False, per_pe=True),
        MemLevel("BUF", 64 * 1024),
        MemLevel("DRAM", None),
    )
    arr = ArraySpec(dims=(4, 4))
    df = make_dataflow(nest, arr, ("C", "K"))
    rb = search_blocking(nest, levels, arr, df, beam=8, engine="batched")
    rs = search_blocking(nest, levels, arr, df, beam=8, engine="scalar")
    assert rb.best.energy_pj == rs.best.energy_pj
    assert rb.evaluated == rs.evaluated
    assert rb.best.schedule.tiling == rs.best.schedule.tiling
    assert rb.best.schedule.order == rs.best.schedule.order


def test_search_prune_preserves_best():
    nest = conv_nest("t", B=2, K=16, C=16, X=8, Y=8, FX=3, FY=3)
    levels = (
        MemLevel("RF", 512, double_buffered=False, per_pe=True),
        MemLevel("BUF", 64 * 1024),
        MemLevel("DRAM", None),
    )
    arr = ArraySpec(dims=(4, 4))
    df = make_dataflow(nest, arr, ("C", "K"))
    pruned = search_blocking(nest, levels, arr, df, beam=8, prune=True)
    full = search_blocking(nest, levels, arr, df, beam=8, prune=False)
    assert pruned.best.energy_pj <= full.best.energy_pj
    assert pruned.evaluated <= full.evaluated + 10000  # dive overhead bounded


def test_max_evals_budget_enforced():
    nest = conv_nest("t", B=2, K=16, C=16, X=8, Y=8, FX=3, FY=3)
    levels = (
        MemLevel("RF", 512, double_buffered=False, per_pe=True),
        MemLevel("BUF", 64 * 1024),
        MemLevel("DRAM", None),
    )
    arr = ArraySpec(dims=(4, 4))
    df = make_dataflow(nest, arr, ("C", "K"))
    unlimited = search_blocking(nest, levels, arr, df, beam=8, prune=False)
    assert unlimited.evaluated > 500
    capped = search_blocking(
        nest, levels, arr, df, beam=8, prune=False, max_evals=500
    )
    # the budget may overshoot by at most one frontier group's order set
    assert capped.evaluated <= 500 + 64
    assert capped.best.schedule.fits()


def test_overflow_guard_falls_back():
    """Nests whose counts could overflow int64 must reject batching at
    construction (no silent wraparound for direct users)."""
    nest = matmul_nest("huge", M=2 ** 20, N=2 ** 20, K=2 ** 20)
    levels = (
        MemLevel("BUF", None, double_buffered=False),
        MemLevel("DRAM", None),
    )
    with pytest.raises(BatchOverflowError):
        BatchedCostModel(nest, levels)
    # the search still completes through the scalar oracle
    res = search_blocking(
        nest, levels, ArraySpec(dims=(1,)), Dataflow(assigns=((),)),
        beam=2, max_choices_per_level=4, max_evals=50,
    )
    assert res.best.energy_pj > 0
