"""Crash-consistent serving (serve/recovery.py): snapshot/restore, the
write-ahead journal, corruption quarantine, and substrate fallback.

The recovery contract under test: a restored engine's surviving requests
finish with outputs **bitwise identical** to a never-crashed run of the
same config — whether restore came from a snapshot + journal tail, from a
cold journal-only replay, or from an older snapshot after the newest one
was quarantined as corrupt.  Corruption that reaches a request's KV (NaN
logits, silent bit rot under checksum mode) fails exactly that request
and releases its blocks; a kernel-level decode failure falls back to the
XLA substrate once instead of killing the engine.
"""

import dataclasses
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.arch.model_zoo import build
from repro.configs.registry import get
from repro.serve import chaos, recovery
from repro.serve.engine import Engine, Request, RequestStatus, ServeConfig

MAX_LEN = 64
BS = 8


@pytest.fixture(scope="module")
def smol():
    cfg = get("smollm-360m-smoke")
    params = build(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _workload(cfg, n=4, seed=1, budget=10):
    rng = np.random.default_rng(seed)
    return [
        Request(
            rng.integers(0, cfg.vocab, int(rng.integers(6, 20))).astype(
                np.int32
            ),
            budget,
            request_id=i,
        )
        for i in range(n)
    ]


def _paged(**kw):
    kw.setdefault("batch", 4)
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("kv_layout", "paged")
    kw.setdefault("block_size", BS)
    kw.setdefault("temperature", 0.8)
    kw.setdefault("seed", 3)
    return ServeConfig(**kw)


@pytest.fixture(scope="module")
def paged_oracle(smol):
    """The never-crashed ground truth every restore is compared against."""
    cfg, params = smol
    reqs = _workload(cfg)
    outs = Engine(cfg, params, _paged()).run(list(reqs))
    return reqs, {r.request_id: o.tolist() for r, o in zip(reqs, outs)}


def _drain_bitwise(eng, reqs, want):
    while eng.step():
        chaos.audit(eng)
    for r in reqs:
        res = eng.pop_result(r.request_id)
        assert res.status == RequestStatus.FINISHED, (r.request_id, res)
        assert res.tolist() == want[r.request_id], (r.request_id, res.tolist())
    if eng.pool is not None:
        assert eng.pool.free_blocks == eng.pool.num_blocks - 1, "block leak"


# ------------------------------------------------------------ journal unit --


def test_journal_roundtrip_and_torn_tail(tmp_path):
    path = str(tmp_path / "wal_0000_00000000.jsonl")
    j = recovery.Journal(path)
    recs = [{"t": "submit", "rid": 1}, {"t": "tok", "rid": 1, "toks": [3, 4]}]
    for r in recs:
        j.append(r)
    j.close()
    assert recovery.read_journal(path) == (recs, 0)
    # crash mid-append: a half-written final line is detected and dropped
    with open(path, "ab") as f:
        f.write(b'001a2b3c {"t":"tok","rid"')
    assert recovery.read_journal(path) == (recs, 1)


def test_journal_crc_rejects_bitflip_and_everything_after(tmp_path):
    path = str(tmp_path / "wal_0000_00000000.jsonl")
    j = recovery.Journal(path)
    for i in range(3):
        j.append({"t": "tok", "rid": i, "toks": [i]})
    j.close()
    with open(path, "rb") as f:
        lines = f.read().split(b"\n")
    body = bytearray(lines[1])
    body[-2] ^= 1  # bit rot inside record 1's JSON
    lines[1] = bytes(body)
    with open(path, "wb") as f:
        f.write(b"\n".join(lines))
    recs, torn = recovery.read_journal(path)
    # record 0 survives; the flipped record AND the valid one after it are
    # dropped — order past a torn line is not trustworthy
    assert [r["rid"] for r in recs] == [0]
    assert torn == 1


# ------------------------------------------------------- restore, bitwise --


def test_snapshot_restore_replays_bitwise(smol, paged_oracle, tmp_path):
    cfg, params = smol
    reqs, want = paged_oracle
    scfg = _paged(snapshot_dir=str(tmp_path), snapshot_every=4)
    eng = Engine(cfg, params, scfg)
    for r in reqs:
        eng.submit(r)
    for _ in range(6):
        eng.step()
    held = eng.pool.reserve(2)  # a co-tenant hold alive at crash time
    assert held
    eng.step()
    eng.recovery.wait()  # snapshot published; later steps live in the WAL
    # simulated SIGKILL: nothing closed, nothing flushed beyond the fsyncs
    eng2, report = recovery.restore_engine(cfg, params, scfg)
    assert report.source == "snapshot" and report.snapshot_key is not None
    assert report.tokens_replayed > 0
    assert recovery.replay_lag(eng2) > 0
    # the reserve holder died with the process: restore released its holds
    assert eng2.pool.external == set()
    chaos.audit(eng2)
    _drain_bitwise(eng2, reqs, want)
    assert recovery.replay_lag(eng2) == 0
    eng2.close()


def test_cold_journal_replay_and_popped_not_resurrected(
    smol, paged_oracle, tmp_path
):
    """Crash before the first snapshot: recovery is a pure journal replay
    through fresh prefill + teacher forcing.  A result the client popped
    pre-crash must not come back."""
    cfg, params = smol
    reqs, want = paged_oracle
    scfg = _paged(snapshot_dir=str(tmp_path), snapshot_every=10_000)
    eng = Engine(cfg, params, scfg)
    for r in reqs:
        eng.submit(r)
    while eng.step():
        pass
    popped = eng.pop_result(0)
    assert popped.status == RequestStatus.FINISHED
    eng2, report = recovery.restore_engine(cfg, params, scfg)
    assert report.source == "cold" and report.snapshot_key is None
    assert report.pops == 1 and report.resubmitted == len(reqs)
    assert eng2.status(0) == RequestStatus.UNKNOWN, "popped result came back"
    chaos.audit(eng2)
    while eng2.step():
        chaos.audit(eng2)
    for r in reqs[1:]:
        res = eng2.pop_result(r.request_id)
        assert res.status == RequestStatus.FINISHED
        assert res.tolist() == want[r.request_id]
    assert eng2.pool.free_blocks == eng2.pool.num_blocks - 1
    eng2.close()


def test_corrupt_snapshot_quarantined_older_one_used(
    smol, paged_oracle, tmp_path
):
    cfg, params = smol
    reqs, want = paged_oracle
    scfg = _paged(snapshot_dir=str(tmp_path), snapshot_every=2)
    eng = Engine(cfg, params, scfg)
    for r in reqs:
        eng.submit(r)
    for _ in range(7):
        eng.step()
    eng.recovery.wait()
    keys = recovery._snapshot_keys(str(tmp_path))
    assert len(keys) >= 2
    assert chaos.corrupt_newest_snapshot(str(tmp_path))
    eng2, report = recovery.restore_engine(cfg, params, scfg)
    assert report.quarantined, "corrupt snapshot was not quarantined"
    assert report.source == "snapshot" and report.snapshot_key == keys[-2]
    assert any(
        n.endswith(".corrupt") for n in os.listdir(tmp_path)
    ), "quarantined snapshot should stay on disk for forensics"
    chaos.audit(eng2)
    _drain_bitwise(eng2, reqs, want)
    eng2.close()


def test_chained_crash_restores_bitwise(smol, paged_oracle, tmp_path):
    """Crash, restore, crash again mid-replay, restore again: the second
    generation's anchor snapshot must make the chain self-contained."""
    cfg, params = smol
    reqs, want = paged_oracle
    scfg = _paged(snapshot_dir=str(tmp_path), snapshot_every=3)
    eng = Engine(cfg, params, scfg)
    for r in reqs:
        eng.submit(r)
    for _ in range(4):
        eng.step()
    eng.recovery.wait()
    eng2, rep2 = recovery.restore_engine(cfg, params, scfg)
    for _ in range(3):
        eng2.step()
    eng2.recovery.wait()
    eng3, rep3 = recovery.restore_engine(cfg, params, scfg)
    assert rep3.source == "snapshot"
    assert rep3.snapshot_key[0] > (rep2.snapshot_key or (0, 0))[0], (
        "second restore should come from the restored engine's generation"
    )
    chaos.audit(eng3)
    _drain_bitwise(eng3, reqs, want)
    eng3.close()


def test_incompatible_config_rejected(smol, tmp_path):
    cfg, params = smol
    scfg = _paged(snapshot_dir=str(tmp_path), snapshot_every=2)
    eng = Engine(cfg, params, scfg)
    for r in _workload(cfg, n=2):
        eng.submit(r)
    for _ in range(3):
        eng.step()
    eng.recovery.wait()
    eng.close()
    drifted = dataclasses.replace(scfg, seed=scfg.seed + 1)
    with pytest.raises(ValueError, match="seed"):
        recovery.restore_engine(cfg, params, drifted)


# ------------------------------------------------- corruption quarantine --


@pytest.mark.parametrize("layout", ["paged", "contiguous"])
def test_nan_guard_quarantines_poisoned_request(smol, layout):
    cfg, params = smol
    kw = dict(batch=4, max_len=MAX_LEN, temperature=0.8, seed=3)
    if layout == "paged":
        scfg = _paged()
    else:
        scfg = ServeConfig(decode_block=BS, **kw)
    reqs = _workload(cfg)
    want = {
        r.request_id: o.tolist()
        for r, o in zip(reqs, Engine(cfg, params, scfg).run(list(reqs)))
    }
    eng = Engine(cfg, params, scfg)
    for r in reqs:
        eng.submit(r)
    for _ in range(3):
        eng.step()
    slot = eng._slot_of(0)
    st = eng._slots[slot]
    assert st.emitted >= 2
    if layout == "paged":
        row = eng._rows[slot]
        pos = row.plen + st.emitted - 2  # last decode-written position
        eng.caches["kpool"] = (
            eng.caches["kpool"]
            .at[:, row.blocks[pos // BS], pos % BS]
            .set(jnp.nan)
        )
    else:
        plen = len(reqs[0].prompt)
        pos = plen + st.emitted - 2
        eng.caches["k"] = eng.caches["k"].at[:, slot, pos].set(jnp.nan)
    while eng.step():
        chaos.audit(eng)
    res = eng.pop_result(0)
    assert res.status == RequestStatus.FAILED
    assert "non-finite" in res.reason
    assert eng.stats["quarantined"] == 1
    # the poisoned request's garbage token reached neither output nor peers
    assert res.tolist() == want[0][: len(res)]
    for r in reqs[1:]:
        out = eng.pop_result(r.request_id)
        assert out.status == RequestStatus.FINISHED
        assert out.tolist() == want[r.request_id]
    if eng.pool is not None:
        assert eng.pool.free_blocks == eng.pool.num_blocks - 1


def test_kv_checksum_detects_finite_corruption(smol, paged_oracle):
    """Silent bit rot that stays finite sails past the NaN guard; checksum
    mode must still catch it at the next step boundary."""
    cfg, params = smol
    reqs, want = paged_oracle
    eng = Engine(cfg, params, _paged(kv_checksum=True))
    for r in reqs:
        eng.submit(r)
    for _ in range(3):
        eng.step()
    slot = eng._slot_of(1)
    row = eng._rows[slot]
    eng.caches["vpool"] = (
        eng.caches["vpool"].at[:, row.blocks[0], 0].add(1.0)
    )
    eng.step()
    assert eng.status(1) == RequestStatus.FAILED
    assert eng.stats["quarantined"] >= 1
    while eng.step():
        chaos.audit(eng)
    for r in reqs:
        res = eng.pop_result(r.request_id)
        if r.request_id == 1:
            assert res.status == RequestStatus.FAILED
        else:
            assert res.status == RequestStatus.FINISHED
            assert res.tolist() == want[r.request_id]
    assert eng.pool.free_blocks == eng.pool.num_blocks - 1


# ------------------------------------------------------ substrate fallback --


def test_substrate_fallback_is_one_shot(smol, paged_oracle):
    cfg, params = smol
    reqs, want = paged_oracle
    eng = Engine(cfg, params, _paged())
    calls = {"n": 0}

    def boom(*args):
        calls["n"] += 1
        raise RuntimeError("pallas lowering exploded")

    eng._decode = boom
    for r in reqs:
        eng.submit(r)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        while eng.step():
            pass
    assert calls["n"] == 1 and eng.stats["fallbacks"] == 1
    assert any("falling back" in str(w.message) for w in caught)
    # deterministic sampling makes the fallback bitwise-invisible
    for r in reqs:
        res = eng.pop_result(r.request_id)
        assert res.status == RequestStatus.FINISHED
        assert res.tolist() == want[r.request_id]
    # the substrate budget is spent: a second kernel failure is fatal
    eng._decode = boom
    eng.submit(Request(reqs[0].prompt, 2, request_id=99))
    with pytest.raises(RuntimeError, match="exploded"):
        while eng.step():
            pass


def test_substrate_fallback_disabled_raises(smol, paged_oracle):
    cfg, params = smol
    reqs, _ = paged_oracle
    eng = Engine(cfg, params, _paged(substrate_fallback=False))

    def boom(*args):
        raise RuntimeError("pallas lowering exploded")

    eng._decode = boom
    eng.submit(reqs[0])
    with pytest.raises(RuntimeError, match="exploded"):
        while eng.step():
            pass


# ------------------------------------------- crash mid-prefill (chunked) --


def test_crash_mid_prefill_restores_bitwise(smol, tmp_path):
    """A snapshot taken while a chunked-prefill lane is mid-flight
    serializes the lane's request as requeued (zero tokens published, its
    blocks released in the persisted pool image): restore re-prefills it
    from scratch and the final output is bitwise identical to a
    never-crashed run."""
    cfg, params = smol
    kw = dict(
        batch=2, max_len=MAX_LEN, kv_layout="paged", block_size=BS,
        temperature=0.8, seed=3, prefill_chunk=BS, token_budget=BS,
    )
    reqs = [
        Request(p, 5, request_id=i)
        for i, p in enumerate(
            np.random.default_rng(7).integers(
                0, cfg.vocab, (3, 40)
            ).astype(np.int32)
        )
    ]
    want = {
        r.request_id: o.tolist()
        for r, o in zip(reqs, Engine(cfg, params, ServeConfig(**kw)).run(
            [Request(r.prompt, 5, request_id=r.request_id) for r in reqs]
        ))
    }

    scfg = ServeConfig(snapshot_dir=str(tmp_path), snapshot_every=1, **kw)
    eng = Engine(cfg, params, scfg)
    for r in reqs:
        eng.submit(r)
    # 40-token prompts at an 8-token budget need 5 steps per lane: two
    # steps in, a lane is guaranteed mid-flight
    eng.step()
    eng.step()
    assert eng._lane is not None, "expected a mid-flight prefill lane"
    mid_rid = eng._lane.rid
    eng.recovery.wait()  # let the armed per-step snapshot publish
    eng.recovery.journal._f.close()  # simulated SIGKILL
    del eng

    eng2, report = recovery.restore_engine(cfg, params, scfg)
    chaos.audit(eng2)
    # the lane's request came back requeued, not resurrected mid-lane
    assert eng2._lane is None
    assert eng2.status(mid_rid) == RequestStatus.WAITING
    assert len(eng2._outputs[mid_rid]) == 0
    _drain_bitwise(eng2, reqs, want)
    eng2.close()
