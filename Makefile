# Repro convenience targets.  PYTHONPATH is injected everywhere so targets
# work from a clean checkout with no install step.

PY := PYTHONPATH=src python

# ruff format is adopted incrementally: new code must be format-clean, the
# pre-lint tree is only `ruff check`ed (see README.md §CI)
FMT_PATHS := src/repro/serve benchmarks/serve_bench.py \
             benchmarks/check_regress.py tests/test_serve_engine.py \
             tests/test_chaos.py tests/test_recovery.py tests/conftest.py

# acceptance matrix for the chaos suite (make test-chaos); override like
# CHAOS_EPISODES=1 CHAOS_SEED=<seed> to replay one failing episode
CHAOS_EPISODES ?= 200
# crash-restart episodes are pricier (each compiles a fresh engine pair)
RECOVERY_EPISODES ?= 6
# seeded silent-data-corruption episodes (make test-sdc); override like
# SDC_EPISODES=1 SDC_SEED=<seed> to replay one failing episode
SDC_EPISODES ?= 4

.PHONY: test test-fast test-fuzz test-chaos test-recovery test-scheduler \
        test-sdc test-autotune lint validate \
        bench bench-mapper bench-simulate bench-dse bench-serve bench-check

# tier-1 verify: the full suite (matches ROADMAP.md)
test:
	$(PY) -m pytest -x -q

# skip the multi-minute system/validation tests and the randomized fuzz
# and chaos suites (CI runs those as their own named steps; `make test`
# runs all, with the chaos suite at its small in-suite episode count)
test-fast:
	$(PY) -m pytest -x -q -m "not slow and not fuzz and not chaos and not recovery and not sdc"

# seeded randomized property suites (paged-KV differential traces, serve
# fuzz).  Deterministic by default; crank locally with FUZZ_EXAMPLES=N
test-fuzz:
	$(PY) -m pytest -q -m fuzz

# seeded fault-injection episode matrix (serve/chaos.py): cancels,
# deadline storms, forced preemptions, block-pressure spikes, audited
# after every step against the unfaulted bitwise oracle
test-chaos:
	CHAOS_EPISODES=$(CHAOS_EPISODES) $(PY) -m pytest -q -m chaos

# unified-scheduler differentials by name: chunked prefill bitwise vs the
# monolithic oracle, PREFILLING observability, mid-prefill preemption /
# cancel / deadline recovery, and the nested-ServeConfig migration shim —
# CI runs this before the full suite so a scheduler regression is named
# in its own step (the same tests also run inside test/test-fast)
test-scheduler:
	$(PY) -m pytest -q tests/test_serve_engine.py \
		-k "chunk or prefill or nested or flat_kwargs or priority"

# seeded crash-restart matrix (serve/recovery.py + serve/chaos.py): kill
# the engine at a random step (sometimes corrupting the newest snapshot),
# restore from snapshot + journal, and require bitwise oracle agreement
test-recovery:
	RECOVERY_EPISODES=$(RECOVERY_EPISODES) $(PY) -m pytest -q -m recovery

# seeded silent-data-corruption matrix (serve/chaos.py bit flips against
# the abft=checksum engine): every fired compute fault must be detected
# and retried, every KV flip quarantined leak-free, and survivors must
# stay bitwise identical to the no-fault oracle
test-sdc:
	SDC_EPISODES=$(SDC_EPISODES) $(PY) -m pytest -q -m sdc

# DSE serve-planner units + the tuning-path cache fixes by name: the
# serveplan sweep/cache/calibration suite, the tile-cache validation
# fallback, and the SweepCache / iso-throughput regressions (the same
# tests also run inside test/test-fast)
test-autotune:
	$(PY) -m pytest -q tests/test_autotune.py tests/test_dse.py \
		-k "not slow"

lint:
	ruff check .
	ruff format --check $(FMT_PATHS)

# the model==simulator oracle (CI smoke job)
validate:
	$(PY) -m benchmarks.run --only validation

# guard the committed BENCH_*.json speedups against silent regression
bench-check:
	$(PY) -m benchmarks.check_regress

# all perf benchmarks: BENCH_{mapper,simulate,dse,serve}.json
bench: bench-mapper bench-simulate bench-dse bench-serve

bench-mapper:
	$(PY) -m benchmarks.perf_compare --mapper

bench-simulate:
	$(PY) -m benchmarks.perf_compare --simulate

bench-dse:
	$(PY) -m benchmarks.perf_compare --dse

bench-serve:
	$(PY) -m benchmarks.serve_bench
