# Repro convenience targets.  PYTHONPATH is injected everywhere so targets
# work from a clean checkout with no install step.

PY := PYTHONPATH=src python

.PHONY: test test-fast bench bench-mapper bench-simulate bench-dse

# tier-1 verify: the full suite (matches ROADMAP.md)
test:
	$(PY) -m pytest -x -q

# skip the multi-minute system/validation tests
test-fast:
	$(PY) -m pytest -x -q -m "not slow"

# all perf benchmarks: BENCH_mapper.json, BENCH_simulate.json, BENCH_dse.json
bench: bench-mapper bench-simulate bench-dse

bench-mapper:
	$(PY) -m benchmarks.perf_compare --mapper

bench-simulate:
	$(PY) -m benchmarks.perf_compare --simulate

bench-dse:
	$(PY) -m benchmarks.perf_compare --dse
