"""Fig 14: the efficient optimizer vs the two baselines on all 9 benchmarks.

Paper claims: up to 3.5x / 2.7x / 4.2x energy gain for VGG-16 / GoogLeNet /
MobileNet, up to 1.6x for LSTMs, up to 1.8x for MLPs, vs an Eyeriss-like
C|K baseline at equal throughput; TOPs/W in the 0.35-1.85 range.
"""

from __future__ import annotations


from benchmarks.common import cached_optimize_layer, network_energy
from repro.core import ArraySpec
from repro.core.networks import PAPER_BENCHMARKS
from repro.core.optimizer import (
    HardwareConfig,
    candidate_hierarchies,
    eyeriss_like,
)

ARR = ArraySpec(dims=(16, 16))


def optimized_config(layers, beam: int = 10, two_level_rf: bool = True):
    """Obs1+Obs2-pruned search over hierarchies, shared across layers."""
    best = None
    for hw in candidate_hierarchies(ARR, two_level_rf=two_level_rf):
        try:
            e = network_energy(layers, hw, beam)
        except ValueError:
            continue
        if best is None or e < best[0]:
            best = (e, hw)
    return best


def tops_per_watt(layers, hw, beam: int = 10, freq: float = 400e6) -> float:
    cycles = sum(
        cached_optimize_layer(n, hw, beam).report.cycles for n in layers
    )
    energy = network_energy(layers, hw, beam)
    macs = sum(n.macs() for n in layers)
    secs = cycles / freq
    watts = energy * 1e-12 / secs
    return (2 * macs / secs) / watts / 1e12


def main(beam: int = 10, benchmarks=None):
    base_hw = eyeriss_like()
    names = benchmarks or list(PAPER_BENCHMARKS)
    for name in names:
        layers = PAPER_BENCHMARKS[name]()
        base = network_energy(layers, base_hw, beam)
        opt = optimized_config(layers, beam)
        if opt is None:
            print(f"fig14,{name},NO_FEASIBLE")
            continue
        e_opt, hw = opt
        print(
            f"fig14,{name},baseline={base/1e6:.0f}uJ,opt={e_opt/1e6:.0f}uJ,"
            f"gain={base/e_opt:.2f}x,hw={hw.name},"
            f"tops_w={tops_per_watt(layers, hw, beam):.2f}"
        )


if __name__ == "__main__":
    main()
