"""Fig 8 analogue: energy across dataflow choices with optimal blocking.

Paper claim: with optimal loop blocking + replication, many dataflows land
within a small band of the best (Obs 1).  We sweep all 2-loop primary
dataflows (with replication fill) on AlexNet CONV3 and GoogLeNet 4C3R for
three hardware configs and report the energy spread.
"""

from __future__ import annotations

from repro.core import ArraySpec, enumerate_dataflows, search_blocking
from repro.core.networks import alexnet_conv3, googlenet_4c3r
from repro.core.optimizer import HardwareConfig


def hw_configs():
    arr = ArraySpec(dims=(16, 16))
    return [
        HardwareConfig("eyeriss-512B", arr, (512,), (128 * 1024,)),
        HardwareConfig("small-rf-64B", arr, (64,), (128 * 1024,)),
        HardwareConfig("big-buf-256K", arr, (64,), (256 * 1024,)),
    ]


def run(layer_name: str = "conv3", batch: int = 16, beam: int = 12,
        replication: bool = True):
    nest = alexnet_conv3(batch) if layer_name == "conv3" else googlenet_4c3r(batch)
    rows = []
    for hw in hw_configs():
        energies = {}
        for df in enumerate_dataflows(nest, hw.array, replication=replication):
            try:
                res = search_blocking(
                    nest, hw.levels(), hw.array, df, beam=beam
                )
            except ValueError:
                continue
            energies[df.label()] = res.best.energy_pj
        best = min(energies.values())
        within_2x = sum(1 for e in energies.values() if e <= 2 * best)
        rows.append(
            dict(
                hw=hw.name,
                n_dataflows=len(energies),
                best_uj=best / 1e6,
                median_over_best=sorted(energies.values())[len(energies) // 2]
                / best,
                frac_within_2x=within_2x / len(energies),
                energies=energies,
            )
        )
    return rows


def main():
    for layer in ("conv3", "4c3r"):
        for row in run(layer):
            print(
                f"fig8,{layer},{row['hw']},best={row['best_uj']:.0f}uJ,"
                f"median/best={row['median_over_best']:.2f},"
                f"within2x={row['frac_within_2x']:.2f},"
                f"n={row['n_dataflows']}"
            )


if __name__ == "__main__":
    main()
